//! Serve-path parity suite: the fused packed forward must agree with the
//! dense `q_deq` reference **bit-for-bit** (0 ULP) for every init method
//! that produces a quantization state, across bit widths {2,3,4,8}, group
//! sizes (including non-divisors) and ragged shapes; the batched kernel —
//! including MIXED-ADAPTER batches served through the grouped path — must
//! be bit-identical to serial single-adapter calls; and the engine must
//! return the same bits as calling the kernel directly, whatever mix of
//! adapters a micro-batch carries.
//!
//! Contract recap (see `rust/src/serve/packed.rs` module docs): per output
//! element the fused kernel accumulates contributions in ascending input-
//! row order with one rounding per multiply-add and the exact dequant op
//! sequence of `QuantState::dequantize`, so packed-vs-dense is exact
//! equality, not a tolerance. Only the comparison against a fully *dense
//! effective weight* (`q_deq + A·Bᵀ` materialized, different accumulation
//! order) is tolerance-based: ≤ 1e-10 relative on these scales.

use cloq::coordinator::quantize::quantize_init;
use cloq::linalg::{matmul_nt, matvec_t, syrk_t, Matrix};
use cloq::lowrank::{init_layer, InitConfig, LoraPair, Method};
use cloq::quant::{quantize_nf, quantize_rtn, QuantState};
use cloq::serve::{AdapterSet, PackedLayer, PackedModel, Request, ServeEngine};
use cloq::util::prng::Rng;

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (u, v)) in a.iter().zip(b).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}: element {k}: {u} vs {v}");
    }
}

fn rand_pair(m: usize, n: usize, r: usize, rng: &mut Rng) -> LoraPair {
    LoraPair::new(Matrix::randn(m, r, 0.1, rng), Matrix::randn(n, r, 0.1, rng))
}

#[test]
fn fused_matches_dense_for_every_state_producing_method() {
    // Ragged on purpose: 70 rows ∤ 32, 37 cols ∤ any per-word count.
    let (m, n, r) = (70usize, 37usize, 6usize);
    let mut rng = Rng::new(500);
    let x_cal = Matrix::randn(2 * m, m, 1.0, &mut rng);
    let h = syrk_t(&x_cal);
    let w = Matrix::randn(m, n, 0.3, &mut rng);

    for method in [Method::QLora, Method::GptqLora, Method::LoftQ, Method::CLoQ] {
        for bits in [2u32, 3, 4] {
            for gs in [32usize, 64] {
                let mut cfg = InitConfig::new(method, bits, r);
                cfg.group_size = gs;
                let li = init_layer(&w, Some(&h), &cfg, &mut rng);
                let (layer, pair) = PackedLayer::from_layer_init("l", method, &li).unwrap();
                let x = rng.gauss_vec(m);
                let fused = layer.forward(&x, Some(&pair));
                let dense = layer.dense_reference_forward(&li.q_deq, &x, Some(&pair));
                assert_bits_eq(&fused, &dense, &format!("{method:?} bits={bits} gs={gs}"));
            }
        }
    }
}

#[test]
fn fused_matches_dense_at_8_bit_and_tiny_groups() {
    // 8-bit INT grid (4 codes per word) plus group sizes 1 and a
    // non-divisor 7 — the packed row/group indexing edge cases.
    let mut rng = Rng::new(501);
    for &(m, n) in &[(1usize, 1usize), (10, 3), (33, 10), (64, 64)] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        for bits in [2u32, 3, 4, 8] {
            for gs in [1usize, 7, 32] {
                let q = quantize_rtn(&w, bits, gs);
                let q_deq = q.dequantize();
                let pair = rand_pair(m, n, 3.min(m), &mut rng);
                let layer = PackedLayer::from_state("l", &QuantState::Int(q)).unwrap();
                let x = rng.gauss_vec(m);
                assert_bits_eq(
                    &layer.forward(&x, Some(&pair)),
                    &layer.dense_reference_forward(&q_deq, &x, Some(&pair)),
                    &format!("{m}x{n} bits={bits} gs={gs}"),
                );
            }
        }
    }
}

#[test]
fn nf_codebook_layers_are_bit_exact_too() {
    // QLoRA's NF state rides the codebook path (levels table + absmax), not
    // the INT grid — same exactness contract.
    let mut rng = Rng::new(502);
    let (m, n) = (50usize, 21usize);
    let w = Matrix::randn(m, n, 0.3, &mut rng);
    for bits in [2u32, 3, 4] {
        let q = quantize_nf(&w, bits, 16);
        let q_deq = q.dequantize();
        let pair = rand_pair(m, n, 4, &mut rng);
        let layer = PackedLayer::from_state("nf", &QuantState::Nf(q)).unwrap();
        let x = rng.gauss_vec(m);
        assert_bits_eq(
            &layer.forward(&x, Some(&pair)),
            &layer.dense_reference_forward(&q_deq, &x, Some(&pair)),
            &format!("nf bits={bits}"),
        );
    }
}

#[test]
fn batched_forward_bit_identical_to_serial() {
    let mut rng = Rng::new(503);
    let (m, n) = (48usize, 19usize);
    let w = Matrix::randn(m, n, 0.3, &mut rng);
    for bits in [2u32, 3, 4, 8] {
        let q = quantize_rtn(&w, bits, 16);
        let pair = rand_pair(m, n, 5, &mut rng);
        let layer = PackedLayer::from_state("l", &QuantState::Int(q)).unwrap();
        for batch in [1usize, 2, 7, 16] {
            let xs = Matrix::randn(batch, m, 1.0, &mut rng);
            let ys = layer.forward_batch(&xs, Some(&pair));
            for bi in 0..batch {
                assert_bits_eq(
                    ys.row(bi),
                    &layer.forward(xs.row(bi), Some(&pair)),
                    &format!("bits={bits} batch={batch} row={bi}"),
                );
            }
        }
    }
}

#[test]
fn mixed_adapter_batch_bit_identical_to_serial_per_adapter() {
    // THE multi-tenant acceptance criterion: a batch mixing several
    // adapters (and base-only rows) through the grouped kernel must give
    // every row the same bits as a serial single-adapter forward — for
    // every adapter in the mix, at every bit width, including interleaved
    // (worst-case grouping) orders.
    let mut rng = Rng::new(509);
    let (m, n) = (44usize, 23usize);
    let w = Matrix::randn(m, n, 0.3, &mut rng);
    for bits in [2u32, 4, 8] {
        let layer =
            PackedLayer::from_state("l", &QuantState::Int(quantize_rtn(&w, bits, 16))).unwrap();
        let pairs: Vec<LoraPair> =
            (0..3).map(|k| rand_pair(m, n, 2 + k, &mut rng)).collect();
        let batch = 11usize;
        let xs = Matrix::randn(batch, m, 1.0, &mut rng);
        // Interleaved: p0, p1, p2, none, p0, p1, ... — maximal group count.
        let slots: Vec<Option<&LoraPair>> =
            (0..batch).map(|bi| if bi % 4 == 3 { None } else { Some(&pairs[bi % 4]) }).collect();
        let ys = layer.forward_batch_grouped(&xs, &slots);
        for bi in 0..batch {
            assert_bits_eq(
                ys.row(bi),
                &layer.forward(xs.row(bi), slots[bi]),
                &format!("bits={bits} row={bi}"),
            );
        }
    }
}

#[test]
fn fused_vs_materialized_effective_weight_within_tolerance() {
    // Different accumulation order ⇒ fp tolerance, not bit equality:
    // y_eff = (q_deq + A·Bᵀ)ᵀ x folds the LoRA delta into every madd.
    let mut rng = Rng::new(504);
    let (m, n) = (64usize, 40usize);
    let x_cal = Matrix::randn(2 * m, m, 1.0, &mut rng);
    let h = syrk_t(&x_cal);
    let w = Matrix::randn(m, n, 0.3, &mut rng);
    let mut cfg = InitConfig::new(Method::CLoQ, 3, 8);
    cfg.group_size = 32;
    let li = init_layer(&w, Some(&h), &cfg, &mut rng);
    let (layer, pair) = PackedLayer::from_layer_init("l", Method::CLoQ, &li).unwrap();
    let w_eff = li.q_deq.add(&matmul_nt(&li.a, &li.b));
    let x = rng.gauss_vec(m);
    let fused = layer.forward(&x, Some(&pair));
    let dense_eff = matvec_t(&w_eff, &x);
    let scale = dense_eff.iter().fold(1.0f64, |s, v| s.max(v.abs()));
    for (k, (u, v)) in fused.iter().zip(&dense_eff).enumerate() {
        assert!(
            (u - v).abs() <= 1e-10 * scale,
            "element {k}: {u} vs {v} (scale {scale})"
        );
    }
}

#[test]
fn engine_returns_the_same_bits_as_the_kernel_across_adapters() {
    // Requests spread over two registered tenants plus base-only, batched
    // however the engine likes: every response must be bit-identical to a
    // direct single-adapter kernel call.
    let mut rng = Rng::new(505);
    let (m, n) = (32usize, 12usize);
    let w = Matrix::randn(m, n, 0.3, &mut rng);
    let q = QuantState::Int(quantize_rtn(&w, 4, 8));
    let layer = PackedLayer::from_state("lin", &q).unwrap();
    let pairs = [rand_pair(m, n, 2, &mut rng), rand_pair(m, n, 3, &mut rng)];
    let xs: Vec<Vec<f64>> = (0..24).map(|_| rng.gauss_vec(m)).collect();
    let slot = |k: usize| match k % 3 {
        2 => None,
        t => Some(t),
    };
    let direct: Vec<Vec<f64>> = xs
        .iter()
        .enumerate()
        .map(|(k, x)| layer.forward(x, slot(k).map(|t| &pairs[t])))
        .collect();

    let engine = ServeEngine::builder(PackedModel::new(vec![layer]))
        .workers(3)
        .max_batch(8)
        .build()
        .unwrap();
    let lin = engine.layer("lin").unwrap();
    let mut tids = Vec::new();
    for (t, pair) in pairs.iter().enumerate() {
        let set = AdapterSet::from_pairs(
            &format!("t{t}"),
            vec![("lin".to_string(), pair.clone())],
        )
        .unwrap();
        tids.push(engine.register_adapter(set).unwrap().id);
    }
    let reqs: Vec<Request> = xs
        .into_iter()
        .enumerate()
        .map(|(k, x)| match slot(k) {
            None => Request::base(lin, x),
            Some(t) => Request::with_adapter(lin, tids[t], x),
        })
        .collect();
    let tickets = engine.submit_all(reqs);
    for (k, t) in tickets.into_iter().enumerate() {
        let resp = t.wait().unwrap();
        assert_bits_eq(&resp.y, &direct[k], &format!("request {k}"));
        assert!(resp.queue_s >= 0.0 && resp.compute_s >= 0.0);
        assert!(resp.adapter_groups >= 1);
    }
    let stats = engine.shutdown();
    assert_eq!(stats.requests, 24);
    assert!(stats.batches <= 24);
    assert!(stats.max_batch_seen >= 2, "burst of 24 must coalesce: {stats:?}");
    assert!(
        stats.mixed_batches >= 1,
        "a one-layer model with 3 tenants must form mixed batches: {stats:?}"
    );
}

#[test]
fn lora16_layers_are_rejected_with_the_method_named() {
    let mut rng = Rng::new(506);
    let w = Matrix::randn(16, 8, 0.3, &mut rng);
    let li = init_layer(&w, None, &InitConfig::new(Method::Lora16, 16, 2), &mut rng);
    let err = PackedLayer::from_layer_init("fp", Method::Lora16, &li).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("'fp'"), "{msg}");
    assert!(msg.contains("LoRA"), "error must name the method: {msg}");
    assert!(msg.contains("re-grid"), "error must say what to do: {msg}");
}

#[test]
fn model_init_exact_state_serves_bit_identically_to_base_q() {
    // End-to-end through the coordinator: quantize_init's `exact` states
    // (keep_exact = true), packed via PackedModel::from_model_init, must
    // serve the same numbers as the dense base the trainer sees
    // (f32-rounded, since base_q is the lowered f32 store) — and
    // bit-identical to the f64 q_deq path.
    let (man, base, grams) = synth::model(2, 8, 12, 2, 507);
    let mut cfg = InitConfig::new(Method::CLoQ, 3, 2);
    cfg.group_size = 8;
    let init = quantize_init(&man, &base, Some(&grams), &cfg, 99, 2, true).unwrap();
    let (packed, set) = PackedModel::from_model_init(&init, "init").unwrap();
    let exact = init.exact.as_ref().unwrap();
    assert_eq!(packed.layers.len(), exact.len());
    assert_eq!(set.len(), exact.len());
    let mut rng = Rng::new(508);
    for (name, qs) in exact {
        let layer = packed.layer(name).unwrap();
        let pair = set.get(name).unwrap();
        let q_deq = qs.dequantize();
        // Adapters in the store are f32; widening is exact, so the packed
        // layer's forward equals the dense reference built from the same
        // widened adapters.
        let x = rng.gauss_vec(layer.rows);
        let fused = layer.forward(&x, Some(pair));
        let dense = layer.dense_reference_forward(&q_deq, &x, Some(pair));
        for (u, v) in fused.iter().zip(&dense) {
            assert_eq!(u.to_bits(), v.to_bits(), "layer {name}");
        }
    }
}

/// In-memory manifest/base/grams builder (mirrors prop_coordinator.rs).
mod synth {
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    use cloq::coordinator::calibrate::GramSet;
    use cloq::linalg::{syrk_t, Matrix};
    use cloq::model::{EntrySpec, Manifest, ModelConfig, ParamStore, TensorSpec};
    use cloq::runtime::{Dtype, Tensor};
    use cloq::util::prng::Rng;

    pub fn model(
        n_layers: usize,
        d_model: usize,
        d_ff: usize,
        rank: usize,
        seed: u64,
    ) -> (Manifest, ParamStore, GramSet) {
        let config = ModelConfig {
            name: "synth".to_string(),
            vocab: 64,
            d_model,
            n_layers,
            n_heads: 2,
            d_ff,
            seq: 8,
            batch: 2,
            rank,
            group_size: 16,
        };
        let mut inputs = Vec::new();
        for l in 0..n_layers {
            for (name, din, dout) in config.linear_specs(l) {
                inputs.push(TensorSpec { name, shape: vec![din, dout], dtype: Dtype::F32 });
            }
        }
        for l in 0..n_layers {
            for (name, din, dout) in config.linear_specs(l) {
                inputs.push(TensorSpec {
                    name: format!("{name}.A"),
                    shape: vec![din, rank],
                    dtype: Dtype::F32,
                });
                inputs.push(TensorSpec {
                    name: format!("{name}.B"),
                    shape: vec![dout, rank],
                    dtype: Dtype::F32,
                });
            }
        }
        inputs.push(TensorSpec {
            name: "tokens".to_string(),
            shape: vec![2, 8],
            dtype: Dtype::I32,
        });
        inputs.push(TensorSpec { name: "mask".to_string(), shape: vec![2, 8], dtype: Dtype::F32 });
        let entry = EntrySpec {
            file: "eval_loss.hlo.txt".to_string(),
            inputs,
            outputs: vec![
                TensorSpec { name: "loss_sum".to_string(), shape: vec![], dtype: Dtype::F32 },
                TensorSpec { name: "count".to_string(), shape: vec![], dtype: Dtype::F32 },
            ],
        };
        let mut entrypoints = BTreeMap::new();
        entrypoints.insert("eval_loss".to_string(), entry);
        let man = Manifest { dir: PathBuf::from("."), config, entrypoints };

        let mut rng = Rng::new(seed);
        let mut base = ParamStore::new();
        let mut grams = GramSet::new();
        for l in 0..n_layers {
            for (name, din, dout) in man.config.linear_specs(l) {
                base.insert(&name, Tensor::from_matrix(&Matrix::randn(din, dout, 0.3, &mut rng)));
                let x = Matrix::randn(din * 2 + 8, din, 1.0, &mut rng);
                grams.insert(name, syrk_t(&x));
            }
        }
        (man, base, grams)
    }
}

//! Golden tests for the packed serving artifacts through the unified
//! [`ArtifactStore`]: save → open must reproduce the exact quantization
//! state **byte-identically** (codes, scales/zeros, codebook
//! levels/absmax) and adapter pairs exactly, and a **bit-identical**
//! packed forward, across bits {2,3,4,8} × group sizes {32,64}; truncated
//! and bit-flipped files must fail with typed `ServeError::Artifact`
//! errors whose `kind` classifies the corruption and whose message names
//! the offending layer; and a legacy v1 file must open as
//! `Artifact::LegacyV1` with bit-identical forward outputs.
//!
//! The zero-copy v3 format gets two more guarantees: a v2 → v3 migration
//! roundtrip is byte-exact (states, forwards, and the v3 file itself are
//! save-stable), and an exhaustive single-bit corruption sweep proves
//! every byte of a v3 file is either covered by a checksum (header,
//! codes, params — the flip is detected with a typed error naming the
//! layer, eagerly or on first mapped touch) or provably outside the
//! checksummed payload (zero alignment padding — the flip changes no
//! served bit).

use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_nf, quantize_rtn, QuantState};
use cloq::serve::{
    AdapterSet, Artifact, ArtifactErrorKind, ArtifactStore, PackedLayer, PackedModel,
    ServeError, V1_ADAPTER_ID,
};
use cloq::util::prng::Rng;

fn store(tag: &str) -> ArtifactStore {
    ArtifactStore::at(
        std::env::temp_dir().join(format!("cloq_golden_{tag}_{}", std::process::id())),
    )
}

fn assert_state_bytes_identical(a: &QuantState, b: &QuantState, what: &str) {
    match (a, b) {
        (QuantState::Int(x), QuantState::Int(y)) => {
            assert_eq!(x.bits, y.bits, "{what}: bits");
            assert_eq!(x.group_size, y.group_size, "{what}: group size");
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{what}: shape");
            assert_eq!(x.codes, y.codes, "{what}: codes");
            let eq_bits = |p: &Matrix, q: &Matrix| {
                p.data.iter().map(|v| v.to_bits()).eq(q.data.iter().map(|v| v.to_bits()))
            };
            assert!(eq_bits(&x.scales, &y.scales), "{what}: scales");
            assert!(eq_bits(&x.zeros, &y.zeros), "{what}: zeros");
        }
        (QuantState::Nf(x), QuantState::Nf(y)) => {
            assert_eq!(x.bits, y.bits, "{what}: bits");
            assert_eq!(x.block_size, y.block_size, "{what}: block size");
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{what}: shape");
            assert_eq!(x.codes, y.codes, "{what}: codes");
            assert!(
                x.levels.iter().map(|v| v.to_bits()).eq(y.levels.iter().map(|v| v.to_bits())),
                "{what}: levels"
            );
            assert!(
                x.absmax
                    .data
                    .iter()
                    .map(|v| v.to_bits())
                    .eq(y.absmax.data.iter().map(|v| v.to_bits())),
                "{what}: absmax"
            );
        }
        _ => panic!("{what}: state kind changed across the roundtrip"),
    }
}

fn assert_pair_exact(a: &LoraPair, b: &LoraPair, what: &str) {
    assert!(
        a.a.data.iter().map(|v| v.to_bits()).eq(b.a.data.iter().map(|v| v.to_bits())),
        "{what}: adapter A"
    );
    assert!(
        a.b.data.iter().map(|v| v.to_bits()).eq(b.b.data.iter().map(|v| v.to_bits())),
        "{what}: adapter B"
    );
}

/// One layer per (bits, group size) point, mixed grid/codebook, ragged
/// shapes so the packed rows have slack bits. Returns the base model, one
/// adapter set covering it, and the original quantizer states.
fn build_model(seed: u64) -> (PackedModel, AdapterSet, Vec<QuantState>) {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut pairs = Vec::new();
    let mut states = Vec::new();
    for &bits in &[2u32, 3, 4, 8] {
        for &gs in &[32usize, 64] {
            let (m, n) = (70usize + bits as usize, 37usize + gs / 16);
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let qs = if bits <= 4 && gs == 32 {
                QuantState::Nf(quantize_nf(&w, bits.max(2), gs))
            } else {
                QuantState::Int(quantize_rtn(&w, bits, gs))
            };
            let r = 4;
            let a = Matrix::randn(m, r, 0.1, &mut rng);
            let b = Matrix::randn(n, r, 0.1, &mut rng);
            let name = format!("blk.b{bits}.g{gs}");
            layers.push(PackedLayer::from_state(&name, &qs).unwrap());
            pairs.push((name, LoraPair::new(a, b)));
            states.push(qs);
        }
    }
    let set = AdapterSet::from_pairs("tenant", pairs).unwrap();
    (PackedModel::new(layers), set, states)
}

#[test]
fn roundtrip_byte_identical_states_and_bit_identical_forward() {
    let st = store("roundtrip");
    let (model, set, states) = build_model(600);
    let bpath = st.save_base(&model, "base.cloqpkd2").unwrap();
    let apath = st.save_adapter(&set, "tenant.cloqadp").unwrap();
    let loaded = st.load_base("base.cloqpkd2").unwrap();
    let lset = st.load_adapter("tenant.cloqadp").unwrap();
    assert_eq!(loaded.layers.len(), model.layers.len());
    assert_eq!(lset.id(), set.id());
    assert_eq!(lset.len(), set.len());

    let mut rng = Rng::new(601);
    for ((orig, got), state) in model.layers.iter().zip(&loaded.layers).zip(&states) {
        assert_eq!(orig.name, got.name);
        assert_eq!(orig.packed, got.packed, "{}: packed words", orig.name);
        // The reloaded state reproduces the ORIGINAL quantizer output
        // byte-for-byte — not just something that dequantizes closely.
        assert_state_bytes_identical(state, &got.to_state().unwrap(), &orig.name);
        // Adapters survive exactly too.
        assert_pair_exact(set.get(&orig.name).unwrap(), lset.get(&orig.name).unwrap(), &orig.name);
        // And the serving numbers are the same bits.
        let x = rng.gauss_vec(orig.rows);
        let ya = orig.forward(&x, set.get(&orig.name));
        let yb = got.forward(&x, lset.get(&got.name));
        for (u, v) in ya.iter().zip(&yb) {
            assert_eq!(u.to_bits(), v.to_bits(), "{}: forward", orig.name);
        }
    }

    // Save → load → save is byte-stable for both artifacts (no hidden
    // nondeterminism).
    let bpath2 = st.save_base(&loaded, "base2.cloqpkd2").unwrap();
    assert_eq!(std::fs::read(&bpath).unwrap(), std::fs::read(&bpath2).unwrap());
    let apath2 = st.save_adapter(&lset, "tenant2.cloqadp").unwrap();
    assert_eq!(std::fs::read(&apath).unwrap(), std::fs::read(&apath2).unwrap());
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn v1_artifact_opens_as_legacy_with_identical_bits() {
    // The legacy path: a CLOQPKD1 file (adapters embedded per layer)
    // opens as Artifact::LegacyV1 — base + one AdapterSet named "v1" —
    // and forwards through the converted halves are byte-for-byte what
    // the embedded layout produced.
    let st = store("v1shim");
    let (model, set, _) = build_model(610);
    st.save_legacy_v1(&model, &set, "legacy.cloqpkd").unwrap();
    let (loaded, lset) = match st.open("legacy.cloqpkd").unwrap() {
        Artifact::LegacyV1 { model, adapters } => (model, adapters),
        other => panic!("expected LegacyV1, got {}", other.kind_name()),
    };
    assert_eq!(lset.id(), V1_ADAPTER_ID);
    assert_eq!(loaded.layers.len(), model.layers.len());
    assert_eq!(lset.len(), model.layers.len());
    let mut rng = Rng::new(611);
    for (orig, got) in model.layers.iter().zip(&loaded.layers) {
        assert_eq!(orig.name, got.name);
        assert_eq!(orig.packed, got.packed, "{}: packed words", orig.name);
        assert_pair_exact(set.get(&orig.name).unwrap(), lset.get(&got.name).unwrap(), &orig.name);
        let x = rng.gauss_vec(orig.rows);
        let ya = orig.forward(&x, set.get(&orig.name));
        let yb = got.forward(&x, lset.get(&got.name));
        for (u, v) in ya.iter().zip(&yb) {
            assert_eq!(u.to_bits(), v.to_bits(), "{}: forward through the legacy path", orig.name);
        }
    }
    // A v2 base file through the same entry point is a plain Base, and
    // the typed base accessor refuses the legacy file with a pointer.
    st.save_base(&model, "base.cloqpkd2").unwrap();
    assert!(matches!(st.open("base.cloqpkd2").unwrap(), Artifact::Base(_)));
    let err = st.load_base("legacy.cloqpkd").unwrap_err();
    assert!(matches!(err, ServeError::Unsupported { .. }), "{err:?}");
    assert!(format!("{err}").contains("LegacyV1"), "{err}");
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn truncated_artifact_names_the_layer_it_died_in() {
    let st = store("trunc");
    let (model, _, _) = build_model(602);
    let path = st.save_base(&model, "base.cloqpkd2").unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // Cut in the middle of the file: some layers load, then a typed
    // Truncated error naming the layer index.
    let cut = bytes.len() / 2;
    std::fs::write(st.path("trunc.cloqpkd2"), &bytes[..cut]).unwrap();
    let err = st.open("trunc.cloqpkd2").unwrap_err();
    assert!(
        matches!(
            &err,
            ServeError::Artifact { kind: ArtifactErrorKind::Truncated, .. }
        ),
        "{err:?}"
    );
    assert!(format!("{err}").contains("layer "), "{err}");

    // Cut just before the final checksum: the LAST layer is named.
    std::fs::write(st.path("trunc2.cloqpkd2"), &bytes[..bytes.len() - 2]).unwrap();
    let err = st.open("trunc2.cloqpkd2").unwrap_err();
    assert!(
        matches!(
            &err,
            ServeError::Artifact { kind: ArtifactErrorKind::Truncated, .. }
        ),
        "{err:?}"
    );
    let msg = format!("{err}");
    let n = model.layers.len();
    assert!(
        msg.contains(&format!("layer {}/{n}", n - 1)),
        "expected the last layer named: {msg}"
    );
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn flipped_bit_is_caught_by_the_layer_checksum() {
    let st = store("flip");
    let (model, set, _) = build_model(603);
    let bpath = st.save_base(&model, "base.cloqpkd2").unwrap();
    let apath = st.save_adapter(&set, "tenant.cloqadp").unwrap();

    // Flip one bit at several depths in BOTH artifact kinds; every open
    // must fail with a typed Artifact error that names a layer (never
    // load garbage silently). Offsets start past each header so the flip
    // lands in the CRC-framed record region.
    // Headers: base = magic(8)+version(4)+count(4);
    // adapter = magic(8)+version(4)+id_len(4)+id+count(4).
    let cases: [(&std::path::Path, usize, &str); 2] =
        [(&bpath, 16, "base"), (&apath, 12 + 4 + set.id().len() + 4, "adapter")];
    for (path, header, kind) in cases {
        let orig = std::fs::read(path).unwrap();
        for &frac in &[0.3f64, 0.6, 0.9] {
            let mut bytes = orig.clone();
            let span = bytes.len() - header - 4;
            let pos = header + (span as f64 * frac) as usize;
            bytes[pos] ^= 0x01;
            let name = format!("flip_{kind}_{pos}");
            std::fs::write(st.path(&name), &bytes).unwrap();
            match st.open(&name) {
                Err(e) => {
                    assert!(
                        matches!(e, ServeError::Artifact { .. }),
                        "{kind} pos {pos}: {e:?}"
                    );
                    let msg = format!("{e}");
                    assert!(msg.contains("layer "), "{kind} pos {pos}: {msg}");
                }
                Ok(_) => {
                    // This format has no padding: every byte is covered by
                    // a length field, a checksum, or checksummed payload.
                    panic!("{kind}: flipped byte at {pos} loaded silently");
                }
            }
        }
    }
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn unpack_error_path_reaches_the_loader_as_malformed() {
    // A layer advertising more packed words than its payload carries is a
    // structural (Malformed) error naming the field, not a panic.
    let st = store("struct");
    let (model, _, _) = build_model(604);
    let path = st.save_base(&model, "base.cloqpkd2").unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Header: magic(8) + version(4) + count(4). First layer record:
    // len(8) + payload. Payload: name_len(4) + name + kind(1) + bits(4) …
    let name_len = u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
    let bits_off = 24 + 4 + name_len + 1;
    let old_bits = u32::from_le_bytes(bytes[bits_off..bits_off + 4].try_into().unwrap());
    assert!((1..=8).contains(&old_bits), "offset math drifted: bits={old_bits}");
    // Lie about the bit width: the packed word count no longer matches.
    bytes[bits_off] = if old_bits == 2 { 4 } else { 2 };
    // Fix the CRC so we hit the structural check, not the checksum.
    let len = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let crc = cloq::serve::crc32(&bytes[24..24 + len]);
    bytes[24 + len..24 + len + 4].copy_from_slice(&crc.to_le_bytes());
    std::fs::write(st.path("lied.cloqpkd2"), &bytes).unwrap();
    let err = st.open("lied.cloqpkd2").unwrap_err();
    assert!(
        matches!(
            &err,
            ServeError::Artifact { kind: ArtifactErrorKind::Malformed, layer: Some(_), .. }
        ),
        "{err:?}"
    );
    let msg = format!("{err}");
    assert!(msg.contains("layer 0"), "{msg}");
    assert!(msg.contains("packed words") || msg.contains("needs"), "{msg}");
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn v2_to_v3_migration_roundtrip_is_byte_exact() {
    // The migration path a deployment takes: load the v2 base it already
    // ships, save it as zero-copy v3, serve from the mapped file. Every
    // hop must be byte-exact — quantizer states, packed words, forwards —
    // and the v3 format itself must be save-stable (save → open → save
    // reproduces the same file bytes).
    let st = store("v3rt");
    let (model, set, states) = build_model(620);
    st.save_base(&model, "base.cloqpkd2").unwrap();
    let v2 = st.load_base("base.cloqpkd2").unwrap();
    let v3path = st.save_base_v3(&v2, "base.cloqpkd3").unwrap();

    // Both entry points read it: the autodetecting eager open and the
    // zero-copy mapped open must agree with the original to the bit.
    let eager = match st.open("base.cloqpkd3").unwrap() {
        Artifact::Base(m) => m,
        other => panic!("expected Base, got {}", other.kind_name()),
    };
    let mapped = match st.open_mapped("base.cloqpkd3").unwrap() {
        Artifact::Base(m) => m,
        other => panic!("expected Base, got {}", other.kind_name()),
    };
    let mut rng = Rng::new(621);
    for (((orig, e), m), state) in
        model.layers.iter().zip(&eager.layers).zip(&mapped.layers).zip(&states)
    {
        assert_eq!(orig.name, e.name);
        assert_eq!(orig.name, m.name);
        assert_eq!(orig.packed, e.packed, "{}: eager v3 packed words", orig.name);
        assert_eq!(orig.packed, m.packed, "{}: mapped v3 packed words", orig.name);
        m.verify().unwrap_or_else(|err| panic!("{}: clean mapped section: {err}", m.name));
        assert_state_bytes_identical(state, &m.to_state().unwrap(), &orig.name);
        let x = rng.gauss_vec(orig.rows);
        let pair = set.get(&orig.name);
        let ya = orig.forward(&x, pair);
        for (tag, got) in [("eager", e), ("mapped", m)] {
            let yb = got.forward(&x, pair);
            for (u, v) in ya.iter().zip(&yb) {
                assert_eq!(u.to_bits(), v.to_bits(), "{}: {tag} v3 forward", orig.name);
            }
        }
    }
    // Where the platform supports it, the mapped open really is zero-copy
    // (v3 sections are page-aligned, so the in-place cast always lines up).
    if cfg!(all(target_os = "linux", target_endian = "little")) {
        for l in &mapped.layers {
            assert!(l.packed.is_mapped(), "{}: expected zero-copy codes on linux", l.name);
        }
    }
    // Save-stability: re-saving either reloaded model reproduces the v3
    // file byte-for-byte (no hidden nondeterminism, mapped or eager).
    let v3b = st.save_base_v3(&eager, "base2.cloqpkd3").unwrap();
    let v3c = st.save_base_v3(&mapped, "base3.cloqpkd3").unwrap();
    let bytes = std::fs::read(&v3path).unwrap();
    assert_eq!(bytes, std::fs::read(&v3b).unwrap(), "eager reload not save-stable");
    assert_eq!(bytes, std::fs::read(&v3c).unwrap(), "mapped reload not save-stable");
    std::fs::remove_dir_all(st.dir()).ok();
}

/// Where a flipped bit lands in a v3 file, and therefore which detector
/// owns it.
#[derive(Clone, Copy, Debug, PartialEq)]
enum V3Region {
    /// Magic, version, count, directory, or dir_crc: eager and mapped
    /// opens both refuse the file before trusting any entry field.
    Header,
    /// Layer i's packed code section: the eager open refuses it; the
    /// mapped open defers to the layer's first-touch `verify()`.
    Codes(usize),
    /// Layer i's params section: decoded (and CRC-checked) eagerly on
    /// BOTH paths — params feed structural validation, so they are never
    /// served lazily.
    Params(usize),
    /// Zero alignment padding: the only unchecksummed bytes, and provably
    /// inert — no served bit may change.
    Padding,
}

/// Minimal v3 directory parse (layout mirrored from the format docs), so
/// the sweep classifies bytes from the FILE's own section table rather
/// than trusting the writer's layout code twice.
fn v3_sections(bytes: &[u8]) -> (usize, Vec<(String, (usize, usize), (usize, usize))>) {
    let u32_at = |o: usize| u32::from_le_bytes(bytes[o..o + 4].try_into().unwrap()) as usize;
    let u64_at = |o: usize| u64::from_le_bytes(bytes[o..o + 8].try_into().unwrap()) as usize;
    assert_eq!(&bytes[..8], b"CLOQPKD3");
    let n = u32_at(12);
    let mut o = 16;
    let mut secs = Vec::with_capacity(n);
    for _ in 0..n {
        let name_len = u32_at(o);
        let name = String::from_utf8(bytes[o + 4..o + 4 + name_len].to_vec()).unwrap();
        o += 4 + name_len + 1 + 4 + 24; // name, kind, bits, gs/rows/cols
        let (codes_off, codes_len) = (u64_at(o), u64_at(o + 8));
        let (params_off, params_len) = (u64_at(o + 20), u64_at(o + 28));
        o += 40; // codes off/len/crc + params off/len/crc
        assert_eq!(codes_off % 4096, 0, "'{name}': codes section not page-aligned");
        assert_eq!(params_off % 4096, 0, "'{name}': params section not page-aligned");
        secs.push((name, (codes_off, codes_len), (params_off, params_len)));
    }
    (o + 4, secs) // + dir_crc
}

#[test]
fn v3_single_bit_sweep_detects_every_flip_or_proves_the_byte_inert() {
    // Exhaustive fault model: flip one bit in EVERY byte of a small v3
    // artifact and demand a proof either way — a typed detection naming
    // the right layer (header/codes/params), or, for alignment padding,
    // bit-identical forwards through the corrupted file.
    let st = store("v3sweep");
    let mut rng = Rng::new(630);
    let w1 = Matrix::randn(8, 5, 0.3, &mut rng);
    let w2 = Matrix::randn(8, 4, 0.3, &mut rng);
    let model = PackedModel::new(vec![
        PackedLayer::from_state("wq", &QuantState::Int(quantize_rtn(&w1, 3, 8))).unwrap(),
        PackedLayer::from_state("wo", &QuantState::Nf(quantize_nf(&w2, 4, 8))).unwrap(),
    ]);
    let path = st.save_base_v3(&model, "sweep.cloqpkd3").unwrap();
    let clean = std::fs::read(&path).unwrap();
    let (header_len, secs) = v3_sections(&clean);
    assert_eq!(secs.len(), model.layers.len());

    // Reference outputs from the clean file, one probe vector per layer.
    let xs: Vec<Vec<f64>> = model.layers.iter().map(|l| rng.gauss_vec(l.rows)).collect();
    let reference: Vec<Vec<u64>> = model
        .layers
        .iter()
        .zip(&xs)
        .map(|(l, x)| l.forward(x, None).iter().map(|v| v.to_bits()).collect())
        .collect();

    let classify = |i: usize| {
        if i < header_len {
            return V3Region::Header;
        }
        for (k, (_, codes, params)) in secs.iter().enumerate() {
            if (codes.0..codes.0 + codes.1).contains(&i) {
                return V3Region::Codes(k);
            }
            if (params.0..params.0 + params.1).contains(&i) {
                return V3Region::Params(k);
            }
        }
        V3Region::Padding
    };
    let assert_names_layer = |e: &ServeError, name: &str, ctx: &str| {
        assert!(
            matches!(
                e,
                ServeError::Artifact {
                    kind: ArtifactErrorKind::ChecksumMismatch,
                    layer: Some(l),
                    ..
                } if l == name
            ),
            "{ctx}: expected ChecksumMismatch naming '{name}', got {e:?}"
        );
    };

    let mut padding = 0usize;
    for i in 0..clean.len() {
        let region = classify(i);
        let mut bytes = clean.clone();
        bytes[i] ^= 0x01;
        std::fs::write(st.path("flip.cloqpkd3"), &bytes).unwrap();
        let eager = st.open("flip.cloqpkd3");
        let mapped = st.open_mapped("flip.cloqpkd3");
        match region {
            V3Region::Header => {
                for (tag, r) in [("eager", &eager), ("mapped", &mapped)] {
                    match r {
                        Err(ServeError::Artifact { .. }) => {}
                        Err(e) => panic!("byte {i} (header, {tag}): untyped error {e:?}"),
                        Ok(a) => panic!(
                            "byte {i} (header, {tag}): corrupt header accepted as {}",
                            a.kind_name()
                        ),
                    }
                }
            }
            V3Region::Codes(k) => {
                let name = &secs[k].0;
                let ctx = format!("byte {i} (codes of '{name}', eager)");
                assert_names_layer(&eager.unwrap_err(), name, &ctx);
                match mapped {
                    // Platform without the in-place cast: codes were
                    // copied and checked eagerly on open.
                    Err(e) => assert_names_layer(&e, name, &format!("byte {i} (codes, mapped)")),
                    // Zero-copy: the open succeeds and the corruption
                    // surfaces at the corrupted layer's first touch ONLY.
                    Ok(Artifact::Base(m)) => {
                        for (j, l) in m.layers.iter().enumerate() {
                            if j == k {
                                let e = l.verify().expect_err("corrupt section verified clean");
                                assert_names_layer(
                                    &e,
                                    name,
                                    &format!("byte {i} (codes, first touch)"),
                                );
                            } else {
                                l.verify().unwrap_or_else(|e| {
                                    panic!("byte {i}: clean layer '{}' failed: {e}", l.name)
                                });
                            }
                        }
                    }
                    Ok(other) => panic!("byte {i}: wrong artifact kind {}", other.kind_name()),
                }
            }
            V3Region::Params(k) => {
                let name = &secs[k].0;
                assert_names_layer(&eager.unwrap_err(), name, &format!("byte {i} (params, eager)"));
                assert_names_layer(
                    &mapped.unwrap_err(),
                    name,
                    &format!("byte {i} (params, mapped)"),
                );
            }
            V3Region::Padding => {
                padding += 1;
                assert_eq!(clean[i], 0, "byte {i}: padding must be zero in the clean file");
                assert!(matches!(eager, Ok(Artifact::Base(_))), "byte {i}: eager refused padding");
                let m = match mapped {
                    Ok(Artifact::Base(m)) => m,
                    Ok(a) => panic!("byte {i}: padded flip opened as {}", a.kind_name()),
                    Err(e) => panic!("byte {i}: mapped open refused padding flip: {e:?}"),
                };
                // The flip is inert: every section still verifies and
                // every forward reproduces the clean file's exact bits.
                for ((l, x), want) in m.layers.iter().zip(&xs).zip(&reference) {
                    l.verify()
                        .unwrap_or_else(|e| panic!("byte {i}: '{}' failed verify: {e}", l.name));
                    let y = l.forward(x, None);
                    assert!(
                        y.iter().map(|v| v.to_bits()).eq(want.iter().copied()),
                        "byte {i}: padding flip changed '{}' forward bits",
                        l.name
                    );
                }
            }
        }
    }
    // Accounting: the checksummed regions plus padding tile the file, and
    // padding really exists (the alignment gaps this sweep proves inert).
    let checksummed: usize =
        header_len + secs.iter().map(|(_, c, p)| c.1 + p.1).sum::<usize>();
    assert_eq!(padding, clean.len() - checksummed, "region map does not tile the file");
    assert!(padding > 0, "a v3 file with page-aligned sections must contain padding");
    std::fs::remove_dir_all(st.dir()).ok();
}

//! Golden tests for the packed serving artifacts through the unified
//! [`ArtifactStore`]: save → open must reproduce the exact quantization
//! state **byte-identically** (codes, scales/zeros, codebook
//! levels/absmax) and adapter pairs exactly, and a **bit-identical**
//! packed forward, across bits {2,3,4,8} × group sizes {32,64}; truncated
//! and bit-flipped files must fail with typed `ServeError::Artifact`
//! errors whose `kind` classifies the corruption and whose message names
//! the offending layer; and a legacy v1 file must open as
//! `Artifact::LegacyV1` with bit-identical forward outputs.

use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_nf, quantize_rtn, QuantState};
use cloq::serve::{
    AdapterSet, Artifact, ArtifactErrorKind, ArtifactStore, PackedLayer, PackedModel,
    ServeError, V1_ADAPTER_ID,
};
use cloq::util::prng::Rng;

fn store(tag: &str) -> ArtifactStore {
    ArtifactStore::at(
        std::env::temp_dir().join(format!("cloq_golden_{tag}_{}", std::process::id())),
    )
}

fn assert_state_bytes_identical(a: &QuantState, b: &QuantState, what: &str) {
    match (a, b) {
        (QuantState::Int(x), QuantState::Int(y)) => {
            assert_eq!(x.bits, y.bits, "{what}: bits");
            assert_eq!(x.group_size, y.group_size, "{what}: group size");
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{what}: shape");
            assert_eq!(x.codes, y.codes, "{what}: codes");
            let eq_bits = |p: &Matrix, q: &Matrix| {
                p.data.iter().map(|v| v.to_bits()).eq(q.data.iter().map(|v| v.to_bits()))
            };
            assert!(eq_bits(&x.scales, &y.scales), "{what}: scales");
            assert!(eq_bits(&x.zeros, &y.zeros), "{what}: zeros");
        }
        (QuantState::Nf(x), QuantState::Nf(y)) => {
            assert_eq!(x.bits, y.bits, "{what}: bits");
            assert_eq!(x.block_size, y.block_size, "{what}: block size");
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{what}: shape");
            assert_eq!(x.codes, y.codes, "{what}: codes");
            assert!(
                x.levels.iter().map(|v| v.to_bits()).eq(y.levels.iter().map(|v| v.to_bits())),
                "{what}: levels"
            );
            assert!(
                x.absmax
                    .data
                    .iter()
                    .map(|v| v.to_bits())
                    .eq(y.absmax.data.iter().map(|v| v.to_bits())),
                "{what}: absmax"
            );
        }
        _ => panic!("{what}: state kind changed across the roundtrip"),
    }
}

fn assert_pair_exact(a: &LoraPair, b: &LoraPair, what: &str) {
    assert!(
        a.a.data.iter().map(|v| v.to_bits()).eq(b.a.data.iter().map(|v| v.to_bits())),
        "{what}: adapter A"
    );
    assert!(
        a.b.data.iter().map(|v| v.to_bits()).eq(b.b.data.iter().map(|v| v.to_bits())),
        "{what}: adapter B"
    );
}

/// One layer per (bits, group size) point, mixed grid/codebook, ragged
/// shapes so the packed rows have slack bits. Returns the base model, one
/// adapter set covering it, and the original quantizer states.
fn build_model(seed: u64) -> (PackedModel, AdapterSet, Vec<QuantState>) {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut pairs = Vec::new();
    let mut states = Vec::new();
    for &bits in &[2u32, 3, 4, 8] {
        for &gs in &[32usize, 64] {
            let (m, n) = (70usize + bits as usize, 37usize + gs / 16);
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let qs = if bits <= 4 && gs == 32 {
                QuantState::Nf(quantize_nf(&w, bits.max(2), gs))
            } else {
                QuantState::Int(quantize_rtn(&w, bits, gs))
            };
            let r = 4;
            let a = Matrix::randn(m, r, 0.1, &mut rng);
            let b = Matrix::randn(n, r, 0.1, &mut rng);
            let name = format!("blk.b{bits}.g{gs}");
            layers.push(PackedLayer::from_state(&name, &qs).unwrap());
            pairs.push((name, LoraPair::new(a, b)));
            states.push(qs);
        }
    }
    let set = AdapterSet::from_pairs("tenant", pairs).unwrap();
    (PackedModel::new(layers), set, states)
}

#[test]
fn roundtrip_byte_identical_states_and_bit_identical_forward() {
    let st = store("roundtrip");
    let (model, set, states) = build_model(600);
    let bpath = st.save_base(&model, "base.cloqpkd2").unwrap();
    let apath = st.save_adapter(&set, "tenant.cloqadp").unwrap();
    let loaded = st.load_base("base.cloqpkd2").unwrap();
    let lset = st.load_adapter("tenant.cloqadp").unwrap();
    assert_eq!(loaded.layers.len(), model.layers.len());
    assert_eq!(lset.id(), set.id());
    assert_eq!(lset.len(), set.len());

    let mut rng = Rng::new(601);
    for ((orig, got), state) in model.layers.iter().zip(&loaded.layers).zip(&states) {
        assert_eq!(orig.name, got.name);
        assert_eq!(orig.packed, got.packed, "{}: packed words", orig.name);
        // The reloaded state reproduces the ORIGINAL quantizer output
        // byte-for-byte — not just something that dequantizes closely.
        assert_state_bytes_identical(state, &got.to_state().unwrap(), &orig.name);
        // Adapters survive exactly too.
        assert_pair_exact(set.get(&orig.name).unwrap(), lset.get(&orig.name).unwrap(), &orig.name);
        // And the serving numbers are the same bits.
        let x = rng.gauss_vec(orig.rows);
        let ya = orig.forward(&x, set.get(&orig.name));
        let yb = got.forward(&x, lset.get(&got.name));
        for (u, v) in ya.iter().zip(&yb) {
            assert_eq!(u.to_bits(), v.to_bits(), "{}: forward", orig.name);
        }
    }

    // Save → load → save is byte-stable for both artifacts (no hidden
    // nondeterminism).
    let bpath2 = st.save_base(&loaded, "base2.cloqpkd2").unwrap();
    assert_eq!(std::fs::read(&bpath).unwrap(), std::fs::read(&bpath2).unwrap());
    let apath2 = st.save_adapter(&lset, "tenant2.cloqadp").unwrap();
    assert_eq!(std::fs::read(&apath).unwrap(), std::fs::read(&apath2).unwrap());
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn v1_artifact_opens_as_legacy_with_identical_bits() {
    // The legacy path: a CLOQPKD1 file (adapters embedded per layer)
    // opens as Artifact::LegacyV1 — base + one AdapterSet named "v1" —
    // and forwards through the converted halves are byte-for-byte what
    // the embedded layout produced.
    let st = store("v1shim");
    let (model, set, _) = build_model(610);
    st.save_legacy_v1(&model, &set, "legacy.cloqpkd").unwrap();
    let (loaded, lset) = match st.open("legacy.cloqpkd").unwrap() {
        Artifact::LegacyV1 { model, adapters } => (model, adapters),
        other => panic!("expected LegacyV1, got {}", other.kind_name()),
    };
    assert_eq!(lset.id(), V1_ADAPTER_ID);
    assert_eq!(loaded.layers.len(), model.layers.len());
    assert_eq!(lset.len(), model.layers.len());
    let mut rng = Rng::new(611);
    for (orig, got) in model.layers.iter().zip(&loaded.layers) {
        assert_eq!(orig.name, got.name);
        assert_eq!(orig.packed, got.packed, "{}: packed words", orig.name);
        assert_pair_exact(set.get(&orig.name).unwrap(), lset.get(&got.name).unwrap(), &orig.name);
        let x = rng.gauss_vec(orig.rows);
        let ya = orig.forward(&x, set.get(&orig.name));
        let yb = got.forward(&x, lset.get(&got.name));
        for (u, v) in ya.iter().zip(&yb) {
            assert_eq!(u.to_bits(), v.to_bits(), "{}: forward through the legacy path", orig.name);
        }
    }
    // A v2 base file through the same entry point is a plain Base, and
    // the typed base accessor refuses the legacy file with a pointer.
    st.save_base(&model, "base.cloqpkd2").unwrap();
    assert!(matches!(st.open("base.cloqpkd2").unwrap(), Artifact::Base(_)));
    let err = st.load_base("legacy.cloqpkd").unwrap_err();
    assert!(matches!(err, ServeError::Unsupported { .. }), "{err:?}");
    assert!(format!("{err}").contains("LegacyV1"), "{err}");
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn truncated_artifact_names_the_layer_it_died_in() {
    let st = store("trunc");
    let (model, _, _) = build_model(602);
    let path = st.save_base(&model, "base.cloqpkd2").unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // Cut in the middle of the file: some layers load, then a typed
    // Truncated error naming the layer index.
    let cut = bytes.len() / 2;
    std::fs::write(st.path("trunc.cloqpkd2"), &bytes[..cut]).unwrap();
    let err = st.open("trunc.cloqpkd2").unwrap_err();
    assert!(
        matches!(
            &err,
            ServeError::Artifact { kind: ArtifactErrorKind::Truncated, .. }
        ),
        "{err:?}"
    );
    assert!(format!("{err}").contains("layer "), "{err}");

    // Cut just before the final checksum: the LAST layer is named.
    std::fs::write(st.path("trunc2.cloqpkd2"), &bytes[..bytes.len() - 2]).unwrap();
    let err = st.open("trunc2.cloqpkd2").unwrap_err();
    assert!(
        matches!(
            &err,
            ServeError::Artifact { kind: ArtifactErrorKind::Truncated, .. }
        ),
        "{err:?}"
    );
    let msg = format!("{err}");
    let n = model.layers.len();
    assert!(
        msg.contains(&format!("layer {}/{n}", n - 1)),
        "expected the last layer named: {msg}"
    );
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn flipped_bit_is_caught_by_the_layer_checksum() {
    let st = store("flip");
    let (model, set, _) = build_model(603);
    let bpath = st.save_base(&model, "base.cloqpkd2").unwrap();
    let apath = st.save_adapter(&set, "tenant.cloqadp").unwrap();

    // Flip one bit at several depths in BOTH artifact kinds; every open
    // must fail with a typed Artifact error that names a layer (never
    // load garbage silently). Offsets start past each header so the flip
    // lands in the CRC-framed record region.
    // Headers: base = magic(8)+version(4)+count(4);
    // adapter = magic(8)+version(4)+id_len(4)+id+count(4).
    let cases: [(&std::path::Path, usize, &str); 2] =
        [(&bpath, 16, "base"), (&apath, 12 + 4 + set.id().len() + 4, "adapter")];
    for (path, header, kind) in cases {
        let orig = std::fs::read(path).unwrap();
        for &frac in &[0.3f64, 0.6, 0.9] {
            let mut bytes = orig.clone();
            let span = bytes.len() - header - 4;
            let pos = header + (span as f64 * frac) as usize;
            bytes[pos] ^= 0x01;
            let name = format!("flip_{kind}_{pos}");
            std::fs::write(st.path(&name), &bytes).unwrap();
            match st.open(&name) {
                Err(e) => {
                    assert!(
                        matches!(e, ServeError::Artifact { .. }),
                        "{kind} pos {pos}: {e:?}"
                    );
                    let msg = format!("{e}");
                    assert!(msg.contains("layer "), "{kind} pos {pos}: {msg}");
                }
                Ok(_) => {
                    // This format has no padding: every byte is covered by
                    // a length field, a checksum, or checksummed payload.
                    panic!("{kind}: flipped byte at {pos} loaded silently");
                }
            }
        }
    }
    std::fs::remove_dir_all(st.dir()).ok();
}

#[test]
fn unpack_error_path_reaches_the_loader_as_malformed() {
    // A layer advertising more packed words than its payload carries is a
    // structural (Malformed) error naming the field, not a panic.
    let st = store("struct");
    let (model, _, _) = build_model(604);
    let path = st.save_base(&model, "base.cloqpkd2").unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Header: magic(8) + version(4) + count(4). First layer record:
    // len(8) + payload. Payload: name_len(4) + name + kind(1) + bits(4) …
    let name_len = u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
    let bits_off = 24 + 4 + name_len + 1;
    let old_bits = u32::from_le_bytes(bytes[bits_off..bits_off + 4].try_into().unwrap());
    assert!((1..=8).contains(&old_bits), "offset math drifted: bits={old_bits}");
    // Lie about the bit width: the packed word count no longer matches.
    bytes[bits_off] = if old_bits == 2 { 4 } else { 2 };
    // Fix the CRC so we hit the structural check, not the checksum.
    let len = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let crc = cloq::serve::crc32(&bytes[24..24 + len]);
    bytes[24 + len..24 + len + 4].copy_from_slice(&crc.to_le_bytes());
    std::fs::write(st.path("lied.cloqpkd2"), &bytes).unwrap();
    let err = st.open("lied.cloqpkd2").unwrap_err();
    assert!(
        matches!(
            &err,
            ServeError::Artifact { kind: ArtifactErrorKind::Malformed, layer: Some(_), .. }
        ),
        "{err:?}"
    );
    let msg = format!("{err}");
    assert!(msg.contains("layer 0"), "{msg}");
    assert!(msg.contains("packed words") || msg.contains("needs"), "{msg}");
    std::fs::remove_dir_all(st.dir()).ok();
}

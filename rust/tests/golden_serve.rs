//! Golden tests for the packed serving artifact: save → load must
//! reproduce the exact quantization state **byte-identically** (codes,
//! scales/zeros, codebook levels/absmax, adapters) and a **bit-identical**
//! packed forward, across bits {2,3,4,8} × group sizes {32,64}; truncated
//! and bit-flipped files must fail with errors naming the offending layer.

use cloq::linalg::Matrix;
use cloq::quant::{quantize_nf, quantize_rtn, QuantState};
use cloq::serve::{load_artifact, save_artifact, PackedLayer, PackedModel};
use cloq::util::prng::Rng;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cloq_golden_{tag}_{}", std::process::id()))
}

fn assert_state_bytes_identical(a: &QuantState, b: &QuantState, what: &str) {
    match (a, b) {
        (QuantState::Int(x), QuantState::Int(y)) => {
            assert_eq!(x.bits, y.bits, "{what}: bits");
            assert_eq!(x.group_size, y.group_size, "{what}: group size");
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{what}: shape");
            assert_eq!(x.codes, y.codes, "{what}: codes");
            let eq_bits = |p: &Matrix, q: &Matrix| {
                p.data.iter().map(|v| v.to_bits()).eq(q.data.iter().map(|v| v.to_bits()))
            };
            assert!(eq_bits(&x.scales, &y.scales), "{what}: scales");
            assert!(eq_bits(&x.zeros, &y.zeros), "{what}: zeros");
        }
        (QuantState::Nf(x), QuantState::Nf(y)) => {
            assert_eq!(x.bits, y.bits, "{what}: bits");
            assert_eq!(x.block_size, y.block_size, "{what}: block size");
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{what}: shape");
            assert_eq!(x.codes, y.codes, "{what}: codes");
            assert!(
                x.levels.iter().map(|v| v.to_bits()).eq(y.levels.iter().map(|v| v.to_bits())),
                "{what}: levels"
            );
            assert!(
                x.absmax.data.iter().map(|v| v.to_bits()).eq(y.absmax.data.iter().map(|v| v.to_bits())),
                "{what}: absmax"
            );
        }
        _ => panic!("{what}: state kind changed across the roundtrip"),
    }
}

/// One layer per (bits, group size) point, mixed grid/codebook, ragged
/// shapes so the packed rows have slack bits.
fn build_model(seed: u64) -> (PackedModel, Vec<QuantState>) {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut states = Vec::new();
    for &bits in &[2u32, 3, 4, 8] {
        for &gs in &[32usize, 64] {
            let (m, n) = (70usize + bits as usize, 37usize + gs / 16);
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let qs = if bits <= 4 && gs == 32 {
                QuantState::Nf(quantize_nf(&w, bits.max(2), gs))
            } else {
                QuantState::Int(quantize_rtn(&w, bits, gs))
            };
            let r = 4;
            let a = Matrix::randn(m, r, 0.1, &mut rng);
            let b = Matrix::randn(n, r, 0.1, &mut rng);
            let name = format!("blk.b{bits}.g{gs}");
            layers.push(PackedLayer::from_state(&name, &qs, &a, &b).unwrap());
            states.push(qs);
        }
    }
    (PackedModel::new(layers), states)
}

#[test]
fn roundtrip_byte_identical_states_and_bit_identical_forward() {
    let dir = tmp("roundtrip");
    let (model, states) = build_model(600);
    let path = dir.join("model.cloqpkd");
    save_artifact(&model, &path).unwrap();
    let loaded = load_artifact(&path).unwrap();
    assert_eq!(loaded.layers.len(), model.layers.len());

    let mut rng = Rng::new(601);
    for ((orig, got), state) in model.layers.iter().zip(&loaded.layers).zip(&states) {
        assert_eq!(orig.name, got.name);
        assert_eq!(orig.packed, got.packed, "{}: packed words", orig.name);
        // The reloaded state reproduces the ORIGINAL quantizer output
        // byte-for-byte — not just something that dequantizes closely.
        assert_state_bytes_identical(state, &got.to_state().unwrap(), &orig.name);
        // Adapters survive exactly too.
        assert!(
            orig.a.data.iter().map(|v| v.to_bits()).eq(got.a.data.iter().map(|v| v.to_bits())),
            "{}: adapter A",
            orig.name
        );
        assert!(
            orig.b.data.iter().map(|v| v.to_bits()).eq(got.b.data.iter().map(|v| v.to_bits())),
            "{}: adapter B",
            orig.name
        );
        // And the serving numbers are the same bits.
        let x = rng.gauss_vec(orig.rows);
        let (ya, yb) = (orig.forward(&x), got.forward(&x));
        for (u, v) in ya.iter().zip(&yb) {
            assert_eq!(u.to_bits(), v.to_bits(), "{}: forward", orig.name);
        }
    }

    // Save → load → save is byte-stable (no hidden nondeterminism).
    let path2 = dir.join("model2.cloqpkd");
    save_artifact(&loaded, &path2).unwrap();
    assert_eq!(std::fs::read(&path).unwrap(), std::fs::read(&path2).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_artifact_names_the_layer_it_died_in() {
    let dir = tmp("trunc");
    let (model, _) = build_model(602);
    let path = dir.join("model.cloqpkd");
    save_artifact(&model, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // Cut in the middle of the file: some layers load, then a named error.
    let cut = bytes.len() / 2;
    let tpath = dir.join("trunc.cloqpkd");
    std::fs::write(&tpath, &bytes[..cut]).unwrap();
    let msg = format!("{}", load_artifact(&tpath).unwrap_err());
    assert!(msg.contains("layer "), "{msg}");
    assert!(msg.contains("truncated"), "{msg}");

    // Cut just before the final checksum: the LAST layer is named.
    let tpath2 = dir.join("trunc2.cloqpkd");
    std::fs::write(&tpath2, &bytes[..bytes.len() - 2]).unwrap();
    let msg2 = format!("{}", load_artifact(&tpath2).unwrap_err());
    let n = model.layers.len();
    assert!(
        msg2.contains(&format!("layer {}/{n}", n - 1)),
        "expected the last layer named: {msg2}"
    );
    assert!(msg2.contains("checksum") || msg2.contains("truncated"), "{msg2}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn flipped_bit_is_caught_by_the_layer_checksum() {
    let dir = tmp("flip");
    let (model, _) = build_model(603);
    let path = dir.join("model.cloqpkd");
    save_artifact(&model, &path).unwrap();
    let orig = std::fs::read(&path).unwrap();

    // Flip one bit at several depths; every load must fail with a
    // checksum error that names a layer (never load garbage silently).
    for &frac in &[0.3f64, 0.6, 0.9] {
        let mut bytes = orig.clone();
        let pos = 16 + ((bytes.len() - 20) as f64 * frac) as usize;
        bytes[pos] ^= 0x01;
        let bpath = dir.join(format!("flip_{pos}.cloqpkd"));
        std::fs::write(&bpath, &bytes).unwrap();
        match load_artifact(&bpath) {
            Err(e) => {
                let msg = format!("{e}");
                assert!(msg.contains("layer "), "pos {pos}: {msg}");
            }
            Ok(loaded) => {
                // The flip landed in a payload-length field in a way that
                // still parsed? Not acceptable: CRC must have been checked.
                // (Reaching here means the artifact was undamaged — only
                // possible if we flipped padding, which this format has
                // none of.)
                panic!(
                    "flipped byte at {pos} loaded silently ({} layers)",
                    loaded.layers.len()
                );
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unpack_error_path_reaches_the_loader() {
    // A layer advertising more packed words than its payload carries is a
    // structural error naming the field, not a panic.
    let dir = tmp("struct");
    let (model, _) = build_model(604);
    let path = dir.join("model.cloqpkd");
    save_artifact(&model, &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Header: magic(8) + version(4) + count(4). First layer record:
    // len(8) + payload. Payload: name_len(4) + name + kind(1) + bits(4) …
    let name_len = u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
    let bits_off = 24 + 4 + name_len + 1;
    let old_bits = u32::from_le_bytes(bytes[bits_off..bits_off + 4].try_into().unwrap());
    assert!((1..=8).contains(&old_bits), "offset math drifted: bits={old_bits}");
    // Lie about the bit width: the packed word count no longer matches.
    bytes[bits_off] = if old_bits == 2 { 4 } else { 2 };
    // Fix the CRC so we hit the structural check, not the checksum.
    let len = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let crc = cloq::serve::crc32(&bytes[24..24 + len]);
    bytes[24 + len..24 + len + 4].copy_from_slice(&crc.to_le_bytes());
    let bpath = dir.join("lied.cloqpkd");
    std::fs::write(&bpath, &bytes).unwrap();
    let msg = format!("{}", load_artifact(&bpath).unwrap_err());
    assert!(msg.contains("layer 0"), "{msg}");
    assert!(msg.contains("packed words") || msg.contains("needs"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

//! Golden tests for the packed serving artifacts: save → load must
//! reproduce the exact quantization state **byte-identically** (codes,
//! scales/zeros, codebook levels/absmax) and adapter pairs exactly, and a
//! **bit-identical** packed forward, across bits {2,3,4,8} × group sizes
//! {32,64}; truncated and bit-flipped files must fail with errors naming
//! the offending layer; and the v1 → v2 compatibility shim must convert
//! legacy single-tenant files into base + one adapter set with
//! bit-identical forward outputs.

use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_nf, quantize_rtn, QuantState};
use cloq::serve::{
    load_adapter_artifact, load_artifact_compat, load_base_artifact, save_adapter_artifact,
    save_artifact_v1, save_base_artifact, AdapterSet, PackedLayer, PackedModel,
};
use cloq::util::prng::Rng;

fn tmp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("cloq_golden_{tag}_{}", std::process::id()))
}

fn assert_state_bytes_identical(a: &QuantState, b: &QuantState, what: &str) {
    match (a, b) {
        (QuantState::Int(x), QuantState::Int(y)) => {
            assert_eq!(x.bits, y.bits, "{what}: bits");
            assert_eq!(x.group_size, y.group_size, "{what}: group size");
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{what}: shape");
            assert_eq!(x.codes, y.codes, "{what}: codes");
            let eq_bits = |p: &Matrix, q: &Matrix| {
                p.data.iter().map(|v| v.to_bits()).eq(q.data.iter().map(|v| v.to_bits()))
            };
            assert!(eq_bits(&x.scales, &y.scales), "{what}: scales");
            assert!(eq_bits(&x.zeros, &y.zeros), "{what}: zeros");
        }
        (QuantState::Nf(x), QuantState::Nf(y)) => {
            assert_eq!(x.bits, y.bits, "{what}: bits");
            assert_eq!(x.block_size, y.block_size, "{what}: block size");
            assert_eq!((x.rows, x.cols), (y.rows, y.cols), "{what}: shape");
            assert_eq!(x.codes, y.codes, "{what}: codes");
            assert!(
                x.levels.iter().map(|v| v.to_bits()).eq(y.levels.iter().map(|v| v.to_bits())),
                "{what}: levels"
            );
            assert!(
                x.absmax
                    .data
                    .iter()
                    .map(|v| v.to_bits())
                    .eq(y.absmax.data.iter().map(|v| v.to_bits())),
                "{what}: absmax"
            );
        }
        _ => panic!("{what}: state kind changed across the roundtrip"),
    }
}

fn assert_pair_exact(a: &LoraPair, b: &LoraPair, what: &str) {
    assert!(
        a.a.data.iter().map(|v| v.to_bits()).eq(b.a.data.iter().map(|v| v.to_bits())),
        "{what}: adapter A"
    );
    assert!(
        a.b.data.iter().map(|v| v.to_bits()).eq(b.b.data.iter().map(|v| v.to_bits())),
        "{what}: adapter B"
    );
}

/// One layer per (bits, group size) point, mixed grid/codebook, ragged
/// shapes so the packed rows have slack bits. Returns the base model, one
/// adapter set covering it, and the original quantizer states.
fn build_model(seed: u64) -> (PackedModel, AdapterSet, Vec<QuantState>) {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    let mut pairs = Vec::new();
    let mut states = Vec::new();
    for &bits in &[2u32, 3, 4, 8] {
        for &gs in &[32usize, 64] {
            let (m, n) = (70usize + bits as usize, 37usize + gs / 16);
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            let qs = if bits <= 4 && gs == 32 {
                QuantState::Nf(quantize_nf(&w, bits.max(2), gs))
            } else {
                QuantState::Int(quantize_rtn(&w, bits, gs))
            };
            let r = 4;
            let a = Matrix::randn(m, r, 0.1, &mut rng);
            let b = Matrix::randn(n, r, 0.1, &mut rng);
            let name = format!("blk.b{bits}.g{gs}");
            layers.push(PackedLayer::from_state(&name, &qs).unwrap());
            pairs.push((name, LoraPair::new(a, b)));
            states.push(qs);
        }
    }
    let set = AdapterSet::from_pairs("tenant", pairs).unwrap();
    (PackedModel::new(layers), set, states)
}

#[test]
fn roundtrip_byte_identical_states_and_bit_identical_forward() {
    let dir = tmp("roundtrip");
    let (model, set, states) = build_model(600);
    let bpath = dir.join("base.cloqpkd2");
    let apath = dir.join("tenant.cloqadp");
    save_base_artifact(&model, &bpath).unwrap();
    save_adapter_artifact(&set, &apath).unwrap();
    let loaded = load_base_artifact(&bpath).unwrap();
    let lset = load_adapter_artifact(&apath).unwrap();
    assert_eq!(loaded.layers.len(), model.layers.len());
    assert_eq!(lset.id(), set.id());
    assert_eq!(lset.len(), set.len());

    let mut rng = Rng::new(601);
    for ((orig, got), state) in model.layers.iter().zip(&loaded.layers).zip(&states) {
        assert_eq!(orig.name, got.name);
        assert_eq!(orig.packed, got.packed, "{}: packed words", orig.name);
        // The reloaded state reproduces the ORIGINAL quantizer output
        // byte-for-byte — not just something that dequantizes closely.
        assert_state_bytes_identical(state, &got.to_state().unwrap(), &orig.name);
        // Adapters survive exactly too.
        assert_pair_exact(set.get(&orig.name).unwrap(), lset.get(&orig.name).unwrap(), &orig.name);
        // And the serving numbers are the same bits.
        let x = rng.gauss_vec(orig.rows);
        let ya = orig.forward(&x, set.get(&orig.name));
        let yb = got.forward(&x, lset.get(&got.name));
        for (u, v) in ya.iter().zip(&yb) {
            assert_eq!(u.to_bits(), v.to_bits(), "{}: forward", orig.name);
        }
    }

    // Save → load → save is byte-stable for both artifacts (no hidden
    // nondeterminism).
    let bpath2 = dir.join("base2.cloqpkd2");
    save_base_artifact(&loaded, &bpath2).unwrap();
    assert_eq!(std::fs::read(&bpath).unwrap(), std::fs::read(&bpath2).unwrap());
    let apath2 = dir.join("tenant2.cloqadp");
    save_adapter_artifact(&lset, &apath2).unwrap();
    assert_eq!(std::fs::read(&apath).unwrap(), std::fs::read(&apath2).unwrap());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn v1_artifact_converts_to_base_plus_adapter_with_identical_bits() {
    // The compatibility shim: a legacy CLOQPKD1 file (adapters embedded
    // per layer) loads as base + one AdapterSet named "v1", and forwards
    // through the converted halves are byte-for-byte what the embedded
    // layout produced.
    let dir = tmp("v1shim");
    let (model, set, _) = build_model(610);
    let path = dir.join("legacy.cloqpkd");
    save_artifact_v1(&model, &set, &path).unwrap();
    let (loaded, lset) = load_artifact_compat(&path).unwrap();
    let lset = lset.expect("v1 files carry embedded adapters");
    assert_eq!(lset.id(), "v1");
    assert_eq!(loaded.layers.len(), model.layers.len());
    assert_eq!(lset.len(), model.layers.len());
    let mut rng = Rng::new(611);
    for (orig, got) in model.layers.iter().zip(&loaded.layers) {
        assert_eq!(orig.name, got.name);
        assert_eq!(orig.packed, got.packed, "{}: packed words", orig.name);
        assert_pair_exact(set.get(&orig.name).unwrap(), lset.get(&got.name).unwrap(), &orig.name);
        let x = rng.gauss_vec(orig.rows);
        let ya = orig.forward(&x, set.get(&orig.name));
        let yb = got.forward(&x, lset.get(&got.name));
        for (u, v) in ya.iter().zip(&yb) {
            assert_eq!(u.to_bits(), v.to_bits(), "{}: forward through the shim", orig.name);
        }
    }
    // A v2 base file through the same entry point reports no adapters.
    let bpath = dir.join("base.cloqpkd2");
    save_base_artifact(&model, &bpath).unwrap();
    let (_, none) = load_artifact_compat(&bpath).unwrap();
    assert!(none.is_none(), "v2 base artifacts carry no adapters");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn truncated_artifact_names_the_layer_it_died_in() {
    let dir = tmp("trunc");
    let (model, _, _) = build_model(602);
    let path = dir.join("base.cloqpkd2");
    save_base_artifact(&model, &path).unwrap();
    let bytes = std::fs::read(&path).unwrap();

    // Cut in the middle of the file: some layers load, then a named error.
    let cut = bytes.len() / 2;
    let tpath = dir.join("trunc.cloqpkd2");
    std::fs::write(&tpath, &bytes[..cut]).unwrap();
    let msg = format!("{}", load_base_artifact(&tpath).unwrap_err());
    assert!(msg.contains("layer "), "{msg}");
    assert!(msg.contains("truncated"), "{msg}");

    // Cut just before the final checksum: the LAST layer is named.
    let tpath2 = dir.join("trunc2.cloqpkd2");
    std::fs::write(&tpath2, &bytes[..bytes.len() - 2]).unwrap();
    let msg2 = format!("{}", load_base_artifact(&tpath2).unwrap_err());
    let n = model.layers.len();
    assert!(
        msg2.contains(&format!("layer {}/{n}", n - 1)),
        "expected the last layer named: {msg2}"
    );
    assert!(msg2.contains("checksum") || msg2.contains("truncated"), "{msg2}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn flipped_bit_is_caught_by_the_layer_checksum() {
    let dir = tmp("flip");
    let (model, set, _) = build_model(603);
    let bpath = dir.join("base.cloqpkd2");
    save_base_artifact(&model, &bpath).unwrap();
    let apath = dir.join("tenant.cloqadp");
    save_adapter_artifact(&set, &apath).unwrap();

    // Flip one bit at several depths in BOTH artifact kinds; every load
    // must fail with a checksum error that names a layer (never load
    // garbage silently). Offsets start past each header so the flip lands
    // in the CRC-framed record region.
    // Headers: base = magic(8)+version(4)+count(4);
    // adapter = magic(8)+version(4)+id_len(4)+id+count(4).
    let cases: [(&std::path::Path, usize, &str); 2] =
        [(&bpath, 16, "base"), (&apath, 12 + 4 + set.id().len() + 4, "adapter")];
    for (path, header, kind) in cases {
        let orig = std::fs::read(path).unwrap();
        for &frac in &[0.3f64, 0.6, 0.9] {
            let mut bytes = orig.clone();
            let span = bytes.len() - header - 4;
            let pos = header + (span as f64 * frac) as usize;
            bytes[pos] ^= 0x01;
            let bad = dir.join(format!("flip_{kind}_{pos}"));
            std::fs::write(&bad, &bytes).unwrap();
            let result = if kind == "base" {
                load_base_artifact(&bad).map(|_| ())
            } else {
                load_adapter_artifact(&bad).map(|_| ())
            };
            match result {
                Err(e) => {
                    let msg = format!("{e}");
                    assert!(msg.contains("layer "), "{kind} pos {pos}: {msg}");
                }
                Ok(()) => {
                    // This format has no padding: every byte is covered by
                    // a length field, a checksum, or checksummed payload.
                    panic!("{kind}: flipped byte at {pos} loaded silently");
                }
            }
        }
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unpack_error_path_reaches_the_loader() {
    // A layer advertising more packed words than its payload carries is a
    // structural error naming the field, not a panic.
    let dir = tmp("struct");
    let (model, _, _) = build_model(604);
    let path = dir.join("base.cloqpkd2");
    save_base_artifact(&model, &path).unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Header: magic(8) + version(4) + count(4). First layer record:
    // len(8) + payload. Payload: name_len(4) + name + kind(1) + bits(4) …
    let name_len = u32::from_le_bytes(bytes[24..28].try_into().unwrap()) as usize;
    let bits_off = 24 + 4 + name_len + 1;
    let old_bits = u32::from_le_bytes(bytes[bits_off..bits_off + 4].try_into().unwrap());
    assert!((1..=8).contains(&old_bits), "offset math drifted: bits={old_bits}");
    // Lie about the bit width: the packed word count no longer matches.
    bytes[bits_off] = if old_bits == 2 { 4 } else { 2 };
    // Fix the CRC so we hit the structural check, not the checksum.
    let len = u64::from_le_bytes(bytes[16..24].try_into().unwrap()) as usize;
    let crc = cloq::serve::crc32(&bytes[24..24 + len]);
    bytes[24 + len..24 + len + 4].copy_from_slice(&crc.to_le_bytes());
    let bpath = dir.join("lied.cloqpkd2");
    std::fs::write(&bpath, &bytes).unwrap();
    let msg = format!("{}", load_base_artifact(&bpath).unwrap_err());
    assert!(msg.contains("layer 0"), "{msg}");
    assert!(msg.contains("packed words") || msg.contains("needs"), "{msg}");
    std::fs::remove_dir_all(&dir).ok();
}

//! Lifecycle tests for the full-model pipelined path: drain-aware
//! shutdown (every admitted traversal finishes every remaining hop),
//! hop-aware backpressure (in-kernel hops count toward the admission
//! limit, not just FIFO entries), and failure isolation (a panicking
//! layer kernel or session step function fails only its own request) —
//! with every failure asserted as its typed `ServeError` variant, not a
//! string search.

use std::sync::mpsc;

use cloq::linalg::Matrix;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    DequantParams, ModelRequest, PackedLayer, PackedModel, ServeEngine, ServeError,
    SessionRequest, StepFn,
};
use cloq::util::prng::Rng;

fn square_layer(name: &str, n: usize, seed: u64) -> PackedLayer {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(n, n, 0.3, &mut rng);
    PackedLayer::from_state(name, &QuantState::Int(quantize_rtn(&w, 4, 8))).unwrap()
}

#[test]
fn shutdown_drains_every_hop_of_admitted_traversals() {
    // 24 three-hop model requests and 4 three-step sessions admitted,
    // then an immediate shutdown: the drain must complete every remaining
    // hop (traversals re-enter the FIFO from workers while the engine is
    // closing), so every ticket resolves Ok.
    let model = PackedModel::new(vec![
        square_layer("a", 16, 700),
        square_layer("b", 16, 701),
        square_layer("c", 16, 702),
    ]);
    let engine = ServeEngine::builder(model).workers(1).max_batch(8).build().unwrap();
    let route = engine.route(&["a", "b", "c"]).unwrap();
    let mut rng = Rng::new(703);
    let models: Vec<_> = (0..24)
        .map(|_| engine.submit_model(ModelRequest::new(route.clone(), rng.gauss_vec(16))))
        .collect();
    let sessions: Vec<_> = (0..4)
        .map(|_| {
            let step: StepFn = Box::new(|_, y| Some(y.to_vec()));
            engine.submit_session(SessionRequest::new(route.clone(), rng.gauss_vec(16), 3, step))
        })
        .collect();
    let stats = engine.shutdown(); // must answer all 28 traversals first
    assert_eq!(stats.model_requests, 28);
    assert_eq!(stats.session_forwards, 24 + 4 * 3);
    assert_eq!(stats.hops, (24 + 4 * 3) * 3);
    assert_eq!(stats.failed_model_requests, 0);
    for t in models {
        assert_eq!(t.wait().unwrap().forwards, 1);
    }
    for t in sessions {
        assert_eq!(t.wait().unwrap().forwards, 3);
    }
}

#[test]
fn backpressure_counts_in_kernel_hops_not_just_the_fifo() {
    // max_pending = 2, one worker. A session parks INSIDE the kernel
    // worker (its step fn blocks on a gate), so the FIFO is empty while
    // one live hop slot is held. One more admission fits; the next must
    // be rejected as Overloaded even though the queue holds just one
    // entry — the in-flight hop counts.
    let model = PackedModel::new(vec![square_layer("sq", 12, 710)]);
    let engine = ServeEngine::builder(model)
        .workers(1)
        .max_batch(4)
        .max_pending(2)
        .build()
        .unwrap();
    let sq = engine.layer("sq").unwrap();
    let route = engine.route(&["sq"]).unwrap();
    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let step: StepFn = Box::new(move |_, y| {
        entered_tx.send(()).unwrap();
        gate_rx.recv().unwrap();
        Some(y.to_vec())
    });
    let mut rng = Rng::new(711);
    let session = engine.submit_session(SessionRequest::new(route, rng.gauss_vec(12), 2, step));
    entered_rx.recv().unwrap(); // the session's hop is now mid-kernel
    let second = engine.submit(sq, None, rng.gauss_vec(12)); // live = 2, queued
    let third = engine.submit(sq, None, rng.gauss_vec(12)); // live limit hit
    let err = third.wait().unwrap_err();
    assert!(
        matches!(err, ServeError::Overloaded { max_pending: 2 }),
        "hop-aware limit must reject as Overloaded: {err:?}"
    );
    gate_tx.send(()).unwrap(); // release the parked session
    assert_eq!(session.wait().unwrap().forwards, 2);
    assert!(second.wait().is_ok(), "the admitted request must still be served");
    let stats = engine.shutdown();
    assert_eq!(stats.rejected, 1);
    assert_eq!(stats.model_requests, 1);
    assert_eq!(stats.requests, 1);
}

/// A layer whose kernel panics on ANY request: hand-built codebook state
/// whose packed codes index past the levels table (the kind of corruption
/// the artifact CRC normally catches — here it stands in for "this layer's
/// kernel is broken").
fn boom_layer(n: usize) -> PackedLayer {
    let wpr = cloq::serve::words_per_row(n, 2);
    PackedLayer {
        name: "boom".to_string(),
        rows: n,
        cols: n,
        bits: 2,
        group_size: n,
        packed: vec![u32::MAX; n * wpr].into(), // every 2-bit code = 3
        params: DequantParams::Codebook {
            levels: vec![0.0, 1.0], // code 3 is out of range → panic
            absmax: Matrix::zeros(1, n),
        },
    }
}

#[test]
fn panicking_layer_fails_only_its_own_traversal_with_the_layer_named() {
    let model = PackedModel::new(vec![
        square_layer("ok1", 10, 720),
        boom_layer(10),
        square_layer("ok2", 10, 721),
    ]);
    let engine = ServeEngine::builder(model).workers(1).max_batch(8).build().unwrap();
    let doomed_route = engine.route(&["ok1", "boom", "ok2"]).unwrap();
    let healthy_route = engine.route(&["ok1", "ok2"]).unwrap();
    let mut rng = Rng::new(722);
    // Both traversals start at ok1 (they may share that micro-batch);
    // only the one routed through boom may fail.
    let doomed = engine.submit_model(ModelRequest::new(doomed_route, rng.gauss_vec(10)));
    let healthy =
        engine.submit_model(ModelRequest::new(healthy_route.clone(), rng.gauss_vec(10)));
    let err = doomed.wait().unwrap_err();
    assert!(
        matches!(
            &err,
            ServeError::WorkerPanic { layer, hop: Some(2), .. } if layer == "boom"
        ),
        "typed WorkerPanic naming layer and hop expected: {err:?}"
    );
    assert!(healthy.wait().is_ok(), "an unrelated traversal must be unaffected");
    // The worker survived the panic: the engine keeps serving.
    assert!(engine
        .submit_model(ModelRequest::new(healthy_route, rng.gauss_vec(10)))
        .wait()
        .is_ok());
    let stats = engine.shutdown();
    assert_eq!(stats.failed_model_requests, 1);
    assert_eq!(stats.model_requests, 2);
    assert!(stats.batch_panics >= 1);
    assert_eq!(stats.failed, 0, "no single-layer rider was in the panicked batch");
}

#[test]
fn single_layer_riders_of_a_panicked_batch_get_a_typed_worker_panic() {
    let model = PackedModel::new(vec![boom_layer(8)]);
    let engine = ServeEngine::builder(model).workers(1).build().unwrap();
    let boom = engine.layer("boom").unwrap();
    let err = engine.submit(boom, None, vec![1.0; 8]).wait().unwrap_err();
    assert!(
        matches!(&err, ServeError::WorkerPanic { layer, hop: None, .. } if layer == "boom"),
        "{err:?}"
    );
    let stats = engine.shutdown();
    assert_eq!(stats.failed, 1);
    assert!(stats.batch_panics >= 1);
}

#[test]
fn step_failures_fail_only_their_session() {
    let model = PackedModel::new(vec![square_layer("sq", 8, 730)]);
    let engine = ServeEngine::builder(model).build().unwrap();
    let route = engine.route(&["sq"]).unwrap();
    let mut rng = Rng::new(731);
    let panicking: StepFn = Box::new(|_, _| panic!("injected step panic"));
    let bad_shape: StepFn = Box::new(|_, _| Some(vec![0.0; 3]));
    let s1 =
        engine.submit_session(SessionRequest::new(route.clone(), rng.gauss_vec(8), 2, panicking));
    let s2 =
        engine.submit_session(SessionRequest::new(route.clone(), rng.gauss_vec(8), 2, bad_shape));
    let ok = engine.submit_model(ModelRequest::new(route, rng.gauss_vec(8)));
    let err = s1.wait().unwrap_err();
    assert!(matches!(&err, ServeError::StepFailed { forward: 1, .. }), "{err:?}");
    assert!(format!("{err}").contains("step function panicked"), "{err}");
    let err = s2.wait().unwrap_err();
    assert!(matches!(&err, ServeError::StepFailed { forward: 1, .. }), "{err:?}");
    let msg = format!("{err}");
    assert!(msg.contains("3 values"), "{msg}");
    assert!(msg.contains("takes 8 features"), "{msg}");
    assert!(ok.wait().is_ok(), "unrelated traffic must be unaffected");
    let stats = engine.shutdown();
    assert_eq!(stats.failed_model_requests, 2);
    assert_eq!(stats.model_requests, 1);
    assert_eq!(stats.batch_panics, 0, "step failures are not kernel panics");
}

#[test]
fn sessions_stop_early_when_the_step_says_so() {
    let model = PackedModel::new(vec![square_layer("sq", 8, 740)]);
    let engine = ServeEngine::builder(model).build().unwrap();
    let route = engine.route(&["sq"]).unwrap();
    let step: StepFn = Box::new(|k, y| if k < 2 { Some(y.to_vec()) } else { None });
    let r = engine
        .submit_session(SessionRequest::new(route, Rng::new(741).gauss_vec(8), 100, step))
        .wait()
        .unwrap();
    assert_eq!(r.forwards, 2, "step returned None after forward 2");
    assert_eq!(r.hops, 2);
    let stats = engine.shutdown();
    assert_eq!(stats.session_forwards, 2);
}

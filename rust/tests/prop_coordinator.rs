//! Coordinator invariants: scheduler completion under injected failures,
//! batcher token conservation, data determinism, report round-trips —
//! the "routing/batching/state" property suite.

use std::collections::BTreeMap;
use std::path::PathBuf;

use cloq::coordinator::calibrate::GramSet;
use cloq::coordinator::quantize::{quantize_init, ModelInit};
use cloq::data::batcher::{pad_rows, task_batch, task_batch_at, LmStream};
use cloq::data::tokenizer::{decode, encode, BOS, EOS, PAD};
use cloq::data::{commonsense170k, math10k, pretrain_mixture, Task, ARITH_TASKS, COMMONSENSE_TASKS};
use cloq::linalg::{syrk_t, Matrix};
use cloq::lowrank::{InitConfig, Method};
use cloq::model::{EntrySpec, Manifest, ModelConfig, ParamStore, TensorSpec};
use cloq::runtime::{Dtype, Tensor};
use cloq::util::prng::Rng;
use cloq::util::threadpool::{run_collect_status, JobStatus};

fn sweep(cases: usize, f: impl Fn(u64, &mut Rng)) {
    for seed in 0..cases as u64 {
        let mut rng = Rng::new(0xC00D ^ seed.wrapping_mul(0xA24B_AED4_963E_E407));
        f(seed, &mut rng);
    }
}

#[test]
fn scheduler_completes_all_jobs_under_random_failures() {
    sweep(20, |seed, rng| {
        let n_jobs = rng.range(1, 40) as usize;
        let workers = rng.range(1, 8) as usize;
        let fail_mask: Vec<bool> = (0..n_jobs).map(|_| rng.chance(0.2)).collect();
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = fail_mask
            .iter()
            .enumerate()
            .map(|(i, &fail)| {
                Box::new(move || {
                    if fail {
                        panic!("injected");
                    }
                    i * 3
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let (results, statuses) = run_collect_status(workers, jobs);
        assert_eq!(results.len(), n_jobs);
        for i in 0..n_jobs {
            if fail_mask[i] {
                assert!(matches!(statuses[i], JobStatus::Panicked(_)), "seed={seed} job={i}");
                assert!(results[i].is_none());
            } else {
                assert_eq!(statuses[i], JobStatus::Done, "seed={seed} job={i}");
                assert_eq!(results[i], Some(i * 3));
            }
        }
    });
}

/// Build a fully in-memory model (manifest + base weights + grams) for the
/// quantize+init stage — no AOT artifacts needed. The manifest only has to
/// carry the `eval_loss` entry the spec helpers derive shapes from.
fn synth_model(n_layers: usize, d_model: usize, d_ff: usize, rank: usize, seed: u64)
    -> (Manifest, ParamStore, GramSet)
{
    let config = ModelConfig {
        name: "synth".to_string(),
        vocab: 64,
        d_model,
        n_layers,
        n_heads: 2,
        d_ff,
        seq: 8,
        batch: 2,
        rank,
        group_size: 16,
    };
    let mut inputs = Vec::new();
    for l in 0..n_layers {
        for (name, din, dout) in config.linear_specs(l) {
            inputs.push(TensorSpec { name, shape: vec![din, dout], dtype: Dtype::F32 });
        }
    }
    for l in 0..n_layers {
        for (name, din, dout) in config.linear_specs(l) {
            inputs.push(TensorSpec {
                name: format!("{name}.A"),
                shape: vec![din, rank],
                dtype: Dtype::F32,
            });
            inputs.push(TensorSpec {
                name: format!("{name}.B"),
                shape: vec![dout, rank],
                dtype: Dtype::F32,
            });
        }
    }
    inputs.push(TensorSpec { name: "tokens".to_string(), shape: vec![2, 8], dtype: Dtype::I32 });
    inputs.push(TensorSpec { name: "mask".to_string(), shape: vec![2, 8], dtype: Dtype::F32 });
    let entry = EntrySpec {
        file: "eval_loss.hlo.txt".to_string(),
        inputs,
        outputs: vec![
            TensorSpec { name: "loss_sum".to_string(), shape: vec![], dtype: Dtype::F32 },
            TensorSpec { name: "count".to_string(), shape: vec![], dtype: Dtype::F32 },
        ],
    };
    let mut entrypoints = BTreeMap::new();
    entrypoints.insert("eval_loss".to_string(), entry);
    let man = Manifest { dir: PathBuf::from("."), config, entrypoints };

    let mut rng = Rng::new(seed);
    let mut base = ParamStore::new();
    let mut grams = GramSet::new();
    for l in 0..n_layers {
        for (name, din, dout) in man.config.linear_specs(l) {
            base.insert(&name, Tensor::from_matrix(&Matrix::randn(din, dout, 0.3, &mut rng)));
            let x = Matrix::randn(din * 2 + 8, din, 1.0, &mut rng);
            grams.insert(name, syrk_t(&x));
        }
    }
    (man, base, grams)
}

fn assert_stores_identical(a: &ParamStore, b: &ParamStore, what: &str) {
    assert_eq!(a.names, b.names, "{what}: name order differs");
    for n in &a.names {
        assert_eq!(a.get(n), b.get(n), "{what}: tensor '{n}' differs");
    }
}

/// Bit-compare the exact serving states (`ModelInit.exact`) — the packed
/// serve path's source of truth must be worker-count-independent too.
fn assert_exact_identical(
    a: &[(String, cloq::quant::QuantState)],
    b: &[(String, cloq::quant::QuantState)],
    what: &str,
) {
    use cloq::quant::QuantState;
    let bits = |m: &cloq::linalg::Matrix| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(a.len(), b.len(), "{what}: layer count");
    for ((n1, q1), (n2, q2)) in a.iter().zip(b) {
        assert_eq!(n1, n2, "{what}: layer order");
        match (q1, q2) {
            (QuantState::Int(x), QuantState::Int(y)) => {
                assert_eq!((x.bits, x.group_size), (y.bits, y.group_size), "{what}: {n1}");
                assert_eq!(x.codes, y.codes, "{what}: {n1} codes");
                assert_eq!(bits(&x.scales), bits(&y.scales), "{what}: {n1} scales");
                assert_eq!(bits(&x.zeros), bits(&y.zeros), "{what}: {n1} zeros");
            }
            (QuantState::Nf(x), QuantState::Nf(y)) => {
                assert_eq!((x.bits, x.block_size), (y.bits, y.block_size), "{what}: {n1}");
                assert_eq!(x.codes, y.codes, "{what}: {n1} codes");
                assert_eq!(bits(&x.absmax), bits(&y.absmax), "{what}: {n1} absmax");
                let lb = |l: &[f64]| l.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                assert_eq!(lb(&x.levels), lb(&y.levels), "{what}: {n1} levels");
            }
            _ => panic!("{what}: {n1} state kind differs across worker counts"),
        }
    }
}

fn init_bytes(init: &ModelInit) -> Vec<u8> {
    // Serialize through the checkpoint writer so "byte-identical" is
    // literal: same bytes on disk.
    let dir = std::env::temp_dir().join(format!(
        "cloq_det_{}_{}",
        std::process::id(),
        init.bits_per_weight.to_bits()
    ));
    let mut all = Vec::new();
    for (tag, store) in [("b", &init.base_q), ("l", &init.lora), ("q", &init.quant)] {
        let path = dir.join(format!("{tag}.ckpt"));
        store.save(&path).unwrap();
        all.extend(std::fs::read(&path).unwrap());
    }
    std::fs::remove_dir_all(&dir).ok();
    all
}

#[test]
fn quantize_init_identical_for_any_worker_count() {
    // The tentpole's determinism contract: layer jobs run on the thread
    // pool with per-layer RNG streams derived from (seed, layer index), so
    // the assembled ModelInit must be byte-identical for workers ∈ {1,2,8}.
    let (man, base, grams) = synth_model(2, 8, 12, 2, 77);
    let mut cfg = InitConfig::new(Method::CLoQ, 3, 2);
    cfg.group_size = 8;
    let one = quantize_init(&man, &base, Some(&grams), &cfg, 123, 1, true).unwrap();
    let one_bytes = init_bytes(&one);
    for workers in [2usize, 8] {
        let many = quantize_init(&man, &base, Some(&grams), &cfg, 123, workers, true).unwrap();
        assert_stores_identical(&one.base_q, &many.base_q, &format!("base_q w={workers}"));
        assert_stores_identical(&one.lora, &many.lora, &format!("lora w={workers}"));
        assert_stores_identical(&one.quant, &many.quant, &format!("quant w={workers}"));
        assert_exact_identical(
            one.exact.as_ref().unwrap(),
            many.exact.as_ref().unwrap(),
            &format!("exact w={workers}"),
        );
        assert_eq!(
            one.bits_per_weight.to_bits(),
            many.bits_per_weight.to_bits(),
            "bits_per_weight w={workers}"
        );
        assert_eq!(one_bytes, init_bytes(&many), "checkpoint bytes w={workers}");
    }
    // Also across methods that use the RNG for their init (std LoRA init
    // draws A ~ N(0, 1/r) per layer).
    let gcfg = InitConfig::new(Method::GptqLora, 3, 2);
    let g1 = quantize_init(&man, &base, Some(&grams), &gcfg, 9, 1, true).unwrap();
    let g8 = quantize_init(&man, &base, Some(&grams), &gcfg, 9, 8, true).unwrap();
    assert_stores_identical(&g1.lora, &g8.lora, "gptq-lora adapters");
}

#[test]
fn keep_exact_false_skips_the_serving_trail_but_changes_nothing_else() {
    // The opt-out must be a pure memory win: every other store is
    // byte-identical with and without the exact trail, the trail itself is
    // absent, and the serve builder refuses actionably.
    let (man, base, grams) = synth_model(2, 8, 12, 2, 79);
    let mut cfg = InitConfig::new(Method::CLoQ, 3, 2);
    cfg.group_size = 8;
    let with = quantize_init(&man, &base, Some(&grams), &cfg, 123, 2, true).unwrap();
    let without = quantize_init(&man, &base, Some(&grams), &cfg, 123, 2, false).unwrap();
    assert!(with.exact.is_some() && without.exact.is_none());
    assert_stores_identical(&with.base_q, &without.base_q, "base_q keep_exact");
    assert_stores_identical(&with.lora, &without.lora, "lora keep_exact");
    assert_stores_identical(&with.quant, &without.quant, "quant keep_exact");
    let err = cloq::serve::PackedModel::from_model_init(&without, "t").unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("keep_exact = true"), "must say how to fix it: {msg}");
}

#[test]
fn panicking_layer_surfaces_without_wedging_pool() {
    // A layer whose Gram matrix is missing panics inside its job
    // (init_layer's `expect`). The pool must drain the remaining jobs,
    // report the failure as JobStatus::Panicked, and quantize_init must
    // surface it as an error naming the layer — not a process abort, not a
    // hang.
    let (man, base, mut grams) = synth_model(2, 8, 12, 2, 78);
    grams.remove("l1.wk").expect("synthetic gram set has l1.wk");
    let cfg = InitConfig::new(Method::CLoQ, 3, 2);
    let err = quantize_init(&man, &base, Some(&grams), &cfg, 9, 4, true).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("panicked"), "error should mention the panic: {msg}");
    assert!(msg.contains("l1.wk"), "error should name the failing layer: {msg}");

    // The pool is not wedged: the same stage succeeds immediately after
    // with an intact gram set on the same process.
    let (man2, base2, grams2) = synth_model(2, 8, 12, 2, 78);
    assert!(quantize_init(&man2, &base2, Some(&grams2), &cfg, 9, 4, true).is_ok());
}

#[test]
fn lm_stream_conserves_tokens() {
    // Every non-BOS token of every batch must be a contiguous slice of the
    // source text: no token loss, no duplication within a pass.
    sweep(15, |seed, rng| {
        let text = pretrain_mixture(seed, 2000 + rng.below(2000));
        let toks = encode(&text);
        let (b, t) = (rng.range(1, 4) as usize, rng.range(8, 24) as usize);
        let mut s = LmStream::new(&text, b, t);
        let mut cursor = 0usize;
        for _ in 0..3 {
            let batch = s.next_batch().unwrap();
            let bt = batch.tokens.as_i32();
            for row in 0..b {
                let r = &bt[row * t..(row + 1) * t];
                assert_eq!(r[0], BOS, "seed={seed}");
                let need = t - 1;
                if cursor + need > toks.len() {
                    cursor = 0;
                }
                assert_eq!(&r[1..], &toks[cursor..cursor + need], "seed={seed} row={row}");
                cursor += need;
            }
        }
    });
}

#[test]
fn task_batches_are_well_formed() {
    sweep(15, |seed, rng| {
        let data = math10k(64, seed);
        let (b, t) = (4usize, rng.range(24, 48) as usize);
        let batch = task_batch(&data, b, t, rng);
        let toks = batch.tokens.as_i32();
        let mask = batch.mask.as_f32();
        for row in 0..b {
            let r = &toks[row * t..(row + 1) * t];
            let m = &mask[row * t..(row + 1) * t];
            assert_eq!(r[0], BOS);
            // mask ⊆ non-pad positions; mask is one contiguous run.
            let first = m.iter().position(|&x| x == 1.0);
            if let Some(f) = first {
                let len = m[f..].iter().take_while(|&&x| x == 1.0).count();
                assert!(m[f + len..].iter().all(|&x| x == 0.0), "contiguous seed={seed}");
                assert!(r[f..f + len].iter().all(|&tk| tk != PAD), "mask-on-pad seed={seed}");
            }
            // Pads only at the tail.
            if let Some(p) = r.iter().position(|&tk| tk == PAD) {
                assert!(r[p..].iter().all(|&tk| tk == PAD), "pad-tail seed={seed}");
            }
        }
    });
}

#[test]
fn eval_batches_cover_dataset_deterministically() {
    sweep(10, |seed, rng| {
        let n = rng.range(5, 40) as usize;
        let data = Task::SAqua.dataset(n, seed, 1);
        let b = 4usize;
        let mut seen = vec![0usize; n];
        let mut start = 0;
        while start < n {
            let (_, idxs) = task_batch_at(&data, start, b, 32);
            for &i in idxs.iter().take(b.min(n - start)) {
                seen[i] += 1;
            }
            start += b;
        }
        assert!(seen.iter().all(|&c| c == 1), "coverage seed={seed}: {seen:?}");
    });
}

#[test]
fn tokenizer_never_emits_reserved_ids_for_text() {
    sweep(10, |seed, rng| {
        let text = pretrain_mixture(seed, 500 + rng.below(500));
        let toks = encode(&text);
        assert!(toks.iter().all(|&t| t >= 4), "seed={seed}");
        assert_eq!(decode(&toks), text, "roundtrip seed={seed}");
    });
}

#[test]
fn pad_rows_respects_capacity() {
    let rows = vec![vec![BOS, 10, 11], vec![BOS, 20]];
    let t = pad_rows(&rows, 4, 5);
    assert_eq!(t.shape, vec![4, 5]);
    let v = t.as_i32();
    assert_eq!(&v[..5], &[BOS, 10, 11, PAD, PAD]);
    assert_eq!(&v[5..10], &[BOS, 20, PAD, PAD, PAD]);
    assert!(v[10..].iter().all(|&x| x == PAD));
}

#[test]
fn dataset_generators_deterministic_and_balanced() {
    let a = commonsense170k(400, 3);
    let b = commonsense170k(400, 3);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.prompt, y.prompt);
        assert_eq!(x.answer, y.answer);
    }
    // All 8 families appear.
    for t in COMMONSENSE_TASKS {
        let probe = t.example(&mut Rng::new(1)).prompt;
        let family_marker = probe.split_whitespace().next().unwrap().to_string();
        let _ = family_marker;
    }
    // Mixture has all arithmetic families (identified by regenerating).
    let m = math10k(600, 5);
    let mcq = m.iter().filter(|e| e.is_mcq()).count();
    assert!(mcq > 60 && mcq < 300, "aqua share off: {mcq}/600");
    let _ = ARITH_TASKS;
    let _ = EOS;
}

#[test]
fn answers_fit_decode_budget() {
    // Greedy decoding uses max_new = 6; every generated answer must fit.
    for t in ARITH_TASKS {
        let data = t.dataset(300, 9, 1);
        for ex in data {
            assert!(ex.answer.len() + 1 <= 6, "{:?}: answer '{}' too long", t, ex.answer);
        }
    }
}

//! Coordinator invariants: scheduler completion under injected failures,
//! batcher token conservation, data determinism, report round-trips —
//! the "routing/batching/state" property suite.

use cloq::data::batcher::{pad_rows, task_batch, task_batch_at, LmStream};
use cloq::data::tokenizer::{decode, encode, BOS, EOS, PAD};
use cloq::data::{commonsense170k, math10k, pretrain_mixture, Task, ARITH_TASKS, COMMONSENSE_TASKS};
use cloq::util::prng::Rng;
use cloq::util::threadpool::{run_collect_status, JobStatus};

fn sweep(cases: usize, f: impl Fn(u64, &mut Rng)) {
    for seed in 0..cases as u64 {
        let mut rng = Rng::new(0xC00D ^ seed.wrapping_mul(0xA24B_AED4_963E_E407));
        f(seed, &mut rng);
    }
}

#[test]
fn scheduler_completes_all_jobs_under_random_failures() {
    sweep(20, |seed, rng| {
        let n_jobs = rng.range(1, 40) as usize;
        let workers = rng.range(1, 8) as usize;
        let fail_mask: Vec<bool> = (0..n_jobs).map(|_| rng.chance(0.2)).collect();
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = fail_mask
            .iter()
            .enumerate()
            .map(|(i, &fail)| {
                Box::new(move || {
                    if fail {
                        panic!("injected");
                    }
                    i * 3
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let (results, statuses) = run_collect_status(workers, jobs);
        assert_eq!(results.len(), n_jobs);
        for i in 0..n_jobs {
            if fail_mask[i] {
                assert!(matches!(statuses[i], JobStatus::Panicked(_)), "seed={seed} job={i}");
                assert!(results[i].is_none());
            } else {
                assert_eq!(statuses[i], JobStatus::Done, "seed={seed} job={i}");
                assert_eq!(results[i], Some(i * 3));
            }
        }
    });
}

#[test]
fn lm_stream_conserves_tokens() {
    // Every non-BOS token of every batch must be a contiguous slice of the
    // source text: no token loss, no duplication within a pass.
    sweep(15, |seed, rng| {
        let text = pretrain_mixture(seed, 2000 + rng.below(2000));
        let toks = encode(&text);
        let (b, t) = (rng.range(1, 4) as usize, rng.range(8, 24) as usize);
        let mut s = LmStream::new(&text, b, t);
        let mut cursor = 0usize;
        for _ in 0..3 {
            let batch = s.next_batch().unwrap();
            let bt = batch.tokens.as_i32();
            for row in 0..b {
                let r = &bt[row * t..(row + 1) * t];
                assert_eq!(r[0], BOS, "seed={seed}");
                let need = t - 1;
                if cursor + need > toks.len() {
                    cursor = 0;
                }
                assert_eq!(&r[1..], &toks[cursor..cursor + need], "seed={seed} row={row}");
                cursor += need;
            }
        }
    });
}

#[test]
fn task_batches_are_well_formed() {
    sweep(15, |seed, rng| {
        let data = math10k(64, seed);
        let (b, t) = (4usize, rng.range(24, 48) as usize);
        let batch = task_batch(&data, b, t, rng);
        let toks = batch.tokens.as_i32();
        let mask = batch.mask.as_f32();
        for row in 0..b {
            let r = &toks[row * t..(row + 1) * t];
            let m = &mask[row * t..(row + 1) * t];
            assert_eq!(r[0], BOS);
            // mask ⊆ non-pad positions; mask is one contiguous run.
            let first = m.iter().position(|&x| x == 1.0);
            if let Some(f) = first {
                let len = m[f..].iter().take_while(|&&x| x == 1.0).count();
                assert!(m[f + len..].iter().all(|&x| x == 0.0), "contiguous seed={seed}");
                assert!(r[f..f + len].iter().all(|&tk| tk != PAD), "mask-on-pad seed={seed}");
            }
            // Pads only at the tail.
            if let Some(p) = r.iter().position(|&tk| tk == PAD) {
                assert!(r[p..].iter().all(|&tk| tk == PAD), "pad-tail seed={seed}");
            }
        }
    });
}

#[test]
fn eval_batches_cover_dataset_deterministically() {
    sweep(10, |seed, rng| {
        let n = rng.range(5, 40) as usize;
        let data = Task::SAqua.dataset(n, seed, 1);
        let b = 4usize;
        let mut seen = vec![0usize; n];
        let mut start = 0;
        while start < n {
            let (_, idxs) = task_batch_at(&data, start, b, 32);
            for &i in idxs.iter().take(b.min(n - start)) {
                seen[i] += 1;
            }
            start += b;
        }
        assert!(seen.iter().all(|&c| c == 1), "coverage seed={seed}: {seen:?}");
    });
}

#[test]
fn tokenizer_never_emits_reserved_ids_for_text() {
    sweep(10, |seed, rng| {
        let text = pretrain_mixture(seed, 500 + rng.below(500));
        let toks = encode(&text);
        assert!(toks.iter().all(|&t| t >= 4), "seed={seed}");
        assert_eq!(decode(&toks), text, "roundtrip seed={seed}");
    });
}

#[test]
fn pad_rows_respects_capacity() {
    let rows = vec![vec![BOS, 10, 11], vec![BOS, 20]];
    let t = pad_rows(&rows, 4, 5);
    assert_eq!(t.shape, vec![4, 5]);
    let v = t.as_i32();
    assert_eq!(&v[..5], &[BOS, 10, 11, PAD, PAD]);
    assert_eq!(&v[5..10], &[BOS, 20, PAD, PAD, PAD]);
    assert!(v[10..].iter().all(|&x| x == PAD));
}

#[test]
fn dataset_generators_deterministic_and_balanced() {
    let a = commonsense170k(400, 3);
    let b = commonsense170k(400, 3);
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.prompt, y.prompt);
        assert_eq!(x.answer, y.answer);
    }
    // All 8 families appear.
    for t in COMMONSENSE_TASKS {
        let probe = t.example(&mut Rng::new(1)).prompt;
        let family_marker = probe.split_whitespace().next().unwrap().to_string();
        let _ = family_marker;
    }
    // Mixture has all arithmetic families (identified by regenerating).
    let m = math10k(600, 5);
    let mcq = m.iter().filter(|e| e.is_mcq()).count();
    assert!(mcq > 60 && mcq < 300, "aqua share off: {mcq}/600");
    let _ = ARITH_TASKS;
    let _ = EOS;
}

#[test]
fn answers_fit_decode_budget() {
    // Greedy decoding uses max_new = 6; every generated answer must fit.
    for t in ARITH_TASKS {
        let data = t.dataset(300, 9, 1);
        for ex in data {
            assert!(ex.answer.len() + 1 <= 6, "{:?}: answer '{}' too long", t, ex.answer);
        }
    }
}

//! Parity suite for the blocked lazy-batch OPTQ engine (the ISSUE 1
//! tentpole): `optq` (blocked) must be BIT-IDENTICAL to `optq_unblocked`
//! (the retained row-by-row reference) — same codes, same scales/zeros,
//! same dequantized values — for every bit-width, group size, block size
//! (including non-divisible edges) and act-order setting.
//!
//! Bit-exactness (not a tolerance band) is achievable because the blocked
//! engine preserves the per-element floating-point operation order of the
//! reference: the deferred panel product applies updates in ascending row
//! order per element, and lazy group fits replay pending updates before
//! reading trailing members (see the `quant::optq` module docs). A ≤1e-10
//! Frobenius fallback is asserted first so a hypothetical future kernel
//! that reassociates still fails loudly at the *right* severity.

use cloq::linalg::{matmul, syrk_t, Matrix};
use cloq::quant::grid::QuantizedTensor;
use cloq::quant::optq::{optq, optq_unblocked, OptqConfig};
use cloq::util::prng::Rng;

/// Correlated-activation layer like the ones the pipeline quantizes.
fn layer(m: usize, n: usize, samples: usize, seed: u64) -> (Matrix, Matrix) {
    let mut rng = Rng::new(seed);
    let base = Matrix::randn(samples, m, 1.0, &mut rng);
    let mix = Matrix::randn(m, m, 0.3, &mut rng);
    let x = matmul(&base, &mix.add(&Matrix::eye(m)));
    let w = Matrix::randn(m, n, 0.5, &mut rng);
    (w, syrk_t(&x))
}

fn assert_bit_identical(a: &QuantizedTensor, b: &QuantizedTensor, ctx: &str) {
    // Frobenius guard first (the ISSUE's ≤1e-10 fallback criterion) …
    let fro2: f64 = a
        .dequantize()
        .sub(&b.dequantize())
        .data
        .iter()
        .map(|x| x * x)
        .sum();
    assert!(fro2.sqrt() <= 1e-10, "{ctx}: Frobenius gap {}", fro2.sqrt());
    // … then the real contract: bit-exact equality of the full state.
    assert_eq!(a.codes, b.codes, "{ctx}: codes differ");
    assert_eq!(a.scales.data, b.scales.data, "{ctx}: scales differ");
    assert_eq!(a.zeros.data, b.zeros.data, "{ctx}: zeros differ");
    assert_eq!(a.group_size, b.group_size, "{ctx}");
    assert_eq!(a.bits, b.bits, "{ctx}");
}

fn check(w: &Matrix, h: &Matrix, cfg: &OptqConfig, ctx: &str) {
    let blocked = optq(w, h, cfg);
    let reference = optq_unblocked(w, h, cfg);
    assert_bit_identical(&blocked, &reference, ctx);
}

#[test]
fn bit_exact_across_bits_and_group_sizes() {
    let (w, h) = layer(64, 24, 192, 900);
    for &bits in &[2u32, 3, 4] {
        // Group sizes: tiny, non-divisor of m, block-aligned, per-channel.
        for &gs in &[8usize, 17, 32, 64] {
            for &bs in &[2usize, 16, 32, 64] {
                let cfg = OptqConfig { bits, group_size: gs, block_size: bs, ..Default::default() };
                check(&w, &h, &cfg, &format!("bits={bits} gs={gs} bs={bs}"));
            }
        }
    }
}

#[test]
fn bit_exact_on_non_divisible_block_edges() {
    // m = 45 with block sizes straddling every edge case: non-divisor,
    // m−1, m, m+1, and far beyond m (single block).
    let (w, h) = layer(45, 7, 128, 901);
    for &bs in &[7usize, 31, 44, 45, 46, 1000] {
        let cfg = OptqConfig { bits: 3, group_size: 20, block_size: bs, ..Default::default() };
        check(&w, &h, &cfg, &format!("m=45 bs={bs}"));
    }
}

#[test]
fn bit_exact_with_act_order() {
    // act_order scatters the members of one quantization group across the
    // whole permuted row order — the hardest case for the lazy group fit
    // (it must replay pending deferred updates for trailing members).
    for seed in [902u64, 903, 904] {
        let (w, h) = layer(48, 12, 160, seed);
        for &bits in &[2u32, 4] {
            for &bs in &[5usize, 16, 48] {
                let cfg = OptqConfig {
                    bits,
                    group_size: 16,
                    act_order: true,
                    block_size: bs,
                    ..Default::default()
                };
                check(&w, &h, &cfg, &format!("act_order seed={seed} bits={bits} bs={bs}"));
            }
        }
    }
}

#[test]
fn bit_exact_on_rectangular_and_tiny_shapes() {
    for &(m, n, samples, seed) in &[
        (3usize, 1usize, 16usize, 905u64), // degenerate thin
        (96, 8, 256, 906),                 // tall
        (16, 96, 64, 907),                 // wide
        (33, 33, 100, 908),                // odd square
    ] {
        let (w, h) = layer(m, n, samples, seed);
        for &bs in &[2usize, 13, 32] {
            let cfg = OptqConfig { bits: 2, group_size: 16, block_size: bs, ..Default::default() };
            check(&w, &h, &cfg, &format!("{m}x{n} bs={bs}"));
        }
    }
}

#[test]
fn bit_exact_with_rank_deficient_hessian() {
    // Fewer samples than features: the escalating-damping branch runs in
    // prepare(); both paths must still agree bit-for-bit.
    let mut rng = Rng::new(909);
    let x = Matrix::randn(8, 40, 1.0, &mut rng);
    let w = Matrix::randn(40, 10, 1.0, &mut rng);
    let h = syrk_t(&x);
    for &bs in &[4usize, 32] {
        let cfg = OptqConfig { bits: 4, group_size: 40, block_size: bs, ..Default::default() };
        check(&w, &h, &cfg, &format!("rank-deficient bs={bs}"));
    }
}

#[test]
fn block_size_one_selects_reference_path() {
    let (w, h) = layer(32, 8, 96, 910);
    let cfg = OptqConfig { bits: 3, group_size: 16, block_size: 1, ..Default::default() };
    let a = optq(&w, &h, &cfg);
    let b = optq_unblocked(&w, &h, &cfg);
    assert_bit_identical(&a, &b, "bs=1 dispatch");
}

//! The error-taxonomy suite: every public failure path of the serving
//! façade must resolve to its matching [`ServeError`] variant, asserted
//! with `matches!` — never by string search. This is the contract that
//! lets callers branch on failures (retry on `Overloaded`, re-route on
//! `ShuttingDown`, fail the tenant on `UnknownAdapter`) without parsing
//! messages.
//!
//! Paths covered: unknown layer / unknown adapter (resolution AND
//! submission), adapter-coverage mismatches, shape mismatches, bad route
//! chains, overload rejection with in-kernel hops counted, post-close
//! submission, kernel panics (single-layer and mid-traversal), step-fn
//! failures, artifact corruption naming the layer with a classified
//! kind, builder/config validation, foreign engine handles (identity
//! tokens — and the O(1) fast path they buy), stale-generation adapter
//! handles after slot recycling, caller-side `wait_timeout` deadlines,
//! and the `anyhow` interop offline callers rely on.

use std::sync::mpsc;

use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    AdapterSet, ArtifactErrorKind, ArtifactStore, DequantParams, ModelRequest, PackedLayer,
    PackedModel, ServeEngine, ServeError, SessionRequest, StepFn,
};
use cloq::util::prng::Rng;

fn model(seed: u64) -> PackedModel {
    // wq: 24→10, wo: 18→7 — deliberately NOT chainable.
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for (name, m, n) in [("wq", 24usize, 10usize), ("wo", 18, 7)] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        layers.push(
            PackedLayer::from_state(name, &QuantState::Int(quantize_rtn(&w, 4, 8))).unwrap(),
        );
    }
    PackedModel::new(layers)
}

fn adapter(id: &str, model: &PackedModel, seed: u64) -> AdapterSet {
    let mut rng = Rng::new(seed);
    let mut set = AdapterSet::new(id);
    for l in &model.layers {
        set.insert(
            &l.name,
            LoraPair::new(
                Matrix::randn(l.rows, 2, 0.1, &mut rng),
                Matrix::randn(l.cols, 2, 0.1, &mut rng),
            ),
        )
        .unwrap();
    }
    set
}

#[test]
fn unknown_layer_and_adapter_are_typed_at_resolution_and_submission() {
    let engine = ServeEngine::builder(model(800)).build().unwrap();
    assert!(matches!(
        engine.layer("ghost").unwrap_err(),
        ServeError::UnknownLayer { layer } if layer == "ghost"
    ));
    assert!(matches!(
        engine.adapter("nobody").unwrap_err(),
        ServeError::UnknownAdapter { adapter } if adapter == "nobody"
    ));
    // The name-resolving submission path reports the same variants.
    assert!(matches!(
        engine.submit_named("ghost", None, vec![0.0; 4]).wait().unwrap_err(),
        ServeError::UnknownLayer { .. }
    ));
    assert!(matches!(
        engine.submit_named("wq", Some("nobody"), vec![0.0; 24]).wait().unwrap_err(),
        ServeError::UnknownAdapter { .. }
    ));
    engine.shutdown();
}

#[test]
fn coverage_and_shape_mismatches_are_typed() {
    let m = model(801);
    let engine = ServeEngine::builder(model(801)).build().unwrap();
    // An adapter covering ONLY wq.
    let mut partial = AdapterSet::new("partial");
    {
        let l = m.layer("wq").unwrap();
        let mut rng = Rng::new(802);
        partial
            .insert(
                "wq",
                LoraPair::new(
                    Matrix::randn(l.rows, 2, 0.1, &mut rng),
                    Matrix::randn(l.cols, 2, 0.1, &mut rng),
                ),
            )
            .unwrap();
    }
    let pid = engine.register_adapter(partial).unwrap().id;
    let (wq, wo) = (engine.layer("wq").unwrap(), engine.layer("wo").unwrap());
    // Single-layer coverage miss names the layer.
    assert!(matches!(
        engine.submit(wo, Some(pid), vec![0.0; 18]).wait().unwrap_err(),
        ServeError::AdapterMismatch { adapter, layer: Some(l) }
            if adapter == "partial" && l == "wo"
    ));
    // Route-level coverage miss has layer: None.
    let wo_route = engine.route(&["wo"]).unwrap();
    assert!(matches!(
        engine
            .submit_model(ModelRequest::with_adapter(wo_route, pid, vec![0.0; 18]))
            .wait()
            .unwrap_err(),
        ServeError::AdapterMismatch { adapter, layer: None } if adapter == "partial"
    ));
    // Wrong input width names the layer it missed.
    assert!(matches!(
        engine.submit(wq, None, vec![0.0; 3]).wait().unwrap_err(),
        ServeError::ShapeMismatch { layer, .. } if layer == "wq"
    ));
    // A misshapen adapter set is refused at registration.
    let mut bad = AdapterSet::new("bad");
    bad.insert("wq", LoraPair::new(Matrix::zeros(24, 2), Matrix::zeros(9, 2))).unwrap();
    assert!(matches!(
        engine.register_adapter(bad).unwrap_err(),
        ServeError::ShapeMismatch { layer, .. } if layer == "wq"
    ));
    engine.shutdown();
}

#[test]
fn broken_route_chains_are_bad_route() {
    let engine = ServeEngine::builder(model(803)).build().unwrap();
    // wq outputs 10 features; wo takes 18 — the chain is broken.
    assert!(matches!(
        engine.route(&["wq", "wo"]).unwrap_err(),
        ServeError::BadRoute { .. }
    ));
    assert!(matches!(engine.route::<&str>(&[]).unwrap_err(), ServeError::BadRoute { .. }));
    // The model-side constructor agrees (same taxonomy offline).
    let m = model(803);
    assert!(matches!(m.route(&["wq", "wo"]).unwrap_err(), ServeError::BadRoute { .. }));
    engine.shutdown();
}

#[test]
fn overload_rejection_is_typed_and_counts_in_kernel_hops() {
    // One worker, max_pending = 1. A session PARKS inside the kernel (its
    // step fn blocks on a gate), so the engine's only live hop slot is
    // held by work that is invisible to the FIFO — the next submit must
    // still be Overloaded.
    let mut rng = Rng::new(804);
    let w = Matrix::randn(8, 8, 0.3, &mut rng);
    let sq = PackedLayer::from_state("sq", &QuantState::Int(quantize_rtn(&w, 4, 8))).unwrap();
    let engine = ServeEngine::builder(PackedModel::new(vec![sq]))
        .workers(1)
        .max_pending(1)
        .build()
        .unwrap();
    let lid = engine.layer("sq").unwrap();
    let route = engine.route(&["sq"]).unwrap();
    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let step: StepFn = Box::new(move |_, y| {
        entered_tx.send(()).unwrap();
        gate_rx.recv().unwrap();
        Some(y.to_vec())
    });
    let session = engine.submit_session(SessionRequest::new(route, rng.gauss_vec(8), 2, step));
    entered_rx.recv().unwrap(); // the hop is mid-kernel; the FIFO is empty
    let err = engine.submit(lid, None, rng.gauss_vec(8)).wait().unwrap_err();
    assert!(matches!(err, ServeError::Overloaded { max_pending: 1 }), "{err:?}");
    gate_tx.send(()).unwrap();
    assert!(session.wait().is_ok());
    let stats = engine.shutdown();
    assert_eq!(stats.rejected, 1);
}

#[test]
fn post_close_submission_is_shutting_down() {
    let engine = ServeEngine::builder(model(805)).build().unwrap();
    let wq = engine.layer("wq").unwrap();
    let route = engine.route(&["wq"]).unwrap();
    let admitted = engine.submit(wq, None, vec![0.5; 24]);
    engine.close();
    assert!(matches!(
        engine.submit(wq, None, vec![0.5; 24]).wait().unwrap_err(),
        ServeError::ShuttingDown
    ));
    assert!(matches!(
        engine.submit_model(ModelRequest::new(route.clone(), vec![0.5; 24])).wait().unwrap_err(),
        ServeError::ShuttingDown
    ));
    let step: StepFn = Box::new(|_, y| Some(y.to_vec()));
    assert!(matches!(
        engine
            .submit_session(SessionRequest::new(route, vec![0.5; 24], 2, step))
            .wait()
            .unwrap_err(),
        ServeError::ShuttingDown
    ));
    assert!(admitted.wait().is_ok(), "pre-close admissions drain normally");
    let stats = engine.shutdown();
    assert_eq!(stats.rejected, 3);
}

/// A layer whose kernel panics on any request (codes index past the
/// codebook).
fn boom_layer(n: usize) -> PackedLayer {
    let wpr = cloq::serve::words_per_row(n, 2);
    PackedLayer {
        name: "boom".to_string(),
        rows: n,
        cols: n,
        bits: 2,
        group_size: n,
        packed: vec![u32::MAX; n * wpr].into(),
        params: DequantParams::Codebook {
            levels: vec![0.0, 1.0],
            absmax: Matrix::zeros(1, n),
        },
    }
}

#[test]
fn kernel_and_step_failures_are_typed() {
    let mut rng = Rng::new(806);
    let w = Matrix::randn(8, 8, 0.3, &mut rng);
    let ok = PackedLayer::from_state("ok", &QuantState::Int(quantize_rtn(&w, 4, 8))).unwrap();
    let engine =
        ServeEngine::builder(PackedModel::new(vec![ok, boom_layer(8)])).workers(1).build().unwrap();
    let boom = engine.layer("boom").unwrap();
    // Single-layer rider: WorkerPanic with hop: None.
    assert!(matches!(
        engine.submit(boom, None, vec![1.0; 8]).wait().unwrap_err(),
        ServeError::WorkerPanic { layer, hop: None, .. } if layer == "boom"
    ));
    // Traversal rider: WorkerPanic names the failing hop.
    let doomed = engine.route(&["ok", "boom"]).unwrap();
    assert!(matches!(
        engine
            .submit_model(ModelRequest::new(doomed, rng.gauss_vec(8)))
            .wait()
            .unwrap_err(),
        ServeError::WorkerPanic { layer, hop: Some(2), .. } if layer == "boom"
    ));
    // Step-fn failures are StepFailed, not WorkerPanic.
    let ok_route = engine.route(&["ok"]).unwrap();
    let panicking: StepFn = Box::new(|_, _| panic!("boom step"));
    assert!(matches!(
        engine
            .submit_session(SessionRequest::new(ok_route, rng.gauss_vec(8), 2, panicking))
            .wait()
            .unwrap_err(),
        ServeError::StepFailed { forward: 1, .. }
    ));
    engine.shutdown();
}

#[test]
fn corrupt_artifacts_are_typed_with_kind_and_layer() {
    let store = ArtifactStore::at(
        std::env::temp_dir().join(format!("cloq_errors_{}", std::process::id())),
    );
    let m = model(807);
    let path = store.save_base(&m, "base.cloqpkd2").unwrap();
    let mut bytes = std::fs::read(&path).unwrap();
    // Flip a bit deep in the LAST layer's payload: checksum catches it
    // and the typed error carries both the classified kind and the
    // offending layer's NAME.
    let n = bytes.len();
    bytes[n - 30] ^= 0x40;
    std::fs::write(store.path("bad.cloqpkd2"), &bytes).unwrap();
    assert!(matches!(
        store.open("bad.cloqpkd2").unwrap_err(),
        ServeError::Artifact {
            kind: ArtifactErrorKind::ChecksumMismatch,
            layer: Some(l),
            ..
        } if l == "wo"
    ));
    // Truncation and magic/version damage classify differently.
    std::fs::write(store.path("cut.cloqpkd2"), &bytes[..n / 2]).unwrap();
    assert!(matches!(
        store.open("cut.cloqpkd2").unwrap_err(),
        ServeError::Artifact { kind: ArtifactErrorKind::Truncated, .. }
    ));
    std::fs::write(store.path("junk.bin"), b"NOTCLOQ!whatever").unwrap();
    assert!(matches!(
        store.open("junk.bin").unwrap_err(),
        ServeError::Artifact { kind: ArtifactErrorKind::BadMagic, .. }
    ));
    assert!(matches!(
        store.open("missing.bin").unwrap_err(),
        ServeError::Artifact { kind: ArtifactErrorKind::Io, .. }
    ));
    std::fs::remove_dir_all(store.dir()).ok();
}

#[test]
fn config_validation_is_typed() {
    assert!(matches!(
        ServeEngine::builder(model(808)).workers(0).build().unwrap_err(),
        ServeError::InvalidConfig { .. }
    ));
    assert!(matches!(
        ServeEngine::builder(model(808)).max_pending(0).build().unwrap_err(),
        ServeError::InvalidConfig { .. }
    ));
    // An adapter set larger than the whole registry budget is config-bad.
    let m = model(809);
    let engine = ServeEngine::builder(model(809)).adapter_budget(8).build().unwrap();
    assert!(matches!(
        engine.register_adapter(adapter("huge", &m, 810)).unwrap_err(),
        ServeError::InvalidConfig { .. }
    ));
    // Duplicate layers inside one adapter set are config-bad too.
    let mut dup = AdapterSet::new("dup");
    dup.insert("wq", LoraPair::new(Matrix::zeros(24, 1), Matrix::zeros(10, 1))).unwrap();
    assert!(matches!(
        dup.insert("wq", LoraPair::new(Matrix::zeros(24, 1), Matrix::zeros(10, 1)))
            .unwrap_err(),
        ServeError::InvalidConfig { .. }
    ));
    engine.shutdown();
}

#[test]
fn foreign_engine_handles_are_refused_typed() {
    // Two engines over IDENTICAL models: without identity tokens, a
    // handle minted by one would silently address whatever sits at that
    // index in the other. Tokens make that a typed refusal — and buy the
    // fast path: a handle carrying THIS engine's token is trusted with
    // one integer compare instead of the O(hops) route re-walk.
    let m = model(820);
    let a = ServeEngine::builder(model(820)).build().unwrap();
    let b = ServeEngine::builder(model(820)).build().unwrap();
    let wq_b = b.layer("wq").unwrap();
    let route_b = b.route(&["wq"]).unwrap();
    let aid_b = b.register_adapter(adapter("tenant", &m, 821)).unwrap().id;
    // The fast path: a's own bound handles admit and return the same
    // bits as the direct forward (the token compare replaced the
    // bounds/route re-validation, not the math).
    let wq_a = a.layer("wq").unwrap();
    let route_a = a.route(&["wq"]).unwrap();
    let mut rng = Rng::new(823);
    let x = rng.gauss_vec(24);
    let direct = m.layers[0].forward(&x, None);
    let y = a.submit(wq_a, None, x.clone()).wait().unwrap().y;
    for (u, v) in y.iter().zip(&direct) {
        assert_eq!(u.to_bits(), v.to_bits());
    }
    let y = a.submit_model(ModelRequest::new(route_a, x)).wait().unwrap().y;
    for (u, v) in y.iter().zip(&direct) {
        assert_eq!(u.to_bits(), v.to_bits());
    }
    // b's layer handle → BadRoute naming the token mismatch.
    let err = a.submit(wq_b, None, vec![0.0; 24]).wait().unwrap_err();
    assert!(matches!(err, ServeError::BadRoute { .. }), "{err:?}");
    assert!(format!("{err}").contains("different engine"), "{err}");
    // b's route → BadRoute, even though every index is in range here.
    let err = a.submit_model(ModelRequest::new(route_b, vec![0.0; 24])).wait().unwrap_err();
    assert!(matches!(err, ServeError::BadRoute { .. }), "{err:?}");
    // b's adapter id → AdapterMismatch carrying the SLOT, not a name:
    // a's registry has a different tenant at that slot, and naming it
    // would point the operator at the wrong tenant.
    let a_same_slot = a.register_adapter(adapter("other", &m, 822)).unwrap().id;
    assert_eq!(a_same_slot.index(), aid_b.index(), "same slot in both registries");
    let err = a.submit(wq_a, Some(aid_b), vec![0.0; 24]).wait().unwrap_err();
    assert!(
        matches!(&err, ServeError::AdapterMismatch { adapter, layer: None } if adapter == "#0"),
        "{err:?}"
    );
    // a's registry still resolves its own tenant by its own id.
    assert!(a.submit(wq_a, Some(a_same_slot), vec![0.0; 24]).wait().is_ok());
    a.shutdown();
    b.shutdown();
}

#[test]
fn stale_generation_handles_fail_typed_after_slot_recycling() {
    // Unregister + re-register recycles the intern SLOT; the generation
    // word in the handle keeps a dead incarnation's AdapterId from
    // silently addressing the new tenant occupying that slot.
    let m = model(830);
    let engine = ServeEngine::builder(model(830)).build().unwrap();
    let stale = engine.register_adapter(adapter("ten", &m, 831)).unwrap().id;
    engine.unregister_adapter("ten").unwrap();
    let fresh = engine.register_adapter(adapter("ten", &m, 832)).unwrap().id;
    assert_eq!(stale.index(), fresh.index(), "the slot is recycled");
    assert_ne!(stale, fresh, "the generation is not");
    assert_eq!(fresh.generation(), stale.generation() + 1);
    let wq = engine.layer("wq").unwrap();
    // The dead handle fails typed — and BY NAME: `name_of` works across
    // generations, so the 3 a.m. error still says which tenant.
    let err = engine.submit(wq, Some(stale), vec![0.0; 24]).wait().unwrap_err();
    assert!(
        matches!(&err, ServeError::UnknownAdapter { adapter } if adapter == "ten"),
        "{err:?}"
    );
    // The traversal path refuses identically.
    let route = engine.route(&["wq"]).unwrap();
    let err = engine
        .submit_model(ModelRequest::with_adapter(route, stale, vec![0.0; 24]))
        .wait()
        .unwrap_err();
    assert!(
        matches!(&err, ServeError::UnknownAdapter { adapter } if adapter == "ten"),
        "{err:?}"
    );
    // The live incarnation serves, and name resolution yields ITS id.
    assert!(engine.submit(wq, Some(fresh), vec![0.0; 24]).wait().is_ok());
    assert_eq!(engine.adapter("ten").unwrap(), fresh);
    let stats = engine.shutdown();
    assert_eq!(stats.rejected, 2);
}

#[test]
fn wait_timeout_is_typed_and_does_not_cancel_the_request() {
    // A session parks mid-kernel on a gate; the caller's deadline fires
    // first. The deadline is caller-side only: releasing the gate lets
    // the request complete in the engine (it still counts in
    // model_requests) with its reply dropped on the floor.
    let mut rng = Rng::new(840);
    let w = Matrix::randn(8, 8, 0.3, &mut rng);
    let sq = PackedLayer::from_state("sq", &QuantState::Int(quantize_rtn(&w, 4, 8))).unwrap();
    let engine = ServeEngine::builder(PackedModel::new(vec![sq])).workers(1).build().unwrap();
    let route = engine.route(&["sq"]).unwrap();
    let lid = engine.layer("sq").unwrap();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let step: StepFn = Box::new(move |_, y| {
        gate_rx.recv().unwrap();
        Some(y.to_vec())
    });
    let session = engine.submit_session(SessionRequest::new(route, rng.gauss_vec(8), 2, step));
    let deadline = std::time::Duration::from_millis(30);
    let err = session.wait_timeout(deadline).unwrap_err();
    assert!(matches!(err, ServeError::Timeout { elapsed } if elapsed >= deadline), "{err:?}");
    gate_tx.send(()).unwrap(); // the request still completes in the engine
    // A reply inside the deadline comes through the same API unchanged.
    let ok = engine
        .submit(lid, None, rng.gauss_vec(8))
        .wait_timeout(std::time::Duration::from_secs(30));
    assert!(ok.is_ok(), "{ok:?}");
    let stats = engine.shutdown();
    assert_eq!(stats.model_requests, 1, "the timed-out session still completed");
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.rejected, 0, "a caller-side timeout is not a rejection");
}

#[test]
fn serve_errors_flow_into_anyhow_for_offline_callers() {
    // The coordinator-style pattern: typed serve results consumed in an
    // anyhow context with plain `?`.
    fn offline(engine: &ServeEngine) -> anyhow::Result<usize> {
        let wq = engine.layer("wq")?;
        let y = engine.submit(wq, None, vec![0.25; 24]).wait()?;
        Ok(y.y.len())
    }
    let engine = ServeEngine::builder(model(811)).build().unwrap();
    assert_eq!(offline(&engine).unwrap(), 10);
    fn offline_bad(engine: &ServeEngine) -> anyhow::Result<()> {
        engine.layer("ghost")?;
        Ok(())
    }
    let msg = format!("{}", offline_bad(&engine).unwrap_err());
    assert!(msg.contains("no such layer 'ghost'"), "{msg}");
    engine.shutdown();
}

//! Lifecycle tests for the sharded work-stealing dispatch core: the
//! per-shard closed+empty drain barrier finishes every admitted traversal
//! (and answers bit-identically to the global reference core), a kernel
//! panic on one shard's batch fails only the riders of that batch, and a
//! CONSTRUCTED steal — two gated sessions pinning both workers of a
//! single-shard workload — both registers in `dispatch_steals_total` and
//! returns bit-identical responses (batch composition, stolen or not,
//! can never change a response's numbers).

use std::sync::mpsc;

use cloq::linalg::Matrix;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    Counter, DequantParams, Dispatch, ModelRequest, PackedLayer, PackedModel, ServeEngine,
    ServeError, SessionRequest, StepFn,
};
use cloq::util::prng::Rng;

fn square_layer(name: &str, n: usize, seed: u64) -> PackedLayer {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(n, n, 0.3, &mut rng);
    PackedLayer::from_state(name, &QuantState::Int(quantize_rtn(&w, 4, 8))).unwrap()
}

/// A layer whose kernel panics on ANY request (packed codes index past
/// the codebook) — the per-shard failure-isolation probe.
fn boom_layer(n: usize) -> PackedLayer {
    let wpr = cloq::serve::words_per_row(n, 2);
    PackedLayer {
        name: "boom".to_string(),
        rows: n,
        cols: n,
        bits: 2,
        group_size: n,
        packed: vec![u32::MAX; n * wpr].into(),
        params: DequantParams::Codebook {
            levels: vec![0.0, 1.0],
            absmax: Matrix::zeros(1, n),
        },
    }
}

#[test]
fn shutdown_drains_across_shards_and_matches_global_bit_for_bit() {
    // Identical workload under both dispatch cores: 24 three-hop model
    // requests + 4 three-step sessions over a 3-layer route that spans
    // both shards of a 2-worker engine (layers 0,2 → shard 0; layer 1 →
    // shard 1), then an immediate shutdown. The sharded drain must finish
    // every remaining hop — traversals re-enter ANOTHER layer's shard
    // from inside a worker while the engine is closing — and the answers
    // must match the global reference core bit-for-bit.
    let mut answers: Vec<Vec<Vec<f64>>> = Vec::new();
    for dispatch in [Dispatch::Sharded, Dispatch::Global] {
        let model = PackedModel::new(vec![
            square_layer("a", 16, 700),
            square_layer("b", 16, 701),
            square_layer("c", 16, 702),
        ]);
        let engine = ServeEngine::builder(model)
            .dispatch(dispatch)
            .workers(2)
            .max_batch(8)
            .build()
            .unwrap();
        let route = engine.route(&["a", "b", "c"]).unwrap();
        let mut rng = Rng::new(703); // same stream in both modes
        let models: Vec<_> = (0..24)
            .map(|_| engine.submit_model(ModelRequest::new(route.clone(), rng.gauss_vec(16))))
            .collect();
        let sessions: Vec<_> = (0..4)
            .map(|_| {
                let step: StepFn = Box::new(|_, y| Some(y.to_vec()));
                engine
                    .submit_session(SessionRequest::new(route.clone(), rng.gauss_vec(16), 3, step))
            })
            .collect();
        let tel = engine.telemetry_handle();
        let stats = engine.shutdown(); // must answer all 28 traversals first
        assert_eq!(stats.model_requests, 28, "{dispatch:?}");
        assert_eq!(stats.session_forwards, 24 + 4 * 3, "{dispatch:?}");
        assert_eq!(stats.hops, (24 + 4 * 3) * 3, "{dispatch:?}");
        assert_eq!(stats.failed_model_requests, 0, "{dispatch:?}");
        let reentries = tel.snapshot(&[]).counter(Counter::ShardReentries);
        match dispatch {
            // Every hop after a traversal's first is a cross-shard push
            // from inside a worker: 24·2 model re-entries + 4·8 session
            // re-entries.
            Dispatch::Sharded => assert_eq!(reentries, 24 * 2 + 4 * 8),
            Dispatch::Global => assert_eq!(reentries, 0, "a global-core-only run must not tick"),
        }
        let mut ys = Vec::new();
        for t in models {
            let r = t.wait().unwrap();
            assert_eq!(r.forwards, 1);
            ys.push(r.y);
        }
        for t in sessions {
            let r = t.wait().unwrap();
            assert_eq!(r.forwards, 3);
            ys.push(r.y);
        }
        answers.push(ys);
    }
    for (k, (s, g)) in answers[0].iter().zip(&answers[1]).enumerate() {
        assert_eq!(s.len(), g.len());
        for (u, v) in s.iter().zip(g) {
            assert_eq!(u.to_bits(), v.to_bits(), "traversal {k}: sharded diverged from global");
        }
    }
}

#[test]
fn panicking_shard_fails_only_its_own_traversal_in_both_modes() {
    // The boom layer owns shard 1 of 2 (layer index 1); healthy layers
    // own shard 0. Whichever worker executes the boom batch — its owner
    // or a stealer — the panic is contained to that batch's riders and
    // the worker survives to keep draining both shards.
    for dispatch in [Dispatch::Sharded, Dispatch::Global] {
        let model = PackedModel::new(vec![
            square_layer("ok1", 10, 720),
            boom_layer(10),
            square_layer("ok2", 10, 721),
        ]);
        let engine = ServeEngine::builder(model)
            .dispatch(dispatch)
            .workers(2)
            .max_batch(8)
            .build()
            .unwrap();
        let doomed_route = engine.route(&["ok1", "boom", "ok2"]).unwrap();
        let healthy_route = engine.route(&["ok1", "ok2"]).unwrap();
        let mut rng = Rng::new(722);
        let doomed = engine.submit_model(ModelRequest::new(doomed_route, rng.gauss_vec(10)));
        let healthy =
            engine.submit_model(ModelRequest::new(healthy_route.clone(), rng.gauss_vec(10)));
        let err = doomed.wait().unwrap_err();
        assert!(
            matches!(
                &err,
                ServeError::WorkerPanic { layer, hop: Some(2), .. } if layer == "boom"
            ),
            "{dispatch:?}: typed WorkerPanic naming layer and hop expected: {err:?}"
        );
        assert!(healthy.wait().is_ok(), "{dispatch:?}: unrelated traversal must be unaffected");
        // The worker survived: both shards keep serving afterwards.
        assert!(engine
            .submit_model(ModelRequest::new(healthy_route, rng.gauss_vec(10)))
            .wait()
            .is_ok());
        let stats = engine.shutdown();
        assert_eq!(stats.failed_model_requests, 1, "{dispatch:?}");
        // `model_requests` counts completions: the doomed traversal is
        // in `failed_model_requests` instead.
        assert_eq!(stats.model_requests, 2, "{dispatch:?}");
        assert!(stats.batch_panics >= 1, "{dispatch:?}");
        assert_eq!(stats.failed, 0, "{dispatch:?}: no single-layer rider rode that batch");
    }
}

#[test]
fn constructed_steal_registers_and_is_bit_identical_to_direct_forward() {
    // Single-layer model: EVERY request maps to shard 0 of 2, so worker 1
    // only ever gets work by stealing. Two sessions whose step functions
    // park mid-kernel pin both workers: the sessions were necessarily
    // taken by DIFFERENT workers (each blocks its taker), and only
    // worker 0 owns shard 0 — so at least one acquisition crossed shards.
    // That makes `Steals >= 1` deterministic, not scheduling luck.
    let n = 12;
    let model = PackedModel::new(vec![square_layer("sq", n, 750)]);
    let reference = square_layer("sq", n, 750); // same seed, same weights
    let engine = ServeEngine::builder(model).workers(2).max_batch(4).build().unwrap();
    let route = engine.route(&["sq"]).unwrap();
    let sq = engine.layer("sq").unwrap();
    let mut rng = Rng::new(751);
    let mut gated = Vec::new();
    let mut gates = Vec::new();
    let mut inputs = Vec::new();
    for _ in 0..2 {
        let (entered_tx, entered_rx) = mpsc::channel::<()>();
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let step: StepFn = Box::new(move |_, y| {
            entered_tx.send(()).unwrap();
            gate_rx.recv().unwrap();
            Some(y.to_vec())
        });
        let x = rng.gauss_vec(n);
        inputs.push(x.clone());
        let t = engine.submit_session(SessionRequest::new(route.clone(), x, 2, step));
        entered_rx.recv().unwrap(); // this session is now mid-step on SOME worker
        gated.push(t);
        gates.push(gate_tx);
    }
    // Flood plain requests while both workers are pinned: they pile up in
    // shard 0 and are drained by both workers (more steals) once the
    // gates open.
    let flood: Vec<(Vec<f64>, _)> = (0..32)
        .map(|_| {
            let x = rng.gauss_vec(n);
            (x.clone(), engine.submit(sq, None, x))
        })
        .collect();
    for g in gates {
        g.send(()).unwrap();
    }
    for (i, t) in gated.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_eq!(r.forwards, 2);
        // Two identity-stepped forwards == the layer applied twice.
        let direct = reference.forward(&reference.forward(&inputs[i], None), None);
        for (u, v) in r.y.iter().zip(&direct) {
            assert_eq!(u.to_bits(), v.to_bits(), "gated session {i} diverged");
        }
    }
    for (x, t) in flood {
        let direct = reference.forward(&x, None);
        let r = t.wait().unwrap();
        for (u, v) in r.y.iter().zip(&direct) {
            assert_eq!(u.to_bits(), v.to_bits(), "steal-path response must be bit-identical");
        }
    }
    let tel = engine.telemetry();
    assert!(tel.counter(Counter::Steals) >= 1, "constructed steal did not register");
    assert!(tel.max_shard_depth_seen >= 1, "pushes must record shard depth");
    let stats = engine.shutdown();
    assert_eq!(stats.requests, 32);
    assert_eq!(stats.model_requests, 2);
    assert_eq!(stats.failed, 0);
    assert_eq!(stats.failed_model_requests, 0);
}

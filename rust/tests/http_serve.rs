//! Loopback integration tests for the HTTP front-end: every endpoint
//! round-tripped over a real socket against the in-process typed façade.
//!
//! The contracts under test (see `serve::http` module docs):
//!
//! * wire parity — a response decoded from the HTTP JSON body is
//!   bit-identical (0 ULP) to the same computation through the in-process
//!   façade, for single-layer submit, multi-hop forward, and multi-step
//!   sessions;
//! * the full tenant adapter lifecycle — register → serve → hot-swap →
//!   draining unregister — works over the wire with the same bits as the
//!   in-process path, and misuse (re-PUT, swap of an absent id) gets the
//!   documented conflict codes;
//! * the auth/quota rejection taxonomy: 401 before 429 before engine
//!   admission, admin endpoints exempt from inference quota;
//! * byte-boundary independence end to end: a request torn at every
//!   byte position parses and serves identically;
//! * pipelined requests answer strictly in request order;
//! * every malformed input maps to its documented `{code, status}` pair —
//!   protocol errors from the parser, typed engine errors from the façade;
//! * `/v1/generate` streaming: chunk framing is exact, token events match
//!   the in-process API byte for byte, an early client disconnect cancels
//!   the session, and a seeded mutation fuzz over the push-parser never
//!   panics and never leaves the typed rejection table.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::http::wire;
use cloq::serve::{
    GenParams, GenRequest, HttpServer, ModelRequest, PackedLayer, PackedModel, ServeEngine,
    SessionRequest,
};
use cloq::util::json::{self, Json};
use cloq::util::prng::Rng;

const TOKEN: &str = "tok-alice";

/// The loopable 12→8→20→12 chain: the tail's output width equals the
/// head's input width, so multi-step sessions can feed y back as x.
/// Layer "d" (12→2) hangs off the chain for the generate tests: a route
/// ending in it has a 2-wide vocabulary, so greedy decode can only ever
/// sample PAD or BOS — never EOS — and deterministically runs to
/// `max_tokens`, which makes cancellation observable.
fn chain_model(seed: u64) -> PackedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for (name, m, n) in
        [("a", 12usize, 8usize), ("b", 8, 20), ("c", 20, 12), ("d", 12, 2)]
    {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let q = QuantState::Int(quantize_rtn(&w, 4, 8));
        layers.push(PackedLayer::from_state(name, &q).unwrap());
    }
    PackedModel::new(layers)
}

/// Engine + server + a bit-identical reference copy of the model.
fn boot() -> (Arc<ServeEngine>, HttpServer, PackedModel) {
    let engine = Arc::new(
        ServeEngine::builder(chain_model(40)).workers(2).max_batch(4).build().unwrap(),
    );
    let server = HttpServer::builder(Arc::clone(&engine))
        .tenant("alice", TOKEN, 8)
        .tenant("bob", "tok-bob", 0)
        .build()
        .unwrap();
    (engine, server, chain_model(40))
}

/// A raw-socket HTTP client: one keep-alive connection, an incremental
/// response reader (status + Content-Length framing, residue preserved
/// for pipelining).
struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        Client { stream: TcpStream::connect(addr).unwrap(), buf: Vec::new() }
    }

    fn send(&mut self, bytes: &[u8]) {
        self.stream.write_all(bytes).unwrap();
    }

    fn request(&mut self, method: &str, path: &str, tok: Option<&str>, body: &str) -> (u16, Json) {
        self.send(&build_request(method, path, tok, body));
        let (status, text) = self.recv();
        (status, json::parse(&text).unwrap())
    }

    /// Read until `pat` appears; drain and return everything up to and
    /// including it.
    fn take_until(&mut self, pat: &[u8]) -> Vec<u8> {
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(pos) = self.buf.windows(pat.len()).position(|w| w == pat) {
                let end = pos + pat.len();
                let out = self.buf[..end].to_vec();
                self.buf.drain(..end);
                return out;
            }
            let n = self.stream.read(&mut tmp).unwrap();
            assert!(n > 0, "server closed mid-stream");
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }

    /// Read exactly `n` bytes.
    fn take_exact(&mut self, n: usize) -> Vec<u8> {
        let mut tmp = [0u8; 4096];
        while self.buf.len() < n {
            let k = self.stream.read(&mut tmp).unwrap();
            assert!(k > 0, "server closed mid-chunk");
            self.buf.extend_from_slice(&tmp[..k]);
        }
        let out = self.buf[..n].to_vec();
        self.buf.drain(..n);
        out
    }

    /// Read one chunked-transfer response off the connection, asserting
    /// the framing byte for byte: a head that declares chunked encoding
    /// (and no Content-Length), hex-length chunk frames each terminated
    /// by CRLF, and the zero-length terminator chunk. Returns the status
    /// and the decoded chunk payloads in arrival order.
    fn recv_chunked(&mut self) -> (u16, Vec<Vec<u8>>) {
        let head = String::from_utf8(self.take_until(b"\r\n\r\n")).unwrap();
        let status: u16 = head.split(' ').nth(1).unwrap().parse().unwrap();
        let lower = head.to_ascii_lowercase();
        assert!(lower.contains("transfer-encoding: chunked"), "{head}");
        assert!(!lower.contains("content-length"), "chunked must not declare a length: {head}");
        let mut chunks = Vec::new();
        loop {
            let line = self.take_until(b"\r\n");
            let hex = std::str::from_utf8(&line[..line.len() - 2]).unwrap();
            let len = usize::from_str_radix(hex, 16).unwrap_or_else(|_| panic!("size {hex:?}"));
            let payload = self.take_exact(len + 2);
            assert_eq!(&payload[len..], b"\r\n", "chunk payload must end in CRLF");
            if len == 0 {
                return (status, chunks);
            }
            chunks.push(payload[..len].to_vec());
        }
    }

    /// Read exactly one response off the connection.
    fn recv(&mut self) -> (u16, String) {
        let mut tmp = [0u8; 4096];
        loop {
            if let Some(pos) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let head = String::from_utf8(self.buf[..pos].to_vec()).unwrap();
                let status: u16 = head.split(' ').nth(1).unwrap().parse().unwrap();
                let cl = head
                    .lines()
                    .find_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse::<usize>().unwrap())
                    })
                    .unwrap_or(0);
                let start = pos + 4;
                while self.buf.len() < start + cl {
                    let n = self.stream.read(&mut tmp).unwrap();
                    assert!(n > 0, "server closed mid-body");
                    self.buf.extend_from_slice(&tmp[..n]);
                }
                let body = String::from_utf8(self.buf[start..start + cl].to_vec()).unwrap();
                self.buf.drain(..start + cl);
                return (status, body);
            }
            let n = self.stream.read(&mut tmp).unwrap();
            assert!(n > 0, "server closed before a full response head");
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }
}

fn build_request(method: &str, path: &str, token: Option<&str>, body: &str) -> Vec<u8> {
    let mut head = format!("{method} {path} HTTP/1.1\r\n");
    if let Some(t) = token {
        head.push_str(&format!("Authorization: Bearer {t}\r\n"));
    }
    head.push_str(&format!("Content-Length: {}\r\n\r\n", body.len()));
    let mut out = head.into_bytes();
    out.extend_from_slice(body.as_bytes());
    out
}

/// One-shot request on a fresh connection.
fn call(addr: SocketAddr, method: &str, path: &str, tok: Option<&str>, body: &str) -> (u16, Json) {
    Client::connect(addr).request(method, path, tok, body)
}

/// Send raw bytes on a fresh connection, read one response.
fn raw_call(addr: SocketAddr, bytes: &[u8]) -> (u16, String) {
    let mut c = Client::connect(addr);
    c.send(bytes);
    c.recv()
}

/// `f64` Display prints the shortest string that parses back to the SAME
/// bits, so JSON round-trips are exact and 0-ULP assertions are fair.
fn nums(xs: &[f64]) -> String {
    xs.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
}

fn y_of(j: &Json) -> Vec<f64> {
    j.get("y").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap()).collect()
}

fn code_of(j: &Json) -> &str {
    j.get("code").unwrap().as_str().unwrap()
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (u, v)) in a.iter().zip(b).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}: element {k}: {u} vs {v}");
    }
}

#[test]
fn submit_forward_and_session_match_the_facade_bit_for_bit() {
    let (engine, server, reference) = boot();
    let addr = server.addr();
    let mut rng = Rng::new(41);

    // Single layer: HTTP y == PackedLayer::forward bits.
    for layer in ["a", "b", "c"] {
        let l = reference.layer(layer).unwrap();
        let x = rng.gauss_vec(l.rows);
        let body = format!("{{\"layer\":\"{layer}\",\"x\":[{}]}}", nums(&x));
        let (status, resp) = call(addr, "POST", "/v1/submit", Some(TOKEN), &body);
        assert_eq!(status, 200, "{resp:?}");
        assert_bits_eq(&y_of(&resp), &l.forward(&x, None), &format!("submit {layer}"));
        assert!(resp.get("batch_size").unwrap().as_usize().unwrap() >= 1);
    }

    // Full-model forward: HTTP y == the hand-chained reference.
    let x = rng.gauss_vec(12);
    let mut want = x.clone();
    for layer in ["a", "b", "c"] {
        want = reference.layer(layer).unwrap().forward(&want, None);
    }
    let body = format!("{{\"route\":[\"a\",\"b\",\"c\"],\"x\":[{}]}}", nums(&x));
    let (status, resp) = call(addr, "POST", "/v1/forward", Some(TOKEN), &body);
    assert_eq!(status, 200, "{resp:?}");
    assert_bits_eq(&y_of(&resp), &want, "forward a→b→c");
    assert_eq!(resp.get("hops").unwrap().as_usize().unwrap(), 3);
    assert_eq!(resp.get("forwards").unwrap().as_usize().unwrap(), 1);

    // Multi-step session: HTTP (identity-bridged) == submit_session with
    // the same identity step through the in-process façade.
    let x0 = rng.gauss_vec(12);
    let route = engine.route(&["a", "b", "c"]).unwrap();
    let direct = engine
        .submit_session(SessionRequest::new(
            route,
            x0.clone(),
            3,
            Box::new(|_, y| Some(y.to_vec())),
        ))
        .wait()
        .unwrap();
    let body =
        format!("{{\"route\":[\"a\",\"b\",\"c\"],\"x\":[{}],\"steps\":3}}", nums(&x0));
    let (status, resp) = call(addr, "POST", "/v1/session", Some(TOKEN), &body);
    assert_eq!(status, 200, "{resp:?}");
    assert_bits_eq(&y_of(&resp), &direct.y, "3-step session");
    assert_eq!(resp.get("forwards").unwrap().as_usize().unwrap(), direct.forwards);
    assert_eq!(resp.get("hops").unwrap().as_usize().unwrap(), direct.hops);

    server.shutdown();
}

#[test]
fn adapter_lifecycle_over_http_register_swap_unregister() {
    let (_engine, server, reference) = boot();
    let addr = server.addr();
    let mut rng = Rng::new(42);

    // Two adapter versions for layer "a" (12×8): factors a[12×2], b[8×2].
    let (rank, rows, cols) = (2usize, 12usize, 8usize);
    let a1: Vec<f64> = (0..rows * rank).map(|i| 0.013 * i as f64 - 0.1).collect();
    let b1: Vec<f64> = (0..cols * rank).map(|i| 0.02 - 0.009 * i as f64).collect();
    let a2: Vec<f64> = a1.iter().map(|v| v * -1.5).collect();
    let b2: Vec<f64> = b1.iter().map(|v| v + 0.05).collect();
    let body_of = |a: &[f64], b: &[f64]| {
        format!(
            "{{\"layers\":[{{\"layer\":\"a\",\"rank\":{rank},\"a\":[{}],\"b\":[{}]}}]}}",
            nums(a),
            nums(b)
        )
    };
    let pair_of = |a: &[f64], b: &[f64]| {
        LoraPair::new(
            Matrix::from_vec(rows, rank, a.to_vec()),
            Matrix::from_vec(cols, rank, b.to_vec()),
        )
    };

    // Register v1 over the wire.
    let (status, resp) = call(addr, "PUT", "/v1/adapters/t1", Some(TOKEN), &body_of(&a1, &b1));
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("replaced").unwrap().as_bool(), Some(false));

    // Serve with it: bits match the in-process forward with the same pair.
    let x = rng.gauss_vec(rows);
    let submit = format!("{{\"layer\":\"a\",\"adapter\":\"t1\",\"x\":[{}]}}", nums(&x));
    let (status, resp) = call(addr, "POST", "/v1/submit", Some(TOKEN), &submit);
    assert_eq!(status, 200, "{resp:?}");
    let want = reference.layer("a").unwrap().forward(&x, Some(&pair_of(&a1, &b1)));
    assert_bits_eq(&y_of(&resp), &want, "v1 adapter over http");

    // Re-PUT conflicts; hot-swapping an absent id 404s.
    let (status, resp) = call(addr, "PUT", "/v1/adapters/t1", Some(TOKEN), &body_of(&a1, &b1));
    assert_eq!((status, code_of(&resp)), (409, "already-registered"));
    let (status, resp) = call(addr, "POST", "/v1/adapters/nope", Some(TOKEN), &body_of(&a1, &b1));
    assert_eq!((status, code_of(&resp)), (404, "unknown-adapter"));

    // Hot-swap to v2: same id, new bits.
    let (status, resp) = call(addr, "POST", "/v1/adapters/t1", Some(TOKEN), &body_of(&a2, &b2));
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("replaced").unwrap().as_bool(), Some(true));
    let (status, resp) = call(addr, "POST", "/v1/submit", Some(TOKEN), &submit);
    assert_eq!(status, 200, "{resp:?}");
    let want = reference.layer("a").unwrap().forward(&x, Some(&pair_of(&a2, &b2)));
    assert_bits_eq(&y_of(&resp), &want, "v2 adapter after hot-swap");

    // Draining unregister, then the id is gone — typed, over the wire.
    let (status, resp) = call(addr, "DELETE", "/v1/adapters/t1", Some(TOKEN), "");
    assert_eq!(status, 200, "{resp:?}");
    let (status, resp) = call(addr, "POST", "/v1/submit", Some(TOKEN), &submit);
    assert_eq!((status, code_of(&resp)), (404, "unknown-adapter"));
    let (status, resp) = call(addr, "DELETE", "/v1/adapters/t1", Some(TOKEN), "");
    assert_eq!((status, code_of(&resp)), (404, "unknown-adapter"));

    server.shutdown();
}

#[test]
fn auth_and_quota_rejections_happen_before_the_engine() {
    let (engine, server, _reference) = boot();
    let addr = server.addr();
    let submit = "{\"layer\":\"a\",\"x\":[0,0,0,0,0,0,0,0,0,0,0,0]}";

    // No token / unknown token → 401 on every /v1/* endpoint.
    let (status, resp) = call(addr, "POST", "/v1/submit", None, submit);
    assert_eq!((status, code_of(&resp)), (401, "unauthorized"));
    let (status, resp) = call(addr, "GET", "/v1/stats", Some("tok-eve"), "");
    assert_eq!((status, code_of(&resp)), (401, "unauthorized"));

    // bob's quota is 0: inference is 429 before admission, but admin and
    // stats keep working (how else would he fix it?).
    let (status, resp) = call(addr, "POST", "/v1/submit", Some("tok-bob"), submit);
    assert_eq!((status, code_of(&resp)), (429, "quota-exceeded"));
    let (status, _) = call(addr, "GET", "/v1/stats", Some("tok-bob"), "");
    assert_eq!(status, 200);
    let (status, _) = call(addr, "DELETE", "/v1/adapters/absent", Some("tok-bob"), "");
    assert_eq!(status, 404, "admin is quota-exempt (typed 404, not 429)");

    // The 429 never reached the engine: no request, no rejection counted.
    assert_eq!(engine.stats().requests, 0);
    assert_eq!(engine.stats().rejected, 0);

    // alice's quota releases on completion: sequential submits all pass.
    for _ in 0..3 {
        let (status, _) = call(addr, "POST", "/v1/submit", Some(TOKEN), submit);
        assert_eq!(status, 200);
    }

    // The taxonomy is observable on the scrape endpoint.
    let (status, text) = raw_call(addr, b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 200);
    assert!(text.contains("cloq_http_auth_rejects_total 2"), "auth rejects missing:\n{text}");
    assert!(text.contains("cloq_http_quota_rejects_total 1"), "quota rejects missing:\n{text}");

    server.shutdown();
}

#[test]
fn pipelined_requests_answer_in_request_order() {
    let (_engine, server, reference) = boot();
    let addr = server.addr();
    let mut rng = Rng::new(43);
    let l = reference.layer("a").unwrap();
    let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.gauss_vec(l.rows)).collect();

    // All four requests in one write; the engine may complete them in any
    // order, the rail must answer them in request order.
    let mut burst = Vec::new();
    for x in &xs {
        let body = format!("{{\"layer\":\"a\",\"x\":[{}]}}", nums(x));
        burst.extend_from_slice(&build_request("POST", "/v1/submit", Some(TOKEN), &body));
    }
    let mut c = Client::connect(addr);
    c.send(&burst);
    for (k, x) in xs.iter().enumerate() {
        let (status, text) = c.recv();
        assert_eq!(status, 200, "pipelined response {k}");
        let resp = json::parse(&text).unwrap();
        assert_bits_eq(&y_of(&resp), &l.forward(x, None), &format!("pipelined {k}"));
    }

    server.shutdown();
}

#[test]
fn requests_torn_at_every_byte_boundary_serve_identically() {
    let (_engine, server, reference) = boot();
    let addr = server.addr();
    let mut rng = Rng::new(44);
    let l = reference.layer("b").unwrap();
    let x = rng.gauss_vec(l.rows);
    let body = format!("{{\"layer\":\"b\",\"x\":[{}]}}", nums(&x));
    let raw = build_request("POST", "/v1/submit", Some(TOKEN), &body);
    let want = l.forward(&x, None);

    // One keep-alive connection; each round tears the same request at a
    // different byte position, with a pause so the server's read loop
    // really sees two fragments.
    let mut c = Client::connect(addr);
    let step = (raw.len() / 41).max(1); // ~41 cut points incl. both edges
    let mut cuts: Vec<usize> = (0..=raw.len()).step_by(step).collect();
    if cuts.last() != Some(&raw.len()) {
        cuts.push(raw.len());
    }
    for cut in cuts {
        c.send(&raw[..cut]);
        std::thread::sleep(Duration::from_millis(2));
        c.send(&raw[cut..]);
        let (status, text) = c.recv();
        assert_eq!(status, 200, "cut={cut}");
        let resp = json::parse(&text).unwrap();
        assert_bits_eq(&y_of(&resp), &want, &format!("torn at {cut}"));
    }

    server.shutdown();
}

#[test]
fn malformed_inputs_map_to_the_documented_code_status_pairs() {
    let (_engine, server, _reference) = boot();
    let addr = server.addr();

    // Parser-level protocol errors (connection closes after each).
    let (status, text) = raw_call(addr, b"NOT A VALID REQUEST\r\n\r\n");
    assert_eq!(status, 400);
    assert!(text.contains("bad-request-line"), "{text}");
    let (status, text) = raw_call(addr, b"GET /metrics HTTP/2.0\r\n\r\n");
    assert_eq!(status, 505);
    assert!(text.contains("bad-version"), "{text}");
    let (status, text) =
        raw_call(addr, b"POST /v1/submit HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n");
    assert_eq!(status, 501);
    assert!(text.contains("unsupported-encoding"), "{text}");
    let (status, text) =
        raw_call(addr, b"POST /v1/submit HTTP/1.1\r\nContent-Length: 999999999\r\n\r\n");
    assert_eq!(status, 413, "refused from the declared length alone");
    assert!(text.contains("body-too-large"), "{text}");
    let mut giant = b"GET /metrics HTTP/1.1\r\n".to_vec();
    for i in 0..70 {
        giant.extend_from_slice(format!("X-Filler-{i}: v\r\n").as_bytes());
    }
    giant.extend_from_slice(b"\r\n");
    let (status, text) = raw_call(addr, &giant);
    assert_eq!(status, 431);
    assert!(text.contains("too-many-headers"), "{text}");

    // Routing and body errors (front-end level).
    let (status, resp) = call(addr, "GET", "/v1/nope", Some(TOKEN), "");
    assert_eq!((status, code_of(&resp)), (404, "no-such-endpoint"));
    let (status, resp) = call(addr, "DELETE", "/v1/submit", Some(TOKEN), "");
    assert_eq!((status, code_of(&resp)), (405, "method-not-allowed"));
    let (status, resp) = call(addr, "PUT", "/metrics", None, "");
    assert_eq!((status, code_of(&resp)), (405, "method-not-allowed"));
    let (status, resp) = call(addr, "POST", "/v1/submit", Some(TOKEN), "{\"layer\":");
    assert_eq!((status, code_of(&resp)), (400, "bad-json"));
    let (status, resp) = call(addr, "POST", "/v1/submit", Some(TOKEN), "{\"layer\":\"a\"}");
    assert_eq!((status, code_of(&resp)), (400, "missing-field"));
    let (status, resp) =
        call(addr, "POST", "/v1/submit", Some(TOKEN), "{\"layer\":\"a\",\"x\":[1,\"two\"]}");
    assert_eq!((status, code_of(&resp)), (400, "bad-json"));

    // Typed engine errors surface with their locked wire mapping.
    let (status, resp) =
        call(addr, "POST", "/v1/submit", Some(TOKEN), "{\"layer\":\"zz\",\"x\":[1]}");
    assert_eq!((status, code_of(&resp)), (404, "unknown-layer"));
    let (status, resp) =
        call(addr, "POST", "/v1/submit", Some(TOKEN), "{\"layer\":\"a\",\"x\":[1,2,3]}");
    assert_eq!((status, code_of(&resp)), (400, "shape-mismatch"));
    let non_loop = "{\"route\":[\"a\",\"b\"],\"x\":[0,0,0,0,0,0,0,0,0,0,0,0],\"steps\":2}";
    let (status, resp) = call(addr, "POST", "/v1/session", Some(TOKEN), non_loop);
    assert_eq!((status, code_of(&resp)), (400, "invalid-config"));

    server.shutdown();
}

#[test]
fn stats_and_metrics_expose_the_served_traffic() {
    let (engine, server, _reference) = boot();
    let addr = server.addr();
    let submit = "{\"layer\":\"a\",\"x\":[0,0,0,0,0,0,0,0,0,0,0,0]}";
    for _ in 0..5 {
        let (status, _) = call(addr, "POST", "/v1/submit", Some(TOKEN), submit);
        assert_eq!(status, 200);
    }

    // /v1/stats mirrors EngineStats through the wire.
    let (status, stats) = call(addr, "GET", "/v1/stats", Some(TOKEN), "");
    assert_eq!(status, 200);
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), 5);
    assert_eq!(stats.get("requests").unwrap().as_usize().unwrap(), engine.stats().requests);
    assert_eq!(stats.get("failed").unwrap().as_usize().unwrap(), 0);

    // /metrics is the unauthenticated Prometheus surface, HTTP counters
    // included.
    let (status, text) = raw_call(addr, b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 200);
    for needle in [
        "cloq_uptime_seconds",
        "cloq_requests_total 5",
        "cloq_http_connections_total",
        "cloq_http_requests_2xx_total",
    ] {
        assert!(text.contains(needle), "missing {needle:?} in /metrics:\n{text}");
    }

    server.shutdown();

    // Ticket plumbing note: requests admitted via HTTP resolve through
    // the same completion cells as the direct façade.
    let direct = engine.submit_named("a", None, vec![0.0; 12]).wait().unwrap();
    assert_eq!(direct.y.len(), 8);
    let route = engine.route(&["a", "b", "c"]).unwrap();
    let direct = engine.submit_model(ModelRequest::new(route, vec![0.0; 12])).wait().unwrap();
    assert_eq!(direct.y.len(), 12);
}

#[test]
fn generate_endpoint_matches_the_in_process_api_and_rejects_typed() {
    let (engine, server, _reference) = boot();
    let addr = server.addr();

    // The in-process reference run. Decode is deterministic — a separate
    // session with the same prompt and params must produce the same
    // tokens and text no matter how the batcher interleaves it.
    let route = engine.route(&["a", "b", "c"]).unwrap();
    let want =
        engine.generate(GenRequest::new(route, "Q: 2+2?", GenParams::greedy(5))).wait().unwrap();

    let body = "{\"route\":[\"a\",\"b\",\"c\"],\"prompt\":\"Q: 2+2?\",\"max_tokens\":5}";
    let (status, resp) = call(addr, "POST", "/v1/generate", Some(TOKEN), body);
    assert_eq!(status, 200, "{resp:?}");
    assert_eq!(resp.get("text").unwrap().as_str().unwrap(), want.text);
    let got: Vec<i32> = resp
        .get("tokens")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|t| t.as_f64().unwrap() as i32)
        .collect();
    assert_eq!(got, want.tokens);
    assert_eq!(resp.get("finish").unwrap().as_str().unwrap(), want.finish.as_str());
    assert_eq!(resp.get("prompt_tokens").unwrap().as_usize().unwrap(), want.prompt_tokens);
    assert_eq!(resp.get("forwards").unwrap().as_usize().unwrap(), want.forwards);

    // Typed rejections ride the same {code, status} taxonomy as every
    // other endpoint — including streamed requests, whose route errors
    // resolve before any response byte is committed.
    let (status, resp) =
        call(addr, "POST", "/v1/generate", Some(TOKEN), "{\"route\":[\"a\"],\"prompt\":\"q\"}");
    assert_eq!((status, code_of(&resp)), (400, "missing-field"));
    let (status, resp) = call(
        addr,
        "POST",
        "/v1/generate",
        Some(TOKEN),
        "{\"route\":[\"zz\"],\"prompt\":\"q\",\"max_tokens\":3,\"stream\":true}",
    );
    assert_eq!((status, code_of(&resp)), (404, "unknown-layer"));
    let (status, resp) = call(
        addr,
        "POST",
        "/v1/generate",
        Some("tok-bob"),
        "{\"route\":[\"a\",\"b\",\"c\"],\"prompt\":\"q\",\"max_tokens\":3}",
    );
    assert_eq!((status, code_of(&resp)), (429, "quota-exceeded"));

    // The runs above landed in the generation telemetry.
    let (status, text) = raw_call(addr, b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n");
    assert_eq!(status, 200);
    for needle in
        ["cloq_gen_sessions_total", "cloq_gen_tokens_total", "cloq_gen_ttft_seconds_count"]
    {
        assert!(text.contains(needle), "missing {needle:?} in /metrics:\n{text}");
    }

    server.shutdown();
}

#[test]
fn generate_streams_chunked_and_matches_the_in_process_api() {
    let (engine, server, _reference) = boot();
    let addr = server.addr();

    let route = engine.route(&["a", "b", "c"]).unwrap();
    let want =
        engine.generate(GenRequest::new(route, "Q: stream?", GenParams::greedy(6))).wait().unwrap();

    let body =
        "{\"route\":[\"a\",\"b\",\"c\"],\"prompt\":\"Q: stream?\",\"max_tokens\":6,\"stream\":true}";
    let mut c = Client::connect(addr);
    c.send(&build_request("POST", "/v1/generate", Some(TOKEN), body));
    let (status, chunks) = c.recv_chunked();
    assert_eq!(status, 200);
    assert!(chunks.len() >= 2, "at least one token event plus the done summary");

    // Every chunk is exactly one NDJSON line: token events in emission
    // order, then the done summary as the final chunk.
    let mut tokens: Vec<i32> = Vec::new();
    let mut text = String::new();
    let mut done: Option<Json> = None;
    for (k, chunk) in chunks.iter().enumerate() {
        assert_eq!(chunk.last(), Some(&b'\n'), "chunk {k} is not a line");
        let ev = json::parse(std::str::from_utf8(chunk).unwrap()).unwrap();
        if ev.get("done").and_then(Json::as_bool) == Some(true) {
            assert_eq!(k, chunks.len() - 1, "done must be the final chunk");
            done = Some(ev);
        } else {
            assert!(done.is_none(), "token event after done");
            assert_eq!(ev.get("index").unwrap().as_usize().unwrap(), tokens.len());
            tokens.push(ev.get("token").unwrap().as_f64().unwrap() as i32);
            text.push_str(ev.get("piece").unwrap().as_str().unwrap());
        }
    }
    let done = done.expect("stream never emitted the done summary");

    // Byte-exact parity with the in-process API: the streamed pieces
    // concatenate to the final text, and both match the reference run.
    assert_eq!(tokens, want.tokens);
    assert_eq!(text, want.text, "concatenated pieces != final text");
    assert_eq!(done.get("text").unwrap().as_str().unwrap(), want.text);
    assert_eq!(done.get("finish").unwrap().as_str().unwrap(), want.finish.as_str());
    assert_eq!(done.get("prompt_tokens").unwrap().as_usize().unwrap(), want.prompt_tokens);

    server.shutdown();
}

#[test]
fn early_client_disconnect_cancels_the_generation_session() {
    let (engine, server, _reference) = boot();
    let addr = server.addr();

    // A route ending in the 2-wide tail "d": greedy can only ever sample
    // PAD or BOS — never EOS, never a stop string — so an uncancelled
    // run would do exactly max_tokens+1 session forwards. Anything far
    // below that proves the disconnect propagated into a cancel.
    const MAX: usize = 10_000;
    let body = format!(
        "{{\"route\":[\"a\",\"b\",\"c\",\"d\"],\"prompt\":\"go\",\"max_tokens\":{MAX},\"stream\":true}}"
    );
    let mut c = Client::connect(addr);
    c.send(&build_request("POST", "/v1/generate", Some(TOKEN), &body));

    // Read the head and the first frame, then vanish mid-stream.
    let _ = c.take_until(b"\r\n\r\n");
    let _ = c.take_until(b"\n");
    drop(c);

    // The writer hits the dead socket, fires the cancel hook, and the
    // session resolves. Poll until forwards quiesce (300ms stable).
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut last = engine.stats().session_forwards;
    let mut stable = 0;
    while stable < 6 {
        assert!(std::time::Instant::now() < deadline, "generation never quiesced");
        std::thread::sleep(Duration::from_millis(50));
        let now = engine.stats().session_forwards;
        if now == last {
            stable += 1;
        } else {
            stable = 0;
            last = now;
        }
    }
    assert!(last >= 1, "the session never ran a forward");
    assert!(
        last < MAX / 2,
        "disconnect did not cancel the session: {last} forwards of {}",
        MAX + 1
    );

    server.shutdown();
}

/// Satellite: seeded mutation fuzzer for the HTTP push-parser. Valid
/// requests are mutated — truncated, duplicated, bit-flipped, spliced,
/// stuffed with random bytes — and fed to a fresh `RequestParser` in
/// random fragment sizes. The parser must never panic, and every
/// rejection must land in the typed `{code, status}` table the wire
/// module documents. Deterministic: seeded PRNG, no time, no I/O.
#[test]
fn push_parser_fuzzer_never_panics_and_rejections_stay_typed() {
    const CASES: usize = 10_000;
    const TABLE: &[(&str, u16)] = &[
        ("bad-request-line", 400),
        ("bad-version", 505),
        ("bad-header", 400),
        ("too-many-headers", 431),
        ("headers-too-large", 431),
        ("bad-content-length", 400),
        ("body-too-large", 413),
        ("unsupported-encoding", 501),
    ];
    let corpus: Vec<Vec<u8>> = vec![
        build_request("POST", "/v1/submit", Some(TOKEN), "{\"layer\":\"a\",\"x\":[1,2]}"),
        build_request("GET", "/v1/stats", Some(TOKEN), ""),
        build_request(
            "POST",
            "/v1/generate",
            Some(TOKEN),
            "{\"route\":[\"a\"],\"prompt\":\"q\",\"max_tokens\":2,\"stream\":true}",
        ),
        build_request("PUT", "/v1/adapters/t1", Some(TOKEN), "{\"layers\":[]}"),
        build_request("DELETE", "/v1/adapters/t1", None, ""),
        b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n".to_vec(),
        {
            // A pipelined pair: mutations can straddle the boundary.
            let mut two = build_request("GET", "/v1/stats", Some(TOKEN), "");
            two.extend_from_slice(&build_request("POST", "/v1/submit", Some(TOKEN), "{}"));
            two
        },
    ];

    let mut r = Rng::new(0xf0_22);
    for case in 0..CASES {
        let mut bytes = r.choose(&corpus).clone();
        for _ in 0..1 + r.below(3) {
            match r.below(5) {
                0 => {
                    // Truncate.
                    if !bytes.is_empty() {
                        bytes.truncate(r.below(bytes.len()));
                    }
                }
                1 => {
                    // Duplicate a slice at a random position.
                    if !bytes.is_empty() {
                        let s = r.below(bytes.len());
                        let e = s + r.below(bytes.len() - s + 1);
                        let slice = bytes[s..e].to_vec();
                        let at = r.below(bytes.len() + 1);
                        bytes.splice(at..at, slice);
                    }
                }
                2 => {
                    // Flip one bit.
                    if !bytes.is_empty() {
                        let i = r.below(bytes.len());
                        bytes[i] ^= 1u8 << r.below(8);
                    }
                }
                3 => {
                    // Splice: our head, another request's tail.
                    let other = r.choose(&corpus).clone();
                    let cut_a = r.below(bytes.len() + 1);
                    let cut_b = r.below(other.len() + 1);
                    bytes.truncate(cut_a);
                    bytes.extend_from_slice(&other[cut_b..]);
                }
                _ => {
                    // Insert 1–8 random bytes.
                    let at = r.below(bytes.len() + 1);
                    let extra: Vec<u8> =
                        (0..1 + r.below(8)).map(|_| r.below(256) as u8).collect();
                    bytes.splice(at..at, extra);
                }
            }
        }

        // Feed in random fragment sizes and pump to a verdict. A parse
        // error poisons the connection, so feeding stops there — exactly
        // what the serving loop does.
        let mut p = wire::RequestParser::new(4096);
        let mut pos = 0;
        let verdict = 'feed: loop {
            if pos >= bytes.len() {
                break None; // incomplete input: the parser just wants more
            }
            let step = (1 + r.below(97)).min(bytes.len() - pos);
            p.feed(&bytes[pos..pos + step]);
            pos += step;
            loop {
                match p.next() {
                    Ok(Some(_)) => continue, // a full request; keep pumping
                    Ok(None) => break,
                    Err(e) => break 'feed Some(e),
                }
            }
        };
        if let Some(e) = verdict {
            let pair = (e.code(), e.status());
            assert!(
                TABLE.contains(&pair),
                "case {case}: rejection {pair:?} is outside the typed table\ninput: {bytes:?}"
            );
        }
    }
}

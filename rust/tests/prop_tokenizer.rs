//! Property tests for the byte-level tokenizer (`data::tokenizer`) — the
//! seam every generation request now crosses twice (prompt in, pieces
//! out). Seeded, deterministic, and exhaustive where the domain is small
//! enough to enumerate (single tokens: all of them).

use cloq::data::tokenizer::{
    decode, decode_token, encode, encode_example, ANSWER_DELIM, BOS, BYTE_OFFSET, EOS, PAD, SEP,
    VOCAB,
};
use cloq::util::prng::Rng;

/// How many random cases each property runs (the suite stays < 1s).
const CASES: usize = 2_000;

/// Random valid UTF-8 string mixing ASCII, multi-byte chars, and
/// whitespace; length 0..=40 chars.
fn rand_text(r: &mut Rng) -> String {
    let alphabet: Vec<char> = "abcXYZ019 +=?\n\té漢🎲µ∑".chars().collect();
    let len = r.below(41);
    (0..len).map(|_| *r.choose(&alphabet)).collect()
}

#[test]
fn encode_decode_roundtrips_any_utf8_text() {
    let mut r = Rng::new(0x70c0);
    for _ in 0..CASES {
        let s = rand_text(&mut r);
        let toks = encode(&s);
        // Byte-level: one token per byte, all inside the byte range.
        assert_eq!(toks.len(), s.len());
        assert!(toks.iter().all(|&t| (BYTE_OFFSET..VOCAB as i32).contains(&t)), "{s:?}");
        assert_eq!(decode(&toks), s, "roundtrip failed for {s:?}");
    }
}

#[test]
fn decode_drops_specials_and_out_of_range_ids_only() {
    let mut r = Rng::new(42);
    for _ in 0..CASES {
        let s = rand_text(&mut r);
        let clean = encode(&s);
        // Splice specials and out-of-range ids at random positions: the
        // decoded text must be unchanged — they carry no bytes.
        let mut noisy = Vec::with_capacity(clean.len() * 2);
        for &t in &clean {
            if r.chance(0.3) {
                noisy.push(*r.choose(&[PAD, BOS, EOS, SEP, VOCAB as i32, -1, 1_000]));
            }
            noisy.push(t);
        }
        assert_eq!(decode(&noisy), s, "specials must decode to nothing in {s:?}");
    }
}

#[test]
fn single_token_decode_is_consistent_with_full_decode() {
    // Small domain: check EVERY id a generation could ever emit, plus
    // out-of-range strays.
    for t in -2..(VOCAB as i32 + 2) {
        assert_eq!(decode_token(t), decode(&[t]), "id {t}");
    }
    // Specials and strays are empty pieces; ASCII bytes are themselves.
    assert_eq!(decode_token(EOS), "");
    assert_eq!(decode_token(VOCAB as i32), "");
    assert_eq!(decode_token('A' as i32 + BYTE_OFFSET), "A");
    // A byte inside a multi-byte character is lossy on its own, but the
    // byte-sequence decode of the full pair recovers the character —
    // the invariant the streaming piece contract documents.
    let toks = encode("é");
    assert_eq!(toks.len(), 2);
    assert_eq!(decode_token(toks[0]), "\u{FFFD}");
    assert_eq!(decode(&toks), "é");
}

#[test]
fn empty_text_is_empty_everywhere() {
    assert_eq!(encode(""), Vec::<i32>::new());
    assert_eq!(decode(&[]), "");
    let (toks, astart) = encode_example("", "");
    // Even an empty example keeps the BOS/delimiter/EOS scaffold.
    assert_eq!(toks.len(), 2 + ANSWER_DELIM.len());
    assert_eq!(astart, 1 + ANSWER_DELIM.len());
}

#[test]
fn encode_example_boundary_invariants_hold_for_random_pairs() {
    let mut r = Rng::new(7);
    for _ in 0..CASES {
        let prompt = rand_text(&mut r);
        let answer = rand_text(&mut r);
        let (toks, astart) = encode_example(&prompt, &answer);
        assert_eq!(toks[0], BOS);
        assert_eq!(*toks.last().unwrap(), EOS);
        // answer_start points at the first answer token: everything
        // before it is prompt + delimiter, everything after (minus the
        // EOS) is exactly the answer.
        assert!(astart >= 1 && astart < toks.len(), "astart {astart} of {}", toks.len());
        assert_eq!(decode(&toks[..astart]), format!("{prompt}{ANSWER_DELIM}"));
        assert_eq!(decode(&toks[astart..toks.len() - 1]), answer);
        // Total length is fully determined by the byte lengths.
        assert_eq!(toks.len(), 2 + prompt.len() + ANSWER_DELIM.len() + answer.len());
        // No specials leak out of the scaffold positions.
        assert!(toks[1..toks.len() - 1].iter().all(|&t| t >= BYTE_OFFSET));
    }
}

//! Random-sweep property tests for Theorem 3.1 (CLoQ's closed form) and
//! the LoftQ baseline — the paper's core mathematical claims, hammered
//! across random layer shapes, activation ranks, and bit-widths.

use cloq::linalg::{matmul, matmul_nt, syrk_t, Matrix};
use cloq::lowrank::{
    cloq_lowrank, damping_lambda, gram_root, init_layer, loftq, CloqConfig, FactorSplit,
    InitConfig, LoftqConfig, LoftqQuantizer, Method,
};
use cloq::quant::metrics::calibrated_error2;
use cloq::util::prng::Rng;

fn sweep(cases: usize, f: impl Fn(u64, &mut Rng)) {
    for seed in 0..cases as u64 {
        let mut rng = Rng::new(0x10AD ^ seed.wrapping_mul(0xD129_0129_9AB9_71FF));
        f(seed, &mut rng);
    }
}

/// Random problem: anisotropic activations + residual-scale ΔW.
fn problem(rng: &mut Rng) -> (Matrix, Matrix, Matrix) {
    let m = rng.range(3, 28) as usize;
    let n = rng.range(2, 20) as usize;
    let eff_rank = rng.range(1, m as i64) as usize;
    let samples = m * 3 + rng.range(0, 40) as usize;
    let base = Matrix::randn(samples, eff_rank, 1.0, rng);
    let mix = Matrix::randn(eff_rank, m, 1.0, rng);
    let x = matmul(&base, &mix);
    let dw = Matrix::randn(m, n, 0.3, rng);
    let mut h = syrk_t(&x);
    h.add_diag(damping_lambda(&h, 0.01).max(1e-9));
    (x, dw, h)
}

#[test]
fn theorem_3_1_optimality_sweep() {
    // The central claim: the closed form dominates (a) plain SVD of ΔW,
    // (b) random rank-r candidates, (c) perturbations of itself.
    sweep(40, |seed, rng| {
        let (_, dw, h) = problem(rng);
        let rmax = dw.rows.min(dw.cols);
        let r = rng.range(1, rmax as i64) as usize;
        let init = cloq_lowrank(&h, &dw, &CloqConfig { rank: r, ..Default::default() });
        let e_opt = calibrated_error2(&h, &init.ab_t().sub(&dw));

        let plain = cloq::linalg::best_rank_r(&dw, r);
        let e_plain = calibrated_error2(&h, &plain.sub(&dw));
        assert!(e_opt <= e_plain + 1e-7 * e_plain.max(1.0), "vs-plain seed={seed} r={r}");

        for _ in 0..8 {
            let p = Matrix::randn(dw.rows, r, 0.5, rng);
            let q = Matrix::randn(dw.cols, r, 0.5, rng);
            let e = calibrated_error2(&h, &matmul_nt(&p, &q).sub(&dw));
            assert!(e_opt <= e + 1e-7 * e.max(1.0), "vs-random seed={seed}");
        }
        for _ in 0..8 {
            let da = Matrix::randn(dw.rows, r, 0.02, rng);
            let db = Matrix::randn(dw.cols, r, 0.02, rng);
            let cand = matmul_nt(&init.a.add(&da), &init.b.add(&db));
            let e = calibrated_error2(&h, &cand.sub(&dw));
            assert!(e_opt <= e + 1e-7 * e.max(1.0), "vs-perturb seed={seed}");
        }
    });
}

#[test]
fn reported_objective_is_exact() {
    sweep(40, |seed, rng| {
        let (_, dw, h) = problem(rng);
        let r = rng.range(0, dw.rows.min(dw.cols) as i64) as usize;
        let init = cloq_lowrank(&h, &dw, &CloqConfig { rank: r, ..Default::default() });
        let direct = calibrated_error2(&h, &init.ab_t().sub(&dw));
        assert!(
            (direct - init.objective).abs() < 1e-6 * init.objective.max(1e-9),
            "seed={seed} r={r}: {direct} vs {}",
            init.objective
        );
    });
}

#[test]
fn factor_splits_agree_on_product() {
    sweep(30, |seed, rng| {
        let (_, dw, h) = problem(rng);
        let r = rng.range(1, dw.rows.min(dw.cols) as i64) as usize;
        let prods: Vec<Matrix> = [FactorSplit::AllInA, FactorSplit::Sqrt, FactorSplit::AllInB]
            .iter()
            .map(|&split| {
                let cfg = CloqConfig { rank: r, split, rcond: 1e-12, randomized: false };
                cloq_lowrank(&h, &dw, &cfg).ab_t()
            })
            .collect();
        let scale = prods[0].max_abs().max(1e-9);
        assert!(prods[0].max_diff(&prods[1]) < 1e-6 * scale, "A-vs-sqrt seed={seed}");
        assert!(prods[0].max_diff(&prods[2]) < 1e-6 * scale, "A-vs-B seed={seed}");
    });
}

#[test]
fn gram_root_squares_back() {
    sweep(40, |seed, rng| {
        let (_, _, h) = problem(rng);
        let root = gram_root(&h, 1e-12);
        let rtr = matmul(&root.r.transpose(), &root.r);
        assert!(rtr.max_diff(&h) < 1e-6 * h.max_abs(), "seed={seed}");
    });
}

#[test]
fn loftq_objective_never_increases_with_best_iterate() {
    sweep(25, |seed, rng| {
        let m = rng.range(6, 32) as usize;
        let n = rng.range(4, 16) as usize;
        let w = Matrix::randn(m, n, 0.5, rng);
        let bits = [2u32, 4][rng.below(2)];
        let r = rng.range(1, m.min(n) as i64) as usize;
        let cfg =
            LoftqConfig { bits, group_size: m, rank: r, iters: 6, quantizer: LoftqQuantizer::Int };
        let init = loftq(&w, &cfg);
        // Returned objective == min over the trace.
        let returned = cloq::linalg::norms::fro2(&init.q_deq.add(&init.ab_t()).sub(&w));
        let min_trace = init.objective_trace.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(
            (returned - min_trace).abs() < 1e-6 * min_trace.max(1e-12),
            "seed={seed}"
        );
        // And ≤ the first iterate (pure quantization + SVD).
        assert!(returned <= init.objective_trace[0] + 1e-9, "seed={seed}");
    });
}

#[test]
fn cloq_init_discrepancy_dominates_baselines_sweep() {
    // Fig. 2's ordering across random layers: CLoQ ≤ GPTQ-LoRA (same base)
    // and typically ≤ LoftQ at 2-bit.
    let mut loftq_wins = 0usize;
    let cases = 20;
    for seed in 0..cases as u64 {
        let mut rng = Rng::new(0xF16 ^ seed.wrapping_mul(0x9E37_79B9));
        let m = rng.range(12, 32) as usize;
        let n = rng.range(8, 24) as usize;
        let base = Matrix::randn(m * 4, (m / 2).max(2), 1.0, &mut rng);
        let mix = Matrix::randn((m / 2).max(2), m, 1.0, &mut rng);
        let x = matmul(&base, &mix);
        let w = Matrix::randn(m, n, 0.4, &mut rng);
        let h = syrk_t(&x);
        let r = (m.min(n) / 3).max(1);

        let disc = |method: Method, rng: &mut Rng| {
            let mut cfg = InitConfig::new(method, 2, r);
            cfg.group_size = m;
            let li = init_layer(&w, Some(&h), &cfg, rng);
            calibrated_error2(&h, &li.q_deq.add(&matmul_nt(&li.a, &li.b)).sub(&w))
        };
        let e_cloq = disc(Method::CLoQ, &mut rng);
        let e_gptq = disc(Method::GptqLora, &mut rng);
        let e_loftq = disc(Method::LoftQ, &mut rng);
        assert!(e_cloq <= e_gptq * 1.001, "seed={seed}: cloq {e_cloq} vs gptq {e_gptq}");
        if e_loftq < e_cloq {
            loftq_wins += 1;
        }
    }
    // LoftQ may win occasionally on near-isotropic draws; it must not win
    // systematically.
    assert!(loftq_wins <= cases / 4, "LoftQ won {loftq_wins}/{cases}");
}

#[test]
fn rank_deficient_h_never_panics_and_stays_finite() {
    sweep(30, |seed, rng| {
        let m = rng.range(4, 24) as usize;
        let n = rng.range(2, 12) as usize;
        let samples = rng.range(1, m as i64) as usize; // strictly deficient
        let x = Matrix::randn(samples, m, 1.0, rng);
        let h = syrk_t(&x); // NOT damped
        let dw = Matrix::randn(m, n, 0.3, rng);
        let r = rng.range(1, n as i64) as usize;
        let init =
            cloq_lowrank(&h, &dw, &CloqConfig { rank: r, rcond: 1e-10, ..Default::default() });
        assert!(init.a.max_abs().is_finite(), "seed={seed}");
        assert!(init.b.max_abs().is_finite(), "seed={seed}");
    });
}

//! Adapter lifecycle under load: hot-swap atomicity, pinned-LRU eviction,
//! unregister drains, and the ship-an-adapter-without-the-base flow — all
//! through the typed façade (interned `AdapterId`s, builder config, the
//! unified `ArtifactStore`).
//!
//! The contracts under test (see `serve::adapters` module docs):
//!
//! * a response is computed entirely with the adapter VERSION resolved at
//!   admission — a hot-swap never mixes old and new weights in one
//!   response (and never invalidates the interned id);
//! * LRU eviction never evicts an adapter with queued (pinned) requests;
//! * `unregister_adapter` blocks until every pinned request is answered
//!   and rejects new submissions immediately, as a typed
//!   `ServeError::UnknownAdapter`;
//! * a base artifact plus a separately-shipped adapter artifact serve
//!   bit-identically to the in-memory halves.

use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    AdapterSet, ArtifactStore, PackedLayer, PackedModel, Request, ServeEngine, ServeError,
};
use cloq::util::prng::Rng;

fn base_model(m: usize, n: usize, seed: u64) -> PackedModel {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(m, n, 0.3, &mut rng);
    let q = QuantState::Int(quantize_rtn(&w, 4, 16));
    PackedModel::new(vec![PackedLayer::from_state("lin", &q).unwrap()])
}

fn adapter(id: &str, m: usize, n: usize, r: usize, seed: u64) -> AdapterSet {
    let mut rng = Rng::new(seed);
    let pair =
        LoraPair::new(Matrix::randn(m, r, 0.1, &mut rng), Matrix::randn(n, r, 0.1, &mut rng));
    AdapterSet::from_pairs(id, vec![("lin".to_string(), pair)]).unwrap()
}

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (u, v)) in a.iter().zip(b).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}: element {k}: {u} vs {v}");
    }
}

#[test]
fn hot_swap_never_mixes_versions_within_a_response() {
    let (m, n) = (32usize, 12usize);
    let model = base_model(m, n, 700);
    let v1 = adapter("t", m, n, 3, 701);
    let v2 = adapter("t", m, n, 3, 702);
    let v1_pair = v1.get("lin").unwrap().clone();
    let v2_pair = v2.get("lin").unwrap().clone();
    let reference = base_model(m, n, 700); // same seed → same base bits

    let engine = ServeEngine::builder(model).workers(2).max_batch(8).build().unwrap();
    let lin = engine.layer("lin").unwrap();
    let t_id = engine.register_adapter(v1).unwrap().id;
    let mut rng = Rng::new(703);
    let xs1: Vec<Vec<f64>> = (0..16).map(|_| rng.gauss_vec(m)).collect();
    let t1 = engine
        .submit_all(xs1.iter().map(|x| Request::with_adapter(lin, t_id, x.clone())).collect());
    // Swap while the first burst is queued/in flight — the interned id
    // survives (slots are stable), only the version behind it changes.
    let swap = engine.register_adapter(v2).unwrap();
    assert!(swap.replaced);
    assert_eq!(swap.id, t_id);
    let xs2: Vec<Vec<f64>> = (0..16).map(|_| rng.gauss_vec(m)).collect();
    let t2 = engine
        .submit_all(xs2.iter().map(|x| Request::with_adapter(lin, t_id, x.clone())).collect());

    // Admission-time version pinning makes the split deterministic: every
    // pre-swap request serves v1 bits, every post-swap request v2 bits —
    // and in particular no response can blend the two.
    let lin_ref = reference.layer("lin").unwrap();
    for (k, (t, x)) in t1.into_iter().zip(&xs1).enumerate() {
        let y = t.wait().unwrap().y;
        assert_bits_eq(&y, &lin_ref.forward(x, Some(&v1_pair)), &format!("pre-swap {k}"));
    }
    for (k, (t, x)) in t2.into_iter().zip(&xs2).enumerate() {
        let y = t.wait().unwrap().y;
        assert_bits_eq(&y, &lin_ref.forward(x, Some(&v2_pair)), &format!("post-swap {k}"));
    }
    engine.shutdown();
}

#[test]
fn eviction_never_evicts_an_adapter_with_queued_requests() {
    // One slow worker and a deep queue of requests pinned to "hot"; the
    // byte budget only fits two adapters, so registering three more MUST
    // evict — but never "hot" while its requests are queued.
    let (m, n) = (192usize, 192usize);
    let model = base_model(m, n, 710);
    let reference = base_model(m, n, 710);
    let hot = adapter("hot", m, n, 4, 711);
    let hot_pair = hot.get("lin").unwrap().clone();
    let budget = 2 * hot.bytes();
    let engine = ServeEngine::builder(model)
        .workers(1)
        .max_batch(2)
        .max_pending(8192)
        .adapter_budget(budget)
        .build()
        .unwrap();
    let lin = engine.layer("lin").unwrap();
    let hot_id = engine.register_adapter(hot).unwrap().id;
    let mut rng = Rng::new(712);
    let xs: Vec<Vec<f64>> = (0..256).map(|_| rng.gauss_vec(m)).collect();
    let tickets = engine
        .submit_all(xs.iter().map(|x| Request::with_adapter(lin, hot_id, x.clone())).collect());
    // While the single worker grinds through 128 micro-batches, pile on
    // cold adapters well past the budget.
    for (id, seed) in [("b", 713u64), ("c", 714), ("d", 715)] {
        engine.register_adapter(adapter(id, m, n, 4, seed)).unwrap();
    }
    assert!(
        engine.registry().contains("hot"),
        "pinned adapter evicted: {:?}",
        engine.registry().ids()
    );
    assert!(engine.registry().stats().evictions >= 1, "budget of 2 never forced an eviction");
    // Every queued request still serves the right weights.
    let lin_ref = reference.layer("lin").unwrap();
    for (k, (t, x)) in tickets.into_iter().zip(&xs).enumerate() {
        let y = t.wait().unwrap().y;
        assert_bits_eq(&y, &lin_ref.forward(x, Some(&hot_pair)), &format!("request {k}"));
    }
    engine.shutdown();
}

#[test]
fn unregister_is_a_full_drain_then_a_hard_barrier() {
    let (m, n) = (64usize, 24usize);
    let model = base_model(m, n, 720);
    let reference = base_model(m, n, 720);
    let set = adapter("ten", m, n, 3, 721);
    let pair = set.get("lin").unwrap().clone();
    let engine = ServeEngine::builder(model).workers(2).max_batch(4).build().unwrap();
    let lin = engine.layer("lin").unwrap();
    let ten = engine.register_adapter(set).unwrap().id;
    let mut rng = Rng::new(722);
    let xs: Vec<Vec<f64>> = (0..64).map(|_| rng.gauss_vec(m)).collect();
    let tickets = engine
        .submit_all(xs.iter().map(|x| Request::with_adapter(lin, ten, x.clone())).collect());
    engine.unregister_adapter("ten").unwrap();
    // The drain returned ⇒ every ticket must already hold its response —
    // resolve them without blocking semantics mattering, and check bits.
    let lin_ref = reference.layer("lin").unwrap();
    for (k, (t, x)) in tickets.into_iter().zip(&xs).enumerate() {
        let y = t.wait().unwrap().y;
        assert_bits_eq(&y, &lin_ref.forward(x, Some(&pair)), &format!("request {k}"));
    }
    // And the barrier holds: the id is gone for new work, as a TYPED
    // error naming the tenant.
    let err = engine.submit(lin, Some(ten), rng.gauss_vec(m)).wait().unwrap_err();
    assert!(
        matches!(&err, ServeError::UnknownAdapter { adapter } if adapter == "ten"),
        "{err:?}"
    );
    let stats = engine.shutdown();
    assert_eq!(stats.requests, 64);
    assert_eq!(stats.rejected, 1);
}

#[test]
fn shipped_adapter_artifact_serves_bit_identically() {
    // The multi-tenant deployment flow: the base ships once (v2 artifact),
    // each tenant ships a small adapter artifact; loading both through the
    // unified store and serving matches the in-memory halves bit-for-bit.
    let store = ArtifactStore::at(
        std::env::temp_dir().join(format!("cloq_lifecycle_{}", std::process::id())),
    );
    let (m, n) = (40usize, 18usize);
    let model = base_model(m, n, 730);
    let set = adapter("tenant-7", m, n, 4, 731);
    let pair = set.get("lin").unwrap().clone();
    store.save_base(&model, "base.cloqpkd2").unwrap();
    store.save_adapter(&set, "tenant7.cloqadp").unwrap();

    let engine = ServeEngine::builder(store.load_base("base.cloqpkd2").unwrap())
        .build()
        .unwrap();
    let shipped = store.load_adapter("tenant7.cloqadp").unwrap();
    let tenant = engine.register_adapter(shipped).unwrap().id;
    let lin = engine.layer("lin").unwrap();
    let mut rng = Rng::new(732);
    let x = rng.gauss_vec(m);
    let y = engine.submit(lin, Some(tenant), x.clone()).wait().unwrap().y;
    let direct = model.layer("lin").unwrap().forward(&x, Some(&pair));
    assert_bits_eq(&y, &direct, "artifact-shipped adapter");
    engine.shutdown();
    std::fs::remove_dir_all(store.dir()).ok();
}

//! Telemetry integration suite: the counter-identity invariant under
//! mixed threaded load (successes, kernel panics, overload rejections,
//! multi-hop sessions), the Prometheus exposition round-trip (every
//! counter and histogram in the text output parses back to its snapshot
//! value), engine-level trace rings with slow capture, the durability
//! counters (WAL appends/fsyncs/replay, artifact open modes), and the
//! back-compat `EngineStats` view being exactly the snapshot collapsed.

use std::sync::mpsc;
use std::sync::Arc;

use cloq::linalg::Matrix;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    AdapterSet, ArtifactStore, Counter, DequantParams, Metric, ModelRequest, PackedLayer,
    PackedModel, Request, ServeEngine, ServeError, SessionRequest, StepFn, TelemetryOptions,
    TraceStage,
};
use cloq::util::logging::{set_level, Level};
use cloq::util::prng::Rng;

fn square_layer(name: &str, n: usize, seed: u64) -> PackedLayer {
    let mut rng = Rng::new(seed);
    let w = Matrix::randn(n, n, 0.3, &mut rng);
    PackedLayer::from_state(name, &QuantState::Int(quantize_rtn(&w, 4, 8))).unwrap()
}

/// A layer whose kernel panics on ANY request (the lifecycle suite's
/// out-of-range codebook idiom).
fn boom_layer(n: usize) -> PackedLayer {
    let wpr = cloq::serve::words_per_row(n, 2);
    PackedLayer {
        name: "boom".to_string(),
        rows: n,
        cols: n,
        bits: 2,
        group_size: n,
        packed: vec![u32::MAX; n * wpr].into(),
        params: DequantParams::Codebook {
            levels: vec![0.0, 1.0],
            absmax: Matrix::zeros(1, n),
        },
    }
}

fn mk_set(id: &str, layer: &str, n: usize, seed: u64) -> AdapterSet {
    let mut rng = Rng::new(seed);
    let pair = cloq::lowrank::LoraPair::new(
        Matrix::randn(n, 2, 0.1, &mut rng),
        Matrix::randn(n, 2, 0.1, &mut rng),
    );
    AdapterSet::from_pairs(id, vec![(layer.to_string(), pair)]).unwrap()
}

#[derive(Default)]
struct Tally {
    singles_ok: u64,
    singles_failed: u64,
    models_ok: u64,
    models_failed: u64,
    rejected: u64,
}

/// The invariant the module docs promise: every resolved submission is
/// counted in exactly one of the five outcome counters —
/// `requests + model_requests + rejected + failed + failed_model_requests`
/// equals the number of submissions whose tickets resolved. Exercised
/// from 4 threads mixing healthy singles, panicking singles, healthy and
/// doomed model routes, multi-step sessions, and a failing step — and
/// asserted not just as a sum but counter-by-counter against the
/// client-side tally of what each ticket actually returned.
#[test]
fn counter_identity_holds_under_mixed_threaded_load() {
    set_level(Level::Error); // panic batches log; keep the test run quiet
    let n = 12;
    let model = PackedModel::new(vec![
        square_layer("ok1", n, 900),
        boom_layer(n),
        square_layer("ok2", n, 901),
    ]);
    let engine = Arc::new(
        ServeEngine::builder(model).workers(2).max_batch(4).max_pending(256).build().unwrap(),
    );
    let ok1 = engine.layer("ok1").unwrap();
    let boom = engine.layer("boom").unwrap();

    let mut total_submitted = 0u64;
    let mut tally = Tally::default();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let engine = Arc::clone(&engine);
            handles.push(s.spawn(move || {
                let mut rng = Rng::new(910 + t);
                let mut tally = Tally::default();
                let mut submitted = 0u64;
                let healthy_route = engine.route(&["ok1", "ok2"]).unwrap();
                let doomed_route = engine.route(&["ok1", "boom"]).unwrap();
                let mut singles = Vec::new();
                let mut models = Vec::new();
                for i in 0..8 {
                    singles.push(engine.submit(ok1, None, rng.gauss_vec(n)));
                    if i % 4 == 0 {
                        singles.push(engine.submit(boom, None, rng.gauss_vec(n)));
                    }
                    submitted += 1 + u64::from(i % 4 == 0);
                }
                for i in 0..4 {
                    let route =
                        if i % 2 == 0 { healthy_route.clone() } else { doomed_route.clone() };
                    models.push(engine.submit_model(ModelRequest::new(route, rng.gauss_vec(n))));
                    submitted += 1;
                }
                let step: StepFn = Box::new(|_, y| Some(y.to_vec()));
                models.push(engine.submit_session(SessionRequest::new(
                    engine.route(&["ok2"]).unwrap(),
                    rng.gauss_vec(n),
                    3,
                    step,
                )));
                let failing: StepFn = Box::new(|_, _| Some(vec![0.0; 3]));
                models.push(engine.submit_session(SessionRequest::new(
                    engine.route(&["ok2"]).unwrap(),
                    rng.gauss_vec(n),
                    2,
                    failing,
                )));
                submitted += 2;
                for tk in singles {
                    match tk.wait() {
                        Ok(_) => tally.singles_ok += 1,
                        Err(ServeError::Overloaded { .. }) => tally.rejected += 1,
                        Err(_) => tally.singles_failed += 1,
                    }
                }
                for tk in models {
                    match tk.wait() {
                        Ok(_) => tally.models_ok += 1,
                        Err(ServeError::Overloaded { .. }) => tally.rejected += 1,
                        Err(_) => tally.models_failed += 1,
                    }
                }
                (submitted, tally)
            }));
        }
        for h in handles {
            let (submitted, t) = h.join().unwrap();
            total_submitted += submitted;
            tally.singles_ok += t.singles_ok;
            tally.singles_failed += t.singles_failed;
            tally.models_ok += t.models_ok;
            tally.models_failed += t.models_failed;
            tally.rejected += t.rejected;
        }
    });

    // Snapshot AFTER shutdown (workers joined → every counter settled),
    // through the handle that outlives the engine.
    let tel = engine.telemetry_handle();
    let engine = Arc::into_inner(engine).unwrap();
    let stats = engine.shutdown();
    let snap = tel.snapshot(&[]);

    // Counter-by-counter against what the tickets actually returned.
    assert_eq!(snap.counter(Counter::SinglesOk), tally.singles_ok);
    assert_eq!(snap.counter(Counter::SinglesFailed), tally.singles_failed);
    assert_eq!(snap.counter(Counter::ModelsOk), tally.models_ok);
    assert_eq!(snap.counter(Counter::ModelsFailed), tally.models_failed);
    assert_eq!(snap.counter(Counter::Rejected), tally.rejected);
    // The identity: five outcome counters partition the submissions.
    let resolved = snap.counter(Counter::SinglesOk)
        + snap.counter(Counter::ModelsOk)
        + snap.counter(Counter::Rejected)
        + snap.counter(Counter::SinglesFailed)
        + snap.counter(Counter::ModelsFailed);
    assert_eq!(resolved, total_submitted);
    // The load was built to exercise every outcome except overload
    // (which this uncontended config should not hit).
    assert_eq!(tally.singles_ok, 4 * 8);
    assert_eq!(tally.singles_failed, 4 * 2, "boom singles");
    assert_eq!(tally.models_ok, 4 * 3, "2 healthy models + 1 good session per thread");
    assert_eq!(tally.models_failed, 4 * 3, "2 doomed models + 1 failing session per thread");
    assert!(snap.counter(Counter::BatchPanics) >= 1);

    // Histogram counts line up with the counters: every rider of a
    // successful batch observed a hop, every batch observed a kernel
    // time, and every ADMITTED request (all of them here — no admission
    // rejects) observed an end-to-end wall time via its trace.
    assert_eq!(snap.hist(Metric::HopQueue).count, snap.counter(Counter::Hops));
    assert_eq!(snap.hist(Metric::HopLatency).count, snap.counter(Counter::Hops));
    assert_eq!(snap.hist(Metric::BatchCompute).count, snap.counter(Counter::Batches));
    assert_eq!(
        snap.hist(Metric::RequestWall).count,
        total_submitted - snap.counter(Counter::Rejected)
    );

    // The back-compat view is exactly the snapshot collapsed; the engine
    // returned the same struct from shutdown().
    let via_snapshot = snap.engine_stats();
    assert_eq!(stats.requests, via_snapshot.requests);
    assert_eq!(stats.model_requests, via_snapshot.model_requests);
    assert_eq!(stats.session_forwards, via_snapshot.session_forwards);
    assert_eq!(stats.hops, via_snapshot.hops);
    assert_eq!(stats.batches, via_snapshot.batches);
    assert_eq!(stats.rejected, via_snapshot.rejected);
    assert_eq!(stats.failed, via_snapshot.failed);
    assert_eq!(stats.failed_model_requests, via_snapshot.failed_model_requests);
    assert_eq!(stats.batch_panics, via_snapshot.batch_panics);
    assert_eq!(stats.max_batch_seen, via_snapshot.max_batch_seen);
    assert!(via_snapshot.total_queue_s >= 0.0);
    assert!(via_snapshot.total_compute_s > 0.0, "kernels ran; compute time must be recorded");

    // Per-layer attribution: rows carry the model's layer names and the
    // per-layer hop counts sum to the global hop counter.
    assert_eq!(snap.per_layer.len(), 3);
    assert_eq!(snap.per_layer[0].name, "ok1");
    assert_eq!(snap.per_layer[1].name, "boom");
    assert_eq!(snap.per_layer[2].name, "ok2");
    let layer_hops: u64 = snap.per_layer.iter().map(|l| l.hops).sum();
    assert_eq!(layer_hops, snap.counter(Counter::Hops));
    assert_eq!(snap.per_layer[1].hops, 0, "boom never completed a batch");
}

/// Deterministic overload: a session parked inside its step function
/// pins a live hop slot, so with `max_pending = 2` the third and fourth
/// arrivals are refused — and land in `Rejected`, not in the failure
/// counters, with no end-to-end wall observation (they never got a
/// trace).
#[test]
fn overload_rejections_count_as_rejected_not_failed() {
    let model = PackedModel::new(vec![square_layer("sq", 12, 920)]);
    let engine = ServeEngine::builder(model)
        .workers(1)
        .max_batch(4)
        .max_pending(2)
        .build()
        .unwrap();
    let sq = engine.layer("sq").unwrap();
    let route = engine.route(&["sq"]).unwrap();
    let (entered_tx, entered_rx) = mpsc::channel::<()>();
    let (gate_tx, gate_rx) = mpsc::channel::<()>();
    let step: StepFn = Box::new(move |_, y| {
        entered_tx.send(()).unwrap();
        gate_rx.recv().unwrap();
        Some(y.to_vec())
    });
    let mut rng = Rng::new(921);
    let session = engine.submit_session(SessionRequest::new(route, rng.gauss_vec(12), 2, step));
    entered_rx.recv().unwrap();
    let second = engine.submit(sq, None, rng.gauss_vec(12));
    let third = engine.submit(sq, None, rng.gauss_vec(12));
    let fourth = engine.submit(sq, None, rng.gauss_vec(12));
    assert!(matches!(third.wait().unwrap_err(), ServeError::Overloaded { .. }));
    assert!(matches!(fourth.wait().unwrap_err(), ServeError::Overloaded { .. }));
    gate_tx.send(()).unwrap();
    assert_eq!(session.wait().unwrap().forwards, 2);
    second.wait().unwrap();
    let tel = engine.telemetry_handle();
    engine.shutdown();
    let snap = tel.snapshot(&[]);
    assert_eq!(snap.counter(Counter::Rejected), 2);
    assert_eq!(snap.counter(Counter::SinglesFailed), 0);
    assert_eq!(snap.counter(Counter::ModelsFailed), 0);
    assert_eq!(snap.counter(Counter::SinglesOk), 1);
    assert_eq!(snap.counter(Counter::ModelsOk), 1);
    assert_eq!(snap.hist(Metric::RequestWall).count, 2, "rejects never start a trace");
}

fn prom_line_value(text: &str, key: &str) -> f64 {
    let mut found = None;
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(key) {
            if let Some(v) = rest.strip_prefix(' ') {
                assert!(found.is_none(), "duplicate exposition row for {key}");
                found = Some(v.parse::<f64>().unwrap_or_else(|_| {
                    panic!("unparseable value {v:?} for {key}")
                }));
            }
        }
    }
    found.unwrap_or_else(|| panic!("missing exposition row {key}"))
}

/// The acceptance round-trip: every counter and every histogram in the
/// snapshot appears in `render_prometheus()` and parses back to exactly
/// the snapshot's value — names, HELP/TYPE preambles, cumulative
/// buckets, `_sum`/`_count`, labeled per-layer and per-adapter rows,
/// and the gauges.
#[test]
fn prometheus_exposition_round_trips_every_counter_and_histogram() {
    let n = 16;
    let model = PackedModel::new(vec![square_layer("lin", n, 930)]);
    let engine = ServeEngine::builder(model).workers(2).max_batch(8).build().unwrap();
    let tenant = engine.register_adapter(mk_set("tenant", "lin", n, 931)).unwrap().id;
    let lin = engine.layer("lin").unwrap();
    let mut rng = Rng::new(932);
    let reqs: Vec<Request> =
        (0..24).map(|_| Request::with_adapter(lin, tenant, rng.gauss_vec(n))).collect();
    for tk in engine.submit_all(reqs) {
        tk.wait().unwrap();
    }
    for tk in (0..4).map(|_| {
        engine.submit_model(ModelRequest::new(engine.route(&["lin"]).unwrap(), rng.gauss_vec(n)))
    }) {
        tk.wait().unwrap();
    }
    let tel = engine.telemetry_handle();
    engine.shutdown();
    let snap = tel.snapshot(&["tenant".to_string()]);
    let text = snap.render_prometheus();

    // Gauges.
    assert!(prom_line_value(&text, "cloq_uptime_seconds") > 0.0);
    assert_eq!(
        prom_line_value(&text, "cloq_max_batch_seen") as usize,
        snap.max_batch_seen
    );

    // Every counter: HELP + TYPE + an exact value row.
    for c in Counter::ALL {
        assert!(
            text.contains(&format!("# HELP cloq_{} ", c.name())),
            "missing HELP for {}",
            c.name()
        );
        assert!(
            text.contains(&format!("# TYPE cloq_{} counter", c.name())),
            "missing TYPE for {}",
            c.name()
        );
        let rendered = prom_line_value(&text, &format!("cloq_{}", c.name()));
        assert_eq!(rendered as u64, snap.counter(c), "counter {} drifted", c.name());
    }

    // Every histogram: TYPE histogram, cumulative buckets ending at
    // +Inf == _count, and _sum/_count parsing back exactly.
    for m in Metric::ALL {
        let h = snap.hist(m);
        assert!(
            text.contains(&format!("# TYPE cloq_{} histogram", m.name())),
            "missing TYPE for {}",
            m.name()
        );
        let count = prom_line_value(&text, &format!("cloq_{}_count", m.name()));
        assert_eq!(count as u64, h.count, "histogram {} count drifted", m.name());
        let sum = prom_line_value(&text, &format!("cloq_{}_sum", m.name()));
        assert_eq!(sum, h.sum_s, "histogram {} sum drifted", m.name());
        let inf =
            prom_line_value(&text, &format!("cloq_{}_bucket{{le=\"+Inf\"}}", m.name()));
        assert_eq!(inf as u64, h.count, "+Inf bucket must equal the total count");
        // Cumulative rows are nondecreasing and each parses back.
        let mut prev = 0u64;
        for (le, cum) in h.cumulative() {
            let key = if le.is_infinite() {
                format!("cloq_{}_bucket{{le=\"+Inf\"}}", m.name())
            } else {
                format!("cloq_{}_bucket{{le=\"{le}\"}}", m.name())
            };
            assert_eq!(prom_line_value(&text, &key) as u64, cum);
            assert!(cum >= prev);
            prev = cum;
        }
    }

    // Labeled attribution rows: the layer and the named adapter.
    assert_eq!(
        prom_line_value(&text, "cloq_layer_hops_total{layer=\"lin\"}") as u64,
        snap.counter(Counter::Hops)
    );
    let adapter_hops = prom_line_value(&text, "cloq_adapter_hops_total{adapter=\"tenant\"}");
    assert_eq!(adapter_hops as u64, 24, "the 24 adapter singles attribute to the tenant");

    // Sanity on the workload itself.
    assert_eq!(snap.counter(Counter::SinglesOk), 24);
    assert_eq!(snap.counter(Counter::ModelsOk), 4);
    assert!(snap.hist(Metric::RequestWall).quantile(0.5) > 0.0);
}

/// Engine-level tracing: responses carry the trace id, the recent ring
/// is bounded (evictions counted), a zero slow-threshold captures every
/// request into the slow ring (also bounded), and each trace's timeline
/// runs admitted → enqueued → hop → replied.
#[test]
fn trace_rings_bound_capture_and_order_events() {
    set_level(Level::Error); // every request logs as slow; keep quiet
    let n = 10;
    let model = PackedModel::new(vec![square_layer("sq", n, 940)]);
    let engine = ServeEngine::builder(model)
        .workers(1)
        .telemetry(
            TelemetryOptions::default().slow_threshold_s(0.0).recent_traces(4).slow_traces(2),
        )
        .build()
        .unwrap();
    let sq = engine.layer("sq").unwrap();
    let mut rng = Rng::new(941);
    let mut ids = Vec::new();
    for _ in 0..10 {
        let resp = engine.submit(sq, None, rng.gauss_vec(n)).wait().unwrap();
        assert_ne!(resp.trace_id, 0, "tracing on → every response names its trace");
        ids.push(resp.trace_id);
    }
    let tel = engine.telemetry_handle();
    engine.shutdown();
    let snap = tel.snapshot(&[]);
    assert_eq!(snap.recent_traces.len(), 4, "recent ring capped");
    assert_eq!(snap.slow_traces.len(), 2, "slow ring capped");
    assert_eq!(snap.counter(Counter::SlowRequests), 10, "0-threshold → all slow");
    assert_eq!(snap.counter(Counter::TracesDropped), 6, "10 finished − 4 kept");
    // The rings hold the most recent finishes, oldest first.
    let kept: Vec<u64> = snap.recent_traces.iter().map(|t| t.id).collect();
    assert_eq!(kept, ids[6..].to_vec());
    for trace in snap.recent_traces.iter().chain(&snap.slow_traces) {
        assert!(trace.ok);
        assert!(matches!(trace.events.first().unwrap().stage, TraceStage::Admitted { .. }));
        assert!(matches!(trace.events.last().unwrap().stage, TraceStage::Replied { ok: true }));
        assert!(
            trace.events.iter().any(|e| matches!(e.stage, TraceStage::Hop { hop: 1, .. })),
            "single-layer trace must record its one hop"
        );
        let rendered = trace.render();
        assert!(rendered.contains("hop 1"), "{rendered}");
        assert!(rendered.contains("replied ok"), "{rendered}");
    }
}

/// Disabled telemetry: no traces, zero-valued snapshot, and the
/// engine still serves and reports back-compat stats correctly.
#[test]
fn disabled_telemetry_serves_with_zeroed_instruments() {
    let n = 10;
    let model = PackedModel::new(vec![square_layer("sq", n, 950)]);
    let engine = ServeEngine::builder(model)
        .telemetry(TelemetryOptions::disabled())
        .build()
        .unwrap();
    let sq = engine.layer("sq").unwrap();
    let mut rng = Rng::new(951);
    let resp = engine.submit(sq, None, rng.gauss_vec(n)).wait().unwrap();
    assert_eq!(resp.trace_id, 0, "tracing off → no trace id");
    let snap = engine.telemetry();
    assert!(!snap.enabled);
    assert_eq!(snap.counter(Counter::SinglesOk), 0);
    assert_eq!(snap.hist(Metric::RequestWall).count, 0);
    assert!(snap.recent_traces.is_empty());
    let stats = engine.shutdown();
    assert_eq!(stats.requests, 0, "the compat view reflects the disabled instruments");
}

/// Durability instrumentation: registers/unregisters count WAL appends
/// and fsyncs, boot replay surfaces the recovered event count, and the
/// artifact store attributes opens to the eager vs mapped paths with
/// durations in the open histogram.
#[test]
fn durability_counters_track_wal_and_artifact_activity() {
    let n = 16;
    let dir = std::env::temp_dir().join(format!("cloq_tel_wal_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    let build = || {
        ServeEngine::builder(PackedModel::new(vec![square_layer("lin", n, 960)]))
            .durable(&dir)
            .build()
            .unwrap()
    };
    let engine = build();
    for i in 0..3 {
        engine.register_adapter(mk_set(&format!("t{i}"), "lin", n, 961 + i as u64)).unwrap();
    }
    engine.unregister_adapter("t1").unwrap();
    let snap = engine.telemetry();
    assert_eq!(snap.counter(Counter::WalAppends), 4, "3 registers + 1 unregister");
    let fsyncs = snap.counter(Counter::WalFsyncs);
    assert!(fsyncs >= 1 && fsyncs <= 4, "sync_every=1 commits each op: {fsyncs}");
    assert_eq!(snap.hist(Metric::WalFsync).count, fsyncs, "every fsync timed");
    assert_eq!(snap.counter(Counter::WalReplayEvents), 0, "fresh log, nothing replayed");
    engine.shutdown();

    // Reboot on the surviving log: the replay counter reports the
    // recovered history (3 registers + 1 unregister decoded).
    let engine = build();
    let snap = engine.telemetry();
    assert_eq!(snap.counter(Counter::WalReplayEvents), 4);

    // Artifact opens, attributed by mode, through the engine's core.
    let store = ArtifactStore::at(&dir).with_telemetry(engine.telemetry_handle());
    let model = PackedModel::new(vec![square_layer("lin", n, 962)]);
    store.save_base_v3(&model, "base.cloqpkd3").unwrap();
    store.open("base.cloqpkd3").unwrap();
    store.open_mapped("base.cloqpkd3").unwrap();
    store.load_base("base.cloqpkd3").unwrap();
    let snap = engine.telemetry();
    assert_eq!(snap.counter(Counter::ArtifactOpensEager), 2, "open + load_base");
    assert_eq!(snap.counter(Counter::ArtifactOpensMapped), 1);
    assert_eq!(snap.hist(Metric::ArtifactOpen).count, 3, "every open timed");
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

//! Integration tests over the real PJRT runtime + AOT artifacts.
//!
//! These need `make artifacts` to have produced `artifacts/micro/`; when it
//! hasn't, every test skips with a message (so `cargo test` stays green on
//! a fresh clone, and the Makefile's `test` target, which builds artifacts
//! first, gets the full signal).

use std::path::PathBuf;

use cloq::model::{base_specs, init_base, lora_specs, zeros_for};
use cloq::runtime::{Runtime, Tensor};
use cloq::util::prng::Rng;

fn artifacts_dir() -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/micro");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: artifacts/micro missing — run `make artifacts` first");
        None
    }
}

fn random_batch(rt: &Runtime, rng: &mut Rng) -> (Tensor, Tensor) {
    let cfg = &rt.manifest.config;
    let n = cfg.batch * cfg.seq;
    let tokens: Vec<i32> = (0..n).map(|_| rng.range(4, cfg.vocab as i64 - 1) as i32).collect();
    (
        Tensor::i32(vec![cfg.batch, cfg.seq], tokens),
        Tensor::f32(vec![cfg.batch, cfg.seq], vec![1.0; n]),
    )
}

#[test]
fn eval_loss_of_random_model_is_near_uniform() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let mut rng = Rng::new(1);
    let base = init_base(&rt.manifest, &mut rng).unwrap();
    let lspecs = lora_specs(&rt.manifest).unwrap();
    let lora = zeros_for(&lspecs);
    let (tokens, mask) = random_batch(&rt, &mut rng);

    let mut inputs = base.in_order();
    inputs.extend(lora.in_order());
    inputs.push(tokens);
    inputs.push(mask);
    let out = rt.run("eval_loss", &inputs).unwrap();
    let (loss_sum, count) = (out[0].scalar(), out[1].scalar());
    let cfg = &rt.manifest.config;
    assert_eq!(count as usize, cfg.batch * (cfg.seq - 1));
    let ce = loss_sum / count;
    let uniform = (cfg.vocab as f32).ln();
    assert!((ce - uniform).abs() < 1.2, "ce={ce} uniform={uniform}");
}

#[test]
fn pretrain_step_decreases_loss() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let mut rng = Rng::new(2);
    let base = init_base(&rt.manifest, &mut rng).unwrap();
    let bspecs = base_specs(&rt.manifest).unwrap();
    let nb = bspecs.len();

    let mut params = base.in_order();
    let mut m: Vec<Tensor> = params.iter().map(|t| Tensor::zeros_f32(t.shape.clone())).collect();
    let mut v = m.clone();
    let (tokens, mask) = random_batch(&rt, &mut rng);

    let mut losses = Vec::new();
    for step in 0..15 {
        let mut inputs = params.clone();
        inputs.extend(m.clone());
        inputs.extend(v.clone());
        inputs.push(tokens.clone());
        inputs.push(mask.clone());
        inputs.push(Tensor::scalar_f32(3e-3)); // lr
        inputs.push(Tensor::scalar_f32(0.0)); // wd
        inputs.push(Tensor::scalar_f32((step + 1) as f32)); // t
        let out = rt.run("pretrain_step", &inputs).unwrap();
        losses.push(out.last().unwrap().scalar());
        params = out[..nb].to_vec();
        m = out[nb..2 * nb].to_vec();
        v = out[2 * nb..3 * nb].to_vec();
    }
    assert!(
        losses.last().unwrap() + 0.3 < losses[0],
        "pretraining failed to learn: {losses:?}"
    );
}

#[test]
fn lora_step_trains_adapters_only() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let mut rng = Rng::new(3);
    let base = init_base(&rt.manifest, &mut rng).unwrap();
    let lspecs = lora_specs(&rt.manifest).unwrap();
    let nl = lspecs.len();
    // Non-zero LoRA init so gradients flow through both factors.
    let mut lora: Vec<Tensor> = lspecs
        .iter()
        .map(|s| {
            let data: Vec<f32> = (0..s.numel()).map(|_| rng.normal(0.0, 0.03) as f32).collect();
            Tensor::f32(s.shape.clone(), data)
        })
        .collect();
    let mut m: Vec<Tensor> = lora.iter().map(|t| Tensor::zeros_f32(t.shape.clone())).collect();
    let mut v = m.clone();
    let (tokens, mask) = random_batch(&rt, &mut rng);
    let base_inputs = base.in_order();

    let mut losses = Vec::new();
    for step in 0..15 {
        let mut inputs = base_inputs.clone();
        inputs.extend(lora.clone());
        inputs.extend(m.clone());
        inputs.extend(v.clone());
        inputs.push(tokens.clone());
        inputs.push(mask.clone());
        inputs.push(Tensor::scalar_f32(5e-3));
        inputs.push(Tensor::scalar_f32(0.0));
        inputs.push(Tensor::scalar_f32((step + 1) as f32));
        let out = rt.run("lora_step", &inputs).unwrap();
        losses.push(out.last().unwrap().scalar());
        lora = out[..nl].to_vec();
        m = out[nl..2 * nl].to_vec();
        v = out[2 * nl..3 * nl].to_vec();
    }
    assert!(
        *losses.last().unwrap() < losses[0],
        "LoRA fine-tuning failed to reduce loss: {losses:?}"
    );
}

#[test]
fn capture_grams_returns_psd_matrices() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let mut rng = Rng::new(4);
    let base = init_base(&rt.manifest, &mut rng).unwrap();
    let (tokens, mask) = random_batch(&rt, &mut rng);
    let mut inputs = base.in_order();
    inputs.push(tokens);
    inputs.push(mask);
    let out = rt.run("capture_grams", &inputs).unwrap();
    let cfg = &rt.manifest.config;
    assert_eq!(out.len(), 6 * cfg.n_layers + 1); // grams + logit checksum
    assert!(out.last().unwrap().scalar().is_finite());
    let grams = &out[..out.len() - 1];
    for (t, spec) in grams.iter().zip(&rt.manifest.entry("capture_grams").unwrap().outputs) {
        assert_eq!(t.shape, spec.shape);
        let h = t.to_matrix();
        // Symmetric + PSD-ish (eigenvalues ≥ -eps relative to top).
        assert!(h.max_diff(&h.transpose()) < 1e-2 * h.max_abs().max(1.0));
        let e = cloq::linalg::eig::sym_eig(&h);
        assert!(e.values.iter().all(|&l| l > -1e-3 * e.values[0].abs().max(1.0)));
    }
}

#[test]
fn qeval_matches_dense_eval_on_grid_weights() {
    // The serving-path contract: quantized (codes) path == dense path on
    // dequantized values — the Rust mirror of the python test, through the
    // REAL artifacts and the REAL Pallas-lowered kernel.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::load(&dir).unwrap();
    let mut rng = Rng::new(5);
    let mut base = init_base(&rt.manifest, &mut rng).unwrap();
    let cfg = rt.manifest.config.clone();

    // Quantize every block linear at 4 bits; replace base with dequantized.
    let mut quant_inputs: Vec<(String, Tensor)> = Vec::new();
    for l in 0..cfg.n_layers {
        for (name, _din, _dout) in cfg.linear_specs(l) {
            let w = base.get(&name).to_matrix();
            let q = cloq::quant::quantize_rtn(&w, 4, cfg.group_size);
            let deq = q.dequantize();
            base.insert(&name, Tensor::from_matrix(&deq));
            let codes_i32: Vec<i32> = q.codes.iter().map(|&c| c as i32).collect();
            quant_inputs
                .push((format!("{name}.codes"), Tensor::i32(vec![q.rows, q.cols], codes_i32)));
            quant_inputs.push((format!("{name}.scales"), Tensor::from_matrix(&q.scales)));
            quant_inputs.push((format!("{name}.zeros"), Tensor::from_matrix(&q.zeros)));
        }
    }
    let lspecs = lora_specs(&rt.manifest).unwrap();
    let lora: Vec<Tensor> = lspecs
        .iter()
        .map(|s| {
            let data: Vec<f32> = (0..s.numel()).map(|_| rng.normal(0.0, 0.05) as f32).collect();
            Tensor::f32(s.shape.clone(), data)
        })
        .collect();
    let (tokens, mask) = random_batch(&rt, &mut rng);

    // Dense eval.
    let mut inputs = base.in_order();
    inputs.extend(lora.clone());
    inputs.push(tokens.clone());
    inputs.push(mask.clone());
    let dense = rt.run("eval_loss", &inputs).unwrap();

    // Quantized eval: follow the manifest input order exactly.
    let qspec = rt.manifest.entry("qeval_loss").unwrap().clone();
    let mut qinputs = Vec::new();
    let mut lora_iter = lspecs.iter().zip(lora.iter());
    for s in &qspec.inputs {
        if s.name == "tokens" {
            qinputs.push(tokens.clone());
        } else if s.name == "mask" {
            qinputs.push(mask.clone());
        } else if s.name.ends_with(".A") || s.name.ends_with(".B") {
            let (ls, lt) = lora_iter.next().unwrap();
            assert_eq!(ls.name, s.name, "lora order mismatch");
            qinputs.push(lt.clone());
        } else if let Some((_, t)) = quant_inputs.iter().find(|(n, _)| n == &s.name) {
            qinputs.push(t.clone());
        } else {
            qinputs.push(base.get(&s.name).clone());
        }
    }
    let quant = rt.run("qeval_loss", &qinputs).unwrap();

    assert_eq!(dense[1].scalar(), quant[1].scalar(), "counts differ");
    let (a, b) = (dense[0].scalar(), quant[0].scalar());
    assert!(
        (a - b).abs() < 2e-2 * a.abs().max(1.0),
        "dense {a} vs quantized {b}"
    );
}

//! Deterministic fault-injection suite for the adapter WAL
//! (`serve::wal`) and the durable engine path built on it.
//!
//! The recovery contract under test: **whatever prefix of the log's
//! bytes survives a crash, replay yields exactly a prefix of the
//! committed operations** — never a reordering, never a half-applied op,
//! never bytes misread as an op — and an engine rebuilt from the
//! survivors serves bit-identical (0 ULP) forwards for every adapter in
//! the recovered state.
//!
//! Fault model, driven through the injectable [`WalFile`] trait:
//! * **Truncation at EVERY byte offset** of a scripted
//!   register → hot-swap → unregister history (the power cut). The suite
//!   walks all ~2k cuts, not a sample.
//! * **Torn appends**: a register that dies mid-record (the `write(2)`
//!   that never finished) must fail typed at the caller AND recover to
//!   the pre-append state on reboot.
//! * **Duplicated tails**: the record-or-piece-of-record the page cache
//!   replayed twice — full duplicates must be state-idempotent, partial
//!   ones must be discarded as a torn tail.
//! * **Repair-then-append**: after recovering from any cut, the log must
//!   accept new operations and replay THOSE too (torn-tail repair
//!   compacts, so the check is state equivalence, not byte equality).

use std::sync::{Arc, Mutex};

use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    AdapterSet, ArtifactErrorKind, PackedLayer, PackedModel, ServeEngine, ServeError, Wal,
    WalEvent, WalFile, WalOptions,
};
use cloq::util::prng::Rng;

// ---------------------------------------------------------------------------
// Injectable WAL files over one shared byte buffer
// ---------------------------------------------------------------------------

type SharedBytes = Arc<Mutex<Vec<u8>>>;

/// In-memory [`WalFile`] over a shareable buffer: the "disk" survives the
/// `Wal` (the "process"), so tests crash one and boot another on the same
/// bytes.
struct MemFile {
    bytes: SharedBytes,
}

impl MemFile {
    fn over(bytes: &SharedBytes) -> Box<MemFile> {
        Box::new(MemFile { bytes: Arc::clone(bytes) })
    }
}

impl WalFile for MemFile {
    fn read_all(&mut self) -> std::io::Result<Vec<u8>> {
        Ok(self.bytes.lock().unwrap().clone())
    }
    fn append(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.bytes.lock().unwrap().extend_from_slice(bytes);
        Ok(())
    }
    fn sync(&mut self) -> std::io::Result<()> {
        Ok(())
    }
    fn replace(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        *self.bytes.lock().unwrap() = bytes.to_vec();
        Ok(())
    }
}

/// A [`WalFile`] whose Nth append dies after writing only `keep` bytes —
/// the torn `write(2)`. Everything else behaves like [`MemFile`].
struct TearingFile {
    bytes: SharedBytes,
    appends_before_tear: usize,
    keep: usize,
    appends_seen: usize,
}

impl WalFile for TearingFile {
    fn read_all(&mut self) -> std::io::Result<Vec<u8>> {
        Ok(self.bytes.lock().unwrap().clone())
    }
    fn append(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        self.appends_seen += 1;
        if self.appends_seen > self.appends_before_tear {
            let keep = self.keep.min(bytes.len());
            self.bytes.lock().unwrap().extend_from_slice(&bytes[..keep]);
            return Err(std::io::Error::other("injected: append torn mid-record"));
        }
        self.bytes.lock().unwrap().extend_from_slice(bytes);
        Ok(())
    }
    fn sync(&mut self) -> std::io::Result<()> {
        Ok(())
    }
    fn replace(&mut self, bytes: &[u8]) -> std::io::Result<()> {
        *self.bytes.lock().unwrap() = bytes.to_vec();
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The scripted history
// ---------------------------------------------------------------------------

/// Two tiny chained layers: l0 6→4, l1 4→3 (rank-2 adapters ≈ 350 bytes
/// per register record — the whole history is ~2 KB, so walking every
/// byte cut stays fast).
fn model() -> PackedModel {
    let mut rng = Rng::new(2600);
    let mut layers = Vec::new();
    for (name, m, n) in [("l0", 6usize, 4usize), ("l1", 4, 3)] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        layers.push(
            PackedLayer::from_state(name, &QuantState::Int(quantize_rtn(&w, 4, 4))).unwrap(),
        );
    }
    PackedModel::new(layers)
}

/// The adapter-set VERSION registered as (id, seed) — rebuilt from the
/// seed wherever a test needs the expected weights.
fn mk_set(id: &str, seed: u64) -> AdapterSet {
    let mut rng = Rng::new(seed);
    let m = model();
    let mut set = AdapterSet::new(id);
    for l in &m.layers {
        set.insert(
            &l.name,
            LoraPair::new(
                Matrix::randn(l.rows, 2, 0.1, &mut rng),
                Matrix::randn(l.cols, 2, 0.1, &mut rng),
            ),
        )
        .unwrap();
    }
    set
}

/// One scripted operation: `("+", id, seed)` register, `("-", id, 0)`
/// unregister.
type Op = (&'static str, &'static str, u64);

/// register a → register b → hot-swap a → unregister b → register c:
/// covers first-registration, multi-tenant, version replacement, removal,
/// and registration-after-removal in five records.
const HISTORY: [Op; 5] =
    [("+", "a", 1), ("+", "b", 2), ("+", "a", 3), ("-", "b", 0), ("+", "c", 4)];

/// Expected live state — (id, seed of the live version) — after the first
/// `k` ops of [`HISTORY`].
fn expected_live(k: usize) -> Vec<(&'static str, u64)> {
    let mut live: Vec<(&'static str, u64)> = Vec::new();
    for &(kind, id, seed) in &HISTORY[..k] {
        live.retain(|&(i, _)| i != id);
        if kind == "+" {
            live.push((id, seed));
        }
    }
    live.sort();
    live
}

/// No-compaction options so the scripted log keeps all five records on
/// disk — the cut sweep needs the full byte sequence.
fn no_compact() -> WalOptions {
    WalOptions { sync_every: 1, compact_min_bytes: usize::MAX, compact_ratio: usize::MAX }
}

/// Write the scripted history through a real `Wal`, returning the full
/// log bytes and the byte offset at which each op's record ends (the
/// commit points). `ends[0] = 12` is the bare header.
fn scripted_log() -> (Vec<u8>, Vec<usize>) {
    let bytes: SharedBytes = Arc::new(Mutex::new(Vec::new()));
    let (mut wal, events) = Wal::open(MemFile::over(&bytes), "scripted", no_compact()).unwrap();
    assert!(events.is_empty());
    let mut ends = vec![bytes.lock().unwrap().len()];
    for &(kind, id, seed) in &HISTORY {
        match kind {
            "+" => wal.log_register(&mk_set(id, seed)).unwrap(),
            _ => wal.log_unregister(id).unwrap(),
        }
        ends.push(bytes.lock().unwrap().len());
    }
    assert_eq!(ends[0], 12, "header is magic + version");
    let log = bytes.lock().unwrap().clone();
    assert_eq!(*ends.last().unwrap(), log.len());
    (log, ends)
}

/// Number of whole committed ops inside the first `cut` bytes.
fn ops_within(ends: &[usize], cut: usize) -> usize {
    HISTORY.len() - ends[1..].iter().filter(|&&e| e > cut).count()
}

/// Fold replayed events into the live (id, set) state, sorted by id —
/// the invariant the sequence-agnostic checks compare on (compaction
/// reorders records into id order, so post-repair logs can only be
/// compared by state, never by raw op sequence).
fn state_of(events: Vec<WalEvent>) -> Vec<(String, AdapterSet)> {
    let mut live: Vec<(String, AdapterSet)> = Vec::new();
    for ev in events {
        match ev {
            WalEvent::Register(set) => {
                live.retain(|(id, _)| *id != set.id());
                live.push((set.id().to_string(), set));
            }
            WalEvent::Unregister(id) => live.retain(|(i, _)| *i != id),
        }
    }
    live.sort_by(|x, y| x.0.cmp(&y.0));
    live
}

/// Assert a recovered live state matches `expected_live(k)` with
/// bit-identical adapter weights (every version rebuilt from its seed).
fn assert_state(live: &[(String, AdapterSet)], k: usize, ctx: &str) {
    let want = expected_live(k);
    let got: Vec<&str> = live.iter().map(|(id, _)| id.as_str()).collect();
    let want_ids: Vec<&str> = want.iter().map(|&(id, _)| id).collect();
    assert_eq!(got, want_ids, "{ctx}: live ids after {k} ops");
    for ((_, set), &(id, seed)) in live.iter().zip(&want) {
        let expect = mk_set(id, seed);
        for (name, pair) in expect.entries() {
            let got_pair = set.get(name).unwrap_or_else(|| panic!("{ctx}: {id} lost {name}"));
            assert_bits(&got_pair.a, &pair.a, &format!("{ctx}: {id}.{name}.a"));
            assert_bits(&got_pair.b, &pair.b, &format!("{ctx}: {id}.{name}.b"));
        }
    }
}

fn assert_bits(got: &Matrix, want: &Matrix, ctx: &str) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{ctx}: shape");
    for (u, v) in got.data.iter().zip(&want.data) {
        assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: weight bits");
    }
}

// ---------------------------------------------------------------------------
// The exhaustive cut sweep
// ---------------------------------------------------------------------------

/// THE property: for EVERY byte cut of the scripted log, replay recovers
/// exactly the ops whose records fit inside the cut — in order, with
/// bit-identical weights — and the repaired log accepts and replays a
/// subsequent append.
#[test]
fn every_byte_cut_recovers_exactly_a_committed_prefix() {
    let (log, ends) = scripted_log();
    let names: Vec<String> = HISTORY.iter().map(|&(k, id, _)| format!("{k}{id}")).collect();
    for cut in 0..=log.len() {
        let k = ops_within(&ends, cut);
        let bytes: SharedBytes = Arc::new(Mutex::new(log[..cut].to_vec()));
        let (mut wal, events) = Wal::open(MemFile::over(&bytes), "cut", no_compact())
            .unwrap_or_else(|e| panic!("cut {cut}: open must recover, got {e}"));
        // The recovered events are EXACTLY the committed prefix, in order.
        let got: Vec<String> = events
            .iter()
            .map(|ev| match ev {
                WalEvent::Register(s) => format!("+{}", s.id()),
                WalEvent::Unregister(id) => format!("-{id}"),
            })
            .collect();
        assert_eq!(got, names[..k], "cut {cut}: recovered op sequence");
        assert_state(&state_of(events), k, &format!("cut {cut}"));
        // Repair-then-append: the repaired log takes a NEW op, and a
        // second boot replays recovered-state + new op. Repair compacts
        // (id order), so this is a state check, not a byte check.
        wal.log_register(&mk_set("d", 5)).unwrap();
        drop(wal);
        let (_, events2) = Wal::open(MemFile::over(&bytes), "cut2", no_compact()).unwrap();
        let live2 = state_of(events2);
        let mut want: Vec<(&str, u64)> = expected_live(k);
        want.push(("d", 5));
        want.sort();
        let got2: Vec<&str> = live2.iter().map(|(id, _)| id.as_str()).collect();
        let want_ids: Vec<&str> = want.iter().map(|&(id, _)| id).collect();
        assert_eq!(got2, want_ids, "cut {cut}: live ids after repair + append");
        for ((_, set), &(id, seed)) in live2.iter().zip(&want) {
            let expect = mk_set(id, seed);
            for (name, pair) in expect.entries() {
                let got_pair = set.get(name).unwrap();
                assert_bits(&got_pair.a, &pair.a, &format!("cut {cut}: {id}.{name}.a"));
            }
        }
    }
}

/// Duplicated tails (a replayed page-cache write): a FULL duplicate of
/// the last committed record is state-idempotent — a register re-applies
/// the same bytes, an unregister of a gone id is dropped — and any
/// PARTIAL duplicate is a torn tail, discarded by the prefix rule.
#[test]
fn duplicated_tail_records_are_idempotent_and_partials_are_torn() {
    let (log, ends) = scripted_log();
    for k in 1..=HISTORY.len() {
        let record = &log[ends[k - 1]..ends[k]];
        // Full duplicate.
        let mut bytes = log[..ends[k]].to_vec();
        bytes.extend_from_slice(record);
        let shared: SharedBytes = Arc::new(Mutex::new(bytes));
        let (_, events) = Wal::open(MemFile::over(&shared), "dup", no_compact()).unwrap();
        assert_state(&state_of(events), k, &format!("full dup of op {k}"));
        // Every partial duplicate length (1..record) is a torn tail.
        for keep in [1, record.len() / 2, record.len() - 1] {
            let mut bytes = log[..ends[k]].to_vec();
            bytes.extend_from_slice(&record[..keep]);
            let shared: SharedBytes = Arc::new(Mutex::new(bytes));
            let (_, events) =
                Wal::open(MemFile::over(&shared), "partdup", no_compact()).unwrap();
            assert_state(&state_of(events), k, &format!("partial dup ({keep}B) of op {k}"));
        }
    }
}

// ---------------------------------------------------------------------------
// Engine-level recovery: replay through the real registry, 0-ULP forwards
// ---------------------------------------------------------------------------

/// Build a durable engine over the given log bytes and assert it serves
/// exactly `expected_live(k)`: every surviving adapter answers requests
/// bit-identical to a direct forward with the seed-rebuilt weights, and
/// every other id is typed-unknown.
fn assert_engine_recovers(bytes: &SharedBytes, k: usize, ctx: &str) {
    let engine = ServeEngine::builder(model())
        .workers(1)
        .durable_wal(MemFile::over(bytes), "crash")
        .build()
        .unwrap_or_else(|e| panic!("{ctx}: durable build must recover, got {e}"));
    let m = model();
    let live = expected_live(k);
    let mut rng = Rng::new(9000 + k as u64);
    for &(id, seed) in &live {
        let aid = engine.adapter(id).unwrap_or_else(|e| panic!("{ctx}: lost '{id}': {e}"));
        let expect = mk_set(id, seed);
        for l in &m.layers {
            let x = rng.gauss_vec(l.rows);
            let want = l.forward(&x, expect.get(&l.name));
            let lid = engine.layer(&l.name).unwrap();
            let got = engine.submit(lid, Some(aid), x).wait().unwrap().y;
            assert_eq!(got.len(), want.len(), "{ctx}: '{id}' on {}", l.name);
            for (u, v) in got.iter().zip(&want) {
                assert_eq!(u.to_bits(), v.to_bits(), "{ctx}: '{id}' on {} bits", l.name);
            }
        }
    }
    for id in ["a", "b", "c"] {
        if !live.iter().any(|&(i, _)| i == id) {
            assert!(
                matches!(engine.adapter(id), Err(ServeError::UnknownAdapter { .. })),
                "{ctx}: '{id}' must NOT survive"
            );
        }
    }
    engine.shutdown();
}

/// A durable engine booted from every commit point serves bit-identical
/// forwards for exactly the committed tenants.
#[test]
fn durable_engine_serves_bit_identical_forwards_from_every_commit_point() {
    let (log, ends) = scripted_log();
    for (k, &end) in ends.iter().enumerate() {
        let bytes: SharedBytes = Arc::new(Mutex::new(log[..end].to_vec()));
        assert_engine_recovers(&bytes, k, &format!("commit point {k}"));
    }
    // And from a mid-record crash: one byte short of the last commit is
    // the previous state.
    let bytes: SharedBytes = Arc::new(Mutex::new(log[..log.len() - 1].to_vec()));
    assert_engine_recovers(&bytes, HISTORY.len() - 1, "one byte short of final commit");
}

/// A register whose WAL append tears mid-record fails TYPED at the
/// caller, leaves the live engine consistent (the op was not applied),
/// and a reboot from the torn bytes recovers the pre-append state.
#[test]
fn torn_append_fails_typed_and_reboots_to_the_previous_state() {
    let bytes: SharedBytes = Arc::new(Mutex::new(Vec::new()));
    let engine = ServeEngine::builder(model())
        .workers(1)
        .durable_wal(MemFile::over(&bytes), "pre")
        .build()
        .unwrap();
    engine.register_adapter(mk_set("a", 1)).unwrap();
    engine.register_adapter(mk_set("b", 2)).unwrap();
    engine.shutdown();
    let committed = bytes.lock().unwrap().len();

    // Reboot on a file whose NEXT append dies 7 bytes in (mid-frame).
    let tearing = Box::new(TearingFile {
        bytes: Arc::clone(&bytes),
        appends_before_tear: 0,
        keep: 7,
        appends_seen: 0,
    });
    let engine = ServeEngine::builder(model())
        .workers(1)
        .durable_wal(tearing, "tear")
        .build()
        .unwrap();
    assert!(engine.adapter("a").is_ok() && engine.adapter("b").is_ok());
    let err = engine.register_adapter(mk_set("c", 4)).unwrap_err();
    assert!(
        matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::Io, .. }),
        "torn append must surface as a typed Io artifact error, got {err:?}"
    );
    // The failed register was never applied: the engine does not serve
    // 'c', and the survivors still answer.
    assert!(matches!(engine.adapter("c"), Err(ServeError::UnknownAdapter { .. })));
    let lid = engine.layer("l0").unwrap();
    let aid = engine.adapter("a").unwrap();
    let mut rng = Rng::new(9100);
    let x = rng.gauss_vec(6);
    let want = model().layers[0].forward(&x, mk_set("a", 1).get("l0"));
    let got = engine.submit(lid, Some(aid), x).wait().unwrap().y;
    for (u, v) in got.iter().zip(&want) {
        assert_eq!(u.to_bits(), v.to_bits());
    }
    engine.shutdown();
    assert_eq!(
        bytes.lock().unwrap().len(),
        committed + 7,
        "the torn bytes are on disk, after the committed prefix"
    );

    // Reboot #2 on the torn bytes: strict prefix — a and b, no c — and
    // the repair leaves an appendable log.
    let engine = ServeEngine::builder(model())
        .workers(1)
        .durable_wal(MemFile::over(&bytes), "reboot")
        .build()
        .unwrap();
    assert!(engine.adapter("a").is_ok() && engine.adapter("b").is_ok());
    assert!(matches!(engine.adapter("c"), Err(ServeError::UnknownAdapter { .. })));
    engine.register_adapter(mk_set("c", 4)).unwrap();
    engine.shutdown();
    let engine = ServeEngine::builder(model())
        .workers(1)
        .durable_wal(MemFile::over(&bytes), "reboot2")
        .build()
        .unwrap();
    assert!(engine.adapter("c").is_ok(), "post-repair appends must replay");
    engine.shutdown();
}

/// Full filesystem round-trip: a durable engine restarted from its on-disk
/// WAL serves the hot-swapped version, not the original.
#[test]
fn fs_backed_engine_restores_hot_swapped_tenants_across_restart() {
    let dir = std::env::temp_dir().join(format!("cloq_crash_wal_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    {
        let engine = ServeEngine::builder(model()).workers(1).durable(&dir).build().unwrap();
        engine.register_adapter(mk_set("t", 10)).unwrap();
        engine.register_adapter(mk_set("t", 11)).unwrap(); // hot-swap
        engine.register_adapter(mk_set("gone", 12)).unwrap();
        engine.unregister_adapter("gone").unwrap();
        engine.shutdown();
    }
    let engine = ServeEngine::builder(model()).workers(1).durable(&dir).build().unwrap();
    assert!(matches!(engine.adapter("gone"), Err(ServeError::UnknownAdapter { .. })));
    let aid = engine.adapter("t").unwrap();
    let lid = engine.layer("l1").unwrap();
    let mut rng = Rng::new(9200);
    let x = rng.gauss_vec(4);
    let want = model().layers[1].forward(&x, mk_set("t", 11).get("l1"));
    let got = engine.submit(lid, Some(aid), x).wait().unwrap().y;
    for (u, v) in got.iter().zip(&want) {
        assert_eq!(u.to_bits(), v.to_bits(), "restart must serve the SWAPPED version");
    }
    engine.shutdown();
    std::fs::remove_dir_all(&dir).ok();
}

//! Token-level generation parity suite: greedy decode through the
//! PIPELINED engine (`ServeEngine::generate`, every decode step re-entering
//! the sharded batcher) must be **bit-identical — 0 ULP — to the
//! caller-driven serial reference** (`serve::generate_serial`: one fused
//! forward per step, no queues, no concurrency), across quantization
//! methods (CLoQ / GPTQ-LoRA / LoftQ / QLoRA-NF), bit widths {2,3,4,8},
//! mixed-adapter traffic, concurrent sessions, and adapter hot-swaps that
//! land mid-decode. Seeded sampling must be exactly reproducible across
//! worker counts and concurrent load.
//!
//! Why this must hold (the contract chain): a generation is a multi-step
//! session whose step-fn is tokenize → sample → re-embed, all of which is
//! deterministic given (prompt, params, model, adapter version). Each
//! forward is bit-identical to its serial composition (`parity_forward.rs`),
//! and the sampler consumes only the forward's output plus its own seeded
//! RNG — so batch composition, worker count, and neighbour traffic can
//! never change a generation's tokens, text, or final logits.

use cloq::linalg::{syrk_t, Matrix};
use cloq::lowrank::{init_layer, InitConfig, LoraPair, Method};
use cloq::quant::{quantize_nf, quantize_rtn, QuantState};
use cloq::serve::{
    generate_serial, AdapterSet, FinishReason, GenEvent, GenParams, GenRequest, GenResponse,
    PackedLayer, PackedModel, Sampling, ServeEngine,
};
use cloq::util::prng::Rng;

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (u, v)) in a.iter().zip(b).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}: element {k}: {u} vs {v}");
    }
}

/// Full-response parity: everything the decode produced must agree, and
/// the final logits must agree to the bit.
fn assert_gen_eq(got: &GenResponse, want: &GenResponse, what: &str) {
    assert_eq!(got.tokens, want.tokens, "{what}: tokens");
    assert_eq!(got.text, want.text, "{what}: text");
    assert_eq!(got.finish, want.finish, "{what}: finish");
    assert_eq!(got.prompt_tokens, want.prompt_tokens, "{what}: prompt_tokens");
    assert_eq!(got.forwards, want.forwards, "{what}: forwards");
    assert_eq!(got.hops, want.hops, "{what}: hops");
    assert_bits_eq(&got.y, &want.y, &format!("{what}: final logits"));
}

fn names(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

/// The same 4-layer mixed-precision base as `parity_forward.rs`: INT-grid
/// and NF-codebook states at bits {2,3,4,8}, 32 → 20 → 28 → 32 → 32. The
/// tail is 32 wide, so decode samples from a 32-id vocabulary (specials
/// plus the first 28 byte ids) and EOS is organically reachable.
fn mixed_bits_model(seed: u64) -> PackedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for (name, m, n, bits, nf) in [
        ("q2", 32usize, 20usize, 2u32, false),
        ("nf3", 20, 28, 3, true),
        ("q4", 28, 32, 4, false),
        ("q8", 32, 32, 8, false),
    ] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let qs = if nf {
            QuantState::Nf(quantize_nf(&w, bits, 16))
        } else {
            QuantState::Int(quantize_rtn(&w, bits, 8))
        };
        layers.push(PackedLayer::from_state(name, &qs).unwrap());
    }
    PackedModel::new(layers)
}

fn rand_set(id: &str, model: &PackedModel, r: usize, seed: u64) -> AdapterSet {
    let mut rng = Rng::new(seed);
    let mut set = AdapterSet::new(id);
    for l in &model.layers {
        let pair = LoraPair::new(
            Matrix::randn(l.rows, r, 0.1, &mut rng),
            Matrix::randn(l.cols, r, 0.1, &mut rng),
        );
        set.insert(&l.name, pair).unwrap();
    }
    set
}

const ROUTE: [&str; 4] = ["q2", "nf3", "q4", "q8"];

#[test]
fn greedy_decode_bit_identical_to_serial_across_init_methods() {
    // Layers initialized by four different methods, each tenant adapter
    // the one its init actually produced — the end-to-end CLoQ serving
    // shape, now decoded token by token.
    let mut rng = Rng::new(900);
    let mut layers = Vec::new();
    let mut pairs = Vec::new();
    for (name, method, m, n) in [
        ("wq", Method::CLoQ, 24usize, 16usize),
        ("wo", Method::GptqLora, 16, 24),
        ("up", Method::QLora, 24, 12),
        ("dn", Method::LoftQ, 12, 24),
    ] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let x_cal = Matrix::randn(2 * m, m, 1.0, &mut rng);
        let h = syrk_t(&x_cal);
        let mut cfg = InitConfig::new(method, 3, 4);
        cfg.group_size = 8;
        let li = init_layer(&w, Some(&h), &cfg, &mut rng);
        let (layer, pair) = PackedLayer::from_layer_init(name, method, &li).unwrap();
        pairs.push((name.to_string(), pair));
        layers.push(layer);
    }
    let model = PackedModel::new(layers);
    let set = AdapterSet::from_pairs("init", pairs).unwrap();
    let route_names = names(&["wq", "wo", "up", "dn"]);
    let serial_route = model.route(&route_names).unwrap();
    let params = GenParams::greedy(8);
    let serial_ad = generate_serial(&model, &serial_route, Some(&set), "Q: cloq?", &params);
    let serial_base = generate_serial(&model, &serial_route, None, "Q: cloq?", &params);

    let engine = ServeEngine::builder(model).workers(2).max_batch(4).build().unwrap();
    let tenant = engine.register_adapter(set).unwrap().id;
    let route = engine.route(&route_names).unwrap();
    let got_ad = engine
        .generate(GenRequest::with_adapter(route.clone(), tenant, "Q: cloq?", params.clone()))
        .wait()
        .unwrap();
    let got_base =
        engine.generate(GenRequest::new(route, "Q: cloq?", params)).wait().unwrap();
    assert_gen_eq(&got_ad, &serial_ad, "init-method adapter decode");
    assert_gen_eq(&got_base, &serial_base, "init-method base decode");
    engine.shutdown();
}

#[test]
fn token_stream_events_reconstruct_the_final_response() {
    // The per-token stream is not a second code path feeding different
    // data: indexes are dense, pieces concatenate to the final text, and
    // the trailing Done carries the same response the ticket resolves to.
    let model = mixed_bits_model(905);
    let engine = ServeEngine::builder(mixed_bits_model(905)).workers(2).build().unwrap();
    let serial_route = model.route(&names(&ROUTE)).unwrap();
    let params = GenParams::greedy(10);
    let want = generate_serial(&model, &serial_route, None, "stream me", &params);

    let route = engine.route(&names(&ROUTE)).unwrap();
    let ticket = engine.generate(GenRequest::new(route, "stream me", params));
    let mut tokens = Vec::new();
    let mut text = String::new();
    let done = loop {
        match ticket.next_token().wait().unwrap() {
            GenEvent::Token { index, token, piece } => {
                assert_eq!(index, tokens.len(), "token indexes must be dense");
                tokens.push(token);
                text.push_str(&piece);
            }
            GenEvent::Done(resp) => break resp,
        }
    };
    assert_eq!(tokens, done.tokens);
    assert_eq!(text, done.text, "streamed pieces must concatenate to the final text");
    assert_gen_eq(&done, &want, "streamed decode vs serial");
    let resolved = ticket.wait().unwrap();
    assert_gen_eq(&resolved, &done, "ticket result vs Done event");
    engine.shutdown();
}

#[test]
fn concurrent_mixed_adapter_generations_each_match_their_serial() {
    // Three tenants plus base-only decoding at once over one mixed-bits
    // base: every generation must match ITS adapter's serial decode,
    // whatever micro-batches the decode steps coalesced into.
    let model = mixed_bits_model(910);
    let sets: Vec<AdapterSet> =
        (0..3).map(|k| rand_set(&format!("t{k}"), &model, 2 + k, 911 + k as u64)).collect();
    let serial_route = model.route(&names(&ROUTE)).unwrap();
    let prompts: Vec<String> = (0..12).map(|i| format!("Q: item {i}?")).collect();
    let serial: Vec<GenResponse> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let set = if i % 4 == 3 { None } else { Some(&sets[i % 4]) };
            generate_serial(&model, &serial_route, set, p, &GenParams::greedy(6 + i % 3))
        })
        .collect();

    let engine =
        ServeEngine::builder(mixed_bits_model(910)).workers(2).max_batch(8).build().unwrap();
    let tids: Vec<_> =
        sets.into_iter().map(|s| engine.register_adapter(s).unwrap().id).collect();
    let route = engine.route(&names(&ROUTE)).unwrap();
    let tickets: Vec<_> = prompts
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let params = GenParams::greedy(6 + i % 3);
            let req = if i % 4 == 3 {
                GenRequest::new(route.clone(), p, params)
            } else {
                GenRequest::with_adapter(route.clone(), tids[i % 4], p, params)
            };
            engine.generate(req)
        })
        .collect();
    for (k, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_gen_eq(&r, &serial[k], &format!("concurrent generation {k}"));
    }
    let stats = engine.shutdown();
    assert_eq!(stats.model_requests, 12);
    assert_eq!(stats.failed_model_requests, 0);
}

#[test]
fn mid_decode_hot_swap_pins_each_generation_to_its_admitted_version() {
    // A generation admitted BEFORE a hot-swap decodes every step on the
    // old adapter version; one admitted after it decodes on the new one —
    // regardless of where the swap lands between its steps. One worker
    // keeps the pre-swap decode in flight across the swap.
    let model = mixed_bits_model(920);
    let v1 = rand_set("ten", &model, 3, 921);
    let v1_ref = v1.clone(); // serial reference after v1 moves into the registry
    let v2 = rand_set("ten", &model, 5, 922);
    let serial_route = model.route(&names(&ROUTE)).unwrap();
    let params = GenParams::greedy(12);
    let serial_v1 = generate_serial(&model, &serial_route, Some(&v1_ref), "pre swap", &params);
    let serial_v2 = generate_serial(&model, &serial_route, Some(&v2), "post swap", &params);

    let engine =
        ServeEngine::builder(mixed_bits_model(920)).workers(1).max_batch(4).build().unwrap();
    let ten = engine.register_adapter(v1).unwrap().id;
    let route = engine.route(&names(&ROUTE)).unwrap();
    let pre =
        engine.generate(GenRequest::with_adapter(route.clone(), ten, "pre swap", params.clone()));
    let swap = engine.register_adapter(v2).unwrap();
    assert!(swap.replaced, "hot-swap must report replacement");
    assert_eq!(swap.id, ten, "hot-swap keeps the interned AdapterId");
    let post =
        engine.generate(GenRequest::with_adapter(route, ten, "post swap", params));
    assert_gen_eq(&pre.wait().unwrap(), &serial_v1, "decode crossing the hot-swap");
    assert_gen_eq(&post.wait().unwrap(), &serial_v2, "decode admitted after the hot-swap");
    engine.shutdown();
}

#[test]
fn seeded_sampling_is_reproducible_across_workers_and_load() {
    // Temperature and top-k sampling draw from a per-session RNG seeded
    // by the request alone, so the same request must reproduce the same
    // tokens on a 1-worker engine, on a 4-worker engine under concurrent
    // load, and through the serial reference.
    let model = mixed_bits_model(930);
    let serial_route = model.route(&names(&ROUTE)).unwrap();
    for (what, sampling) in [
        ("temperature", Sampling::Temperature { t: 0.8 }),
        ("top-k", Sampling::TopK { k: 8, t: 0.9 }),
    ] {
        let params = GenParams::greedy(10).sampling(sampling).seed(77);
        let want = generate_serial(&model, &serial_route, None, "sample me", &params);

        let quiet = ServeEngine::builder(mixed_bits_model(930)).workers(1).build().unwrap();
        let route = quiet.route(&names(&ROUTE)).unwrap();
        let solo =
            quiet.generate(GenRequest::new(route, "sample me", params.clone())).wait().unwrap();
        quiet.shutdown();

        let busy = ServeEngine::builder(mixed_bits_model(930))
            .workers(4)
            .max_batch(8)
            .build()
            .unwrap();
        let route = busy.route(&names(&ROUTE)).unwrap();
        // Neighbour traffic with different seeds, in flight around the probe.
        let noise: Vec<_> = (0..6)
            .map(|i| {
                let p = GenParams::greedy(8)
                    .sampling(Sampling::Temperature { t: 1.1 })
                    .seed(1000 + i);
                busy.generate(GenRequest::new(route.clone(), "noise", p))
            })
            .collect();
        let probe =
            busy.generate(GenRequest::new(route, "sample me", params)).wait().unwrap();
        for t in noise {
            t.wait().unwrap();
        }
        busy.shutdown();

        assert_gen_eq(&solo, &want, &format!("{what}: quiet engine vs serial"));
        assert_gen_eq(&probe, &want, &format!("{what}: loaded engine vs serial"));
    }
}

#[test]
fn stop_strings_and_max_tokens_agree_with_serial() {
    // Stop handling is part of the decode loop, so it must hit at the
    // same step on both paths. Derive a stop string from the decode's own
    // output to guarantee it fires.
    let model = mixed_bits_model(940);
    let serial_route = model.route(&names(&ROUTE)).unwrap();
    let engine = ServeEngine::builder(mixed_bits_model(940)).workers(2).build().unwrap();
    let route = engine.route(&names(&ROUTE)).unwrap();

    let free = generate_serial(&model, &serial_route, None, "halt?", &GenParams::greedy(8));
    assert!(
        matches!(free.finish, FinishReason::Eos | FinishReason::MaxTokens),
        "{:?}",
        free.finish
    );
    if let Some(ch) = free.text.chars().next() {
        let params = GenParams::greedy(8).stop(&ch.to_string());
        let serial = generate_serial(&model, &serial_route, None, "halt?", &params);
        assert_eq!(serial.finish, FinishReason::Stop);
        let got =
            engine.generate(GenRequest::new(route.clone(), "halt?", params)).wait().unwrap();
        assert_gen_eq(&got, &serial, "stop-string decode");
    }

    // max_tokens = 0 is a degenerate but legal request: prefill only.
    let params = GenParams::greedy(0);
    let serial = generate_serial(&model, &serial_route, None, "empty", &params);
    let got = engine.generate(GenRequest::new(route, "empty", params)).wait().unwrap();
    assert_gen_eq(&got, &serial, "zero-token decode");
    assert_eq!(got.finish, FinishReason::MaxTokens);
    assert!(got.tokens.is_empty());
    engine.shutdown();
}

//! Full-model forward parity suite: the PIPELINED traversal
//! (`ServeEngine::submit_model` / `submit_session`, hops re-entering the
//! batcher's FIFO between layers) must be **bit-identical — 0 ULP — to the
//! caller-driven serial reference** (`serve::forward_route_serial`: one
//! fused `PackedLayer::forward` per route layer), across quantization
//! methods (CLoQ / GPTQ-LoRA / LoftQ / QLoRA-NF), bit widths {2,3,4,8},
//! mixed-adapter traffic, multi-step sessions, and adapter hot-swaps that
//! land mid-flight — all through the typed façade (`Route` handles,
//! interned `AdapterId`s).
//!
//! Why this must hold (the contract chain): every hop is one row of a
//! grouped batch kernel that is itself bit-identical to a serial
//! single-adapter `forward` call (`parity_serve.rs`), and a traversal
//! feeds hop k's output verbatim into hop k+1 — so whatever micro-batches
//! the engine forms, the composition is the exact serial composition.
//! Batch composition, concurrency, and hot-swap timing can never change a
//! model response's numbers.

use cloq::linalg::{syrk_t, Matrix};
use cloq::lowrank::{init_layer, InitConfig, LoraPair, Method};
use cloq::quant::{quantize_nf, quantize_rtn, QuantState};
use cloq::serve::{
    forward_route_serial, AdapterSet, ModelRequest, PackedLayer, PackedModel, ServeEngine,
    ServeError, SessionRequest, StepFn,
};
use cloq::util::prng::Rng;

fn assert_bits_eq(a: &[f64], b: &[f64], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (k, (u, v)) in a.iter().zip(b).enumerate() {
        assert_eq!(u.to_bits(), v.to_bits(), "{what}: element {k}: {u} vs {v}");
    }
}

fn names(v: &[&str]) -> Vec<String> {
    v.iter().map(|s| s.to_string()).collect()
}

/// A chainable 4-layer model mixing INT-grid and NF-codebook states at
/// bits {2,3,4,8}: 32 → 20 → 28 → 32 → 32 (tail matches head, so sessions
/// can loop with a same-length step).
fn mixed_bits_model(seed: u64) -> PackedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for (name, m, n, bits, nf) in [
        ("q2", 32usize, 20usize, 2u32, false),
        ("nf3", 20, 28, 3, true),
        ("q4", 28, 32, 4, false),
        ("q8", 32, 32, 8, false),
    ] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let qs = if nf {
            QuantState::Nf(quantize_nf(&w, bits, 16))
        } else {
            QuantState::Int(quantize_rtn(&w, bits, 8))
        };
        layers.push(PackedLayer::from_state(name, &qs).unwrap());
    }
    PackedModel::new(layers)
}

fn rand_set(id: &str, model: &PackedModel, r: usize, seed: u64) -> AdapterSet {
    let mut rng = Rng::new(seed);
    let mut set = AdapterSet::new(id);
    for l in &model.layers {
        let pair = LoraPair::new(
            Matrix::randn(l.rows, r, 0.1, &mut rng),
            Matrix::randn(l.cols, r, 0.1, &mut rng),
        );
        set.insert(&l.name, pair).unwrap();
    }
    set
}

#[test]
fn pipelined_forward_bit_identical_to_serial_across_init_methods() {
    // Layers initialized by four different methods, chained 24→16→24→12;
    // the tenant's adapters are the ones each init actually produced
    // (PackedLayer::from_layer_init), so this is the end-to-end CLoQ
    // serving shape.
    let mut rng = Rng::new(600);
    let mut layers = Vec::new();
    let mut pairs = Vec::new();
    for (name, method, m, n) in [
        ("wq", Method::CLoQ, 24usize, 16usize),
        ("wo", Method::GptqLora, 16, 24),
        ("up", Method::QLora, 24, 12),
        ("dn", Method::LoftQ, 12, 24),
    ] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let x_cal = Matrix::randn(2 * m, m, 1.0, &mut rng);
        let h = syrk_t(&x_cal);
        let mut cfg = InitConfig::new(method, 3, 4);
        cfg.group_size = 8;
        let li = init_layer(&w, Some(&h), &cfg, &mut rng);
        let (layer, pair) = PackedLayer::from_layer_init(name, method, &li).unwrap();
        pairs.push((name.to_string(), pair));
        layers.push(layer);
    }
    let model = PackedModel::new(layers);
    let set = AdapterSet::from_pairs("init", pairs).unwrap();
    let route_names = names(&["wq", "wo", "up", "dn"]);
    let serial_route = model.route(&route_names).unwrap();

    let mut xrng = Rng::new(601);
    let xs: Vec<Vec<f64>> = (0..10).map(|_| xrng.gauss_vec(24)).collect();
    let serial: Vec<Vec<f64>> = xs
        .iter()
        .map(|x| forward_route_serial(&model, &serial_route, Some(&set), x))
        .collect();
    let serial_base: Vec<Vec<f64>> =
        xs.iter().map(|x| forward_route_serial(&model, &serial_route, None, x)).collect();

    let engine = ServeEngine::builder(model).workers(2).max_batch(4).build().unwrap();
    let tenant = engine.register_adapter(set).unwrap().id;
    let route = engine.route(&route_names).unwrap();
    let tickets: Vec<_> = xs
        .iter()
        .map(|x| engine.submit_model(ModelRequest::with_adapter(route.clone(), tenant, x.clone())))
        .collect();
    let base_tickets: Vec<_> = xs
        .iter()
        .map(|x| engine.submit_model(ModelRequest::new(route.clone(), x.clone())))
        .collect();
    for (k, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_bits_eq(&r.y, &serial[k], &format!("adapter request {k}"));
        assert_eq!(r.forwards, 1);
        assert_eq!(r.hops, 4);
        assert!(r.queue_s >= 0.0 && r.compute_s >= 0.0 && r.wall_s >= 0.0);
    }
    for (k, t) in base_tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_bits_eq(&r.y, &serial_base[k], &format!("base request {k}"));
    }
    let stats = engine.shutdown();
    assert_eq!(stats.model_requests, 20);
    assert_eq!(stats.session_forwards, 20);
    assert_eq!(stats.hops, 80, "20 requests x 4 hops");
    assert!(stats.max_batch_seen >= 2, "concurrent traversals must coalesce: {stats:?}");
}

#[test]
fn concurrent_mixed_adapter_traversals_each_match_their_own_serial() {
    // Three tenants plus base-only over one mixed-bits base, all in
    // flight at once: every response must match ITS adapter's serial
    // composition, whatever batches the hops coalesced into.
    let model = mixed_bits_model(610);
    let sets: Vec<AdapterSet> =
        (0..3).map(|k| rand_set(&format!("t{k}"), &model, 2 + k, 611 + k as u64)).collect();
    let route_names = names(&["q2", "nf3", "q4", "q8"]);
    let serial_route = model.route(&route_names).unwrap();
    let mut xrng = Rng::new(615);
    let xs: Vec<Vec<f64>> = (0..24).map(|_| xrng.gauss_vec(32)).collect();
    let serial: Vec<Vec<f64>> = xs
        .iter()
        .enumerate()
        .map(|(i, x)| {
            let set = if i % 4 == 3 { None } else { Some(&sets[i % 4]) };
            forward_route_serial(&model, &serial_route, set, x)
        })
        .collect();

    let engine =
        ServeEngine::builder(mixed_bits_model(610)).workers(2).max_batch(8).build().unwrap();
    let tids: Vec<_> =
        sets.into_iter().map(|s| engine.register_adapter(s).unwrap().id).collect();
    let route = engine.route(&route_names).unwrap();
    let tickets: Vec<_> = xs
        .iter()
        .enumerate()
        .map(|(i, x)| {
            let req = if i % 4 == 3 {
                ModelRequest::new(route.clone(), x.clone())
            } else {
                ModelRequest::with_adapter(route.clone(), tids[i % 4], x.clone())
            };
            engine.submit_model(req)
        })
        .collect();
    let mut max_batch = 0usize;
    let mut mixed = 0usize;
    for (k, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_bits_eq(&r.y, &serial[k], &format!("request {k}"));
        max_batch = max_batch.max(r.max_batch_seen);
        mixed += r.mixed_hops;
    }
    assert!(max_batch >= 2, "24 concurrent 4-hop traversals must coalesce somewhere");
    assert!(mixed >= 1, "4 tenant groups over one route must mix in some batch");
    let stats = engine.shutdown();
    assert_eq!(stats.model_requests, 24);
    assert_eq!(stats.hops, 96);
    assert_eq!(stats.failed_model_requests, 0);
}

#[test]
fn sessions_bit_identical_to_serial_stepped_reference() {
    // Multi-step sessions (the autoregressive-decode shape): N forwards
    // with a deterministic step between them must equal the hand-stepped
    // serial composition bit-for-bit, per session, with 8 sessions in
    // flight at once.
    let model = mixed_bits_model(620);
    let set = rand_set("gen", &model, 3, 621);
    let route_names = names(&["q2", "nf3", "q4", "q8"]);
    let serial_route = model.route(&route_names).unwrap();
    let steps = 4usize;
    let step_of = |y: &[f64]| -> Vec<f64> { y.iter().map(|v| v * 0.5).collect() };

    let mut xrng = Rng::new(622);
    let x0s: Vec<Vec<f64>> = (0..8).map(|_| xrng.gauss_vec(32)).collect();
    let serial: Vec<Vec<f64>> = x0s
        .iter()
        .map(|x0| {
            let mut x = x0.clone();
            let mut y = Vec::new();
            for _ in 0..steps {
                y = forward_route_serial(&model, &serial_route, Some(&set), &x);
                x = step_of(&y);
            }
            y
        })
        .collect();

    let engine =
        ServeEngine::builder(mixed_bits_model(620)).workers(2).max_batch(8).build().unwrap();
    let tenant = engine.register_adapter(set).unwrap().id;
    let route = engine.route(&route_names).unwrap();
    let tickets: Vec<_> = x0s
        .iter()
        .map(|x0| {
            let step: StepFn = Box::new(move |_, y| Some(y.iter().map(|v| v * 0.5).collect()));
            engine.submit_session(SessionRequest::with_adapter(
                route.clone(),
                tenant,
                x0.clone(),
                steps,
                step,
            ))
        })
        .collect();
    for (k, t) in tickets.into_iter().enumerate() {
        let r = t.wait().unwrap();
        assert_bits_eq(&r.y, &serial[k], &format!("session {k}"));
        assert_eq!(r.forwards, steps);
        assert_eq!(r.hops, steps * 4);
    }
    let stats = engine.shutdown();
    assert_eq!(stats.model_requests, 8);
    assert_eq!(stats.session_forwards, 8 * steps);
    assert_eq!(stats.hops, 8 * steps * 4);
}

#[test]
fn mid_flight_hot_swap_never_mixes_adapter_versions() {
    // Requests admitted BEFORE a hot-swap must compute every hop on the
    // old version (their pin spans the whole traversal), requests after
    // it on the new one — regardless of when the swap lands relative to
    // the hops. The interned AdapterId survives the swap (slots are
    // stable), so the SAME handle is used throughout. One worker keeps
    // plenty of traversal hops in flight across the swap.
    let model = mixed_bits_model(630);
    let v1 = rand_set("ten", &model, 3, 631);
    let v1_ref = v1.clone(); // serial reference after v1 moves into the registry
    let v2 = rand_set("ten", &model, 5, 632);
    let route_names = names(&["q2", "nf3", "q4", "q8"]);
    let serial_route = model.route(&route_names).unwrap();
    let mut xrng = Rng::new(633);
    let xs: Vec<Vec<f64>> = (0..12).map(|_| xrng.gauss_vec(32)).collect();
    let serial_v1: Vec<Vec<f64>> =
        xs.iter().map(|x| forward_route_serial(&model, &serial_route, Some(&v1), x)).collect();
    let serial_v2: Vec<Vec<f64>> =
        xs.iter().map(|x| forward_route_serial(&model, &serial_route, Some(&v2), x)).collect();

    let engine =
        ServeEngine::builder(mixed_bits_model(630)).workers(1).max_batch(4).build().unwrap();
    let ten = engine.register_adapter(v1).unwrap().id;
    let route = engine.route(&route_names).unwrap();
    // A session admitted pre-swap: all 3 of its forwards must use v1.
    let step: StepFn = Box::new(move |_, y| Some(y.iter().map(|v| v * 0.25).collect()));
    let session = engine.submit_session(SessionRequest::with_adapter(
        route.clone(),
        ten,
        xs[0].clone(),
        3,
        step,
    ));
    let pre: Vec<_> = xs
        .iter()
        .take(6)
        .map(|x| engine.submit_model(ModelRequest::with_adapter(route.clone(), ten, x.clone())))
        .collect();
    // Hot-swap while the session and the pre-batch are queued/in flight;
    // the interned id is unchanged.
    let swap = engine.register_adapter(v2).unwrap();
    assert!(swap.replaced);
    assert_eq!(swap.id, ten, "hot-swap keeps the interned AdapterId");
    let post: Vec<_> = xs
        .iter()
        .skip(6)
        .map(|x| engine.submit_model(ModelRequest::with_adapter(route.clone(), ten, x.clone())))
        .collect();
    for (k, t) in pre.into_iter().enumerate() {
        assert_bits_eq(&t.wait().unwrap().y, &serial_v1[k], &format!("pre-swap {k}"));
    }
    for (k, t) in post.into_iter().enumerate() {
        assert_bits_eq(&t.wait().unwrap().y, &serial_v2[k + 6], &format!("post-swap {k}"));
    }
    let sr = session.wait().unwrap();
    let mut x = xs[0].clone();
    let mut y = Vec::new();
    for _ in 0..3 {
        y = forward_route_serial(&model, &serial_route, Some(&v1_ref), &x);
        x = y.iter().map(|v| v * 0.25).collect();
    }
    assert_bits_eq(&sr.y, &y, "session crossing a hot-swap stays on its admitted version");
    engine.shutdown();
}

#[test]
fn partial_adapters_run_base_only_on_uncovered_route_layers() {
    // An adapter covering only part of the route: covered hops apply its
    // delta, uncovered hops are base-only — matching the serial reference
    // built from the same partial set.
    let model = mixed_bits_model(640);
    let mut partial = AdapterSet::new("part");
    {
        let mut rng = Rng::new(641);
        for name in ["nf3", "q8"] {
            let l = model.layer(name).unwrap();
            partial
                .insert(
                    name,
                    LoraPair::new(
                        Matrix::randn(l.rows, 3, 0.1, &mut rng),
                        Matrix::randn(l.cols, 3, 0.1, &mut rng),
                    ),
                )
                .unwrap();
        }
    }
    let route_names = names(&["q2", "nf3", "q4", "q8"]);
    let serial_route = model.route(&route_names).unwrap();
    let x = Rng::new(642).gauss_vec(32);
    let serial = forward_route_serial(&model, &serial_route, Some(&partial), &x);

    let engine = ServeEngine::builder(mixed_bits_model(640)).build().unwrap();
    let part = engine.register_adapter(partial).unwrap().id;
    let route = engine.route(&route_names).unwrap();
    let r = engine.submit_model(ModelRequest::with_adapter(route, part, x)).wait().unwrap();
    assert_bits_eq(&r.y, &serial, "partial-coverage traversal");
    // An adapter with NO route overlap is a typed admission error, not a
    // silent base-only run.
    let mut elsewhere = AdapterSet::new("off-route");
    {
        let mut rng = Rng::new(643);
        let l = engine.model().layer("nf3").unwrap();
        elsewhere
            .insert(
                "nf3",
                LoraPair::new(
                    Matrix::randn(l.rows, 2, 0.1, &mut rng),
                    Matrix::randn(l.cols, 2, 0.1, &mut rng),
                ),
            )
            .unwrap();
    }
    let off = engine.register_adapter(elsewhere).unwrap().id;
    let q8_route = engine.route(&names(&["q8"])).unwrap();
    let err = engine
        .submit_model(ModelRequest::with_adapter(q8_route, off, vec![0.0; 32]))
        .wait()
        .unwrap_err();
    assert!(
        matches!(
            &err,
            ServeError::AdapterMismatch { adapter, layer: None } if adapter == "off-route"
        ),
        "{err:?}"
    );
    assert!(format!("{err}").contains("no delta for any layer on the route"), "{err}");
    engine.shutdown();
}

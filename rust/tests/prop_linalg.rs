//! Seeded random-sweep property tests for the linear-algebra substrate
//! (the offline stand-in for proptest — hundreds of randomized cases per
//! invariant with the failing seed printed on assert).

use cloq::linalg::chol::{chol_inv_upper, cholesky, inv_spd};
use cloq::linalg::eig::sym_eig;
use cloq::linalg::norms::{fro, spectral};
use cloq::linalg::qr::qr;
use cloq::linalg::{
    best_rank_r, matmul, matmul_naive, matmul_nt, matmul_nt_tiled, matmul_tiled, matmul_tn,
    matmul_tn_tiled, pinv, sub_matmul_tn_tail, svd, syrk_t, syrk_t_tiled, Matrix,
};
use cloq::util::prng::Rng;

/// Sweep driver: runs `f(seed, rng)` for many seeds.
fn sweep(cases: usize, f: impl Fn(u64, &mut Rng)) {
    for seed in 0..cases as u64 {
        let mut rng = Rng::new(0xBEEF ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        f(seed, &mut rng);
    }
}

fn rand_dims(rng: &mut Rng, lo: usize, hi: usize) -> (usize, usize) {
    (
        rng.range(lo as i64, hi as i64) as usize,
        rng.range(lo as i64, hi as i64) as usize,
    )
}

#[test]
fn matmul_is_associative_and_distributive() {
    sweep(60, |seed, rng| {
        let (m, k) = rand_dims(rng, 1, 20);
        let (n, p) = rand_dims(rng, 1, 20);
        let a = Matrix::randn(m, k, 1.0, rng);
        let b = Matrix::randn(k, n, 1.0, rng);
        let c = Matrix::randn(n, p, 1.0, rng);
        // (AB)C == A(BC)
        let left = matmul(&matmul(&a, &b), &c);
        let right = matmul(&a, &matmul(&b, &c));
        assert!(left.max_diff(&right) < 1e-8 * (k * n) as f64, "assoc seed={seed}");
        // A(B + B') == AB + AB'
        let b2 = Matrix::randn(k, n, 1.0, rng);
        let d1 = matmul(&a, &b.add(&b2));
        let d2 = matmul(&a, &b).add(&matmul(&a, &b2));
        assert!(d1.max_diff(&d2) < 1e-9 * k as f64, "distrib seed={seed}");
    });
}

#[test]
fn transpose_products_consistent() {
    sweep(60, |seed, rng| {
        let (m, k) = rand_dims(rng, 1, 24);
        let n = rng.range(1, 24) as usize;
        let a = Matrix::randn(k, m, 1.0, rng);
        let b = Matrix::randn(k, n, 1.0, rng);
        assert!(
            matmul_tn(&a, &b).max_diff(&matmul(&a.transpose(), &b)) < 1e-9 * k as f64,
            "tn seed={seed}"
        );
        let c = Matrix::randn(n, m, 1.0, rng);
        let at = a.transpose(); // m? a is k×m; at is m×k... use fresh shapes
        let _ = at;
        let d = Matrix::randn(5, m, 1.0, rng);
        assert!(
            matmul_nt(&d, &c.transpose().transpose()).max_diff(&matmul(&d, &c.transpose()))
                < 1e-9 * m as f64,
            "nt seed={seed}"
        );
    });
}

#[test]
fn tiled_matmul_agrees_with_naive_on_random_shapes() {
    // Random rectangular shapes, including 0- and 1-sized dimensions (the
    // degenerate cases the tile loops must step over cleanly).
    sweep(60, |seed, rng| {
        let m = rng.range(0, 40) as usize;
        let k = rng.range(0, 40) as usize;
        let n = rng.range(0, 40) as usize;
        let a = Matrix::randn(m, k, 1.0, rng);
        let b = Matrix::randn(k, n, 1.0, rng);
        let naive = matmul_naive(&a, &b);
        assert!(matmul_tiled(&a, &b).max_diff(&naive) < 1e-10, "tiled seed={seed} {m}x{k}x{n}");
        assert!(matmul(&a, &b).max_diff(&naive) < 1e-10, "dispatch seed={seed} {m}x{k}x{n}");
    });
}

#[test]
fn tiled_matmul_agrees_on_tile_boundaries() {
    // Deterministic shapes straddling every tile edge ±1 (MC=64, KC=256,
    // NC=512 in blas.rs) plus 1-dim degenerates.
    let mut rng = Rng::new(0x71_1E);
    let shapes: &[(usize, usize, usize)] = &[
        (1, 1, 1),
        (1, 513, 1),
        (63, 64, 65),
        (64, 64, 64),
        (65, 63, 64),
        (63, 255, 513),
        (64, 256, 512),
        (65, 257, 511),
        (128, 2, 512),
        (2, 300, 2),
    ];
    for &(m, k, n) in shapes {
        let a = Matrix::randn(m, k, 1.0, &mut rng);
        let b = Matrix::randn(k, n, 1.0, &mut rng);
        let naive = matmul_naive(&a, &b);
        // The tiled kernels keep per-element ascending-k accumulation, so
        // agreement is exact, not just within tolerance.
        assert_eq!(matmul_tiled(&a, &b).data, naive.data, "tiled {m}x{k}x{n}");
        assert_eq!(matmul(&a, &b).data, naive.data, "dispatch {m}x{k}x{n}");
    }
}

#[test]
fn tiled_transposed_variants_agree_with_references() {
    sweep(30, |seed, rng| {
        let k = rng.range(1, 80) as usize;
        let m = rng.range(1, 80) as usize;
        let n = rng.range(1, 50) as usize;
        let a = Matrix::randn(k, m, 1.0, rng);
        let b = Matrix::randn(k, n, 1.0, rng);
        let tn_ref = matmul(&a.transpose(), &b);
        assert!(matmul_tn_tiled(&a, &b).max_diff(&tn_ref) < 1e-9 * k as f64, "tn seed={seed}");
        assert!(matmul_tn(&a, &b).max_diff(&tn_ref) < 1e-9 * k as f64, "tn dispatch seed={seed}");
        let c = Matrix::randn(n, m, 1.0, rng);
        let d = Matrix::randn(k, m, 1.0, rng);
        let nt_ref = matmul(&d, &c.transpose());
        assert!(matmul_nt_tiled(&d, &c).max_diff(&nt_ref) < 1e-9 * m as f64, "nt seed={seed}");
        assert!(matmul_nt(&d, &c).max_diff(&nt_ref) < 1e-9 * m as f64, "nt dispatch seed={seed}");
    });
}

#[test]
fn tiled_syrk_agrees_with_gram() {
    sweep(25, |seed, rng| {
        let k = rng.range(1, 60) as usize;
        let n = rng.range(1, 90) as usize;
        let a = Matrix::randn(k, n, 1.0, rng);
        let expect = matmul(&a.transpose(), &a);
        assert!(syrk_t_tiled(&a).max_diff(&expect) < 1e-9 * k as f64, "tiled seed={seed}");
        assert!(syrk_t(&a).max_diff(&expect) < 1e-9 * k as f64, "dispatch seed={seed}");
    });
}

#[test]
fn panel_update_equals_sequential_rank1() {
    // The OPTQ lazy-batch kernel: C_tail -= A_panelᵀ·B must equal applying
    // the rank-1 updates one row at a time — exactly (same FP op order).
    sweep(30, |seed, rng| {
        let m = rng.range(2, 40) as usize;
        let n = rng.range(1, 12) as usize;
        let t0 = rng.range(0, m as i64 - 1) as usize;
        let nt = rng.range(1, (m - t0) as i64) as usize;
        let row0 = rng.range(0, m as i64) as usize;
        let u = Matrix::randn(m, m, 1.0, rng);
        let errs = Matrix::randn(nt, n, 1.0, rng);
        let w0 = Matrix::randn(m, n, 1.0, rng);

        let mut seq = w0.clone();
        for t in 0..nt {
            for k in row0..m {
                let utk = u.at(t0 + t, k);
                for j in 0..n {
                    *seq.at_mut(k, j) -= utk * errs.at(t, j);
                }
            }
        }
        let mut got = w0.clone();
        sub_matmul_tn_tail(&mut got, row0, &u, t0, nt, &errs);
        assert_eq!(got.data, seq.data, "seed={seed} m={m} t0={t0} nt={nt} row0={row0}");
    });
}

#[test]
fn chol_inv_upper_is_inverse_hessian_root() {
    // The OPTQ setup kernel: UᵀU == H⁻¹ (against the explicit-inverse
    // route) across random SPD matrices.
    sweep(25, |seed, rng| {
        let n = rng.range(1, 28) as usize;
        let x = Matrix::randn(n + 6, n, 1.0, rng);
        let mut h = syrk_t(&x);
        h.add_diag(0.05);
        let u = chol_inv_upper(&h).unwrap();
        let seed_route = cholesky(&inv_spd(&h).unwrap()).unwrap().transpose();
        assert!(
            u.max_diff(&seed_route) < 1e-6 * u.max_abs().max(1.0),
            "root seed={seed} n={n}"
        );
    });
}

#[test]
fn svd_reconstructs_arbitrary_shapes() {
    sweep(50, |seed, rng| {
        let (m, n) = rand_dims(rng, 1, 28);
        let a = Matrix::randn(m, n, rng.range_f64(0.1, 3.0), rng);
        let d = svd(&a);
        assert!(
            a.max_diff(&d.reconstruct()) < 1e-7 * (m.max(n) as f64),
            "recon seed={seed} ({m}x{n})"
        );
        // Spectral norm == top singular value.
        let s = spectral(&a);
        assert!((s - d.s[0]).abs() < 1e-5 * d.s[0].max(1e-12), "spec seed={seed}");
        // Frobenius² == Σσ².
        let f2 = fro(&a).powi(2);
        let s2: f64 = d.s.iter().map(|x| x * x).sum();
        assert!((f2 - s2).abs() < 1e-7 * f2.max(1e-12), "fro seed={seed}");
    });
}

#[test]
fn eckart_young_dominates_random_candidates() {
    sweep(30, |seed, rng| {
        let (m, n) = rand_dims(rng, 2, 16);
        let a = Matrix::randn(m, n, 1.0, rng);
        let r = rng.range(1, m.min(n) as i64) as usize;
        let opt = best_rank_r(&a, r);
        let e_opt = fro(&a.sub(&opt)).powi(2);
        for _ in 0..10 {
            let p = Matrix::randn(m, r, 1.0, rng);
            let q = Matrix::randn(r, n, 1.0, rng);
            let e = fro(&a.sub(&matmul(&p, &q))).powi(2);
            assert!(e_opt <= e + 1e-9, "seed={seed} r={r}");
        }
    });
}

#[test]
fn cholesky_solve_and_inverse_agree() {
    sweep(40, |seed, rng| {
        let n = rng.range(1, 24) as usize;
        let x = Matrix::randn(n + 4, n, 1.0, rng);
        let mut h = syrk_t(&x);
        h.add_diag(0.05);
        let l = cholesky(&h).unwrap();
        assert!(h.max_diff(&matmul_nt(&l, &l)) < 1e-8 * h.max_abs(), "chol seed={seed}");
        let inv = inv_spd(&h).unwrap();
        assert!(
            matmul(&h, &inv).max_diff(&Matrix::eye(n)) < 1e-6,
            "inv seed={seed} n={n}"
        );
    });
}

#[test]
fn sym_eig_invariants() {
    sweep(40, |seed, rng| {
        let n = rng.range(1, 24) as usize;
        let samples = rng.range(1, 32) as usize; // sometimes rank-deficient
        let x = Matrix::randn(samples, n, 1.0, rng);
        let h = syrk_t(&x);
        let e = sym_eig(&h);
        // Orthonormal vectors, PSD values, trace preserved.
        let vtv = matmul(&e.vectors.transpose(), &e.vectors);
        assert!(vtv.max_diff(&Matrix::eye(n)) < 1e-7, "orth seed={seed}");
        let floor = -1e-7 * e.values[0].abs().max(1.0);
        assert!(e.values.iter().all(|&l| l > floor), "psd seed={seed}");
        let tr: f64 = e.values.iter().sum();
        assert!((tr - h.trace()).abs() < 1e-6 * h.trace().abs().max(1.0), "trace seed={seed}");
        // Rank bound: at most `samples` nonzero eigenvalues.
        let nonzero = e.values.iter().filter(|&&l| l > 1e-8 * e.values[0].max(1.0)).count();
        assert!(nonzero <= samples.min(n), "rank seed={seed}: {nonzero} > {samples}");
    });
}

#[test]
fn pinv_solves_least_squares() {
    sweep(30, |seed, rng| {
        let (mut m, mut n) = rand_dims(rng, 2, 16);
        if m < n {
            std::mem::swap(&mut m, &mut n);
        }
        let a = Matrix::randn(m, n, 1.0, rng);
        let ap = pinv(&a, 1e-12);
        // x = A⁺b minimizes ‖Ax − b‖: check the normal equations AᵀAx = Aᵀb.
        let b = Matrix::randn(m, 1, 1.0, rng);
        let x = matmul(&ap, &b);
        let lhs = matmul(&syrk_t(&a), &x);
        let rhs = matmul_tn(&a, &b);
        assert!(lhs.max_diff(&rhs) < 1e-6 * (m as f64), "normaleq seed={seed}");
    });
}

#[test]
fn qr_orthonormality_random_sweep() {
    sweep(40, |seed, rng| {
        let n = rng.range(1, 20) as usize;
        let m = n + rng.range(0, 12) as usize;
        let a = Matrix::randn(m, n, 1.0, rng);
        let d = qr(&a);
        assert!(a.max_diff(&matmul(&d.q, &d.r)) < 1e-8, "qr recon seed={seed}");
        let qtq = matmul(&d.q.transpose(), &d.q);
        assert!(qtq.max_diff(&Matrix::eye(n)) < 1e-8, "qr orth seed={seed}");
    });
}

//! Seeded random-sweep property tests for the linear-algebra substrate
//! (the offline stand-in for proptest — hundreds of randomized cases per
//! invariant with the failing seed printed on assert).

use cloq::linalg::chol::{cholesky, inv_spd};
use cloq::linalg::eig::sym_eig;
use cloq::linalg::norms::{fro, spectral};
use cloq::linalg::qr::qr;
use cloq::linalg::{best_rank_r, matmul, matmul_nt, matmul_tn, pinv, svd, syrk_t, Matrix};
use cloq::util::prng::Rng;

/// Sweep driver: runs `f(seed, rng)` for many seeds.
fn sweep(cases: usize, f: impl Fn(u64, &mut Rng)) {
    for seed in 0..cases as u64 {
        let mut rng = Rng::new(0xBEEF ^ seed.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        f(seed, &mut rng);
    }
}

fn rand_dims(rng: &mut Rng, lo: usize, hi: usize) -> (usize, usize) {
    (
        rng.range(lo as i64, hi as i64) as usize,
        rng.range(lo as i64, hi as i64) as usize,
    )
}

#[test]
fn matmul_is_associative_and_distributive() {
    sweep(60, |seed, rng| {
        let (m, k) = rand_dims(rng, 1, 20);
        let (n, p) = rand_dims(rng, 1, 20);
        let a = Matrix::randn(m, k, 1.0, rng);
        let b = Matrix::randn(k, n, 1.0, rng);
        let c = Matrix::randn(n, p, 1.0, rng);
        // (AB)C == A(BC)
        let left = matmul(&matmul(&a, &b), &c);
        let right = matmul(&a, &matmul(&b, &c));
        assert!(left.max_diff(&right) < 1e-8 * (k * n) as f64, "assoc seed={seed}");
        // A(B + B') == AB + AB'
        let b2 = Matrix::randn(k, n, 1.0, rng);
        let d1 = matmul(&a, &b.add(&b2));
        let d2 = matmul(&a, &b).add(&matmul(&a, &b2));
        assert!(d1.max_diff(&d2) < 1e-9 * k as f64, "distrib seed={seed}");
    });
}

#[test]
fn transpose_products_consistent() {
    sweep(60, |seed, rng| {
        let (m, k) = rand_dims(rng, 1, 24);
        let n = rng.range(1, 24) as usize;
        let a = Matrix::randn(k, m, 1.0, rng);
        let b = Matrix::randn(k, n, 1.0, rng);
        assert!(
            matmul_tn(&a, &b).max_diff(&matmul(&a.transpose(), &b)) < 1e-9 * k as f64,
            "tn seed={seed}"
        );
        let c = Matrix::randn(n, m, 1.0, rng);
        let at = a.transpose(); // m? a is k×m; at is m×k... use fresh shapes
        let _ = at;
        let d = Matrix::randn(5, m, 1.0, rng);
        assert!(
            matmul_nt(&d, &c.transpose().transpose()).max_diff(&matmul(&d, &c.transpose()))
                < 1e-9 * m as f64,
            "nt seed={seed}"
        );
    });
}

#[test]
fn svd_reconstructs_arbitrary_shapes() {
    sweep(50, |seed, rng| {
        let (m, n) = rand_dims(rng, 1, 28);
        let a = Matrix::randn(m, n, rng.range_f64(0.1, 3.0), rng);
        let d = svd(&a);
        assert!(
            a.max_diff(&d.reconstruct()) < 1e-7 * (m.max(n) as f64),
            "recon seed={seed} ({m}x{n})"
        );
        // Spectral norm == top singular value.
        let s = spectral(&a);
        assert!((s - d.s[0]).abs() < 1e-5 * d.s[0].max(1e-12), "spec seed={seed}");
        // Frobenius² == Σσ².
        let f2 = fro(&a).powi(2);
        let s2: f64 = d.s.iter().map(|x| x * x).sum();
        assert!((f2 - s2).abs() < 1e-7 * f2.max(1e-12), "fro seed={seed}");
    });
}

#[test]
fn eckart_young_dominates_random_candidates() {
    sweep(30, |seed, rng| {
        let (m, n) = rand_dims(rng, 2, 16);
        let a = Matrix::randn(m, n, 1.0, rng);
        let r = rng.range(1, m.min(n) as i64) as usize;
        let opt = best_rank_r(&a, r);
        let e_opt = fro(&a.sub(&opt)).powi(2);
        for _ in 0..10 {
            let p = Matrix::randn(m, r, 1.0, rng);
            let q = Matrix::randn(r, n, 1.0, rng);
            let e = fro(&a.sub(&matmul(&p, &q))).powi(2);
            assert!(e_opt <= e + 1e-9, "seed={seed} r={r}");
        }
    });
}

#[test]
fn cholesky_solve_and_inverse_agree() {
    sweep(40, |seed, rng| {
        let n = rng.range(1, 24) as usize;
        let x = Matrix::randn(n + 4, n, 1.0, rng);
        let mut h = syrk_t(&x);
        h.add_diag(0.05);
        let l = cholesky(&h).unwrap();
        assert!(h.max_diff(&matmul_nt(&l, &l)) < 1e-8 * h.max_abs(), "chol seed={seed}");
        let inv = inv_spd(&h).unwrap();
        assert!(
            matmul(&h, &inv).max_diff(&Matrix::eye(n)) < 1e-6,
            "inv seed={seed} n={n}"
        );
    });
}

#[test]
fn sym_eig_invariants() {
    sweep(40, |seed, rng| {
        let n = rng.range(1, 24) as usize;
        let samples = rng.range(1, 32) as usize; // sometimes rank-deficient
        let x = Matrix::randn(samples, n, 1.0, rng);
        let h = syrk_t(&x);
        let e = sym_eig(&h);
        // Orthonormal vectors, PSD values, trace preserved.
        let vtv = matmul(&e.vectors.transpose(), &e.vectors);
        assert!(vtv.max_diff(&Matrix::eye(n)) < 1e-7, "orth seed={seed}");
        assert!(e.values.iter().all(|&l| l > -1e-7 * e.values[0].abs().max(1.0)), "psd seed={seed}");
        let tr: f64 = e.values.iter().sum();
        assert!((tr - h.trace()).abs() < 1e-6 * h.trace().abs().max(1.0), "trace seed={seed}");
        // Rank bound: at most `samples` nonzero eigenvalues.
        let nonzero = e.values.iter().filter(|&&l| l > 1e-8 * e.values[0].max(1.0)).count();
        assert!(nonzero <= samples.min(n), "rank seed={seed}: {nonzero} > {samples}");
    });
}

#[test]
fn pinv_solves_least_squares() {
    sweep(30, |seed, rng| {
        let (mut m, mut n) = rand_dims(rng, 2, 16);
        if m < n {
            std::mem::swap(&mut m, &mut n);
        }
        let a = Matrix::randn(m, n, 1.0, rng);
        let ap = pinv(&a, 1e-12);
        // x = A⁺b minimizes ‖Ax − b‖: check the normal equations AᵀAx = Aᵀb.
        let b = Matrix::randn(m, 1, 1.0, rng);
        let x = matmul(&ap, &b);
        let lhs = matmul(&syrk_t(&a), &x);
        let rhs = matmul_tn(&a, &b);
        assert!(lhs.max_diff(&rhs) < 1e-6 * (m as f64), "normaleq seed={seed}");
    });
}

#[test]
fn qr_orthonormality_random_sweep() {
    sweep(40, |seed, rng| {
        let n = rng.range(1, 20) as usize;
        let m = n + rng.range(0, 12) as usize;
        let a = Matrix::randn(m, n, 1.0, rng);
        let d = qr(&a);
        assert!(a.max_diff(&matmul(&d.q, &d.r)) < 1e-8, "qr recon seed={seed}");
        let qtq = matmul(&d.q.transpose(), &d.q);
        assert!(qtq.max_diff(&Matrix::eye(n)) < 1e-8, "qr orth seed={seed}");
    });
}

//! Random-sweep property tests for the quantization substrate.

use cloq::linalg::{syrk_t, Matrix};
use cloq::quant::grid::{find_params, quantize_rtn, quantize_value};
use cloq::quant::metrics::calibrated_error2;
use cloq::quant::nf::{nf_levels, quantize_nf};
use cloq::quant::optq::{optq, OptqConfig};
use cloq::quant::packing::{pack_codes, unpack_codes};
use cloq::util::prng::Rng;

fn sweep(cases: usize, f: impl Fn(u64, &mut Rng)) {
    for seed in 0..cases as u64 {
        let mut rng = Rng::new(0xFACE ^ seed.wrapping_mul(0x2545_F491_4F6C_DD1D));
        f(seed, &mut rng);
    }
}

#[test]
fn rtn_codes_in_range_and_error_bounded() {
    sweep(60, |seed, rng| {
        let m = rng.range(1, 64) as usize;
        let n = rng.range(1, 12) as usize;
        let gs = [4usize, 8, 16, 64][rng.below(4)];
        let bits = [2u32, 3, 4, 8][rng.below(4)];
        let scale = rng.range_f64(1e-3, 10.0);
        let w = Matrix::randn(m, n, scale, rng);
        let q = quantize_rtn(&w, bits, gs);
        let qmax = (1u32 << bits) - 1;
        assert!(q.codes.iter().all(|&c| (c as u32) <= qmax), "range seed={seed}");
        let deq = q.dequantize();
        for i in 0..m {
            let g = q.group_of_row(i);
            for j in 0..n {
                assert!(
                    (w.at(i, j) - deq.at(i, j)).abs() <= q.scales.at(g, j) + 1e-9,
                    "halfstep seed={seed} bits={bits}"
                );
            }
        }
    });
}

#[test]
fn rtn_scale_equivariance() {
    // quantize(c·W) == c·quantize(W) for c > 0 (same codes).
    sweep(40, |seed, rng| {
        let w = Matrix::randn(24, 6, 1.0, rng);
        let c = rng.range_f64(0.1, 8.0);
        let q1 = quantize_rtn(&w, 3, 8);
        let q2 = quantize_rtn(&w.scale(c), 3, 8);
        assert_eq!(q1.codes, q2.codes, "codes seed={seed} c={c}");
        assert!(
            q1.dequantize().scale(c).max_diff(&q2.dequantize()) < 1e-9 * c,
            "deq seed={seed}"
        );
    });
}

#[test]
fn grid_contains_zero() {
    // Zero must always be exactly representable (padding correctness).
    sweep(40, |seed, rng| {
        let vals: Vec<f64> = (0..16).map(|_| rng.normal(3.0, 1.0)).collect(); // all-positive-ish
        for bits in [2u32, 4] {
            let p = find_params(&vals, bits);
            let (_, dq) = quantize_value(0.0, p, bits);
            assert!(dq.abs() < 1e-12, "zero seed={seed} bits={bits} dq={dq}");
        }
    });
}

#[test]
fn nf_levels_monotone_and_bounded() {
    for bits in [2u32, 3, 4] {
        let l = nf_levels(bits);
        assert_eq!(l.len(), 1 << bits);
        assert!(l.windows(2).all(|w| w[0] < w[1]));
        assert!(l[0] == -1.0 && *l.last().unwrap() == 1.0);
        assert!(l.contains(&0.0));
    }
}

#[test]
fn nf_error_bounded_by_max_gap() {
    sweep(40, |seed, rng| {
        let w = Matrix::randn(32, 4, rng.range_f64(0.01, 5.0), rng);
        let bits = [2u32, 3, 4][rng.below(3)];
        let q = quantize_nf(&w, bits, 16);
        let levels = nf_levels(bits);
        let max_gap = levels.windows(2).map(|p| p[1] - p[0]).fold(0.0f64, f64::max);
        let deq = q.dequantize();
        for i in 0..32 {
            let b = i / 16;
            for j in 0..4 {
                let bound = 0.5 * max_gap * q.absmax.at(b, j) + 1e-9;
                assert!(
                    (w.at(i, j) - deq.at(i, j)).abs() <= bound,
                    "seed={seed} bits={bits}"
                );
            }
        }
    });
}

#[test]
fn optq_never_worse_than_rtn_on_calibration() {
    sweep(15, |seed, rng| {
        let m = rng.range(8, 40) as usize;
        let n = rng.range(2, 12) as usize;
        let samples = m * 4;
        let base = Matrix::randn(samples, (m / 2).max(1), 1.0, rng);
        let mix = Matrix::randn((m / 2).max(1), m, 1.0, rng);
        let x = cloq::linalg::matmul(&base, &mix);
        let w = Matrix::randn(m, n, 0.5, rng);
        let h = syrk_t(&x);
        let bits = [2u32, 3, 4][rng.below(3)];
        let gs = m; // per-channel
        let q = optq(&w, &h, &OptqConfig { bits, group_size: gs, ..Default::default() });
        let e_optq = calibrated_error2(&h, &w.sub(&q.dequantize()));
        let e_rtn = calibrated_error2(&h, &w.sub(&quantize_rtn(&w, bits, gs).dequantize()));
        assert!(
            e_optq <= e_rtn * 1.02 + 1e-9,
            "seed={seed} bits={bits}: optq {e_optq} rtn {e_rtn}"
        );
    });
}

#[test]
fn packing_roundtrip_random() {
    sweep(60, |seed, rng| {
        let bits = rng.range(1, 8) as u32;
        let n = rng.range(0, 500) as usize;
        let codes: Vec<u8> = (0..n).map(|_| rng.below(1usize << bits) as u8).collect();
        let packed = pack_codes(&codes, bits);
        assert_eq!(unpack_codes(&packed, bits, n), codes, "seed={seed} bits={bits} n={n}");
        // Compactness: within one word of optimal.
        let per_word = 32 / bits as usize;
        assert!(packed.len() <= n / per_word + 1);
    });
}

//! Offline stub of the `xla` PJRT bindings.
//!
//! The sandbox vendors no PJRT C-API closure, so this crate provides just
//! enough type surface for `cloq::runtime` to compile. Every path that
//! would touch a device errors at [`PjRtClient::cpu`] with a clear message;
//! host-only code (tensors, manifests, quantization, the whole numerics
//! stack) is unaffected, and artifact-dependent tests/benches skip when
//! `artifacts/` is absent, before ever constructing a client.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla/PJRT backend unavailable in this offline build (stub crate): {what}"
    )))
}

/// Host literal placeholder (carries no data in the stub).
#[derive(Debug, Clone)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: Copy>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("Literal::to_tuple")
    }
}

/// Device buffer handle returned by an execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }
}

pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_errors_clearly() {
        let err = PjRtClient::cpu().err().unwrap();
        assert!(format!("{err}").contains("offline"));
    }
}

//! Minimal offline stand-in for the `anyhow` crate.
//!
//! The sandbox has no crates.io access, so this shim provides exactly the
//! surface the workspace uses: [`Result`], [`Error`], and the `anyhow!` /
//! `bail!` / `ensure!` macros. Like real anyhow, [`Error`] deliberately does
//! NOT implement `std::error::Error` so the blanket `From<E: Error>` impl
//! can coexist with the reflexive `From<Error>`.

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error value. Context is baked into the message at
/// construction (the shim has no cause chain; `{:#}` prints the same text
/// as `{}`).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything displayable.
    pub fn msg<M: std::fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string() }
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::fmt::Debug for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        Error::msg(&e)
    }
}

/// Construct an [`Error`] from a format string (or any displayable value).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!("condition failed: `{}`", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    fn io_fail() -> crate::Result<()> {
        std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(())
    }

    fn ensured(x: i32) -> crate::Result<i32> {
        crate::ensure!(x > 0, "x must be positive, got {x}");
        if x > 100 {
            crate::bail!("x too large: {}", x);
        }
        Ok(x)
    }

    #[test]
    fn conversions_and_macros() {
        assert!(io_fail().is_err());
        assert_eq!(ensured(5).unwrap(), 5);
        let e = ensured(-1).unwrap_err();
        assert!(format!("{e}").contains("positive"));
        assert!(format!("{e:#}").contains("positive"));
        assert!(ensured(200).is_err());
        let direct = crate::anyhow!("plain");
        assert_eq!(format!("{direct:?}"), "plain");
    }
}

//! Artifact + durability benchmarks — the numbers behind EXPERIMENTS.md
//! §Durability, emitted as BENCH_artifact.json:
//!
//! 1. **cold start**: time from "file on disk" to "PackedModel in hand"
//!    for the zero-copy v3 path (`open_mapped`: directory + params only,
//!    code sections served from mapped pages with their CRC deferred to
//!    first touch) vs the eager v2 path (`load_base`: full read, every
//!    byte CRC-checked and copied), at several base sizes. The v3 win is
//!    the headline of the format: cold start stops paying for the bytes
//!    it has not touched yet.
//! 2. **WAL replay**: boot-time recovery rate — decode a
//!    register/hot-swap/unregister history from a CLOQWAL1 log and apply
//!    it to a fresh registry, in events/s vs history length. This is the
//!    exact work a durable engine does in `build()` before serving.
//! 3. **WAL group commit**: durable register throughput, one thread vs
//!    many. Registration appends under the WAL lock but fsyncs OUTSIDE
//!    it (`Wal::commit_through`), so concurrent registers that appended
//!    while an fsync was in flight ride that fsync instead of issuing
//!    their own — visible as `fsyncs_per_op` dropping below 1 (counted by
//!    engine telemetry, `Counter::WalFsyncs`) while registers/s rises.
//!
//! Under `CLOQ_BENCH_SMOKE=1` (the CI bench-smoke job) shapes and counts
//! shrink and the record carries `"smoke": true` so `scripts/bench_diff.py`
//! only compares like against like.
//!
//! Correctness is NOT measured here: mapped-vs-eager bit parity and the
//! single-bit corruption sweep live in `rust/tests/golden_serve.rs`;
//! crash-recovery semantics in `rust/tests/crash_wal.rs`.

use std::sync::Arc;
use std::time::Instant;

use cloq::bench::{bench, section, smoke, smoke_scaled, target_time, write_bench_json};
use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    AdapterRegistry, AdapterSet, Artifact, ArtifactStore, Counter, FsWalFile, PackedLayer,
    PackedModel, ServeEngine, Wal, WalEvent, WalOptions,
};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

fn mk_model(layers: usize, n: usize, seed: u64) -> PackedModel {
    let mut rng = Rng::new(seed);
    let ls = (0..layers)
        .map(|i| {
            let w = Matrix::randn(n, n, 0.3, &mut rng);
            PackedLayer::from_state(&format!("l{i}"), &QuantState::Int(quantize_rtn(&w, 4, 64)))
                .unwrap()
        })
        .collect();
    PackedModel::new(ls)
}

fn mk_set(id: &str, n: usize, rng: &mut Rng) -> AdapterSet {
    let pair = LoraPair::new(Matrix::randn(n, 2, 0.1, rng), Matrix::randn(n, 2, 0.1, rng));
    AdapterSet::from_pairs(id, vec![("l0".to_string(), pair)]).unwrap()
}

fn main() {
    let t = target_time(0.3);
    let dir = std::env::temp_dir().join(format!("cloq_bench_artifact_{}", std::process::id()));
    let st = ArtifactStore::at(&dir);

    // ---- 1. cold start: mmap v3 vs copy v2 --------------------------------
    section("cold start: zero-copy v3 open_mapped vs eager v2 load_base");
    let sizes: Vec<(usize, usize)> =
        if smoke() { vec![(2, 128), (4, 192)] } else { vec![(4, 256), (8, 512), (16, 768)] };
    let mut cold_rows = Vec::new();
    let mut worst_speedup = f64::INFINITY;
    for &(layers, n) in &sizes {
        let model = mk_model(layers, n, 40 + n as u64);
        let v2 = format!("base_{layers}x{n}.cloqpkd2");
        let v3 = format!("base_{layers}x{n}.cloqpkd3");
        st.save_base(&model, &v2).unwrap();
        let v3path = st.save_base_v3(&model, &v3).unwrap();
        let bytes = std::fs::metadata(&v3path).unwrap().len() as usize;
        let r_v2 = bench(&format!("v2 copy  {layers}x{n}x{n}"), t, || {
            st.load_base(&v2).unwrap().layers.len()
        });
        let r_v3 = bench(&format!("v3 mmap  {layers}x{n}x{n}"), t, || {
            match st.open_mapped(&v3).unwrap() {
                Artifact::Base(m) => m.layers.len(),
                _ => unreachable!("a v3 base opened as something else"),
            }
        });
        let speedup = r_v2.min_s / r_v3.min_s.max(1e-12);
        worst_speedup = worst_speedup.min(speedup);
        println!(
            "cold start {layers}x{n}x{n} ({:.1} MiB): v2 {:.2}ms, v3 {:.2}ms → {speedup:.1}x",
            bytes as f64 / (1 << 20) as f64,
            r_v2.min_s * 1e3,
            r_v3.min_s * 1e3
        );
        let mut row = Json::obj();
        row.set("layers", Json::from(layers));
        row.set("n", Json::from(n));
        row.set("bytes", Json::from(bytes));
        row.set("v2_open_s", Json::from(r_v2.min_s));
        row.set("v3_open_s", Json::from(r_v3.min_s));
        row.set("speedup_v3_vs_v2", Json::from(speedup));
        row.set("v2", r_v2.to_json());
        row.set("v3", r_v3.to_json());
        cold_rows.push(row);
    }

    // ---- 2. WAL replay rate ----------------------------------------------
    section("WAL replay: boot-time recovery rate vs history length");
    let event_counts: Vec<usize> = if smoke() { vec![64] } else { vec![256, 1024] };
    // Compaction off while BUILDING the history so the log keeps every
    // event; replay must decode the whole thing.
    let opts = WalOptions {
        sync_every: 1024,
        compact_min_bytes: usize::MAX,
        compact_ratio: usize::MAX,
    };
    let wn = smoke_scaled(96, 48);
    let reg_model = Arc::new(mk_model(1, wn, 77));
    let mut replay_rows = Vec::new();
    for &count in &event_counts {
        let path = dir.join(format!("replay_{count}.cloqwal"));
        {
            let (mut wal, events) =
                Wal::open(Box::new(FsWalFile::at(&path)), "bench", opts).unwrap();
            assert!(events.is_empty(), "fresh bench log was not empty");
            let mut rng = Rng::new(78);
            // Half the registers are hot-swaps of earlier ids; every 16th
            // event retires the id registered just before it.
            let distinct = (count / 2).max(1);
            for i in 0..count {
                if i % 16 == 15 {
                    wal.log_unregister(&format!("t{}", (i - 1) % distinct)).unwrap();
                } else {
                    wal.log_register(&mk_set(&format!("t{}", i % distinct), wn, &mut rng))
                        .unwrap();
                }
            }
        }
        let log_bytes = std::fs::metadata(&path).unwrap().len() as usize;
        let r = bench(&format!("replay {count} events"), t, || {
            let (_wal, events) =
                Wal::open(Box::new(FsWalFile::at(&path)), "bench", opts).unwrap();
            let reg = AdapterRegistry::new(Arc::clone(&reg_model), usize::MAX);
            let mut applied = 0usize;
            for ev in events {
                match ev {
                    WalEvent::Register(set) => {
                        reg.register(set).unwrap();
                    }
                    WalEvent::Unregister(id) => {
                        let _ = reg.unregister(&id);
                    }
                }
                applied += 1;
            }
            applied
        });
        let events_per_s = count as f64 / r.min_s.max(1e-12);
        println!(
            "replay {count} events ({:.1} KiB log): {:.2}ms → {events_per_s:.0} events/s",
            log_bytes as f64 / 1024.0,
            r.min_s * 1e3
        );
        // Same history through a snapshotted WAL with compaction ON: boot
        // reads the CLOQSNP1 live state plus the tail since the last
        // compaction instead of decoding the whole history.
        let spath = dir.join(format!("replay_{count}_snap.cloqwal"));
        let snpath = dir.join(format!("replay_{count}.cloqsnp"));
        let snap_opts =
            WalOptions { sync_every: 1024, compact_min_bytes: 4096, compact_ratio: 2 };
        {
            let (mut wal, _) = Wal::open_snapshotted(
                Box::new(FsWalFile::at(&spath)),
                Box::new(FsWalFile::at(&snpath)),
                "bench",
                snap_opts,
            )
            .unwrap();
            let mut rng = Rng::new(78);
            let distinct = (count / 2).max(1);
            for i in 0..count {
                if i % 16 == 15 {
                    wal.log_unregister(&format!("t{}", (i - 1) % distinct)).unwrap();
                } else {
                    wal.log_register(&mk_set(&format!("t{}", i % distinct), wn, &mut rng))
                        .unwrap();
                }
            }
        }
        let mut snap_events = 0usize;
        let r_snap = bench(&format!("replay {count} ops from snapshot"), t, || {
            let (_wal, events) = Wal::open_snapshotted(
                Box::new(FsWalFile::at(&spath)),
                Box::new(FsWalFile::at(&snpath)),
                "bench",
                snap_opts,
            )
            .unwrap();
            let reg = AdapterRegistry::new(Arc::clone(&reg_model), usize::MAX);
            let mut applied = 0usize;
            for ev in events {
                match ev {
                    WalEvent::Register(set) => {
                        reg.register(set).unwrap();
                    }
                    WalEvent::Unregister(id) => {
                        let _ = reg.unregister(&id);
                    }
                }
                applied += 1;
            }
            snap_events = applied;
            applied
        });
        let snap_speedup = r.min_s / r_snap.min_s.max(1e-12);
        println!(
            "replay {count} ops from snapshot: {} replay events, {:.2}ms → {snap_speedup:.1}x \
             vs full-history replay",
            snap_events,
            r_snap.min_s * 1e3
        );
        let mut row = Json::obj();
        row.set("events", Json::from(count));
        row.set("log_bytes", Json::from(log_bytes));
        row.set("replay_s", Json::from(r.min_s));
        row.set("events_per_s", Json::from(events_per_s));
        row.set("snapshot_replay_s", Json::from(r_snap.min_s));
        row.set("snapshot_replay_events", Json::from(snap_events));
        row.set("snapshot_speedup", Json::from(snap_speedup));
        row.set("detail", r.to_json());
        row.set("snapshot_detail", r_snap.to_json());
        replay_rows.push(row);
    }

    // ---- 3. WAL group commit: serial vs concurrent durable registers ------
    section("WAL group commit: durable register throughput, 1 thread vs 8");
    let n_regs = smoke_scaled(128, 32);
    let gc_threads = 8usize;
    // Sets are pre-built and cloned into the timed region so both modes
    // time register_adapter (append + fsync policy + registry apply) and
    // nothing else. Compaction is off: a mid-run log rewrite would hand
    // one mode a free durability point.
    let gc_opts =
        WalOptions { sync_every: 1, compact_min_bytes: usize::MAX, compact_ratio: usize::MAX };
    let mut gc_rng = Rng::new(79);
    let gc_sets: Vec<AdapterSet> =
        (0..n_regs).map(|i| mk_set(&format!("gc{i}"), wn, &mut gc_rng)).collect();
    let mut gc_json = Json::obj();
    let mut gc_rps = [0.0f64; 2]; // [serial, concurrent]
    for (k, mode) in ["serial", "concurrent"].into_iter().enumerate() {
        let mut best = f64::INFINITY;
        let mut best_fsyncs = 0u64;
        for round in 0..3 {
            let wdir = dir.join(format!("gc_{mode}_{round}"));
            std::fs::create_dir_all(&wdir).unwrap();
            let engine = ServeEngine::builder(mk_model(1, wn, 77))
                .workers(1)
                .durable(&wdir)
                .wal_options(gc_opts)
                .build()
                .unwrap();
            let t0 = Instant::now();
            if mode == "serial" {
                for set in &gc_sets {
                    engine.register_adapter(set.clone()).unwrap();
                }
            } else {
                std::thread::scope(|s| {
                    for chunk in gc_sets.chunks(n_regs.div_ceil(gc_threads)) {
                        let engine = &engine;
                        s.spawn(move || {
                            for set in chunk {
                                engine.register_adapter(set.clone()).unwrap();
                            }
                        });
                    }
                });
            }
            let wall = t0.elapsed().as_secs_f64();
            let fsyncs = engine.telemetry().counter(Counter::WalFsyncs);
            engine.shutdown();
            if wall < best {
                best = wall;
                best_fsyncs = fsyncs;
            }
        }
        gc_rps[k] = n_regs as f64 / best.max(1e-12);
        let fsyncs_per_op = best_fsyncs as f64 / n_regs as f64;
        println!(
            "group commit {mode:<10} {n_regs} registers in {best:.4}s → {:.0} reg/s, \
             {fsyncs_per_op:.2} fsyncs/op",
            gc_rps[k]
        );
        let mut row = Json::obj();
        row.set("registers", Json::from(n_regs));
        row.set("threads", Json::from(if mode == "serial" { 1 } else { gc_threads }));
        row.set("best_wall_s", Json::from(best));
        row.set("registers_per_s", Json::from(gc_rps[k]));
        row.set("fsyncs", Json::from(best_fsyncs as usize));
        row.set("fsyncs_per_op", Json::from(fsyncs_per_op));
        gc_json.set(mode, row);
    }
    let gc_speedup = gc_rps[1] / gc_rps[0].max(1e-30);
    println!("\ngroup-commit concurrent-vs-serial: {gc_speedup:.2}x");
    gc_json.set("speedup_concurrent_vs_serial", Json::from(gc_speedup));

    let record = Json::from_pairs(vec![
        ("bench", Json::from("artifact")),
        ("smoke", Json::from(smoke())),
        // Identity keys for bench_diff: rows pair by index, so the gate
        // must refuse comparison when the sweep points change.
        (
            "sizes",
            Json::Arr(
                sizes
                    .iter()
                    .map(|&(l, n)| Json::Arr(vec![Json::from(l), Json::from(n)]))
                    .collect(),
            ),
        ),
        (
            "event_counts",
            Json::Arr(event_counts.iter().map(|&c| Json::from(c)).collect()),
        ),
        ("cold_start", Json::Arr(cold_rows)),
        ("replay", Json::Arr(replay_rows)),
        ("group_commit", gc_json),
        (
            "parity",
            Json::from(
                "mapped v3 forwards bit-identical to eager v2 and every single-bit flip \
                 detected — rust/tests/golden_serve.rs; crash recovery is exactly a \
                 committed prefix — rust/tests/crash_wal.rs",
            ),
        ),
    ]);
    write_bench_json("artifact", record);
    if worst_speedup < 1.0 {
        eprintln!(
            "WARNING: zero-copy v3 cold start fell to {worst_speedup:.2}x of the eager v2 \
             path at some size (timing noise is possible; correctness is unaffected)"
        );
    }
    std::fs::remove_dir_all(&dir).ok();
}

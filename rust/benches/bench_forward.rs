//! Full-model pipelined forward benchmarks — the numbers behind
//! EXPERIMENTS.md §Forward, emitted as BENCH_forward.json:
//!
//! 1. **pipelined vs caller-driven serial**: S concurrent "sessions", each
//!    K sequential full-model forwards over an L-layer chain.
//!    *Pipelined* = one `submit_session` per session: every hop re-enters
//!    the batcher, so hops from different sessions at the same depth
//!    coalesce into shared grouped kernel calls. *Serial* = what a caller
//!    without `submit_model` must do: drive the chain by hand with one
//!    single-layer `submit().wait()` per hop (S caller threads, so the
//!    engine still sees concurrent traffic — it just can't see past each
//!    caller's next hop). The gap at S ≥ 8 is the continuous-batching win
//!    this path exists for; at S = 1 the two are the same work and the
//!    pipelined path only saves ticket round-trips.
//! 2. **mixed-adapter sessions**: the same pipelined workload spread
//!    round-robin over 4 tenants on one base — multi-tenant decode.
//!
//! Under `CLOQ_BENCH_SMOKE=1` (the CI bench-smoke job) shapes and counts
//! shrink and the record carries `"smoke": true` so `scripts/bench_diff.py`
//! only compares like against like.
//!
//! Correctness is NOT measured here: the pipelined traversal is bit-exact
//! vs the serial reference by `rust/tests/parity_forward.rs`; this file is
//! pure throughput.

use std::time::Instant;

use cloq::bench::{section, smoke, smoke_scaled, write_bench_json};
use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    AdapterSet, ModelRequest, PackedLayer, PackedModel, Route, ServeEngine, SessionRequest,
    StepFn,
};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

fn mk_chain(layers: usize, width: usize, seed: u64) -> (PackedModel, Vec<String>) {
    let mut rng = Rng::new(seed);
    let mut packed = Vec::new();
    let mut route = Vec::new();
    for l in 0..layers {
        let name = format!("l{l}");
        let w = Matrix::randn(width, width, 0.3, &mut rng);
        packed.push(
            PackedLayer::from_state(&name, &QuantState::Int(quantize_rtn(&w, 4, 64))).unwrap(),
        );
        route.push(name);
    }
    (PackedModel::new(packed), route)
}

fn mk_set(id: &str, model: &PackedModel, r: usize, rng: &mut Rng) -> AdapterSet {
    let mut set = AdapterSet::new(id);
    for l in &model.layers {
        let pair =
            LoraPair::new(Matrix::randn(l.rows, r, 0.1, rng), Matrix::randn(l.cols, r, 0.1, rng));
        set.insert(&l.name, pair).unwrap();
    }
    set
}

/// The inter-forward step both modes share: normalize to unit max-abs so
/// K forwards cannot overflow whatever the chain's gain is.
fn step_of(y: &[f64]) -> Vec<f64> {
    let s = y.iter().fold(1e-30f64, |a, v| a.max(v.abs()));
    y.iter().map(|v| v / s).collect()
}

/// Engine plus the route interned against it ONCE — submissions below
/// clone an Arc, never a Vec<String>.
fn engine_of(layers: usize, width: usize, seed: u64) -> (ServeEngine, Route) {
    let (model, names) = mk_chain(layers, width, seed);
    let engine = ServeEngine::builder(model).workers(2).max_batch(32).build().unwrap();
    let route = engine.route(&names).unwrap();
    (engine, route)
}

fn main() {
    let n_layers = smoke_scaled(6, 4);
    let width = smoke_scaled(256, 64);
    let k_forwards = smoke_scaled(16, 4);
    let runs = smoke_scaled(3, 2);
    let session_counts: Vec<usize> = if smoke() { vec![1, 4, 8] } else { vec![1, 8, 64] };
    let mut rng = Rng::new(31);

    section(&format!(
        "pipelined vs caller-driven serial ({n_layers} layers x {width} wide, \
         {k_forwards} forwards/session)"
    ));
    let mut sweep_records = Vec::new();
    let mut speedup_at_max = 0.0f64;
    for &sessions in &session_counts {
        let x0s: Vec<Vec<f64>> = (0..sessions).map(|_| rng.gauss_vec(width)).collect();
        let total_forwards = sessions * k_forwards;

        // --- pipelined: one SessionRequest per session --------------------
        let mut best_pipe = f64::INFINITY;
        let mut best_stats = None;
        for _ in 0..runs {
            let (engine, route) = engine_of(n_layers, width, 32);
            let t0 = Instant::now();
            let tickets: Vec<_> = x0s
                .iter()
                .map(|x0| {
                    let step: StepFn = Box::new(|_, y| Some(step_of(y)));
                    engine.submit_session(SessionRequest::new(
                        route.clone(),
                        x0.clone(),
                        k_forwards,
                        step,
                    ))
                })
                .collect();
            for t in tickets {
                t.wait().unwrap();
            }
            let wall = t0.elapsed().as_secs_f64();
            let stats = engine.shutdown();
            if wall < best_pipe {
                best_pipe = wall;
                best_stats = Some(stats);
            }
        }
        let stats = best_stats.unwrap();

        // --- serial: each caller thread drives its chain hop by hop -------
        let mut best_serial = f64::INFINITY;
        for _ in 0..runs {
            let (engine, route) = engine_of(n_layers, width, 32);
            let t0 = Instant::now();
            std::thread::scope(|s| {
                for x0 in &x0s {
                    let engine = &engine;
                    let route = &route;
                    s.spawn(move || {
                        let mut x = x0.clone();
                        for _ in 0..k_forwards {
                            for &lid in route.as_ids() {
                                x = engine.submit(lid, None, x).wait().unwrap().y;
                            }
                            x = step_of(&x);
                        }
                    });
                }
            });
            let wall = t0.elapsed().as_secs_f64();
            engine.shutdown();
            best_serial = best_serial.min(wall);
        }

        let pipe_fps = total_forwards as f64 / best_pipe;
        let serial_fps = total_forwards as f64 / best_serial;
        let speedup = pipe_fps / serial_fps.max(1e-30);
        speedup_at_max = speedup; // last iteration = largest session count
        println!(
            "sessions={sessions:<3} pipelined {pipe_fps:>8.0} fwd/s (mean batch {:.1})   \
             serial {serial_fps:>8.0} fwd/s   speedup {speedup:.2}x",
            stats.mean_batch(),
        );
        let mut pipe_rec = Json::obj();
        pipe_rec.set("best_wall_s", Json::from(best_pipe));
        pipe_rec.set("forwards_per_s", Json::from(pipe_fps));
        pipe_rec.set("mean_batch", Json::from(stats.mean_batch()));
        pipe_rec.set("max_batch_seen", Json::from(stats.max_batch_seen));
        let mut serial_rec = Json::obj();
        serial_rec.set("best_wall_s", Json::from(best_serial));
        serial_rec.set("forwards_per_s", Json::from(serial_fps));
        let mut rec = Json::obj();
        rec.set("sessions", Json::from(sessions));
        rec.set("forwards_each", Json::from(k_forwards));
        rec.set("total_forwards", Json::from(total_forwards));
        rec.set("pipelined", pipe_rec);
        rec.set("serial", serial_rec);
        rec.set("speedup_pipelined_vs_serial", Json::from(speedup));
        sweep_records.push(rec);
    }

    // ---- mixed-adapter sessions: multi-tenant decode ----------------------
    let tenants = 4usize;
    let sessions = *session_counts.last().unwrap();
    section(&format!("mixed-adapter pipelined sessions ({sessions} sessions, {tenants} tenants)"));
    let x0s: Vec<Vec<f64>> = (0..sessions).map(|_| rng.gauss_vec(width)).collect();
    let mut best_mixed = f64::INFINITY;
    let mut mixed_hops = 0usize;
    let mut total_hops = 0usize;
    for _ in 0..runs {
        let (model, names) = mk_chain(n_layers, width, 32);
        let mut arng = Rng::new(33);
        let sets: Vec<AdapterSet> =
            (0..tenants).map(|a| mk_set(&format!("t{a}"), &model, 8, &mut arng)).collect();
        let engine = ServeEngine::builder(model).workers(2).max_batch(32).build().unwrap();
        let route = engine.route(&names).unwrap();
        let tids: Vec<_> =
            sets.into_iter().map(|set| engine.register_adapter(set).unwrap().id).collect();
        let t0 = Instant::now();
        let tickets: Vec<_> = x0s
            .iter()
            .enumerate()
            .map(|(i, x0)| {
                let step: StepFn = Box::new(|_, y| Some(step_of(y)));
                engine.submit_session(SessionRequest::with_adapter(
                    route.clone(),
                    tids[i % tenants],
                    x0.clone(),
                    k_forwards,
                    step,
                ))
            })
            .collect();
        let mut run_mixed = 0usize;
        let mut run_hops = 0usize;
        for t in tickets {
            let r = t.wait().unwrap();
            run_mixed += r.mixed_hops;
            run_hops += r.hops;
        }
        let wall = t0.elapsed().as_secs_f64();
        engine.shutdown();
        if wall < best_mixed {
            best_mixed = wall;
            mixed_hops = run_mixed;
            total_hops = run_hops;
        }
    }
    let mixed_fps = (sessions * k_forwards) as f64 / best_mixed;
    let mixed_share = mixed_hops as f64 / total_hops.max(1) as f64;
    println!(
        "mixed tenants: {mixed_fps:.0} fwd/s ({:.0}% of hops rode a mixed batch)",
        mixed_share * 100.0
    );
    let mut mixed_json = Json::obj();
    mixed_json.set("tenants", Json::from(tenants));
    mixed_json.set("sessions", Json::from(sessions));
    mixed_json.set("best_wall_s", Json::from(best_mixed));
    mixed_json.set("forwards_per_s", Json::from(mixed_fps));
    mixed_json.set("mixed_hop_share", Json::from(mixed_share));

    // One smoke check worth failing loudly on even in a bench: a model
    // request through the pipelined path must agree with the serial
    // reference (the full contract lives in tests/parity_forward.rs).
    {
        let (model, names) = mk_chain(n_layers, width, 32);
        let serial_route = model.route(&names).unwrap();
        let x = Rng::new(34).gauss_vec(width);
        let serial = cloq::serve::forward_route_serial(&model, &serial_route, None, &x);
        let engine = ServeEngine::builder(model).build().unwrap();
        let route = engine.route(&names).unwrap();
        let y = engine.submit_model(ModelRequest::new(route, x)).wait().unwrap().y;
        engine.shutdown();
        assert_eq!(y, serial, "pipelined forward drifted from the serial reference");
    }

    let record = Json::from_pairs(vec![
        ("bench", Json::from("serve_forward_pipeline")),
        ("smoke", Json::from(smoke())),
        ("shape", Json::Arr(vec![Json::from(width), Json::from(width)])),
        ("layers", Json::from(n_layers)),
        ("rank", Json::from(8usize)),
        ("forwards_per_session", Json::from(k_forwards)),
        // Identity key for bench_diff: sweep rows pair by index, so the
        // gate must refuse comparison when the session counts change.
        ("sessions", Json::Arr(session_counts.iter().map(|&s| Json::from(s)).collect())),
        ("session_sweep", Json::Arr(sweep_records)),
        ("speedup_at_max_sessions", Json::from(speedup_at_max)),
        ("mixed_adapter", mixed_json),
        (
            "parity",
            Json::from(
                "pipelined full-model forward bit-exact (0 ULP) vs the caller-driven \
                 serial reference — enforced by rust/tests/parity_forward.rs",
            ),
        ),
    ]);
    write_bench_json("forward", record);
    if speedup_at_max < 1.0 {
        // Timing noise must not turn a measurement into a flaky bench exit;
        // correctness is enforced by the parity suite.
        eprintln!(
            "WARNING: pipelined measured slower than caller-driven serial at \
             {sessions} sessions ({speedup_at_max:.2}x)"
        );
    }
}

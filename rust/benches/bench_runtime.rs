//! PJRT runtime benchmarks: per-step latency of every AOT entry point —
//! the serving/training hot path the L3 coordinator drives. Skips politely
//! when artifacts are missing.

use cloq::bench::{bench, section};
use cloq::model::{init_base, lora_specs, zeros_for};
use cloq::runtime::{Runtime, Tensor};
use cloq::util::prng::Rng;

fn main() {
    let dir = std::path::PathBuf::from("artifacts/tiny-s");
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP bench_runtime: run `make artifacts` first");
        return;
    }
    let mut rt = Runtime::load(&dir).unwrap();
    let cfg = rt.manifest.config.clone();
    let mut rng = Rng::new(4);
    let base = init_base(&rt.manifest, &mut rng).unwrap();
    let lspecs = lora_specs(&rt.manifest).unwrap();
    let lora = zeros_for(&lspecs);
    let n = cfg.batch * cfg.seq;
    let tokens = Tensor::i32(
        vec![cfg.batch, cfg.seq],
        (0..n).map(|_| rng.range(4, cfg.vocab as i64 - 1) as i32).collect(),
    );
    let mask = Tensor::f32(vec![cfg.batch, cfg.seq], vec![1.0; n]);
    let t = 1.0;

    section(&format!(
        "PJRT step latency ({}: d={} L={} batch={} seq={})",
        cfg.name, cfg.d_model, cfg.n_layers, cfg.batch, cfg.seq
    ));

    // eval_loss
    let mut ev = base.in_order();
    ev.extend(lora.in_order());
    ev.push(tokens.clone());
    ev.push(mask.clone());
    bench("eval_loss", t, || rt.run("eval_loss", &ev).unwrap());
    let tok_per_s = n as f64;

    // eval_logits
    let mut el = base.in_order();
    el.extend(lora.in_order());
    el.push(tokens.clone());
    let r = bench("eval_logits", t, || rt.run("eval_logits", &el).unwrap());
    println!("    -> {:.0} tok/s", tok_per_s / r.min_s);

    // capture_grams
    let mut cg = base.in_order();
    cg.push(tokens.clone());
    cg.push(mask.clone());
    bench("capture_grams", t, || rt.run("capture_grams", &cg).unwrap());

    // lora_step
    let lvals = lora.in_order();
    let zeros: Vec<Tensor> = lvals.iter().map(|x| Tensor::zeros_f32(x.shape.clone())).collect();
    let mut ls = base.in_order();
    ls.extend(lvals.clone());
    ls.extend(zeros.clone());
    ls.extend(zeros.clone());
    ls.push(tokens.clone());
    ls.push(mask.clone());
    ls.push(Tensor::scalar_f32(1e-3));
    ls.push(Tensor::scalar_f32(0.0));
    ls.push(Tensor::scalar_f32(1.0));
    let r = bench("lora_step (fwd+bwd+AdamW)", t, || rt.run("lora_step", &ls).unwrap());
    println!("    -> {:.0} tok/s", tok_per_s / r.min_s);

    // pretrain_step
    let bvals = base.in_order();
    let bzeros: Vec<Tensor> = bvals.iter().map(|x| Tensor::zeros_f32(x.shape.clone())).collect();
    let mut ps = bvals.clone();
    ps.extend(bzeros.clone());
    ps.extend(bzeros.clone());
    ps.push(tokens.clone());
    ps.push(mask.clone());
    ps.push(Tensor::scalar_f32(1e-3));
    ps.push(Tensor::scalar_f32(0.0));
    ps.push(Tensor::scalar_f32(1.0));
    let r = bench("pretrain_step (full params)", t, || rt.run("pretrain_step", &ps).unwrap());
    println!("    -> {:.0} tok/s", tok_per_s / r.min_s);

    // qeval_loss (serving path with Pallas fused dequant kernel)
    let qspec = rt.manifest.entry("qeval_loss").unwrap().clone();
    let mut qs: Vec<Tensor> = Vec::new();
    for s in &qspec.inputs {
        if s.name == "tokens" {
            qs.push(tokens.clone());
        } else if s.name == "mask" {
            qs.push(mask.clone());
        } else if s.name.ends_with(".codes") {
            let layer = s.name.trim_end_matches(".codes");
            let w = base.get(layer).to_matrix();
            let q = cloq::quant::quantize_rtn(&w, 2, cfg.group_size);
            qs.push(Tensor::i32(vec![q.rows, q.cols], q.codes.iter().map(|&c| c as i32).collect()));
        } else if s.name.ends_with(".scales") {
            let layer = s.name.trim_end_matches(".scales");
            let q = cloq::quant::quantize_rtn(&base.get(layer).to_matrix(), 2, cfg.group_size);
            qs.push(Tensor::from_matrix(&q.scales));
        } else if s.name.ends_with(".zeros") {
            let layer = s.name.trim_end_matches(".zeros");
            let q = cloq::quant::quantize_rtn(&base.get(layer).to_matrix(), 2, cfg.group_size);
            qs.push(Tensor::from_matrix(&q.zeros));
        } else if s.name.ends_with(".A") || s.name.ends_with(".B") {
            qs.push(lora.get(&s.name).clone());
        } else {
            qs.push(base.get(&s.name).clone());
        }
    }
    let r = bench("qeval_loss (Pallas dequant path)", t, || rt.run("qeval_loss", &qs).unwrap());
    println!("    -> {:.0} tok/s", tok_per_s / r.min_s);
}

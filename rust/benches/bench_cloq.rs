//! Initialization-method benchmarks: CLoQ's two-SVD closed form vs LoftQ's
//! AltMin vs the zero-init baselines — Table 10's duration column at
//! several scales, plus the rank sweep.

use cloq::bench::{bench, section};
use cloq::linalg::{matmul, syrk_t, Matrix};
use cloq::lowrank::{
    cloq_lowrank, damping_lambda, init_layer, CloqConfig, InitConfig, LoftqConfig,
    LoftqQuantizer, Method,
};
use cloq::lowrank::loftq;
use cloq::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(3);
    let t = 0.4;

    section("closed form (Theorem 3.1) vs LoftQ AltMin — full per-layer init");
    for (m, n) in [(96usize, 96usize), (96, 256), (256, 256)] {
        let base = Matrix::randn(m * 4, (m / 3).max(2), 1.0, &mut rng);
        let mix = Matrix::randn((m / 3).max(2), m, 1.0, &mut rng);
        let x = matmul(&base, &mix);
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let h = syrk_t(&x);
        for method in
            [Method::QLora, Method::GptqLora, Method::LoftQ, Method::CLoQNoMagR, Method::CLoQ]
        {
            let mut cfg = InitConfig::new(method, 2, 16);
            cfg.group_size = 64;
            let mut r2 = Rng::new(9);
            bench(&format!("{} {m}x{n}", method.name()), t, || {
                init_layer(&w, Some(&h), &cfg, &mut r2)
            });
        }
    }

    section("CLoQ low-rank step only, rank sweep (96x256)");
    {
        let base = Matrix::randn(384, 32, 1.0, &mut rng);
        let mix = Matrix::randn(32, 96, 1.0, &mut rng);
        let x = matmul(&base, &mix);
        let dw = Matrix::randn(96, 256, 0.1, &mut rng);
        let mut h = syrk_t(&x);
        h.add_diag(damping_lambda(&h, 0.01));
        for r in [4usize, 16, 64] {
            bench(&format!("cloq_lowrank rank {r}"), t, || {
                cloq_lowrank(&h, &dw, &CloqConfig { rank: r, ..Default::default() })
            });
        }
    }

    section("exact vs randomized SVD inside cloq_lowrank (96x256)");
    {
        let base = Matrix::randn(384, 32, 1.0, &mut rng);
        let mix = Matrix::randn(32, 96, 1.0, &mut rng);
        let x = matmul(&base, &mix);
        let dw = Matrix::randn(96, 256, 0.1, &mut rng);
        let mut h = syrk_t(&x);
        h.add_diag(damping_lambda(&h, 0.01));
        for randomized in [false, true] {
            let cfg = CloqConfig { rank: 16, randomized, ..Default::default() };
            bench(&format!("cloq_lowrank randomized={randomized}"), t, || {
                cloq_lowrank(&h, &dw, &cfg)
            });
        }
        // diag-H (LQ-LoRA-style) midpoint for context.
        bench("lqlora_lowrank (diag-H)", t, || {
            cloq::lowrank::lqlora_lowrank(&h, &dw, 16, 0.01)
        });
    }

    section("LoftQ iteration sweep (96x256, 2-bit)");
    {
        let w = Matrix::randn(96, 256, 0.3, &mut rng);
        for iters in [1usize, 5, 10] {
            let cfg = LoftqConfig {
                bits: 2,
                group_size: 64,
                rank: 16,
                iters,
                quantizer: LoftqQuantizer::Int,
            };
            bench(&format!("loftq iters={iters}"), t, || loftq(&w, &cfg));
        }
    }
}

//! Serving-path benchmarks — the numbers behind EXPERIMENTS.md §Serve,
//! emitted as BENCH_serve.json:
//!
//! 1. **fused vs dense forward**: the packed fused kernel against (a) a
//!    dense matvec over a pre-materialized `q_deq` ("dense cached" — pays
//!    8 bytes/weight of memory traffic instead of bits/8) and (b) a
//!    dequantize-then-matvec per request ("dense remat" — what a server
//!    without a packed path would do).
//! 2. **batched vs serial throughput**: the kernel's row-reuse batch sweep
//!    plus the end-to-end engine with coalescing on vs off.
//! 3. **submission overhead, interned vs named**: the same burst admitted
//!    through the typed façade (`submit(LayerId, Some(AdapterId), x)` —
//!    handles resolved once up front) vs the legacy stringly path
//!    (`submit_named("lin", Some("tenant"), x)` — a name hash plus an
//!    adapter-id hash per call). A small layer keeps kernel time from
//!    drowning the admission cost being measured.
//!
//! Under `CLOQ_BENCH_SMOKE=1` (the CI bench-smoke job) shapes and request
//! counts shrink and the record carries `"smoke": true` so
//! `scripts/bench_diff.py` only compares like against like.
//!
//! Correctness is NOT measured here — the fused/batched paths are
//! bit-exact vs the dense reference by `rust/tests/parity_serve.rs`; this
//! file is pure speed.

use std::time::Instant;

use cloq::bench::{bench, section, smoke, smoke_scaled, target_time, write_bench_json};
use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{AdapterSet, PackedLayer, PackedModel, Request, ServeEngine};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

fn mk_layer(
    m: usize,
    n: usize,
    bits: u32,
    gs: usize,
    r: usize,
    rng: &mut Rng,
) -> (PackedLayer, LoraPair, Matrix) {
    let w = Matrix::randn(m, n, 0.3, rng);
    let q = quantize_rtn(&w, bits, gs);
    let q_deq = q.dequantize();
    let a = Matrix::randn(m, r, 0.1, rng);
    let b = Matrix::randn(n, r, 0.1, rng);
    let layer = PackedLayer::from_state("bench", &QuantState::Int(q)).unwrap();
    (layer, LoraPair::new(a, b), q_deq)
}

fn main() {
    let mut rng = Rng::new(11);
    let t = target_time(0.4);
    let (m, n) = (smoke_scaled(512, 96), smoke_scaled(512, 96));
    let r = 16usize;

    // ---- fused vs dense, across bit widths --------------------------------
    section(&format!("packed fused vs dense forward ({m}x{n}, rank {r}, g64, batch 1)"));
    let mut fused_records = Vec::new();
    let mut speedup_vs_remat_4bit = 0.0;
    let mut speedup_vs_cached_4bit = 0.0;
    for bits in [2u32, 4, 8] {
        let (layer, pair, q_deq) = mk_layer(m, n, bits, 64, r, &mut rng);
        let x = rng.gauss_vec(m);
        // All three paths compute the SAME function (base + factored LoRA)
        // via dense_reference_forward, so the ratios isolate weight access:
        // fused reads packed words; cached reads a pre-materialized q_deq;
        // remat pays a full dequantize per request.
        let r_fused = bench(&format!("fused {bits}-bit"), t, || layer.forward(&x, Some(&pair)));
        let r_cached = bench(&format!("dense cached {bits}-bit"), t, || {
            layer.dense_reference_forward(&q_deq, &x, Some(&pair))
        });
        let r_remat = bench(&format!("dense remat {bits}-bit"), t, || {
            let q_deq = layer.dequantize().unwrap();
            layer.dense_reference_forward(&q_deq, &x, Some(&pair))
        });
        if bits == 4 {
            speedup_vs_remat_4bit = r_remat.min_s / r_fused.min_s;
            speedup_vs_cached_4bit = r_cached.min_s / r_fused.min_s;
        }
        let mut rec = Json::obj();
        rec.set("bits", Json::from(bits as usize));
        rec.set("fused", r_fused.to_json());
        rec.set("dense_cached", r_cached.to_json());
        rec.set("dense_remat", r_remat.to_json());
        rec.set("packed_bytes", Json::from(layer.packed_bytes()));
        rec.set("dense_bytes", Json::from(m * n * 8));
        fused_records.push(rec);
    }
    println!(
        "\nfused vs dense-remat @4-bit: {speedup_vs_remat_4bit:.2}x, \
         vs dense-cached: {speedup_vs_cached_4bit:.2}x"
    );

    // ---- kernel batch sweep ----------------------------------------------
    section(&format!("kernel micro-batch sweep ({m}x{n}, 4-bit)"));
    let (layer, pair, _) = mk_layer(m, n, 4, 64, r, &mut rng);
    let mut batch_records = Vec::new();
    let mut serial_rps = 0.0;
    let mut best_batched_rps = 0.0;
    for batch in [1usize, 4, 16, 64] {
        let xs = Matrix::randn(batch, m, 1.0, &mut rng);
        let rb = bench(&format!("forward_batch batch={batch}"), t, || {
            layer.forward_batch(&xs, Some(&pair))
        });
        let rps = batch as f64 / rb.min_s;
        if batch == 1 {
            serial_rps = rps; // baseline only — never a candidate for "best batched",
        } else {
            best_batched_rps = best_batched_rps.max(rps); // so a real <1.0 regression shows
        }
        let mut rec = rb.to_json();
        rec.set("batch", Json::from(batch));
        rec.set("requests_per_s_min", Json::from(rps));
        batch_records.push(rec);
    }
    let kernel_batch_speedup = best_batched_rps / serial_rps.max(1e-30);
    println!("\nkernel batched-vs-serial throughput: {kernel_batch_speedup:.2}x");

    // ---- end-to-end engine: coalescing on vs off --------------------------
    let n_req = smoke_scaled(256, 48);
    section(&format!("engine throughput: coalescing on vs off ({n_req} requests)"));
    let xs: Vec<Vec<f64>> = (0..n_req).map(|_| rng.gauss_vec(m)).collect();
    let mut engine_json = Json::obj();
    let mut engine_rps = [0.0f64; 2];
    for (k, max_batch) in [1usize, 32].into_iter().enumerate() {
        // Best of 3 runs; each run builds a fresh engine so worker spawn is
        // inside the measurement honestly (it is microseconds vs the work).
        // The emitted stats are the BEST run's, so the JSON record is one
        // internally consistent execution.
        let mut best = f64::INFINITY;
        let mut best_stats = None;
        for _ in 0..3 {
            let model = PackedModel::new(vec![layer.clone()]);
            let engine =
                ServeEngine::builder(model).workers(2).max_batch(max_batch).build().unwrap();
            let set = AdapterSet::from_pairs(
                "tenant",
                vec![("bench".to_string(), pair.clone())],
            )
            .unwrap();
            let tenant = engine.register_adapter(set).unwrap().id;
            let lid = engine.layer("bench").unwrap();
            let t0 = Instant::now();
            let tickets = engine.submit_all(
                xs.iter().map(|x| Request::with_adapter(lid, tenant, x.clone())).collect(),
            );
            for tk in tickets {
                tk.wait().unwrap();
            }
            let wall = t0.elapsed().as_secs_f64();
            let stats = engine.shutdown();
            if wall < best {
                best = wall;
                best_stats = Some(stats);
            }
        }
        let stats = best_stats.unwrap();
        let rps = n_req as f64 / best;
        engine_rps[k] = rps;
        println!(
            "engine max_batch={max_batch:<3} {n_req} reqs in {best:.4}s → {rps:.0} req/s \
             (mean batch {:.1}, max seen {})",
            stats.mean_batch(),
            stats.max_batch_seen
        );
        let mut rec = Json::obj();
        rec.set("max_batch", Json::from(max_batch));
        rec.set("requests", Json::from(n_req));
        rec.set("best_wall_s", Json::from(best));
        rec.set("requests_per_s", Json::from(rps));
        rec.set("mean_batch", Json::from(stats.mean_batch()));
        rec.set("max_batch_seen", Json::from(stats.max_batch_seen));
        rec.set("mean_queue_s", Json::from(stats.mean_queue_s()));
        engine_json.set(if max_batch == 1 { "serial" } else { "batched" }, rec);
    }
    let engine_speedup = engine_rps[1] / engine_rps[0].max(1e-30);
    println!("\nengine batched-vs-serial: {engine_speedup:.2}x");

    // ---- submission overhead: interned handles vs stringly names ----------
    // A SMALL layer so per-request admission work (resolution, cloning,
    // checkout) is a visible fraction of the round trip; both paths run
    // the identical burst and the identical kernel work.
    let n_sub = smoke_scaled(2048, 256);
    section(&format!("submission overhead: interned vs named admission ({n_sub} requests)"));
    let (small_layer, small_pair, _) = mk_layer(48, 16, 4, 16, 4, &mut rng);
    let sub_xs: Vec<Vec<f64>> = (0..n_sub).map(|_| rng.gauss_vec(48)).collect();
    let mut sub_rps = [0.0f64; 2]; // [interned, named]
    for (k, mode) in ["interned", "named"].into_iter().enumerate() {
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let model = PackedModel::new(vec![small_layer.clone()]);
            let engine =
                ServeEngine::builder(model).workers(2).max_batch(32).build().unwrap();
            let set = AdapterSet::from_pairs(
                "tenant",
                vec![("bench".to_string(), small_pair.clone())],
            )
            .unwrap();
            let tenant = engine.register_adapter(set).unwrap().id;
            let lid = engine.layer("bench").unwrap();
            let t0 = Instant::now();
            let tickets: Vec<_> = sub_xs
                .iter()
                .map(|x| {
                    if mode == "interned" {
                        engine.submit(lid, Some(tenant), x.clone())
                    } else {
                        engine.submit_named("bench", Some("tenant"), x.clone())
                    }
                })
                .collect();
            for tk in tickets {
                tk.wait().unwrap();
            }
            best = best.min(t0.elapsed().as_secs_f64());
            engine.shutdown();
        }
        sub_rps[k] = n_sub as f64 / best;
        println!("submission {mode:<9} {n_sub} reqs → {:>9.0} req/s", sub_rps[k]);
    }
    let submission_speedup = sub_rps[0] / sub_rps[1].max(1e-30);
    println!("\ninterned-vs-named admission: {submission_speedup:.2}x");
    let mut submission_json = Json::obj();
    submission_json.set("requests", Json::from(n_sub));
    submission_json.set("layer_shape", Json::Arr(vec![Json::from(48usize), Json::from(16usize)]));
    let mut interned = Json::obj();
    interned.set("requests_per_s", Json::from(sub_rps[0]));
    let mut named = Json::obj();
    named.set("requests_per_s", Json::from(sub_rps[1]));
    submission_json.set("interned", interned);
    submission_json.set("named", named);
    submission_json.set("speedup_interned_vs_named", Json::from(submission_speedup));

    let record = Json::from_pairs(vec![
        ("bench", Json::from("serve_packed_forward")),
        ("smoke", Json::from(smoke())),
        ("shape", Json::Arr(vec![Json::from(m), Json::from(n)])),
        ("rank", Json::from(r)),
        ("group_size", Json::from(64usize)),
        ("fused_vs_dense", Json::Arr(fused_records)),
        ("speedup_fused_vs_dense_remat_4bit", Json::from(speedup_vs_remat_4bit)),
        ("speedup_fused_vs_dense_cached_4bit", Json::from(speedup_vs_cached_4bit)),
        ("kernel_batch_sweep", Json::Arr(batch_records)),
        ("kernel_batched_vs_serial_speedup", Json::from(kernel_batch_speedup)),
        ("engine", engine_json),
        ("engine_batched_vs_serial_speedup", Json::from(engine_speedup)),
        ("submission", submission_json),
        (
            "parity",
            Json::from(
                "fused == dense reference bit-exact; batch == serial bit-exact — \
                 enforced by rust/tests/parity_serve.rs",
            ),
        ),
    ]);
    write_bench_json("serve", record);
    if kernel_batch_speedup < 1.0 {
        // Timing noise must not turn a measurement into a flaky bench exit;
        // correctness is enforced by the parity suite.
        eprintln!(
            "WARNING: batched kernel measured slower than serial ({kernel_batch_speedup:.2}x)"
        );
    }
}

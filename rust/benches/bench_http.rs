//! HTTP front-end benchmarks — the numbers behind EXPERIMENTS.md §HTTP,
//! emitted as BENCH_http.json:
//!
//! 1. **requests/s vs keep-alive connections**: closed-loop clients on
//!    1 / 16 / 64 keep-alive loopback connections, each firing sequential
//!    `POST /v1/submit` calls. This measures the whole wire path — parse,
//!    auth, lazy JSON scan, engine round trip, completion-callback
//!    serialization, rail write — under increasing connection-level
//!    concurrency.
//! 2. **wire overhead vs direct submit**: the SAME request burst through
//!    the in-process typed façade (`submit_all` + wait) and through 16
//!    HTTP connections. The headline `wire_overhead_us` is what one
//!    request pays for leaving the process.
//! 3. **`/metrics` scrape latency**: a full Prometheus scrape round trip
//!    on a keep-alive connection — the cost a metrics poller imposes.
//!
//! Under `CLOQ_BENCH_SMOKE=1` shapes and request counts shrink and the
//! record carries `"smoke": true` so `scripts/bench_diff.py` only
//! compares like against like. Endpoint correctness is NOT measured
//! here — that lives in `rust/tests/http_serve.rs`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Instant;

use cloq::bench::{bench, section, smoke, smoke_scaled, target_time, write_bench_json};
use cloq::linalg::Matrix;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{HttpServer, PackedLayer, PackedModel, Request, ServeEngine};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

const TOKEN: &str = "tok-bench";

/// Minimal blocking client: send raw bytes, frame responses by
/// Content-Length. Allocation-light on purpose — the bench should time
/// the server, not the harness.
struct Client {
    stream: TcpStream,
    buf: Vec<u8>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_nodelay(true).unwrap();
        Client { stream, buf: Vec::new() }
    }

    fn roundtrip(&mut self, request: &[u8]) -> u16 {
        self.stream.write_all(request).unwrap();
        let mut tmp = [0u8; 8192];
        loop {
            if let Some(pos) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                let head = std::str::from_utf8(&self.buf[..pos]).unwrap();
                let status: u16 = head.split(' ').nth(1).unwrap().parse().unwrap();
                let cl = head
                    .lines()
                    .find_map(|l| {
                        let (k, v) = l.split_once(':')?;
                        k.eq_ignore_ascii_case("content-length")
                            .then(|| v.trim().parse::<usize>().unwrap())
                    })
                    .unwrap_or(0);
                let total = pos + 4 + cl;
                while self.buf.len() < total {
                    let n = self.stream.read(&mut tmp).unwrap();
                    assert!(n > 0, "server closed mid-response");
                    self.buf.extend_from_slice(&tmp[..n]);
                }
                self.buf.drain(..total);
                return status;
            }
            let n = self.stream.read(&mut tmp).unwrap();
            assert!(n > 0, "server closed before a response");
            self.buf.extend_from_slice(&tmp[..n]);
        }
    }
}

fn submit_request(x: &[f64]) -> Vec<u8> {
    let xs = x.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",");
    let body = format!("{{\"layer\":\"bench\",\"x\":[{xs}]}}");
    format!(
        "POST /v1/submit HTTP/1.1\r\nAuthorization: Bearer {TOKEN}\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    )
    .into_bytes()
}

/// Closed-loop burst: `conns` keep-alive connections, each firing its
/// share of `total` sequential requests. Returns wall seconds.
fn http_burst(addr: SocketAddr, request: &[u8], conns: usize, total: usize) -> f64 {
    let per = total / conns;
    let t0 = Instant::now();
    let handles: Vec<_> = (0..conns)
        .map(|_| {
            let request = request.to_vec();
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                for _ in 0..per {
                    assert_eq!(c.roundtrip(&request), 200);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    t0.elapsed().as_secs_f64()
}

fn main() {
    let mut rng = Rng::new(29);
    let t = target_time(0.3);
    let (m, n) = (smoke_scaled(128, 32), smoke_scaled(128, 32));
    let w = Matrix::randn(m, n, 0.3, &mut rng);
    let layer = PackedLayer::from_state("bench", &QuantState::Int(quantize_rtn(&w, 4, 32)))
        .unwrap();
    let engine = Arc::new(
        ServeEngine::builder(PackedModel::new(vec![layer]))
            .workers(2)
            .max_batch(32)
            .build()
            .unwrap(),
    );
    let server = HttpServer::builder(Arc::clone(&engine))
        .max_connections(128)
        .tenant("bench", TOKEN, 256)
        .build()
        .unwrap();
    let addr = server.addr();
    let x = rng.gauss_vec(m);
    let request = submit_request(&x);

    // ---- 1. requests/s vs keep-alive connections --------------------------
    let connection_counts = [1usize, 16, 64];
    let total = smoke_scaled(2048, 192);
    let rounds = smoke_scaled(3, 2);
    section(&format!(
        "http throughput: {total} POST /v1/submit ({m}x{n}) over 1/16/64 keep-alive connections"
    ));
    let mut sweep = Vec::new();
    for &conns in &connection_counts {
        let mut best = f64::INFINITY;
        for _ in 0..rounds {
            best = best.min(http_burst(addr, &request, conns, total));
        }
        let served = (total / conns) * conns; // integer split, exact count
        let rps = served as f64 / best;
        println!("  {conns:>3} connections: {rps:>9.0} req/s (best of {rounds})");
        sweep.push(Json::from_pairs(vec![
            ("connections", Json::from(conns)),
            ("requests", Json::from(served)),
            ("best_wall_s", Json::from(best)),
            ("requests_per_s", Json::from(rps)),
        ]));
    }

    // ---- 2. wire overhead vs the in-process façade ------------------------
    section("wire overhead: 16 http connections vs direct submit_all");
    let lid = engine.layer("bench").unwrap();
    let mut direct_wall = f64::INFINITY;
    for _ in 0..rounds {
        let reqs: Vec<Request> = (0..total).map(|_| Request::base(lid, x.clone())).collect();
        let t0 = Instant::now();
        for tk in engine.submit_all(reqs) {
            tk.wait().unwrap();
        }
        direct_wall = direct_wall.min(t0.elapsed().as_secs_f64());
    }
    let mut http_wall = f64::INFINITY;
    for _ in 0..rounds {
        http_wall = http_wall.min(http_burst(addr, &request, 16, total));
    }
    let served = (total / 16) * 16;
    let direct_rps = total as f64 / direct_wall;
    let http_rps = served as f64 / http_wall;
    let wire_overhead_us = (http_wall / served as f64 - direct_wall / total as f64) * 1e6;
    println!(
        "  direct {direct_rps:>9.0} req/s, http {http_rps:>9.0} req/s → \
         wire overhead {wire_overhead_us:.1} µs/request"
    );
    let overhead_json = Json::from_pairs(vec![
        (
            "direct",
            Json::from_pairs(vec![
                ("requests", Json::from(total)),
                ("best_wall_s", Json::from(direct_wall)),
                ("requests_per_s", Json::from(direct_rps)),
            ]),
        ),
        (
            "http",
            Json::from_pairs(vec![
                ("requests", Json::from(served)),
                ("best_wall_s", Json::from(http_wall)),
                ("requests_per_s", Json::from(http_rps)),
            ]),
        ),
        ("wire_overhead_us", Json::from(wire_overhead_us)),
    ]);

    // ---- 3. /metrics scrape latency ---------------------------------------
    section("scrape: GET /metrics round trip on one keep-alive connection");
    let scrape = b"GET /metrics HTTP/1.1\r\n\r\n";
    let mut c = Client::connect(addr);
    let r_scrape = bench("GET /metrics", t, || c.roundtrip(scrape));
    println!("  scrape {:.1} µs round trip", r_scrape.min_s * 1e6);
    let scrape_json = r_scrape.to_json();

    server.shutdown();
    drop(c);

    let record = Json::from_pairs(vec![
        ("bench", Json::from("http")),
        ("smoke", Json::from(smoke())),
        ("shape", Json::Arr(vec![Json::from(m), Json::from(n)])),
        (
            "connection_counts",
            Json::Arr(connection_counts.iter().map(|&c| Json::from(c)).collect()),
        ),
        ("connections", Json::from_pairs(vec![("sweep", Json::Arr(sweep))])),
        ("overhead", overhead_json),
        ("scrape", scrape_json),
        (
            "parity",
            Json::from(
                "0-ULP wire parity vs the in-process façade, the rejection taxonomy, and \
                 byte-split robustness are enforced by rust/tests/http_serve.rs",
            ),
        ),
    ]);
    write_bench_json("http", record);
}

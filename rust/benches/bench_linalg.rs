//! Linear-algebra substrate benchmarks: GEMM / SYRK / SVD / eig / Cholesky
//! scaling. These are the primitives under OPTQ (Cholesky + rank-1-ish
//! updates) and CLoQ (eig + SVD), so their scaling curves bound every
//! init-cost number in Table 10.
//!
//! Run: `cargo bench --bench bench_linalg` (offline: add `--offline`).

use cloq::bench::{bench, section};
use cloq::linalg::chol::{cholesky, inv_spd};
use cloq::linalg::eig::sym_eig;
use cloq::linalg::{matmul, svd, syrk_t, Matrix};
use cloq::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let t = 0.3;

    section("GEMM (square)");
    for n in [32usize, 64, 128, 256] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let r = bench(&format!("matmul {n}x{n}x{n}"), t, || matmul(&a, &b));
        let flops = 2.0 * (n as f64).powi(3);
        println!("    -> {:.2} GFLOP/s", flops / r.min_s / 1e9);
    }

    section("SYRK (Gram accumulation, calibration shape)");
    for (s, f) in [(512usize, 96usize), (512, 256), (2048, 96)] {
        let x = Matrix::randn(s, f, 1.0, &mut rng);
        bench(&format!("syrk_t {s}x{f}"), t, || syrk_t(&x));
    }

    section("Cholesky + SPD inverse (OPTQ inner)");
    for n in [64usize, 128, 256] {
        let x = Matrix::randn(n + 16, n, 1.0, &mut rng);
        let mut h = syrk_t(&x);
        h.add_diag(0.1);
        bench(&format!("cholesky {n}"), t, || cholesky(&h).unwrap());
        bench(&format!("inv_spd {n}"), t, || inv_spd(&h).unwrap());
    }

    section("Symmetric eig (CLoQ step 3)");
    for n in [32usize, 64, 96, 128] {
        let x = Matrix::randn(n + 16, n, 1.0, &mut rng);
        let h = syrk_t(&x);
        bench(&format!("sym_eig {n}"), t, || sym_eig(&h));
    }

    section("SVD (CLoQ step 5)");
    for (m, n) in [(64usize, 48usize), (96, 64), (128, 96), (96, 256)] {
        let a = Matrix::randn(m, n, 1.0, &mut rng);
        bench(&format!("svd {m}x{n}"), t, || svd(&a));
    }
}

//! Linear-algebra substrate benchmarks: GEMM / SYRK / SVD / eig / Cholesky
//! scaling. These are the primitives under OPTQ (Cholesky + rank-1-ish
//! updates) and CLoQ (eig + SVD), so their scaling curves bound every
//! init-cost number in Table 10.
//!
//! Run: `cargo bench --bench bench_linalg` (offline: add `--offline`).
//!
//! The tiled-vs-naive section emits BENCH_linalg.json (EXPERIMENTS.md
//! §Perf).
//!
//! Under `CLOQ_BENCH_SMOKE=1` (the CI bench-smoke job) sizes and target
//! times shrink and the record carries `"smoke": true` so
//! `scripts/bench_diff.py` only compares like against like.

use cloq::bench::{bench, section, smoke, smoke_scaled, target_time, write_bench_json};
use cloq::linalg::chol::{chol_inv_upper, cholesky, inv_spd};
use cloq::linalg::eig::sym_eig;
use cloq::linalg::{
    matmul, matmul_naive, matmul_nt_tiled, matmul_tiled, svd, syrk_t_tiled, syrk_t, Matrix,
};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let t = target_time(0.3);
    let mut records = Vec::new();

    section("GEMM (square)");
    let gemm_ns: Vec<usize> = if smoke() { vec![32, 64] } else { vec![32, 64, 128, 256] };
    for &n in &gemm_ns {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let r = bench(&format!("matmul {n}x{n}x{n}"), t, || matmul(&a, &b));
        let flops = 2.0 * (n as f64).powi(3);
        println!("    -> {:.2} GFLOP/s", flops / r.min_s / 1e9);
    }

    section("SYRK (Gram accumulation, calibration shape)");
    let syrk_shapes: Vec<(usize, usize)> =
        if smoke() { vec![(256, 64)] } else { vec![(512, 96), (512, 256), (2048, 96)] };
    for &(s, f) in &syrk_shapes {
        let x = Matrix::randn(s, f, 1.0, &mut rng);
        bench(&format!("syrk_t {s}x{f}"), t, || syrk_t(&x));
    }

    section("tiled vs naive GEMM (square)");
    let tiled_ns: Vec<usize> = if smoke() { vec![64, 128] } else { vec![64, 128, 256, 384] };
    for &n in &tiled_ns {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let r_naive = bench(&format!("matmul_naive {n}^3"), t, || matmul_naive(&a, &b));
        let r_tiled = bench(&format!("matmul_tiled {n}^3"), t, || matmul_tiled(&a, &b));
        println!("    -> tiled speedup {:.2}x", r_naive.min_s / r_tiled.min_s);
        let mut rec = Json::from_pairs(vec![
            ("kernel", Json::from("matmul")),
            ("n", Json::from(n)),
            ("naive", r_naive.to_json()),
            ("tiled", r_tiled.to_json()),
            ("speedup", Json::from(r_naive.min_s / r_tiled.min_s)),
        ]);
        // Transposed-B panel form at the same size.
        let r_nt = bench(&format!("matmul_nt_tiled {n}^3"), t, || matmul_nt_tiled(&a, &b));
        rec.set("nt_tiled", r_nt.to_json());
        records.push(rec);
    }

    section("tiled vs plain SYRK (Gram accumulation, wide layer)");
    let (syrk_s, syrk_f) = (smoke_scaled(2048, 512), smoke_scaled(512, 128));
    {
        let x = Matrix::randn(syrk_s, syrk_f, 1.0, &mut rng);
        let r_tiled =
            bench(&format!("syrk_t_tiled {syrk_s}x{syrk_f}"), t, || syrk_t_tiled(&x));
        records.push(Json::from_pairs(vec![
            ("kernel", Json::from("syrk_t")),
            ("shape", Json::Arr(vec![Json::from(syrk_s), Json::from(syrk_f)])),
            ("tiled", r_tiled.to_json()),
        ]));
    }

    section("Cholesky + SPD inverse (OPTQ inner)");
    let chol_ns: Vec<usize> = if smoke() { vec![64] } else { vec![64, 128, 256] };
    for &n in &chol_ns {
        let x = Matrix::randn(n + 16, n, 1.0, &mut rng);
        let mut h = syrk_t(&x);
        h.add_diag(0.1);
        bench(&format!("cholesky {n}"), t, || cholesky(&h).unwrap());
        bench(&format!("inv_spd {n}"), t, || inv_spd(&h).unwrap());
        // The seed OPTQ setup (inv_spd + re-factorize) vs the fused root.
        let r_seed = bench(&format!("U via inv_spd+cholesky {n}"), t, || {
            cholesky(&inv_spd(&h).unwrap()).unwrap().transpose()
        });
        let r_fast = bench(&format!("U via chol_inv_upper {n}"), t, || {
            chol_inv_upper(&h).unwrap()
        });
        println!("    -> root speedup {:.2}x", r_seed.min_s / r_fast.min_s);
        records.push(Json::from_pairs(vec![
            ("kernel", Json::from("inv_hessian_root")),
            ("n", Json::from(n)),
            ("seed_route", r_seed.to_json()),
            ("chol_inv_upper", r_fast.to_json()),
            ("speedup", Json::from(r_seed.min_s / r_fast.min_s)),
        ]));
    }

    section("Symmetric eig (CLoQ step 3)");
    let eig_ns: Vec<usize> = if smoke() { vec![32, 64] } else { vec![32, 64, 96, 128] };
    for &n in &eig_ns {
        let x = Matrix::randn(n + 16, n, 1.0, &mut rng);
        let h = syrk_t(&x);
        bench(&format!("sym_eig {n}"), t, || sym_eig(&h));
    }

    section("SVD (CLoQ step 5)");
    let svd_shapes: Vec<(usize, usize)> =
        if smoke() { vec![(64, 48)] } else { vec![(64, 48), (96, 64), (128, 96), (96, 256)] };
    for &(m, n) in &svd_shapes {
        let a = Matrix::randn(m, n, 1.0, &mut rng);
        bench(&format!("svd {m}x{n}"), t, || svd(&a));
    }

    write_bench_json(
        "linalg",
        Json::from_pairs(vec![
            ("bench", Json::from("linalg_tiled_kernels")),
            ("smoke", Json::from(smoke())),
            // Identity key for bench_diff: records pair by index, so the
            // gate must refuse comparison when ANY sweep feeding the
            // records array (tiled GEMM, syrk shape, Cholesky-root ns)
            // is re-sized.
            (
                "sizes",
                Json::Arr(
                    tiled_ns
                        .iter()
                        .chain(&[syrk_s, syrk_f])
                        .chain(&chol_ns)
                        .map(|&n| Json::from(n))
                        .collect(),
                ),
            ),
            ("records", Json::Arr(records)),
        ]),
    );
}

//! Linear-algebra substrate benchmarks: GEMM / SYRK / SVD / eig / Cholesky
//! scaling. These are the primitives under OPTQ (Cholesky + rank-1-ish
//! updates) and CLoQ (eig + SVD), so their scaling curves bound every
//! init-cost number in Table 10.
//!
//! Run: `cargo bench --bench bench_linalg` (offline: add `--offline`).
//!
//! The tiled-vs-naive section emits BENCH_linalg.json (EXPERIMENTS.md
//! §Perf).

use cloq::bench::{bench, section, write_bench_json};
use cloq::linalg::chol::{chol_inv_upper, cholesky, inv_spd};
use cloq::linalg::eig::sym_eig;
use cloq::linalg::{
    matmul, matmul_naive, matmul_nt_tiled, matmul_tiled, svd, syrk_t_tiled, syrk_t, Matrix,
};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

fn main() {
    let mut rng = Rng::new(1);
    let t = 0.3;
    let mut records = Vec::new();

    section("GEMM (square)");
    for n in [32usize, 64, 128, 256] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let r = bench(&format!("matmul {n}x{n}x{n}"), t, || matmul(&a, &b));
        let flops = 2.0 * (n as f64).powi(3);
        println!("    -> {:.2} GFLOP/s", flops / r.min_s / 1e9);
    }

    section("SYRK (Gram accumulation, calibration shape)");
    for (s, f) in [(512usize, 96usize), (512, 256), (2048, 96)] {
        let x = Matrix::randn(s, f, 1.0, &mut rng);
        bench(&format!("syrk_t {s}x{f}"), t, || syrk_t(&x));
    }

    section("tiled vs naive GEMM (square)");
    for n in [64usize, 128, 256, 384] {
        let a = Matrix::randn(n, n, 1.0, &mut rng);
        let b = Matrix::randn(n, n, 1.0, &mut rng);
        let r_naive = bench(&format!("matmul_naive {n}^3"), t, || matmul_naive(&a, &b));
        let r_tiled = bench(&format!("matmul_tiled {n}^3"), t, || matmul_tiled(&a, &b));
        println!("    -> tiled speedup {:.2}x", r_naive.min_s / r_tiled.min_s);
        let mut rec = Json::from_pairs(vec![
            ("kernel", Json::from("matmul")),
            ("n", Json::from(n)),
            ("naive", r_naive.to_json()),
            ("tiled", r_tiled.to_json()),
            ("speedup", Json::from(r_naive.min_s / r_tiled.min_s)),
        ]);
        // Transposed-B panel form at the same size.
        let r_nt = bench(&format!("matmul_nt_tiled {n}^3"), t, || matmul_nt_tiled(&a, &b));
        rec.set("nt_tiled", r_nt.to_json());
        records.push(rec);
    }

    section("tiled vs plain SYRK (Gram accumulation, 512-wide layer)");
    {
        let x = Matrix::randn(2048, 512, 1.0, &mut rng);
        let r_tiled = bench("syrk_t_tiled 2048x512", t, || syrk_t_tiled(&x));
        records.push(Json::from_pairs(vec![
            ("kernel", Json::from("syrk_t")),
            ("shape", Json::Arr(vec![Json::from(2048usize), Json::from(512usize)])),
            ("tiled", r_tiled.to_json()),
        ]));
    }

    section("Cholesky + SPD inverse (OPTQ inner)");
    for n in [64usize, 128, 256] {
        let x = Matrix::randn(n + 16, n, 1.0, &mut rng);
        let mut h = syrk_t(&x);
        h.add_diag(0.1);
        bench(&format!("cholesky {n}"), t, || cholesky(&h).unwrap());
        bench(&format!("inv_spd {n}"), t, || inv_spd(&h).unwrap());
        // The seed OPTQ setup (inv_spd + re-factorize) vs the fused root.
        let r_seed = bench(&format!("U via inv_spd+cholesky {n}"), t, || {
            cholesky(&inv_spd(&h).unwrap()).unwrap().transpose()
        });
        let r_fast = bench(&format!("U via chol_inv_upper {n}"), t, || {
            chol_inv_upper(&h).unwrap()
        });
        println!("    -> root speedup {:.2}x", r_seed.min_s / r_fast.min_s);
        records.push(Json::from_pairs(vec![
            ("kernel", Json::from("inv_hessian_root")),
            ("n", Json::from(n)),
            ("seed_route", r_seed.to_json()),
            ("chol_inv_upper", r_fast.to_json()),
            ("speedup", Json::from(r_seed.min_s / r_fast.min_s)),
        ]));
    }

    section("Symmetric eig (CLoQ step 3)");
    for n in [32usize, 64, 96, 128] {
        let x = Matrix::randn(n + 16, n, 1.0, &mut rng);
        let h = syrk_t(&x);
        bench(&format!("sym_eig {n}"), t, || sym_eig(&h));
    }

    section("SVD (CLoQ step 5)");
    for (m, n) in [(64usize, 48usize), (96, 64), (128, 96), (96, 256)] {
        let a = Matrix::randn(m, n, 1.0, &mut rng);
        bench(&format!("svd {m}x{n}"), t, || svd(&a));
    }

    write_bench_json(
        "linalg",
        Json::from_pairs(vec![
            ("bench", Json::from("linalg_tiled_kernels")),
            ("records", Json::Arr(records)),
        ]),
    );
}

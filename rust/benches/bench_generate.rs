//! Token-level generation benchmarks — the numbers behind EXPERIMENTS.md
//! §Generate, emitted as BENCH_generate.json:
//!
//! 1. **serial decode baseline**: `generate_serial` over the same session
//!    plans, no queues, no concurrency — the per-token cost floor a
//!    single caller pays.
//! 2. **engine decode under Poisson load**: sessions admitted with
//!    exponential inter-arrival times and heavy-tailed (Zipf) prompt and
//!    output lengths — the open-loop arrival shape real serving sees.
//!    One consumer thread per session drains the token stream recording
//!    per-token timestamps; the record carries TTFT (admission → first
//!    token) and ITL (token → next token) p50/p95/p99 plus aggregate
//!    decoded tokens/s.
//!
//! Under `CLOQ_BENCH_SMOKE=1` (the CI bench-smoke job) session counts and
//! output lengths shrink and the record carries `"smoke": true` so
//! `scripts/bench_diff.py` only compares like against like. The committed
//! smoke baseline is deliberately conservative (generous latencies, low
//! throughput floors): latency percentiles under open-loop load are far
//! noisier than closed-loop min-time rows, and the gate must catch
//! collapses, not jitter.
//!
//! Correctness is NOT measured here — pipelined decode is bit-exact vs
//! `generate_serial` by `rust/tests/parity_generate.rs`; this file is
//! pure speed.

use std::thread;
use std::time::{Duration, Instant};

use cloq::bench::{section, smoke, smoke_scaled, write_bench_json};
use cloq::linalg::Matrix;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    generate_serial, GenEvent, GenParams, GenRequest, PackedLayer, PackedModel, ServeEngine,
};
use cloq::util::json::Json;
use cloq::util::prng::{Rng, Zipf};

/// Loopable 32 → 24 → 28 → 32 chain; the 32-wide tail is the decode
/// vocabulary (specials + the first 28 byte ids).
fn chain_model(seed: u64) -> PackedModel {
    let mut rng = Rng::new(seed);
    let mut layers = Vec::new();
    for (name, m, n) in [("a", 32usize, 24usize), ("b", 24, 28), ("c", 28, 32)] {
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let q = QuantState::Int(quantize_rtn(&w, 4, 8));
        layers.push(PackedLayer::from_state(name, &q).unwrap());
    }
    PackedModel::new(layers)
}

/// Nearest-rank percentile of an ascending-sorted slice.
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    sorted[(((sorted.len() - 1) as f64) * q).round() as usize]
}

fn main() {
    let workers = 4usize;
    let sessions = smoke_scaled(48, 8);
    let mean_gap_s = 0.002; // Poisson arrivals: mean inter-arrival time

    // Heavy-tailed session plans (deterministic): Zipf-ranked prompt and
    // output lengths — most sessions short, a few long, like real decode
    // traffic.
    let mut rng = Rng::new(17);
    let prompt_zipf = Zipf::new(24, 1.1);
    let tokens_zipf = Zipf::new(smoke_scaled(96, 24), 1.05);
    let plans: Vec<(String, usize)> = (0..sessions)
        .map(|i| {
            let plen = 4 + 3 * prompt_zipf.sample(&mut rng);
            let prompt: String =
                (0..plen).map(|k| char::from(b'a' + ((i + k) % 26) as u8)).collect();
            let max_tokens = 4 + tokens_zipf.sample(&mut rng);
            (prompt, max_tokens)
        })
        .collect();
    let route_names: Vec<String> = ["a", "b", "c"].iter().map(|s| s.to_string()).collect();

    // ---- serial decode baseline ------------------------------------------
    section(&format!("serial decode baseline ({sessions} sessions, generate_serial)"));
    let model = chain_model(18);
    let serial_route = model.route(&route_names).unwrap();
    let t0 = Instant::now();
    let mut serial_tokens = 0usize;
    for (prompt, max_tokens) in &plans {
        let r =
            generate_serial(&model, &serial_route, None, prompt, &GenParams::greedy(*max_tokens));
        serial_tokens += r.tokens.len();
    }
    let serial_wall = t0.elapsed().as_secs_f64();
    let serial_tps = serial_tokens as f64 / serial_wall.max(1e-12);
    println!(
        "serial   {serial_tokens} tokens in {serial_wall:.4}s → {serial_tps:.0} tokens/s"
    );

    // ---- engine decode under Poisson open-loop load ----------------------
    section(&format!(
        "engine decode under Poisson load ({sessions} sessions, {workers} workers, \
         mean gap {:.1}ms)",
        mean_gap_s * 1e3
    ));
    let engine =
        ServeEngine::builder(chain_model(18)).workers(workers).max_batch(8).build().unwrap();
    let route = engine.route(&route_names).unwrap();
    let mut arrival_rng = Rng::new(19);
    let t_start = Instant::now();
    let mut handles = Vec::new();
    for (prompt, max_tokens) in &plans {
        // Exponential inter-arrival gap — an open-loop Poisson process,
        // so queueing shows up in TTFT instead of being absorbed by
        // closed-loop backpressure.
        let gap = -mean_gap_s * (1.0 - arrival_rng.f64()).ln();
        thread::sleep(Duration::from_secs_f64(gap));
        let t_admit = Instant::now();
        let ticket =
            engine.generate(GenRequest::new(route.clone(), prompt, GenParams::greedy(*max_tokens)));
        handles.push(thread::spawn(move || {
            let mut prev = t_admit;
            let mut ttft = 0.0f64;
            let mut itl = Vec::new();
            let mut tokens = 0usize;
            loop {
                match ticket.next_token().wait().unwrap() {
                    GenEvent::Token { .. } => {
                        let now = Instant::now();
                        if tokens == 0 {
                            ttft = (now - t_admit).as_secs_f64();
                        } else {
                            itl.push((now - prev).as_secs_f64());
                        }
                        prev = now;
                        tokens += 1;
                    }
                    GenEvent::Done(_) => break,
                }
            }
            (ttft, itl, tokens)
        }));
    }
    let mut ttfts = Vec::new();
    let mut itls = Vec::new();
    let mut load_tokens = 0usize;
    for h in handles {
        let (ttft, itl, tokens) = h.join().unwrap();
        ttfts.push(ttft);
        itls.extend(itl);
        load_tokens += tokens;
    }
    let load_wall = t_start.elapsed().as_secs_f64();
    let load_tps = load_tokens as f64 / load_wall.max(1e-12);
    let stats = engine.shutdown();
    ttfts.sort_by(|a, b| a.partial_cmp(b).unwrap());
    itls.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (ttft_p50, ttft_p95, ttft_p99) =
        (percentile(&ttfts, 0.50), percentile(&ttfts, 0.95), percentile(&ttfts, 0.99));
    let (itl_p50, itl_p95, itl_p99) =
        (percentile(&itls, 0.50), percentile(&itls, 0.95), percentile(&itls, 0.99));
    println!(
        "load     {load_tokens} tokens in {load_wall:.4}s → {load_tps:.0} tokens/s \
         (mean batch {:.1})",
        stats.mean_batch()
    );
    println!(
        "TTFT     p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms",
        ttft_p50 * 1e3,
        ttft_p95 * 1e3,
        ttft_p99 * 1e3
    );
    println!(
        "ITL      p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms  ({} gaps)",
        itl_p50 * 1e3,
        itl_p95 * 1e3,
        itl_p99 * 1e3,
        itls.len()
    );

    let mut arrivals = Json::obj();
    arrivals.set("process", Json::from("poisson"));
    arrivals.set("mean_interarrival_s", Json::from(mean_gap_s));
    let mut serial = Json::obj();
    serial.set("tokens", Json::from(serial_tokens));
    serial.set("wall_s", Json::from(serial_wall));
    serial.set("tokens_per_s", Json::from(serial_tps));
    let mut load = Json::obj();
    load.set("total_tokens", Json::from(load_tokens));
    load.set("wall_s", Json::from(load_wall));
    load.set("tokens_per_s", Json::from(load_tps));
    load.set("ttft_p50_s", Json::from(ttft_p50));
    load.set("ttft_p95_s", Json::from(ttft_p95));
    load.set("ttft_p99_s", Json::from(ttft_p99));
    load.set("itl_p50_s", Json::from(itl_p50));
    load.set("itl_p95_s", Json::from(itl_p95));
    load.set("itl_p99_s", Json::from(itl_p99));
    load.set("itl_gaps", Json::from(itls.len()));
    load.set("mean_batch", Json::from(stats.mean_batch()));

    let record = Json::from_pairs(vec![
        ("bench", Json::from("generate")),
        ("smoke", Json::from(smoke())),
        ("layers", Json::from(3usize)),
        ("workers", Json::from(workers)),
        ("sessions", Json::from(sessions)),
        ("arrivals", arrivals),
        ("serial", serial),
        ("load", load),
        (
            "parity",
            Json::from(
                "pipelined decode == generate_serial bit-exact — \
                 enforced by rust/tests/parity_generate.rs",
            ),
        ),
    ]);
    write_bench_json("generate", record);
}

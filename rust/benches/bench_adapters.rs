//! Multi-adapter serving benchmarks — the numbers behind EXPERIMENTS.md
//! §Adapters, emitted as BENCH_adapters.json:
//!
//! 1. **adapter-count sweep**: end-to-end engine throughput with requests
//!    spread round-robin over 1 / 8 / 64 registered adapters on ONE packed
//!    base. The base pass dominates, so throughput should degrade only
//!    mildly as the tenant count grows — that near-flatness IS the
//!    multi-tenant win (one base, many adapters), and this sweep is the
//!    regression guard on it.
//! 2. **mixed-adapter batch penalty**: kernel-level cost of a micro-batch
//!    whose rows belong to k adapter groups vs an adapter-uniform batch of
//!    the same size, plus the unsorted worst case (every row a new group)
//!    that the engine's batch sorter exists to avoid.
//! 3. **eviction churn**: registry register/evict throughput under a tight
//!    byte budget, plus hot-swap (same-id re-register) rate.
//!
//! Under `CLOQ_BENCH_SMOKE=1` (the CI bench-smoke job) shapes and counts
//! shrink and the record carries `"smoke": true` so `scripts/bench_diff.py`
//! only compares like against like.
//!
//! Correctness is NOT measured here: mixed-batch bit-exactness is enforced
//! by `rust/tests/parity_serve.rs`, lifecycle invariants by
//! `rust/tests/lifecycle_adapters.rs`.

use std::sync::Arc;
use std::time::Instant;

use cloq::bench::{bench, section, smoke, smoke_scaled, target_time, write_bench_json};
use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    AdapterRegistry, AdapterSet, PackedLayer, PackedModel, Request, ServeEngine,
};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

fn mk_base(m: usize, n: usize, rng: &mut Rng) -> PackedModel {
    let w = Matrix::randn(m, n, 0.3, rng);
    let q = QuantState::Int(quantize_rtn(&w, 4, 64));
    PackedModel::new(vec![PackedLayer::from_state("lin", &q).unwrap()])
}

fn mk_set(id: &str, m: usize, n: usize, r: usize, rng: &mut Rng) -> AdapterSet {
    let pair = LoraPair::new(Matrix::randn(m, r, 0.1, rng), Matrix::randn(n, r, 0.1, rng));
    AdapterSet::from_pairs(id, vec![("lin".to_string(), pair)]).unwrap()
}

fn main() {
    let mut rng = Rng::new(21);
    let t = target_time(0.3);
    let (m, n) = (smoke_scaled(384, 96), smoke_scaled(384, 96));
    let r = 8usize;

    // ---- 1. adapter-count sweep ------------------------------------------
    let n_req = smoke_scaled(512, 64);
    section(&format!(
        "engine throughput vs adapter count ({m}x{n}, rank {r}, {n_req} requests)"
    ));
    let adapter_counts: Vec<usize> = if smoke() { vec![1, 4, 8] } else { vec![1, 8, 64] };
    let xs: Vec<Vec<f64>> = (0..n_req).map(|_| rng.gauss_vec(m)).collect();
    let mut sweep_records = Vec::new();
    let mut rps_1 = 0.0f64;
    let mut rps_max_adapters = 0.0f64;
    for &n_adapters in &adapter_counts {
        let mut best = f64::INFINITY;
        let mut best_stats = None;
        for _ in 0..3 {
            let engine = ServeEngine::builder(mk_base(m, n, &mut Rng::new(22)))
                .workers(2)
                .max_batch(16)
                .build()
                .unwrap();
            let lid = engine.layer("lin").unwrap();
            let mut arng = Rng::new(23);
            // Intern once per tenant; the request loop is handle-only.
            let tids: Vec<_> = (0..n_adapters)
                .map(|a| {
                    let set = mk_set(&format!("t{a}"), m, n, r, &mut arng);
                    engine.register_adapter(set).unwrap().id
                })
                .collect();
            let reqs: Vec<Request> = xs
                .iter()
                .enumerate()
                .map(|(i, x)| Request::with_adapter(lid, tids[i % n_adapters], x.clone()))
                .collect();
            let t0 = Instant::now();
            let tickets = engine.submit_all(reqs);
            for tk in tickets {
                tk.wait().unwrap();
            }
            let wall = t0.elapsed().as_secs_f64();
            let stats = engine.shutdown();
            if wall < best {
                best = wall;
                best_stats = Some(stats);
            }
        }
        let stats = best_stats.unwrap();
        let rps = n_req as f64 / best;
        if n_adapters == 1 {
            rps_1 = rps;
        }
        rps_max_adapters = rps; // last iteration = largest count
        println!(
            "adapters={n_adapters:<3} {n_req} reqs in {best:.4}s → {rps:.0} req/s \
             (mean batch {:.1}, mixed batches {})",
            stats.mean_batch(),
            stats.mixed_batches
        );
        let mut rec = Json::obj();
        rec.set("adapters", Json::from(n_adapters));
        rec.set("requests", Json::from(n_req));
        rec.set("best_wall_s", Json::from(best));
        rec.set("requests_per_s", Json::from(rps));
        rec.set("mean_batch", Json::from(stats.mean_batch()));
        rec.set("mixed_batches", Json::from(stats.mixed_batches));
        sweep_records.push(rec);
    }
    let multi_tenant_retention = rps_max_adapters / rps_1.max(1e-30);
    println!(
        "\nthroughput retained at {} adapters vs 1: {:.2}x",
        adapter_counts.last().unwrap(),
        multi_tenant_retention
    );

    // ---- 2. mixed-adapter batch penalty (kernel level) --------------------
    section(&format!("mixed-adapter batch penalty ({m}x{n}, batch 32)"));
    let base = mk_base(m, n, &mut Rng::new(24));
    let layer = base.layer("lin").unwrap();
    let pairs: Vec<LoraPair> = (0..8)
        .map(|_| {
            LoraPair::new(
                Matrix::randn(m, r, 0.1, &mut rng),
                Matrix::randn(n, r, 0.1, &mut rng),
            )
        })
        .collect();
    let batch = 32usize;
    let xsb = Matrix::randn(batch, m, 1.0, &mut rng);
    let uniform: Vec<Option<&LoraPair>> = vec![Some(&pairs[0]); batch];
    // Sorted: 8 contiguous groups of 4 (what the engine's sorter produces).
    let sorted8: Vec<Option<&LoraPair>> =
        (0..batch).map(|i| Some(&pairs[i / (batch / 8)])).collect();
    // Interleaved: every row a new group — the worst case sorting avoids.
    let interleaved8: Vec<Option<&LoraPair>> = (0..batch).map(|i| Some(&pairs[i % 8])).collect();
    let r_uniform = bench("uniform (1 group)", t, || layer.forward_batch_grouped(&xsb, &uniform));
    let r_sorted =
        bench("8 adapters, sorted (8 groups)", t, || layer.forward_batch_grouped(&xsb, &sorted8));
    let r_interleaved = bench("8 adapters, interleaved (32 groups)", t, || {
        layer.forward_batch_grouped(&xsb, &interleaved8)
    });
    let penalty_sorted = r_sorted.min_s / r_uniform.min_s;
    let penalty_interleaved = r_interleaved.min_s / r_uniform.min_s;
    println!(
        "\nmixed-batch penalty: sorted {penalty_sorted:.2}x, \
         interleaved {penalty_interleaved:.2}x (vs uniform)"
    );
    let mut mixed_json = Json::obj();
    mixed_json.set("batch", Json::from(batch));
    mixed_json.set("uniform", r_uniform.to_json());
    mixed_json.set("sorted_8_groups", r_sorted.to_json());
    mixed_json.set("interleaved_32_groups", r_interleaved.to_json());
    mixed_json.set("penalty_sorted_vs_uniform", Json::from(penalty_sorted));
    mixed_json.set("penalty_interleaved_vs_uniform", Json::from(penalty_interleaved));

    // ---- 3. eviction churn + hot-swap rate --------------------------------
    section("registry churn: LRU eviction under a 4-set budget, hot-swap rate");
    let churn_n = smoke_scaled(64, 16);
    let one_set_bytes = mk_set("probe", m, n, r, &mut Rng::new(25)).bytes();
    // The registry is model-bound now: registration shape-checks and
    // resolves each set against this base, so the churn number includes
    // the real production registration cost.
    let reg_model = Arc::new(mk_base(m, n, &mut Rng::new(28)));
    let r_churn = bench(&format!("register {churn_n} sets, budget 4"), t, || {
        let reg = AdapterRegistry::new(Arc::clone(&reg_model), 4 * one_set_bytes);
        let mut crng = Rng::new(26);
        for i in 0..churn_n {
            reg.register(mk_set(&format!("c{i}"), m, n, r, &mut crng)).unwrap();
        }
        reg.stats().evictions
    });
    let reg = AdapterRegistry::new(Arc::clone(&reg_model), 4 * one_set_bytes);
    let mut crng = Rng::new(26);
    for i in 0..churn_n {
        reg.register(mk_set(&format!("c{i}"), m, n, r, &mut crng)).unwrap();
    }
    let churn_evictions = reg.stats().evictions;
    let registers_per_s = churn_n as f64 / r_churn.min_s;
    let r_swap = bench(&format!("hot-swap same id x{churn_n}"), t, || {
        let reg = AdapterRegistry::new(Arc::clone(&reg_model), 4 * one_set_bytes);
        let mut srng = Rng::new(27);
        for _ in 0..churn_n {
            reg.register(mk_set("hot", m, n, r, &mut srng)).unwrap();
        }
    });
    let swaps_per_s = churn_n as f64 / r_swap.min_s;
    println!(
        "\nchurn: {registers_per_s:.0} registers/s ({churn_evictions} evictions), \
         {swaps_per_s:.0} hot-swaps/s"
    );
    let mut evict_json = Json::obj();
    evict_json.set("budget_sets", Json::from(4usize));
    evict_json.set("registers", Json::from(churn_n));
    evict_json.set("evictions", Json::from(churn_evictions));
    evict_json.set("registers_per_s", Json::from(registers_per_s));
    evict_json.set("hot_swaps_per_s", Json::from(swaps_per_s));
    evict_json.set("set_bytes", Json::from(one_set_bytes));

    let record = Json::from_pairs(vec![
        ("bench", Json::from("serve_adapters")),
        ("smoke", Json::from(smoke())),
        ("shape", Json::Arr(vec![Json::from(m), Json::from(n)])),
        ("rank", Json::from(r)),
        // Identity key for bench_diff: sweep rows pair by index, so the
        // gate must refuse comparison when the adapter counts change.
        (
            "adapter_counts",
            Json::Arr(adapter_counts.iter().map(|&a| Json::from(a)).collect()),
        ),
        ("adapter_sweep", Json::Arr(sweep_records)),
        ("multi_tenant_throughput_retention", Json::from(multi_tenant_retention)),
        ("mixed_batch", mixed_json),
        ("eviction", evict_json),
        (
            "parity",
            Json::from(
                "mixed-adapter batches bit-exact vs serial single-adapter forwards — \
                 enforced by rust/tests/parity_serve.rs and lifecycle_adapters.rs",
            ),
        ),
    ]);
    write_bench_json("adapters", record);
    if multi_tenant_retention < 0.5 {
        eprintln!(
            "WARNING: throughput at {} adapters fell to {multi_tenant_retention:.2}x of \
             single-adapter (timing noise is possible; correctness is unaffected)",
            adapter_counts.last().unwrap()
        );
    }
}

//! Telemetry overhead benchmarks — the numbers behind EXPERIMENTS.md
//! §Observability, emitted as BENCH_telemetry.json:
//!
//! 1. **instrumented vs disabled engine throughput**: the SAME coalescing
//!    burst as `bench_serve`'s engine section, once through an engine with
//!    default telemetry (counters + histograms + per-layer/per-adapter
//!    attribution + tracing) and once with
//!    `TelemetryOptions::disabled()`. The headline `overhead_pct` is the
//!    throughput the instruments cost, and `scripts/bench_diff.py` gates
//!    it ABSOLUTELY at <5% — the subsystem's design budget, not a
//!    relative-to-baseline check.
//! 2. **snapshot + Prometheus render**: merging every shard and walking
//!    the histogram buckets into exposition text. This is the SCRAPE
//!    cost, paid by a metrics thread, never by a request.
//! 3. **trace record cost**: begin → per-hop event → finish through the
//!    bounded ring, instrumented vs disabled, isolated from kernel work
//!    on a standalone core.
//!
//! Under `CLOQ_BENCH_SMOKE=1` (the CI bench-smoke job) shapes and request
//! counts shrink and the record carries `"smoke": true` so
//! `scripts/bench_diff.py` only compares like against like.
//!
//! Counter correctness is NOT measured here — the identity invariants and
//! the Prometheus round-trip live in `rust/tests/telemetry_serve.rs`.

use std::time::Instant;

use cloq::bench::{bench, section, smoke, smoke_scaled, target_time, write_bench_json};
use cloq::linalg::Matrix;
use cloq::lowrank::LoraPair;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{
    AdapterSet, Counter, PackedLayer, PackedModel, Request, ServeEngine, Telemetry,
    TelemetryOptions, TraceKind,
};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

fn mk_layer(m: usize, n: usize, r: usize, rng: &mut Rng) -> (PackedLayer, LoraPair) {
    let w = Matrix::randn(m, n, 0.3, rng);
    let q = quantize_rtn(&w, 4, 64);
    let a = Matrix::randn(m, r, 0.1, rng);
    let b = Matrix::randn(n, r, 0.1, rng);
    let layer = PackedLayer::from_state("bench", &QuantState::Int(q)).unwrap();
    (layer, LoraPair::new(a, b))
}

/// One coalescing burst through a fresh engine (the bench_serve engine
/// idiom: best-of-`rounds`, fresh engine per round so worker spawn is
/// inside the measurement honestly). Returns the best wall time.
fn run_burst(
    layer: &PackedLayer,
    pair: &LoraPair,
    xs: &[Vec<f64>],
    opts: TelemetryOptions,
    rounds: usize,
) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..rounds {
        let model = PackedModel::new(vec![layer.clone()]);
        let engine = ServeEngine::builder(model)
            .workers(2)
            .max_batch(32)
            .telemetry(opts)
            .build()
            .unwrap();
        let set = AdapterSet::from_pairs("tenant", vec![("bench".to_string(), pair.clone())])
            .unwrap();
        let tenant = engine.register_adapter(set).unwrap().id;
        let lid = engine.layer("bench").unwrap();
        let t0 = Instant::now();
        let tickets = engine
            .submit_all(xs.iter().map(|x| Request::with_adapter(lid, tenant, x.clone())).collect());
        for tk in tickets {
            tk.wait().unwrap();
        }
        best = best.min(t0.elapsed().as_secs_f64());
        engine.shutdown();
    }
    best
}

fn main() {
    let mut rng = Rng::new(23);
    let t = target_time(0.3);
    let (m, n) = (smoke_scaled(512, 96), smoke_scaled(512, 96));
    let r = 16usize;
    let (layer, pair) = mk_layer(m, n, r, &mut rng);

    // ---- 1. instrumented vs disabled engine throughput --------------------
    let n_req = smoke_scaled(256, 48);
    section(&format!(
        "telemetry overhead: instrumented vs disabled coalescing ({n_req} requests, {m}x{n})"
    ));
    let xs: Vec<Vec<f64>> = (0..n_req).map(|_| rng.gauss_vec(m)).collect();
    // Interleave the two modes round-robin (rather than 3 rounds of one
    // then 3 of the other) so machine drift during the bench lands on both
    // sides of the ratio evenly — overhead_pct is gated absolutely.
    let rounds = 5;
    let mut wall = [f64::INFINITY; 2]; // [instrumented, disabled]
    for _ in 0..rounds {
        wall[0] = wall[0].min(run_burst(&layer, &pair, &xs, TelemetryOptions::default(), 1));
        wall[1] = wall[1].min(run_burst(&layer, &pair, &xs, TelemetryOptions::disabled(), 1));
    }
    let rps = [n_req as f64 / wall[0], n_req as f64 / wall[1]];
    let overhead_pct = (rps[1] - rps[0]) / rps[1].max(1e-30) * 100.0;
    println!(
        "instrumented {:>9.0} req/s, disabled {:>9.0} req/s → overhead {overhead_pct:.2}%",
        rps[0], rps[1]
    );
    let mut engine_json = Json::obj();
    for (k, mode) in ["instrumented", "disabled"].into_iter().enumerate() {
        let mut rec = Json::obj();
        rec.set("requests", Json::from(n_req));
        rec.set("best_wall_s", Json::from(wall[k]));
        rec.set("requests_per_s", Json::from(rps[k]));
        engine_json.set(mode, rec);
    }

    // ---- 2. snapshot + Prometheus render ----------------------------------
    section("scrape cost: shard merge snapshot + Prometheus exposition");
    // One instrumented engine, kept alive with a full burst's worth of
    // observations in its shards, so the scrape walks realistic state.
    let model = PackedModel::new(vec![layer.clone()]);
    let engine = ServeEngine::builder(model).workers(2).max_batch(32).build().unwrap();
    let set =
        AdapterSet::from_pairs("tenant", vec![("bench".to_string(), pair.clone())]).unwrap();
    let tenant = engine.register_adapter(set).unwrap().id;
    let lid = engine.layer("bench").unwrap();
    for tk in engine
        .submit_all(xs.iter().map(|x| Request::with_adapter(lid, tenant, x.clone())).collect())
    {
        tk.wait().unwrap();
    }
    let r_snap = bench("snapshot (merge shards)", t, || engine.telemetry().counter(Counter::Hops));
    let snap = engine.telemetry();
    let r_render = bench("render_prometheus", t, || snap.render_prometheus().len());
    let render_bytes = snap.render_prometheus().len();
    engine.shutdown();
    println!(
        "snapshot {:.1}µs, render {:.1}µs ({render_bytes} bytes of exposition)",
        r_snap.min_s * 1e6,
        r_render.min_s * 1e6
    );
    let mut scrape_json = Json::obj();
    scrape_json.set("snapshot_s", Json::from(r_snap.min_s));
    scrape_json.set("render_s", Json::from(r_render.min_s));
    scrape_json.set("render_bytes", Json::from(render_bytes));
    scrape_json.set("snapshot", r_snap.to_json());
    scrape_json.set("render", r_render.to_json());

    // ---- 3. trace record cost ---------------------------------------------
    section("trace record: begin → hop event → finish through the ring");
    // Standalone cores isolate the trace path from kernel work. A huge
    // slow threshold keeps the warn-log capture out of the loop — the
    // ring push is what every traced request pays; the slow path is rare
    // by construction.
    let mut trace_json = Json::obj();
    for (name, opts) in [
        ("enabled", TelemetryOptions::default().slow_threshold_s(1e9)),
        ("disabled", TelemetryOptions::disabled()),
    ] {
        let tel = Telemetry::new(vec!["bench".to_string()], 2, opts);
        let rt = bench(&format!("trace {name}"), t, || {
            let mut done = 0u64;
            for _ in 0..64 {
                if let Some(mut tr) = tel.begin_trace(TraceKind::Single, None) {
                    tr.hop(0, 8, 1, 1e-6, 2e-6);
                    tel.finish_trace(tr, true);
                    done += 1;
                }
            }
            done
        });
        let per_trace_s = rt.min_s / 64.0;
        println!("trace {name:<9} {:.1}ns per traced request", per_trace_s * 1e9);
        let mut rec = rt.to_json();
        rec.set("per_trace_s", Json::from(per_trace_s));
        trace_json.set(name, rec);
    }

    let record = Json::from_pairs(vec![
        ("bench", Json::from("telemetry")),
        ("smoke", Json::from(smoke())),
        ("shape", Json::Arr(vec![Json::from(m), Json::from(n)])),
        ("rank", Json::from(r)),
        ("engine", engine_json),
        // The headline: gated ABSOLUTELY (<5) by scripts/bench_diff.py —
        // negative values just mean timing noise favored the instrumented
        // run this time.
        ("overhead_pct", Json::from(overhead_pct)),
        ("scrape", scrape_json),
        ("trace", trace_json),
        (
            "parity",
            Json::from(
                "counter identities, Prometheus round-trip, and 0-ULP forwards with tracing \
                 enabled are enforced by rust/tests/telemetry_serve.rs and the parity suites",
            ),
        ),
    ]);
    write_bench_json("telemetry", record);
    if overhead_pct >= 5.0 {
        eprintln!(
            "WARNING: telemetry overhead measured at {overhead_pct:.2}% (budget 5%); \
             scripts/bench_diff.py gates this row"
        );
    }
}

//! Admission-contention benchmarks — the numbers behind EXPERIMENTS.md
//! §Scale, emitted as BENCH_contention.json:
//!
//! **requests/s vs concurrent submitters (1 → 64), sharded vs global
//! dispatch**, on two workloads:
//!
//! 1. **single_layer**: every request is a one-hop forward through the
//!    same layer. All traffic maps to ONE shard, so this is the worst
//!    case for sharding (the steal path carries half the work) and the
//!    best case for the global batcher's coalescing — if sharded wins
//!    here it wins everywhere.
//! 2. **pipelined**: four-hop model traversals through a 4-layer route.
//!    Hops spread across all shards and every hop re-enters a shard
//!    push-only, so this measures the dispatch path the sharded core was
//!    built for: admission and re-entry never touching a global lock.
//!
//! Submitters run CLOSED-LOOP (submit → wait → submit), so `submitters`
//! is the concurrency level of the ADMISSION path — exactly where the
//! global batcher's single mutex flatlines as submitters grow. Modes are
//! interleaved round-robin (best-of-rounds per mode) so machine drift
//! lands on both sides of the gated speedup evenly.
//!
//! `scripts/bench_diff.py` gates the 64-submitter requests/s rows against
//! the committed baseline and FLOORS `speedup_sharded_vs_global` at 1.0
//! on both workloads: sharded dispatch must never lose to the reference
//! core it replaced.
//!
//! Under `CLOQ_BENCH_SMOKE=1` shapes and request counts shrink and the
//! record carries `"smoke": true` so bench_diff only compares like
//! against like. Correctness is NOT measured here — bit-parity between
//! the two cores and the steal path is enforced by
//! `rust/tests/lifecycle_shards.rs` and the parity suites.

use std::time::Instant;

use cloq::bench::{section, smoke, smoke_scaled, write_bench_json};
use cloq::linalg::Matrix;
use cloq::quant::{quantize_rtn, QuantState};
use cloq::serve::{Dispatch, ModelRequest, PackedLayer, PackedModel, ServeEngine};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

const WORKERS: usize = 4;
const SUBMITTERS: [usize; 4] = [1, 4, 16, 64];

fn mk_layer(name: &str, n: usize, rng: &mut Rng) -> PackedLayer {
    let w = Matrix::randn(n, n, 0.3, rng);
    PackedLayer::from_state(name, &QuantState::Int(quantize_rtn(&w, 4, 64))).unwrap()
}

fn build(layers: &[PackedLayer], dispatch: Dispatch) -> ServeEngine {
    ServeEngine::builder(PackedModel::new(layers.to_vec()))
        .dispatch(dispatch)
        .workers(WORKERS)
        .max_batch(32)
        .max_pending(8192)
        .build()
        .unwrap()
}

/// One closed-loop round: `subs` submitter threads, each driving `per`
/// requests with exactly one in flight at a time. Fresh engine per round
/// so worker spawn and shard setup are inside the measurement honestly.
fn round_wall(
    layers: &[PackedLayer],
    dispatch: Dispatch,
    subs: usize,
    per: usize,
    n: usize,
    pipelined: bool,
) -> f64 {
    let engine = build(layers, dispatch);
    let names: Vec<&str> = layers.iter().map(|l| l.name.as_str()).collect();
    let route = engine.route(&names).unwrap();
    let lid = engine.layer(names[0]).unwrap();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for sid in 0..subs {
            let engine = &engine;
            let route = route.clone();
            s.spawn(move || {
                let mut rng = Rng::new(0x5eed + sid as u64);
                for _ in 0..per {
                    if pipelined {
                        let req = ModelRequest::new(route.clone(), rng.gauss_vec(n));
                        engine.submit_model(req).wait().unwrap();
                    } else {
                        engine.submit(lid, None, rng.gauss_vec(n)).wait().unwrap();
                    }
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    engine.shutdown();
    wall
}

fn main() {
    let mut rng = Rng::new(31);
    let n = smoke_scaled(128, 48);
    let per = smoke_scaled(64, 6);
    let rounds = 3;
    let layers: Vec<PackedLayer> =
        (0..4).map(|i| mk_layer(&format!("l{i}"), n, &mut rng)).collect();

    let mut workloads: Vec<(&str, Json)> = Vec::new();
    for pipelined in [false, true] {
        let wname = if pipelined { "pipelined" } else { "single_layer" };
        let active: &[PackedLayer] = if pipelined { &layers } else { &layers[..1] };
        section(&format!(
            "{wname}: requests/s vs submitters, sharded vs global ({WORKERS} workers, \
             {per} reqs/submitter, {n}x{n})"
        ));
        let mut sweep = Vec::new();
        let mut at64: Option<(f64, f64, f64)> = None;
        for &subs in &SUBMITTERS {
            let total = subs * per;
            // Interleave the two cores round-robin so machine drift lands
            // on both sides of the floored speedup evenly.
            let mut wall = [f64::INFINITY; 2]; // [sharded, global]
            for _ in 0..rounds {
                for (k, d) in [Dispatch::Sharded, Dispatch::Global].into_iter().enumerate() {
                    wall[k] = wall[k].min(round_wall(active, d, subs, per, n, pipelined));
                }
            }
            let rps = [total as f64 / wall[0], total as f64 / wall[1]];
            let speedup = rps[0] / rps[1].max(1e-30);
            println!(
                "  {subs:>2} submitters: sharded {:>9.0} req/s, global {:>9.0} req/s \
                 → {speedup:.2}x",
                rps[0], rps[1]
            );
            let mut point = Json::obj();
            point.set("submitters", Json::from(subs));
            point.set("requests", Json::from(total));
            for (k, mode) in ["sharded", "global"].into_iter().enumerate() {
                let mut rec = Json::obj();
                rec.set("best_wall_s", Json::from(wall[k]));
                rec.set("requests_per_s", Json::from(rps[k]));
                point.set(mode, rec);
            }
            point.set("speedup_sharded_vs_global", Json::from(speedup));
            sweep.push(point);
            if subs == 64 {
                at64 = Some((rps[0], rps[1], speedup));
            }
        }
        let (s_rps, g_rps, speedup) = at64.expect("the sweep always includes 64 submitters");
        // The 64-submitter point again under a stable dotted path — the
        // scaling headline bench_diff gates without '*' index pairing.
        let mut headline = Json::obj();
        for (mode, rps) in [("sharded", s_rps), ("global", g_rps)] {
            let mut rec = Json::obj();
            rec.set("requests_per_s", Json::from(rps));
            headline.set(mode, rec);
        }
        headline.set("speedup_sharded_vs_global", Json::from(speedup));
        let mut wjson = Json::obj();
        wjson.set("sweep", Json::Arr(sweep));
        wjson.set("submitters_64", headline);
        workloads.push((wname, wjson));
    }

    let mut pairs: Vec<(&str, Json)> = vec![
        ("bench", Json::from("contention")),
        ("smoke", Json::from(smoke())),
        ("shape", Json::Arr(vec![Json::from(n), Json::from(n)])),
        ("layers", Json::from(4usize)),
        ("workers", Json::from(WORKERS)),
        ("submitters", Json::Arr(SUBMITTERS.iter().map(|&s| Json::from(s)).collect())),
        ("per_submitter_requests", Json::from(per)),
    ];
    pairs.extend(workloads);
    pairs.push((
        "parity",
        Json::from(
            "sharded-vs-global and steal-path bit-parity are enforced by \
             rust/tests/lifecycle_shards.rs; this bench only measures contention",
        ),
    ));
    write_bench_json("contention", Json::from_pairs(pairs));
}

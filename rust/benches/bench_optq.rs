//! OPTQ / MagR / RTN / NF quantization benchmarks across layer sizes and
//! bit-widths — the per-layer cost column behind Table 10, plus the
//! act-order ablation called out in DESIGN.md.

use cloq::bench::{bench, section};
use cloq::linalg::{matmul, syrk_t, Matrix};
use cloq::quant::magr::{magr, MagrConfig};
use cloq::quant::optq::{optq, OptqConfig};
use cloq::quant::{quantize_nf, quantize_rtn};
use cloq::util::prng::Rng;

fn layer(m: usize, n: usize, rng: &mut Rng) -> (Matrix, Matrix) {
    let base = Matrix::randn(m * 4, (m / 3).max(2), 1.0, rng);
    let mix = Matrix::randn((m / 3).max(2), m, 1.0, rng);
    let x = matmul(&base, &mix);
    (Matrix::randn(m, n, 0.3, rng), syrk_t(&x))
}

fn main() {
    let mut rng = Rng::new(2);
    let t = 0.4;

    section("data-free quantizers");
    for (m, n) in [(96usize, 96usize), (96, 256), (256, 96)] {
        let (w, _) = layer(m, n, &mut rng);
        bench(&format!("rtn 2-bit {m}x{n} g64"), t, || quantize_rtn(&w, 2, 64));
        bench(&format!("nf4 {m}x{n} b64"), t, || quantize_nf(&w, 4, 64));
    }

    section("OPTQ across sizes (2-bit, group 64)");
    for (m, n) in [(96usize, 96usize), (96, 256), (256, 96), (256, 256)] {
        let (w, h) = layer(m, n, &mut rng);
        let cfg = OptqConfig { bits: 2, group_size: 64, ..Default::default() };
        bench(&format!("optq {m}x{n}"), t, || optq(&w, &h, &cfg));
    }

    section("OPTQ across bit-widths (96x256)");
    let (w, h) = layer(96, 256, &mut rng);
    for bits in [2u32, 3, 4, 8] {
        let cfg = OptqConfig { bits, group_size: 64, ..Default::default() };
        bench(&format!("optq {bits}-bit"), t, || optq(&w, &h, &cfg));
    }

    section("OPTQ act-order ablation (96x256, 2-bit)");
    for act_order in [false, true] {
        let cfg = OptqConfig { bits: 2, group_size: 64, act_order, ..Default::default() };
        bench(&format!("optq act_order={act_order}"), t, || optq(&w, &h, &cfg));
    }

    section("MagR preprocessing (FISTA)");
    for iters in [30usize, 150] {
        let cfg = MagrConfig { alpha_rel: 1e-3, iters };
        bench(&format!("magr 96x256 iters={iters}"), t, || magr(&w, &h, &cfg));
    }
}

//! OPTQ / MagR / RTN / NF quantization benchmarks across layer sizes and
//! bit-widths — the per-layer cost column behind Table 10, plus the
//! act-order ablation called out in DESIGN.md and the lazy-batch blocking
//! comparison behind EXPERIMENTS.md §Perf (emitted as BENCH_optq.json).
//!
//! Under `CLOQ_BENCH_SMOKE=1` (the CI bench-smoke job) shapes, block-size
//! sweeps and target times shrink and the record carries `"smoke": true`
//! so `scripts/bench_diff.py` only compares like against like.

use cloq::bench::{bench, section, smoke, smoke_scaled, target_time, write_bench_json};
use cloq::linalg::{matmul, syrk_t, Matrix};
use cloq::quant::magr::{magr, MagrConfig};
use cloq::quant::optq::{optq, optq_unblocked, OptqConfig};
use cloq::quant::{quantize_nf, quantize_rtn};
use cloq::util::json::Json;
use cloq::util::prng::Rng;

fn layer(m: usize, n: usize, rng: &mut Rng) -> (Matrix, Matrix) {
    let base = Matrix::randn(m * 4, (m / 3).max(2), 1.0, rng);
    let mix = Matrix::randn((m / 3).max(2), m, 1.0, rng);
    let x = matmul(&base, &mix);
    (Matrix::randn(m, n, 0.3, rng), syrk_t(&x))
}

fn main() {
    let mut rng = Rng::new(2);
    let t = target_time(0.4);

    section("data-free quantizers");
    let sizes: Vec<(usize, usize)> =
        if smoke() { vec![(48, 48)] } else { vec![(96, 96), (96, 256), (256, 96)] };
    for &(m, n) in &sizes {
        let (w, _) = layer(m, n, &mut rng);
        bench(&format!("rtn 2-bit {m}x{n} g64"), t, || quantize_rtn(&w, 2, 64));
        bench(&format!("nf4 {m}x{n} b64"), t, || quantize_nf(&w, 4, 64));
    }

    section("OPTQ across sizes (2-bit, group 64)");
    let sizes: Vec<(usize, usize)> = if smoke() {
        vec![(48, 48), (48, 96)]
    } else {
        vec![(96, 96), (96, 256), (256, 96), (256, 256)]
    };
    for &(m, n) in &sizes {
        let (w, h) = layer(m, n, &mut rng);
        let cfg = OptqConfig { bits: 2, group_size: 64, ..Default::default() };
        bench(&format!("optq {m}x{n}"), t, || optq(&w, &h, &cfg));
    }

    let (ma, na) = (smoke_scaled(96, 48), smoke_scaled(256, 96));
    section(&format!("OPTQ across bit-widths ({ma}x{na})"));
    let (w, h) = layer(ma, na, &mut rng);
    for bits in [2u32, 3, 4, 8] {
        let cfg = OptqConfig { bits, group_size: 64, ..Default::default() };
        bench(&format!("optq {bits}-bit"), t, || optq(&w, &h, &cfg));
    }

    section(&format!("OPTQ act-order ablation ({ma}x{na}, 2-bit)"));
    for act_order in [false, true] {
        let cfg = OptqConfig { bits: 2, group_size: 64, act_order, ..Default::default() };
        bench(&format!("optq act_order={act_order}"), t, || optq(&w, &h, &cfg));
    }

    section("MagR preprocessing (FISTA)");
    let iter_counts: Vec<usize> = if smoke() { vec![30] } else { vec![30, 150] };
    for &iters in &iter_counts {
        let cfg = MagrConfig { alpha_rel: 1e-3, iters };
        bench(&format!("magr {ma}x{na} iters={iters}"), t, || magr(&w, &h, &cfg));
    }

    // ---- lazy-batch blocking: the acceptance benchmark -------------------
    // 512×512: big enough that the trailing submatrix (2 MiB f64) falls out
    // of L2, which is exactly the regime the blocked engine targets (the
    // smoke-mode 128×128 just proves the path runs and stays comparable to
    // its own smoke baseline). The parity suite (tests/parity_blocked.rs)
    // proves both paths produce identical quantized output, so this ratio
    // is a pure-speed comparison.
    let (m512, n512) = (smoke_scaled(512, 128), smoke_scaled(512, 128));
    section(&format!("lazy-batch blocking: blocked vs row-by-row, {m512}x{n512} 2-bit g64"));
    let (w, h) = layer(m512, n512, &mut rng);
    let base_cfg = OptqConfig { bits: 2, group_size: 64, ..Default::default() };
    let r_ref = bench(&format!("optq unblocked {m512}x{n512} (seed path)"), t, || {
        optq_unblocked(&w, &h, &base_cfg)
    });
    let mut blocked_records = Vec::new();
    let mut best_min = f64::INFINITY;
    let mut best_bs = 0usize;
    let block_sizes: Vec<usize> = if smoke() { vec![16, 32] } else { vec![16, 32, 64, 128] };
    for &bs in &block_sizes {
        let cfg = OptqConfig { block_size: bs, ..base_cfg.clone() };
        let r = bench(&format!("optq blocked bs={bs} {m512}x{n512}"), t, || optq(&w, &h, &cfg));
        if r.min_s < best_min {
            best_min = r.min_s;
            best_bs = bs;
        }
        let mut rec = r.to_json();
        rec.set("block_size", Json::from(bs));
        blocked_records.push(rec);
    }
    let speedup = r_ref.min_s / best_min;
    println!("\nblocked speedup @{m512}x{n512}: {speedup:.2}x (best block_size={best_bs})");

    let record = Json::from_pairs(vec![
        ("bench", Json::from("optq_lazy_batch_blocking")),
        ("smoke", Json::from(smoke())),
        ("shape", Json::Arr(vec![Json::from(m512), Json::from(n512)])),
        ("bits", Json::from(2usize)),
        ("group_size", Json::from(64usize)),
        ("unblocked", r_ref.to_json()),
        // Identity key for bench_diff: blocked rows pair by index, so the
        // gate must refuse comparison when the block-size sweep changes.
        (
            "block_sizes",
            Json::Arr(block_sizes.iter().map(|&b| Json::from(b)).collect()),
        ),
        ("blocked", Json::Arr(blocked_records)),
        ("best_block_size", Json::from(best_bs)),
        ("speedup_min_over_min", Json::from(speedup)),
        (
            "parity",
            Json::from("bit-exact vs unblocked — enforced by rust/tests/parity_blocked.rs"),
        ),
    ]);
    write_bench_json("optq", record);
    if speedup < 1.0 {
        // Not a hard failure: timing noise on loaded machines must not turn
        // a measurement into a flaky bench exit; correctness is enforced by
        // tests/parity_blocked.rs.
        eprintln!("WARNING: blocked OPTQ measured slower than reference ({speedup:.2}x)");
    }
}

//! Micro-benchmark harness (criterion is not in the offline crate set —
//! DESIGN.md §3). `cargo bench` targets are `harness = false` binaries
//! that drive this module.
//!
//! Methodology: warmup runs, then timed iterations with mean / min /
//! stddev; iteration count auto-scales to the op cost so each benchmark
//! takes ~`target_time`.

use std::time::Instant;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub stddev_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10}   mean {:>12}   min {:>12}   ±{:>10}",
            self.name,
            format!("x{}", self.iters),
            fmt_time(self.mean_s),
            fmt_time(self.min_s),
            fmt_time(self.stddev_s),
        )
    }

    /// Structured record for the BENCH_*.json reports.
    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("name", Json::from(self.name.clone())),
            ("iters", Json::from(self.iters)),
            ("mean_s", Json::from(self.mean_s)),
            ("min_s", Json::from(self.min_s)),
            ("stddev_s", Json::from(self.stddev_s)),
        ])
    }
}

/// True when `CLOQ_BENCH_SMOKE=1` — the CI bench-smoke mode: benches
/// shrink shapes, request counts and per-measurement target times so the
/// whole `scripts/check.sh --bench` pass finishes in seconds while still
/// exercising every code path and emitting the same JSON schema. Records
/// carry a `"smoke"` flag so `scripts/bench_diff.py` never compares smoke
/// numbers against full-run baselines (or vice versa).
pub fn smoke() -> bool {
    std::env::var("CLOQ_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// `full` in a normal run, `small` under `CLOQ_BENCH_SMOKE=1`.
pub fn smoke_scaled(full: usize, small: usize) -> usize {
    if smoke() {
        small
    } else {
        full
    }
}

/// Per-measurement target time: `full` seconds normally, 20 ms in smoke
/// mode (enough for the auto-scaler's minimum 3 iterations on every op
/// benched here).
pub fn target_time(full: f64) -> f64 {
    if smoke() {
        0.02
    } else {
        full
    }
}

/// Write a BENCH_<id>.json record next to the working directory, so bench
/// runs leave a machine-readable trail (EXPERIMENTS.md §Perf).
pub fn write_bench_json(id: &str, record: Json) {
    let path = std::path::PathBuf::from(format!("BENCH_{id}.json"));
    match std::fs::write(&path, record.to_string_pretty()) {
        Ok(()) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("could not write {}: {e}", path.display()),
    }
}

pub fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Benchmark `f`, auto-scaling iterations to roughly `target_time` seconds.
pub fn bench<T>(name: &str, target_time: f64, mut f: impl FnMut() -> T) -> BenchResult {
    // Warmup + cost estimate.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let est = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_time / est) as usize).clamp(3, 10_000);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed().as_secs_f64());
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let min = samples.iter().cloned().fold(f64::INFINITY, f64::min);
    let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / samples.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: mean,
        min_s: min,
        stddev_s: var.sqrt(),
    };
    println!("{}", r.report());
    r
}

/// Section header for bench output.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let r = bench("spin", 0.05, || {
            let mut s = 0u64;
            for i in 0..10_000 {
                s = s.wrapping_add(i);
            }
            s
        });
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.mean_s);
        assert!(r.iters >= 3);
    }

    #[test]
    fn time_formatting() {
        assert!(fmt_time(2.0).ends_with(" s"));
        assert!(fmt_time(2e-3).ends_with(" ms"));
        assert!(fmt_time(2e-6).ends_with(" us"));
        assert!(fmt_time(2e-9).ends_with(" ns"));
    }
}

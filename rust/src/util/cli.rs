//! Tiny argument parser (the sandbox has no `clap`).
//!
//! Supports `command --flag value --switch positional` style. Each subcommand
//! in `main.rs` declares the options it understands; unknown flags are
//! reported with the available set.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`. Flags are `--name value`; switches are `--name`
    /// followed by another flag or end-of-args.
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                out.command = it.next().unwrap().clone();
            }
        }
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.flags.insert(name.to_string(), it.next().unwrap().clone());
                    }
                    _ => out.switches.push(name.to_string()),
                }
            } else {
                out.positional.push(a.clone());
            }
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn str(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt_str(&self, name: &str) -> Option<String> {
        self.flags.get(name).cloned()
    }

    pub fn usize(&self, name: &str, default: usize) -> usize {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn f64(&self, name: &str, default: f64) -> f64 {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn u64(&self, name: &str, default: u64) -> u64 {
        self.flags
            .get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{v}'")))
            .unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn parses_command_flags_switches() {
        let a = parse("table --id 3 --fast --out reports pos1");
        assert_eq!(a.command, "table");
        assert_eq!(a.usize("id", 0), 3);
        assert!(a.has("fast"));
        assert_eq!(a.str("out", "x"), "reports");
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parse("run");
        assert_eq!(a.usize("steps", 100), 100);
        assert_eq!(a.f64("lr", 1e-3), 1e-3);
        assert!(!a.has("fast"));
    }

    #[test]
    fn negative_number_values() {
        // A value starting with '-' but not '--' is still a value.
        let a = parse("x --shift -5");
        assert_eq!(a.f64("shift", 0.0), -5.0);
    }

    #[test]
    fn no_command_all_flags() {
        let a = parse("--alpha 1");
        assert_eq!(a.command, "");
        assert_eq!(a.usize("alpha", 0), 1);
    }
}

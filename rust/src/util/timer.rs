//! Wall-clock timing + peak-RSS tracking for Table 10 and the §Perf log.

use std::time::Instant;

/// Simple scoped timer.
pub struct Timer {
    start: Instant,
    pub label: String,
}

impl Timer {
    pub fn start(label: &str) -> Self {
        Self { start: Instant::now(), label: label.to_string() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

/// Time a closure, returning (result, seconds).
pub fn timeit<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Instant::now();
    let out = f();
    (out, t.elapsed().as_secs_f64())
}

/// Current process peak resident set size in MiB (Linux `/proc/self/status`,
/// `VmHWM`). Returns 0.0 if unavailable — callers treat it as "unknown".
pub fn peak_rss_mib() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

/// Current RSS in MiB (`VmRSS`).
pub fn rss_mib() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmRSS:") {
            let kb: f64 = rest
                .trim()
                .trim_end_matches("kB")
                .trim()
                .parse()
                .unwrap_or(0.0);
            return kb / 1024.0;
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_measures_sleep() {
        let t = Timer::start("x");
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(t.elapsed_ms() >= 18.0);
    }

    #[test]
    fn timeit_returns_value() {
        let (v, s) = timeit(|| 2 + 2);
        assert_eq!(v, 4);
        assert!(s >= 0.0);
    }

    #[test]
    fn rss_is_positive_on_linux() {
        assert!(peak_rss_mib() > 0.0);
        assert!(rss_mib() > 0.0);
    }
}

//! Thread pools for parallel jobs (no `rayon` offline).
//!
//! Two shapes of parallelism live here:
//!
//! * [`run_parallel`] / [`run_collect_status`] — one-shot scoped batches.
//!   The coordinator quantizes / initializes transformer layers as
//!   independent jobs; results come back in submission order, panics are
//!   caught and reported per job (used by the scheduler's progress display
//!   and the failure-injection tests).
//! * [`WorkerPool`] — a persistent pool with dynamically submitted jobs,
//!   the execution substrate of the serving engine's `Dispatch::Global`
//!   reference path: the batcher coalesces requests into micro-batches and
//!   submits each batch as one job; workers outlive any individual request.
//! * [`ShardedQueues`] — the queueing substrate of the engine's sharded
//!   work-stealing dispatch (`Dispatch::Sharded`): N independent
//!   mutex+condvar deques with lock-free atomic depth mirrors, so an idle
//!   worker can pick a steal victim without touching any other shard's
//!   lock. The policy (layer affinity, batch formation, steal order) stays
//!   in `serve::engine`; this type only owns the shards' memory and the
//!   park/wake protocol.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

/// Outcome of one job as seen by the scheduler.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobStatus {
    Done,
    Panicked(String),
}

/// Default worker count for layer-parallel stages: the machine's available
/// parallelism, 1 if it cannot be determined.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `jobs` on up to `workers` threads; return results in submission order.
///
/// Panics in a job are caught and rethrown after all jobs finish, so one bad
/// layer cannot wedge the pool (and tests can assert on partial completion
/// via `run_collect_status`).
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (results, statuses) = run_collect_status(workers, jobs);
    for (i, s) in statuses.iter().enumerate() {
        if let JobStatus::Panicked(msg) = s {
            panic!("job {i} panicked: {msg}");
        }
    }
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Like [`run_parallel`] but never panics: returns per-job `Option<T>` plus
/// statuses. Used by the scheduler tests with injected failures.
pub fn run_collect_status<T, F>(
    workers: usize,
    jobs: Vec<F>,
) -> (Vec<Option<T>>, Vec<JobStatus>)
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    let workers = workers.max(1).min(n.max(1));
    // Work queue: (index, job).
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, Result<T, String>)>();

    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = { queue.lock().unwrap().pop() };
            match job {
                None => break,
                Some((idx, f)) => {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
                        .map_err(|e| panic_message(&e));
                    // Receiver may be gone if the caller panicked; ignore.
                    let _ = tx.send((idx, result));
                }
            }
        }));
    }
    drop(tx);

    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let mut statuses: Vec<JobStatus> = vec![JobStatus::Done; n];
    for (idx, r) in rx {
        match r {
            Ok(v) => results[idx] = Some(v),
            Err(msg) => statuses[idx] = JobStatus::Panicked(msg),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    (results, statuses)
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    jobs: VecDeque<Job>,
    open: bool,
    panicked: usize,
    /// Jobs popped from the queue and currently executing — `wait_idle`
    /// blocks until this is 0 AND the queue is empty.
    active: usize,
}

struct PoolShared {
    state: Mutex<PoolState>,
    /// Workers park here waiting for jobs (or shutdown).
    cv: Condvar,
    /// `wait_idle` callers park here; run-to-idle transitions notify it.
    /// A separate condvar so `submit` can wake exactly one worker without
    /// broadcasting to every thread on the dispatch hot path (and without
    /// the stranded-job hazard a shared condvar + notify_one would have).
    idle_cv: Condvar,
}

/// Persistent worker pool: jobs are submitted dynamically (unlike the
/// one-shot [`run_parallel`]) and executed by long-lived workers in FIFO
/// order. Shutdown (explicit or on drop) drains the queue before joining,
/// so every submitted job runs. A panicking job is caught and counted —
/// one bad request cannot take a worker down.
///
/// **Re-entrancy**: `submit` may be called from INSIDE a running job (the
/// serving engine's hop re-entry shape: a finished micro-batch enqueues
/// follow-up work from a worker thread). The pool lock is only held for
/// the queue push — job bodies run lock-free — so a worker enqueuing more
/// work can never deadlock the pool or the thread that dispatches into
/// it. `wait_idle` stays correct across re-entrant submits: the submitting
/// job is still counted `active` while it pushes, so the pool is never
/// observed "idle" between a job finishing its work and publishing its
/// follow-ups.
pub struct WorkerPool {
    shared: Arc<PoolShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    pub fn new(workers: usize) -> WorkerPool {
        let shared = Arc::new(PoolShared {
            state: Mutex::new(PoolState {
                jobs: VecDeque::new(),
                open: true,
                panicked: 0,
                active: 0,
            }),
            cv: Condvar::new(),
            idle_cv: Condvar::new(),
        });
        let handles = (0..workers.max(1))
            .map(|_| {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || loop {
                    let job = {
                        let mut st = shared.state.lock().unwrap();
                        loop {
                            if let Some(j) = st.jobs.pop_front() {
                                st.active += 1; // claimed under the same lock as the pop
                                break Some(j);
                            }
                            if !st.open {
                                break None;
                            }
                            st = shared.cv.wait(st).unwrap();
                        }
                    };
                    match job {
                        None => break,
                        Some(j) => {
                            let panicked =
                                std::panic::catch_unwind(std::panic::AssertUnwindSafe(j)).is_err();
                            let idle = {
                                let mut st = shared.state.lock().unwrap();
                                if panicked {
                                    st.panicked += 1;
                                }
                                st.active -= 1;
                                st.jobs.is_empty() && st.active == 0
                            };
                            if idle {
                                shared.idle_cv.notify_all(); // wake wait_idle callers
                            }
                        }
                    }
                })
            })
            .collect();
        WorkerPool { shared, handles }
    }

    /// Queue a job for execution. Panics if called after [`shutdown`].
    ///
    /// [`shutdown`]: WorkerPool::shutdown
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        {
            let mut st = self.shared.state.lock().unwrap();
            assert!(st.open, "submit on a shut-down WorkerPool");
            st.jobs.push_back(Box::new(job));
        }
        // Only workers wait on `cv` (`wait_idle` parks on `idle_cv`), so a
        // single wakeup always lands on a thread that can claim the job —
        // no broadcast needed on the dispatch hot path.
        self.shared.cv.notify_one();
    }

    /// Number of jobs that panicked so far (each was caught; its worker
    /// kept running).
    pub fn panicked(&self) -> usize {
        self.shared.state.lock().unwrap().panicked
    }

    /// Block until every job submitted SO FAR has finished (queue empty and
    /// no worker mid-job). The serving engine's batcher uses this on
    /// shutdown so every dispatched micro-batch has answered its riders
    /// before the batcher thread exits. Concurrent `submit` calls restart
    /// the wait — this is a quiescence point, not a shutdown, so callers
    /// must have stopped (or be prepared to outwait) new submissions.
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap();
        while !(st.jobs.is_empty() && st.active == 0) {
            st = self.shared.idle_cv.wait(st).unwrap();
        }
    }

    /// Drain the queue and join the workers. Also runs on drop; calling it
    /// explicitly just makes the join point visible in the caller.
    pub fn shutdown(mut self) {
        self.shutdown_impl();
    }

    fn shutdown_impl(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.open = false;
        }
        self.shared.cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.shutdown_impl();
    }
}

/// One shard of a [`ShardedQueues`]: a mutex-guarded deque, the condvar
/// its owning worker parks on, and an atomic mirror of the deque's length
/// so stealers can rank victims without taking the lock.
struct QueueShard<T> {
    q: Mutex<VecDeque<T>>,
    cv: Condvar,
    depth: AtomicUsize,
}

/// N independent work queues with a park/steal protocol — the substrate of
/// the serving engine's sharded dispatch. Each worker OWNS one shard: it
/// pushes and pops under that shard's lock only, so disjoint shards never
/// contend. Cross-shard visibility goes through the atomic `depth` mirrors
/// (which may lag the locked deque by one push or pop — fine for victim
/// ranking, never used for correctness).
///
/// Wakeup discipline: `push`/`push_all` notify the target shard's condvar
/// after releasing its lock. `wake_all` (used when the close-and-drained
/// exit condition becomes true) locks each shard and then broadcasts,
/// which closes the lost-wakeup window against a parker that checked the
/// exit predicate just before waiting. `park` additionally bounds every
/// wait with a caller-supplied timeout, so an unlocked [`assist`] nudge —
/// or a missed race — costs at most one timeout, never a hang.
///
/// [`assist`]: ShardedQueues::assist
pub struct ShardedQueues<T> {
    shards: Vec<QueueShard<T>>,
    closed: AtomicBool,
}

impl<T> ShardedQueues<T> {
    pub fn new(n: usize) -> ShardedQueues<T> {
        let shards = (0..n.max(1))
            .map(|_| QueueShard {
                q: Mutex::new(VecDeque::new()),
                cv: Condvar::new(),
                depth: AtomicUsize::new(0),
            })
            .collect();
        ShardedQueues { shards, closed: AtomicBool::new(false) }
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Lock-free depth of shard `i` (may lag the locked deque briefly).
    pub fn depth(&self, i: usize) -> usize {
        self.shards[i].depth.load(Ordering::Acquire)
    }

    /// Append one item to shard `i`, wake its owner, return the new depth.
    pub fn push(&self, i: usize, item: T) -> usize {
        let s = &self.shards[i];
        let depth = {
            let mut q = s.q.lock().unwrap();
            q.push_back(item);
            let d = q.len();
            s.depth.store(d, Ordering::Release);
            d
        };
        s.cv.notify_one();
        depth
    }

    /// Append a run of items to shard `i` under ONE lock hold (a burst
    /// stays adjacent, hence coalescible), wake its owner, return depth.
    pub fn push_all(&self, i: usize, items: impl IntoIterator<Item = T>) -> usize {
        let s = &self.shards[i];
        let depth = {
            let mut q = s.q.lock().unwrap();
            q.extend(items);
            let d = q.len();
            s.depth.store(d, Ordering::Release);
            d
        };
        s.cv.notify_one();
        depth
    }

    /// Run `f` against shard `i`'s locked deque (batch formation: the
    /// caller may remove any items it likes), then refresh the depth
    /// mirror from what remains.
    pub fn pop_group<R>(&self, i: usize, f: impl FnOnce(&mut VecDeque<T>) -> R) -> R {
        let s = &self.shards[i];
        let mut q = s.q.lock().unwrap();
        let out = f(&mut q);
        s.depth.store(q.len(), Ordering::Release);
        out
    }

    /// Steal-victim ranking: the index of the deepest non-empty shard
    /// other than `me`, by the atomic mirrors alone (no locks taken).
    pub fn most_loaded_other(&self, me: usize) -> Option<usize> {
        let mut best: Option<usize> = None;
        let mut best_depth = 0usize;
        for (i, s) in self.shards.iter().enumerate() {
            if i == me {
                continue;
            }
            let d = s.depth.load(Ordering::Acquire);
            if d > best_depth {
                best = Some(i);
                best_depth = d;
            }
        }
        best
    }

    /// UNLOCKED nudge of shard `i`'s parker — a backlog hint ("my shard is
    /// deep, come steal"). A lost wakeup here is tolerated by design: the
    /// parker's timeout re-scans for steals anyway.
    pub fn assist(&self, i: usize) {
        self.shards[i].cv.notify_one();
    }

    /// Mark the queues closed and broadcast to every parker. Closing does
    /// NOT drop queued items — owners keep draining until their exit
    /// predicate (closed AND nothing left anywhere) holds.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        self.wake_all();
    }

    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// Park the owner of shard `i` until its queue is non-empty, `timeout`
    /// elapses, or `exit()` holds. Returns `false` iff the caller should
    /// stop (exit observed with an empty own queue); `true` means "scan
    /// for work again" — the own queue has items, or the timed/notified
    /// wake says it is time to re-check steals.
    ///
    /// `exit` is evaluated under shard `i`'s lock, which pairs with
    /// [`wake_all`](ShardedQueues::wake_all)'s lock-then-broadcast to
    /// close the classic check-then-wait lost-wakeup race.
    pub fn park(&self, i: usize, timeout: std::time::Duration, exit: impl Fn() -> bool) -> bool {
        let s = &self.shards[i];
        let q = s.q.lock().unwrap();
        if !q.is_empty() {
            return true;
        }
        if exit() {
            return false;
        }
        let (q, _timed_out) = s.cv.wait_timeout(q, timeout).unwrap();
        !(q.is_empty() && exit())
    }

    /// Lock each shard in turn (immediately dropping the guard) and then
    /// broadcast its condvar. The lock acquisition serializes against any
    /// parker between its predicate check and its wait, so the broadcast
    /// cannot be lost — this is the drain-completion wake path.
    pub fn wake_all(&self) {
        for s in &self.shards {
            drop(s.q.lock().unwrap());
            s.cv.notify_all();
        }
    }
}

fn panic_message(e: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_submission_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..32usize)
            .map(|i| {
                Box::new(move || {
                    // Vary the work so completion order differs.
                    std::thread::sleep(std::time::Duration::from_micros((32 - i) as u64 * 10));
                    i * i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = run_parallel(4, jobs);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_works() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_parallel(1, jobs), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn empty_jobs_ok() {
        let jobs: Vec<fn() -> ()> = vec![];
        let out = run_parallel(4, jobs);
        assert!(out.is_empty());
    }

    #[test]
    fn worker_pool_runs_all_jobs_across_shutdown() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = Arc::new(AtomicUsize::new(0));
        let pool = WorkerPool::new(3);
        for _ in 0..40 {
            let done = Arc::clone(&done);
            pool.submit(move || {
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown(); // must drain the queue, not abandon it
        assert_eq!(done.load(Ordering::SeqCst), 40);
    }

    #[test]
    fn wait_idle_blocks_until_all_jobs_finish() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = Arc::new(AtomicUsize::new(0));
        let pool = WorkerPool::new(2);
        pool.wait_idle(); // empty pool is already idle
        for _ in 0..16 {
            let done = Arc::clone(&done);
            pool.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 16, "wait_idle returned with work pending");
        pool.wait_idle(); // idempotent once idle
        pool.shutdown();
    }

    #[test]
    fn jobs_can_submit_follow_up_jobs_without_deadlock() {
        // The serving engine's hop re-entry shape: each finished job
        // enqueues the next from inside a worker. wait_idle must observe
        // the whole chain (the submitting job is still `active` while it
        // pushes its follow-up, so there is no idle window mid-chain).
        use std::sync::atomic::{AtomicUsize, Ordering};
        fn chain(pool: Arc<WorkerPool>, done: Arc<AtomicUsize>, depth: usize) {
            let p2 = Arc::clone(&pool);
            pool.submit(move || {
                if depth > 1 {
                    chain(Arc::clone(&p2), Arc::clone(&done), depth - 1);
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        let pool = Arc::new(WorkerPool::new(2));
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..4 {
            chain(Arc::clone(&pool), Arc::clone(&done), 8);
        }
        pool.wait_idle();
        assert_eq!(done.load(Ordering::SeqCst), 32, "every re-entrant hop must run");
    }

    #[test]
    fn worker_pool_survives_panicking_jobs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = Arc::new(AtomicUsize::new(0));
        let mut pool = WorkerPool::new(2);
        for i in 0..10 {
            let done = Arc::clone(&done);
            pool.submit(move || {
                if i % 3 == 0 {
                    panic!("injected {i}");
                }
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.shutdown_impl(); // join in place so accounting stays readable
        assert_eq!(done.load(Ordering::SeqCst), 6);
        assert_eq!(pool.panicked(), 4); // i ∈ {0,3,6,9}
    }

    #[test]
    fn sharded_queues_track_depth_through_push_and_pop_group() {
        let q: ShardedQueues<u32> = ShardedQueues::new(3);
        assert_eq!(q.shards(), 3);
        assert_eq!(q.push(1, 10), 1);
        assert_eq!(q.push(1, 11), 2);
        assert_eq!(q.push_all(2, [20, 21, 22]), 3);
        assert_eq!((q.depth(0), q.depth(1), q.depth(2)), (0, 2, 3));
        let got = q.pop_group(1, |d| d.drain(..).collect::<Vec<_>>());
        assert_eq!(got, vec![10, 11], "FIFO within a shard");
        assert_eq!(q.depth(1), 0, "depth mirror refreshed after pop_group");
    }

    #[test]
    fn most_loaded_other_ranks_victims_and_skips_self() {
        let q: ShardedQueues<u32> = ShardedQueues::new(3);
        assert_eq!(q.most_loaded_other(0), None, "all empty: nothing to steal");
        q.push(0, 1);
        q.push_all(2, [2, 3]);
        assert_eq!(q.most_loaded_other(0), Some(2), "deepest other shard wins");
        assert_eq!(q.most_loaded_other(2), Some(0), "own shard never a victim");
        q.pop_group(2, |d| d.clear());
        assert_eq!(q.most_loaded_other(0), None, "empty shards are not victims");
    }

    #[test]
    fn park_wakes_on_push_and_exits_when_told() {
        use std::sync::atomic::AtomicBool;
        let q: Arc<ShardedQueues<u32>> = Arc::new(ShardedQueues::new(2));
        let stop = Arc::new(AtomicBool::new(false));
        let long = std::time::Duration::from_secs(30);
        // Non-empty own queue: park returns true without waiting.
        q.push(0, 1);
        assert!(q.park(0, long, || false));
        q.pop_group(0, |d| d.clear());
        // A push from another thread wakes the parker well before timeout.
        let (q2, t0) = (Arc::clone(&q), std::time::Instant::now());
        let h = std::thread::spawn(move || {
            std::thread::sleep(std::time::Duration::from_millis(10));
            q2.push(0, 7);
        });
        assert!(q.park(0, long, || false), "push must wake the parked owner");
        assert!(t0.elapsed() < long, "woke by notify, not timeout");
        h.join().unwrap();
        // Exit observed with an empty queue: park says stop.
        q.pop_group(0, |d| d.clear());
        stop.store(true, Ordering::SeqCst);
        let stop2 = Arc::clone(&stop);
        assert!(!q.park(0, long, move || stop2.load(Ordering::SeqCst)));
    }

    #[test]
    fn wake_all_releases_parkers_for_the_exit_check() {
        let q: Arc<ShardedQueues<u32>> = Arc::new(ShardedQueues::new(2));
        let q2 = Arc::clone(&q);
        let parker = std::thread::spawn(move || {
            // Loops like a dispatch worker: park until closed-and-empty.
            while q2.park(1, std::time::Duration::from_secs(30), || q2.is_closed()) {}
        });
        std::thread::sleep(std::time::Duration::from_millis(10));
        q.close(); // close() broadcasts via wake_all
        parker.join().unwrap(); // would hang ~30s if the wake were lost
        assert!(q.is_closed());
    }

    #[test]
    fn sharded_workers_drain_everything_with_steals() {
        use std::sync::atomic::AtomicUsize;
        // All work lands in shard 0; two workers (owners of shard 0 and 1)
        // must still drain all of it — worker 1 only ever steals.
        let q: Arc<ShardedQueues<u32>> = Arc::new(ShardedQueues::new(2));
        let done = Arc::new(AtomicUsize::new(0));
        let total = 200usize;
        let workers: Vec<_> = (0..2usize)
            .map(|me| {
                let q = Arc::clone(&q);
                let done = Arc::clone(&done);
                std::thread::spawn(move || loop {
                    let own = q.pop_group(me, |d| d.pop_front());
                    if let Some(_v) = own {
                        done.fetch_add(1, Ordering::SeqCst);
                        continue;
                    }
                    if let Some(victim) = q.most_loaded_other(me) {
                        if q.pop_group(victim, |d| d.pop_front()).is_some() {
                            done.fetch_add(1, Ordering::SeqCst);
                            continue;
                        }
                    }
                    let exit = {
                        let (q, done) = (Arc::clone(&q), Arc::clone(&done));
                        move || q.is_closed() && done.load(Ordering::SeqCst) == total
                    };
                    if !q.park(me, std::time::Duration::from_millis(1), exit) {
                        break;
                    }
                })
            })
            .collect();
        for v in 0..total as u32 {
            q.push(0, v);
        }
        q.close();
        for w in workers {
            w.join().unwrap();
        }
        assert_eq!(done.load(Ordering::SeqCst), total, "close() must not drop queued work");
    }

    #[test]
    fn panics_reported_but_others_complete() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..8usize)
            .map(|i| {
                Box::new(move || {
                    if i == 3 {
                        panic!("injected failure on {i}");
                    }
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let (results, statuses) = run_collect_status(3, jobs);
        assert!(matches!(statuses[3], JobStatus::Panicked(_)));
        for i in 0..8 {
            if i != 3 {
                assert_eq!(results[i], Some(i));
                assert_eq!(statuses[i], JobStatus::Done);
            }
        }
    }
}

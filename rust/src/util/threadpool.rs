//! Scoped thread pool for layer-parallel jobs (no `rayon` offline).
//!
//! The coordinator quantizes / initializes transformer layers as independent
//! jobs. This pool executes `FnOnce` jobs on N worker threads and joins them,
//! propagating panics, collecting results in submission order, and reporting
//! per-job status to an optional observer (used by the scheduler's progress
//! display and the failure-injection tests).

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Outcome of one job as seen by the scheduler.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobStatus {
    Done,
    Panicked(String),
}

/// Default worker count for layer-parallel stages: the machine's available
/// parallelism, 1 if it cannot be determined.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `jobs` on up to `workers` threads; return results in submission order.
///
/// Panics in a job are caught and rethrown after all jobs finish, so one bad
/// layer cannot wedge the pool (and tests can assert on partial completion
/// via `run_collect_status`).
pub fn run_parallel<T, F>(workers: usize, jobs: Vec<F>) -> Vec<T>
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let (results, statuses) = run_collect_status(workers, jobs);
    for (i, s) in statuses.iter().enumerate() {
        if let JobStatus::Panicked(msg) = s {
            panic!("job {i} panicked: {msg}");
        }
    }
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Like [`run_parallel`] but never panics: returns per-job `Option<T>` plus
/// statuses. Used by the scheduler tests with injected failures.
pub fn run_collect_status<T, F>(
    workers: usize,
    jobs: Vec<F>,
) -> (Vec<Option<T>>, Vec<JobStatus>)
where
    T: Send + 'static,
    F: FnOnce() -> T + Send + 'static,
{
    let n = jobs.len();
    let workers = workers.max(1).min(n.max(1));
    // Work queue: (index, job).
    let queue: Arc<Mutex<Vec<(usize, F)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().rev().collect()));
    let (tx, rx) = mpsc::channel::<(usize, Result<T, String>)>();

    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = Arc::clone(&queue);
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = { queue.lock().unwrap().pop() };
            match job {
                None => break,
                Some((idx, f)) => {
                    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f))
                        .map_err(|e| panic_message(&e));
                    // Receiver may be gone if the caller panicked; ignore.
                    let _ = tx.send((idx, result));
                }
            }
        }));
    }
    drop(tx);

    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let mut statuses: Vec<JobStatus> = vec![JobStatus::Done; n];
    for (idx, r) in rx {
        match r {
            Ok(v) => results[idx] = Some(v),
            Err(msg) => statuses[idx] = JobStatus::Panicked(msg),
        }
    }
    for h in handles {
        let _ = h.join();
    }
    (results, statuses)
}

fn panic_message(e: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic>".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_submission_order() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..32usize)
            .map(|i| {
                Box::new(move || {
                    // Vary the work so completion order differs.
                    std::thread::sleep(std::time::Duration::from_micros((32 - i) as u64 * 10));
                    i * i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let out = run_parallel(4, jobs);
        assert_eq!(out, (0..32).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_works() {
        let jobs: Vec<_> = (0..5).map(|i| move || i + 1).collect();
        assert_eq!(run_parallel(1, jobs), vec![1, 2, 3, 4, 5]);
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }

    #[test]
    fn empty_jobs_ok() {
        let jobs: Vec<fn() -> ()> = vec![];
        let out = run_parallel(4, jobs);
        assert!(out.is_empty());
    }

    #[test]
    fn panics_reported_but_others_complete() {
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = (0..8usize)
            .map(|i| {
                Box::new(move || {
                    if i == 3 {
                        panic!("injected failure on {i}");
                    }
                    i
                }) as Box<dyn FnOnce() -> usize + Send>
            })
            .collect();
        let (results, statuses) = run_collect_status(3, jobs);
        assert!(matches!(statuses[3], JobStatus::Panicked(_)));
        for i in 0..8 {
            if i != 3 {
                assert_eq!(results[i], Some(i));
                assert_eq!(statuses[i], JobStatus::Done);
            }
        }
    }
}

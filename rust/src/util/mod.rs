//! Foundational substrates built in-repo (the offline sandbox vendors only
//! the `xla` crate closure — see DESIGN.md §3 for the substitution table).

pub mod cli;
pub mod json;
pub mod logging;
pub mod prng;
pub mod threadpool;
pub mod timer;

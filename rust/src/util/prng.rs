//! Deterministic pseudo-random number generation.
//!
//! The offline sandbox has no `rand` crate, so we implement the two PRNGs the
//! framework needs from scratch:
//!
//! * [`SplitMix64`] — seed expansion / stream splitting (Steele et al. 2014).
//! * [`Xoshiro256pp`] — the workhorse generator (Blackman & Vigna 2019),
//!   used for data generation, initialization noise and property-test sweeps.
//!
//! Everything in the repository that consumes randomness takes an explicit
//! `&mut Rng`, so every experiment is reproducible from a single `u64` seed.

/// SplitMix64: tiny, full-period 64-bit generator. Used to expand one seed
/// into the 256-bit state of [`Xoshiro256pp`] and to derive child seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ — fast, high-quality, 2^256-1 period.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box–Muller pair.
    gauss_cache: Option<f64>,
}

impl Rng {
    /// Seed via SplitMix64 expansion (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    /// Derive an independent child generator (for parallel streams).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 top bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Lemire's unbiased method.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0)");
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as usize) as i64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        // Avoid log(0).
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
        self.gauss_cache = Some(r * s);
        r * c
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "weighted() with zero total mass");
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }

    /// Vector of standard normals.
    pub fn gauss_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.gauss()).collect()
    }
}

/// Zipf-distributed integer sampler over {0, .., n-1} with exponent `s`.
/// Precomputes the CDF; used by the synthetic corpus generator to give the
/// vocabulary realistic (heavy-tailed) token statistics.
#[derive(Clone, Debug)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = *cdf.last().unwrap();
        for c in cdf.iter_mut() {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Rng::new(3);
        let n = 10;
        let mut counts = vec![0usize; n];
        for _ in 0..100_000 {
            counts[r.below(n)] += 1;
        }
        for &c in &counts {
            // 10k expected; allow ±15%
            assert!((8_500..11_500).contains(&c), "counts={counts:?}");
        }
    }

    #[test]
    fn gauss_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gauss()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn weighted_respects_mass() {
        let mut r = Rng::new(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((2.6..3.4).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn zipf_is_heavy_headed() {
        let z = Zipf::new(100, 1.1);
        let mut r = Rng::new(13);
        let mut counts = vec![0usize; 100];
        for _ in 0..50_000 {
            counts[z.sample(&mut r)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[50] * 5);
    }

    #[test]
    fn split_streams_are_independent() {
        let mut parent = Rng::new(1234);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 4);
    }
}

//! Minimal JSON parser + serializer.
//!
//! The sandbox has no `serde`/`serde_json`, so this module provides the small
//! JSON surface the framework needs: reading the AOT `artifacts/manifest.json`
//! written by `python/compile/aot.py`, and writing structured experiment
//! reports under `reports/`.
//!
//! The parser is a straightforward recursive-descent over UTF-8 text and
//! supports the full JSON grammar (objects, arrays, strings with escapes,
//! numbers, booleans, null). Numbers are stored as `f64`, which is exact for
//! every integer the manifests contain (|n| < 2^53).

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn from_pairs(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn set(&mut self, key: &str, v: Json) {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v);
        } else {
            panic!("set() on non-object Json");
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(a) => a.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers with contextual error messages.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing JSON key '{key}'"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{key}' is not a string"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_f64()
            .map(|x| x as usize)
            .ok_or_else(|| anyhow::anyhow!("JSON key '{key}' is not a number"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("JSON key '{key}' is not an array"))
    }

    // ---- serialization ----
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// ---- parsing ----

pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

/// Convenience: parse a JSON file.
pub fn parse_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {} (found {:?})",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => {
                anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)
            }
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                other => anyhow::bail!(
                    "expected ',' or '}}' at byte {} (found {:?})",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                other => anyhow::bail!(
                    "expected ',' or ']' at byte {} (found {:?})",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.pos += 4;
                            // Surrogate pairs: join if a low surrogate follows.
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes.get(self.pos + 1) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 2) == Some(&b'u')
                                {
                                    let hex2 = std::str::from_utf8(
                                        &self.bytes[self.pos + 3..self.pos + 7],
                                    )?;
                                    let lo = u32::from_str_radix(hex2, 16)?;
                                    self.pos += 6;
                                    char::from_u32(
                                        0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00),
                                    )
                                    .unwrap_or('\u{fffd}')
                                } else {
                                    '\u{fffd}'
                                }
                            } else {
                                char::from_u32(cp).unwrap_or('\u{fffd}')
                            };
                            s.push(c);
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

// ---- convenience builders ----

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Self {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Self {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Self {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Self {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Self {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [true, null, "x\ny"], "c": {"d": -2.5e3}}"#;
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
        assert_eq!(v.get("b").unwrap().idx(2).unwrap().as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2500.0));
        // Serialize and reparse — must be identical.
        let again = parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, again);
        let again = parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn parses_empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::obj());
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str(), Some("é😀"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        let v = Json::Num(42.0);
        assert_eq!(v.to_string_compact(), "42");
    }

    #[test]
    fn nested_roundtrip_fuzz() {
        // Build a nested structure programmatically; serialize; reparse.
        let mut root = Json::obj();
        for i in 0..20 {
            let arr: Vec<Json> = (0..i)
                .map(|j| {
                    Json::from_pairs(vec![
                        ("idx", Json::from(j as i64)),
                        ("val", Json::from(j as f64 * 0.5)),
                        ("name", Json::from(format!("item-{j}\"quoted\""))),
                    ])
                })
                .collect();
            root.set(&format!("k{i}"), Json::Arr(arr));
        }
        let text = root.to_string_pretty();
        assert_eq!(parse(&text).unwrap(), root);
    }
}

//! Leveled stderr logger (no `log`/`tracing` crates offline).
//!
//! Level is controlled by the `CLOQ_LOG` env var (`error|warn|info|debug`),
//! default `info`; an unrecognized value warns once and falls back to the
//! default. Messages carry a monotonic timestamp since process start so
//! pipeline stage costs are visible in plain runs.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX);
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let var = std::env::var("CLOQ_LOG");
    let parsed = match var.as_deref() {
        Ok("error") => Level::Error,
        Ok("warn") => Level::Warn,
        Ok("info") => Level::Info,
        Ok("debug") => Level::Debug,
        _ => Level::Info,
    } as u8;
    // Store BEFORE warning about an unknown value: the warning itself goes
    // through `log()` → `level()`, and an unset level would recurse.
    LEVEL.store(parsed, Ordering::Relaxed);
    if let Ok(other) = var.as_deref() {
        if !matches!(other, "error" | "warn" | "info" | "debug") {
            static WARNED: std::sync::Once = std::sync::Once::new();
            WARNED.call_once(|| {
                crate::warn!(
                    "CLOQ_LOG={other:?} is not one of error|warn|info|debug; defaulting to info"
                );
            });
        }
    }
    parsed
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn log(l: Level, msg: std::fmt::Arguments<'_>) {
    if (l as u8) <= level() {
        let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
        let tag = match l {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
        };
        eprintln!("[{t:9.3}s {tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($arg)*)) };
}

//! The AOT artifact manifest: the binding contract between
//! `python/compile/aot.py` (producer) and the Rust runtime (consumer).
//!
//! `artifacts/<config>/manifest.json` records, for every lowered entry
//! point, the exact flat ordering of inputs and outputs (names, shapes,
//! dtypes) plus the model configuration the graphs were specialized to.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::runtime::tensor::Dtype;
use crate::util::json::{parse_file, Json};

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> anyhow::Result<TensorSpec> {
        let name = j.req_str("name")?.to_string();
        let shape = j
            .req_arr("shape")?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow::anyhow!("bad shape in {name}")))
            .collect::<anyhow::Result<Vec<_>>>()?;
        let dtype = Dtype::parse(j.req_str("dtype")?)?;
        Ok(TensorSpec { name, shape, dtype })
    }
}

#[derive(Clone, Debug)]
pub struct EntrySpec {
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl EntrySpec {
    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }
}

/// Mirror of `python/compile/model.py::Config`.
#[derive(Clone, Debug)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq: usize,
    pub batch: usize,
    pub rank: usize,
    pub group_size: usize,
}

impl ModelConfig {
    fn from_json(j: &Json) -> anyhow::Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.req_str("name")?.to_string(),
            vocab: j.req_usize("vocab")?,
            d_model: j.req_usize("d_model")?,
            n_layers: j.req_usize("n_layers")?,
            n_heads: j.req_usize("n_heads")?,
            d_ff: j.req_usize("d_ff")?,
            seq: j.req_usize("seq")?,
            batch: j.req_usize("batch")?,
            rank: j.req_usize("rank")?,
            group_size: j.req_usize("group_size")?,
        })
    }

    /// The six LoRA-targeted linear maps of block `l`:
    /// (name, in_dim, out_dim) — mirrors `model.py::linear_specs`.
    pub fn linear_specs(&self, l: usize) -> Vec<(String, usize, usize)> {
        let d = self.d_model;
        let f = self.d_ff;
        vec![
            (format!("l{l}.wq"), d, d),
            (format!("l{l}.wk"), d, d),
            (format!("l{l}.wv"), d, d),
            (format!("l{l}.wo"), d, d),
            (format!("l{l}.w_up"), d, f),
            (format!("l{l}.w_down"), f, d),
        ]
    }

    /// All quantizable linear layer names in canonical order.
    pub fn all_linear_names(&self) -> Vec<String> {
        (0..self.n_layers)
            .flat_map(|l| self.linear_specs(l).into_iter().map(|(n, _, _)| n))
            .collect()
    }

    /// The ordered layer route a full-model forward request traverses
    /// (`serve::forward::ModelRequest`): every linear map in canonical
    /// order. The chain is shape-consistent by construction — the d→d
    /// attention maps, then the d→f up- and f→d down-projection, block
    /// after block — which `PackedModel::route` re-checks against the
    /// packed shapes when the `Route` is built (and the unit test below
    /// pins here).
    pub fn forward_route(&self) -> Vec<String> {
        self.all_linear_names()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: ModelConfig,
    pub entrypoints: BTreeMap<String, EntrySpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let j = parse_file(&dir.join("manifest.json"))?;
        let config = ModelConfig::from_json(j.req("config")?)?;
        let mut entrypoints = BTreeMap::new();
        let eps = j
            .req("entrypoints")?
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("entrypoints not an object"))?;
        for (name, ej) in eps {
            let inputs = ej
                .req_arr("inputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            let outputs = ej
                .req_arr("outputs")?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<anyhow::Result<Vec<_>>>()?;
            entrypoints.insert(
                name.clone(),
                EntrySpec { file: ej.req_str("file")?.to_string(), inputs, outputs },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), config, entrypoints })
    }

    pub fn entry(&self, name: &str) -> anyhow::Result<&EntrySpec> {
        self.entrypoints
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("no entrypoint '{name}' in {}", self.dir.display()))
    }

    pub fn hlo_path(&self, entry: &str) -> anyhow::Result<PathBuf> {
        Ok(self.dir.join(&self.entry(entry)?.file))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_micro() -> Option<PathBuf> {
        let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/micro");
        p.join("manifest.json").exists().then_some(p)
    }

    #[test]
    fn forward_route_is_ordered_and_shape_chainable() {
        let config = ModelConfig {
            name: "t".to_string(),
            vocab: 64,
            d_model: 8,
            n_layers: 3,
            n_heads: 2,
            d_ff: 20,
            seq: 4,
            batch: 1,
            rank: 2,
            group_size: 4,
        };
        let route = config.forward_route();
        assert_eq!(route.len(), 6 * config.n_layers);
        assert_eq!(route[0], "l0.wq");
        assert_eq!(route[5], "l0.w_down");
        assert_eq!(route[6], "l1.wq");
        // Chainability: spec k's out_dim feeds spec k+1's in_dim — the
        // invariant PackedModel::validate_route enforces at admission.
        let specs: Vec<(String, usize, usize)> =
            (0..config.n_layers).flat_map(|l| config.linear_specs(l)).collect();
        assert_eq!(specs.len(), route.len());
        for (k, w) in specs.windows(2).enumerate() {
            assert_eq!(
                w[0].2, w[1].1,
                "route break between {} ({} out) and {} ({} in)",
                w[0].0, w[0].2, w[1].0, w[1].1
            );
            assert_eq!(route[k], w[0].0);
        }
    }

    #[test]
    fn loads_micro_manifest_if_present() {
        let Some(dir) = artifacts_micro() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.config.name, "micro");
        assert!(m.entrypoints.contains_key("lora_step"));
        let e = m.entry("eval_loss").unwrap();
        // tokens + mask at the end of eval_loss inputs.
        let last = &e.inputs[e.inputs.len() - 2];
        assert_eq!(last.name, "tokens");
        assert_eq!(last.dtype, Dtype::I32);
        assert_eq!(last.shape, vec![m.config.batch, m.config.seq]);
        assert_eq!(e.outputs.len(), 2);
        // linear specs consistent with the config.
        let names = m.config.all_linear_names();
        assert_eq!(names.len(), 6 * m.config.n_layers);
    }
}

//! Parameter store: named tensors, initialization, checkpoint I/O.
//!
//! Parameter *specs* (names, shapes, order) are always derived from the
//! artifact manifest — never duplicated in Rust — so the store can't drift
//! from what the lowered graphs expect.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use crate::model::manifest::{Manifest, TensorSpec};
use crate::runtime::tensor::{Dtype, Tensor, TensorData};
use crate::util::prng::Rng;

/// Ordered collection of named tensors.
#[derive(Clone, Debug, Default)]
pub struct ParamStore {
    pub names: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        if !self.map.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> &Tensor {
        self.map
            .get(name)
            .unwrap_or_else(|| panic!("missing param '{name}'"))
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        self.map
            .get_mut(name)
            .unwrap_or_else(|| panic!("missing param '{name}'"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Tensors in insertion order (= manifest order when built from specs).
    pub fn in_order(&self) -> Vec<Tensor> {
        self.names.iter().map(|n| self.map[n].clone()).collect()
    }

    pub fn numel(&self) -> usize {
        self.names.iter().map(|n| self.map[n].numel()).sum()
    }

    // ---- checkpoint I/O (simple length-prefixed binary format) ----

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(b"CLOQCKPT")?;
        f.write_all(&(self.names.len() as u64).to_le_bytes())?;
        for name in &self.names {
            let t = &self.map[name];
            f.write_all(&(name.len() as u32).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            let (dt, bytes): (u8, Vec<u8>) = match &t.data {
                TensorData::F32(v) => (0, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
                TensorData::I32(v) => (1, v.iter().flat_map(|x| x.to_le_bytes()).collect()),
            };
            f.write_all(&[dt])?;
            f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            f.write_all(&bytes)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<ParamStore> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == b"CLOQCKPT", "bad checkpoint magic in {}", path.display());
        let mut store = ParamStore::new();
        let n = read_u64(&mut f)? as usize;
        for _ in 0..n {
            let name_len = read_u32(&mut f)? as usize;
            let mut name_bytes = vec![0u8; name_len];
            f.read_exact(&mut name_bytes)?;
            let name = String::from_utf8(name_bytes)?;
            let mut dt = [0u8; 1];
            f.read_exact(&mut dt)?;
            let rank = read_u32(&mut f)? as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                shape.push(read_u64(&mut f)? as usize);
            }
            let numel: usize = shape.iter().product();
            let t = match dt[0] {
                0 => {
                    let mut buf = vec![0u8; numel * 4];
                    f.read_exact(&mut buf)?;
                    let v = buf
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    Tensor::f32(shape, v)
                }
                1 => {
                    let mut buf = vec![0u8; numel * 4];
                    f.read_exact(&mut buf)?;
                    let v = buf
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect();
                    Tensor::i32(shape, v)
                }
                other => anyhow::bail!("bad dtype tag {other}"),
            };
            store.insert(&name, t);
        }
        Ok(store)
    }
}

fn read_u64(f: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32(f: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

// ---- spec helpers derived from the manifest ----

/// Base (pretrained / frozen) parameter specs: the `eval_loss` inputs that
/// are neither LoRA factors nor the batch.
pub fn base_specs(man: &Manifest) -> anyhow::Result<Vec<TensorSpec>> {
    let e = man.entry("eval_loss")?;
    Ok(e.inputs
        .iter()
        .filter(|s| {
            !s.name.ends_with(".A")
                && !s.name.ends_with(".B")
                && s.name != "tokens"
                && s.name != "mask"
        })
        .cloned()
        .collect())
}

/// LoRA adapter specs (`*.A` / `*.B`), in manifest order.
pub fn lora_specs(man: &Manifest) -> anyhow::Result<Vec<TensorSpec>> {
    let e = man.entry("eval_loss")?;
    Ok(e.inputs
        .iter()
        .filter(|s| s.name.ends_with(".A") || s.name.ends_with(".B"))
        .cloned()
        .collect())
}

/// Quantized-weight input specs of the qeval path (`*.codes/scales/zeros`).
pub fn quant_specs(man: &Manifest) -> anyhow::Result<Vec<TensorSpec>> {
    let e = man.entry("qeval_loss")?;
    Ok(e.inputs
        .iter()
        .filter(|s| {
            s.name.ends_with(".codes") || s.name.ends_with(".scales") || s.name.ends_with(".zeros")
        })
        .cloned()
        .collect())
}

/// GPT-2-style random initialization of the base parameters.
pub fn init_base(man: &Manifest, rng: &mut Rng) -> anyhow::Result<ParamStore> {
    let mut store = ParamStore::new();
    for spec in base_specs(man)? {
        let t = if spec.name.ends_with("_g") {
            Tensor::f32(spec.shape.clone(), vec![1.0; spec.numel()])
        } else if spec.name.ends_with("_b") {
            Tensor::zeros_f32(spec.shape.clone())
        } else {
            let std = 0.06;
            let data: Vec<f32> = (0..spec.numel()).map(|_| rng.normal(0.0, std) as f32).collect();
            Tensor::f32(spec.shape.clone(), data)
        };
        store.insert(&spec.name, t);
    }
    Ok(store)
}

/// Zero tensors matching `specs` (optimizer state, LoRA-B, masks…).
pub fn zeros_for(specs: &[TensorSpec]) -> ParamStore {
    let mut store = ParamStore::new();
    for s in specs {
        let t = match s.dtype {
            Dtype::F32 => Tensor::zeros_f32(s.shape.clone()),
            Dtype::I32 => Tensor::i32(s.shape.clone(), vec![0; s.numel()]),
        };
        store.insert(&s.name, t);
    }
    store
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn store_roundtrip_via_file() {
        let mut s = ParamStore::new();
        s.insert("w1", Tensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]));
        s.insert("codes", Tensor::i32(vec![4], vec![1, 2, 3, 4]));
        s.insert("scalar", Tensor::scalar_f32(7.5));
        let dir = std::env::temp_dir().join(format!("cloq_test_{}", std::process::id()));
        let path = dir.join("ckpt.bin");
        s.save(&path).unwrap();
        let loaded = ParamStore::load(&path).unwrap();
        assert_eq!(loaded.names, s.names);
        for n in &s.names {
            assert_eq!(loaded.get(n), s.get(n), "param {n}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn insert_preserves_order_and_overwrites() {
        let mut s = ParamStore::new();
        s.insert("b", Tensor::scalar_f32(1.0));
        s.insert("a", Tensor::scalar_f32(2.0));
        s.insert("b", Tensor::scalar_f32(3.0));
        assert_eq!(s.names, vec!["b", "a"]);
        assert_eq!(s.get("b").scalar(), 3.0);
        assert_eq!(s.numel(), 2);
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join(format!("cloq_test_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, b"NOTACKPT").unwrap();
        assert!(ParamStore::load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Model state: the artifact manifest (spec contract with the AOT layer)
//! and the parameter store (weights, adapters, optimizer state,
//! checkpoints).

pub mod manifest;
pub mod params;

pub use manifest::{EntrySpec, Manifest, ModelConfig, TensorSpec};
pub use params::{base_specs, init_base, lora_specs, quant_specs, zeros_for, ParamStore};

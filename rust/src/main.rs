//! `cloq` — CLI for the CLoQ reproduction.
//!
//! ```text
//! cloq pretrain  --config tiny-s [--steps 400] [--seed 42]
//! cloq pipeline  --config tiny-s --method cloq --bits 2 --task gsm8k
//! cloq table <1..10> [--fast]
//! cloq fig   <1|2>
//! cloq reports [--fast]          # regenerate everything
//! cloq gen-data --task s-GSM8K -n 5
//! cloq inspect --config tiny-s
//! ```

use cloq::coordinator::tables::{run_fig, run_table, TableOpts};
use cloq::coordinator::{
    ensure_grams, ensure_pretrained, run_one, FinetuneTask, PipelineOpts, RunSpec,
};
use cloq::lowrank::Method;
use cloq::runtime::Runtime;
use cloq::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let result = match args.command.as_str() {
        "pretrain" => cmd_pretrain(&args),
        "pipeline" => cmd_pipeline(&args),
        "table" => cmd_table(&args),
        "fig" => cmd_fig(&args),
        "reports" => cmd_reports(&args),
        "gen-data" => cmd_gen_data(&args),
        "inspect" => cmd_inspect(&args),
        "" | "help" | "--help" => {
            print_help();
            Ok(())
        }
        other => {
            print_help();
            Err(anyhow::anyhow!("unknown command '{other}'"))
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn print_help() {
    println!(
        "cloq — CLoQ: Calibrated LoRA Initialization for Quantized LLMs (reproduction)\n\n\
         commands:\n\
         \x20 pretrain  --config <name> [--steps N] [--seed S]     pretrain + cache the base LM\n\
         \x20 pipeline  --config <name> --method <m> --bits <b> --task <t> [--steps N]\n\
         \x20           methods: lora qlora gptq-lora loftq cloq cloq-nomagr cloq-sqrt cloq-allinb\n\
         \x20           tasks:   wiki gsm8k math10k commonsense mixed\n\
         \x20 table <1..10> [--fast]                                regenerate a paper table\n\
         \x20 fig   <1|2>   [--fast]                                regenerate a paper figure\n\
         \x20 reports [--fast]                                      regenerate all tables+figures\n\
         \x20 gen-data  --task <name> [--n N]                       print synthetic task samples\n\
         \x20 inspect   --config <name>                             artifact manifest summary"
    );
}

fn table_opts(args: &Args) -> TableOpts {
    let mut t = TableOpts::default();
    t.fast = args.has("fast");
    t.steps = args.usize("steps", t.steps);
    t.seed = args.u64("seed", t.seed);
    if let Some(dir) = args.opt_str("reports-dir") {
        t.reports_dir = dir.into();
    }
    t
}

fn cmd_pretrain(args: &Args) -> anyhow::Result<()> {
    let config = args.str("config", "tiny-s");
    let opts = PipelineOpts::new(&config);
    let steps = args.usize("steps", opts.pretrain_steps);
    let seed = args.u64("seed", opts.seed);
    let opts = opts.pretrain_steps(steps).seed(seed);
    let mut rt = Runtime::load(&opts.artifacts)?;
    let (_base, outcome) = ensure_pretrained(&mut rt, &opts)?;
    if let Some(o) = outcome {
        println!("pretrained {config}: final loss {:.4}", o.final_loss);
    } else {
        println!("pretrained base already cached for {config}");
    }
    Ok(())
}

fn cmd_pipeline(args: &Args) -> anyhow::Result<()> {
    let config = args.str("config", "tiny-s");
    let method = Method::parse(&args.str("method", "cloq"))
        .ok_or_else(|| anyhow::anyhow!("bad --method"))?;
    let bits = args.usize("bits", 2) as u32;
    let task = FinetuneTask::parse(&args.str("task", "gsm8k"))
        .ok_or_else(|| anyhow::anyhow!("bad --task"))?;

    let mut opts = PipelineOpts::new(&config);
    if args.has("fast") {
        opts = opts.fast();
    }
    let seed = args.u64("seed", opts.seed);
    let opts = opts.seed(seed);
    let mut rt = Runtime::load(&opts.artifacts)?;
    let (base, _) = ensure_pretrained(&mut rt, &opts)?;
    let grams = ensure_grams(&mut rt, &base, &opts, opts.calib_samples)?;

    let mut spec = RunSpec::new(method, bits, task);
    spec.steps = args.usize("steps", spec.steps);
    spec.lr = args.f64("lr", spec.lr);
    spec.weight_decay = args.f64("wd", spec.weight_decay);
    spec.seed = args.u64("run-seed", spec.seed);
    let r = run_one(&mut rt, &base, &grams, &spec, &opts)?;

    println!("== pipeline result: {} @ {}-bit on {:?} ==", method.name(), bits, task);
    if let Some(p) = r.ppl {
        println!("perplexity       : {p:.3}");
    }
    for (name, acc) in &r.accuracies {
        println!("accuracy {name:12}: {:.1}%", acc * 100.0);
    }
    println!("bits/weight      : {:.2}", r.bits_per_weight);
    println!("init time        : {:.2}s", r.init_seconds);
    println!("finetune time    : {:.2}s ({} steps)", r.finetune_seconds, spec.steps);
    println!("final train loss : {:.4}", r.final_train_loss);
    Ok(())
}

fn cmd_table(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.opt_str("id"))
        .ok_or_else(|| anyhow::anyhow!("usage: cloq table <1..10>"))?;
    run_table(&id, &table_opts(args))
}

fn cmd_fig(args: &Args) -> anyhow::Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .or_else(|| args.opt_str("id"))
        .ok_or_else(|| anyhow::anyhow!("usage: cloq fig <1|2>"))?;
    run_fig(&id, &table_opts(args))
}

fn cmd_reports(args: &Args) -> anyhow::Result<()> {
    let t = table_opts(args);
    for id in ["10", "2", "7", "8", "9", "6", "5", "1", "3", "4"] {
        if let Err(e) = run_table(id, &t) {
            eprintln!("table {id} FAILED: {e:#}");
        }
    }
    for id in ["2", "1"] {
        if let Err(e) = run_fig(id, &t) {
            eprintln!("fig {id} FAILED: {e:#}");
        }
    }
    Ok(())
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    use cloq::data::Task;
    let name = args.str("task", "s-GSM8K");
    let n = args.usize("n", 5);
    let task = Task::parse(&name).ok_or_else(|| anyhow::anyhow!("unknown task '{name}'"))?;
    for ex in task.dataset(n, args.u64("seed", 1), 0) {
        if ex.is_mcq() {
            println!("{}  options={:?}  answer={}", ex.prompt, ex.options, ex.answer);
        } else {
            println!("{}  answer={}", ex.prompt, ex.answer);
        }
    }
    Ok(())
}

fn cmd_inspect(args: &Args) -> anyhow::Result<()> {
    let config = args.str("config", "tiny-s");
    let dir = std::path::PathBuf::from("artifacts").join(&config);
    let man = cloq::model::Manifest::load(&dir)?;
    let c = &man.config;
    println!(
        "config {}: d_model={} layers={} heads={} d_ff={} vocab={} seq={} batch={} rank={} group={}",
        c.name, c.d_model, c.n_layers, c.n_heads, c.d_ff, c.vocab, c.seq, c.batch, c.rank, c.group_size
    );
    for (name, e) in &man.entrypoints {
        let in_elems: usize = e.inputs.iter().map(|s| s.numel()).sum();
        let out_elems: usize = e.outputs.iter().map(|s| s.numel()).sum();
        println!(
            "  {name:16} {} inputs ({:>9} elems)  {} outputs ({:>9} elems)  [{}]",
            e.inputs.len(),
            in_elems,
            e.outputs.len(),
            out_elems,
            e.file
        );
    }
    Ok(())
}

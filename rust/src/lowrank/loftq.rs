//! LoftQ baseline (Li et al., 2023): data-free alternating minimization of
//! `‖Q + A·Bᵀ − W‖_F²` (paper eq. (6)). Default 5 AltMin iterations, each
//! one RTN/NF quantization plus one SVD — exactly the comparator CLoQ's
//! Fig. 2 / tables are measured against.

use crate::linalg::svd::{scale_cols, svd};
use crate::linalg::{matmul_nt, Matrix};
use crate::quant::grid::quantize_rtn;
use crate::quant::nf::quantize_nf;
use crate::quant::QuantizedTensor;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoftqQuantizer {
    /// Uniform INT grid (matches the paper's INT experiments).
    Int,
    /// NF-k codebook (LoftQ's original NF4 setting).
    Nf,
}

#[derive(Clone, Debug)]
pub struct LoftqConfig {
    pub bits: u32,
    pub group_size: usize,
    pub rank: usize,
    pub iters: usize,
    pub quantizer: LoftqQuantizer,
}

impl Default for LoftqConfig {
    fn default() -> Self {
        Self { bits: 4, group_size: 64, rank: 64, iters: 5, quantizer: LoftqQuantizer::Int }
    }
}

pub struct LoftqInit {
    pub q: QuantizedTensor,
    /// Dequantized Q (kept so NF and INT paths expose the same surface).
    pub q_deq: Matrix,
    pub a: Matrix,
    pub b: Matrix,
    /// ‖Q + ABᵀ − W‖_F² per iteration (monotone — asserted in tests).
    pub objective_trace: Vec<f64>,
}

impl LoftqInit {
    pub fn ab_t(&self) -> Matrix {
        matmul_nt(&self.a, &self.b)
    }
}

fn quantize(w: &Matrix, cfg: &LoftqConfig) -> (QuantizedTensor, Matrix) {
    match cfg.quantizer {
        LoftqQuantizer::Int => {
            let q = quantize_rtn(w, cfg.bits, cfg.group_size);
            let d = q.dequantize();
            (q, d)
        }
        LoftqQuantizer::Nf => {
            let nf = quantize_nf(w, cfg.bits, cfg.group_size);
            let d = nf.dequantize();
            // Carry NF dequant through an INT container by re-gridding at
            // 8 bits for storage (value-preserving to fp tolerance is not
            // needed — trainers consume `q_deq` directly).
            let q = quantize_rtn(&d, 8, cfg.group_size);
            (q, d)
        }
    }
}

/// LoftQ Algorithm 1: alternate `Q ← quant(W − ABᵀ)` and
/// `(A,B) ← SVD_r(W − Q)`, starting from `A·Bᵀ = 0`.
pub fn loftq(w: &Matrix, cfg: &LoftqConfig) -> LoftqInit {
    let r = cfg.rank.min(w.rows.min(w.cols));
    let mut ab = Matrix::zeros(w.rows, w.cols);
    let mut trace = Vec::with_capacity(cfg.iters);
    let mut best: Option<(QuantizedTensor, Matrix, Matrix, Matrix, f64)> = None;

    for _ in 0..cfg.iters.max(1) {
        let (q, q_deq) = quantize(&w.sub(&ab), cfg);
        let resid = w.sub(&q_deq);
        let d = svd(&resid).truncate(r);
        // LoftQ's split: A = UΣ, B = V.
        let a = scale_cols(&d.u, &d.s);
        let b = d.v.clone();
        ab = matmul_nt(&a, &b);
        let obj = crate::linalg::norms::fro2(&q_deq.add(&ab).sub(w));
        trace.push(obj);
        let better = best.as_ref().map(|(_, _, _, _, o)| obj < *o).unwrap_or(true);
        if better {
            best = Some((q, q_deq, a, b, obj));
        }
    }
    let (q, q_deq, a, b, _) = best.unwrap();
    LoftqInit { q, q_deq, a, b, objective_trace: trace }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::fro2;
    use crate::util::prng::Rng;

    #[test]
    fn objective_not_worse_than_quant_only() {
        let mut rng = Rng::new(100);
        let w = Matrix::randn(48, 24, 0.5, &mut rng);
        for &bits in &[2u32, 4] {
            let cfg = LoftqConfig {
                bits,
                group_size: 16,
                rank: 8,
                iters: 5,
                quantizer: LoftqQuantizer::Int,
            };
            let init = loftq(&w, &cfg);
            let e_loftq = fro2(&init.q_deq.add(&init.ab_t()).sub(&w));
            let e_quant = fro2(&quantize_rtn(&w, bits, 16).dequantize().sub(&w));
            assert!(e_loftq <= e_quant + 1e-9, "bits={bits}: {e_loftq} vs {e_quant}");
        }
    }

    #[test]
    fn best_iterate_is_returned() {
        let mut rng = Rng::new(101);
        let w = Matrix::randn(32, 16, 0.5, &mut rng);
        let cfg = LoftqConfig { bits: 2, group_size: 32, rank: 4, iters: 8, ..Default::default() };
        let init = loftq(&w, &cfg);
        let returned = fro2(&init.q_deq.add(&init.ab_t()).sub(&w));
        let min_trace = init.objective_trace.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!((returned - min_trace).abs() < 1e-7 * min_trace.max(1e-12));
    }

    #[test]
    fn single_iteration_matches_manual() {
        let mut rng = Rng::new(102);
        let w = Matrix::randn(20, 10, 1.0, &mut rng);
        let cfg = LoftqConfig { bits: 3, group_size: 20, rank: 3, iters: 1, ..Default::default() };
        let init = loftq(&w, &cfg);
        let q_deq = quantize_rtn(&w, 3, 20).dequantize();
        assert!(init.q_deq.max_diff(&q_deq) < 1e-12);
        let expect_ab = crate::linalg::best_rank_r(&w.sub(&q_deq), 3);
        assert!(init.ab_t().max_diff(&expect_ab) < 1e-8);
    }

    #[test]
    fn nf_path_runs() {
        let mut rng = Rng::new(103);
        let w = Matrix::randn(32, 8, 0.1, &mut rng);
        let cfg = LoftqConfig {
            bits: 4,
            group_size: 32,
            rank: 4,
            iters: 3,
            quantizer: LoftqQuantizer::Nf,
        };
        let init = loftq(&w, &cfg);
        let e = fro2(&init.q_deq.add(&init.ab_t()).sub(&w));
        assert!(e < fro2(&w), "reconstruction must beat zero model");
    }

    #[test]
    fn rank_covers_residual_fully_when_large() {
        let mut rng = Rng::new(104);
        let w = Matrix::randn(12, 8, 1.0, &mut rng);
        let cfg = LoftqConfig { bits: 2, group_size: 12, rank: 8, iters: 2, ..Default::default() };
        let init = loftq(&w, &cfg);
        // rank = min(m,n): A·Bᵀ equals the residual exactly → objective ~0.
        let e = fro2(&init.q_deq.add(&init.ab_t()).sub(&w));
        assert!(e < 1e-12, "e={e}");
    }
}

//! LoRA initialization methods: CLoQ's Theorem-3.1 closed form, the LoftQ
//! AltMin baseline, and the per-layer method registry used by the
//! coordinator and bench harness.

pub mod cloq;
pub mod init;
pub mod loftq;
pub mod lqlora;

pub use cloq::{cloq_lowrank, damping_lambda, gram_root, CloqConfig, FactorSplit, LowRankInit};
pub use init::{init_layer, InitConfig, LayerInit, LoraPair, Method};
pub use loftq::{loftq, LoftqConfig, LoftqInit, LoftqQuantizer};
pub use lqlora::lqlora_lowrank;

//! LQ-LoRA-style baseline (Guo et al., 2024): Fisher-weighted low-rank +
//! quantized decomposition, under the row/column homogeneity assumption.
//!
//! The original method weights the reconstruction by the diagonal Fisher
//! matrix (which requires back-propagation through the pre-trained model).
//! Per DESIGN.md §3 we substitute the Fisher proxy the paper's own
//! homogeneity assumption licenses: with `F_ij ≈ r_i · c_j` and activation
//! statistics as the importance signal, the row weights become
//! `r_i = diag(H)_i = Σ_s X_{s,i}²` (input-feature second moments) and
//! `c_j = 1`. The weighted problem then reduces to a *scaled* SVD:
//!
//! ```text
//!   min ‖D^{1/2} (A·Bᵀ − ΔW)‖_F²,   D = diag(diag(H))
//!   ⇒ A·Bᵀ = D^{-1/2} · LR_r(D^{1/2} ΔW)
//! ```
//!
//! which is exactly CLoQ's Theorem 3.1 with H replaced by its diagonal —
//! making this baseline the scientifically interesting midpoint between
//! LoftQ (no activation information) and CLoQ (the full Gram matrix). The
//! ablation `bench_cloq` and `prop_lowrank` quantify the gap.

use crate::linalg::svd::{scale_cols, svd};
use crate::linalg::Matrix;
use crate::lowrank::cloq::LowRankInit;

/// Closed-form weighted low-rank init with D = diag(diag(H)) + λ.
pub fn lqlora_lowrank(h: &Matrix, delta_w: &Matrix, rank: usize, damp_pct: f64) -> LowRankInit {
    assert_eq!(h.rows, delta_w.rows);
    let m = h.rows;
    let r = rank.min(delta_w.rows.min(delta_w.cols));
    let lambda = damp_pct * h.trace() / m as f64;
    let d: Vec<f64> = (0..m).map(|i| (h.at(i, i) + lambda).max(1e-300)).collect();
    let d_sqrt: Vec<f64> = d.iter().map(|x| x.sqrt()).collect();
    let d_isqrt: Vec<f64> = d_sqrt.iter().map(|x| 1.0 / x).collect();

    // Scale rows of ΔW by D^{1/2}.
    let scaled = Matrix::from_fn(delta_w.rows, delta_w.cols, |i, j| d_sqrt[i] * delta_w.at(i, j));
    let dec = svd(&scaled);
    let objective: f64 = dec.s.iter().skip(r).map(|s| s * s).sum();
    let dec = dec.truncate(r);
    // A = D^{-1/2} U Σ, B = V (AllInA split, matching CLoQ's default).
    let us = scale_cols(&dec.u, &dec.s);
    let a = Matrix::from_fn(m, r, |i, j| d_isqrt[i] * us.at(i, j));
    LowRankInit { a, b: dec.v, objective }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_nt, syrk_t};
    use crate::lowrank::cloq::{cloq_lowrank, damping_lambda, CloqConfig};
    use crate::quant::metrics::calibrated_error2;
    use crate::util::prng::Rng;

    fn setup(seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        // Strongly anisotropic, correlated activations.
        let base = Matrix::randn(200, 6, 1.0, &mut rng);
        let mix = Matrix::randn(6, 24, 1.0, &mut rng);
        let x = matmul(&base, &mix);
        let dw = Matrix::randn(24, 16, 0.3, &mut rng);
        let h = syrk_t(&x);
        (x, dw, h)
    }

    #[test]
    fn weighted_objective_is_optimal_for_diagonal_h() {
        // When H is EXACTLY diagonal, LQ-LoRA == CLoQ (both solve the same
        // problem); verify they agree.
        let mut rng = Rng::new(130);
        let d: Vec<f64> = (0..12).map(|_| rng.range_f64(0.1, 5.0)).collect();
        let h = Matrix::diag(&d);
        let dw = Matrix::randn(12, 9, 1.0, &mut rng);
        let lq = lqlora_lowrank(&h, &dw, 3, 0.0);
        let cq = cloq_lowrank(&h, &dw, &CloqConfig { rank: 3, ..Default::default() });
        let e_lq = calibrated_error2(&h, &lq.ab_t().sub(&dw));
        let e_cq = calibrated_error2(&h, &cq.ab_t().sub(&dw));
        assert!((e_lq - e_cq).abs() < 1e-7 * e_cq.max(1e-9), "{e_lq} vs {e_cq}");
    }

    #[test]
    fn between_loftq_and_cloq_on_correlated_activations() {
        // The ablation claim: diag(H) information helps over no-X (LoftQ's
        // plain SVD) but loses to the full Gram (CLoQ) when activations are
        // correlated. Checked across seeds with majority voting (the
        // midpoint can tie on near-diagonal draws).
        let mut lq_beats_plain = 0;
        let mut cq_beats_lq = 0;
        let n_seeds = 10u64;
        for seed in 0..n_seeds {
            let (_, dw, h) = setup(131 + seed);
            let mut hd = h.clone();
            hd.add_diag(damping_lambda(&h, 0.01));
            let r = 4;
            let plain = crate::linalg::best_rank_r(&dw, r);
            let e_plain = calibrated_error2(&hd, &plain.sub(&dw));
            let lq = lqlora_lowrank(&h, &dw, r, 0.01);
            let e_lq = calibrated_error2(&hd, &lq.ab_t().sub(&dw));
            let cq = cloq_lowrank(&hd, &dw, &CloqConfig { rank: r, ..Default::default() });
            let e_cq = calibrated_error2(&hd, &cq.ab_t().sub(&dw));
            assert!(e_cq <= e_lq + 1e-9, "seed={seed}: CLoQ must dominate (optimal)");
            if e_lq < e_plain {
                lq_beats_plain += 1;
            }
            if e_cq < e_lq * 0.999 {
                cq_beats_lq += 1;
            }
        }
        assert!(
            lq_beats_plain >= 6,
            "diag-H should usually beat plain SVD: {lq_beats_plain}/{n_seeds}"
        );
        assert!(
            cq_beats_lq >= 6,
            "full H should usually strictly beat diag-H: {cq_beats_lq}/{n_seeds}"
        );
    }

    #[test]
    fn shapes_and_finiteness() {
        let (_, dw, h) = setup(140);
        let lq = lqlora_lowrank(&h, &dw, 5, 0.01);
        assert_eq!(lq.a.rows, 24);
        assert_eq!(lq.a.cols, 5);
        assert_eq!(lq.b.rows, 16);
        assert!(lq.a.max_abs().is_finite());
        assert!(matmul_nt(&lq.a, &lq.b).max_abs().is_finite());
    }
}

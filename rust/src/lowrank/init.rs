//! Per-layer initialization method registry — every row of the paper's
//! tables corresponds to one [`Method`] here.
//!
//! Each method takes the pre-trained layer weights `W` (m×n, `Y = X·W`
//! orientation), optionally the calibration Gram matrix `H = XᵀX`, and a
//! seed, and produces the frozen base `Q` plus LoRA factors `(A, B)`.

use crate::linalg::Matrix;
use crate::lowrank::cloq::{cloq_lowrank, damping_lambda, CloqConfig, FactorSplit};
use crate::lowrank::loftq::{loftq, LoftqConfig, LoftqQuantizer};
use crate::quant::magr::{magr, MagrConfig};
use crate::quant::optq::{optq, OptqConfig};
use crate::quant::{quantize_nf, QuantState};
use crate::util::prng::Rng;

/// The fine-tuning initialization methods compared in the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// FP16 LoRA (no quantization): Q = W, A ~ N(0, σ²), B = 0.
    Lora16,
    /// QLoRA: NF-k quantization, standard (Gaussian, zero) LoRA init.
    QLora,
    /// GPTQ-LoRA: OPTQ base, standard LoRA init.
    GptqLora,
    /// LoftQ: data-free AltMin of ‖Q + ABᵀ − W‖_F².
    LoftQ,
    /// CLoQ (ours): MagR+OPTQ base, Theorem-3.1 calibrated low-rank init.
    CLoQ,
    /// CLoQ without MagR preprocessing (ablation).
    CLoQNoMagR,
    /// CLoQ with the √Σ factor split (Table 7 ablation).
    CLoQSqrtSplit,
    /// CLoQ with the Σ-in-B split (Table 7 ablation).
    CLoQAllInB,
}

impl Method {
    pub fn name(&self) -> &'static str {
        match self {
            Method::Lora16 => "LoRA",
            Method::QLora => "QLoRA",
            Method::GptqLora => "GPTQ-LoRA",
            Method::LoftQ => "LoftQ",
            Method::CLoQ => "CLoQ",
            Method::CLoQNoMagR => "CLoQ(-MagR)",
            Method::CLoQSqrtSplit => "CLoQ(sqrt split)",
            Method::CLoQAllInB => "CLoQ(S in B)",
        }
    }

    pub fn parse(s: &str) -> Option<Method> {
        Some(match s.to_ascii_lowercase().as_str() {
            "lora" | "lora16" => Method::Lora16,
            "qlora" => Method::QLora,
            "gptq-lora" | "gptqlora" => Method::GptqLora,
            "loftq" => Method::LoftQ,
            "cloq" => Method::CLoQ,
            "cloq-nomagr" => Method::CLoQNoMagR,
            "cloq-sqrt" => Method::CLoQSqrtSplit,
            "cloq-allinb" => Method::CLoQAllInB,
            _ => return None,
        })
    }

    /// Does this method consume calibration data (a Gram matrix)?
    pub fn needs_calibration(&self) -> bool {
        matches!(
            self,
            Method::GptqLora
                | Method::CLoQ
                | Method::CLoQNoMagR
                | Method::CLoQSqrtSplit
                | Method::CLoQAllInB
        )
    }
}

#[derive(Clone, Debug)]
pub struct InitConfig {
    pub method: Method,
    pub bits: u32,
    pub group_size: usize,
    pub rank: usize,
    /// Damping percent for H (paper: 0.01).
    pub damp_percent: f64,
    /// LoftQ AltMin iterations (paper default: 5).
    pub loftq_iters: usize,
    pub magr: MagrConfig,
}

impl InitConfig {
    pub fn new(method: Method, bits: u32, rank: usize) -> Self {
        Self {
            method,
            bits,
            group_size: 64,
            rank,
            damp_percent: 0.01,
            loftq_iters: 5,
            magr: MagrConfig::default(),
        }
    }
}

/// One layer's LoRA factor pair `(A, B)` with `delta = A·Bᵀ` — the unit
/// the serving path ships and hot-swaps independently of the frozen base
/// (`serve::adapters::AdapterSet` is a named collection of these).
#[derive(Clone, Debug)]
pub struct LoraPair {
    /// m×r factor.
    pub a: Matrix,
    /// n×r factor.
    pub b: Matrix,
}

impl LoraPair {
    pub fn new(a: Matrix, b: Matrix) -> LoraPair {
        assert_eq!(a.cols, b.cols, "LoraPair: rank mismatch {} vs {}", a.cols, b.cols);
        LoraPair { a, b }
    }

    pub fn rank(&self) -> usize {
        self.a.cols
    }

    /// Storage footprint in bytes (both factors, f64).
    pub fn bytes(&self) -> usize {
        (self.a.data.len() + self.b.data.len()) * 8
    }
}

/// The initialized layer: frozen base + trainable adapters.
pub struct LayerInit {
    /// Dequantized frozen base Q (m×n). For `Lora16` this is W itself.
    pub q_deq: Matrix,
    /// The exact quantization state (INT grid codes/scales/zeros, or the NF
    /// codebook + absmax for QLoRA) when the method produces one — consumed
    /// verbatim by the packed serving path (`serve::packed`) so the fused
    /// kernel agrees with `q_deq` bit-for-bit. `None` only for methods that
    /// keep the fp base (LoRA16); the serve builder re-grids those.
    pub quant: Option<QuantState>,
    /// m×r adapter.
    pub a: Matrix,
    /// n×r adapter.
    pub b: Matrix,
    /// Nominal storage bits per base weight.
    pub bits_per_weight: f64,
}

impl LayerInit {
    /// Extract the adapter as a standalone [`LoraPair`] — what the serving
    /// path registers per tenant, decoupled from the frozen packed base.
    pub fn lora_pair(&self) -> LoraPair {
        LoraPair::new(self.a.clone(), self.b.clone())
    }
}

/// Initialize one linear layer. `h` is the **undamped** Gram matrix; it is
/// required iff `cfg.method.needs_calibration()`.
pub fn init_layer(w: &Matrix, h: Option<&Matrix>, cfg: &InitConfig, rng: &mut Rng) -> LayerInit {
    let r = cfg.rank.min(w.rows.min(w.cols));
    // Standard LoRA init: A ~ N(0, 1/r) Kaiming-ish, B = 0 → A·Bᵀ = 0.
    let std_lora = |rng: &mut Rng| {
        let a = Matrix::randn(w.rows, r, 1.0 / (r as f64).sqrt(), rng);
        let b = Matrix::zeros(w.cols, r);
        (a, b)
    };

    match cfg.method {
        Method::Lora16 => {
            let (a, b) = std_lora(rng);
            LayerInit { q_deq: w.clone(), a, b, bits_per_weight: 16.0, quant: None }
        }
        Method::QLora => {
            let q = quantize_nf(w, cfg.bits, cfg.group_size);
            let (a, b) = std_lora(rng);
            LayerInit {
                q_deq: q.dequantize(),
                a,
                b,
                bits_per_weight: cfg.bits as f64 + 16.0 / cfg.group_size as f64,
                // NF codebook ≠ INT grid, so serving carries the codebook
                // itself: packed codes index the levels table (the artifact
                // stores both), no lossy re-grid.
                quant: Some(QuantState::Nf(q)),
            }
        }
        Method::GptqLora => {
            let h = h.expect("GPTQ-LoRA needs calibration H");
            let q = optq(
                w,
                h,
                &OptqConfig {
                    bits: cfg.bits,
                    group_size: cfg.group_size,
                    damp_percent: cfg.damp_percent,
                    ..Default::default()
                },
            );
            let (a, b) = std_lora(rng);
            LayerInit {
                q_deq: q.dequantize(),
                a,
                b,
                bits_per_weight: q.bits_per_weight(),
                quant: Some(QuantState::Int(q)),
            }
        }
        Method::LoftQ => {
            let init = loftq(
                w,
                &LoftqConfig {
                    bits: cfg.bits,
                    group_size: cfg.group_size,
                    rank: r,
                    iters: cfg.loftq_iters,
                    quantizer: LoftqQuantizer::Int,
                },
            );
            let bpw = init.q.bits_per_weight();
            LayerInit {
                q_deq: init.q_deq,
                a: init.a,
                b: init.b,
                bits_per_weight: bpw,
                quant: Some(QuantState::Int(init.q)),
            }
        }
        Method::CLoQ | Method::CLoQNoMagR | Method::CLoQSqrtSplit | Method::CLoQAllInB => {
            let h = h.expect("CLoQ needs calibration H");
            // Step 1 (paper §3.1.1): MagR preprocessing + OPTQ.
            let w_pre = if cfg.method == Method::CLoQNoMagR {
                w.clone()
            } else {
                magr(w, h, &cfg.magr)
            };
            let q = optq(
                &w_pre,
                h,
                &OptqConfig {
                    bits: cfg.bits,
                    group_size: cfg.group_size,
                    damp_percent: cfg.damp_percent,
                    ..Default::default()
                },
            );
            let q_deq = q.dequantize();
            // Step 2 (paper §3.1.2): closed-form calibrated low-rank init of
            // the residual vs the ORIGINAL weights.
            let delta_w = w.sub(&q_deq);
            let mut hd = h.clone();
            hd.add_diag(damping_lambda(h, cfg.damp_percent));
            let split = match cfg.method {
                Method::CLoQSqrtSplit => FactorSplit::Sqrt,
                Method::CLoQAllInB => FactorSplit::AllInB,
                _ => FactorSplit::AllInA,
            };
            // Randomized truncated SVD: exact-to-tolerance on these residual
            // spectra and ~2.2x faster (EXPERIMENTS.md §Perf).
            let ccfg = CloqConfig { rank: r, split, rcond: 1e-12, randomized: true };
            let lr = cloq_lowrank(&hd, &delta_w, &ccfg);
            LayerInit {
                q_deq,
                a: lr.a,
                b: lr.b,
                bits_per_weight: q.bits_per_weight(),
                quant: Some(QuantState::Int(q)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul_nt, syrk_t};
    use crate::quant::metrics::calibrated_error2;

    fn setup(seed: u64) -> (Matrix, Matrix, Rng) {
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(128, 32, 1.0, &mut rng);
        let w = Matrix::randn(32, 24, 0.3, &mut rng);
        let h = syrk_t(&x);
        (w, h, rng)
    }

    fn init_discrepancy(w: &Matrix, h: &Matrix, li: &LayerInit) -> f64 {
        // ‖X(Q + ABᵀ − W)‖² — the paper's problem (2) objective.
        let e = li.q_deq.add(&matmul_nt(&li.a, &li.b)).sub(w);
        calibrated_error2(h, &e)
    }

    #[test]
    fn all_methods_produce_shapes() {
        let (w, h, mut rng) = setup(110);
        for m in [
            Method::Lora16,
            Method::QLora,
            Method::GptqLora,
            Method::LoftQ,
            Method::CLoQ,
            Method::CLoQNoMagR,
            Method::CLoQSqrtSplit,
            Method::CLoQAllInB,
        ] {
            let cfg = InitConfig::new(m, 2, 8);
            let li = init_layer(&w, Some(&h), &cfg, &mut rng);
            assert_eq!(li.q_deq.rows, 32);
            assert_eq!(li.q_deq.cols, 24);
            assert_eq!(li.a.rows, 32);
            assert_eq!(li.a.cols, 8);
            assert_eq!(li.b.rows, 24);
            assert_eq!(li.b.cols, 8);
            assert!(li.q_deq.max_abs().is_finite());
        }
    }

    #[test]
    fn lora16_is_exact_at_init() {
        let (w, h, mut rng) = setup(111);
        let li = init_layer(&w, Some(&h), &InitConfig::new(Method::Lora16, 16, 8), &mut rng);
        assert!(init_discrepancy(&w, &h, &li) < 1e-18);
    }

    #[test]
    fn cloq_beats_loftq_and_qlora_at_2bit() {
        // Fig. 2's claim, as a hard unit test: the calibrated discrepancy of
        // the CLoQ init is below LoftQ and QLoRA at INT2.
        for seed in [112u64, 113, 114] {
            let (w, h, mut rng) = setup(seed);
            let mk = |m, rng: &mut Rng| {
                let mut cfg = InitConfig::new(m, 2, 8);
                cfg.group_size = 32;
                init_layer(&w, Some(&h), &cfg, rng)
            };
            let e_cloq = init_discrepancy(&w, &h, &mk(Method::CLoQ, &mut rng));
            let e_loftq = init_discrepancy(&w, &h, &mk(Method::LoftQ, &mut rng));
            let e_qlora = init_discrepancy(&w, &h, &mk(Method::QLora, &mut rng));
            assert!(e_cloq < e_loftq, "seed {seed}: cloq {e_cloq} loftq {e_loftq}");
            assert!(e_cloq < e_qlora, "seed {seed}: cloq {e_cloq} qlora {e_qlora}");
        }
    }

    #[test]
    fn cloq_beats_gptq_lora_given_same_base() {
        // With the identical OPTQ base, the calibrated low-rank correction
        // can only reduce the discrepancy vs the zero-init adapter.
        let (w, h, mut rng) = setup(115);
        let mut cfg = InitConfig::new(Method::CLoQNoMagR, 2, 8);
        cfg.group_size = 32;
        let cloq = init_layer(&w, Some(&h), &cfg, &mut rng);
        let mut gcfg = InitConfig::new(Method::GptqLora, 2, 8);
        gcfg.group_size = 32;
        let gptq = init_layer(&w, Some(&h), &gcfg, &mut rng);
        // Same base (both OPTQ, no MagR) ⇒ same q_deq.
        assert!(cloq.q_deq.max_diff(&gptq.q_deq) < 1e-12);
        assert!(init_discrepancy(&w, &h, &cloq) <= init_discrepancy(&w, &h, &gptq) + 1e-9);
    }

    #[test]
    fn standard_splits_ab_product_zero() {
        let (w, h, mut rng) = setup(116);
        for m in [Method::QLora, Method::GptqLora] {
            let li = init_layer(&w, Some(&h), &InitConfig::new(m, 4, 8), &mut rng);
            assert!(matmul_nt(&li.a, &li.b).max_abs() < 1e-12, "{m:?} must start at Q");
        }
    }

    #[test]
    fn bits_accounting() {
        let (w, h, mut rng) = setup(117);
        let li4 = init_layer(&w, Some(&h), &InitConfig::new(Method::CLoQ, 4, 4), &mut rng);
        let li2 = init_layer(&w, Some(&h), &InitConfig::new(Method::CLoQ, 2, 4), &mut rng);
        assert!(li2.bits_per_weight < li4.bits_per_weight);
        assert!(li2.bits_per_weight >= 2.0);
    }

    #[test]
    fn exact_state_dequantizes_to_q_deq() {
        // The serving contract: whenever a method hands over a quantization
        // state, re-dequantizing that state reproduces `q_deq` bit-for-bit
        // (the packed serve path consumes the state, the trainer consumes
        // q_deq — they must be the same numbers).
        let (w, h, mut rng) = setup(118);
        for m in [
            Method::QLora,
            Method::GptqLora,
            Method::LoftQ,
            Method::CLoQ,
            Method::CLoQNoMagR,
            Method::CLoQSqrtSplit,
            Method::CLoQAllInB,
        ] {
            let li = init_layer(&w, Some(&h), &InitConfig::new(m, 3, 4), &mut rng);
            let qs = li.quant.as_ref().unwrap_or_else(|| panic!("{m:?} must produce state"));
            assert_eq!(qs.dequantize().data, li.q_deq.data, "{m:?}");
        }
        let li = init_layer(&w, Some(&h), &InitConfig::new(Method::Lora16, 16, 4), &mut rng);
        assert!(li.quant.is_none(), "LoRA16 keeps the fp base");
    }

    #[test]
    fn method_parse_roundtrip() {
        for (s, m) in [
            ("lora", Method::Lora16),
            ("qlora", Method::QLora),
            ("gptq-lora", Method::GptqLora),
            ("loftq", Method::LoftQ),
            ("cloq", Method::CLoQ),
        ] {
            assert_eq!(Method::parse(s), Some(m));
        }
        assert_eq!(Method::parse("nope"), None);
    }
}

//! CLoQ's generalized low-rank approximation (paper §3.1.2, Theorem 3.1).
//!
//! Given the (damped) Gram matrix `H = XᵀX + λI` and the quantization
//! residual `ΔW = W − Q`, find `A ∈ ℝ^{m×r}, B ∈ ℝ^{n×r}` minimizing
//! `‖X(A·Bᵀ − ΔW)‖_F²` in closed form:
//!
//! ```text
//!   H = U_H Σ_H U_Hᵀ                (one symmetric SVD/eig)
//!   R = Σ_H^{1/2} U_Hᵀ              (non-symmetric root, H = RᵀR)
//!   LR_r(R·ΔW) = U_{:r} Σ_{:r} V_{:r}ᵀ    (one more SVD)
//!   A·Bᵀ = R⁻¹ · LR_r(R·ΔW)
//! ```
//!
//! The factorization of `A·Bᵀ` into `(A, B)` is not unique; the paper's
//! Table 7 ablates three splits and finds `A = R⁻¹U_{:r}Σ_{:r}`, `B = V_{:r}`
//! (all energy in A) the best for subsequent fine-tuning — that is our
//! default [`FactorSplit::AllInA`].

use crate::linalg::eig::sym_eig;
use crate::linalg::svd::{scale_cols, svd};
use crate::linalg::{matmul, matmul_nt, Matrix};

/// How to split `A·Bᵀ = R⁻¹·U Σ Vᵀ` into `(A, B)` — the paper's Table 7.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FactorSplit {
    /// `A = R⁻¹ U Σ, B = V` (paper default; best fine-tuning accuracy).
    AllInA,
    /// `A = R⁻¹ U Σ^{1/2}, B = V Σ^{1/2}`.
    Sqrt,
    /// `A = R⁻¹ U, B = V Σ` (paper: diverges during fine-tuning).
    AllInB,
}

impl FactorSplit {
    pub fn name(&self) -> &'static str {
        match self {
            FactorSplit::AllInA => "(R^-1 U S, V)",
            FactorSplit::Sqrt => "(R^-1 U S^1/2, V S^1/2)",
            FactorSplit::AllInB => "(R^-1 U, V S)",
        }
    }
}

#[derive(Clone, Debug)]
pub struct CloqConfig {
    pub rank: usize,
    pub split: FactorSplit,
    /// Relative eigenvalue cutoff below which H directions are treated as
    /// null (pseudo-inverse branch of the paper's rank-deficient remark).
    pub rcond: f64,
    /// Use the randomized truncated SVD for `LR_r(R·ΔW)` (§Perf: ~O(mnr)
    /// instead of O(min(m,n)²·max(m,n)); exact for the fast-decaying
    /// residual spectra the pipeline produces). The Gram eig stays exact.
    pub randomized: bool,
}

impl Default for CloqConfig {
    fn default() -> Self {
        Self { rank: 64, split: FactorSplit::AllInA, rcond: 1e-12, randomized: false }
    }
}

/// Result of the closed-form initialization.
pub struct LowRankInit {
    /// m×r.
    pub a: Matrix,
    /// n×r.
    pub b: Matrix,
    /// Optimal objective value `‖X(A·Bᵀ − ΔW)‖_F²` (= Σ_{i>r} σ_i²(R·ΔW)),
    /// reported for Fig. 2 / diagnostics.
    pub objective: f64,
}

impl LowRankInit {
    /// `A·Bᵀ` (m×n).
    pub fn ab_t(&self) -> Matrix {
        matmul_nt(&self.a, &self.b)
    }
}

/// Internal: the root `R = Σ^{1/2}Uᵀ` and its pseudo-inverse
/// `R⁺ = U Σ^{-1/2}`, from the eigendecomposition of `H`.
pub struct GramRoot {
    /// m×m, `H = RᵀR`.
    pub r: Matrix,
    /// m×m pseudo-inverse (exact inverse when H is full-rank).
    pub r_pinv: Matrix,
    /// Rank of H at the configured cutoff.
    pub rank: usize,
}

/// Factor `H` (symmetric PSD) into its non-symmetric root.
pub fn gram_root(h: &Matrix, rcond: f64) -> GramRoot {
    let m = h.rows;
    let e = sym_eig(h);
    let lmax = e.values.first().copied().unwrap_or(0.0).max(0.0);
    let cutoff = rcond * lmax;
    let mut rank = 0;
    let mut sqrt_vals = vec![0.0; m];
    let mut inv_sqrt_vals = vec![0.0; m];
    for (i, &l) in e.values.iter().enumerate() {
        if l > cutoff && l > 0.0 {
            sqrt_vals[i] = l.sqrt();
            inv_sqrt_vals[i] = 1.0 / l.sqrt();
            rank += 1;
        }
    }
    // R = Σ^{1/2} Uᵀ → scale *rows* of Uᵀ ⇔ scale cols of U then transpose.
    let r = scale_cols(&e.vectors, &sqrt_vals).transpose();
    // R⁺ = U Σ^{-1/2}.
    let r_pinv = scale_cols(&e.vectors, &inv_sqrt_vals);
    GramRoot { r, r_pinv, rank }
}

/// Algorithm 1, steps 3–6: closed-form optimal (A, B) for
/// `min ‖X(A·Bᵀ − ΔW)‖_F²` given `H` (already damped by the caller — see
/// [`damping_lambda`]).
pub fn cloq_lowrank(h: &Matrix, delta_w: &Matrix, cfg: &CloqConfig) -> LowRankInit {
    assert_eq!(h.rows, delta_w.rows, "H is m×m over input features");
    let r = cfg.rank.min(delta_w.rows.min(delta_w.cols));

    // §Perf: Theorem 3.1 holds for ANY invertible root with H = RᵀR — the
    // proof only uses that identity — and the resulting (A, B) is root-
    // independent (two roots differ by a left-orthogonal factor Q, which
    // transports into U of the SVD and cancels through R⁻¹U). The Cholesky
    // factor (R = Lᵀ) is an order of magnitude cheaper than the Jacobi
    // eigendecomposition at m ≥ 256 and turns R⁻¹· into triangular solves.
    // Fall back to the paper's symmetric root via eig when H is not PD
    // (the rank-deficient / pseudo-inverse remark of §3.1.2).
    if let Ok(l) = crate::linalg::chol::cholesky(h) {
        return cloq_lowrank_chol(&l, delta_w, r, cfg);
    }
    let root = gram_root(h, cfg.rcond);

    // SVD of R·ΔW, truncated to rank r (randomized sketch on the fast
    // path — see CloqConfig::randomized).
    let rdw = matmul(&root.r, delta_w);
    let (d, objective) = if cfg.randomized {
        let total = crate::linalg::norms::fro2(&rdw);
        let mut rng = crate::util::prng::Rng::new(0x5EED_C10A);
        let d = crate::linalg::rsvd::rsvd(&rdw, r, &Default::default(), &mut rng);
        let captured: f64 = d.s.iter().map(|s| s * s).sum();
        (d, (total - captured).max(0.0))
    } else {
        let d = svd(&rdw);
        let objective: f64 = d.s.iter().skip(r).map(|s| s * s).sum();
        (d.truncate(r), objective)
    };

    // Split Σ between the factors.
    let (sa, sb): (Vec<f64>, Vec<f64>) = match cfg.split {
        FactorSplit::AllInA => (d.s.clone(), vec![1.0; r]),
        FactorSplit::AllInB => (vec![1.0; r], d.s.clone()),
        FactorSplit::Sqrt => {
            let sq: Vec<f64> = d.s.iter().map(|s| s.sqrt()).collect();
            (sq.clone(), sq)
        }
    };

    // A = R⁺ · U_{:r} · diag(sa);  B = V_{:r} · diag(sb).
    let a = matmul(&root.r_pinv, &scale_cols(&d.u, &sa));
    let b = scale_cols(&d.v, &sb);
    LowRankInit { a, b, objective }
}

/// Fast path: closed form with the Cholesky root `R = Lᵀ` (H = L·Lᵀ PD).
fn cloq_lowrank_chol(l: &Matrix, delta_w: &Matrix, r: usize, cfg: &CloqConfig) -> LowRankInit {
    use crate::linalg::chol::solve_lower_t;
    let m = l.rows;
    // R·ΔW = Lᵀ·ΔW.
    let rdw = crate::linalg::matmul_tn(l, delta_w);
    let (d, objective) = if cfg.randomized {
        let total = crate::linalg::norms::fro2(&rdw);
        let mut rng = crate::util::prng::Rng::new(0x5EED_C10A);
        let d = crate::linalg::rsvd::rsvd(&rdw, r, &Default::default(), &mut rng);
        let captured: f64 = d.s.iter().map(|s| s * s).sum();
        (d, (total - captured).max(0.0))
    } else {
        let d = svd(&rdw);
        let objective: f64 = d.s.iter().skip(r).map(|s| s * s).sum();
        (d.truncate(r), objective)
    };
    let (sa, sb): (Vec<f64>, Vec<f64>) = match cfg.split {
        FactorSplit::AllInA => (d.s.clone(), vec![1.0; r]),
        FactorSplit::AllInB => (vec![1.0; r], d.s.clone()),
        FactorSplit::Sqrt => {
            let sq: Vec<f64> = d.s.iter().map(|s| s.sqrt()).collect();
            (sq.clone(), sq)
        }
    };
    // A = R⁻¹·(U·diag(sa)) via triangular solves Lᵀ·a_j = (U·sa)_j.
    let us = scale_cols(&d.u, &sa);
    let mut a = Matrix::zeros(m, r);
    for j in 0..r {
        let col = solve_lower_t(l, &us.col(j));
        a.set_col(j, &col);
    }
    LowRankInit { a, b: scale_cols(&d.v, &sb), objective }
}

/// The paper's damping rule: `λ = pct · Tr(H)/m` (§3.1.2, default pct 0.01).
pub fn damping_lambda(h: &Matrix, pct: f64) -> f64 {
    pct * h.trace() / h.rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::fro2;
    use crate::linalg::syrk_t;
    use crate::quant::metrics::calibrated_error2;
    use crate::util::prng::Rng;

    fn setup(m: usize, n: usize, samples: usize, seed: u64) -> (Matrix, Matrix, Matrix) {
        let mut rng = Rng::new(seed);
        let x = Matrix::randn(samples, m, 1.0, &mut rng);
        let dw = Matrix::randn(m, n, 0.2, &mut rng);
        let mut h = syrk_t(&x);
        let lam = damping_lambda(&h, 0.01);
        h.add_diag(lam);
        (x, dw, h)
    }

    #[test]
    fn gram_root_squares_to_h() {
        let (_, _, h) = setup(16, 4, 64, 90);
        let root = gram_root(&h, 1e-12);
        let rtr = matmul(&root.r.transpose(), &root.r);
        assert!(h.max_diff(&rtr) < 1e-8 * h.max_abs());
        assert_eq!(root.rank, 16);
        // R⁺ is the true inverse here.
        let id = matmul(&root.r, &root.r_pinv);
        assert!(id.max_diff(&Matrix::eye(16)) < 1e-7);
    }

    #[test]
    fn theorem_3_1_exact_at_full_rank() {
        // r = min(m,n) ⇒ A·Bᵀ = ΔW exactly (H invertible).
        let (_, dw, h) = setup(12, 8, 48, 91);
        let init = cloq_lowrank(&h, &dw, &CloqConfig { rank: 8, ..Default::default() });
        assert!(dw.max_diff(&init.ab_t()) < 1e-7);
        assert!(init.objective < 1e-12);
    }

    #[test]
    fn objective_matches_reported_value() {
        let (_, dw, h) = setup(20, 10, 80, 92);
        for r in [1usize, 3, 7] {
            let init = cloq_lowrank(&h, &dw, &CloqConfig { rank: r, ..Default::default() });
            let resid = init.ab_t().sub(&dw);
            let direct = calibrated_error2(&h, &resid);
            assert!(
                (direct - init.objective).abs() < 1e-7 * init.objective.max(1e-12),
                "r={r}: direct {direct} vs reported {}",
                init.objective
            );
        }
    }

    #[test]
    fn optimality_beats_plain_svd_and_random() {
        // The paper's key point: LR of ΔW directly (LoftQ-style, no X) is
        // suboptimal for the calibrated objective.
        let mut rng = Rng::new(93);
        // Anisotropic activations make the gap pronounced.
        let base = Matrix::randn(100, 16, 1.0, &mut rng);
        let scales: Vec<f64> = (0..16).map(|i| 1.0 / (1.0 + i as f64)).collect();
        let x = Matrix::from_fn(100, 16, |i, j| base.at(i, j) * scales[j] * 3.0);
        let dw = Matrix::randn(16, 12, 0.3, &mut rng);
        let mut h = syrk_t(&x);
        h.add_diag(damping_lambda(&h, 0.01));

        let r = 4;
        let cloq = cloq_lowrank(&h, &dw, &CloqConfig { rank: r, ..Default::default() });
        let e_cloq = calibrated_error2(&h, &cloq.ab_t().sub(&dw));

        // Plain SVD of ΔW (ignores X).
        let plain = crate::linalg::best_rank_r(&dw, r);
        let e_plain = calibrated_error2(&h, &plain.sub(&dw));
        assert!(e_cloq <= e_plain + 1e-9, "cloq {e_cloq} vs plain-svd {e_plain}");

        // Random rank-r candidates.
        for _ in 0..30 {
            let p = Matrix::randn(16, r, 0.5, &mut rng);
            let q = Matrix::randn(12, r, 0.5, &mut rng);
            let e = calibrated_error2(&h, &matmul_nt(&p, &q).sub(&dw));
            assert!(e_cloq <= e + 1e-9);
        }

        // Perturbations of the optimum (first-order optimality).
        for _ in 0..30 {
            let da = Matrix::randn(16, r, 0.01, &mut rng);
            let db = Matrix::randn(12, r, 0.01, &mut rng);
            let e = calibrated_error2(&h, &matmul_nt(&cloq.a.add(&da), &cloq.b.add(&db)).sub(&dw));
            assert!(e_cloq <= e + 1e-9);
        }
    }

    #[test]
    fn all_splits_same_product() {
        let (_, dw, h) = setup(10, 14, 60, 94);
        let mk = |split| {
            let cfg = CloqConfig { rank: 5, split, rcond: 1e-12, randomized: false };
            cloq_lowrank(&h, &dw, &cfg).ab_t()
        };
        let a = mk(FactorSplit::AllInA);
        let b = mk(FactorSplit::Sqrt);
        let c = mk(FactorSplit::AllInB);
        assert!(a.max_diff(&b) < 1e-8);
        assert!(a.max_diff(&c) < 1e-8);
    }

    #[test]
    fn split_energy_distribution() {
        let (_, dw, h) = setup(10, 14, 60, 95);
        let cfg_a =
            CloqConfig { rank: 5, split: FactorSplit::AllInA, rcond: 1e-12, randomized: false };
        let all_a = cloq_lowrank(&h, &dw, &cfg_a);
        // With AllInA, B has orthonormal columns (BᵀB = I).
        let btb = matmul(&all_a.b.transpose(), &all_a.b);
        assert!(btb.max_diff(&Matrix::eye(5)) < 1e-8);
        let cfg_b =
            CloqConfig { rank: 5, split: FactorSplit::AllInB, rcond: 1e-12, randomized: false };
        let all_b = cloq_lowrank(&h, &dw, &cfg_b);
        // With AllInB, ‖B‖ carries the spectrum: column norms = σ_i.
        let sq = svd(&matmul(&gram_root(&h, 1e-12).r, &dw));
        for i in 0..5 {
            let bn: f64 = all_b.b.col(i).iter().map(|x| x * x).sum::<f64>().sqrt();
            assert!((bn - sq.s[i]).abs() < 1e-6 * sq.s[i].max(1e-12), "col {i}");
        }
    }

    #[test]
    fn rank_deficient_h_uses_pinv_branch() {
        // 4 calibration samples, 16 features → H rank ≤ 4 (undamped).
        let mut rng = Rng::new(96);
        let x = Matrix::randn(4, 16, 1.0, &mut rng);
        let h = syrk_t(&x); // deliberately NOT damped
        let dw = Matrix::randn(16, 8, 0.3, &mut rng);
        let init =
            cloq_lowrank(&h, &dw, &CloqConfig { rank: 4, rcond: 1e-10, ..Default::default() });
        assert!(init.a.max_abs().is_finite());
        // Calibrated objective still ≤ plain-SVD candidate's.
        let e_cloq = calibrated_error2(&h, &init.ab_t().sub(&dw));
        let plain = crate::linalg::best_rank_r(&dw, 4);
        let e_plain = calibrated_error2(&h, &plain.sub(&dw));
        assert!(e_cloq <= e_plain + 1e-9);
    }

    #[test]
    fn rank_zero_gives_zero_adapter() {
        let (_, dw, h) = setup(8, 6, 32, 97);
        let init = cloq_lowrank(&h, &dw, &CloqConfig { rank: 0, ..Default::default() });
        assert_eq!(init.a.cols, 0);
        assert_eq!(init.b.cols, 0);
        let obj_direct = calibrated_error2(&h, &dw.scale(-1.0));
        assert!((init.objective - obj_direct).abs() < 1e-7 * obj_direct);
        let _ = fro2(&dw);
    }

    #[test]
    fn randomized_path_matches_exact() {
        // The §Perf fast path must agree with the exact SVD on realistic
        // (fast-decaying) residuals.
        let (_, _, h) = setup(24, 16, 96, 99);
        let mut rng = Rng::new(995);
        // Build a residual with decaying spectrum.
        let u = crate::linalg::qr::random_orthonormal(24, 12, &mut rng);
        let v = crate::linalg::qr::random_orthonormal(16, 12, &mut rng);
        let s: Vec<f64> = (0..12).map(|i| (0.6f64).powi(i as i32)).collect();
        let dw = crate::linalg::matmul_nt(&crate::linalg::svd::scale_cols(&u, &s), &v);
        for r in [2usize, 4] {
            let exact = cloq_lowrank(&h, &dw, &CloqConfig { rank: r, ..Default::default() });
            let fast = cloq_lowrank(
                &h,
                &dw,
                &CloqConfig { rank: r, randomized: true, ..Default::default() },
            );
            let e_exact = calibrated_error2(&h, &exact.ab_t().sub(&dw));
            let e_fast = calibrated_error2(&h, &fast.ab_t().sub(&dw));
            assert!(
                e_fast <= e_exact * 1.02 + 1e-9,
                "r={r}: randomized {e_fast} vs exact {e_exact}"
            );
        }
    }

    #[test]
    fn objective_monotone_in_rank() {
        let (_, dw, h) = setup(18, 12, 72, 98);
        let mut last = f64::INFINITY;
        for r in 0..=12 {
            let init = cloq_lowrank(&h, &dw, &CloqConfig { rank: r, ..Default::default() });
            assert!(init.objective <= last + 1e-9, "r={r}");
            last = init.objective;
        }
        assert!(last < 1e-10, "full rank must be exact");
    }
}

//! Trainers: full pretraining (builds the "pre-trained" base the paper
//! starts from) and LoRA fine-tuning (the paper's training stage). The Rust
//! side owns the loop, batching, LR schedule and metrics; each step is one
//! PJRT execution of the AOT train-step graph.

use crate::data::batcher::{task_batch, Batch, LmStream};
use crate::data::corpus::{corpus_text, Split};
use crate::data::Example;
use crate::model::{base_specs, lora_specs, ParamStore};
use crate::runtime::{Runtime, Tensor};
use crate::util::prng::Rng;

#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub weight_decay: f64,
    /// Warmup fraction then cosine decay (paper Table 11: 3–10% warmup).
    pub warmup_frac: f64,
    pub log_every: usize,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self { steps: 100, lr: 1e-3, weight_decay: 0.1, warmup_frac: 0.05, log_every: 25 }
    }
}

/// Warmup + cosine LR schedule (the paper's WikiText/GSM8K setting).
pub fn lr_at(cfg: &TrainConfig, step: usize) -> f64 {
    let warmup = (cfg.warmup_frac * cfg.steps as f64).max(1.0);
    if (step as f64) < warmup {
        cfg.lr * (step as f64 + 1.0) / warmup
    } else {
        let t = (step as f64 - warmup) / (cfg.steps as f64 - warmup).max(1.0);
        cfg.lr * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

/// Which data the trainer feeds.
pub enum DataSource<'a> {
    /// Language modelling on the synthetic corpus (given seed).
    Corpus(u64),
    /// Supervised task examples (prompt-masked loss).
    Tasks(&'a [Example]),
}

pub struct TrainOutcome {
    pub losses: Vec<f32>,
    pub final_loss: f32,
}

/// Pretrain all base parameters from `base` (updated in place semantics:
/// returns the new store). This is the e2e "train a small transformer and
/// log the loss curve" driver.
pub fn pretrain(
    rt: &mut Runtime,
    base: &ParamStore,
    cfg: &TrainConfig,
    corpus_seed: u64,
) -> anyhow::Result<(ParamStore, TrainOutcome)> {
    let mcfg = rt.manifest.config.clone();
    let bspecs = base_specs(&rt.manifest)?;
    let nb = bspecs.len();

    let bytes = cfg.steps * mcfg.batch * mcfg.seq + 65536;
    // Pretraining mixture: prose + task-formatted lines (see data::pretrain_mixture).
    let text = crate::data::pretrain_mixture(corpus_seed, bytes.min(4_000_000));
    let mut stream = LmStream::new(&text, mcfg.batch, mcfg.seq);

    let mut params = base.in_order();
    let mut m: Vec<Tensor> = params.iter().map(|t| Tensor::zeros_f32(t.shape.clone())).collect();
    let mut v = m.clone();
    let mut losses = Vec::with_capacity(cfg.steps);

    for step in 0..cfg.steps {
        let batch = stream.next_batch().unwrap();
        let mut inputs = Vec::with_capacity(3 * nb + 5);
        inputs.extend(params.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(batch.tokens);
        inputs.push(batch.mask);
        inputs.push(Tensor::scalar_f32(lr_at(cfg, step) as f32));
        inputs.push(Tensor::scalar_f32(cfg.weight_decay as f32));
        inputs.push(Tensor::scalar_f32((step + 1) as f32));
        let out = rt.run("pretrain_step", &inputs)?;
        let loss = out.last().unwrap().scalar();
        anyhow::ensure!(loss.is_finite(), "pretraining diverged at step {step}");
        losses.push(loss);
        params = out[..nb].to_vec();
        m = out[nb..2 * nb].to_vec();
        v = out[2 * nb..3 * nb].to_vec();
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            crate::info!("pretrain step {step:4}  loss {loss:.4}  lr {:.2e}", lr_at(cfg, step));
        }
    }

    let mut store = ParamStore::new();
    for (spec, t) in bspecs.iter().zip(params) {
        store.insert(&spec.name, t);
    }
    let final_loss = *losses.last().unwrap_or(&f32::NAN);
    Ok((store, TrainOutcome { losses, final_loss }))
}

/// LoRA fine-tuning: base frozen, adapters trained.
pub fn finetune_lora(
    rt: &mut Runtime,
    base_q: &ParamStore,
    lora: &ParamStore,
    data: DataSource<'_>,
    cfg: &TrainConfig,
    seed: u64,
) -> anyhow::Result<(ParamStore, TrainOutcome)> {
    let mcfg = rt.manifest.config.clone();
    let lspecs = lora_specs(&rt.manifest)?;
    let nl = lspecs.len();
    let base_inputs = base_q.in_order();

    let mut lora_vals = lora.in_order();
    let mut m: Vec<Tensor> =
        lora_vals.iter().map(|t| Tensor::zeros_f32(t.shape.clone())).collect();
    let mut v = m.clone();
    let mut rng = Rng::new(seed);

    let mut corpus_stream = match data {
        DataSource::Corpus(s) => {
            let bytes = cfg.steps * mcfg.batch * mcfg.seq + 65536;
            Some(LmStream::new(
                &corpus_text(s, Split::Train, bytes.min(4_000_000)),
                mcfg.batch,
                mcfg.seq,
            ))
        }
        DataSource::Tasks(_) => None,
    };

    let mut losses = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let batch: Batch = match &data {
            DataSource::Corpus(_) => corpus_stream.as_mut().unwrap().next_batch().unwrap(),
            DataSource::Tasks(examples) => task_batch(examples, mcfg.batch, mcfg.seq, &mut rng),
        };
        let mut inputs = Vec::with_capacity(base_inputs.len() + 3 * nl + 5);
        inputs.extend(base_inputs.iter().cloned());
        inputs.extend(lora_vals.iter().cloned());
        inputs.extend(m.iter().cloned());
        inputs.extend(v.iter().cloned());
        inputs.push(batch.tokens);
        inputs.push(batch.mask);
        inputs.push(Tensor::scalar_f32(lr_at(cfg, step) as f32));
        inputs.push(Tensor::scalar_f32(cfg.weight_decay as f32));
        inputs.push(Tensor::scalar_f32((step + 1) as f32));
        let out = rt.run("lora_step", &inputs)?;
        let loss = out.last().unwrap().scalar();
        anyhow::ensure!(loss.is_finite(), "fine-tuning diverged at step {step}");
        losses.push(loss);
        lora_vals = out[..nl].to_vec();
        m = out[nl..2 * nl].to_vec();
        v = out[2 * nl..3 * nl].to_vec();
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            crate::info!("finetune step {step:4}  loss {loss:.4}  lr {:.2e}", lr_at(cfg, step));
        }
    }

    let mut store = ParamStore::new();
    for (spec, t) in lspecs.iter().zip(lora_vals) {
        store.insert(&spec.name, t);
    }
    let final_loss = *losses.last().unwrap_or(&f32::NAN);
    Ok((store, TrainOutcome { losses, final_loss }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let cfg = TrainConfig { steps: 100, lr: 1e-3, warmup_frac: 0.1, ..Default::default() };
        // Warmup is increasing.
        assert!(lr_at(&cfg, 0) < lr_at(&cfg, 5));
        assert!(lr_at(&cfg, 5) < lr_at(&cfg, 9));
        // Peak near end of warmup.
        assert!((lr_at(&cfg, 10) - 1e-3).abs() < 1e-4);
        // Decays after.
        assert!(lr_at(&cfg, 50) < lr_at(&cfg, 12));
        assert!(lr_at(&cfg, 99) < lr_at(&cfg, 50));
        assert!(lr_at(&cfg, 99) >= 0.0);
    }
}

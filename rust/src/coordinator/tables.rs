//! Table/figure regeneration harnesses — one function per table and figure
//! of the paper's evaluation section (DESIGN.md §5 maps each to its
//! modules). Absolute numbers come from the tiny substitute models; the
//! *shape* (method ordering, low-bit behaviour, ablation trends) is the
//! reproduction target and is what EXPERIMENTS.md compares.

use std::path::{Path, PathBuf};

use crate::lowrank::{FactorSplit, Method};
use crate::runtime::Runtime;
use crate::util::timer::timeit;

use super::pipeline::{
    ensure_grams, ensure_pretrained, init_model, run_one, FinetuneTask, PipelineOpts, RunSpec,
};
use super::report::{fmt_f, fmt_pct, Table};

pub struct TableOpts {
    pub fast: bool,
    pub reports_dir: PathBuf,
    pub steps: usize,
    pub seed: u64,
}

impl Default for TableOpts {
    fn default() -> Self {
        Self { fast: false, reports_dir: PathBuf::from("reports"), steps: 60, seed: 7 }
    }
}

fn popts(config: &str, t: &TableOpts) -> PipelineOpts {
    let o = PipelineOpts::new(config);
    if t.fast {
        o.fast()
    } else {
        o
    }
}

/// Shared context per model config: runtime + pretrained base + grams.
struct Ctx {
    rt: Runtime,
    base: crate::model::ParamStore,
    grams: super::calibrate::GramSet,
    opts: PipelineOpts,
}

fn ctx(config: &str, t: &TableOpts) -> anyhow::Result<Ctx> {
    let opts = popts(config, t);
    anyhow::ensure!(
        opts.artifacts.join("manifest.json").exists(),
        "artifacts/{config} missing — run `make artifacts`"
    );
    let mut rt = Runtime::load(&opts.artifacts)?;
    let (base, _) = ensure_pretrained(&mut rt, &opts)?;
    let grams = ensure_grams(&mut rt, &base, &opts, opts.calib_samples)?;
    Ok(Ctx { rt, base, grams, opts })
}

fn spec(method: Method, bits: u32, task: FinetuneTask, t: &TableOpts) -> RunSpec {
    let mut s = RunSpec::new(method, bits, task);
    s.steps = if t.fast { t.steps.min(40) } else { t.steps };
    s.seed = t.seed;
    s
}

/// The method×bits grid of Tables 1/3/5.
fn method_grid(full: bool) -> Vec<(Method, u32)> {
    let mut grid = vec![(Method::Lora16, 16)];
    let bits: &[u32] = if full { &[4, 3, 2] } else { &[4, 2] };
    for &b in bits {
        grid.push((Method::QLora, b));
        grid.push((Method::GptqLora, b));
        grid.push((Method::LoftQ, b));
        grid.push((Method::CLoQ, b));
    }
    grid
}

// ------------------------------------------------------------------
// Table 1/2: WikiText ppl + GSM8K accuracy
// ------------------------------------------------------------------

fn wiki_gsm8k_table(
    configs: &[&str],
    id: &str,
    title: &str,
    grid: Vec<(Method, u32)>,
    t: &TableOpts,
) -> anyhow::Result<()> {
    let mut headers = vec!["Method".to_string(), "Bit".to_string()];
    for c in configs {
        headers.push(format!("{c} Wiki(ppl)"));
        headers.push(format!("{c} GSM8K(acc%)"));
    }
    let mut table = Table::new(title, &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>());

    // Gather per config to reuse runtime/base/grams.
    let mut cells: Vec<Vec<String>> =
        grid.iter().map(|(m, b)| vec![m.name().to_string(), b.to_string()]).collect();
    for config in configs {
        let mut c = ctx(config, t)?;
        for (i, (method, bits)) in grid.iter().enumerate() {
            let wspec = spec(*method, *bits, FinetuneTask::Wiki, t);
            let r_wiki = run_one(&mut c.rt, &c.base, &c.grams, &wspec, &c.opts)?;
            let gspec = spec(*method, *bits, FinetuneTask::Gsm8k, t);
            let r_gsm = run_one(&mut c.rt, &c.base, &c.grams, &gspec, &c.opts)?;
            cells[i].push(fmt_f(r_wiki.ppl.unwrap_or(f64::NAN), 2));
            cells[i].push(fmt_pct(r_gsm.accuracies[0].1));
        }
    }
    for row in cells {
        table.row(row);
    }
    table.emit(&t.reports_dir, id)
}

pub fn table1(t: &TableOpts) -> anyhow::Result<()> {
    wiki_gsm8k_table(
        &["tiny-s", "tiny-m"],
        "table1",
        "Table 1: WikiText ppl + GSM8K acc (tiny-s ~ Llama2-7B, tiny-m ~ Llama2-13B)",
        method_grid(!t.fast),
        t,
    )
}

pub fn table2(t: &TableOpts) -> anyhow::Result<()> {
    // Paper Table 2: only 16-bit LoRA + 2-bit methods on the other archs.
    let grid = vec![
        (Method::Lora16, 16),
        (Method::GptqLora, 2),
        (Method::LoftQ, 2),
        (Method::CLoQ, 2),
    ];
    wiki_gsm8k_table(
        &["tiny-wide", "tiny-deep"],
        "table2",
        "Table 2: WikiText ppl + GSM8K acc (tiny-wide ~ Llama3-8B, tiny-deep ~ Mistral-7B)",
        grid,
        t,
    )
}

// ------------------------------------------------------------------
// Table 3/4: multi-task arithmetic reasoning
// ------------------------------------------------------------------

fn arith_headers(config: &str) -> Vec<String> {
    vec![
        "Method".into(),
        "Bit".into(),
        format!("{config} GSM8K"),
        format!("{config} SVAMP"),
        format!("{config} MAWPS"),
        format!("{config} AQuA"),
        format!("{config} Avg"),
    ]
}

fn arith_cells(r: &super::pipeline::RunResult) -> Vec<String> {
    // accuracies order = ARITH_TASKS = [gsm, svamp, mawps, aqua]
    let mut cells: Vec<String> = r.accuracies.iter().map(|(_, a)| fmt_pct(*a)).collect();
    cells.push(fmt_pct(r.avg_accuracy()));
    cells
}

pub fn table3(t: &TableOpts) -> anyhow::Result<()> {
    let grid = method_grid(!t.fast);
    let configs = ["tiny-s", "tiny-m"];
    let mut headers = vec!["Method".to_string(), "Bit".to_string()];
    for c in &configs {
        for h in &arith_headers(c)[2..] {
            headers.push(h.clone());
        }
    }
    let mut table = Table::new(
        "Table 3: four arithmetic reasoning tasks (fine-tuned on s-Math10K)",
        &headers.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
    );
    let mut cells: Vec<Vec<String>> =
        grid.iter().map(|(m, b)| vec![m.name().to_string(), b.to_string()]).collect();
    for config in &configs {
        let mut c = ctx(config, t)?;
        for (i, (method, bits)) in grid.iter().enumerate() {
            let mspec = spec(*method, *bits, FinetuneTask::Math10k, t);
            let r = run_one(&mut c.rt, &c.base, &c.grams, &mspec, &c.opts)?;
            cells[i].extend(arith_cells(&r));
        }
    }
    for row in cells {
        table.row(row);
    }
    table.emit(&t.reports_dir, "table3")
}

pub fn table4(t: &TableOpts) -> anyhow::Result<()> {
    let config = "tiny-wide";
    let mut c = ctx(config, t)?;
    let mut table = Table::new(
        "Table 4: arithmetic reasoning on tiny-wide (~Llama3-8B); CLoQ over 5 seeds (mean±std)",
        &["Method", "Bit", "GSM8K", "SVAMP", "MAWPS", "AQuA", "Avg"],
    );
    for (method, bits) in [(Method::Lora16, 16u32), (Method::LoftQ, 2), (Method::GptqLora, 2)] {
        let mspec = spec(method, bits, FinetuneTask::Math10k, t);
        let r = run_one(&mut c.rt, &c.base, &c.grams, &mspec, &c.opts)?;
        let mut row = vec![method.name().to_string(), bits.to_string()];
        row.extend(arith_cells(&r));
        table.row(row);
    }
    // CLoQ over seeds.
    let n_seeds = if t.fast { 2 } else { 5 };
    let mut per_task: Vec<Vec<f64>> = vec![Vec::new(); 4];
    let mut avgs = Vec::new();
    for s in 0..n_seeds {
        let mut sp = spec(Method::CLoQ, 2, FinetuneTask::Math10k, t);
        sp.seed = t.seed + s as u64;
        let r = run_one(&mut c.rt, &c.base, &c.grams, &sp, &c.opts)?;
        for (k, (_, a)) in r.accuracies.iter().enumerate() {
            per_task[k].push(*a);
        }
        avgs.push(r.avg_accuracy());
    }
    let mean_std = |xs: &[f64]| {
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64;
        format!("{:.1}±{:.2}", 100.0 * m, 100.0 * v.sqrt())
    };
    let mut row = vec![format!("CLoQ (n={n_seeds})"), "2".to_string()];
    for k in 0..4 {
        row.push(mean_std(&per_task[k]));
    }
    row.push(mean_std(&avgs));
    table.row(row);
    table.emit(&t.reports_dir, "table4")
}

// ------------------------------------------------------------------
// Table 5: commonsense reasoning (8 tasks)
// ------------------------------------------------------------------

pub fn table5(t: &TableOpts) -> anyhow::Result<()> {
    let configs = if t.fast { vec!["tiny-s"] } else { vec!["tiny-s", "tiny-m"] };
    let mut table = Table::new(
        "Table 5: eight commonsense reasoning tasks (fine-tuned on s-CS170K)",
        &[
            "Model", "Method", "Bit", "Parity", "Compare", "Majority", "Succ", "Member",
            "Copy", "Reverse", "Bool", "Avg",
        ],
    );
    let grid = if t.fast {
        vec![(Method::Lora16, 16), (Method::QLora, 4), (Method::LoftQ, 2), (Method::CLoQ, 2)]
    } else {
        method_grid(true)
    };
    for config in &configs {
        let mut c = ctx(config, t)?;
        for (method, bits) in &grid {
            let cspec = spec(*method, *bits, FinetuneTask::Commonsense, t);
            let r = run_one(&mut c.rt, &c.base, &c.grams, &cspec, &c.opts)?;
            let mut row = vec![config.to_string(), method.name().to_string(), bits.to_string()];
            for (_, a) in &r.accuracies {
                row.push(fmt_pct(*a));
            }
            row.push(fmt_pct(r.avg_accuracy()));
            table.row(row);
        }
    }
    table.emit(&t.reports_dir, "table5")
}

// ------------------------------------------------------------------
// Table 6: mixed-dataset fine-tuning
// ------------------------------------------------------------------

pub fn table6(t: &TableOpts) -> anyhow::Result<()> {
    let mut c = ctx("tiny-s", t)?;
    let mut table = Table::new(
        "Table 6: arithmetic accuracy after fine-tuning on the MIXED dataset (math + commonsense)",
        &["Method", "Bit", "GSM8K", "SVAMP", "MAWPS", "AQuA", "Avg", "Avg(pure-math)"],
    );
    for bits in [4u32, 2] {
        for method in [Method::LoftQ, Method::CLoQ] {
            let xspec = spec(method, bits, FinetuneTask::Mixed, t);
            let r_mixed = run_one(&mut c.rt, &c.base, &c.grams, &xspec, &c.opts)?;
            let pspec = spec(method, bits, FinetuneTask::Math10k, t);
            let r_pure = run_one(&mut c.rt, &c.base, &c.grams, &pspec, &c.opts)?;
            let mut row = vec![method.name().to_string(), bits.to_string()];
            row.extend(arith_cells(&r_mixed));
            row.push(fmt_pct(r_pure.avg_accuracy()));
            table.row(row);
        }
    }
    table.emit(&t.reports_dir, "table6")
}

// ------------------------------------------------------------------
// Table 7: (A, B) factor-split ablation
// ------------------------------------------------------------------

pub fn table7(t: &TableOpts) -> anyhow::Result<()> {
    let mut c = ctx("tiny-s", t)?;
    let mut table = Table::new(
        "Table 7: fine-tuning with different (A,B) combinations at 2-bit",
        &["Split", "Bit", "Wiki(ppl)", "GSM8K(acc%)"],
    );
    for (method, label) in [
        (Method::CLoQAllInB, FactorSplit::AllInB.name()),
        (Method::CLoQSqrtSplit, FactorSplit::Sqrt.name()),
        (Method::CLoQ, FactorSplit::AllInA.name()),
    ] {
        let wspec = spec(method, 2, FinetuneTask::Wiki, t);
        let r_wiki = run_one(&mut c.rt, &c.base, &c.grams, &wspec, &c.opts)?;
        let gspec = spec(method, 2, FinetuneTask::Gsm8k, t);
        let r_gsm = run_one(&mut c.rt, &c.base, &c.grams, &gspec, &c.opts)?;
        table.row(vec![
            label.to_string(),
            "2".to_string(),
            fmt_f(r_wiki.ppl.unwrap_or(f64::NAN), 2),
            fmt_pct(r_gsm.accuracies[0].1),
        ]);
    }
    table.emit(&t.reports_dir, "table7")
}

// ------------------------------------------------------------------
// Table 8: calibration-size ablation
// ------------------------------------------------------------------

pub fn table8(t: &TableOpts) -> anyhow::Result<()> {
    let opts = popts("tiny-s", t);
    let mut rt = Runtime::load(&opts.artifacts)?;
    let (base, _) = ensure_pretrained(&mut rt, &opts)?;
    let mut table = Table::new(
        "Table 8: CLoQ accuracy vs calibration dataset size",
        &["CalibSize", "Bit", "Wiki(ppl)", "GSM8K(acc%)", "Arith Avg(acc%)"],
    );
    let sizes: &[usize] = if t.fast { &[32, 128] } else { &[32, 64, 128, 256] };
    for bits in [4u32, 2] {
        for &n in sizes {
            let grams = ensure_grams(&mut rt, &base, &opts, n)?;
            let wspec = spec(Method::CLoQ, bits, FinetuneTask::Wiki, t);
            let r_wiki = run_one(&mut rt, &base, &grams, &wspec, &opts)?;
            let gspec = spec(Method::CLoQ, bits, FinetuneTask::Gsm8k, t);
            let r_gsm = run_one(&mut rt, &base, &grams, &gspec, &opts)?;
            let mspec = spec(Method::CLoQ, bits, FinetuneTask::Math10k, t);
            let r_math = run_one(&mut rt, &base, &grams, &mspec, &opts)?;
            table.row(vec![
                n.to_string(),
                bits.to_string(),
                fmt_f(r_wiki.ppl.unwrap_or(f64::NAN), 2),
                fmt_pct(r_gsm.accuracies[0].1),
                fmt_pct(r_math.avg_accuracy()),
            ]);
        }
    }
    table.emit(&t.reports_dir, "table8")
}

// ------------------------------------------------------------------
// Table 9: sequence-length ablation (needs the seq-variant artifacts)
// ------------------------------------------------------------------

pub fn table9(t: &TableOpts) -> anyhow::Result<()> {
    let mut table = Table::new(
        "Table 9: 2-bit CLoQ arithmetic accuracy vs fine-tuning sequence length",
        &["SeqLen", "GSM8K", "SVAMP", "MAWPS", "AQuA", "Avg"],
    );
    let configs: &[(&str, usize)] = if t.fast {
        &[("tiny-s-seq32", 32), ("tiny-s", 64)]
    } else {
        &[("tiny-s-seq16", 16), ("tiny-s-seq32", 32), ("tiny-s", 64), ("tiny-s-seq128", 128)]
    };
    for (config, seq) in configs {
        let mut c = ctx(config, t)?;
        let mspec = spec(Method::CLoQ, 2, FinetuneTask::Math10k, t);
        let r = run_one(&mut c.rt, &c.base, &c.grams, &mspec, &c.opts)?;
        let mut row = vec![seq.to_string()];
        row.extend(arith_cells(&r));
        table.row(row);
    }
    table.emit(&t.reports_dir, "table9")
}

// ------------------------------------------------------------------
// Table 10: initialization duration + peak memory
// ------------------------------------------------------------------

pub fn table10(t: &TableOpts) -> anyhow::Result<()> {
    let mut table = Table::new(
        "Table 10: initialization duration and peak memory",
        &["Size", "Method", "Duration(s)", "PeakRSS(MiB)", "bits/weight@2"],
    );
    let configs = if t.fast { vec!["tiny-s"] } else { vec!["tiny-s", "tiny-m"] };
    for config in &configs {
        let c = ctx(config, t)?;
        for method in [Method::QLora, Method::GptqLora, Method::LoftQ, Method::CLoQ] {
            let sp = spec(method, 2, FinetuneTask::Wiki, t);
            // Average over 3 repetitions for a stable duration.
            let reps = 3;
            let (mut secs, mut bpw) = (0.0, 0.0);
            for _ in 0..reps {
                let (init, s) = init_model(&c.rt, &c.base, &c.grams, &sp)?;
                secs += s;
                bpw = init.bits_per_weight;
            }
            table.row(vec![
                config.to_string(),
                method.name().to_string(),
                fmt_f(secs / reps as f64, 3),
                fmt_f(crate::util::timer::peak_rss_mib(), 0),
                fmt_f(bpw, 2),
            ]);
        }
    }
    table.emit(&t.reports_dir, "table10")
}

// ------------------------------------------------------------------
// Fig 1: summary bars (reads table1/table3 reports)
// ------------------------------------------------------------------

pub fn fig1(t: &TableOpts) -> anyhow::Result<()> {
    let t1 = Table::load(&t.reports_dir.join("table1.json"))
        .map_err(|e| anyhow::anyhow!("fig 1 needs table1 first: {e}"))?;
    let t3 = Table::load(&t.reports_dir.join("table3.json"))
        .map_err(|e| anyhow::anyhow!("fig 1 needs table3 first: {e}"))?;
    let mut fig = Table::new(
        "Fig 1: fine-tuning summary (series = method@bit; from table1/table3)",
        &["Series", "Wiki ppl (tiny-s)", "GSM8K acc (tiny-s)", "Arith avg (tiny-s)"],
    );
    for (r1, r3) in t1.rows.iter().zip(&t3.rows) {
        let series = format!("{}@{}", r1[0], r1[1]);
        fig.row(vec![series, r1[2].clone(), r1[3].clone(), r3[6].clone()]);
    }
    fig.emit(&t.reports_dir, "fig1")
}

// ------------------------------------------------------------------
// Fig 2: layer discrepancy ‖X(Q+ABᵀ−W)‖ vs rank, CLoQ vs LoftQ @ INT2
// ------------------------------------------------------------------

pub fn fig2(t: &TableOpts) -> anyhow::Result<()> {
    use crate::linalg::matmul;
    use crate::linalg::norms::{discrepancy_from_re};
    use crate::lowrank::{
        cloq_lowrank, damping_lambda, gram_root, loftq, CloqConfig, LoftqConfig, LoftqQuantizer,
    };
    use crate::quant::magr::magr;
    use crate::quant::optq::{optq, OptqConfig};

    let opts = popts("tiny-s", t);
    let mut rt = Runtime::load(&opts.artifacts)?;
    let (base, _) = ensure_pretrained(&mut rt, &opts)?;
    let grams = ensure_grams(&mut rt, &base, &opts, opts.calib_samples)?;

    // A mid-network layer, like the paper's randomly-selected Llama2 layer.
    let layer = "l1.w_up";
    let w = base.get(layer).to_matrix();
    let h = grams
        .get(layer)
        .ok_or_else(|| anyhow::anyhow!("no gram for {layer}"))?
        .clone();
    let mut hd = h.clone();
    hd.add_diag(damping_lambda(&h, 0.01));
    let root = gram_root(&hd, 1e-12);

    let bits = 2;
    let gs = rt.manifest.config.group_size;
    let max_rank = rt.manifest.config.rank;

    // CLoQ base: MagR + OPTQ (as in the method).
    let w_magr = magr(&w, &hd, &Default::default());
    let q_cloq = optq(&w_magr, &h, &OptqConfig { bits, group_size: gs, ..Default::default() })
        .dequantize();

    let mut fig = Table::new(
        &format!("Fig 2: ||X(Q + AB' - W)|| vs rank at INT2 (layer {layer})"),
        &["Rank", "CLoQ spec", "LoftQ spec", "CLoQ fro", "LoftQ fro"],
    );
    let ranks: Vec<usize> = [1usize, 2, 4, 8, 16, 32, 64]
        .into_iter()
        .filter(|&r| r <= max_rank)
        .collect();
    for &r in &ranks {
        // CLoQ: closed form on ΔW = W − Q.
        let dw = w.sub(&q_cloq);
        let init = cloq_lowrank(&hd, &dw, &CloqConfig { rank: r, ..Default::default() });
        let e_cloq = q_cloq.add(&init.ab_t()).sub(&w);
        let d_cloq = discrepancy_from_re(&matmul(&root.r, &e_cloq));

        // LoftQ: data-free AltMin (INT quantizer, 5 iters).
        let lcfg =
            LoftqConfig { bits, group_size: gs, rank: r, iters: 5, quantizer: LoftqQuantizer::Int };
        let lq = loftq(&w, &lcfg);
        let e_loftq = lq.q_deq.add(&lq.ab_t()).sub(&w);
        let d_loftq = discrepancy_from_re(&matmul(&root.r, &e_loftq));

        fig.row(vec![
            r.to_string(),
            fmt_f(d_cloq.spectral, 4),
            fmt_f(d_loftq.spectral, 4),
            fmt_f(d_cloq.frobenius, 4),
            fmt_f(d_loftq.frobenius, 4),
        ]);
    }
    fig.emit(&t.reports_dir, "fig2")
}

/// Dispatch by id.
pub fn run_table(id: &str, t: &TableOpts) -> anyhow::Result<()> {
    let (out, secs) = timeit(|| match id {
        "1" => table1(t),
        "2" => table2(t),
        "3" => table3(t),
        "4" => table4(t),
        "5" => table5(t),
        "6" => table6(t),
        "7" => table7(t),
        "8" => table8(t),
        "9" => table9(t),
        "10" => table10(t),
        other => Err(anyhow::anyhow!("unknown table '{other}' (1-10)")),
    });
    crate::info!("table {id} completed in {secs:.1}s");
    out
}

pub fn run_fig(id: &str, t: &TableOpts) -> anyhow::Result<()> {
    match id {
        "1" => fig1(t),
        "2" => fig2(t),
        other => Err(anyhow::anyhow!("unknown figure '{other}' (1-2)")),
    }
}

#[allow(dead_code)]
fn _unused(_: &Path) {}

//! L3 coordinator: the pipeline that reproduces the paper's workflow —
//! pretrain a base LM, calibrate on a small dataset, quantize layer-wise
//! (MagR+OPTQ), initialize LoRA adapters (CLoQ closed form or a baseline),
//! fine-tune the adapters, and evaluate — plus the reporting layer that
//! regenerates every table/figure.

pub mod calibrate;
pub mod evaluator;
pub mod pipeline;
pub mod quantize;
pub mod report;
pub mod tables;
pub mod trainer;

pub use calibrate::{calibrate, GramSet};
pub use evaluator::{accuracy_choice, accuracy_greedy, perplexity, task_accuracy};
pub use pipeline::{
    ensure_grams, ensure_pretrained, init_model, run_one, FinetuneTask, PipelineOpts, RunResult,
    RunSpec,
};
pub use quantize::{quantize_init, ModelInit};
pub use report::Table;
pub use trainer::{finetune_lora, pretrain, DataSource, TrainConfig};

//! Paper-style table rendering + JSON report persistence.
//!
//! Every `table <n>` / `fig <n>` harness produces a [`Table`]; it is
//! printed as aligned text (the same rows the paper reports) and saved
//! under `reports/` as JSON for downstream tooling / EXPERIMENTS.md.

use std::path::Path;

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("## {}\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::from_pairs(vec![
            ("title", Json::from(self.title.clone())),
            ("headers", Json::from(self.headers.clone())),
            (
                "rows",
                Json::Arr(self.rows.iter().map(|r| Json::from(r.clone())).collect()),
            ),
        ])
    }

    /// Print to stdout and persist under `reports/<id>.json`.
    pub fn emit(&self, reports_dir: &Path, id: &str) -> anyhow::Result<()> {
        println!("{}", self.render());
        std::fs::create_dir_all(reports_dir)?;
        let path = reports_dir.join(format!("{id}.json"));
        std::fs::write(&path, self.to_json().to_string_pretty())?;
        crate::info!("report saved to {}", path.display());
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Table> {
        let j = crate::util::json::parse_file(path)?;
        let headers = j
            .req_arr("headers")?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();
        let rows = j
            .req_arr("rows")?
            .iter()
            .map(|r| {
                r.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(|v| v.as_str().unwrap_or("").to_string())
                    .collect()
            })
            .collect();
        Ok(Table { title: j.req_str("title")?.to_string(), headers, rows })
    }
}

/// Format helpers used across harnesses.
pub fn fmt_f(x: f64, digits: usize) -> String {
    format!("{x:.digits$}")
}

pub fn fmt_pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_roundtrip() {
        let mut t = Table::new("Demo", &["Method", "Bit", "Acc"]);
        t.row(vec!["CLoQ".into(), "2".into(), "33.7".into()]);
        t.row(vec!["LoftQ".into(), "2".into(), "20.9".into()]);
        let rendered = t.render();
        assert!(rendered.contains("CLoQ"));
        assert!(rendered.contains("Method"));

        let dir = std::env::temp_dir().join(format!("cloq_rep_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        t.emit(&dir, "demo").unwrap();
        let back = Table::load(&dir.join("demo.json")).unwrap();
        assert_eq!(back.headers, t.headers);
        assert_eq!(back.rows, t.rows);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}

//! Evaluation: perplexity (WikiText protocol), exact-match accuracy via
//! greedy decoding (GSM8K protocol), and option log-likelihood scoring
//! (AQuA / commonsense protocol).

use crate::data::batcher::{pad_rows, prompt_with_candidate, LmStream};
use crate::data::corpus::{corpus_text, Split};
use crate::data::tokenizer::{decode, encode_example, EOS, PAD};
use crate::data::Example;
use crate::model::ParamStore;
use crate::runtime::{Runtime, Tensor};

/// Perplexity on `n_batches` deterministic windows of the given split.
pub fn perplexity(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &ParamStore,
    corpus_seed: u64,
    split: Split,
    n_batches: usize,
) -> anyhow::Result<f64> {
    let cfg = rt.manifest.config.clone();
    let bytes = (n_batches + 1) * cfg.batch * cfg.seq * 2 + 4096;
    let text = corpus_text(corpus_seed, split, bytes);
    let mut stream = LmStream::new(&text, cfg.batch, cfg.seq);
    let mut inputs_base = base.in_order();
    inputs_base.extend(lora.in_order());

    let (mut total_loss, mut total_count) = (0.0f64, 0.0f64);
    for _ in 0..n_batches {
        let b = stream.next_batch().unwrap();
        let mut inputs = inputs_base.clone();
        inputs.push(b.tokens);
        inputs.push(b.mask);
        let out = rt.run("eval_loss", &inputs)?;
        total_loss += out[0].scalar() as f64;
        total_count += out[1].scalar() as f64;
    }
    anyhow::ensure!(total_count > 0.0, "empty perplexity eval");
    Ok((total_loss / total_count).exp())
}

/// Run `eval_logits` on already-padded token rows; returns the raw logits
/// buffer [B, T, V] (flattened) for post-processing.
fn logits_for(
    rt: &mut Runtime,
    model_inputs: &[Tensor],
    tokens: Tensor,
) -> anyhow::Result<Vec<f32>> {
    let mut inputs = model_inputs.to_vec();
    inputs.push(tokens);
    let out = rt.run("eval_logits", &inputs)?;
    Ok(out[0].as_f32().to_vec())
}

fn log_softmax_at(logits: &[f32], b: usize, t: usize, seq: usize, vocab: usize) -> Vec<f64> {
    let off = (b * seq + t) * vocab;
    let row = &logits[off..off + vocab];
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let lse = row.iter().map(|&x| ((x as f64) - max).exp()).sum::<f64>().ln() + max;
    row.iter().map(|&x| x as f64 - lse).collect()
}

fn argmax_at(logits: &[f32], b: usize, t: usize, seq: usize, vocab: usize) -> i32 {
    let off = (b * seq + t) * vocab;
    let row = &logits[off..off + vocab];
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in row.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as i32
}

/// Exact-match accuracy by greedy decoding (generative tasks).
/// Decodes up to `max_new` tokens after `[BOS] prompt " A: "`.
pub fn accuracy_greedy(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &ParamStore,
    examples: &[Example],
    max_new: usize,
) -> anyhow::Result<f64> {
    let cfg = rt.manifest.config.clone();
    let (bsz, seq, vocab) = (cfg.batch, cfg.seq, cfg.vocab);
    let mut model_inputs = base.in_order();
    model_inputs.extend(lora.in_order());

    let mut correct = 0usize;
    for chunk in examples.chunks(bsz) {
        // Prompt rows: [BOS] prompt " A: " (room left for max_new tokens).
        let mut rows: Vec<Vec<i32>> = chunk
            .iter()
            .map(|ex| {
                let (mut toks, astart) = encode_example(&ex.prompt, "");
                toks.truncate(astart);
                toks.truncate(seq - max_new);
                toks
            })
            .collect();
        let prompt_lens: Vec<usize> = rows.iter().map(|r| r.len()).collect();
        let mut done = vec![false; chunk.len()];
        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let tokens = pad_rows(&rows, bsz, seq);
            let logits = logits_for(rt, &model_inputs, tokens)?;
            for (i, row) in rows.iter_mut().enumerate() {
                if done[i] || row.len() >= seq {
                    done[i] = true;
                    continue;
                }
                let next = argmax_at(&logits, i, row.len() - 1, seq, vocab);
                if next == EOS || next == PAD {
                    done[i] = true;
                } else {
                    row.push(next);
                }
            }
        }
        for (i, ex) in chunk.iter().enumerate() {
            let answer = decode(&rows[i][prompt_lens[i]..]);
            if answer.trim() == ex.answer.trim() {
                correct += 1;
            }
        }
    }
    Ok(correct as f64 / examples.len().max(1) as f64)
}

/// Choice accuracy by option log-likelihood (MCQ tasks): score each option
/// as the mean token log-probability of the candidate; pick the max.
pub fn accuracy_choice(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &ParamStore,
    examples: &[Example],
) -> anyhow::Result<f64> {
    let cfg = rt.manifest.config.clone();
    let (bsz, seq, vocab) = (cfg.batch, cfg.seq, cfg.vocab);
    let mut model_inputs = base.in_order();
    model_inputs.extend(lora.in_order());

    // Flatten (example, option) pairs into rows.
    struct RowRef {
        example: usize,
        option: usize,
        tokens: Vec<i32>,
        astart: usize,
    }
    let mut all_rows = Vec::new();
    for (ei, ex) in examples.iter().enumerate() {
        anyhow::ensure!(ex.is_mcq(), "accuracy_choice needs MCQ examples");
        for (oi, opt) in ex.options.iter().enumerate() {
            let (tokens, astart) = prompt_with_candidate(&ex.prompt, opt, seq);
            all_rows.push(RowRef { example: ei, option: oi, tokens, astart });
        }
    }

    let mut scores: Vec<Vec<f64>> =
        examples.iter().map(|ex| vec![f64::NEG_INFINITY; ex.options.len()]).collect();
    for chunk in all_rows.chunks(bsz) {
        let rows: Vec<Vec<i32>> = chunk.iter().map(|r| r.tokens.clone()).collect();
        let tokens = pad_rows(&rows, bsz, seq);
        let logits = logits_for(rt, &model_inputs, tokens)?;
        for (i, r) in chunk.iter().enumerate() {
            let mut lp = 0.0f64;
            let mut count = 0usize;
            for t in r.astart..r.tokens.len() {
                let ls = log_softmax_at(&logits, i, t - 1, seq, vocab);
                lp += ls[r.tokens[t] as usize];
                count += 1;
            }
            scores[r.example][r.option] =
                if count > 0 { lp / count as f64 } else { f64::NEG_INFINITY };
        }
    }

    let mut correct = 0usize;
    for (ex, sc) in examples.iter().zip(&scores) {
        let best = sc
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .unwrap();
        if ex.options[best] == ex.answer {
            correct += 1;
        }
    }
    Ok(correct as f64 / examples.len().max(1) as f64)
}

/// Dispatch: greedy for generative tasks, choice scoring for MCQ.
pub fn task_accuracy(
    rt: &mut Runtime,
    base: &ParamStore,
    lora: &ParamStore,
    examples: &[Example],
) -> anyhow::Result<f64> {
    if examples.iter().all(|e| e.is_mcq()) {
        accuracy_choice(rt, base, lora, examples)
    } else {
        accuracy_greedy(rt, base, lora, examples, 6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn log_softmax_normalizes() {
        // vocab 4, single position
        let logits = vec![1.0f32, 2.0, 3.0, 4.0];
        let ls = log_softmax_at(&logits, 0, 0, 1, 4);
        let total: f64 = ls.iter().map(|x| x.exp()).sum();
        assert!((total - 1.0).abs() < 1e-9);
        assert!(ls[3] > ls[0]);
    }

    #[test]
    fn argmax_picks_max() {
        let logits = vec![0.0f32, 5.0, -1.0, 2.0, /* pos 1 */ 9.0, 0.0, 0.0, 0.0];
        assert_eq!(argmax_at(&logits, 0, 0, 2, 4), 1);
        assert_eq!(argmax_at(&logits, 0, 1, 2, 4), 0);
    }
}

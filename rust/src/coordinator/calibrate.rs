//! Calibration: stream calibration batches through the `capture_grams`
//! graph and accumulate the per-linear Gram matrices `H = Σ_b X_bᵀX_b`.
//!
//! Mirrors the paper's protocol: N samples (default 128) of `seq`-token
//! windows from the training split of the calibration corpus (§4,
//! "Models and Datasets"), one Gram matrix per quantizable linear layer.

use std::collections::BTreeMap;

use crate::data::batcher::LmStream;
use crate::data::corpus::{corpus_text, Split};
use crate::linalg::Matrix;
use crate::model::ParamStore;
use crate::runtime::Runtime;

/// Per-layer Gram matrices keyed by linear name (`l0.wq`, `l1.w_down`, …).
pub type GramSet = BTreeMap<String, Matrix>;

/// Run calibration with `n_samples` sequences.
pub fn calibrate(
    rt: &mut Runtime,
    base: &ParamStore,
    n_samples: usize,
    corpus_seed: u64,
) -> anyhow::Result<GramSet> {
    let cfg = rt.manifest.config.clone();
    let entry = rt.manifest.entry("capture_grams")?.clone();
    // Output names are "<linear>.H" + trailing checksum.
    let names: Vec<String> = entry
        .outputs
        .iter()
        .filter(|s| s.name.ends_with(".H"))
        .map(|s| s.name.trim_end_matches(".H").to_string())
        .collect();

    // Enough text for n_samples windows.
    let bytes = (n_samples + cfg.batch) * cfg.seq * 2 + 4096;
    let text = corpus_text(corpus_seed, Split::Calibration, bytes);
    let mut stream = LmStream::new(&text, cfg.batch, cfg.seq);

    let mut grams: GramSet = BTreeMap::new();
    let mut seen = 0usize;
    let base_inputs = base.in_order();
    while seen < n_samples {
        let batch = stream
            .next_batch()
            .ok_or_else(|| anyhow::anyhow!("calibration stream exhausted"))?;
        let mut inputs = base_inputs.clone();
        inputs.push(batch.tokens);
        inputs.push(batch.mask);
        let out = rt.run("capture_grams", &inputs)?;
        anyhow::ensure!(
            out.last().unwrap().scalar().is_finite(),
            "calibration forward produced non-finite logits"
        );
        for (t, name) in out.iter().zip(&names) {
            let h = t.to_matrix();
            grams
                .entry(name.clone())
                .and_modify(|acc| acc.add_assign(&h))
                .or_insert(h);
        }
        seen += cfg.batch;
    }
    crate::info!(
        "calibrated {} layers with {} samples ({} batches)",
        grams.len(),
        seen,
        seen / cfg.batch
    );
    Ok(grams)
}

/// Persist / reload Gram sets (they are expensive to recompute across the
/// table harnesses — one set is shared by every method/bit combination).
pub fn save_grams(grams: &GramSet, path: &std::path::Path) -> anyhow::Result<()> {
    let mut store = ParamStore::new();
    for (name, h) in grams {
        store.insert(name, crate::runtime::Tensor::from_matrix(h));
    }
    store.save(path)
}

pub fn load_grams(path: &std::path::Path) -> anyhow::Result<GramSet> {
    let store = ParamStore::load(path)?;
    let mut grams = GramSet::new();
    for name in &store.names {
        grams.insert(name.clone(), store.get(name).to_matrix());
    }
    Ok(grams)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::syrk_t;
    use crate::util::prng::Rng;

    #[test]
    fn gram_save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let mut grams = GramSet::new();
        for name in ["l0.wq", "l0.w_down"] {
            let x = Matrix::randn(20, 8, 1.0, &mut rng);
            grams.insert(name.to_string(), syrk_t(&x));
        }
        let dir = std::env::temp_dir().join(format!("cloq_gram_{}", std::process::id()));
        let path = dir.join("grams.bin");
        save_grams(&grams, &path).unwrap();
        let back = load_grams(&path).unwrap();
        assert_eq!(back.len(), 2);
        for (name, h) in &grams {
            assert!(back[name].max_diff(h) < 1e-6);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! Calibration: stream calibration batches through the `capture_grams`
//! graph and accumulate the per-linear Gram matrices `H = Σ_b X_bᵀX_b`.
//!
//! Mirrors the paper's protocol: N samples (default 128) of `seq`-token
//! windows from the training split of the calibration corpus (§4,
//! "Models and Datasets"), one Gram matrix per quantizable linear layer.

use std::collections::BTreeMap;

use crate::data::batcher::LmStream;
use crate::data::corpus::{corpus_text, Split};
use crate::linalg::{syrk_t, Matrix};
use crate::model::ParamStore;
use crate::runtime::Runtime;

/// Per-layer Gram matrices keyed by linear name (`l0.wq`, `l1.w_down`, …).
pub type GramSet = BTreeMap<String, Matrix>;

/// Streaming accumulator for per-layer Gram matrices — the single place
/// every calibration source funnels through, so the hot accumulation path
/// is routed through the tiled SYRK/add kernels regardless of whether the
/// grams arrive pre-reduced from the AOT graph ([`GramAccumulator::add_gram`])
/// or as raw activation batches captured Rust-side
/// ([`GramAccumulator::add_activations`]).
#[derive(Default)]
pub struct GramAccumulator {
    grams: GramSet,
}

impl GramAccumulator {
    pub fn new() -> GramAccumulator {
        GramAccumulator { grams: GramSet::new() }
    }

    /// Fold in a pre-reduced Gram contribution `H_b` for `name` (by value:
    /// the first contribution is moved in, not copied).
    pub fn add_gram(&mut self, name: &str, h: Matrix) {
        match self.grams.get_mut(name) {
            Some(acc) => acc.add_assign(&h),
            None => {
                self.grams.insert(name.to_string(), h);
            }
        }
    }

    /// Fold in a raw activation batch `X_b` (samples×features) for `name`:
    /// `H_name += X_bᵀX_b` through the (size-dispatched, tiled) `syrk_t`.
    pub fn add_activations(&mut self, name: &str, x: &Matrix) {
        self.add_gram(name, syrk_t(x));
    }

    pub fn len(&self) -> usize {
        self.grams.len()
    }

    pub fn is_empty(&self) -> bool {
        self.grams.is_empty()
    }

    pub fn finish(self) -> GramSet {
        self.grams
    }
}

/// Run calibration with `n_samples` sequences.
pub fn calibrate(
    rt: &mut Runtime,
    base: &ParamStore,
    n_samples: usize,
    corpus_seed: u64,
) -> anyhow::Result<GramSet> {
    let cfg = rt.manifest.config.clone();
    let entry = rt.manifest.entry("capture_grams")?.clone();
    // Output names are "<linear>.H" + trailing checksum.
    let names: Vec<String> = entry
        .outputs
        .iter()
        .filter(|s| s.name.ends_with(".H"))
        .map(|s| s.name.trim_end_matches(".H").to_string())
        .collect();

    // Enough text for n_samples windows.
    let bytes = (n_samples + cfg.batch) * cfg.seq * 2 + 4096;
    let text = corpus_text(corpus_seed, Split::Calibration, bytes);
    let mut stream = LmStream::new(&text, cfg.batch, cfg.seq);

    let mut acc = GramAccumulator::new();
    let mut seen = 0usize;
    let base_inputs = base.in_order();
    while seen < n_samples {
        let batch = stream
            .next_batch()
            .ok_or_else(|| anyhow::anyhow!("calibration stream exhausted"))?;
        let mut inputs = base_inputs.clone();
        inputs.push(batch.tokens);
        inputs.push(batch.mask);
        let out = rt.run("capture_grams", &inputs)?;
        anyhow::ensure!(
            out.last().unwrap().scalar().is_finite(),
            "calibration forward produced non-finite logits"
        );
        for (t, name) in out.iter().zip(&names) {
            acc.add_gram(name, t.to_matrix());
        }
        seen += cfg.batch;
    }
    crate::info!(
        "calibrated {} layers with {} samples ({} batches)",
        acc.len(),
        seen,
        seen / cfg.batch
    );
    Ok(acc.finish())
}

/// Persist / reload Gram sets (they are expensive to recompute across the
/// table harnesses — one set is shared by every method/bit combination).
pub fn save_grams(grams: &GramSet, path: &std::path::Path) -> anyhow::Result<()> {
    let mut store = ParamStore::new();
    for (name, h) in grams {
        store.insert(name, crate::runtime::Tensor::from_matrix(h));
    }
    store.save(path)
}

pub fn load_grams(path: &std::path::Path) -> anyhow::Result<GramSet> {
    let store = ParamStore::load(path)?;
    let mut grams = GramSet::new();
    for name in &store.names {
        grams.insert(name.clone(), store.get(name).to_matrix());
    }
    Ok(grams)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn accumulator_matches_one_shot_gram() {
        // Streaming batches through the accumulator == one SYRK over the
        // stacked activations (associativity of the sum of Gram terms).
        let mut rng = Rng::new(21);
        let batches: Vec<Matrix> = (0..5).map(|_| Matrix::randn(16, 12, 1.0, &mut rng)).collect();
        let mut acc = GramAccumulator::new();
        assert!(acc.is_empty());
        let mut stacked = batches[0].clone();
        acc.add_activations("l0.wq", &batches[0]);
        for b in &batches[1..] {
            acc.add_activations("l0.wq", b);
            stacked = stacked.vstack(b);
        }
        // A second layer fed pre-reduced grams takes the other entry path.
        let h1 = syrk_t(&batches[0]);
        acc.add_gram("l0.wk", h1.clone());
        acc.add_gram("l0.wk", h1.clone());
        assert_eq!(acc.len(), 2);
        let grams = acc.finish();
        let expect = syrk_t(&stacked);
        assert!(grams["l0.wq"].max_diff(&expect) < 1e-9 * expect.max_abs().max(1.0));
        assert!(grams["l0.wk"].max_diff(&h1.scale(2.0)) < 1e-12);
    }

    #[test]
    fn gram_save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let mut grams = GramSet::new();
        for name in ["l0.wq", "l0.w_down"] {
            let x = Matrix::randn(20, 8, 1.0, &mut rng);
            grams.insert(name.to_string(), syrk_t(&x));
        }
        let dir = std::env::temp_dir().join(format!("cloq_gram_{}", std::process::id()));
        let path = dir.join("grams.bin");
        save_grams(&grams, &path).unwrap();
        let back = load_grams(&path).unwrap();
        assert_eq!(back.len(), 2);
        for (name, h) in &grams {
            assert!(back[name].max_diff(h) < 1e-6);
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! The quantize+init stage: applies a [`Method`] to every linear layer,
//! producing the frozen base (`q_deq`) and the LoRA adapters.
//!
//! Layers are independent jobs dispatched on the thread pool (the
//! scheduler); results are reassembled in manifest order so the output
//! stores marshal directly into the AOT graphs.

use crate::lowrank::{init_layer, InitConfig, Method};
use crate::model::manifest::Manifest;
use crate::model::{base_specs, lora_specs, ParamStore};
use crate::quant::{quantize_rtn, QuantState};
use crate::runtime::Tensor;
use crate::util::prng::Rng;
use crate::util::threadpool::{run_collect_status, JobStatus};

use super::calibrate::GramSet;

/// Result of initializing the whole model.
pub struct ModelInit {
    /// Base params with quantized (dequantized-value) linears, manifest order.
    pub base_q: ParamStore,
    /// LoRA adapters, manifest order.
    pub lora: ParamStore,
    /// Per-layer packed quantization state for the qeval serving path
    /// (codes/scales/zeros tensors keyed by `<linear>.{codes,scales,zeros}`).
    pub quant: ParamStore,
    /// Exact per-layer quantization state in manifest order, kept at full
    /// f64 precision for the packed serving artifact (`serve::artifact`):
    /// the serve kernel must agree with `base_q` bit-for-bit, which the f32
    /// `quant` store (lowered for the qeval graph) cannot guarantee.
    ///
    /// OPT-IN: `None` unless `quantize_init` is called with
    /// `keep_exact = true`. The duplicate trail costs ~1 byte/weight of
    /// codes plus the f64 group params on top of the f32 stores (~25%
    /// extra per-layer copy), which pure train/eval sweeps that never
    /// serve should not pay. `PackedModel::from_model_init` errors
    /// actionably when the trail is absent.
    ///
    /// LOSSY EXCEPTION: layers whose method keeps an fp base (LoRA16) are
    /// re-gridded into an 8-bit INT container — the packed engine then
    /// matches that container bit-exactly, NOT the fp weights (same policy
    /// as the qeval fallback below). Callers that want a hard error for
    /// fp-base methods instead should go through
    /// `serve::PackedLayer::from_layer_init`, which rejects them by name.
    pub exact: Option<Vec<(String, QuantState)>>,
    /// Mean bits/weight over quantized layers.
    pub bits_per_weight: f64,
}

/// Apply `method` at `bits` to every linear layer of `base`.
///
/// `grams` must contain every linear's H when the method is calibrated;
/// `workers` sizes the scheduler's thread pool; `keep_exact` opts into the
/// f64 serving trail (`ModelInit::exact`) that the packed serve path
/// consumes — leave it `false` for train/eval sweeps that never serve and
/// skip the extra per-layer copy. The result is
/// WORKER-COUNT-INDEPENDENT: each layer job derives its own RNG stream from
/// `(seed, layer index)` and results are reassembled in manifest order, so
/// `workers ∈ {1, 2, 8, …}` produce byte-identical `ModelInit`s (locked
/// down by `tests/prop_coordinator.rs`). A panicking layer job surfaces as
/// an error naming the layer (via [`JobStatus::Panicked`]) after the pool
/// has drained the remaining jobs — one bad layer cannot wedge the stage.
pub fn quantize_init(
    man: &Manifest,
    base: &ParamStore,
    grams: Option<&GramSet>,
    cfg: &InitConfig,
    seed: u64,
    workers: usize,
    keep_exact: bool,
) -> anyhow::Result<ModelInit> {
    let mcfg = &man.config;
    anyhow::ensure!(
        cfg.rank == mcfg.rank,
        "InitConfig.rank {} must match artifact rank {} (shapes are lowered statically)",
        cfg.rank,
        mcfg.rank
    );
    if cfg.method.needs_calibration() {
        anyhow::ensure!(grams.is_some(), "{:?} needs calibration grams", cfg.method);
    }

    // One job per linear layer.
    let linear_names = mcfg.all_linear_names();
    let jobs: Vec<_> = linear_names
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let w = base.get(name).to_matrix();
            let h = grams.and_then(|g| g.get(name).cloned());
            let cfg = cfg.clone();
            let name = name.clone();
            move || {
                // Deterministic per-layer stream: a pure function of
                // (seed, layer index), never of scheduling order.
                let mut rng = Rng::new(seed ^ ((i as u64 + 1) * 0x9E37_79B9));
                let li = init_layer(&w, h.as_ref(), &cfg, &mut rng);
                (name, li)
            }
        })
        .collect();
    let (results, statuses) = run_collect_status(workers, jobs);
    let failed: Vec<String> = statuses
        .iter()
        .enumerate()
        .filter_map(|(i, s)| match s {
            JobStatus::Panicked(msg) => Some(format!("{} ({msg})", linear_names[i])),
            JobStatus::Done => None,
        })
        .collect();
    anyhow::ensure!(
        failed.is_empty(),
        "quantize_init: {}/{} layer jobs panicked (the pool completed the rest): {}",
        failed.len(),
        linear_names.len(),
        failed.join("; ")
    );
    let results: Vec<(String, crate::lowrank::LayerInit)> = results.into_iter().flatten().collect();

    // Reassemble in manifest order.
    let mut base_q = ParamStore::new();
    for spec in base_specs(man)? {
        if let Some((_, li)) = results.iter().find(|(n, _)| *n == spec.name) {
            base_q.insert(&spec.name, Tensor::from_matrix(&li.q_deq));
        } else {
            base_q.insert(&spec.name, base.get(&spec.name).clone());
        }
    }
    let mut lora = ParamStore::new();
    for spec in lora_specs(man)? {
        let (layer, kind) = spec.name.rsplit_once('.').unwrap();
        let (_, li) = results
            .iter()
            .find(|(n, _)| n == layer)
            .ok_or_else(|| anyhow::anyhow!("no init result for {layer}"))?;
        let m = if kind == "A" { &li.a } else { &li.b };
        anyhow::ensure!(
            m.rows == spec.shape[0] && m.cols == spec.shape[1],
            "{}: init shape {}x{} vs manifest {:?}",
            spec.name,
            m.rows,
            m.cols,
            spec.shape
        );
        lora.insert(&spec.name, Tensor::from_matrix(m));
    }

    // Packed state for the qeval serving graph: use the EXACT INT state
    // when the method produced one (OPTQ/LoftQ/CLoQ — the qeval path then
    // agrees with the dense path to fp tolerance); NF/fp bases fall back to
    // an 8-bit re-grid (a value-faithful container, not the NF codebook,
    // which the lowered INT-grid graph cannot index). The qeval graph is
    // lowered for group_size = mcfg.group_size, so exact states with a
    // different group size are re-gridded too.
    //
    // The `exact` vector is the OPT-IN parallel f64 trail for the Rust-side
    // packed serving engine: the method's own state verbatim whenever one
    // exists (any grid/codebook, any group size), and for fp bases (LoRA16)
    // a LOSSY 8-bit RTN container — see the `ModelInit::exact` field docs.
    let mut quant = ParamStore::new();
    let mut exact = keep_exact.then(|| Vec::with_capacity(linear_names.len()));
    for name in &linear_names {
        let (_, li) = results.iter().find(|(n, _)| n == name).unwrap();
        // The qeval container: the method's own INT state when the group
        // size matches the lowered graph, an RTN re-grid otherwise (the
        // lowered INT-grid graph cannot index an NF codebook or a foreign
        // group size). Methods without a state (LoRA16 — the only `None`)
        // share a single 8-bit RTN container between both trails.
        let q = match &li.quant {
            Some(QuantState::Int(qi)) if qi.group_size == mcfg.group_size => qi.clone(),
            Some(_) => quantize_rtn(&li.q_deq, cfg.bits.max(4), mcfg.group_size),
            None => {
                debug_assert_eq!(cfg.method, Method::Lora16);
                quantize_rtn(&li.q_deq, 8, mcfg.group_size)
            }
        };
        if let Some(exact) = exact.as_mut() {
            let qs = match &li.quant {
                Some(qs) => qs.clone(),
                None => QuantState::Int(q.clone()),
            };
            exact.push((name.clone(), qs));
        }
        let codes: Vec<i32> = q.codes.iter().map(|&c| c as i32).collect();
        quant.insert(&format!("{name}.codes"), Tensor::i32(vec![q.rows, q.cols], codes));
        quant.insert(&format!("{name}.scales"), Tensor::from_matrix(&q.scales));
        quant.insert(&format!("{name}.zeros"), Tensor::from_matrix(&q.zeros));
    }

    let bpw = results.iter().map(|(_, li)| li.bits_per_weight).sum::<f64>()
        / results.len().max(1) as f64;
    Ok(ModelInit { base_q, lora, quant, exact, bits_per_weight: bpw })
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end in rust/tests/integration.rs and the pipeline
    // tests (needs artifacts); unit-level behaviour is covered by
    // lowrank::init tests.
}

//! The end-to-end pipeline: pretrain → calibrate → quantize+init →
//! fine-tune → evaluate, with disk caching of the expensive shared stages
//! (the pretrained base and the calibration Gram set are shared by every
//! method/bit combination of a table).

use std::path::PathBuf;

use crate::data::{commonsense170k, math10k, mixed_dataset, Task, ARITH_TASKS, COMMONSENSE_TASKS};
use crate::lowrank::{InitConfig, Method};
use crate::model::ParamStore;
use crate::runtime::Runtime;
use crate::util::prng::Rng;
use crate::util::timer::{peak_rss_mib, timeit};

use super::calibrate::{calibrate, load_grams, save_grams, GramSet};
use super::evaluator::{perplexity, task_accuracy};
use super::quantize::{quantize_init, ModelInit};
use super::trainer::{finetune_lora, pretrain, DataSource, TrainConfig, TrainOutcome};

#[derive(Clone, Debug)]
pub struct PipelineOpts {
    /// artifacts/<config> directory.
    pub artifacts: PathBuf,
    /// Cache directory for pretrained bases / gram sets (runs/<config>).
    pub runs_dir: PathBuf,
    pub seed: u64,
    pub pretrain_steps: usize,
    pub pretrain_lr: f64,
    pub calib_samples: usize,
    /// Examples per fine-tuning dataset / per eval set.
    pub train_examples: usize,
    pub eval_examples: usize,
    pub eval_ppl_batches: usize,
}

impl Default for PipelineOpts {
    /// The `tiny-s` defaults under the conventional `artifacts/` /
    /// `runs/` roots — [`PipelineOpts::new`] with the default config name.
    fn default() -> Self {
        PipelineOpts::new("tiny-s")
    }
}

impl PipelineOpts {
    pub fn new(config: &str) -> PipelineOpts {
        PipelineOpts {
            artifacts: PathBuf::from("artifacts").join(config),
            runs_dir: PathBuf::from("runs").join(config),
            seed: 42,
            pretrain_steps: 3000,
            pretrain_lr: 2e-3,
            calib_samples: 128,
            train_examples: 384,
            eval_examples: 48,
            eval_ppl_batches: 12,
        }
    }

    pub fn fast(mut self) -> PipelineOpts {
        self.pretrain_steps = 1200;
        self.calib_samples = 32;
        self.train_examples = 128;
        self.eval_examples = 24;
        self.eval_ppl_batches = 4;
        self
    }

    // Builder-style setters, symmetric with the serving engine's
    // `ServeEngine::builder(..).workers(n).build()` shape — offline and
    // online configuration read the same way. Fields stay public for
    // in-place tweaks, but chained construction is the primary surface.

    /// RNG seed shared by pretraining, calibration and fine-tuning.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Pretraining steps for the cached base model.
    pub fn pretrain_steps(mut self, steps: usize) -> Self {
        self.pretrain_steps = steps;
        self
    }

    /// Pretraining learning rate.
    pub fn pretrain_lr(mut self, lr: f64) -> Self {
        self.pretrain_lr = lr;
        self
    }

    /// Calibration samples feeding the Gram set.
    pub fn calib_samples(mut self, n: usize) -> Self {
        self.calib_samples = n;
        self
    }

    /// Examples per fine-tuning dataset.
    pub fn train_examples(mut self, n: usize) -> Self {
        self.train_examples = n;
        self
    }

    /// Examples per evaluation set.
    pub fn eval_examples(mut self, n: usize) -> Self {
        self.eval_examples = n;
        self
    }

    /// Batches used by the perplexity evaluator.
    pub fn eval_ppl_batches(mut self, n: usize) -> Self {
        self.eval_ppl_batches = n;
        self
    }
}

/// Load-or-train the pretrained base model (cached on disk).
pub fn ensure_pretrained(
    rt: &mut Runtime,
    opts: &PipelineOpts,
) -> anyhow::Result<(ParamStore, Option<TrainOutcome>)> {
    let path = opts.runs_dir.join(format!("base_s{}_p{}.ckpt", opts.seed, opts.pretrain_steps));
    if path.exists() {
        crate::info!("loading pretrained base from {}", path.display());
        return Ok((ParamStore::load(&path)?, None));
    }
    crate::info!("pretraining base model ({} steps)…", opts.pretrain_steps);
    let mut rng = Rng::new(opts.seed);
    let init = crate::model::init_base(&rt.manifest, &mut rng)?;
    let tcfg = TrainConfig {
        steps: opts.pretrain_steps,
        lr: opts.pretrain_lr,
        weight_decay: 0.01,
        warmup_frac: 0.05,
        log_every: 50,
    };
    let (base, outcome) = pretrain(rt, &init, &tcfg, opts.seed)?;
    base.save(&path)?;
    Ok((base, Some(outcome)))
}

/// Load-or-compute the calibration Gram set (cached on disk, keyed by the
/// calibration sample count — Table 8 sweeps it).
pub fn ensure_grams(
    rt: &mut Runtime,
    base: &ParamStore,
    opts: &PipelineOpts,
    n_samples: usize,
) -> anyhow::Result<GramSet> {
    let path = opts
        .runs_dir
        .join(format!("grams_s{}_p{}_n{}.bin", opts.seed, opts.pretrain_steps, n_samples));
    if path.exists() {
        return load_grams(&path);
    }
    let grams = calibrate(rt, base, n_samples, opts.seed)?;
    save_grams(&grams, &path)?;
    Ok(grams)
}

/// What to fine-tune / evaluate on — one per experiment family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinetuneTask {
    /// WikiText protocol: LM fine-tune, ppl on the valid split.
    Wiki,
    /// Single-task GSM8K protocol: exact-match accuracy.
    Gsm8k,
    /// Math10K → 4 arithmetic test sets.
    Math10k,
    /// Commonsense170K → 8 MCQ test sets.
    Commonsense,
    /// Table 6: Math10K + commonsense samples → 4 arithmetic test sets.
    Mixed,
}

impl FinetuneTask {
    pub fn parse(s: &str) -> Option<FinetuneTask> {
        Some(match s.to_ascii_lowercase().as_str() {
            "wiki" => FinetuneTask::Wiki,
            "gsm8k" | "gsm" => FinetuneTask::Gsm8k,
            "math10k" | "arith" => FinetuneTask::Math10k,
            "commonsense" | "cs" => FinetuneTask::Commonsense,
            "mixed" => FinetuneTask::Mixed,
            _ => return None,
        })
    }
}

/// One (method, bits, task) experiment.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub method: Method,
    pub bits: u32,
    pub task: FinetuneTask,
    pub steps: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub seed: u64,
    /// Override the quantization group size (Table 12 sweeps 64/128/chan).
    pub group_size: Option<usize>,
}

impl RunSpec {
    pub fn new(method: Method, bits: u32, task: FinetuneTask) -> RunSpec {
        // Defaults follow the paper's Table 11/12 shape (scaled to the tiny
        // models): LM/arith share one LR; commonsense takes a smaller one.
        // The step budget is deliberately modest — like the paper's 7B-scale
        // setting, fine-tuning must START from a good initialization rather
        // than being able to re-learn the quantization damage from scratch;
        // at tiny scale that regime corresponds to O(60) steps.
        let lr = match task {
            FinetuneTask::Commonsense => 7e-4,
            _ => 1e-3,
        };
        let weight_decay = match task {
            FinetuneTask::Wiki | FinetuneTask::Gsm8k => 0.1,
            _ => 1.0,
        };
        RunSpec { method, bits, task, steps: 60, lr, weight_decay, seed: 7, group_size: None }
    }
}

/// Metrics out of one experiment.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub ppl: Option<f64>,
    /// (task name, accuracy) pairs.
    pub accuracies: Vec<(String, f64)>,
    pub init_seconds: f64,
    pub finetune_seconds: f64,
    pub bits_per_weight: f64,
    pub peak_rss_mib: f64,
    pub final_train_loss: f32,
}

impl RunResult {
    pub fn avg_accuracy(&self) -> f64 {
        if self.accuracies.is_empty() {
            return f64::NAN;
        }
        self.accuracies.iter().map(|(_, a)| a).sum::<f64>() / self.accuracies.len() as f64
    }
}

/// Initialize the model per the spec (quantize + LoRA init), without
/// fine-tuning — used directly by Fig. 2 / Table 10 harnesses.
pub fn init_model(
    rt: &Runtime,
    base: &ParamStore,
    grams: &GramSet,
    spec: &RunSpec,
) -> anyhow::Result<(ModelInit, f64)> {
    let mut icfg = InitConfig::new(spec.method, spec.bits, rt.manifest.config.rank);
    if let Some(gs) = spec.group_size {
        icfg.group_size = gs;
    } else {
        icfg.group_size = rt.manifest.config.group_size;
    }
    let grams_opt = spec.method.needs_calibration().then_some(grams);
    let workers = crate::util::threadpool::default_workers();
    // Sweep paths train + evaluate but never serve: skip the exact f64
    // serving trail (~25% extra per-layer copy). Serving callers build
    // their ModelInit with `quantize_init(.., keep_exact = true)` and go
    // through `PackedModel::from_model_init`.
    let (init, secs) =
        timeit(|| quantize_init(&rt.manifest, base, grams_opt, &icfg, spec.seed, workers, false));
    Ok((init?, secs))
}

/// Execute one full experiment: init → fine-tune → evaluate.
pub fn run_one(
    rt: &mut Runtime,
    base: &ParamStore,
    grams: &GramSet,
    spec: &RunSpec,
    opts: &PipelineOpts,
) -> anyhow::Result<RunResult> {
    crate::info!(
        "run: method={} bits={} task={:?} steps={} lr={:.1e}",
        spec.method.name(),
        spec.bits,
        spec.task,
        spec.steps,
        spec.lr
    );
    let (init, init_seconds) = init_model(rt, base, grams, spec)?;

    let tcfg = TrainConfig {
        steps: spec.steps,
        lr: spec.lr,
        weight_decay: spec.weight_decay,
        warmup_frac: 0.05,
        log_every: 0,
    };
    let n = opts.train_examples;
    let train_data = match spec.task {
        FinetuneTask::Wiki => None,
        FinetuneTask::Gsm8k => Some(Task::SGsm.dataset(n, spec.seed, 0)),
        FinetuneTask::Math10k => Some(math10k(n, spec.seed)),
        FinetuneTask::Commonsense => Some(commonsense170k(n, spec.seed)),
        FinetuneTask::Mixed => Some(mixed_dataset(n, n / 3, spec.seed)),
    };
    let source = match &train_data {
        None => DataSource::Corpus(opts.seed),
        Some(d) => DataSource::Tasks(d),
    };
    let (ft_result, finetune_seconds) =
        timeit(|| finetune_lora(rt, &init.base_q, &init.lora, source, &tcfg, spec.seed));
    let (lora, outcome): (ParamStore, TrainOutcome) = ft_result?;

    // Evaluation per protocol.
    let mut ppl = None;
    let mut accuracies = Vec::new();
    match spec.task {
        FinetuneTask::Wiki => {
            ppl = Some(perplexity(
                rt,
                &init.base_q,
                &lora,
                opts.seed,
                crate::data::Split::Valid,
                opts.eval_ppl_batches,
            )?);
        }
        FinetuneTask::Gsm8k => {
            let test = Task::SGsm.dataset(opts.eval_examples, spec.seed, 1);
            accuracies.push((
                Task::SGsm.name().to_string(),
                task_accuracy(rt, &init.base_q, &lora, &test)?,
            ));
        }
        FinetuneTask::Math10k | FinetuneTask::Mixed => {
            for t in ARITH_TASKS {
                let test = t.dataset(opts.eval_examples, spec.seed, 1);
                accuracies.push((
                    t.name().to_string(),
                    task_accuracy(rt, &init.base_q, &lora, &test)?,
                ));
            }
        }
        FinetuneTask::Commonsense => {
            for t in COMMONSENSE_TASKS {
                let test = t.dataset(opts.eval_examples, spec.seed, 1);
                accuracies.push((
                    t.name().to_string(),
                    task_accuracy(rt, &init.base_q, &lora, &test)?,
                ));
            }
        }
    }

    Ok(RunResult {
        ppl,
        accuracies,
        init_seconds,
        finetune_seconds,
        bits_per_weight: init.bits_per_weight,
        peak_rss_mib: peak_rss_mib(),
        final_train_loss: outcome.final_loss,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_parse() {
        assert_eq!(FinetuneTask::parse("wiki"), Some(FinetuneTask::Wiki));
        assert_eq!(FinetuneTask::parse("GSM8K"), Some(FinetuneTask::Gsm8k));
        assert_eq!(FinetuneTask::parse("nope"), None);
    }

    #[test]
    fn pipeline_opts_builder_setters_chain() {
        let o = PipelineOpts::new("cfg").seed(7).pretrain_steps(10).calib_samples(4);
        assert_eq!(o.seed, 7);
        assert_eq!(o.pretrain_steps, 10);
        assert_eq!(o.calib_samples, 4);
        assert!(o.artifacts.ends_with("cfg"));
        // Default = the tiny-s config's defaults.
        let d = PipelineOpts::default();
        assert_eq!(d.seed, 42);
        assert!(d.artifacts.ends_with("tiny-s"));
        // fast() composes with the setters.
        let f = PipelineOpts::default().fast().eval_examples(3);
        assert_eq!(f.pretrain_steps, 1200);
        assert_eq!(f.eval_examples, 3);
    }

    #[test]
    fn runspec_defaults_follow_protocol() {
        let s = RunSpec::new(Method::CLoQ, 2, FinetuneTask::Commonsense);
        assert!(s.lr < 2e-3);
        assert_eq!(s.weight_decay, 1.0);
        let s = RunSpec::new(Method::CLoQ, 2, FinetuneTask::Wiki);
        assert_eq!(s.weight_decay, 0.1);
    }
}

//! Singular value decomposition via one-sided Jacobi, plus the Eckart–Young
//! best rank-r approximation used throughout CLoQ/LoftQ.
//!
//! One-sided Jacobi orthogonalizes the columns of A by plane rotations
//! (applied on the right); on convergence the column norms are the singular
//! values, the normalized columns form U, and the accumulated rotations form
//! V. It is slower than bidiagonalization+QR asymptotically but extremely
//! robust and accurate — the right trade-off for layer-sized matrices.

use super::matrix::Matrix;

pub struct Svd {
    /// m×k with orthonormal columns (k = min(m, n)).
    pub u: Matrix,
    /// Singular values, descending.
    pub s: Vec<f64>,
    /// n×k with orthonormal columns.
    pub v: Matrix,
}

impl Svd {
    /// Reconstruct U·diag(s)·Vᵀ (for tests / truncation).
    pub fn reconstruct(&self) -> Matrix {
        let us = scale_cols(&self.u, &self.s);
        super::blas::matmul_nt(&us, &self.v)
    }

    /// Truncate to the top-r components.
    pub fn truncate(&self, r: usize) -> Svd {
        let r = r.min(self.s.len());
        Svd {
            u: self.u.cols_front(r),
            s: self.s[..r].to_vec(),
            v: self.v.cols_front(r),
        }
    }
}

/// Multiply column j of `m` by `s[j]`.
pub fn scale_cols(m: &Matrix, s: &[f64]) -> Matrix {
    assert!(s.len() >= m.cols);
    Matrix::from_fn(m.rows, m.cols, |i, j| m.at(i, j) * s[j])
}

/// Thin SVD of an arbitrary matrix.
pub fn svd(a: &Matrix) -> Svd {
    if a.rows >= a.cols {
        svd_tall(a)
    } else {
        // SVD of Aᵀ then swap factors: A = U S Vᵀ ⇔ Aᵀ = V S Uᵀ.
        let t = svd_tall(&a.transpose());
        Svd { u: t.v, s: t.s, v: t.u }
    }
}

/// One-sided Jacobi for m ≥ n.
fn svd_tall(a: &Matrix) -> Svd {
    let (m, n) = (a.rows, a.cols);
    debug_assert!(m >= n);
    // Work on columns: store A column-major for contiguous column access.
    let mut cols: Vec<Vec<f64>> = (0..n).map(|j| a.col(j)).collect();
    let mut v = Matrix::eye(n);

    let fro2: f64 = a.data.iter().map(|x| x * x).sum::<f64>();
    let eps = 1e-15;
    let tol2 = (eps * fro2.sqrt().max(1e-300)).powi(2);
    const MAX_SWEEPS: usize = 60;

    for _sweep in 0..MAX_SWEEPS {
        let mut rotated = false;
        for p in 0..n.saturating_sub(1) {
            for q in p + 1..n {
                // Gram entries for the (p,q) column pair.
                let (mut app, mut aqq, mut apq) = (0.0, 0.0, 0.0);
                for i in 0..m {
                    let (x, y) = (cols[p][i], cols[q][i]);
                    app += x * x;
                    aqq += y * y;
                    apq += x * y;
                }
                // Converged pair? |a_p·a_q|² ≤ tol²·small → skip.
                if apq * apq <= eps * eps * app * aqq + tol2 * 1e-30 {
                    continue;
                }
                if apq.abs() < 1e-300 {
                    continue;
                }
                rotated = true;
                // Jacobi rotation zeroing the off-diagonal of the 2×2 Gram.
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                // Rotate the column pair.
                for i in 0..m {
                    let (x, y) = (cols[p][i], cols[q][i]);
                    cols[p][i] = c * x - s * y;
                    cols[q][i] = s * x + c * y;
                }
                // Accumulate V.
                for k in 0..n {
                    let (x, y) = (v.at(k, p), v.at(k, q));
                    v.set(k, p, c * x - s * y);
                    v.set(k, q, s * x + c * y);
                }
            }
        }
        if !rotated {
            break;
        }
    }

    // Singular values = column norms; U = normalized columns.
    let mut svals: Vec<f64> = cols
        .iter()
        .map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    // Sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| svals[j].partial_cmp(&svals[i]).unwrap());
    let mut u = Matrix::zeros(m, n);
    let mut vs = Matrix::zeros(n, n);
    let mut s_sorted = Vec::with_capacity(n);
    for (new_j, &old_j) in order.iter().enumerate() {
        let sv = svals[old_j];
        s_sorted.push(sv);
        if sv > 1e-300 {
            for i in 0..m {
                u.set(i, new_j, cols[old_j][i] / sv);
            }
        } else {
            // Null singular value: leave U column zero (callers using thin
            // SVD with rank truncation never touch it; pinv skips it).
        }
        for i in 0..n {
            vs.set(i, new_j, v.at(i, old_j));
        }
    }
    svals = s_sorted;
    Svd { u, s: svals, v: vs }
}

/// Eckart–Young best rank-r approximation `LR_r(A)` (Frobenius-optimal).
pub fn best_rank_r(a: &Matrix, r: usize) -> Matrix {
    let t = svd(a).truncate(r);
    t.reconstruct()
}

/// Moore–Penrose pseudo-inverse via SVD, truncating singular values below
/// `rcond · s_max`.
pub fn pinv(a: &Matrix, rcond: f64) -> Matrix {
    let d = svd(a);
    let smax = d.s.first().copied().unwrap_or(0.0);
    let cutoff = rcond * smax;
    let sinv: Vec<f64> = d
        .s
        .iter()
        .map(|&s| if s > cutoff && s > 0.0 { 1.0 / s } else { 0.0 })
        .collect();
    // A⁺ = V Σ⁺ Uᵀ
    let vsi = scale_cols(&d.v, &sinv);
    super::blas::matmul_nt(&vsi, &d.u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::matmul;
    use crate::util::prng::Rng;

    fn check_svd(a: &Matrix, d: &Svd, tol: f64) {
        let k = a.rows.min(a.cols);
        assert_eq!(d.s.len(), k);
        // Reconstruction.
        assert!(a.max_diff(&d.reconstruct()) < tol, "recon err {}", a.max_diff(&d.reconstruct()));
        // Orthonormal columns (skip null-space columns of U).
        let utu = matmul(&d.u.transpose(), &d.u);
        for i in 0..k {
            for j in 0..k {
                let expect = if i == j && d.s[i] > 1e-12 {
                    1.0
                } else if i == j {
                    utu.at(i, j)
                } else {
                    0.0
                };
                if d.s[i] > 1e-12 && d.s[j] > 1e-12 {
                    let want = if i == j { 1.0 } else { 0.0 };
                    assert!((utu.at(i, j) - want).abs() < tol, "UᵀU[{i}][{j}]");
                }
                let _ = expect;
            }
        }
        let vtv = matmul(&d.v.transpose(), &d.v);
        assert!(vtv.max_diff(&Matrix::eye(k)) < tol);
        // Descending non-negative.
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn identity_and_diag() {
        let d = svd(&Matrix::eye(4));
        assert!(d.s.iter().all(|&s| (s - 1.0).abs() < 1e-12));
        let a = Matrix::diag(&[3.0, -2.0, 0.5]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-12);
        assert!((d.s[1] - 2.0).abs() < 1e-12);
        assert!((d.s[2] - 0.5).abs() < 1e-12);
        check_svd(&a, &d, 1e-10);
    }

    #[test]
    fn random_shapes() {
        let mut rng = Rng::new(14);
        for &(m, n) in &[(1, 1), (5, 3), (3, 5), (20, 20), (48, 16), (16, 48), (7, 64)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let d = svd(&a);
            check_svd(&a, &d, 1e-8 * (m.max(n) as f64));
        }
    }

    #[test]
    fn rank_deficient() {
        let mut rng = Rng::new(15);
        // Build an exactly rank-3 10×8 matrix.
        let b = Matrix::randn(10, 3, 1.0, &mut rng);
        let c = Matrix::randn(3, 8, 1.0, &mut rng);
        let a = matmul(&b, &c);
        let d = svd(&a);
        check_svd(&a, &d, 1e-8);
        assert!(d.s[3] < 1e-9, "s={:?}", d.s);
    }

    #[test]
    fn best_rank_r_is_frobenius_optimal_vs_random() {
        let mut rng = Rng::new(16);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let r = 4;
        let lr = best_rank_r(&a, r);
        let err_opt: f64 = a.sub(&lr).data.iter().map(|x| x * x).sum();
        // Against 50 random rank-r candidates built as products.
        for _ in 0..50 {
            let p = Matrix::randn(20, r, 1.0, &mut rng);
            let q = Matrix::randn(r, 12, 1.0, &mut rng);
            let cand = matmul(&p, &q);
            let err: f64 = a.sub(&cand).data.iter().map(|x| x * x).sum();
            assert!(err_opt <= err + 1e-9);
        }
        // Error equals sum of squared trailing singular values.
        let d = svd(&a);
        let tail: f64 = d.s[r..].iter().map(|s| s * s).sum();
        assert!((err_opt - tail).abs() < 1e-8);
    }

    #[test]
    fn pinv_properties() {
        let mut rng = Rng::new(17);
        let a = Matrix::randn(9, 5, 1.0, &mut rng);
        let ap = pinv(&a, 1e-12);
        // A·A⁺·A = A
        let aapa = matmul(&matmul(&a, &ap), &a);
        assert!(a.max_diff(&aapa) < 1e-8);
        // A⁺·A·A⁺ = A⁺
        let apaap = matmul(&matmul(&ap, &a), &ap);
        assert!(ap.max_diff(&apaap) < 1e-8);
        // For full-column-rank A, A⁺·A = I.
        assert!(matmul(&ap, &a).max_diff(&Matrix::eye(5)) < 1e-8);
    }

    #[test]
    fn pinv_rank_deficient() {
        let mut rng = Rng::new(18);
        let b = Matrix::randn(8, 2, 1.0, &mut rng);
        let c = Matrix::randn(2, 6, 1.0, &mut rng);
        let a = matmul(&b, &c);
        let ap = pinv(&a, 1e-10);
        let aapa = matmul(&matmul(&a, &ap), &a);
        assert!(a.max_diff(&aapa) < 1e-8);
    }

    #[test]
    fn wide_matrix_consistency() {
        let mut rng = Rng::new(19);
        let a = Matrix::randn(6, 30, 1.0, &mut rng);
        let d1 = svd(&a);
        let d2 = svd(&a.transpose());
        for (s1, s2) in d1.s.iter().zip(&d2.s) {
            assert!((s1 - s2).abs() < 1e-9);
        }
    }
}

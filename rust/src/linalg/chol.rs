//! Cholesky factorization of symmetric positive-definite matrices.
//!
//! OPTQ needs the Cholesky of the (damped) inverse Hessian; the SPD solve is
//! also the workhorse behind `R⁻¹·` products in the CLoQ closed form when we
//! prefer a solve over an explicit inverse.

use super::matrix::Matrix;

/// Lower-triangular L with A = L·Lᵀ. Errors if A is not SPD.
pub fn cholesky(a: &Matrix) -> anyhow::Result<Matrix> {
    assert_eq!(a.rows, a.cols, "cholesky needs square");
    let n = a.rows;
    let mut l = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            // s = A[i][j] - sum_k L[i][k] L[j][k]
            let mut s = a.at(i, j);
            let (li, lj) = (&l.data[i * n..i * n + j], &l.data[j * n..j * n + j]);
            for (x, y) in li.iter().zip(lj) {
                s -= x * y;
            }
            if i == j {
                if s <= 0.0 {
                    anyhow::bail!(
                        "cholesky: matrix not positive definite at pivot {i} (s={s:.3e})"
                    );
                }
                l.data[i * n + i] = s.sqrt();
            } else {
                l.data[i * n + j] = s / l.data[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Cholesky with automatic diagonal damping on failure: retries with
/// λ = percent·mean(diag) escalating ×10 until it succeeds.
/// Returns (L, λ_used). Mirrors the paper's `λ = 0.01·Tr(H)/m` convention.
pub fn cholesky_damped(a: &Matrix, initial_percent: f64) -> (Matrix, f64) {
    let n = a.rows;
    let mean_diag = a.trace() / n as f64;
    let mut lambda = 0.0;
    // First try undamped, then escalate.
    loop {
        let mut damped = a.clone();
        damped.add_diag(lambda);
        match cholesky(&damped) {
            Ok(l) => return (l, lambda),
            Err(_) => {
                lambda = if lambda == 0.0 {
                    initial_percent * mean_diag.max(1e-12)
                } else {
                    lambda * 10.0
                };
                assert!(
                    lambda < 1e12 * mean_diag.max(1.0),
                    "cholesky_damped failed to converge"
                );
            }
        }
    }
}

/// Solve L·y = b (forward substitution), L lower-triangular.
pub fn solve_lower(l: &Matrix, b: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(b.len(), n);
    let mut y = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        let row = &l.data[i * n..i * n + i];
        for (lk, yk) in row.iter().zip(&y[..i]) {
            s -= lk * yk;
        }
        y[i] = s / l.at(i, i);
    }
    y
}

/// Solve Lᵀ·x = y (back substitution), L lower-triangular.
pub fn solve_lower_t(l: &Matrix, y: &[f64]) -> Vec<f64> {
    let n = l.rows;
    assert_eq!(y.len(), n);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = y[i];
        for k in i + 1..n {
            s -= l.at(k, i) * x[k];
        }
        x[i] = s / l.at(i, i);
    }
    x
}

/// Solve A·x = b for SPD A via Cholesky.
pub fn solve_spd(a: &Matrix, b: &[f64]) -> anyhow::Result<Vec<f64>> {
    let l = cholesky(a)?;
    Ok(solve_lower_t(&l, &solve_lower(&l, b)))
}

/// Upper-triangular `U` with `A⁻¹ = UᵀU`, computed WITHOUT forming `A⁻¹`.
///
/// This is the inverse-Hessian root OPTQ's recursion consumes (GPTQ's
/// numerics). The seed path materialized `H⁻¹` via `inv_spd` and then
/// re-factorized it — ~1.3·n³ multiply-adds; this route is ~n³/3:
///
/// 1. flip-reorder: `Ã[i,j] = A[n-1-i, n-1-j]`, factor `Ã = L̃·L̃ᵀ`;
/// 2. un-flip `L̃` → upper-triangular `U_A` with `A = U_A·U_Aᵀ`
///    (flipping a lower-triangular factor yields the UL decomposition);
/// 3. invert the triangular factor: `A⁻¹ = U_A⁻ᵀ·U_A⁻¹ = UᵀU` with
///    `U = U_A⁻¹` (back substitution, upper output).
///
/// Both routes produce the unique positive-diagonal factor, so they agree
/// to floating-point tolerance (see tests). Errors if `A` is not SPD.
pub fn chol_inv_upper(a: &Matrix) -> anyhow::Result<Matrix> {
    assert_eq!(a.rows, a.cols, "chol_inv_upper needs square");
    let n = a.rows;
    let flipped = Matrix::from_fn(n, n, |i, j| a.at(n - 1 - i, n - 1 - j));
    let lt = cholesky(&flipped)?;
    let ua = Matrix::from_fn(n, n, |i, j| lt.at(n - 1 - i, n - 1 - j));
    // Column-wise back substitution: U_A · U[:, j] = e_j, exploiting that
    // column j of the inverse has no entries below row j.
    let mut u = Matrix::zeros(n, n);
    for j in 0..n {
        u.set(j, j, 1.0 / ua.at(j, j));
        for i in (0..j).rev() {
            let mut s = 0.0;
            for k in i + 1..=j {
                s -= ua.at(i, k) * u.at(k, j);
            }
            u.set(i, j, s / ua.at(i, i));
        }
    }
    Ok(u)
}

/// Inverse of SPD A via Cholesky (column-by-column solves).
pub fn inv_spd(a: &Matrix) -> anyhow::Result<Matrix> {
    let n = a.rows;
    let l = cholesky(a)?;
    let mut inv = Matrix::zeros(n, n);
    let mut e = vec![0.0; n];
    for j in 0..n {
        e[j] = 1.0;
        let col = solve_lower_t(&l, &solve_lower(&l, &e));
        inv.set_col(j, &col);
        e[j] = 0.0;
    }
    Ok(inv)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{matmul, matmul_nt, syrk_t};
    use crate::util::prng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        let x = Matrix::randn(n + 8, n, 1.0, rng);
        let mut h = syrk_t(&x);
        h.add_diag(0.1);
        h
    }

    #[test]
    fn reconstructs() {
        let mut rng = Rng::new(8);
        for &n in &[1, 2, 5, 17, 48] {
            let a = random_spd(n, &mut rng);
            let l = cholesky(&a).unwrap();
            let llt = matmul_nt(&l, &l);
            assert!(a.max_diff(&llt) < 1e-8, "n={n}");
            // L is lower triangular.
            for i in 0..n {
                for j in i + 1..n {
                    assert_eq!(l.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]); // eigenvalues 3, -1
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn damped_recovers_singular() {
        // Rank-1 PSD matrix: plain cholesky fails, damped succeeds.
        let v = Matrix::from_vec(1, 4, vec![1.0, 2.0, 3.0, 4.0]);
        let a = syrk_t(&v);
        assert!(cholesky(&a).is_err());
        let (l, lambda) = cholesky_damped(&a, 0.01);
        assert!(lambda > 0.0);
        let mut target = a.clone();
        target.add_diag(lambda);
        assert!(target.max_diff(&matmul_nt(&l, &l)) < 1e-8);
    }

    #[test]
    fn solve_spd_matches_direct() {
        let mut rng = Rng::new(9);
        let a = random_spd(12, &mut rng);
        let x_true = rng.gauss_vec(12);
        let b = crate::linalg::blas::matvec(&a, &x_true);
        let x = solve_spd(&a, &b).unwrap();
        for (u, v) in x.iter().zip(&x_true) {
            assert!((u - v).abs() < 1e-7);
        }
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Rng::new(10);
        let a = random_spd(10, &mut rng);
        let inv = inv_spd(&a).unwrap();
        assert!(matmul(&a, &inv).max_diff(&Matrix::eye(10)) < 1e-7);
    }

    #[test]
    fn chol_inv_upper_matches_seed_route() {
        // The fast route must agree with inv_spd + cholesky (both compute
        // the unique positive-diagonal U with A⁻¹ = UᵀU).
        let mut rng = Rng::new(11);
        for &n in &[1usize, 2, 7, 24, 48] {
            let a = random_spd(n, &mut rng);
            let fast = chol_inv_upper(&a).unwrap();
            let seed = cholesky(&inv_spd(&a).unwrap()).unwrap().transpose();
            assert!(
                fast.max_diff(&seed) < 1e-7 * fast.max_abs().max(1.0),
                "n={n}: {}",
                fast.max_diff(&seed)
            );
            // U is upper triangular with positive diagonal.
            for i in 0..n {
                assert!(fast.at(i, i) > 0.0);
                for j in 0..i {
                    assert_eq!(fast.at(i, j), 0.0, "lower entry ({i},{j}) nonzero");
                }
            }
            // UᵀU · A == I.
            let utu = matmul(&fast.transpose(), &fast);
            assert!(matmul(&utu, &a).max_diff(&Matrix::eye(n)) < 1e-6, "n={n}");
        }
    }

    #[test]
    fn chol_inv_upper_rejects_indefinite() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]);
        assert!(chol_inv_upper(&a).is_err());
    }
}

//! Dense row-major `f64` matrix — the storage type for all calibration and
//! initialization math (CLoQ/OPTQ run in f64; model execution runs in f32 on
//! the PJRT side).

use crate::util::prng::Rng;

#[derive(Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let rmax = self.rows.min(6);
        let cmax = self.cols.min(8);
        for i in 0..rmax {
            write!(f, "  ")?;
            for j in 0..cmax {
                write!(f, "{:>10.4} ", self.at(i, j))?;
            }
            writeln!(f, "{}", if cmax < self.cols { "…" } else { "" })?;
        }
        if rmax < self.rows {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Matrix {
    // ---- constructors ----

    pub fn zeros(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Matrix {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Matrix {
        assert_eq!(data.len(), rows * cols, "from_vec shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Matrix {
        let mut data = Vec::with_capacity(rows * cols);
        for i in 0..rows {
            for j in 0..cols {
                data.push(f(i, j));
            }
        }
        Matrix { rows, cols, data }
    }

    /// i.i.d. N(0, std²) entries.
    pub fn randn(rows: usize, cols: usize, std: f64, rng: &mut Rng) -> Matrix {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.normal(0.0, std)).collect(),
        }
    }

    /// Uniform in [lo, hi).
    pub fn rand_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Rng) -> Matrix {
        Matrix {
            rows,
            cols,
            data: (0..rows * cols).map(|_| rng.range_f64(lo, hi)).collect(),
        }
    }

    pub fn diag(d: &[f64]) -> Matrix {
        let n = d.len();
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = d[i];
        }
        m
    }

    // ---- element access ----

    #[inline(always)]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn at_mut(&mut self, i: usize, j: usize) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i * self.cols + j] = v;
    }

    #[inline(always)]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline(always)]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn col(&self, j: usize) -> Vec<f64> {
        (0..self.rows).map(|i| self.at(i, j)).collect()
    }

    pub fn set_col(&mut self, j: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for i in 0..self.rows {
            self.set(i, j, v[i]);
        }
    }

    pub fn diag_vec(&self) -> Vec<f64> {
        (0..self.rows.min(self.cols)).map(|i| self.at(i, i)).collect()
    }

    pub fn trace(&self) -> f64 {
        self.diag_vec().iter().sum()
    }

    // ---- shape ops ----

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        // Blocked transpose for cache friendliness on larger matrices.
        const B: usize = 32;
        for ib in (0..self.rows).step_by(B) {
            for jb in (0..self.cols).step_by(B) {
                for i in ib..(ib + B).min(self.rows) {
                    for j in jb..(jb + B).min(self.cols) {
                        out.data[j * self.rows + i] = self.data[i * self.cols + j];
                    }
                }
            }
        }
        out
    }

    /// Copy of the leading `r` columns.
    pub fn cols_front(&self, r: usize) -> Matrix {
        assert!(r <= self.cols);
        Matrix::from_fn(self.rows, r, |i, j| self.at(i, j))
    }

    /// Copy of a row range [r0, r1).
    pub fn rows_range(&self, r0: usize, r1: usize) -> Matrix {
        assert!(r0 <= r1 && r1 <= self.rows);
        Matrix {
            rows: r1 - r0,
            cols: self.cols,
            data: self.data[r0 * self.cols..r1 * self.cols].to_vec(),
        }
    }

    /// Stack `other` below `self`.
    pub fn vstack(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.cols);
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Matrix { rows: self.rows + other.rows, cols: self.cols, data }
    }

    // ---- elementwise ----

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|a| a * s).collect(),
        }
    }

    pub fn add_assign(&mut self, other: &Matrix) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Add `v` to the diagonal in place (the paper's λ-damping of H).
    pub fn add_diag(&mut self, v: f64) {
        let n = self.rows.min(self.cols);
        for i in 0..n {
            self.data[i * self.cols + i] += v;
        }
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    // ---- conversions ----

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Matrix {
        assert_eq!(data.len(), rows * cols);
        Matrix {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }

    /// Max |a-b| against another matrix — used everywhere in tests.
    pub fn max_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f64, |m, (a, b)| m.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_access() {
        let m = Matrix::from_fn(3, 4, |i, j| (i * 10 + j) as f64);
        assert_eq!(m.at(2, 3), 23.0);
        assert_eq!(m.row(1), &[10.0, 11.0, 12.0, 13.0]);
        assert_eq!(m.col(2), vec![2.0, 12.0, 22.0]);
        assert_eq!(Matrix::eye(3).trace(), 3.0);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let m = Matrix::randn(7, 13, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().at(5, 3), m.at(3, 5));
    }

    #[test]
    fn elementwise_algebra() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![5.0, 6.0, 7.0, 8.0]);
        assert_eq!(a.add(&b).sub(&b), a);
        assert_eq!(a.scale(2.0).at(1, 1), 8.0);
        let mut c = a.clone();
        c.add_diag(10.0);
        assert_eq!(c.at(0, 0), 11.0);
        assert_eq!(c.at(0, 1), 2.0);
    }

    #[test]
    fn slicing() {
        let m = Matrix::from_fn(4, 3, |i, j| (i * 3 + j) as f64);
        let top = m.rows_range(0, 2);
        assert_eq!(top.rows, 2);
        assert_eq!(top.at(1, 2), 5.0);
        let front = m.cols_front(2);
        assert_eq!(front.cols, 2);
        assert_eq!(front.at(3, 1), 10.0);
        let st = top.vstack(&m.rows_range(2, 4));
        assert_eq!(st, m);
    }

    #[test]
    fn f32_roundtrip() {
        let mut rng = Rng::new(2);
        let m = Matrix::randn(5, 5, 1.0, &mut rng);
        let back = Matrix::from_f32(5, 5, &m.to_f32());
        assert!(m.max_diff(&back) < 1e-6);
    }
}

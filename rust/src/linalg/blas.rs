//! Dense kernels: blocked/tiled GEMM, GEMV, SYRK, and the OPTQ lazy-batch
//! panel update.
//!
//! These are the L3 hot loops (OPTQ is O(m²n) per layer; CLoQ's R·ΔW is a
//! full GEMM; calibration accumulates Gram matrices). Each product comes in
//! two forms behind one public entry point:
//!
//! * a **small-size path** — the simple k-blocked loop, lowest overhead for
//!   the ≤64³ shapes that dominate unit tests and tiny layers;
//! * a **cache-tiled path** — i/k/j tiling sized so the active C tile and
//!   B panel stay resident in L1/L2 while streaming the large operand,
//!   which is what keeps 256–1024-wide layers from going memory-bound.
//!
//! The public `matmul` / `matmul_tn` / `matmul_nt` / `syrk_t` dispatch on
//! problem size; `matmul_naive` is the textbook reference the property
//! tests compare against.
//!
//! **Determinism contract** (load-bearing for the OPTQ parity suite and the
//! cross-language golden tests): every kernel accumulates each output
//! element in ascending-k order with one rounding per multiply-add, so the
//! naive, small, and tiled paths produce BIT-IDENTICAL results — tiling
//! changes traversal order, never the per-element floating-point op
//! sequence.

use super::matrix::Matrix;

/// Flop count (m·k·n) above which the tiled paths take over. 64³ keeps the
/// dispatch trivially cheap and below any shape where tiling matters.
const TILE_THRESHOLD_FLOPS: usize = 1 << 18;

/// i-tile: rows of C/A kept hot per pass.
const MC: usize = 64;
/// k-tile: depth of the B panel held in cache.
const KC: usize = 256;
/// j-tile: width of the C/B panel (KC×NC f64 panel ≈ 1 MiB, L2-sized).
const NC: usize = 512;

/// y += a·x over contiguous slices, 4-way unrolled. Each `y[j]` gets one
/// rounding per call — the accumulation-order building block shared by all
/// kernel variants (public because the serve fused kernel's batched path
/// leans on the exact same per-element op sequence for its parity
/// contract — see `serve::packed`).
#[inline]
pub fn axpy(y: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    let n = x.len();
    let n4 = n / 4 * 4;
    let mut j = 0;
    while j < n4 {
        y[j] += a * x[j];
        y[j + 1] += a * x[j + 1];
        y[j + 2] += a * x[j + 2];
        y[j + 3] += a * x[j + 3];
        j += 4;
    }
    while j < n {
        y[j] += a * x[j];
        j += 1;
    }
}

/// y -= a·x over contiguous slices (the subtractive twin, used by the OPTQ
/// error spread).
#[inline]
pub(crate) fn axpy_sub(y: &mut [f64], a: f64, x: &[f64]) {
    debug_assert_eq!(y.len(), x.len());
    let n = x.len();
    let n4 = n / 4 * 4;
    let mut j = 0;
    while j < n4 {
        y[j] -= a * x[j];
        y[j + 1] -= a * x[j + 1];
        y[j + 2] -= a * x[j + 2];
        y[j + 3] -= a * x[j + 3];
        j += 4;
    }
    while j < n {
        y[j] -= a * x[j];
        j += 1;
    }
}

/// C = A · B (size-dispatched).
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul inner dim {} vs {}", a.cols, b.rows);
    if a.rows * a.cols * b.cols <= TILE_THRESHOLD_FLOPS {
        matmul_small(a, b)
    } else {
        matmul_tiled(a, b)
    }
}

/// Textbook i-j-k GEMM — the reference implementation for property tests
/// and the tiled-vs-naive benchmarks. Strided B access: slow on purpose.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul inner dim {} vs {}", a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0;
            for kk in 0..k {
                s += a.data[i * k + kk] * b.data[kk * n + j];
            }
            c.data[i * n + j] = s;
        }
    }
    c
}

/// Small-size GEMM: k-blocking only, i-k-j loop order over packed row-major
/// storage so the inner loop is a contiguous fused multiply-add over the
/// output row.
fn matmul_small(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    const KB: usize = 64;
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                axpy(crow, aik, &b.data[kk * n..(kk + 1) * n]);
            }
        }
    }
    c
}

/// Cache-tiled GEMM: j-tiles (NC) bound the active C/B panel width, k-tiles
/// (KC) keep a B panel L2-resident, i-tiles (MC) keep the C tile hot while
/// it accumulates. Per-element accumulation order is still ascending k, so
/// the result is bit-identical to [`matmul_naive`].
pub fn matmul_tiled(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul inner dim {} vs {}", a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for jb in (0..n).step_by(NC) {
        let jend = (jb + NC).min(n);
        for kb in (0..k).step_by(KC) {
            let kend = (kb + KC).min(k);
            for ib in (0..m).step_by(MC) {
                let iend = (ib + MC).min(m);
                for i in ib..iend {
                    let arow = &a.data[i * k..(i + 1) * k];
                    let crow = &mut c.data[i * n + jb..i * n + jend];
                    for kk in kb..kend {
                        let aik = arow[kk];
                        if aik == 0.0 {
                            continue;
                        }
                        axpy(crow, aik, &b.data[kk * n + jb..kk * n + jend]);
                    }
                }
            }
        }
    }
    c
}

/// C = Aᵀ · B without materializing Aᵀ (size-dispatched).
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn inner dim");
    if a.rows * a.cols * b.cols <= TILE_THRESHOLD_FLOPS {
        matmul_tn_small(a, b)
    } else {
        matmul_tn_tiled(a, b)
    }
}

fn matmul_tn_small(a: &Matrix, b: &Matrix) -> Matrix {
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for kk in 0..k {
        let arow = &a.data[kk * m..(kk + 1) * m];
        let brow = &b.data[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = arow[i];
            if aik == 0.0 {
                continue;
            }
            axpy(&mut c.data[i * n..(i + 1) * n], aik, brow);
        }
    }
    c
}

/// Tiled Aᵀ·B: i-tiles keep an MC×n stripe of C hot across the full k
/// sweep instead of re-streaming all of C once per k step.
pub fn matmul_tn_tiled(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn inner dim");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for ib in (0..m).step_by(MC) {
        let iend = (ib + MC).min(m);
        for kk in 0..k {
            let arow = &a.data[kk * m..(kk + 1) * m];
            let brow = &b.data[kk * n..(kk + 1) * n];
            for i in ib..iend {
                let aik = arow[i];
                if aik == 0.0 {
                    continue;
                }
                axpy(&mut c.data[i * n..(i + 1) * n], aik, brow);
            }
        }
    }
    c
}

/// C = A · Bᵀ without materializing Bᵀ (size-dispatched; inner loops are
/// two contiguous rows).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dim");
    if a.rows * a.cols * b.rows <= TILE_THRESHOLD_FLOPS {
        matmul_nt_small(a, b)
    } else {
        matmul_nt_tiled(a, b)
    }
}

fn matmul_nt_small(a: &Matrix, b: &Matrix) -> Matrix {
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            c.data[i * n + j] = dot(arow, &b.data[j * k..(j + 1) * k]);
        }
    }
    c
}

/// Tiled A·Bᵀ: j-tiles sized so the active B row panel stays L2-resident
/// while every A row streams past it once per tile.
pub fn matmul_nt_tiled(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dim");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    // B panel budget ≈ 256 KiB of f64.
    let jt = (32_768 / k.max(1)).clamp(8, n.max(8));
    for jb in (0..n).step_by(jt) {
        let jend = (jb + jt).min(n);
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            for j in jb..jend {
                c.data[i * n + j] = dot(arow, &b.data[j * k..(j + 1) * k]);
            }
        }
    }
    c
}

/// Gram matrix H = Aᵀ · A (symmetric rank-k update; only computes the upper
/// triangle then mirrors). This is the calibration hot path when
/// activations are accumulated Rust-side (size-dispatched).
pub fn syrk_t(a: &Matrix) -> Matrix {
    let (k, n) = (a.rows, a.cols);
    if n * n * k / 2 <= TILE_THRESHOLD_FLOPS {
        syrk_t_small(a)
    } else {
        syrk_t_tiled(a)
    }
}

fn syrk_t_small(a: &Matrix) -> Matrix {
    let (k, n) = (a.rows, a.cols);
    let mut h = Matrix::zeros(n, n);
    for kk in 0..k {
        let row = &a.data[kk * n..(kk + 1) * n];
        for i in 0..n {
            let ri = row[i];
            if ri == 0.0 {
                continue;
            }
            axpy(&mut h.data[i * n + i..(i + 1) * n], ri, &row[i..]);
        }
    }
    mirror_upper(&mut h);
    h
}

/// Tiled SYRK: i-tiles keep an MC-row stripe of H hot across the whole
/// sample sweep — for 512-wide layers H is ~2 MiB and the untiled form
/// re-streams all of it once per sample row.
pub fn syrk_t_tiled(a: &Matrix) -> Matrix {
    let (k, n) = (a.rows, a.cols);
    let mut h = Matrix::zeros(n, n);
    for ib in (0..n).step_by(MC) {
        let iend = (ib + MC).min(n);
        for kk in 0..k {
            let row = &a.data[kk * n..(kk + 1) * n];
            for i in ib..iend {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                axpy(&mut h.data[i * n + i..(i + 1) * n], ri, &row[i..]);
            }
        }
    }
    mirror_upper(&mut h);
    h
}

fn mirror_upper(h: &mut Matrix) {
    let n = h.rows;
    for i in 0..n {
        for j in 0..i {
            h.data[i * n + j] = h.data[j * n + i];
        }
    }
}

/// OPTQ's lazy-batch deferred error spread as one panel product:
///
/// ```text
///   c[k, :] -= Σ_{t=0..nt} a[t0+t, k] · b[t, :]     for k in row0..c.rows
/// ```
///
/// i.e. `C_tail -= A_panelᵀ · B` where the panel is rows `t0..t0+nt` of `a`
/// restricted to columns `row0..`. Each trailing row of `c` is touched
/// ONCE per block instead of once per quantized row — the memory-traffic
/// win behind blocked OPTQ. `t` runs in ascending order per element, so
/// the result is bit-identical to applying the `nt` rank-1 updates
/// row-by-row (the parity suite relies on this).
pub fn sub_matmul_tn_tail(
    c: &mut Matrix,
    row0: usize,
    a: &Matrix,
    t0: usize,
    nt: usize,
    b: &Matrix,
) {
    assert_eq!(a.cols, c.rows, "panel column space must index c's rows");
    assert_eq!(b.cols, c.cols, "update width mismatch");
    assert!(t0 + nt <= a.rows && nt <= b.rows, "panel rows out of range");
    let n = c.cols;
    for k in row0..c.rows {
        let crow = &mut c.data[k * n..(k + 1) * n];
        for t in 0..nt {
            let utk = a.data[(t0 + t) * a.cols + k];
            if utk == 0.0 {
                continue;
            }
            axpy_sub(crow, utk, &b.data[t * n..(t + 1) * n]);
        }
    }
}

/// y = A · x.
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    (0..a.rows).map(|i| dot(a.row(i), x)).collect()
}

/// y = Aᵀ · x.
pub fn matvec_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        for (yj, aij) in y.iter_mut().zip(a.row(i)) {
            *yj += xi * aij;
        }
    }
    y
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulators: better ILP and slightly better numerics.
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let n4 = a.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = s0 + s1 + s2 + s3;
    while i < a.len() {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 128, 32)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.max_diff(&matmul_naive(&a, &b)) < 1e-10);
        }
    }

    #[test]
    fn tiled_paths_bit_identical_to_naive() {
        // The determinism contract: tiling must not change per-element
        // accumulation order. Shapes straddle every tile boundary.
        let mut rng = Rng::new(13);
        for &(m, k, n) in
            &[(63, 65, 64), (65, 257, 31), (64, 256, 512), (66, 258, 514), (2, 300, 5)]
        {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let naive = matmul_naive(&a, &b);
            assert_eq!(matmul_tiled(&a, &b).data, naive.data, "{m}x{k}x{n}");
            assert_eq!(matmul(&a, &b).data, naive.data, "{m}x{k}x{n} dispatch");
        }
    }

    #[test]
    fn transposed_variants_match() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let b = Matrix::randn(20, 15, 1.0, &mut rng);
        assert!(matmul_tn(&a, &b).max_diff(&matmul(&a.transpose(), &b)) < 1e-10);
        let b2 = Matrix::randn(9, 12, 1.0, &mut rng);
        assert!(matmul_nt(&a, &b2).max_diff(&matmul(&a, &b2.transpose())) < 1e-10);
    }

    #[test]
    fn transposed_tiled_variants_match_small() {
        let mut rng = Rng::new(14);
        // Big enough that the tiled code paths differ from the small ones.
        let a = Matrix::randn(300, 70, 1.0, &mut rng);
        let b = Matrix::randn(300, 90, 1.0, &mut rng);
        assert_eq!(matmul_tn_tiled(&a, &b).data, matmul_tn_small(&a, &b).data);
        let c = Matrix::randn(80, 70, 1.0, &mut rng);
        assert_eq!(matmul_nt_tiled(&a, &c).data, matmul_nt_small(&a, &c).data);
    }

    #[test]
    fn syrk_is_gram() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(40, 16, 1.0, &mut rng);
        let h = syrk_t(&a);
        assert!(h.max_diff(&matmul(&a.transpose(), &a)) < 1e-9);
        // Symmetry.
        assert!(h.max_diff(&h.transpose()) < 1e-12);
    }

    #[test]
    fn syrk_tiled_bit_identical() {
        let mut rng = Rng::new(15);
        for &(k, n) in &[(10, 65), (33, 130), (200, 96)] {
            let a = Matrix::randn(k, n, 1.0, &mut rng);
            assert_eq!(syrk_t_tiled(&a).data, syrk_t_small(&a).data, "{k}x{n}");
        }
    }

    #[test]
    fn panel_update_matches_rank1_sequence() {
        // sub_matmul_tn_tail == applying each rank-1 update row-by-row, to
        // the bit (OPTQ's blocked/unblocked parity rests on this).
        let mut rng = Rng::new(16);
        let (m, n, t0, nt, row0) = (23, 9, 4, 6, 10);
        let u = Matrix::randn(m, m, 1.0, &mut rng);
        let errs = Matrix::randn(nt, n, 1.0, &mut rng);
        let w0 = Matrix::randn(m, n, 1.0, &mut rng);

        let mut seq = w0.clone();
        for t in 0..nt {
            for k in row0..m {
                let utk = u.at(t0 + t, k);
                if utk == 0.0 {
                    continue;
                }
                // Same per-element op order: t ascending for each (k, j).
                for j in 0..n {
                    *seq.at_mut(k, j) -= utk * errs.at(t, j);
                }
            }
        }

        let mut got = w0.clone();
        sub_matmul_tn_tail(&mut got, row0, &u, t0, nt, &errs);
        assert_eq!(got.data, seq.data);
        // Rows before row0 untouched.
        for k in 0..row0 {
            assert_eq!(got.row(k), w0.row(k));
        }
    }

    #[test]
    fn degenerate_shapes_ok() {
        let a = Matrix::zeros(0, 5);
        let b = Matrix::zeros(5, 3);
        assert_eq!(matmul(&a, &b).rows, 0);
        let a = Matrix::zeros(4, 0);
        let b = Matrix::zeros(0, 3);
        let c = matmul(&a, &b);
        assert_eq!((c.rows, c.cols), (4, 3));
        assert!(c.max_abs() == 0.0);
        assert_eq!(matmul_naive(&a, &b).data, c.data);
        let e = Matrix::zeros(0, 4);
        assert_eq!(syrk_t(&e).rows, 4);
    }

    #[test]
    fn matvec_variants() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(8, 5, 1.0, &mut rng);
        let x5 = rng.gauss_vec(5);
        let x8 = rng.gauss_vec(8);
        let y = matvec(&a, &x5);
        let ynaive: Vec<f64> = (0..8).map(|i| dot(a.row(i), &x5)).collect();
        assert_eq!(y, ynaive);
        let yt = matvec_t(&a, &x8);
        let ytn = matvec(&a.transpose(), &x8);
        for (u, v) in yt.iter().zip(&ytn) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(10, 10, 1.0, &mut rng);
        assert!(matmul(&a, &Matrix::eye(10)).max_diff(&a) < 1e-12);
        assert!(matmul(&Matrix::eye(10), &a).max_diff(&a) < 1e-12);
    }
}

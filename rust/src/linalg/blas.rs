//! Dense kernels: blocked GEMM, GEMV, SYRK.
//!
//! These are the L3 hot loops (OPTQ is O(m²n) per layer; CLoQ's R·ΔW is a
//! full GEMM). The GEMM uses i-k-j loop order over a packed row-major layout
//! so the inner loop is a contiguous fused multiply-add over the output row —
//! the standard cache-friendly form for row-major storage — plus k-blocking
//! to keep the B panel resident in L1/L2.

use super::matrix::Matrix;

/// C = A · B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows, "matmul inner dim {} vs {}", a.cols, b.rows);
    let (m, k, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    // k-blocking: keep a KB×n slab of B hot.
    const KB: usize = 64;
    for kb in (0..k).step_by(KB) {
        let kend = (kb + KB).min(k);
        for i in 0..m {
            let arow = &a.data[i * k..(i + 1) * k];
            let crow = &mut c.data[i * n..(i + 1) * n];
            for kk in kb..kend {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue;
                }
                let brow = &b.data[kk * n..(kk + 1) * n];
                // Contiguous FMA over the output row; unrolled by 4 to help
                // the scalar backend (1-core sandbox, no explicit SIMD).
                let mut j = 0;
                while j + 4 <= n {
                    crow[j] += aik * brow[j];
                    crow[j + 1] += aik * brow[j + 1];
                    crow[j + 2] += aik * brow[j + 2];
                    crow[j + 3] += aik * brow[j + 3];
                    j += 4;
                }
                while j < n {
                    crow[j] += aik * brow[j];
                    j += 1;
                }
            }
        }
    }
    c
}

/// C = Aᵀ · B without materializing Aᵀ.
pub fn matmul_tn(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.rows, b.rows, "matmul_tn inner dim");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut c = Matrix::zeros(m, n);
    for kk in 0..k {
        let arow = &a.data[kk * m..(kk + 1) * m];
        let brow = &b.data[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aik = arow[i];
            if aik == 0.0 {
                continue;
            }
            let crow = &mut c.data[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += aik * brow[j];
            }
        }
    }
    c
}

/// C = A · Bᵀ without materializing Bᵀ (inner loops are two contiguous rows).
pub fn matmul_nt(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.cols, "matmul_nt inner dim");
    let (m, k, n) = (a.rows, a.cols, b.rows);
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        let arow = &a.data[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b.data[j * k..(j + 1) * k];
            c.data[i * n + j] = dot(arow, brow);
        }
    }
    c
}

/// Gram matrix H = Aᵀ · A (symmetric rank-k update; only computes the upper
/// triangle then mirrors). This is the calibration hot path when activations
/// are accumulated Rust-side.
pub fn syrk_t(a: &Matrix) -> Matrix {
    let (k, n) = (a.rows, a.cols);
    let mut h = Matrix::zeros(n, n);
    for kk in 0..k {
        let row = &a.data[kk * n..(kk + 1) * n];
        for i in 0..n {
            let ri = row[i];
            if ri == 0.0 {
                continue;
            }
            let hrow = &mut h.data[i * n..(i + 1) * n];
            for j in i..n {
                hrow[j] += ri * row[j];
            }
        }
    }
    // Mirror upper → lower.
    for i in 0..n {
        for j in 0..i {
            h.data[i * n + j] = h.data[j * n + i];
        }
    }
    h
}

/// y = A · x.
pub fn matvec(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.cols, x.len());
    (0..a.rows).map(|i| dot(a.row(i), x)).collect()
}

/// y = Aᵀ · x.
pub fn matvec_t(a: &Matrix, x: &[f64]) -> Vec<f64> {
    assert_eq!(a.rows, x.len());
    let mut y = vec![0.0; a.cols];
    for i in 0..a.rows {
        let xi = x[i];
        if xi == 0.0 {
            continue;
        }
        for (yj, aij) in y.iter_mut().zip(a.row(i)) {
            *yj += xi * aij;
        }
    }
    y
}

#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    // 4-way unrolled accumulators: better ILP and slightly better numerics.
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let n4 = a.len() / 4 * 4;
    let mut i = 0;
    while i < n4 {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = s0 + s1 + s2 + s3;
    while i < a.len() {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn naive_matmul(a: &Matrix, b: &Matrix) -> Matrix {
        let mut c = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut s = 0.0;
                for k in 0..a.cols {
                    s += a.at(i, k) * b.at(k, j);
                }
                c.set(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(3);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 2), (17, 33, 9), (64, 128, 32)] {
            let a = Matrix::randn(m, k, 1.0, &mut rng);
            let b = Matrix::randn(k, n, 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.max_diff(&naive_matmul(&a, &b)) < 1e-10);
        }
    }

    #[test]
    fn transposed_variants_match() {
        let mut rng = Rng::new(4);
        let a = Matrix::randn(20, 12, 1.0, &mut rng);
        let b = Matrix::randn(20, 15, 1.0, &mut rng);
        assert!(matmul_tn(&a, &b).max_diff(&matmul(&a.transpose(), &b)) < 1e-10);
        let b2 = Matrix::randn(9, 12, 1.0, &mut rng);
        assert!(matmul_nt(&a, &b2).max_diff(&matmul(&a, &b2.transpose())) < 1e-10);
    }

    #[test]
    fn syrk_is_gram() {
        let mut rng = Rng::new(5);
        let a = Matrix::randn(40, 16, 1.0, &mut rng);
        let h = syrk_t(&a);
        assert!(h.max_diff(&matmul(&a.transpose(), &a)) < 1e-9);
        // Symmetry.
        assert!(h.max_diff(&h.transpose()) < 1e-12);
    }

    #[test]
    fn matvec_variants() {
        let mut rng = Rng::new(6);
        let a = Matrix::randn(8, 5, 1.0, &mut rng);
        let x5 = rng.gauss_vec(5);
        let x8 = rng.gauss_vec(8);
        let y = matvec(&a, &x5);
        let ynaive: Vec<f64> = (0..8).map(|i| dot(a.row(i), &x5)).collect();
        assert_eq!(y, ynaive);
        let yt = matvec_t(&a, &x8);
        let ytn = matvec(&a.transpose(), &x8);
        for (u, v) in yt.iter().zip(&ytn) {
            assert!((u - v).abs() < 1e-12);
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(7);
        let a = Matrix::randn(10, 10, 1.0, &mut rng);
        assert!(matmul(&a, &Matrix::eye(10)).max_diff(&a) < 1e-12);
        assert!(matmul(&Matrix::eye(10), &a).max_diff(&a) < 1e-12);
    }
}

//! Householder QR decomposition.
//!
//! Used for orthonormal basis generation (random orthogonal test fixtures,
//! subspace comparisons) and as an independent cross-check of the SVD in the
//! property-test suite.

use super::matrix::Matrix;

pub struct Qr {
    /// m×n with orthonormal columns (thin Q).
    pub q: Matrix,
    /// n×n upper triangular.
    pub r: Matrix,
}

/// Thin QR for m ≥ n via Householder reflections.
pub fn qr(a: &Matrix) -> Qr {
    let (m, n) = (a.rows, a.cols);
    assert!(m >= n, "qr expects tall/square input");
    let mut r = a.clone();
    // Store the reflectors to build thin Q afterwards.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(n);

    for k in 0..n {
        // Householder vector for column k below the diagonal.
        let mut v: Vec<f64> = (k..m).map(|i| r.at(i, k)).collect();
        let alpha = -v[0].signum() * v.iter().map(|x| x * x).sum::<f64>().sqrt();
        if alpha.abs() < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            vs.push(vec![0.0; m - k]);
            continue;
        }
        // Apply H = I − 2vvᵀ/‖v‖² to R[k.., k..].
        for j in k..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * r.at(i, j);
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                *r.at_mut(i, j) -= f * v[i - k];
            }
        }
        vs.push(v);
    }

    // Build thin Q by applying reflectors to the first n identity columns,
    // in reverse order.
    let mut q = Matrix::from_fn(m, n, |i, j| if i == j { 1.0 } else { 0.0 });
    for k in (0..n).rev() {
        let v = &vs[k];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 < 1e-300 {
            continue;
        }
        for j in 0..n {
            let mut dot = 0.0;
            for i in k..m {
                dot += v[i - k] * q.at(i, j);
            }
            let f = 2.0 * dot / vnorm2;
            for i in k..m {
                *q.at_mut(i, j) -= f * v[i - k];
            }
        }
    }

    // Zero the strictly-lower part of R (numerically it already is).
    let mut r_thin = Matrix::zeros(n, n);
    for i in 0..n {
        for j in i..n {
            r_thin.set(i, j, r.at(i, j));
        }
    }
    Qr { q, r: r_thin }
}

/// Random matrix with orthonormal columns (Haar-ish via QR of a Gaussian).
pub fn random_orthonormal(m: usize, n: usize, rng: &mut crate::util::prng::Rng) -> Matrix {
    let g = Matrix::randn(m, n, 1.0, rng);
    qr(&g).q
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::matmul;
    use crate::util::prng::Rng;

    #[test]
    fn qr_reconstructs_and_q_orthonormal() {
        let mut rng = Rng::new(23);
        for &(m, n) in &[(4, 4), (10, 6), (50, 12), (3, 1)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let d = qr(&a);
            assert!(a.max_diff(&matmul(&d.q, &d.r)) < 1e-9, "({m},{n})");
            let qtq = matmul(&d.q.transpose(), &d.q);
            assert!(qtq.max_diff(&Matrix::eye(n)) < 1e-9);
            // R upper triangular.
            for i in 0..n {
                for j in 0..i {
                    assert_eq!(d.r.at(i, j), 0.0);
                }
            }
        }
    }

    #[test]
    fn random_orthonormal_is_orthonormal() {
        let mut rng = Rng::new(24);
        let q = random_orthonormal(20, 7, &mut rng);
        let qtq = matmul(&q.transpose(), &q);
        assert!(qtq.max_diff(&Matrix::eye(7)) < 1e-10);
    }
}

//! Symmetric eigendecomposition via the cyclic Jacobi method.
//!
//! CLoQ needs the SVD of the Gram matrix `H = XᵀX` (symmetric PSD), i.e. its
//! eigendecomposition `H = U_H Σ_H U_Hᵀ`. Jacobi is simple, numerically
//! excellent for the moderate sizes a layer's input dimension takes here
//! (≤ ~1024), and embarrassingly verifiable.

use super::matrix::Matrix;

/// Result of `sym_eig`: eigenvalues in descending order, with matching
/// eigenvector columns (`vectors.col(i)` ↔ `values[i]`).
pub struct SymEig {
    pub values: Vec<f64>,
    /// n×n orthogonal matrix; column i is the i-th eigenvector.
    pub vectors: Matrix,
}

/// Cyclic Jacobi with threshold sweeps. `a` must be symmetric.
pub fn sym_eig(a: &Matrix) -> SymEig {
    assert_eq!(a.rows, a.cols, "sym_eig needs square");
    let n = a.rows;
    let mut m = a.clone();
    let mut v = Matrix::eye(n);
    if n <= 1 {
        return sorted(m.diag_vec(), v);
    }

    // Convergence scale: off(A) relative to ||A||_F.
    let fro: f64 = a.data.iter().map(|x| x * x).sum::<f64>().sqrt();
    let tol = 1e-14 * fro.max(1e-300);
    const MAX_SWEEPS: usize = 60;

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n - 1 {
            for q in p + 1..n {
                off = off.max(m.at(p, q).abs());
            }
        }
        if off <= tol {
            break;
        }
        for p in 0..n - 1 {
            for q in p + 1..n {
                let apq = m.at(p, q);
                if apq.abs() <= tol * 1e-2 {
                    continue;
                }
                let app = m.at(p, p);
                let aqq = m.at(q, q);
                // Stable rotation computation (Golub & Van Loan §8.5).
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;

                // Update rows/cols p and q of m: m ← Jᵀ m J.
                for k in 0..n {
                    let mkp = m.at(k, p);
                    let mkq = m.at(k, q);
                    m.set(k, p, c * mkp - s * mkq);
                    m.set(k, q, s * mkp + c * mkq);
                }
                for k in 0..n {
                    let mpk = m.at(p, k);
                    let mqk = m.at(q, k);
                    m.set(p, k, c * mpk - s * mqk);
                    m.set(q, k, s * mpk + c * mqk);
                }
                // Accumulate eigenvectors: v ← v J.
                for k in 0..n {
                    let vkp = v.at(k, p);
                    let vkq = v.at(k, q);
                    v.set(k, p, c * vkp - s * vkq);
                    v.set(k, q, s * vkp + c * vkq);
                }
            }
        }
    }
    sorted(m.diag_vec(), v)
}

fn sorted(values: Vec<f64>, vectors: Matrix) -> SymEig {
    let n = values.len();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| values[j].partial_cmp(&values[i]).unwrap());
    let sorted_vals: Vec<f64> = order.iter().map(|&i| values[i]).collect();
    let mut sorted_vecs = Matrix::zeros(n, n);
    for (new_j, &old_j) in order.iter().enumerate() {
        for i in 0..n {
            sorted_vecs.set(i, new_j, vectors.at(i, old_j));
        }
    }
    SymEig { values: sorted_vals, vectors: sorted_vecs }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::{matmul, matmul_nt, syrk_t};
    use crate::util::prng::Rng;

    fn check_decomposition(a: &Matrix, e: &SymEig, tol: f64) {
        let n = a.rows;
        // A·V = V·Λ
        let av = matmul(a, &e.vectors);
        let vl = matmul(&e.vectors, &Matrix::diag(&e.values));
        assert!(av.max_diff(&vl) < tol, "A·V != V·Λ: {}", av.max_diff(&vl));
        // Vᵀ·V = I
        let vtv = matmul(&e.vectors.transpose(), &e.vectors);
        assert!(vtv.max_diff(&Matrix::eye(n)) < tol);
        // Descending order
        for w in e.values.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
    }

    #[test]
    fn diagonal_matrix() {
        let a = Matrix::diag(&[3.0, 1.0, 2.0]);
        let e = sym_eig(&a);
        assert_eq!(e.values, vec![3.0, 2.0, 1.0]);
        check_decomposition(&a, &e, 1e-12);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let a = Matrix::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let e = sym_eig(&a);
        assert!((e.values[0] - 3.0).abs() < 1e-12);
        assert!((e.values[1] - 1.0).abs() < 1e-12);
        check_decomposition(&a, &e, 1e-12);
    }

    #[test]
    fn random_gram_matrices() {
        let mut rng = Rng::new(11);
        for &n in &[2, 7, 24, 64] {
            let x = Matrix::randn(n + 16, n, 1.0, &mut rng);
            let h = syrk_t(&x);
            let e = sym_eig(&h);
            check_decomposition(&h, &e, 1e-7 * (n as f64));
            // PSD: all eigenvalues >= -eps.
            assert!(e.values.iter().all(|&l| l > -1e-8));
            // trace preserved
            let tr: f64 = e.values.iter().sum();
            assert!((tr - h.trace()).abs() < 1e-7 * h.trace().abs().max(1.0));
        }
    }

    #[test]
    fn rank_deficient_gram() {
        // 5-dim features from 3 samples → rank ≤ 3.
        let mut rng = Rng::new(12);
        let x = Matrix::randn(3, 5, 1.0, &mut rng);
        let h = syrk_t(&x);
        let e = sym_eig(&h);
        check_decomposition(&h, &e, 1e-8);
        assert!(e.values[3].abs() < 1e-9);
        assert!(e.values[4].abs() < 1e-9);
    }

    #[test]
    fn reconstruction() {
        let mut rng = Rng::new(13);
        let x = Matrix::randn(40, 20, 1.0, &mut rng);
        let h = syrk_t(&x);
        let e = sym_eig(&h);
        // H = V Λ Vᵀ
        let rec = matmul_nt(&matmul(&e.vectors, &Matrix::diag(&e.values)), &e.vectors);
        assert!(h.max_diff(&rec) < 1e-7);
    }
}

//! Dense linear-algebra substrate built from scratch (DESIGN.md §4).
//!
//! Everything CLoQ's closed form needs: blocked GEMM, Cholesky, symmetric
//! Jacobi eigendecomposition (for `H = U_H Σ_H U_Hᵀ`), one-sided Jacobi SVD
//! (for `LR_r(R·ΔW)`), pseudo-inverse, and the Frobenius/spectral norms the
//! paper's Fig. 2 plots.

pub mod blas;
pub mod chol;
pub mod eig;
pub mod matrix;
pub mod norms;
pub mod qr;
pub mod rsvd;
pub mod svd;

pub use blas::{
    axpy, dot, matmul, matmul_naive, matmul_nt, matmul_nt_tiled, matmul_tiled, matmul_tn,
    matmul_tn_tiled, matvec, matvec_t, sub_matmul_tn_tail, syrk_t, syrk_t_tiled,
};
pub use matrix::Matrix;
pub use svd::{best_rank_r, pinv, svd, Svd};

//! Matrix norms: Frobenius, spectral (power iteration), and the calibrated
//! layer-discrepancy norms used by Fig. 2.

use super::blas::{matvec, matvec_t};
use super::matrix::Matrix;

/// ‖A‖_F.
pub fn fro(a: &Matrix) -> f64 {
    a.data.iter().map(|x| x * x).sum::<f64>().sqrt()
}

/// ‖A‖_F².
pub fn fro2(a: &Matrix) -> f64 {
    a.data.iter().map(|x| x * x).sum::<f64>()
}

/// Spectral norm ‖A‖₂ = σ_max via power iteration on AᵀA.
/// Deterministic start vector; converges geometrically with ratio
/// (σ₂/σ₁)² — we run to a tight relative tolerance with an iteration cap.
pub fn spectral(a: &Matrix) -> f64 {
    let n = a.cols;
    if n == 0 || a.rows == 0 {
        return 0.0;
    }
    // Deterministic pseudo-random start to avoid orthogonal-start stalls.
    let mut v: Vec<f64> = (0..n)
        .map(|i| ((i as f64 * 12.9898).sin() * 43758.5453).fract() + 0.01)
        .collect();
    normalize(&mut v);
    let mut sigma = 0.0f64;
    for _ in 0..300 {
        // w = Aᵀ(Av)
        let av = matvec(a, &v);
        let mut w = matvec_t(a, &av);
        let norm = normalize(&mut w);
        let new_sigma = norm.sqrt();
        let done = (new_sigma - sigma).abs() <= 1e-12 * new_sigma.max(1e-300);
        sigma = new_sigma;
        v = w;
        if done {
            break;
        }
    }
    sigma
}

fn normalize(v: &mut [f64]) -> f64 {
    let n = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if n > 0.0 {
        for x in v.iter_mut() {
            *x /= n;
        }
    }
    n
}

/// Calibrated discrepancy `‖X·E‖` where `E = Q + A·Bᵀ − W` — both norms the
/// paper plots in Fig. 2. Computed through the Gram matrix when only
/// `H = XᵀX` is available: ‖X·E‖_F² = Tr(Eᵀ H E); the spectral version uses
/// the non-symmetric root `R` with ‖X·E‖₂ = ‖R·E‖₂ (same singular values).
pub struct Discrepancy {
    pub frobenius: f64,
    pub spectral: f64,
}

/// Discrepancy via an explicit root R of H (so ‖X E‖ = ‖R E‖ exactly in
/// both norms). `re = R·E` should be precomputed by the caller.
pub fn discrepancy_from_re(re: &Matrix) -> Discrepancy {
    Discrepancy { frobenius: fro(re), spectral: spectral(re) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::blas::matmul;
    use crate::linalg::svd::svd;
    use crate::util::prng::Rng;

    #[test]
    fn fro_matches_definition() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 0.0, 4.0, 0.0]);
        assert!((fro(&a) - 5.0).abs() < 1e-12);
        assert!((fro2(&a) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn spectral_matches_svd() {
        let mut rng = Rng::new(20);
        for &(m, n) in &[(5, 5), (12, 8), (8, 12), (30, 30)] {
            let a = Matrix::randn(m, n, 1.0, &mut rng);
            let s_pi = spectral(&a);
            let s_svd = svd(&a).s[0];
            assert!(
                (s_pi - s_svd).abs() < 1e-6 * s_svd,
                "power-iter {s_pi} vs svd {s_svd}"
            );
        }
    }

    #[test]
    fn spectral_of_rank_one() {
        // uvᵀ has spectral norm |u||v|.
        let u = [1.0, 2.0, 2.0]; // norm 3
        let v = [3.0, 4.0]; // norm 5
        let a = Matrix::from_fn(3, 2, |i, j| u[i] * v[j]);
        assert!((spectral(&a) - 15.0).abs() < 1e-9);
    }

    #[test]
    fn norm_inequalities() {
        let mut rng = Rng::new(21);
        let a = Matrix::randn(10, 6, 1.0, &mut rng);
        let s = spectral(&a);
        let f = fro(&a);
        assert!(s <= f + 1e-9);
        assert!(f <= s * (6f64).sqrt() + 1e-9);
    }

    #[test]
    fn discrepancy_via_root_equals_direct() {
        let mut rng = Rng::new(22);
        // X: 40×8, E: 8×5. Direct ‖XE‖ vs via R = Σ^{1/2}Uᵀ of H = XᵀX.
        let x = Matrix::randn(40, 8, 1.0, &mut rng);
        let e = Matrix::randn(8, 5, 1.0, &mut rng);
        let xe = matmul(&x, &e);
        let direct = Discrepancy { frobenius: fro(&xe), spectral: spectral(&xe) };

        let h = crate::linalg::blas::syrk_t(&x);
        let eg = crate::linalg::eig::sym_eig(&h);
        let sqrt_vals: Vec<f64> = eg.values.iter().map(|&l| l.max(0.0).sqrt()).collect();
        // R = Σ^{1/2} Uᵀ (rows scaled).
        let ut = eg.vectors.transpose();
        let r = Matrix::from_fn(8, 8, |i, j| sqrt_vals[i] * ut.at(i, j));
        let re = matmul(&r, &e);
        let via_root = discrepancy_from_re(&re);
        assert!((direct.frobenius - via_root.frobenius).abs() < 1e-8 * direct.frobenius);
        assert!((direct.spectral - via_root.spectral).abs() < 1e-6 * direct.spectral);
    }
}

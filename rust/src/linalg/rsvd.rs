//! Randomized truncated SVD (Halko–Martinsson–Tropp) — the §Perf
//! optimization for CLoQ's second SVD.
//!
//! CLoQ only needs the top-r components of `R·ΔW` with r ≪ min(m, n); the
//! full one-sided Jacobi SVD costs O(min(m,n)²·max(m,n)) while the
//! randomized sketch costs O(m·n·(r+p)) plus an O((r+p)³) tail — a large
//! win at rank 16–64 on 256–1024-wide layers. Accuracy is controlled by
//! the oversampling `p` and `q` power iterations; with q = 2 the top-r
//! subspace is accurate to fp tolerance for the residual spectra seen in
//! quantization (fast decay after MagR+OPTQ).

use super::blas::{matmul, matmul_tn};
use super::matrix::Matrix;
use super::qr::qr;
use super::svd::{svd, Svd};
use crate::util::prng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct RsvdConfig {
    /// Oversampling columns beyond the target rank.
    pub oversample: usize,
    /// Power iterations (subspace refinement).
    pub power_iters: usize,
}

impl Default for RsvdConfig {
    fn default() -> Self {
        Self { oversample: 8, power_iters: 2 }
    }
}

/// Randomized top-`r` SVD of `a` (m×n). Returns a thin [`Svd`] with exactly
/// `min(r, min(m,n))` components.
pub fn rsvd(a: &Matrix, r: usize, cfg: &RsvdConfig, rng: &mut Rng) -> Svd {
    let (m, n) = (a.rows, a.cols);
    let k = r.min(m.min(n));
    if k == 0 {
        return Svd { u: Matrix::zeros(m, 0), s: vec![], v: Matrix::zeros(n, 0) };
    }
    let sketch = (k + cfg.oversample).min(m.min(n));
    // When the sketch covers the full spectrum anyway, exact SVD is cheaper
    // and exact — fall through.
    if sketch * 2 >= m.min(n) {
        return svd(a).truncate(k);
    }

    // Range finder: Y = (A Aᵀ)^q A Ω, orthonormalized between steps for
    // numerical stability.
    let omega = Matrix::randn(n, sketch, 1.0, rng);
    let mut y = matmul(a, &omega); // m×s
    let mut q_basis = qr(&y).q;
    for _ in 0..cfg.power_iters {
        let z = matmul_tn(a, &q_basis); // n×s = Aᵀ Q
        let qz = qr(&z).q;
        y = matmul(a, &qz);
        q_basis = qr(&y).q;
    }

    // Project: B = Qᵀ A (s×n), small exact SVD, lift U back.
    let b = matmul_tn(&q_basis, a);
    let d = svd(&b).truncate(k);
    Svd { u: matmul(&q_basis, &d.u), s: d.s, v: d.v }
}

/// Best rank-r approximation via the randomized path.
pub fn best_rank_r_randomized(a: &Matrix, r: usize, cfg: &RsvdConfig, rng: &mut Rng) -> Matrix {
    rsvd(a, r, cfg, rng).reconstruct()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::norms::fro;

    #[test]
    fn exact_on_low_rank_matrices() {
        let mut rng = Rng::new(120);
        // Exactly rank-5 matrix: rsvd at r=5 must reconstruct it.
        let p = Matrix::randn(60, 5, 1.0, &mut rng);
        let q = Matrix::randn(5, 40, 1.0, &mut rng);
        let a = matmul(&p, &q);
        let d = rsvd(&a, 5, &RsvdConfig::default(), &mut rng);
        assert!(a.max_diff(&d.reconstruct()) < 1e-7, "err {}", a.max_diff(&d.reconstruct()));
    }

    #[test]
    fn near_optimal_on_decaying_spectra() {
        let mut rng = Rng::new(121);
        // Synthetic decaying spectrum like a quantization residual.
        let u = crate::linalg::qr::random_orthonormal(80, 30, &mut rng);
        let v = crate::linalg::qr::random_orthonormal(50, 30, &mut rng);
        let s: Vec<f64> = (0..30).map(|i| (0.75f64).powi(i as i32)).collect();
        let a = matmul(&crate::linalg::svd::scale_cols(&u, &s), &v.transpose());
        for r in [2usize, 5, 10] {
            let exact = crate::linalg::best_rank_r(&a, r);
            let approx = best_rank_r_randomized(&a, r, &RsvdConfig::default(), &mut rng);
            let e_exact = fro(&a.sub(&exact));
            let e_approx = fro(&a.sub(&approx));
            assert!(
                e_approx <= e_exact * 1.01 + 1e-9,
                "r={r}: randomized {e_approx} vs exact {e_exact}"
            );
        }
    }

    #[test]
    fn singular_values_match_exact() {
        let mut rng = Rng::new(122);
        let a = Matrix::randn(70, 45, 1.0, &mut rng);
        let exact = svd(&a);
        let approx = rsvd(&a, 6, &RsvdConfig { oversample: 10, power_iters: 3 }, &mut rng);
        for i in 0..6 {
            assert!(
                (approx.s[i] - exact.s[i]).abs() < 2e-2 * exact.s[i],
                "sigma_{i}: {} vs {}",
                approx.s[i],
                exact.s[i]
            );
        }
    }

    #[test]
    fn orthonormal_factors() {
        let mut rng = Rng::new(123);
        let a = Matrix::randn(50, 64, 1.0, &mut rng);
        let d = rsvd(&a, 8, &RsvdConfig::default(), &mut rng);
        let utu = matmul_tn(&d.u, &d.u);
        assert!(utu.max_diff(&Matrix::eye(8)) < 1e-8);
        let vtv = matmul_tn(&d.v, &d.v);
        assert!(vtv.max_diff(&Matrix::eye(8)) < 1e-8);
    }

    #[test]
    fn degenerate_ranks() {
        let mut rng = Rng::new(124);
        let a = Matrix::randn(10, 8, 1.0, &mut rng);
        let d0 = rsvd(&a, 0, &RsvdConfig::default(), &mut rng);
        assert!(d0.s.is_empty());
        // r beyond min dim clamps.
        let dbig = rsvd(&a, 100, &RsvdConfig::default(), &mut rng);
        assert_eq!(dbig.s.len(), 8);
        assert!(a.max_diff(&dbig.reconstruct()) < 1e-7);
    }
}

//! # CLoQ — Calibrated LoRA Initialization for Quantized LLMs
//!
//! A three-layer Rust + JAX + Pallas reproduction of
//! *"CLoQ: Enhancing Fine-Tuning of Quantized LLMs via Calibrated LoRA
//! Initialization"* (Deng et al., 2025).
//!
//! Layer map (see `DESIGN.md`):
//! * **L3 (this crate)** — coordinator + full numerics: calibration,
//!   MagR+OPTQ post-training quantization, the Theorem-3.1 closed-form LoRA
//!   initialization, every baseline (RTN/NF4/QLoRA/GPTQ-LoRA/LoftQ), the
//!   fine-tuning trainer, evaluation, the table/figure bench harness, and
//!   the multi-tenant packed-weight serving engine (`serve`: fused
//!   dequant×matmul kernel, hot-swappable adapter registry, adapter-aware
//!   request batcher, versioned base + adapter artifacts).
//! * **L2 (`python/compile/model.py`)** — the TinyGPT compute graphs,
//!   AOT-lowered once to HLO text under `artifacts/`.
//! * **L1 (`python/compile/kernels/`)** — Pallas fused dequant-matmul +
//!   LoRA kernel (interpret mode), verified against a pure-jnp oracle.
//!
//! The `runtime` module loads the artifacts via the PJRT C API (`xla` crate)
//! so Python is never on the run path.

pub mod bench;
pub mod coordinator;
pub mod data;
pub mod linalg;
pub mod lowrank;
pub mod model;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;

//! `synth-wiki`: the WikiText-2 stand-in (DESIGN.md §3).
//!
//! A seeded probabilistic template grammar over a zipfian synthetic
//! vocabulary. The grammar carries enough structure for a tiny LM to learn
//! (determiner agreement, verb argument patterns, punctuation rhythm,
//! topic-repeated nouns), while the zipfian lexicon gives realistic
//! heavy-tailed token statistics. Splits (train/valid/test/calibration) come
//! from disjoint seed streams of the same distribution, mirroring how the
//! paper calibrates on WikiText train samples and evaluates ppl on the
//! validation split.

use crate::util::prng::{Rng, Zipf};

/// Deterministic synthetic lexicon: CV-syllable words.
fn make_words(n: usize, min_syl: usize, max_syl: usize, rng: &mut Rng) -> Vec<String> {
    const C: &[&str] =
        &["b", "d", "f", "g", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z", "th", "sh"];
    const V: &[&str] = &["a", "e", "i", "o", "u", "ai", "or"];
    let mut words = Vec::with_capacity(n);
    while words.len() < n {
        let syls = rng.range(min_syl as i64, max_syl as i64) as usize;
        let mut w = String::new();
        for _ in 0..syls {
            w.push_str(C[rng.below(C.len())]);
            w.push_str(V[rng.below(V.len())]);
        }
        if !words.contains(&w) {
            words.push(w);
        }
    }
    words
}

/// The grammar: fixed per seed, shared across splits.
pub struct Corpus {
    nouns: Vec<String>,
    verbs: Vec<String>,
    adjs: Vec<String>,
    noun_dist: Zipf,
    verb_dist: Zipf,
    adj_dist: Zipf,
}

impl Corpus {
    pub fn new(seed: u64) -> Corpus {
        let mut rng = Rng::new(seed ^ 0xC0FFEE);
        Corpus {
            nouns: make_words(60, 2, 3, &mut rng),
            verbs: make_words(30, 2, 2, &mut rng),
            adjs: make_words(20, 2, 3, &mut rng),
            noun_dist: Zipf::new(60, 1.05),
            verb_dist: Zipf::new(30, 1.05),
            adj_dist: Zipf::new(20, 1.0),
        }
    }

    fn noun(&self, rng: &mut Rng) -> (String, bool) {
        // (word, plural?)
        let w = self.nouns[self.noun_dist.sample(rng)].clone();
        if rng.chance(0.3) {
            (format!("{w}s"), true)
        } else {
            (w, false)
        }
    }

    fn np(&self, rng: &mut Rng, topic: Option<&str>) -> (String, bool) {
        let (mut n, plural) = match topic {
            // Topic nouns recur within a paragraph (discourse coherence).
            Some(t) if rng.chance(0.45) => (t.to_string(), false),
            _ => self.noun(rng),
        };
        if rng.chance(0.35) {
            let a = &self.adjs[self.adj_dist.sample(rng)];
            n = format!("{a} {n}");
        }
        let det = if plural {
            if rng.chance(0.5) { "the" } else { "some" }
        } else if rng.chance(0.6) {
            "the"
        } else {
            "a"
        };
        (format!("{det} {n}"), plural)
    }

    /// One sentence. Subject-verb agreement: singular subject → verb+"s".
    pub fn sentence(&self, rng: &mut Rng, topic: &str) -> String {
        let (subj, plural) = self.np(rng, Some(topic));
        let v = &self.verbs[self.verb_dist.sample(rng)];
        let verb = if plural { v.clone() } else { format!("{v}s") };
        let (obj, _) = self.np(rng, Some(topic));
        let mut s = format!("{subj} {verb} {obj}");
        if rng.chance(0.25) {
            let (obj2, _) = self.np(rng, None);
            s = format!("{s} near {obj2}");
        }
        s.push('.');
        s
    }

    /// A paragraph of `n_sentences` around one topic noun.
    pub fn paragraph(&self, rng: &mut Rng, n_sentences: usize) -> String {
        let topic = self.nouns[self.noun_dist.sample(rng)].clone();
        (0..n_sentences)
            .map(|_| self.sentence(rng, &topic))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// A document of roughly `target_bytes` characters.
    pub fn document(&self, rng: &mut Rng, target_bytes: usize) -> String {
        let mut doc = String::new();
        while doc.len() < target_bytes {
            if !doc.is_empty() {
                doc.push('\n');
            }
            let n = rng.range(2, 5) as usize;
            doc.push_str(&self.paragraph(rng, n));
        }
        doc.truncate(target_bytes);
        doc
    }
}

/// The four standard splits, as independent seed streams.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Valid,
    Test,
    Calibration,
}

impl Split {
    fn stream(self) -> u64 {
        match self {
            Split::Train => 0x7721,
            Split::Valid => 0xAAC3,
            Split::Test => 0x51D5,
            Split::Calibration => 0xFE07,
        }
    }
}

/// Generate `bytes` of corpus text for (seed, split).
pub fn corpus_text(seed: u64, split: Split, bytes: usize) -> String {
    let corpus = Corpus::new(seed);
    let mut rng = Rng::new(seed.wrapping_mul(0x9E3779B9) ^ split.stream());
    corpus.document(&mut rng, bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        assert_eq!(corpus_text(1, Split::Train, 500), corpus_text(1, Split::Train, 500));
        assert_ne!(corpus_text(1, Split::Train, 500), corpus_text(2, Split::Train, 500));
    }

    #[test]
    fn splits_differ_but_share_lexicon() {
        let train = corpus_text(7, Split::Train, 2000);
        let valid = corpus_text(7, Split::Valid, 2000);
        assert_ne!(train, valid);
        // Shared lexicon: the most common noun of train appears in valid.
        let c = Corpus::new(7);
        let top_noun = &c.nouns[0];
        assert!(train.contains(top_noun.as_str()) || valid.contains(top_noun.as_str()));
    }

    #[test]
    fn has_sentence_structure() {
        let text = corpus_text(3, Split::Train, 3000);
        assert!(text.contains('.'));
        assert!(text.contains("the "));
        // Zipfian: "the" should be very frequent.
        let the_count = text.matches("the ").count();
        assert!(the_count > 20, "the_count={the_count}");
    }

    #[test]
    fn agreement_holds() {
        // Every "a <noun> <verb>" clause uses the -s verb form: sample some
        // sentences and check singular subjects get verb+s.
        let c = Corpus::new(11);
        let mut rng = Rng::new(42);
        for _ in 0..50 {
            let s = c.sentence(&mut rng, "topic");
            // crude check: sentence contains a verb; structure is intact
            assert!(s.ends_with('.'));
            assert!(s.split_whitespace().count() >= 4, "{s}");
        }
    }

    #[test]
    fn target_length_respected() {
        let text = corpus_text(5, Split::Test, 1234);
        assert_eq!(text.len(), 1234);
    }
}

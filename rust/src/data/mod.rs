//! Data substrate: tokenizer, synthetic corpus (WikiText-2 stand-in),
//! task generators (arithmetic + commonsense families), and batching.

pub mod batcher;
pub mod corpus;
pub mod tasks;
pub mod tokenizer;

pub use batcher::{task_batch, task_batch_at, Batch, LmStream};
pub use corpus::{corpus_text, Split};
pub use tasks::{
    commonsense170k, math10k, mixed_dataset, Example, Task, ARITH_TASKS, COMMONSENSE_TASKS,
};

/// Pretraining mixture: synth-wiki prose interleaved with task-formatted
/// lines (arithmetic + commonsense QA). Mirrors how a real pretrained LLM
/// has already seen arithmetic and QA formats before fine-tuning — the
/// paper's starting point is a model that *can* do these tasks at FP16.
pub fn pretrain_mixture(seed: u64, bytes: usize) -> String {
    use crate::util::prng::Rng;
    let prose = corpus_text(seed, Split::Train, bytes / 2);
    let mut rng = Rng::new(seed ^ 0x9E77_1234);
    let mut out = String::with_capacity(bytes + 256);
    let mut prose_iter = prose.split('\n');
    let all_tasks: Vec<Task> =
        ARITH_TASKS.iter().chain(COMMONSENSE_TASKS.iter()).copied().collect();
    while out.len() < bytes {
        // A paragraph of prose…
        if let Some(p) = prose_iter.next() {
            out.push_str(p);
            out.push('\n');
        }
        // …then a burst of task lines.
        for _ in 0..rng.range(3, 8) {
            let t = all_tasks[rng.below(all_tasks.len())];
            let ex = t.example(&mut rng);
            out.push_str(&ex.prompt);
            out.push_str(" A: ");
            out.push_str(&ex.answer);
            out.push('\n');
        }
    }
    out.truncate(bytes);
    out
}

//! Synthetic task families standing in for the paper's fine-tuning /
//! evaluation datasets (DESIGN.md §3):
//!
//! * **Arithmetic** (Tables 1–4): `s-gsm` (two-step sums), `s-svamp`
//!   (one-step word form), `s-mawps` (small operands), `s-aqua`
//!   (multiple choice). Generative families are scored by exact match on
//!   the decoded answer; `s-aqua` by option log-likelihood.
//! * **Commonsense** (Table 5): eight MCQ families (parity, comparison,
//!   majority, succession, membership, copy, reverse, boolean logic),
//!   all scored by option log-likelihood — mirroring the eight benchmarks
//!   BoolQ/PIQA/SIQA/HellaSwag/WinoGrande/ARC-e/ARC-c/OBQA in mechanics
//!   and difficulty spread.

use crate::util::prng::Rng;

/// One supervised example.
#[derive(Clone, Debug)]
pub struct Example {
    pub prompt: String,
    pub answer: String,
    /// For MCQ tasks: all options (including the answer); empty for
    /// generative tasks.
    pub options: Vec<String>,
}

impl Example {
    fn gen(prompt: String, answer: String) -> Example {
        Example { prompt, answer, options: vec![] }
    }

    fn mcq(prompt: String, options: Vec<String>, correct: usize) -> Example {
        Example { prompt, answer: options[correct].clone(), options }
    }

    pub fn is_mcq(&self) -> bool {
        !self.options.is_empty()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Task {
    // arithmetic
    SGsm,
    SSvamp,
    SMawps,
    SAqua,
    // commonsense
    CParity,
    CCompare,
    CMajority,
    CSucc,
    CMember,
    CCopy,
    CReverse,
    CBool,
}

pub const ARITH_TASKS: [Task; 4] = [Task::SGsm, Task::SSvamp, Task::SMawps, Task::SAqua];
pub const COMMONSENSE_TASKS: [Task; 8] = [
    Task::CParity,
    Task::CCompare,
    Task::CMajority,
    Task::CSucc,
    Task::CMember,
    Task::CCopy,
    Task::CReverse,
    Task::CBool,
];

impl Task {
    pub fn name(&self) -> &'static str {
        match self {
            Task::SGsm => "s-GSM8K",
            Task::SSvamp => "s-SVAMP",
            Task::SMawps => "s-MAWPS",
            Task::SAqua => "s-AQuA",
            Task::CParity => "c-Parity",
            Task::CCompare => "c-Compare",
            Task::CMajority => "c-Majority",
            Task::CSucc => "c-Succ",
            Task::CMember => "c-Member",
            Task::CCopy => "c-Copy",
            Task::CReverse => "c-Reverse",
            Task::CBool => "c-Bool",
        }
    }

    pub fn parse(s: &str) -> Option<Task> {
        let all = ARITH_TASKS.iter().chain(COMMONSENSE_TASKS.iter());
        for t in all {
            if t.name().eq_ignore_ascii_case(s) {
                return Some(*t);
            }
        }
        None
    }

    /// Generate one example.
    pub fn example(&self, rng: &mut Rng) -> Example {
        match self {
            Task::SMawps => {
                // easiest: single-step, small operands, 1-digit answers
                let a = rng.range(0, 5);
                let b = rng.range(0, 5);
                if rng.chance(0.5) {
                    Example::gen(format!("Q: {a}+{b}=?"), format!("{}", a + b))
                } else {
                    let (hi, lo) = (a.max(b), a.min(b));
                    Example::gen(format!("Q: {hi}-{lo}=?"), format!("{}", hi - lo))
                }
            }
            Task::SSvamp => {
                // one-step word form with a distractor number
                let a = rng.range(2, 9);
                let b = rng.range(1, 8);
                let d = rng.range(1, 9);
                if rng.chance(0.5) {
                    Example::gen(
                        format!("Q: {a} cups and {b} more, {d} hats. cups?"),
                        format!("{}", a + b),
                    )
                } else {
                    let (hi, lo) = (a.max(b), a.min(b));
                    Example::gen(
                        format!("Q: {hi} cups, {lo} lost, {d} hats. cups?"),
                        format!("{}", hi - lo),
                    )
                }
            }
            Task::SGsm => {
                // hardest generative: two-step chain
                let a = rng.range(2, 9);
                let b = rng.range(1, 8);
                let c = rng.range(1, (a + b).min(9));
                Example::gen(format!("Q: {a}+{b}-{c}=?"), format!("{}", a + b - c))
            }
            Task::SAqua => {
                // multiple choice, 4 options
                let a = rng.range(2, 12);
                let b = rng.range(1, 9);
                let ans = a + b;
                let mut opts = vec![ans];
                while opts.len() < 4 {
                    let delta = rng.range(1, 6) * if rng.chance(0.5) { 1 } else { -1 };
                    let cand = (ans + delta).max(0);
                    if !opts.contains(&cand) {
                        opts.push(cand);
                    }
                }
                rng.shuffle(&mut opts);
                let correct = opts.iter().position(|&x| x == ans).unwrap();
                Example::mcq(
                    format!("Q: {a}+{b}=?"),
                    opts.iter().map(|x| x.to_string()).collect(),
                    correct,
                )
            }
            Task::CParity => {
                let n = rng.range(0, 99);
                let yes = n % 2 == 0;
                Example::mcq(
                    format!("is {n} even?"),
                    vec!["yes".into(), "no".into()],
                    if yes { 0 } else { 1 },
                )
            }
            Task::CCompare => {
                let mut xs = [rng.range(0, 30), rng.range(0, 30), rng.range(0, 30)];
                while xs[0] == xs[1] || xs[1] == xs[2] || xs[0] == xs[2] {
                    xs = [rng.range(0, 30), rng.range(0, 30), rng.range(0, 30)];
                }
                let max = *xs.iter().max().unwrap();
                let correct = xs.iter().position(|&x| x == max).unwrap();
                Example::mcq(
                    format!("max of {} {} {}?", xs[0], xs[1], xs[2]),
                    xs.iter().map(|x| x.to_string()).collect(),
                    correct,
                )
            }
            Task::CMajority => {
                let len = rng.range(5, 9) as usize;
                let mut s = String::new();
                let mut x_count = 0usize;
                for _ in 0..len {
                    if rng.chance(0.5) {
                        s.push('x');
                        x_count += 1;
                    } else {
                        s.push('o');
                    }
                }
                // Force a strict majority.
                if 2 * x_count == len {
                    s.push('x');
                    x_count += 1;
                }
                let more_x = 2 * x_count > s.len();
                Example::mcq(
                    format!("more x or o in {s}?"),
                    vec!["x".into(), "o".into()],
                    if more_x { 0 } else { 1 },
                )
            }
            Task::CSucc => {
                let n = rng.range(0, 50);
                let opts = vec![
                    format!("{}", n + 1),
                    format!("{}", n + 2),
                    format!("{}", (n - 1).max(0)),
                ];
                Example::mcq(format!("after {n} comes?"), opts, 0)
            }
            Task::CMember => {
                const WORDS: &[&str] = &["apple", "stone", "river", "cloud", "tiger", "bread"];
                let w = *rng.choose(WORDS);
                let c = (b'a' + rng.below(26) as u8) as char;
                let yes = w.contains(c);
                Example::mcq(
                    format!("is {c} in {w}?"),
                    vec!["yes".into(), "no".into()],
                    if yes { 0 } else { 1 },
                )
            }
            Task::CCopy => {
                let len = rng.range(3, 5) as usize;
                let s: String = (0..len).map(|_| (b'a' + rng.below(6) as u8) as char).collect();
                let mut wrong: Vec<char> = s.chars().collect();
                wrong.swap(0, len - 1);
                let wrong: String = wrong.into_iter().collect();
                if wrong == s {
                    // all-same string; perturb instead
                    let mut w2: Vec<char> = s.chars().collect();
                    w2[0] = if w2[0] == 'a' { 'b' } else { 'a' };
                    let w2: String = w2.into_iter().collect();
                    return Example::mcq(format!("copy {s}?"), vec![s.clone(), w2], 0);
                }
                Example::mcq(format!("copy {s}?"), vec![s.clone(), wrong], 0)
            }
            Task::CReverse => {
                let len = rng.range(3, 4) as usize;
                let s: String = (0..len).map(|_| (b'a' + rng.below(8) as u8) as char).collect();
                let rev: String = s.chars().rev().collect();
                if rev == s {
                    let opts = vec![rev.clone(), format!("{rev}x")];
                    return Example::mcq(format!("reverse {s}?"), opts, 0);
                }
                let opts = vec![rev, s.clone()];
                Example::mcq(format!("reverse {s}?"), opts, 0)
            }
            Task::CBool => {
                let a = rng.chance(0.5);
                let b = rng.chance(0.5);
                let and = rng.chance(0.5);
                let result = if and { a && b } else { a || b };
                let op = if and { "and" } else { "or" };
                let f = |x: bool| if x { "true" } else { "false" };
                Example::mcq(
                    format!("{} {op} {}?", f(a), f(b)),
                    vec!["true".into(), "false".into()],
                    if result { 0 } else { 1 },
                )
            }
        }
    }

    /// A deterministic dataset of `n` examples for (task, seed, split).
    pub fn dataset(&self, n: usize, seed: u64, split: u64) -> Vec<Example> {
        let mut rng =
            Rng::new(seed ^ split.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (*self as u64) << 32);
        (0..n).map(|_| self.example(&mut rng)).collect()
    }
}

/// The `Math10K` stand-in: a mixture over the generative arithmetic
/// families plus AQuA (the paper fine-tunes on GSM8K+MAWPS+AQuA samples).
pub fn math10k(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ 0x3A7);
    let tasks = [Task::SGsm, Task::SMawps, Task::SAqua, Task::SSvamp];
    let weights = [0.4, 0.25, 0.2, 0.15];
    (0..n)
        .map(|_| {
            let t = tasks[rng.weighted(&weights)];
            t.example(&mut rng)
        })
        .collect()
}

/// The `Commonsense170K` stand-in: uniform mixture over the 8 families.
pub fn commonsense170k(n: usize, seed: u64) -> Vec<Example> {
    let mut rng = Rng::new(seed ^ 0xC5);
    (0..n)
        .map(|_| {
            let t = COMMONSENSE_TASKS[rng.below(8)];
            t.example(&mut rng)
        })
        .collect()
}

/// The Table-6 mixed set: math10k + `extra` commonsense samples.
pub fn mixed_dataset(n_math: usize, n_cs: usize, seed: u64) -> Vec<Example> {
    let mut out = math10k(n_math, seed);
    out.extend(commonsense170k(n_cs, seed ^ 0x1111));
    let mut rng = Rng::new(seed ^ 0x2222);
    rng.shuffle(&mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate_valid_examples() {
        let mut rng = Rng::new(1);
        for t in ARITH_TASKS.iter().chain(COMMONSENSE_TASKS.iter()) {
            for _ in 0..50 {
                let ex = t.example(&mut rng);
                assert!(!ex.prompt.is_empty());
                assert!(!ex.answer.is_empty());
                if ex.is_mcq() {
                    assert!(ex.options.contains(&ex.answer));
                    assert!(ex.options.len() >= 2);
                    // Options are distinct.
                    let mut o = ex.options.clone();
                    o.sort();
                    o.dedup();
                    assert_eq!(o.len(), ex.options.len(), "{ex:?}");
                }
            }
        }
    }

    #[test]
    fn arithmetic_answers_are_correct() {
        let mut rng = Rng::new(2);
        for _ in 0..100 {
            let ex = Task::SMawps.example(&mut rng);
            // Parse "Q: a+b=?" or "Q: a-b=?"
            let q = ex.prompt.trim_start_matches("Q: ").trim_end_matches("=?");
            let ans: i64 = ex.answer.parse().unwrap();
            if let Some((a, b)) = q.split_once('+') {
                assert_eq!(ans, a.parse::<i64>().unwrap() + b.parse::<i64>().unwrap());
            } else if let Some((a, b)) = q.split_once('-') {
                assert_eq!(ans, a.parse::<i64>().unwrap() - b.parse::<i64>().unwrap());
            } else {
                panic!("unexpected prompt {q}");
            }
            assert!(ans >= 0);
        }
    }

    #[test]
    fn gsm_two_step_correct() {
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let ex = Task::SGsm.example(&mut rng);
            let q = ex.prompt.trim_start_matches("Q: ").trim_end_matches("=?");
            let (ab, c) = q.rsplit_once('-').unwrap();
            let (a, b) = ab.split_once('+').unwrap();
            let expect =
                a.parse::<i64>().unwrap() + b.parse::<i64>().unwrap() - c.parse::<i64>().unwrap();
            assert_eq!(ex.answer.parse::<i64>().unwrap(), expect);
            assert!(expect >= 0);
        }
    }

    #[test]
    fn datasets_deterministic_and_split_disjoint() {
        let d1 = Task::SGsm.dataset(50, 7, 0);
        let d2 = Task::SGsm.dataset(50, 7, 0);
        assert_eq!(
            d1.iter().map(|e| &e.prompt).collect::<Vec<_>>(),
            d2.iter().map(|e| &e.prompt).collect::<Vec<_>>()
        );
        let test = Task::SGsm.dataset(50, 7, 1);
        let train_prompts: Vec<_> = d1.iter().map(|e| e.prompt.clone()).collect();
        let overlap = test.iter().filter(|e| train_prompts.contains(&e.prompt)).count();
        assert!(overlap < 25, "splits should differ: overlap={overlap}");
    }

    #[test]
    fn mixtures_have_both_kinds() {
        let m = mixed_dataset(50, 20, 9);
        assert_eq!(m.len(), 70);
        assert!(m.iter().any(|e| e.is_mcq()));
        assert!(m.iter().any(|e| !e.is_mcq()));
    }

    #[test]
    fn mcq_correctness_spotcheck() {
        let mut rng = Rng::new(4);
        for _ in 0..50 {
            let ex = Task::CParity.example(&mut rng);
            let n: i64 = ex
                .prompt
                .trim_start_matches("is ")
                .trim_end_matches(" even?")
                .parse()
                .unwrap();
            assert_eq!(ex.answer == "yes", n % 2 == 0);

            let ex = Task::CBool.example(&mut rng);
            let p = ex.prompt.trim_end_matches('?');
            let parts: Vec<&str> = p.split_whitespace().collect();
            let a = parts[0] == "true";
            let b = parts[2] == "true";
            let expect = if parts[1] == "and" { a && b } else { a || b };
            assert_eq!(ex.answer == "true", expect);
        }
    }
}

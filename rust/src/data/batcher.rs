//! Batching: packing corpus text and task examples into the fixed
//! `[batch, seq]` token / loss-mask tensors the AOT graphs expect.

use crate::data::tokenizer::{self, BOS, PAD};
use crate::data::tasks::Example;
use crate::runtime::Tensor;
use crate::util::prng::Rng;

/// A [B, T] token batch + loss mask (mask[b,t]=1 ⇔ token t is a target).
#[derive(Clone, Debug)]
pub struct Batch {
    pub tokens: Tensor,
    pub mask: Tensor,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    fn from_rows(rows: Vec<(Vec<i32>, Vec<f32>)>, seq: usize) -> Batch {
        let b = rows.len();
        let mut tokens = vec![PAD; b * seq];
        let mut mask = vec![0.0f32; b * seq];
        for (i, (toks, ms)) in rows.into_iter().enumerate() {
            let n = toks.len().min(seq);
            tokens[i * seq..i * seq + n].copy_from_slice(&toks[..n]);
            mask[i * seq..i * seq + n].copy_from_slice(&ms[..n]);
        }
        Batch {
            tokens: Tensor::i32(vec![b, seq], tokens),
            mask: Tensor::f32(vec![b, seq], mask),
            batch: b,
            seq,
        }
    }
}

/// Language-modeling stream: chop tokenized text into contiguous windows of
/// `seq` tokens (BOS-prefixed), mask = 1 on all real tokens.
pub struct LmStream {
    tokens: Vec<i32>,
    pos: usize,
    batch: usize,
    seq: usize,
}

impl LmStream {
    pub fn new(text: &str, batch: usize, seq: usize) -> LmStream {
        LmStream { tokens: tokenizer::encode(text), pos: 0, batch, seq }
    }

    /// Number of full batches available.
    pub fn num_batches(&self) -> usize {
        self.tokens.len() / ((self.seq - 1) * self.batch)
    }

    /// Next batch, wrapping around at the end (for training); returns None
    /// only for an empty stream.
    pub fn next_batch(&mut self) -> Option<Batch> {
        if self.tokens.len() < self.seq {
            return None;
        }
        let mut rows = Vec::with_capacity(self.batch);
        for _ in 0..self.batch {
            let need = self.seq - 1;
            if self.pos + need > self.tokens.len() {
                self.pos = 0;
            }
            let mut toks = vec![BOS];
            toks.extend_from_slice(&self.tokens[self.pos..self.pos + need]);
            self.pos += need;
            let mask = vec![1.0f32; self.seq];
            rows.push((toks, mask));
        }
        Some(Batch::from_rows(rows, self.seq))
    }
}

/// Task fine-tuning batches: each row is `[BOS] prompt " A: " answer [EOS]`
/// with loss mask covering the answer + EOS (the target positions).
pub fn task_batch(examples: &[Example], batch: usize, seq: usize, rng: &mut Rng) -> Batch {
    let mut rows = Vec::with_capacity(batch);
    for _ in 0..batch {
        let ex = &examples[rng.below(examples.len())];
        rows.push(example_row(ex, seq));
    }
    Batch::from_rows(rows, seq)
}

/// Deterministic sequential batch over `examples[start..start+batch]`
/// (wrapping), for evaluation. Returns the example indices used.
pub fn task_batch_at(
    examples: &[Example],
    start: usize,
    batch: usize,
    seq: usize,
) -> (Batch, Vec<usize>) {
    let mut rows = Vec::with_capacity(batch);
    let mut idxs = Vec::with_capacity(batch);
    for k in 0..batch {
        let i = (start + k) % examples.len();
        idxs.push(i);
        rows.push(example_row(&examples[i], seq));
    }
    (Batch::from_rows(rows, seq), idxs)
}

fn example_row(ex: &Example, seq: usize) -> (Vec<i32>, Vec<f32>) {
    let (toks, astart) = tokenizer::encode_example(&ex.prompt, &ex.answer);
    let mut mask = vec![0.0f32; toks.len()];
    for m in mask[astart..].iter_mut() {
        *m = 1.0;
    }
    let mut toks = toks;
    if toks.len() > seq {
        toks.truncate(seq);
        mask.truncate(seq);
    }
    (toks, mask)
}

/// A prompt-only row for scoring/decoding: `[BOS] prompt " A: " <candidate>`.
/// Returns (tokens, index where the candidate begins).
pub fn prompt_with_candidate(prompt: &str, candidate: &str, seq: usize) -> (Vec<i32>, usize) {
    let (mut toks, astart) = tokenizer::encode_example(prompt, candidate);
    toks.pop(); // drop EOS: candidates are scored without terminal credit
    if toks.len() > seq {
        toks.truncate(seq);
    }
    (toks, astart)
}

/// Pad a set of token rows into a [B, T] tokens tensor (mask unused).
pub fn pad_rows(rows: &[Vec<i32>], batch: usize, seq: usize) -> Tensor {
    assert!(rows.len() <= batch);
    let mut tokens = vec![PAD; batch * seq];
    for (i, r) in rows.iter().enumerate() {
        let n = r.len().min(seq);
        tokens[i * seq..i * seq + n].copy_from_slice(&r[..n]);
    }
    Tensor::i32(vec![batch, seq], tokens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::corpus::{corpus_text, Split};
    use crate::data::tasks::Task;
    use crate::data::tokenizer::{decode, EOS};

    #[test]
    fn lm_stream_covers_text_without_loss() {
        let text = corpus_text(1, Split::Train, 4000);
        let mut s = LmStream::new(&text, 4, 16);
        let b = s.next_batch().unwrap();
        assert_eq!(b.tokens.shape, vec![4, 16]);
        // First token of each row is BOS; all masked.
        let toks = b.tokens.as_i32();
        let mask = b.mask.as_f32();
        for i in 0..4 {
            assert_eq!(toks[i * 16], BOS);
            assert!(mask[i * 16..(i + 1) * 16].iter().all(|&m| m == 1.0));
        }
        // Consecutive batches advance through the text.
        let b2 = s.next_batch().unwrap();
        assert_ne!(b.tokens.as_i32(), b2.tokens.as_i32());
    }

    #[test]
    fn task_batch_masks_answers_only() {
        let mut rng = Rng::new(5);
        let data = Task::SMawps.dataset(20, 1, 0);
        let b = task_batch(&data, 4, 32, &mut rng);
        let toks = b.tokens.as_i32();
        let mask = b.mask.as_f32();
        for i in 0..4 {
            let row = &toks[i * 32..(i + 1) * 32];
            let mrow = &mask[i * 32..(i + 1) * 32];
            // The delimiter region is unmasked; the answer is masked.
            let first_masked = mrow.iter().position(|&m| m == 1.0).unwrap();
            assert!(mrow[..first_masked].iter().all(|&m| m == 0.0));
            let prompt = decode(&row[..first_masked]);
            assert!(prompt.ends_with(" A: "), "{prompt:?}");
            // EOS masked, pads unmasked.
            let eos_pos = row.iter().position(|&t| t == EOS).unwrap();
            assert_eq!(mrow[eos_pos], 1.0);
            if eos_pos + 1 < 32 {
                assert!(mrow[eos_pos + 1..].iter().all(|&m| m == 0.0));
            }
        }
    }

    #[test]
    fn deterministic_eval_batches() {
        let data = Task::SAqua.dataset(10, 2, 1);
        let (b1, i1) = task_batch_at(&data, 0, 4, 32);
        let (b2, i2) = task_batch_at(&data, 0, 4, 32);
        assert_eq!(i1, i2);
        assert_eq!(b1.tokens.as_i32(), b2.tokens.as_i32());
        let (_, i3) = task_batch_at(&data, 8, 4, 32);
        assert_eq!(i3, vec![8, 9, 0, 1]); // wraps
    }

    #[test]
    fn candidate_rows() {
        let (toks, astart) = prompt_with_candidate("is 4 even?", "yes", 32);
        assert!(tokenizer::decode(&toks[..astart]).ends_with(" A: "));
        assert_ne!(*toks.last().unwrap(), EOS);
        assert_eq!(tokenizer::decode(&toks[astart..]), "yes");
    }

    #[test]
    fn truncation_respected() {
        let long = Example {
            prompt: "x".repeat(100),
            answer: "y".repeat(50),
            options: vec![],
        };
        let (toks, mask) = example_row(&long, 40);
        assert_eq!(toks.len(), 40);
        assert_eq!(mask.len(), 40);
    }
}

//! Byte-level tokenizer with special tokens.
//!
//! Ids: 0 = PAD, 1 = BOS, 2 = EOS, 3 = SEP, byte `b` → `4 + b`
//! (vocab = 260, matching `model.py::Config.vocab`).

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;
pub const BYTE_OFFSET: i32 = 4;
pub const VOCAB: usize = 260;

pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32 + BYTE_OFFSET).collect()
}

pub fn decode(tokens: &[i32]) -> String {
    let bytes: Vec<u8> = tokens
        .iter()
        .filter(|&&t| t >= BYTE_OFFSET && t < VOCAB as i32)
        .map(|&t| (t - BYTE_OFFSET) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

/// Decode a single token to its text piece: one byte's UTF-8-lossy text
/// for ids in the byte range, the empty string for specials and
/// out-of-range ids. The serving decode loop streams pieces token by
/// token with this (`serve::generate`). Because decoding is per BYTE, a
/// token inside a multi-byte UTF-8 character renders as U+FFFD here — the
/// final response text is decoded from the full byte sequence instead and
/// is therefore identical to `decode` over the generation's token ids.
pub fn decode_token(token: i32) -> String {
    decode(&[token])
}

/// Textual answer delimiter. Examples are encoded as
/// `[BOS] prompt " A: " answer [EOS]` — the SAME surface format the
/// pretraining mixture uses for its task lines, so fine-tuning only has to
/// adapt the answer distribution, not learn a new separator token (exactly
/// the situation of a real pretrained LLM).
pub const ANSWER_DELIM: &str = " A: ";

/// Encode one supervised example, returning (tokens, answer_start) where
/// `answer_start` indexes the first answer token (loss masks cover
/// `answer_start..len`).
pub fn encode_example(prompt: &str, answer: &str) -> (Vec<i32>, usize) {
    let mut toks = vec![BOS];
    toks.extend(encode(prompt));
    toks.extend(encode(ANSWER_DELIM));
    let answer_start = toks.len();
    toks.extend(encode(answer));
    toks.push(EOS);
    (toks, answer_start)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let s = "Q: 17+25=? A: 42";
        assert_eq!(decode(&encode(s)), s);
    }

    #[test]
    fn roundtrip_all_bytes() {
        let bytes: Vec<u8> = (0u8..=255).collect();
        let toks: Vec<i32> = bytes.iter().map(|&b| b as i32 + BYTE_OFFSET).collect();
        let decoded = decode(&toks);
        assert_eq!(decoded.as_bytes().len() > 0, true);
        // Tokens are all in range.
        assert!(toks.iter().all(|&t| t >= 4 && t < VOCAB as i32));
    }

    #[test]
    fn specials_are_reserved() {
        let toks = encode("anything");
        assert!(toks.iter().all(|&t| t >= BYTE_OFFSET));
    }

    #[test]
    fn example_layout() {
        let (toks, astart) = encode_example("1+1=?", "2");
        assert_eq!(toks[0], BOS);
        assert_eq!(*toks.last().unwrap(), EOS);
        assert_eq!(decode(&toks[..astart]), format!("1+1=?{ANSWER_DELIM}"));
        assert_eq!(decode(&toks[astart..toks.len() - 1]), "2");
    }
}

//! Packed-weight base layers and the fused unpack→dequant→dot forward
//! kernel.
//!
//! A [`PackedLayer`] holds the **base half** of a served linear layer:
//! `b`-bit codes packed little-endian into `u32` words (the
//! `quant::packing` layout, row-aligned so row `i` starts at word
//! `i·words_per_row`) plus the per-group dequantization parameters (INT
//! grid scales/zeros, or the NF codebook levels + absmax). The LoRA delta
//! is NOT stored here: it lives in a [`LoraPair`] (one per layer per
//! tenant, collected into `serve::adapters::AdapterSet`s) and is passed
//! into the forward calls, so one packed base serves many hot-swappable
//! adapters. The forward computes
//!
//! ```text
//!   y = Q̂ᵀx + B·(Aᵀx)        (layer orientation Y = X·W, W ∈ ℝ^{m×n})
//! ```
//!
//! unpacking and dequantizing **in-register** — the dense `q_deq` matrix is
//! never materialized; the only per-layer scratch is one n-wide row buffer
//! on the batched path.
//!
//! **Parity contract** (locked down by `rust/tests/parity_serve.rs`):
//! every output element is accumulated in ascending input-row order with
//! one rounding per multiply-add, `x[i] == 0` contributions skipped, and
//! the dequantized value computed by the exact op sequence of
//! `QuantState::dequantize` — so the fused forward is **bit-identical**
//! (0 ULP) to the dense reference `matvec_t(q_deq, x)` plus the same
//! factored LoRA product, for every bit width, group size and shape. The
//! batched forward reuses each dequantized row across the micro-batch
//! without changing any per-element op, so it is bit-identical to serial
//! request-at-a-time calls — and the **grouped** batched forward
//! ([`PackedLayer::forward_batch_grouped`]) extends that to mixed-adapter
//! micro-batches: the base pass is shared across the whole batch while the
//! LoRA skinny products run per adapter group, so every row is still
//! bit-identical to a serial single-adapter call. Against a fully *dense
//! effective weight* (`q_deq + A·Bᵀ` materialized, different accumulation
//! order) agreement is to floating-point tolerance only — that comparison
//! is also in the parity suite, with the tolerance stated there.

use std::sync::atomic::{AtomicU8, AtomicU64, Ordering};
use std::sync::Arc;

use crate::linalg::blas::{axpy, dot, matvec_t};
use crate::linalg::{matmul, Matrix};
use crate::lowrank::{LayerInit, LoraPair, Method};
use crate::quant::packing::{pack_codes, try_unpack_codes};
use crate::quant::{NfQuantized, QuantState, QuantizedTensor};
use crate::serve::error::{ArtifactErrorKind, ServeError};
use crate::serve::mmap::MappedFile;

/// Mint a fresh process-unique identity token. Engines and registries
/// stamp the handles they hand out ([`LayerId`], [`Route`],
/// `AdapterId`) with their own token so admission can tell "this handle
/// is MINE" with one integer compare — and reject foreign handles with a
/// typed error instead of silently addressing whatever sits at that
/// index. Token 0 is reserved for unbound handles (built directly
/// against a bare [`PackedModel`], which has no owning engine); those
/// take the legacy full-validation path at admission.
pub(crate) fn next_identity_token() -> u64 {
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

/// Words per packed row: codes are row-aligned so each row of an m×n layer
/// occupies `ceil(n / (32/bits))` little-endian u32 words.
pub fn words_per_row(cols: usize, bits: u32) -> usize {
    cols.div_ceil(32 / bits as usize)
}

/// An interned layer handle: the index of a layer inside the
/// [`PackedModel`] it was resolved against ([`PackedModel::resolve`] /
/// `ServeEngine::layer`). Resolving once and submitting by id keeps the
/// per-request hot path free of string hashing and cloning — a `LayerId`
/// is `Copy` and compares as one integer.
///
/// An id is only meaningful for the model it was resolved against, and
/// ids minted by a `ServeEngine` carry the engine's **identity token**:
/// admission compares the token first, so a handle from the owning
/// engine is admitted on one integer compare (index already validated at
/// resolve time), while a handle minted by a DIFFERENT engine fails with
/// a typed `BadRoute` even when its index happens to be in range.
/// Token-0 ids (resolved against a bare [`PackedModel`], which has no
/// owning engine) take the legacy full bounds check at admission.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct LayerId {
    index: u32,
    token: u64,
}

impl LayerId {
    pub(crate) fn new(index: usize) -> LayerId {
        LayerId { index: index as u32, token: 0 }
    }

    /// An id stamped with its owning engine's identity token.
    pub(crate) fn bound(index: usize, token: u64) -> LayerId {
        LayerId { index: index as u32, token }
    }

    /// The layer's position in its model's `layers` vector.
    pub fn index(self) -> usize {
        self.index as usize
    }

    /// The owning engine's identity token (0 = unbound).
    pub(crate) fn token(self) -> u64 {
        self.token
    }
}

/// A validated forward route: the ordered [`LayerId`]s a model request
/// traverses. Built by [`PackedModel::route`] (or `ServeEngine::route`),
/// which resolves every name once and checks chainability up front —
/// cloning a `Route` is one `Arc` bump, so submitting the same route for
/// thousands of requests never re-resolves or re-clones layer names.
#[derive(Clone, Debug)]
pub struct Route {
    hops: Arc<[LayerId]>,
    /// The minting engine's identity token (0 = built against a bare
    /// model). A token-bound route is admitted on ONE integer compare —
    /// the per-submission O(hops) re-validation only runs for unbound
    /// routes.
    token: u64,
}

impl Route {
    /// Construction is crate-private: a `Route` in caller hands has always
    /// been validated against a model (non-empty, in range, chainable).
    pub(crate) fn from_validated(ids: Vec<LayerId>) -> Route {
        debug_assert!(!ids.is_empty());
        Route { hops: ids.into(), token: 0 }
    }

    /// A validated route stamped with its owning engine's identity token.
    pub(crate) fn from_validated_bound(ids: Vec<LayerId>, token: u64) -> Route {
        debug_assert!(!ids.is_empty());
        Route { hops: ids.into(), token }
    }

    /// The owning engine's identity token (0 = unbound).
    pub(crate) fn token(&self) -> u64 {
        self.token
    }

    /// The route's layer ids, in traversal order.
    pub fn as_ids(&self) -> &[LayerId] {
        &self.hops
    }

    /// Hops per full forward pass.
    pub fn len(&self) -> usize {
        self.hops.len()
    }

    /// Always false — validated routes are non-empty (provided so callers
    /// and clippy get the conventional pair).
    pub fn is_empty(&self) -> bool {
        self.hops.is_empty()
    }
}

/// How a packed layer turns codes into values.
#[derive(Clone, Debug)]
pub enum DequantParams {
    /// Asymmetric INT grid: `v = (c − zeros[g][j]) · scales[g][j]`.
    Grid { scales: Matrix, zeros: Matrix },
    /// NF-k codebook: `v = levels[c] · absmax[g][j]`.
    Codebook { levels: Vec<f64>, absmax: Matrix },
}

/// Lazy-CRC verification states for a mapped code section.
const CRC_UNVERIFIED: u8 = 0;
const CRC_OK: u8 = 1;
const CRC_BAD: u8 = 2;

/// A code section borrowed straight from a [`MappedFile`]'s pages: the
/// v3 zero-copy path. The section's CRC is recorded at open time but only
/// *checked* on first touch ([`PackedSource::verify`]) — cold starts pay
/// for the header, not for hashing gigabytes of codes.
#[derive(Clone, Debug)]
pub struct MappedCodes {
    /// Keeps the pages alive as long as any layer borrows them.
    file: Arc<MappedFile>,
    /// Byte offset of the section inside the file (4096-aligned by the
    /// v3 writer; the reader additionally requires the resulting pointer
    /// to be 4-aligned before constructing a `MappedCodes`).
    byte_off: usize,
    /// Section length in u32 words.
    words: usize,
    /// Expected CRC-32 of the section bytes (from the v3 directory).
    crc: u32,
    /// Artifact path, for the typed error.
    path: Arc<str>,
    /// Lazy verification state, shared across clones: CRC_UNVERIFIED /
    /// CRC_OK / CRC_BAD.
    state: Arc<AtomicU8>,
}

/// Where a [`PackedLayer`]'s code words live: an owned buffer (the
/// v1/v2 copy path and everything built in process — byte-identical
/// forwards to before this type existed) or mapped pages (the v3
/// zero-copy path, CRC-checked lazily on first touch).
#[derive(Clone, Debug)]
pub enum PackedSource {
    Owned(Vec<u32>),
    Mapped(MappedCodes),
}

impl PackedSource {
    /// The v3 zero-copy constructor. Caller contract (enforced by the
    /// artifact reader): the platform is little-endian, `byte_off` is
    /// 4-aligned within the mapping, and `[byte_off, byte_off+words*4)`
    /// is in bounds — so `words()` can reinterpret the bytes in place.
    pub(crate) fn mapped(
        file: Arc<MappedFile>,
        byte_off: usize,
        words: usize,
        crc: u32,
        path: Arc<str>,
    ) -> PackedSource {
        debug_assert!(byte_off + words * 4 <= file.len());
        debug_assert_eq!((file.bytes().as_ptr() as usize + byte_off) % 4, 0);
        PackedSource::Mapped(MappedCodes {
            file,
            byte_off,
            words,
            crc,
            path,
            state: Arc::new(AtomicU8::new(CRC_UNVERIFIED)),
        })
    }

    /// The code words, wherever they live. For a mapped source this
    /// reinterprets the page bytes in place (alignment + endianness
    /// guaranteed at construction) — no copy, no verification; call
    /// [`PackedSource::verify`] before trusting the values.
    pub fn words(&self) -> &[u32] {
        match self {
            PackedSource::Owned(v) => v,
            PackedSource::Mapped(m) => {
                let bytes = &m.file.bytes()[m.byte_off..m.byte_off + m.words * 4];
                // SAFETY: construction guaranteed 4-alignment, in-bounds
                // length, and a little-endian host; the mapping is
                // immutable (PROT_READ) and outlives `self` via the Arc.
                unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const u32, m.words) }
            }
        }
    }

    /// Section length in u32 words.
    pub fn len(&self) -> usize {
        match self {
            PackedSource::Owned(v) => v.len(),
            PackedSource::Mapped(m) => m.words,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True on the zero-copy path (codes served straight from mapped
    /// pages).
    pub fn is_mapped(&self) -> bool {
        matches!(self, PackedSource::Mapped(_))
    }

    /// Check the section's integrity. Owned buffers were fully verified
    /// when decoded, so this is free; mapped sections hash their bytes on
    /// the FIRST call and cache the verdict (shared across clones) — a
    /// corrupt section fails every subsequent call with the same typed
    /// `ChecksumMismatch` naming `layer`.
    pub fn verify(&self, layer: &str) -> Result<(), ServeError> {
        let m = match self {
            PackedSource::Owned(_) => return Ok(()),
            PackedSource::Mapped(m) => m,
        };
        let state = match m.state.load(Ordering::Acquire) {
            CRC_UNVERIFIED => {
                let bytes = &m.file.bytes()[m.byte_off..m.byte_off + m.words * 4];
                let ok = crate::serve::artifact::crc32(bytes) == m.crc;
                let verdict = if ok { CRC_OK } else { CRC_BAD };
                // Racing first-touches compute the same verdict; last
                // store wins harmlessly.
                m.state.store(verdict, Ordering::Release);
                verdict
            }
            s => s,
        };
        if state == CRC_OK {
            return Ok(());
        }
        Err(ServeError::Artifact {
            path: m.path.to_string(),
            layer: Some(layer.to_string()),
            kind: ArtifactErrorKind::ChecksumMismatch,
            detail: "mapped code section failed its CRC on first touch".to_string(),
        })
    }

    /// True iff the next [`PackedSource::verify`] call will actually hash
    /// bytes (a mapped section whose lazy CRC has not run yet). Owned
    /// buffers and already-verified sections return false. Telemetry
    /// probes this to count first-touch verifications; two racing batches
    /// may both see true (and both count) — acceptable for a diagnostic
    /// counter.
    pub fn crc_pending(&self) -> bool {
        matches!(
            self,
            PackedSource::Mapped(m) if m.state.load(Ordering::Relaxed) == CRC_UNVERIFIED
        )
    }
}

impl From<Vec<u32>> for PackedSource {
    fn from(words: Vec<u32>) -> PackedSource {
        PackedSource::Owned(words)
    }
}

impl PartialEq for PackedSource {
    fn eq(&self, other: &PackedSource) -> bool {
        self.words() == other.words()
    }
}

/// One packed linear **base** layer: codes + dequant params. Adapter-free —
/// the LoRA delta is a per-request [`LoraPair`] argument.
#[derive(Clone, Debug)]
pub struct PackedLayer {
    pub name: String,
    /// Input features m (rows of W).
    pub rows: usize,
    /// Output features n (cols of W).
    pub cols: usize,
    pub bits: u32,
    /// Input rows sharing one scale/zero (or absmax) entry.
    pub group_size: usize,
    /// Row-aligned packed codes: row `i` is words
    /// `[i·words_per_row, (i+1)·words_per_row)`. Owned for everything
    /// built in process or loaded through the copy path; mapped pages
    /// for v3 zero-copy artifacts (see [`PackedSource`]).
    pub packed: PackedSource,
    pub params: DequantParams,
}

/// Are two optional adapter references the same adapter? (`None` = base
/// only; `Some`s compare by address — the grouped kernel keys groups on
/// identity, never on value equality.) Shared with the engine's group
/// accounting (`serve::engine`) so the reported group count can never
/// drift from the grouping the kernel actually executes.
pub(crate) fn same_adapter(a: Option<&LoraPair>, b: Option<&LoraPair>) -> bool {
    match (a, b) {
        (None, None) => true,
        (Some(x), Some(y)) => std::ptr::eq(x, y),
        _ => false,
    }
}

impl PackedLayer {
    /// Pack an exact quantization state.
    pub fn from_state(name: &str, qs: &QuantState) -> Result<PackedLayer, ServeError> {
        let (rows, cols) = (qs.rows(), qs.cols());
        if rows < 1 || cols < 1 {
            return Err(ServeError::ShapeMismatch {
                layer: name.to_string(),
                detail: format!("degenerate shape {rows}x{cols}"),
            });
        }
        let (bits, group_size, codes, params) = match qs {
            QuantState::Int(q) => (
                q.bits,
                q.group_size,
                &q.codes,
                DequantParams::Grid { scales: q.scales.clone(), zeros: q.zeros.clone() },
            ),
            QuantState::Nf(q) => (
                q.bits,
                q.block_size,
                &q.codes,
                DequantParams::Codebook { levels: q.levels.clone(), absmax: q.absmax.clone() },
            ),
        };
        let wpr = words_per_row(cols, bits);
        let mut packed = Vec::with_capacity(rows * wpr);
        for i in 0..rows {
            packed.extend_from_slice(&pack_codes(&codes[i * cols..(i + 1) * cols], bits));
        }
        debug_assert_eq!(packed.len(), rows * wpr);
        Ok(PackedLayer {
            name: name.to_string(),
            rows,
            cols,
            bits,
            group_size,
            packed: packed.into(),
            params,
        })
    }

    /// Check this layer's code section integrity — free for owned codes,
    /// a one-time lazy CRC for mapped v3 sections (see
    /// [`PackedSource::verify`]). The engine calls this before the first
    /// kernel touch of a batch so a corrupt mapped artifact surfaces as a
    /// typed `ChecksumMismatch` naming the layer, never as garbage math.
    pub fn verify(&self) -> Result<(), ServeError> {
        self.packed.verify(&self.name)
    }

    /// Whether the next [`PackedLayer::verify`] will run the one-time
    /// lazy CRC pass (see [`PackedSource::crc_pending`]).
    pub fn crc_pending(&self) -> bool {
        self.packed.crc_pending()
    }

    /// Pack a [`LayerInit`] into its two serving halves: the frozen base
    /// and the extracted adapter. Errors actionably when the method kept an
    /// fp base and there is no quantization state to pack.
    pub fn from_layer_init(
        name: &str,
        method: Method,
        li: &LayerInit,
    ) -> Result<(PackedLayer, LoraPair), ServeError> {
        let qs = li.quant.as_ref().ok_or_else(|| ServeError::Unsupported {
            detail: format!(
                "layer '{name}': method {} keeps the fp base and produced no packed \
                 quantization state; re-grid it for serving (e.g. \
                 QuantState::Int(quantize_rtn(&li.q_deq, 8, group_size))) or pick a \
                 quantized method",
                method.name()
            ),
        })?;
        let base = Self::from_state(name, qs)?;
        let pair = li.lora_pair();
        base.check_adapter(&pair)?;
        Ok((base, pair))
    }

    /// Validate that `pair` fits this base layer (A: rows×r, B: cols×r).
    pub fn check_adapter(&self, pair: &LoraPair) -> Result<(), ServeError> {
        if pair.a.rows == self.rows && pair.b.rows == self.cols && pair.a.cols == pair.b.cols {
            return Ok(());
        }
        Err(ServeError::ShapeMismatch {
            layer: self.name.clone(),
            detail: format!(
                "adapter {}x{} / {}x{} does not fit base {}x{}",
                pair.a.rows, pair.a.cols, pair.b.rows, pair.b.cols, self.rows, self.cols,
            ),
        })
    }

    /// Reconstruct the exact quantization state (the artifact roundtrip
    /// tests assert this is byte-identical to what was packed).
    pub fn to_state(&self) -> anyhow::Result<QuantState> {
        let wpr = words_per_row(self.cols, self.bits);
        let mut codes = Vec::with_capacity(self.rows * self.cols);
        for i in 0..self.rows {
            codes.extend(try_unpack_codes(
                &self.packed.words()[i * wpr..(i + 1) * wpr],
                self.bits,
                self.cols,
            )?);
        }
        Ok(match &self.params {
            DequantParams::Grid { scales, zeros } => QuantState::Int(QuantizedTensor {
                bits: self.bits,
                group_size: self.group_size,
                rows: self.rows,
                cols: self.cols,
                codes,
                scales: scales.clone(),
                zeros: zeros.clone(),
            }),
            DequantParams::Codebook { levels, absmax } => QuantState::Nf(NfQuantized {
                bits: self.bits,
                block_size: self.group_size,
                rows: self.rows,
                cols: self.cols,
                codes,
                absmax: absmax.clone(),
                levels: levels.clone(),
            }),
        })
    }

    /// Dense dequantized base (reference / debugging; the serving hot path
    /// never calls this).
    pub fn dequantize(&self) -> anyhow::Result<Matrix> {
        Ok(self.to_state()?.dequantize())
    }

    /// Unpack + dequantize row `i`, feeding each `(j, value)` to `sink` in
    /// ascending-j order with the exact op sequence of
    /// `QuantState::dequantize`. The ONE implementation of the dequant
    /// loops — `forward` folds values into `y` in-register, the batched
    /// path writes them to its row scratch; a single body means the 0-ULP
    /// parity contract cannot drift between the two.
    #[inline]
    fn for_each_dequant(&self, i: usize, mut sink: impl FnMut(usize, f64)) {
        let wpr = words_per_row(self.cols, self.bits);
        let per_word = 32 / self.bits as usize;
        let mask = ((1u64 << self.bits) - 1) as u32;
        let g = i / self.group_size;
        let words = &self.packed.words()[i * wpr..(i + 1) * wpr];
        match &self.params {
            DequantParams::Grid { scales, zeros } => {
                let srow = scales.row(g);
                let zrow = zeros.row(g);
                let mut j = 0usize;
                'row: for &word in words {
                    for k in 0..per_word {
                        if j == self.cols {
                            break 'row;
                        }
                        let c = ((word >> (k as u32 * self.bits)) & mask) as f64;
                        sink(j, (c - zrow[j]) * srow[j]);
                        j += 1;
                    }
                }
            }
            DequantParams::Codebook { levels, absmax } => {
                let arow = absmax.row(g);
                let mut j = 0usize;
                'row: for &word in words {
                    for k in 0..per_word {
                        if j == self.cols {
                            break 'row;
                        }
                        let c = ((word >> (k as u32 * self.bits)) & mask) as usize;
                        sink(j, levels[c] * arow[j]);
                        j += 1;
                    }
                }
            }
        }
    }

    /// `y += B·(Aᵀx)` — the two skinny products, shared verbatim by the
    /// fused and dense reference paths so LoRA handling can never break
    /// parity. Rank-0 pairs are skipped entirely (adding 0.0 would still
    /// flip a −0.0 base output).
    fn add_lora(&self, y: &mut [f64], x: &[f64], pair: &LoraPair) {
        if pair.rank() == 0 {
            return;
        }
        let t = matvec_t(&pair.a, x);
        for (j, yj) in y.iter_mut().enumerate() {
            *yj += dot(&t, pair.b.row(j));
        }
    }

    /// Fused packed forward for one request: unpack → dequant → dot in one
    /// pass over the packed words, never materializing the dense base, plus
    /// the factored delta of `lora` when one is given. Bit-identical to
    /// [`PackedLayer::dense_reference_forward`] on the layer's own
    /// dequantized base (the parity contract in the module docs).
    pub fn forward(&self, x: &[f64], lora: Option<&LoraPair>) -> Vec<f64> {
        assert_eq!(x.len(), self.rows, "layer '{}': input len vs rows", self.name);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue; // matvec_t's skip — keeps the op sequences identical
            }
            self.for_each_dequant(i, |j, v| y[j] += xi * v);
        }
        if let Some(pair) = lora {
            self.add_lora(&mut y, x, pair);
        }
        y
    }

    /// Micro-batched forward with ONE adapter (or none) for every request:
    /// `Y[b] = forward(X[b], lora)`. A thin wrapper over the grouped kernel
    /// with a single group — one kernel body, so the uniform and the
    /// mixed-adapter paths cannot drift apart.
    pub fn forward_batch(&self, xs: &Matrix, lora: Option<&LoraPair>) -> Matrix {
        self.forward_batch_grouped(xs, &vec![lora; xs.rows])
    }

    /// Micro-batched forward over a batch whose rows may belong to
    /// DIFFERENT adapters: `adapters[b]` is request `b`'s pair (`None` =
    /// base only). Every packed base row is unpacked + dequantized ONCE and
    /// reused across the whole batch — the amortization the engine's
    /// coalescer exists to harvest — while the LoRA t-product runs as one
    /// skinny GEMM (`X_g·A`) per consecutive same-adapter group, whose
    /// per-element accumulation order equals the serial `matvec_t` (blas
    /// determinism contract). Bit-identical to `xs.rows` serial
    /// [`PackedLayer::forward`] calls, whatever the adapter mix.
    ///
    /// Callers wanting the fewest groups should order the batch so
    /// same-adapter requests are adjacent (the engine's batcher does).
    pub fn forward_batch_grouped(&self, xs: &Matrix, adapters: &[Option<&LoraPair>]) -> Matrix {
        assert_eq!(xs.cols, self.rows, "layer '{}': batch cols vs rows", self.name);
        assert_eq!(
            adapters.len(),
            xs.rows,
            "layer '{}': one adapter slot per batch row",
            self.name
        );
        let (batch, n) = (xs.rows, self.cols);
        let mut ys = Matrix::zeros(batch, n);
        let mut wrow = vec![0.0; n];
        for i in 0..self.rows {
            self.for_each_dequant(i, |j, v| wrow[j] = v);
            for bi in 0..batch {
                let xi = xs.at(bi, i);
                if xi == 0.0 {
                    continue;
                }
                axpy(ys.row_mut(bi), xi, &wrow);
            }
        }
        let mut g0 = 0usize;
        while g0 < batch {
            let mut g1 = g0 + 1;
            while g1 < batch && same_adapter(adapters[g0], adapters[g1]) {
                g1 += 1;
            }
            if let Some(pair) = adapters[g0] {
                if pair.rank() > 0 {
                    let xg = xs.rows_range(g0, g1);
                    // (g1-g0)×r, same per-element order as matvec_t.
                    let t = matmul(&xg, &pair.a);
                    for bi in g0..g1 {
                        let trow = t.row(bi - g0);
                        let yrow = ys.row_mut(bi);
                        for (j, yj) in yrow.iter_mut().enumerate() {
                            *yj += dot(trow, pair.b.row(j));
                        }
                    }
                }
            }
            g0 = g1;
        }
        ys
    }

    /// The dense reference the parity suite pins the fused kernel against:
    /// a plain `matvec_t` over a pre-materialized `q_deq` plus the same
    /// factored LoRA product.
    pub fn dense_reference_forward(
        &self,
        q_deq: &Matrix,
        x: &[f64],
        lora: Option<&LoraPair>,
    ) -> Vec<f64> {
        assert_eq!(q_deq.rows, self.rows);
        assert_eq!(q_deq.cols, self.cols);
        let mut y = matvec_t(q_deq, x);
        if let Some(pair) = lora {
            self.add_lora(&mut y, x, pair);
        }
        y
    }

    /// Packed base storage footprint in bytes (codes + dequant params;
    /// adapters are accounted separately by `AdapterSet::bytes`) — reported
    /// by the engine and the bench harness.
    pub fn packed_bytes(&self) -> usize {
        let params = match &self.params {
            DequantParams::Grid { scales, zeros } => (scales.data.len() + zeros.data.len()) * 8,
            DequantParams::Codebook { levels, absmax } => (levels.len() + absmax.data.len()) * 8,
        };
        self.packed.len() * 4 + params
    }
}

/// A served model: packed base layers addressable by name.
#[derive(Clone, Debug, Default)]
pub struct PackedModel {
    pub layers: Vec<PackedLayer>,
}

impl PackedModel {
    pub fn new(layers: Vec<PackedLayer>) -> PackedModel {
        PackedModel { layers }
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.layers.iter().position(|l| l.name == name)
    }

    pub fn layer(&self, name: &str) -> Option<&PackedLayer> {
        self.index_of(name).map(|i| &self.layers[i])
    }

    /// Intern a layer name into its [`LayerId`] handle. Resolve once, then
    /// submit/route by id — the typed façade's hot path never hashes or
    /// clones names. (This scan is linear; `ServeEngine::layer` resolves
    /// through its O(1) index.)
    pub fn resolve(&self, name: &str) -> Result<LayerId, ServeError> {
        self.index_of(name)
            .map(LayerId::new)
            .ok_or_else(|| ServeError::UnknownLayer { layer: name.to_string() })
    }

    /// The layer behind an interned id (`None` when the id was resolved
    /// against a different, larger model).
    pub fn get(&self, id: LayerId) -> Option<&PackedLayer> {
        self.layers.get(id.index())
    }

    /// Total packed base bytes across layers.
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.packed_bytes()).sum()
    }

    /// Resolve an ordered forward route of layer names into a validated
    /// [`Route`] (see [`PackedModel::validate_route`]). Layers may repeat
    /// (a square layer applied twice is a legal route).
    pub fn route<S: AsRef<str>>(&self, names: &[S]) -> Result<Route, ServeError> {
        let mut ids = Vec::with_capacity(names.len());
        for name in names {
            ids.push(self.resolve(name.as_ref())?);
        }
        self.validate_route(&ids)?;
        Ok(Route::from_validated(ids))
    }

    /// Validate a forward route against the packed shapes: non-empty,
    /// in-range, and CHAINABLE — each layer's output width (`cols`) must
    /// equal the next layer's input width (`rows`), because hop `k+1`
    /// consumes hop `k`'s activation verbatim. Errors name both ends of
    /// the first break.
    pub fn validate_route(&self, ids: &[LayerId]) -> Result<(), ServeError> {
        if ids.is_empty() {
            return Err(ServeError::BadRoute { detail: "forward route is empty".to_string() });
        }
        for &id in ids {
            if id.index() >= self.layers.len() {
                return Err(ServeError::BadRoute {
                    detail: format!(
                        "route layer index {} out of range ({} layers) — id resolved \
                         against a different model?",
                        id.index(),
                        self.layers.len()
                    ),
                });
            }
        }
        for w in ids.windows(2) {
            let (a, b) = (&self.layers[w[0].index()], &self.layers[w[1].index()]);
            if a.cols != b.rows {
                return Err(ServeError::BadRoute {
                    detail: format!(
                        "route break between '{}' ({} features out) and '{}' (takes {} \
                         features in)",
                        a.name, a.cols, b.name, b.rows
                    ),
                });
            }
        }
        Ok(())
    }

    /// Build the serving halves straight from a `quantize_init` result: the
    /// packed base from the exact f64 quantization states, and one
    /// [`AdapterSet`] (named `adapter_id`) holding the adapters from the
    /// f32 LoRA store. The f32→f64 widening is lossless, but the adapter
    /// VALUES are the f32-rounded ones the trainer itself consumes — served
    /// outputs match the trainer's adapters exactly, and may differ in
    /// low-order bits from the init-time f64 `LayerInit.a`/`b` (use
    /// [`PackedLayer::from_layer_init`] to serve those). The 0-ULP parity
    /// contract is per layer, against its own packed state and adapters,
    /// and holds on either path.
    ///
    /// Requires `quantize_init(.., keep_exact = true, ..)`; errors
    /// actionably otherwise.
    ///
    /// [`AdapterSet`]: crate::serve::adapters::AdapterSet
    pub fn from_model_init(
        init: &crate::coordinator::ModelInit,
        adapter_id: &str,
    ) -> Result<(PackedModel, crate::serve::adapters::AdapterSet), ServeError> {
        let exact = init.exact.as_ref().ok_or_else(|| ServeError::Unsupported {
            detail: "ModelInit carries no exact serving states: quantize_init was called \
                     with keep_exact = false (the train/eval-sweep mode); re-run it with \
                     keep_exact = true to build a packed serving model"
                .to_string(),
        })?;
        let mut layers = Vec::with_capacity(exact.len());
        let mut pairs = Vec::with_capacity(exact.len());
        for (name, qs) in exact {
            let (ka, kb) = (format!("{name}.A"), format!("{name}.B"));
            if !init.lora.contains(&ka) || !init.lora.contains(&kb) {
                return Err(ServeError::InvalidConfig {
                    detail: format!(
                        "layer '{name}': adapters {ka}/{kb} missing from the init's LoRA \
                         store"
                    ),
                });
            }
            let a = init.lora.get(&ka).to_matrix();
            let b = init.lora.get(&kb).to_matrix();
            let layer = PackedLayer::from_state(name, qs)?;
            let pair = LoraPair::new(a, b);
            layer.check_adapter(&pair)?;
            layers.push(layer);
            pairs.push((name.clone(), pair));
        }
        let model = PackedModel { layers };
        let set = crate::serve::adapters::AdapterSet::from_pairs(adapter_id, pairs)?;
        set.check_against(&model)?;
        Ok((model, set))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize_rtn;
    use crate::util::prng::Rng;

    fn mk_layer(
        m: usize,
        n: usize,
        bits: u32,
        gs: usize,
        r: usize,
        seed: u64,
    ) -> (PackedLayer, LoraPair, Matrix) {
        let mut rng = Rng::new(seed);
        let w = Matrix::randn(m, n, 0.3, &mut rng);
        let q = quantize_rtn(&w, bits, gs);
        let q_deq = q.dequantize();
        let a = Matrix::randn(m, r, 0.1, &mut rng);
        let b = Matrix::randn(n, r, 0.1, &mut rng);
        let l = PackedLayer::from_state("t", &QuantState::Int(q)).unwrap();
        (l, LoraPair::new(a, b), q_deq)
    }

    #[test]
    fn fused_forward_bit_exact_vs_dense_reference() {
        let mut rng = Rng::new(200);
        for &(m, n, bits, gs) in
            &[(10usize, 3usize, 2u32, 4usize), (70, 37, 3, 32), (64, 64, 4, 64), (33, 10, 8, 7)]
        {
            let (l, pair, q_deq) = mk_layer(m, n, bits, gs, 4, 201);
            let x = rng.gauss_vec(m);
            let fused = l.forward(&x, Some(&pair));
            let dense = l.dense_reference_forward(&q_deq, &x, Some(&pair));
            for (u, v) in fused.iter().zip(&dense) {
                assert_eq!(u.to_bits(), v.to_bits(), "{m}x{n} bits={bits} gs={gs}");
            }
        }
    }

    #[test]
    fn batch_bit_exact_vs_serial() {
        let (l, pair, _) = mk_layer(48, 19, 3, 16, 5, 202);
        let mut rng = Rng::new(203);
        let xs = Matrix::randn(6, 48, 1.0, &mut rng);
        let ys = l.forward_batch(&xs, Some(&pair));
        for bi in 0..6 {
            let y = l.forward(xs.row(bi), Some(&pair));
            for (u, v) in ys.row(bi).iter().zip(&y) {
                assert_eq!(u.to_bits(), v.to_bits(), "row {bi}");
            }
        }
    }

    #[test]
    fn grouped_batch_bit_exact_vs_serial_per_adapter() {
        // Three adapters interleaved in one batch: every row must carry its
        // own adapter's delta, bit-identical to the serial call.
        let (l, pair0, _) = mk_layer(40, 17, 4, 8, 3, 206);
        let mut rng = Rng::new(207);
        let pair1 = LoraPair::new(
            Matrix::randn(40, 5, 0.2, &mut rng),
            Matrix::randn(17, 5, 0.2, &mut rng),
        );
        let xs = Matrix::randn(7, 40, 1.0, &mut rng);
        let slots: Vec<Option<&LoraPair>> =
            vec![Some(&pair0), Some(&pair0), None, Some(&pair1), Some(&pair1), None, Some(&pair0)];
        let ys = l.forward_batch_grouped(&xs, &slots);
        for (bi, slot) in slots.iter().enumerate() {
            let y = l.forward(xs.row(bi), *slot);
            for (u, v) in ys.row(bi).iter().zip(&y) {
                assert_eq!(u.to_bits(), v.to_bits(), "row {bi}");
            }
        }
    }

    #[test]
    fn state_roundtrip_is_exact() {
        let (l, _, q_deq) = mk_layer(30, 11, 2, 8, 3, 204);
        let qs = l.to_state().unwrap();
        assert_eq!(qs.dequantize().data, q_deq.data);
        match qs {
            QuantState::Int(q) => {
                assert_eq!(q.rows, 30);
                assert_eq!(q.cols, 11);
            }
            _ => panic!("grid state expected"),
        }
    }

    #[test]
    fn no_adapter_serves_base_only() {
        let (l, _, q_deq) = mk_layer(16, 8, 4, 8, 2, 205);
        let x = Rng::new(206).gauss_vec(16);
        let y = l.forward(&x, None);
        let y_ref = crate::linalg::matvec_t(&q_deq, &x);
        assert_eq!(y, y_ref);
        let ys = l.forward_batch(&Matrix::from_vec(1, 16, x), None);
        assert_eq!(ys.data, y_ref);
    }

    #[test]
    fn route_validation_checks_chainability() {
        let mut rng = Rng::new(208);
        let mut layers = Vec::new();
        for (name, m, n) in [("a", 12usize, 8usize), ("b", 8, 12), ("c", 5, 5)] {
            let w = Matrix::randn(m, n, 0.3, &mut rng);
            layers.push(
                PackedLayer::from_state(name, &QuantState::Int(quantize_rtn(&w, 4, 4))).unwrap(),
            );
        }
        let model = PackedModel::new(layers);
        // Chainable, including a repeated layer (a→b is 12→8→12, so a can
        // run again) — and a single-layer route is trivially valid.
        let r = model.route(&["a", "b", "a", "b"]).unwrap();
        let idxs: Vec<usize> = r.as_ids().iter().map(|id| id.index()).collect();
        assert_eq!(idxs, [0, 1, 0, 1]);
        assert_eq!(r.len(), 4);
        assert!(!r.is_empty());
        assert_eq!(model.route(&["c"]).unwrap().as_ids(), [model.resolve("c").unwrap()]);
        // Breaks name both ends with their widths, as a typed BadRoute.
        let err = model.route(&["a", "c"]).unwrap_err();
        assert!(matches!(err, ServeError::BadRoute { .. }), "{err:?}");
        let msg = format!("{err}");
        assert!(msg.contains("route break"), "{msg}");
        assert!(msg.contains("'a' (8 features out)"), "{msg}");
        assert!(msg.contains("'c' (takes 5 features in)"), "{msg}");
        // Unknown names and empty routes are admission errors too.
        let err = model.route(&["ghost"]).unwrap_err();
        assert!(matches!(&err, ServeError::UnknownLayer { layer } if layer == "ghost"), "{err}");
        let err = model.route::<&str>(&[]).unwrap_err();
        assert!(format!("{err}").contains("route is empty"), "{err}");
        let err = model.validate_route(&[LayerId::new(0), LayerId::new(99)]).unwrap_err();
        assert!(format!("{err}").contains("out of range"), "{err}");
    }

    #[test]
    fn adapter_shape_mismatch_rejected() {
        let mut rng = Rng::new(207);
        let w = Matrix::randn(12, 6, 0.3, &mut rng);
        let q = QuantState::Int(quantize_rtn(&w, 4, 8));
        let l = PackedLayer::from_state("bad", &q).unwrap();
        let pair = LoraPair::new(Matrix::zeros(12, 2), Matrix::zeros(5, 2)); // cols must be 6
        let err = l.check_adapter(&pair).unwrap_err();
        assert!(format!("{err}").contains("bad"), "{err}");
    }
}

//! The packed-weight serving subsystem — the inference path CLoQ's
//! quantize+init stage exists to feed.
//!
//! After `quantize_init` produces a frozen INT base plus calibrated LoRA
//! adapters, serving must consume that state **as quantized**: the memory
//! win (2–8 bits/weight instead of 64) evaporates if the server
//! re-materializes dense weights per layer. And because CLoQ's output is
//! exactly one frozen base plus a cheap per-task adapter pair, the server
//! is **multi-tenant**: the packed base loads once, and every request
//! routes to one of many hot-swappable adapters. This module provides the
//! five pieces:
//!
//! * [`packed`] — [`PackedLayer`]/[`PackedModel`]: the base half — codes
//!   bit-packed into u32 words plus a **fused unpack→dequant→dot forward
//!   kernel** that applies a caller-supplied `LoraPair` delta as two
//!   skinny products (`y = Q̂ᵀx + B(Aᵀx)`), including a grouped batch
//!   kernel for mixed-adapter micro-batches, and forward-route validation
//!   (name resolution + output/input width chaining). Bit-identical to
//!   the dense `q_deq` reference — the parity contract is spelled out in
//!   the module docs and enforced by `rust/tests/parity_serve.rs`.
//! * [`adapters`] — [`AdapterSet`]/[`AdapterRegistry`]: the tenant half —
//!   named per-layer LoRA collections with register/unregister/hot-swap
//!   under load, pin-counted checkouts, LRU eviction under a byte budget,
//!   and a blocking per-adapter drain (`rust/tests/lifecycle_adapters.rs`).
//! * [`artifact`] — versioned binary checkpoints with per-layer CRC-32
//!   validation and corruption errors that name the offending layer
//!   (`rust/tests/golden_serve.rs`): the v2 `CLOQPKD2` **base** artifact
//!   (no LoRA payloads), the small `CLOQADP1` **adapter** artifact so new
//!   tenants ship without re-shipping the base, and a v1 (`CLOQPKD1`)
//!   compatibility reader that converts old single-tenant files into
//!   base + one adapter set.
//! * [`engine`] — [`ServeEngine`]: a batching front-end on the persistent
//!   `util::threadpool::WorkerPool` that coalesces concurrent requests
//!   into per-layer micro-batches (grouping same-adapter requests inside
//!   each batch), with hop-aware backpressure and a drain-aware shutdown,
//!   and reports per-request latency plus aggregate throughput counters.
//! * [`forward`] — [`ModelRequest`]/[`SessionRequest`]: **full-model
//!   pipelined forwards**. A request names an ordered layer route (from
//!   `model::ModelConfig::forward_route` or hand-built); the engine
//!   decomposes it into per-layer hops that re-enter the batcher's FIFO
//!   after each micro-batch, so concurrent model requests at the same
//!   depth coalesce into shared grouped kernel calls — continuous
//!   batching for the layer chain. Sessions run N sequential forwards
//!   with a caller step function between them (the autoregressive-decode
//!   shape), entirely inside the engine, with per-session stats in the
//!   [`ModelResponse`]. Bit-identical (0 ULP) to the caller-driven serial
//!   reference [`forward_route_serial`] — enforced by
//!   `rust/tests/parity_forward.rs`, with shutdown/overload/panic
//!   semantics in `rust/tests/lifecycle_forward.rs`.
//!
//! Benchmarks: `cargo bench --bench bench_serve` writes `BENCH_serve.json`
//! (fused vs dense forward, batched vs serial throughput),
//! `cargo bench --bench bench_adapters` writes `BENCH_adapters.json`
//! (adapter-count sweep, mixed-batch penalty, eviction churn), and
//! `cargo bench --bench bench_forward` writes `BENCH_forward.json`
//! (pipelined vs caller-driven-serial full-model throughput across
//! concurrent session counts, mixed-adapter sweep) — see EXPERIMENTS.md
//! §Serve, §Adapters and §Forward.

pub mod adapters;
pub mod artifact;
pub mod engine;
pub mod forward;
pub mod packed;

pub use adapters::{
    AdapterHandle, AdapterRegistry, AdapterSet, RegisterOutcome, RegistryStats,
};
pub use artifact::{
    crc32, load_adapter_artifact, load_artifact_compat, load_base_artifact,
    save_adapter_artifact, save_artifact_v1, save_base_artifact,
};
pub use engine::{EngineConfig, EngineStats, Request, Response, ServeEngine, Ticket};
pub use forward::{
    forward_route_serial, ModelRequest, ModelResponse, ModelTicket, SessionRequest, StepFn,
};
pub use packed::{words_per_row, DequantParams, PackedLayer, PackedModel};

//! The packed-weight serving subsystem — the inference path CLoQ's
//! quantize+init stage exists to feed.
//!
//! After `quantize_init` produces a frozen INT base plus calibrated LoRA
//! adapters, serving must consume that state **as quantized**: the memory
//! win (2–8 bits/weight instead of 64) evaporates if the server
//! re-materializes dense weights per layer. This module provides the three
//! pieces:
//!
//! * [`packed`] — [`PackedLayer`]/[`PackedModel`]: codes bit-packed into
//!   u32 words plus a **fused unpack→dequant→dot forward kernel** with the
//!   LoRA delta as two skinny products (`y = Q̂ᵀx + B(Aᵀx)`). The kernel is
//!   bit-identical to the dense `q_deq` reference — the parity contract is
//!   spelled out in the module docs and enforced by
//!   `rust/tests/parity_serve.rs`.
//! * [`artifact`] — one versioned binary checkpoint for the whole packed
//!   model, with per-layer CRC-32 validation and corruption errors that
//!   name the offending layer (`rust/tests/golden_serve.rs`).
//! * [`engine`] — [`ServeEngine`]: a batching front-end on the persistent
//!   `util::threadpool::WorkerPool` that coalesces concurrent requests
//!   into per-layer micro-batches and reports per-request latency plus
//!   aggregate throughput counters.
//!
//! Benchmarks: `cargo bench --bench bench_serve` writes `BENCH_serve.json`
//! (fused vs dense forward, batched vs serial throughput) — see
//! EXPERIMENTS.md §Serve.

pub mod artifact;
pub mod engine;
pub mod packed;

pub use artifact::{crc32, load_artifact, save_artifact};
pub use engine::{EngineConfig, EngineStats, Response, ServeEngine, Ticket};
pub use packed::{words_per_row, DequantParams, PackedLayer, PackedModel};

//! The packed-weight serving subsystem — the inference path CLoQ's
//! quantize+init stage exists to feed, behind one **typed façade**.
//!
//! After `quantize_init` produces a frozen INT base plus calibrated LoRA
//! adapters, serving must consume that state **as quantized**: the memory
//! win (2–8 bits/weight instead of 64) evaporates if the server
//! re-materializes dense weights per layer. And because CLoQ's output is
//! exactly one frozen base plus a cheap per-task adapter pair, the server
//! is **multi-tenant**: the packed base loads once, and every request
//! routes to one of many hot-swappable adapters.
//!
//! # The façade, in one sitting
//!
//! ```ignore
//! // Build: validated knobs, no bare config structs.
//! let engine = ServeEngine::builder(model)
//!     .workers(4).max_batch(32).max_pending(8192)
//!     .adapter_budget(512 << 20)
//!     .build()?;
//!
//! // Intern once: names become Copy handles; the hot path never hashes
//! // or clones a string again.
//! let wq = engine.layer("blk0.wq")?;                 // LayerId
//! let tenant = engine.register_adapter(set)?.id;     // AdapterId
//! let route = engine.route(&model_cfg.forward_route())?; // Route (chain-checked)
//!
//! // Submit by handle; failures are typed, not stringly.
//! match engine.submit(wq, Some(tenant), x).wait() {
//!     Ok(resp) => consume(resp.y),
//!     Err(ServeError::Overloaded { .. }) => retry_later(),
//!     Err(ServeError::ShuttingDown) => reroute(),
//!     Err(e) => fail_tenant(e),
//! }
//!
//! // Artifacts: one store, four formats, autodetected on open.
//! let store = ArtifactStore::at("/srv/cloq");
//! store.save_base_v3(&model, "base.cloqpkd3")?;   // page-aligned, mmap-able
//! store.save_adapter(&set, "tenant-a.cloqadp")?;
//! let m = store.open_mapped("base.cloqpkd3")?;    // zero-copy cold start
//! match store.open("anything.bin")? {
//!     Artifact::Base(m) => serve(m),
//!     Artifact::Adapter(s) => register(s),
//!     Artifact::LegacyV1 { model, adapters } => migrate(model, adapters),
//! }
//!
//! // Durability: a crash-safe engine replays its adapter WAL on boot.
//! let engine = ServeEngine::builder(model).durable("/srv/cloq/state").build()?;
//! ```
//!
//! # The pieces
//!
//! * [`error`] — [`ServeError`] / [`ArtifactErrorKind`]: the structured
//!   error taxonomy every public failure path resolves to (admission
//!   refusals, overload, shutdown, kernel panics, artifact corruption),
//!   matched with `matches!` instead of string search and convertible
//!   into `anyhow` for offline callers (`rust/tests/errors_serve.rs`).
//! * [`packed`] — [`PackedLayer`]/[`PackedModel`]: the base half — codes
//!   bit-packed into u32 words plus a **fused unpack→dequant→dot forward
//!   kernel** that applies a caller-supplied `LoraPair` delta as two
//!   skinny products (`y = Q̂ᵀx + B(Aᵀx)`), including a grouped batch
//!   kernel for mixed-adapter micro-batches. [`LayerId`] interns layer
//!   names; [`Route`] is a pre-validated, cheaply-cloneable forward route.
//!   Bit-identical to the dense `q_deq` reference — the parity contract is
//!   spelled out in the module docs and enforced by
//!   `rust/tests/parity_serve.rs`.
//! * [`adapters`] — [`AdapterSet`]/[`AdapterId`]/[`AdapterRegistry`]: the
//!   tenant half — named per-layer LoRA collections registered into a
//!   model-bound registry that interns ids into stable slots,
//!   shape-checks at registration, resolves each set into a per-layer
//!   table (per-hop adapter lookup = one array index), pin-counts
//!   checkouts, LRU-evicts under a byte budget, and drains on unregister
//!   (`rust/tests/lifecycle_adapters.rs`).
//! * [`artifact`] — [`ArtifactStore`]/[`Artifact`]: versioned binary
//!   checkpoints with per-layer CRC-32 validation and typed corruption
//!   errors that name the offending layer and classify the failure
//!   (`rust/tests/golden_serve.rs`): the v2 `CLOQPKD2` **base** artifact
//!   (no LoRA payloads), the small `CLOQADP1` **adapter** artifact so new
//!   tenants ship without re-shipping the base, and the legacy `CLOQPKD1`
//!   reader — all behind one magic-autodetecting `open`. The
//!   **zero-copy v3** `CLOQPKD3` base artifact page-aligns its packed
//!   code sections so `ArtifactStore::open_mapped` serves them straight
//!   out of `mmap`ed pages ([`mmap`]/[`MappedFile`]) — no copy, no
//!   up-front CRC pass; each mapped section verifies lazily on first
//!   touch with a typed [`ServeError::Artifact`] naming the layer.
//! * [`wal`] — [`Wal`]/[`WalFile`]: the **crash-safe adapter WAL**.
//!   Durable engines ([`ServeEngineBuilder::durable`]) log every adapter
//!   register / hot-swap / unregister before applying it and replay the
//!   log on boot; whatever prefix of the log survives a crash, recovery
//!   yields exactly a prefix of the committed operations and bit-identical
//!   weights for every surviving tenant (`rust/tests/crash_wal.rs`).
//! * [`engine`] — [`ServeEngine`]: a batching front-end that coalesces
//!   concurrent requests into per-layer micro-batches (grouping
//!   same-adapter requests inside each batch), with hop-aware
//!   backpressure, a non-blocking [`ServeEngine::close`] and a drain-aware
//!   [`ServeEngine::shutdown`], configured through
//!   [`ServeEngine::builder`]. Two dispatch cores behind one knob
//!   ([`Dispatch`]): the default **sharded work-stealing** core — per-layer
//!   queue shards owned by the workers themselves, lock-free admission
//!   accounting, idle workers stealing the oldest batchable group — and
//!   the single-FIFO **global batcher** reference core (on the persistent
//!   `util::threadpool::WorkerPool`), kept as the parity baseline and
//!   `bench_contention` comparison row. Batch composition never changes
//!   response bits in either core, so the choice is purely contention
//!   behavior.
//! * [`forward`] — [`ModelRequest`]/[`SessionRequest`]: **full-model
//!   pipelined forwards**. A request carries a [`Route`]; the engine
//!   decomposes it into per-layer hops that re-enter the batcher's FIFO
//!   after each micro-batch, so concurrent model requests at the same
//!   depth coalesce into shared grouped kernel calls — continuous
//!   batching for the layer chain. Sessions run N sequential forwards
//!   with a caller step function between them (the autoregressive-decode
//!   shape). Bit-identical (0 ULP) to the caller-driven serial reference
//!   [`forward_route_serial`] — enforced by `rust/tests/parity_forward.rs`,
//!   with shutdown/overload/panic semantics in
//!   `rust/tests/lifecycle_forward.rs`.
//! * [`generate`] — [`GenRequest`]/[`GenTicket`]: **token-level
//!   generation**, the autoregressive-decode workload the engine exists
//!   for. [`ServeEngine::generate`] tokenizes a prompt with the byte-level
//!   seed tokenizer, runs prefill, and drives a per-token decode loop
//!   (logits → [`Sampler`] → absorb → re-enter) INSIDE the hop machinery,
//!   so concurrent generations continuously batch at token granularity.
//!   Deterministic sampling (greedy / temperature / top-k on a seeded
//!   per-session RNG stream), typed stop conditions (EOS / max-tokens /
//!   stop-string / cancel), and per-session state behind the
//!   [`SessionState`] trait (a real KV cache can slot in later). The
//!   ticket is a [`Completion`] twice over: per token via
//!   [`GenTicket::next_token`] and whole-response via the ticket itself.
//!   Greedy decode through the batcher is bit-identical (0 ULP) to the
//!   serial reference [`generate_serial`] — across adapters, hot-swaps,
//!   and concurrent sessions (`rust/tests/parity_generate.rs`).
//! * [`telemetry`] — [`Telemetry`]/[`TelemetrySnapshot`]: the engine's
//!   **observability core**. Per-worker sharded atomic counters and
//!   log-scale latency histograms (queue wait, kernel compute, per-hop,
//!   end-to-end wall, WAL fsync, artifact open) that the hot path updates
//!   with relaxed atomics — no mutex, no allocation — merged only when a
//!   snapshot is taken. Per-layer and per-adapter breakdowns are indexed
//!   by the interned [`LayerId`]/[`AdapterId`] slots (no hashing).
//!   Request **lifecycle tracing** records timestamped span events
//!   (admitted → queued → hop N → replied) into bounded recent/slow
//!   rings, with automatic capture + `warn!` logging of requests over the
//!   slow threshold. [`TelemetrySnapshot::render_prometheus`] exposes
//!   everything in Prometheus text format; [`ServeEngine::stats`] stays
//!   as the back-compat view derived from the same snapshot
//!   (`rust/tests/telemetry_serve.rs`).
//! * [`completion`] — the [`Completion`] trait: the unified non-blocking
//!   ticket interface. Every submit returns a handle ([`Ticket`] /
//!   [`ModelTicket`]) backed by a one-shot completion cell with three
//!   consumption modes — blocking `wait`/`wait_timeout` (the original
//!   contract, unchanged), polling `try_wait`, and callback
//!   `on_complete` (the completing engine thread runs it; no parked
//!   waiter). The HTTP front-end rides the callback mode: one thread per
//!   connection, any number of in-flight requests.
//! * [`http`] — [`HttpServer`]: the **wire front-end**. A dependency-free
//!   HTTP/1.1 server over `std::net` (the workspace is offline by
//!   design) that maps REST endpoints onto this façade: `POST
//!   /v1/submit` / `/v1/forward` / `/v1/session` for inference, `POST
//!   /v1/generate` for token-level generation (non-streaming JSON by
//!   default; `"stream": true` switches the reply to chunked
//!   transfer-encoding with one NDJSON token event per chunk, and a
//!   client disconnect cancels the session at the next token boundary),
//!   `PUT` / `POST` / `DELETE /v1/adapters/{id}` for the tenant adapter
//!   lifecycle (register / hot-swap / draining unregister), `GET
//!   /v1/stats`, and `GET /metrics` straight from
//!   [`TelemetrySnapshot::render_prometheus`]. Per-tenant bearer tokens
//!   carry in-flight quotas enforced BEFORE engine admission; every
//!   error crosses the wire as `{code, message}` with the stable
//!   [`ServeError::code`] / [`ServeError::http_status`] mapping; the
//!   hot-path JSON decode is a lazy scan-for-path pass, not a tree parse
//!   (`rust/tests/http_serve.rs`).
//!
//! Benchmarks: `cargo bench --bench bench_serve` writes `BENCH_serve.json`
//! (fused vs dense forward, batched vs serial throughput, and the
//! interned-vs-named submission-overhead row),
//! `cargo bench --bench bench_adapters` writes `BENCH_adapters.json`
//! (adapter-count sweep, mixed-batch penalty, eviction churn), and
//! `cargo bench --bench bench_forward` writes `BENCH_forward.json`
//! (pipelined vs caller-driven-serial full-model throughput across
//! concurrent session counts, mixed-adapter sweep), and
//! `cargo bench --bench bench_telemetry` writes `BENCH_telemetry.json`
//! (instrumented vs telemetry-disabled coalescing throughput — the <5%
//! overhead gate — plus snapshot/render and trace-capture costs), and
//! `cargo bench --bench bench_contention` writes `BENCH_contention.json`
//! (requests/s vs 1→64 concurrent submitters, sharded vs global dispatch,
//! single-layer and pipelined workloads — the admission-scaling gate),
//! and `cargo bench --bench bench_http` writes `BENCH_http.json`
//! (requests/s vs keep-alive connection counts, wire overhead vs direct
//! in-process submit, `/metrics` scrape latency), and
//! `cargo bench --bench bench_generate` writes `BENCH_generate.json`
//! (p50/p95/p99 TTFT and inter-token latency under Poisson arrivals with
//! heavy-tailed prompt/output lengths, plus aggregate tokens/s and the
//! serial-decode baseline) — see EXPERIMENTS.md §Serve, §Adapters,
//! §Forward, §API, §Observability, §Scale, §HTTP and §Generate.

pub mod adapters;
pub mod artifact;
pub mod completion;
pub mod engine;
pub mod error;
pub mod forward;
pub mod generate;
pub mod http;
pub mod mmap;
pub mod packed;
pub mod telemetry;
pub mod wal;

pub use adapters::{
    AdapterHandle, AdapterId, AdapterRegistry, AdapterSet, RegisterOutcome, RegistryStats,
};
pub use artifact::{crc32, Artifact, ArtifactStore, V1_ADAPTER_ID};
pub use completion::Completion;
pub use engine::{
    Dispatch, EngineStats, Request, Response, ServeEngine, ServeEngineBuilder, Ticket,
};
pub use error::{ArtifactErrorKind, ServeError};
pub use forward::{
    forward_route_serial, ModelRequest, ModelResponse, ModelTicket, SessionRequest, StepFn,
};
pub use generate::{
    generate_serial, FinishReason, GenEvent, GenParams, GenRequest, GenResponse, GenTicket,
    HashEmbedState, Sampler, Sampling, SessionState, TokenTicket,
};
pub use http::{HttpServer, HttpServerBuilder};
pub use mmap::MappedFile;
pub use packed::{
    words_per_row, DequantParams, LayerId, PackedLayer, PackedModel, PackedSource, Route,
};
pub use telemetry::{
    Counter, HistSnapshot, Metric, SlotSnapshot, Telemetry, TelemetryOptions, TelemetrySnapshot,
    Trace, TraceBuf, TraceEvent, TraceKind, TraceStage,
};
pub use wal::{FsWalFile, Wal, WalEvent, WalFile, WalOptions};

//! The packed serving artifact: one versioned binary file holding every
//! layer's packed codes, dequantization parameters and LoRA adapters.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//!   magic    "CLOQPKD1"                       8 bytes
//!   version  u32                              currently 1
//!   n_layers u32
//!   repeat n_layers times:
//!     payload_len u64
//!     payload     payload_len bytes           (see encode_layer)
//!     crc32       u32                         IEEE CRC-32 of payload
//! ```
//!
//! Each layer payload carries its own name, shapes and parameter kind, so
//! the loader can validate structurally and — the part that matters at
//! 3 a.m. — every corruption error **names the offending layer**: a
//! truncated file, a flipped bit (CRC mismatch), or an inconsistent shape
//! all report `layer k ('name'): …` instead of a bare parse failure.
//!
//! Roundtrip contract (locked by `rust/tests/golden_serve.rs`): save →
//! load reproduces every layer's quantization state **byte-identically**
//! (codes, scales/zeros or levels/absmax, adapters — all f64, no precision
//! laundering) and therefore a bit-identical packed forward.

use std::io::Write;
use std::path::Path;

use crate::linalg::Matrix;
use crate::serve::packed::{words_per_row, DequantParams, PackedLayer, PackedModel};

pub const MAGIC: &[u8; 8] = b"CLOQPKD1";
pub const VERSION: u32 = 1;

const KIND_GRID: u8 = 0;
const KIND_CODEBOOK: u8 = 1;

// ---- CRC-32 (IEEE 802.3), table built at compile time ----

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 over `bytes` (the checksum guarding each layer payload).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = (c >> 8) ^ CRC_TABLE[((c ^ b as u32) & 0xFF) as usize];
    }
    c ^ 0xFFFF_FFFF
}

// ---- encoding ----

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn encode_layer(l: &PackedLayer) -> Vec<u8> {
    let mut b = Vec::new();
    put_u32(&mut b, l.name.len() as u32);
    b.extend_from_slice(l.name.as_bytes());
    b.push(match &l.params {
        DequantParams::Grid { .. } => KIND_GRID,
        DequantParams::Codebook { .. } => KIND_CODEBOOK,
    });
    put_u32(&mut b, l.bits);
    put_u64(&mut b, l.group_size as u64);
    put_u64(&mut b, l.rows as u64);
    put_u64(&mut b, l.cols as u64);
    put_u64(&mut b, l.rank() as u64);
    put_u64(&mut b, l.packed.len() as u64);
    for w in &l.packed {
        put_u32(&mut b, *w);
    }
    match &l.params {
        DequantParams::Grid { scales, zeros } => {
            put_u64(&mut b, scales.rows as u64);
            put_f64s(&mut b, &scales.data);
            put_f64s(&mut b, &zeros.data);
        }
        DequantParams::Codebook { levels, absmax } => {
            put_u32(&mut b, levels.len() as u32);
            put_f64s(&mut b, levels);
            put_u64(&mut b, absmax.rows as u64);
            put_f64s(&mut b, &absmax.data);
        }
    }
    put_f64s(&mut b, &l.a.data);
    put_f64s(&mut b, &l.b.data);
    b
}

/// Save `model` as one packed artifact file.
pub fn save_artifact(model: &PackedModel, path: &Path) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(model.layers.len() as u32).to_le_bytes())?;
    for l in &model.layers {
        let payload = encode_layer(l);
        f.write_all(&(payload.len() as u64).to_le_bytes())?;
        f.write_all(&payload)?;
        f.write_all(&crc32(&payload).to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

// ---- decoding ----

/// Bounds-checked byte reader; every read error carries the field name so
/// the loader's layer-context wrapper produces actionable messages.
struct Rd<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, off: 0 }
    }

    fn bytes(&mut self, n: usize, what: &str) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.buf.len() - self.off, // subtraction form: off ≤ len, no overflow
            "truncated while reading {what} (need {n} bytes at offset {}, have {})",
            self.off,
            self.buf.len() - self.off,
        );
        let buf = self.buf; // copy the &'a reference so the slice outlives &mut self
        let s = &buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> anyhow::Result<u32> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> anyhow::Result<u64> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f64s(&mut self, n: usize, what: &str) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(
            n <= (self.buf.len() - self.off) / 8,
            "truncated while reading {what} (need {n} f64s, have {} bytes)",
            self.buf.len() - self.off,
        );
        let b = self.bytes(n * 8, what)?;
        Ok(b.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }
}

/// Best-effort layer name from a payload prefix, for CRC-mismatch errors
/// where the payload itself is untrustworthy.
fn peek_name(payload: &[u8]) -> String {
    let mut rd = Rd::new(payload);
    if let Ok(len) = rd.u32("name length") {
        if let Ok(bytes) = rd.bytes(len as usize, "name") {
            if let Ok(s) = std::str::from_utf8(bytes) {
                return s.to_string();
            }
        }
    }
    "<unreadable>".to_string()
}

fn decode_layer(payload: &[u8]) -> anyhow::Result<PackedLayer> {
    let mut rd = Rd::new(payload);
    let name_len = rd.u32("name length")? as usize;
    let name = String::from_utf8(rd.bytes(name_len, "name")?.to_vec())
        .map_err(|e| anyhow::anyhow!("layer name is not UTF-8: {e}"))?;
    let kind = rd.bytes(1, "param kind")?[0];
    let bits = rd.u32("bits")?;
    anyhow::ensure!((1..=8).contains(&bits), "'{name}': bit width {bits} outside 1..=8");
    let group_size = rd.u64("group size")? as usize;
    anyhow::ensure!(group_size >= 1, "'{name}': group size 0");
    let rows = rd.u64("rows")? as usize;
    let cols = rd.u64("cols")? as usize;
    anyhow::ensure!(rows >= 1 && cols >= 1, "'{name}': degenerate shape {rows}x{cols}");
    let rank = rd.u64("rank")? as usize;
    let n_words = rd.u64("packed word count")? as usize;
    // Checked arithmetic throughout: size fields come from untrusted bytes,
    // and a wrapped multiplication must become a named error, not a panic.
    let expect_words = rows
        .checked_mul(words_per_row(cols, bits))
        .ok_or_else(|| anyhow::anyhow!("'{name}': shape {rows}x{cols} overflows"))?;
    anyhow::ensure!(
        n_words == expect_words,
        "'{name}': {n_words} packed words, but {rows}x{cols} at {bits} bits needs {expect_words}"
    );
    anyhow::ensure!(
        n_words <= payload.len() / 4,
        "'{name}': {n_words} packed words exceed the payload"
    );
    let wbytes = rd.bytes(n_words * 4, "packed words")?;
    let packed: Vec<u32> =
        wbytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
    let num_groups = rows.div_ceil(group_size);
    let params = match kind {
        KIND_GRID => {
            let sg = rd.u64("scale group count")? as usize;
            anyhow::ensure!(
                sg == num_groups,
                "'{name}': {sg} scale groups, but {rows} rows at group size {group_size} \
                 needs {num_groups}"
            );
            let sn = sg
                .checked_mul(cols)
                .filter(|&v| v <= payload.len() / 8)
                .ok_or_else(|| anyhow::anyhow!("'{name}': {sg}x{cols} scales exceed the payload"))?;
            let scales = Matrix::from_vec(sg, cols, rd.f64s(sn, "scales")?);
            let zeros = Matrix::from_vec(sg, cols, rd.f64s(sn, "zeros")?);
            DequantParams::Grid { scales, zeros }
        }
        KIND_CODEBOOK => {
            let nl = rd.u32("codebook size")? as usize;
            anyhow::ensure!(
                nl == 1usize << bits,
                "'{name}': codebook of {nl} levels cannot index {bits}-bit codes"
            );
            let levels = rd.f64s(nl, "codebook levels")?;
            let ag = rd.u64("absmax group count")? as usize;
            anyhow::ensure!(
                ag == num_groups,
                "'{name}': {ag} absmax groups, but {rows} rows at block size {group_size} \
                 needs {num_groups}"
            );
            let an = ag
                .checked_mul(cols)
                .filter(|&v| v <= payload.len() / 8)
                .ok_or_else(|| anyhow::anyhow!("'{name}': {ag}x{cols} absmax exceed the payload"))?;
            let absmax = Matrix::from_vec(ag, cols, rd.f64s(an, "absmax")?);
            DequantParams::Codebook { levels, absmax }
        }
        other => anyhow::bail!("'{name}': unknown param kind {other}"),
    };
    let numel = |d: usize, what: &str| {
        d.checked_mul(rank)
            .filter(|&v| v <= payload.len() / 8)
            .ok_or_else(|| anyhow::anyhow!("'{name}': {what} of {d}x{rank} exceeds the payload"))
    };
    let a = Matrix::from_vec(rows, rank, rd.f64s(numel(rows, "adapter A")?, "adapter A")?);
    let b = Matrix::from_vec(cols, rank, rd.f64s(numel(cols, "adapter B")?, "adapter B")?);
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{name}': {} trailing bytes after adapter B",
        rd.remaining()
    );
    Ok(PackedLayer { name, rows, cols, bits, group_size, packed, params, a, b })
}

/// Load a packed artifact, validating magic, version, per-layer checksums
/// and structural consistency. Every failure names the offending layer.
pub fn load_artifact(path: &Path) -> anyhow::Result<PackedModel> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("cannot read artifact {}: {e}", path.display()))?;
    let ctx = |msg: String| anyhow::anyhow!("artifact {}: {msg}", path.display());
    let mut rd = Rd::new(&bytes);
    let magic = rd.bytes(8, "magic").map_err(|e| ctx(format!("{e}")))?;
    if magic != MAGIC {
        return Err(ctx(format!(
            "bad magic {:02x?} (expected {:02x?} — not a packed serving artifact)",
            magic, MAGIC
        )));
    }
    let version = rd.u32("version").map_err(|e| ctx(format!("{e}")))?;
    if version != VERSION {
        return Err(ctx(format!("unsupported version {version} (this build reads {VERSION})")));
    }
    let n_layers = rd.u32("layer count").map_err(|e| ctx(format!("{e}")))? as usize;
    // Untrusted count: cap the reservation by what the remaining bytes could
    // possibly hold (≥ 12 bytes per record: length + checksum), so a corrupt
    // header cannot trigger a huge allocation before validation runs.
    let mut layers = Vec::with_capacity(n_layers.min(rd.remaining() / 12));
    for idx in 0..n_layers {
        let lctx = |msg: String| ctx(format!("layer {idx}/{n_layers}: {msg}"));
        let len = rd
            .u64("payload length")
            .map_err(|e| lctx(format!("{e} — file truncated mid-header")))? as usize;
        let payload = rd
            .bytes(len, "payload")
            .map_err(|e| lctx(format!("{e} — file truncated mid-layer")))?;
        let stored_crc = rd
            .u32("checksum")
            .map_err(|e| lctx(format!("{e} — file truncated before checksum")))?;
        let computed = crc32(payload);
        if computed != stored_crc {
            return Err(lctx(format!(
                "('{}') checksum mismatch: stored {stored_crc:08x}, computed {computed:08x} — \
                 layer bytes are corrupted",
                peek_name(payload)
            )));
        }
        let layer = decode_layer(payload).map_err(|e| lctx(format!("{e}")))?;
        if let Some(prev) = layers.iter().position(|l: &PackedLayer| l.name == layer.name) {
            return Err(lctx(format!(
                "duplicate layer name '{}' (also layer {prev}) — name-addressed serving \
                 would route requests ambiguously",
                layer.name
            )));
        }
        layers.push(layer);
    }
    anyhow::ensure!(
        rd.remaining() == 0,
        "artifact {}: {} trailing bytes after the last layer",
        path.display(),
        rd.remaining()
    );
    Ok(PackedModel { layers })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_nf, quantize_rtn, QuantState};
    use crate::util::prng::Rng;

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cloq_serve_{tag}_{}", std::process::id()))
    }

    fn small_model(seed: u64) -> PackedModel {
        let mut rng = Rng::new(seed);
        let w1 = Matrix::randn(20, 9, 0.3, &mut rng);
        let w2 = Matrix::randn(16, 5, 0.3, &mut rng);
        let l1 = PackedLayer::from_state(
            "blk0.wq",
            &QuantState::Int(quantize_rtn(&w1, 3, 8)),
            &Matrix::randn(20, 2, 0.1, &mut rng),
            &Matrix::randn(9, 2, 0.1, &mut rng),
        )
        .unwrap();
        let l2 = PackedLayer::from_state(
            "blk0.wo",
            &QuantState::Nf(quantize_nf(&w2, 4, 8)),
            &Matrix::randn(16, 2, 0.1, &mut rng),
            &Matrix::randn(5, 2, 0.1, &mut rng),
        )
        .unwrap();
        PackedModel::new(vec![l1, l2])
    }

    #[test]
    fn roundtrip_preserves_forward_bits() {
        let dir = tmp("rt");
        let model = small_model(300);
        let path = dir.join("model.cloqpkd");
        save_artifact(&model, &path).unwrap();
        let loaded = load_artifact(&path).unwrap();
        let mut rng = Rng::new(301);
        for (a, b) in model.layers.iter().zip(&loaded.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.packed, b.packed);
            let x = rng.gauss_vec(a.rows);
            let (ya, yb) = (a.forward(&x), b.forward(&x));
            for (u, v) in ya.iter().zip(&yb) {
                assert_eq!(u.to_bits(), v.to_bits(), "layer {}", a.name);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_names_the_layer() {
        let dir = tmp("bad");
        let model = small_model(302);
        let path = dir.join("model.cloqpkd");
        save_artifact(&model, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one bit deep inside the SECOND layer's payload.
        let n = bytes.len();
        bytes[n - 40] ^= 0x10;
        let bad = dir.join("flipped.cloqpkd");
        std::fs::write(&bad, &bytes).unwrap();
        let msg = format!("{}", load_artifact(&bad).unwrap_err());
        assert!(msg.contains("layer 1/2"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        assert!(msg.contains("blk0.wo"), "error should name the layer: {msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let dir = tmp("magic");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.bin");
        std::fs::write(&p, b"NOTCLOQ!rest").unwrap();
        let msg = format!("{}", load_artifact(&p).unwrap_err());
        assert!(msg.contains("bad magic"), "{msg}");

        let model = small_model(303);
        let good = dir.join("good.cloqpkd");
        save_artifact(&model, &good).unwrap();
        let mut bytes = std::fs::read(&good).unwrap();
        bytes[8] = 99; // version field
        let vbad = dir.join("vbad.cloqpkd");
        std::fs::write(&vbad, &bytes).unwrap();
        let msg = format!("{}", load_artifact(&vbad).unwrap_err());
        assert!(msg.contains("unsupported version 99"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

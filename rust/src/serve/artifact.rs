//! The packed serving artifacts: versioned binary checkpoints for the
//! packed base and for individual adapter sets, unified behind
//! [`ArtifactStore`].
//!
//! Two current formats plus one legacy reader (all integers little-endian,
//! every record CRC-framed):
//!
//! ```text
//!   base artifact (v2)                adapter artifact
//!   magic    "CLOQPKD2"   8 bytes     magic    "CLOQADP1"   8 bytes
//!   version  u32 (= 2)                version  u32 (= 1)
//!   n_layers u32                      id_len   u32
//!   repeat n_layers times:            id       id_len bytes
//!     payload_len u64                 n_layers u32
//!     payload     (base layer)        repeat n_layers times:
//!     crc32       u32                   payload_len u64
//!                                       payload     (name, shape, A, B)
//!                                       crc32       u32
//! ```
//!
//! The v2 **base** artifact carries NO LoRA payloads: codes + dequant
//! params only. Adapters ship separately in the small **adapter** artifact
//! (`CLOQADP1`), so a new tenant deploys without re-shipping the packed
//! base — the multi-tenant split `serve::adapters` serves from. The v1
//! format (`CLOQPKD1`, the original single-tenant layout with A/B embedded
//! per layer) is still readable: [`ArtifactStore::open`] autodetects it
//! and returns [`Artifact::LegacyV1`] with the embedded adapters split
//! into one set named [`V1_ADAPTER_ID`].
//!
//! **The store** is the one entry point: [`ArtifactStore::save_base`] /
//! [`ArtifactStore::save_adapter`] write the two current formats, and
//! [`ArtifactStore::open`] reads ANY of the three — the magic bytes, not
//! the file name, decide what comes back, so a deployment script can
//! point the server at a directory of mixed artifacts and match on
//! [`Artifact`]. The six former free functions remain as thin
//! `#[deprecated]` shims over the same internals.
//!
//! Each layer payload carries its own name, shapes and parameter kind, so
//! the loaders can validate structurally and — the part that matters at
//! 3 a.m. — every corruption error is a typed
//! [`ServeError::Artifact`] whose `kind` classifies the failure
//! ([`ArtifactErrorKind`]: truncation vs checksum vs structure) and whose
//! `layer` **names the offending layer** whenever the bytes still reveal
//! it, instead of a bare parse failure.
//!
//! Roundtrip contract (locked by `rust/tests/golden_serve.rs`): save →
//! load reproduces every layer's quantization state **byte-identically**
//! (codes, scales/zeros or levels/absmax, adapters — all f64, no precision
//! laundering) and therefore a bit-identical packed forward; and loading a
//! v1 file through the legacy path forwards bit-identically to the
//! original embedded-adapter layers.

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::linalg::Matrix;
use crate::lowrank::LoraPair;
use crate::serve::adapters::AdapterSet;
use crate::serve::error::{ArtifactErrorKind, ServeError};
use crate::serve::packed::{words_per_row, DequantParams, PackedLayer, PackedModel};

/// Legacy single-tenant format: adapters embedded per layer.
pub const MAGIC_V1: &[u8; 8] = b"CLOQPKD1";
pub const VERSION_V1: u32 = 1;
/// Current base format: no LoRA payloads.
pub const MAGIC_BASE: &[u8; 8] = b"CLOQPKD2";
pub const VERSION_BASE: u32 = 2;
/// Adapter artifact: one AdapterSet, shippable without the base.
pub const MAGIC_ADAPTER: &[u8; 8] = b"CLOQADP1";
pub const VERSION_ADAPTER: u32 = 1;

/// Adapter-set id assigned when a legacy v1 artifact's embedded adapters
/// are split out ([`Artifact::LegacyV1`]).
pub const V1_ADAPTER_ID: &str = "v1";

const KIND_GRID: u8 = 0;
const KIND_CODEBOOK: u8 = 1;

// ---- CRC-32 (IEEE 802.3), table built at compile time ----

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 over `bytes` (the checksum guarding each layer payload).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = (c >> 8) ^ CRC_TABLE[((c ^ b as u32) & 0xFF) as usize];
    }
    c ^ 0xFFFF_FFFF
}

// ---- the unified store ----

/// What [`ArtifactStore::open`] found, decided by the file's magic bytes.
pub enum Artifact {
    /// A v2 base artifact: the packed model, no adapters.
    Base(PackedModel),
    /// An adapter artifact: one tenant's set, shipped without the base.
    Adapter(AdapterSet),
    /// A legacy v1 single-tenant file: the base plus its embedded
    /// adapters, split into one set named [`V1_ADAPTER_ID`]. The
    /// conversion is value-exact (same f64 bits), so forwards through the
    /// converted pair are bit-identical to the embedded layout.
    LegacyV1 { model: PackedModel, adapters: AdapterSet },
}

impl Artifact {
    /// Short slug for logs and error messages.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Artifact::Base(_) => "base",
            Artifact::Adapter(_) => "adapter",
            Artifact::LegacyV1 { .. } => "legacy-v1",
        }
    }

    /// The packed model, refusing non-base artifacts. A legacy file is
    /// refused too — its embedded adapters must not be dropped silently;
    /// match [`Artifact::LegacyV1`] to keep them.
    pub fn into_base(self) -> Result<PackedModel, ServeError> {
        match self {
            Artifact::Base(m) => Ok(m),
            other => Err(ServeError::Unsupported {
                detail: format!(
                    "expected a base artifact, found a {} artifact; open() and match \
                     the Artifact variant instead",
                    other.kind_name()
                ),
            }),
        }
    }

    /// The adapter set, refusing non-adapter artifacts.
    pub fn into_adapter(self) -> Result<AdapterSet, ServeError> {
        match self {
            Artifact::Adapter(s) => Ok(s),
            other => Err(ServeError::Unsupported {
                detail: format!(
                    "expected an adapter artifact, found a {} artifact; open() and \
                     match the Artifact variant instead",
                    other.kind_name()
                ),
            }),
        }
    }
}

/// The unified serving-artifact store: one directory, three formats, one
/// read entry point. Writers pick the format
/// ([`ArtifactStore::save_base`] / [`ArtifactStore::save_adapter`]);
/// [`ArtifactStore::open`] autodetects what a file is from its magic
/// bytes and returns the matching [`Artifact`]. All failures are typed
/// [`ServeError::Artifact`] values carrying the path, the failure
/// [`ArtifactErrorKind`], and the offending layer's name when known.
pub struct ArtifactStore {
    dir: PathBuf,
}

impl ArtifactStore {
    /// A store rooted at `dir` (created lazily on the first save).
    pub fn at(dir: impl Into<PathBuf>) -> ArtifactStore {
        ArtifactStore { dir: dir.into() }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The on-disk path a name maps to (`dir/name` — names may carry
    /// their own extension convention, e.g. `base.cloqpkd2`).
    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Write the packed BASE (v2, `CLOQPKD2`): codes + dequant params, no
    /// LoRA. Returns the written path.
    pub fn save_base(&self, model: &PackedModel, name: &str) -> Result<PathBuf, ServeError> {
        let path = self.path(name);
        save_base_at(model, &path)?;
        Ok(path)
    }

    /// Write one adapter set (`CLOQADP1`) — the small per-tenant file that
    /// ships without re-shipping the packed base. Returns the written path.
    pub fn save_adapter(&self, set: &AdapterSet, name: &str) -> Result<PathBuf, ServeError> {
        let path = self.path(name);
        save_adapter_at(set, &path)?;
        Ok(path)
    }

    /// Write the LEGACY v1 single-tenant layout (`CLOQPKD1`): every layer
    /// embeds its adapter from `set`, which must cover the whole model.
    /// Kept so the v1 compatibility path stays testable byte-for-byte; new
    /// deployments write base + adapter artifacts instead.
    pub fn save_legacy_v1(
        &self,
        model: &PackedModel,
        set: &AdapterSet,
        name: &str,
    ) -> Result<PathBuf, ServeError> {
        let path = self.path(name);
        save_v1_at(model, set, &path)?;
        Ok(path)
    }

    /// Read `name`, autodetecting which of the three formats it holds from
    /// the magic bytes.
    pub fn open(&self, name: &str) -> Result<Artifact, ServeError> {
        open_at(&self.path(name))
    }

    /// Read a base artifact, refusing adapter and legacy files with a
    /// pointer to [`ArtifactStore::open`] (a legacy file's embedded
    /// adapters must not be dropped silently).
    pub fn load_base(&self, name: &str) -> Result<PackedModel, ServeError> {
        load_base_at(&self.path(name))
    }

    /// Read an adapter artifact, refusing the other formats (one source
    /// of truth: [`Artifact::into_adapter`], with the path prepended).
    pub fn load_adapter(&self, name: &str) -> Result<AdapterSet, ServeError> {
        self.open(name)?.into_adapter().map_err(|e| match e {
            ServeError::Unsupported { detail } => ServeError::Unsupported {
                detail: format!("artifact {}: {detail}", self.path(name).display()),
            },
            other => other,
        })
    }
}

// ---- encoding ----

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// The base-layer fields shared by the v1 and v2 payloads: identity,
/// quantization geometry, packed words and dequant params. v1 additionally
/// interleaves `rank` (after `cols`) and appends A/B — see `encode_layer_v1`.
fn encode_base_fields(b: &mut Vec<u8>, l: &PackedLayer, rank_v1: Option<usize>) {
    put_str(b, &l.name);
    b.push(match &l.params {
        DequantParams::Grid { .. } => KIND_GRID,
        DequantParams::Codebook { .. } => KIND_CODEBOOK,
    });
    put_u32(b, l.bits);
    put_u64(b, l.group_size as u64);
    put_u64(b, l.rows as u64);
    put_u64(b, l.cols as u64);
    if let Some(r) = rank_v1 {
        put_u64(b, r as u64);
    }
    put_u64(b, l.packed.len() as u64);
    for w in &l.packed {
        put_u32(b, *w);
    }
    match &l.params {
        DequantParams::Grid { scales, zeros } => {
            put_u64(b, scales.rows as u64);
            put_f64s(b, &scales.data);
            put_f64s(b, &zeros.data);
        }
        DequantParams::Codebook { levels, absmax } => {
            put_u32(b, levels.len() as u32);
            put_f64s(b, levels);
            put_u64(b, absmax.rows as u64);
            put_f64s(b, &absmax.data);
        }
    }
}

fn encode_layer_base(l: &PackedLayer) -> Vec<u8> {
    let mut b = Vec::new();
    encode_base_fields(&mut b, l, None);
    b
}

/// v1 layout (byte-for-byte): base fields with `rank` after `cols`, then A
/// and B row-major f64.
fn encode_layer_v1(l: &PackedLayer, pair: &LoraPair) -> Vec<u8> {
    let mut b = Vec::new();
    encode_base_fields(&mut b, l, Some(pair.rank()));
    put_f64s(&mut b, &pair.a.data);
    put_f64s(&mut b, &pair.b.data);
    b
}

fn encode_layer_adapter(name: &str, pair: &LoraPair) -> Vec<u8> {
    let mut b = Vec::new();
    put_str(&mut b, name);
    put_u64(&mut b, pair.a.rows as u64);
    put_u64(&mut b, pair.b.rows as u64);
    put_u64(&mut b, pair.rank() as u64);
    put_f64s(&mut b, &pair.a.data);
    put_f64s(&mut b, &pair.b.data);
    b
}

fn io_err(path: &Path, what: &str, e: std::io::Error) -> ServeError {
    ServeError::Artifact {
        path: path.display().to_string(),
        layer: None,
        kind: ArtifactErrorKind::Io,
        detail: format!("{what}: {e}"),
    }
}

fn write_file(path: &Path, header: &[u8], payloads: Vec<Vec<u8>>) -> Result<(), ServeError> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).map_err(|e| io_err(path, "cannot create dir", e))?;
    }
    let inner = || -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(header)?;
        for payload in &payloads {
            f.write_all(&(payload.len() as u64).to_le_bytes())?;
            f.write_all(payload)?;
            f.write_all(&crc32(payload).to_le_bytes())?;
        }
        f.flush()
    };
    inner().map_err(|e| io_err(path, "cannot write", e))
}

fn save_base_at(model: &PackedModel, path: &Path) -> Result<(), ServeError> {
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_BASE);
    header.extend_from_slice(&VERSION_BASE.to_le_bytes());
    header.extend_from_slice(&(model.layers.len() as u32).to_le_bytes());
    write_file(path, &header, model.layers.iter().map(encode_layer_base).collect())
}

fn save_adapter_at(set: &AdapterSet, path: &Path) -> Result<(), ServeError> {
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_ADAPTER);
    header.extend_from_slice(&VERSION_ADAPTER.to_le_bytes());
    put_str(&mut header, set.id());
    header.extend_from_slice(&(set.len() as u32).to_le_bytes());
    let payloads = set.entries().map(|(n, p)| encode_layer_adapter(n, p)).collect();
    write_file(path, &header, payloads)
}

/// v1 embeds one adapter per layer: fetch and shape-check the layer's pair
/// from `set`, as a typed error when it is absent.
fn v1_pair<'a>(l: &PackedLayer, set: &'a AdapterSet) -> Result<&'a LoraPair, ServeError> {
    let pair = set.get(&l.name).ok_or_else(|| ServeError::AdapterMismatch {
        adapter: set.id().to_string(),
        layer: Some(l.name.clone()),
    })?;
    l.check_adapter(pair)?;
    Ok(pair)
}

fn save_v1_at(model: &PackedModel, set: &AdapterSet, path: &Path) -> Result<(), ServeError> {
    let mut payloads = Vec::with_capacity(model.layers.len());
    for l in &model.layers {
        payloads.push(encode_layer_v1(l, v1_pair(l, set)?));
    }
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_V1);
    header.extend_from_slice(&VERSION_V1.to_le_bytes());
    header.extend_from_slice(&(model.layers.len() as u32).to_le_bytes());
    write_file(path, &header, payloads)
}

// ---- decoding ----

/// Bounds-checked byte reader; every read error carries the field name so
/// the loader's layer-context wrapper produces actionable messages.
struct Rd<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, off: 0 }
    }

    fn bytes(&mut self, n: usize, what: &str) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.buf.len() - self.off, // subtraction form: off ≤ len, no overflow
            "truncated while reading {what} (need {n} bytes at offset {}, have {})",
            self.off,
            self.buf.len() - self.off,
        );
        let buf = self.buf; // copy the &'a reference so the slice outlives &mut self
        let s = &buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> anyhow::Result<u32> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> anyhow::Result<u64> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f64s(&mut self, n: usize, what: &str) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(
            n <= (self.buf.len() - self.off) / 8,
            "truncated while reading {what} (need {n} f64s, have {} bytes)",
            self.buf.len() - self.off,
        );
        let b = self.bytes(n * 8, what)?;
        Ok(b.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn str(&mut self, what: &str) -> anyhow::Result<String> {
        let len = self.u32(&format!("{what} length"))? as usize;
        String::from_utf8(self.bytes(len, what)?.to_vec())
            .map_err(|e| anyhow::anyhow!("{what} is not UTF-8: {e}"))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }
}

/// Best-effort layer name from a payload prefix, for errors where the
/// payload itself is suspect or partially decoded.
fn peek_name(payload: &[u8]) -> Option<String> {
    Rd::new(payload).str("name").ok()
}

/// Decode the base fields shared by v1 and v2 payloads. `v1` controls
/// whether the legacy interleaved `rank` field is read (returned as 0 for
/// v2). Leaves `rd` positioned after the dequant params.
fn decode_base_fields(rd: &mut Rd, v1: bool) -> anyhow::Result<(PackedLayer, usize)> {
    let name = rd.str("layer name")?;
    let kind = rd.bytes(1, "param kind")?[0];
    let bits = rd.u32("bits")?;
    anyhow::ensure!((1..=8).contains(&bits), "'{name}': bit width {bits} outside 1..=8");
    let group_size = rd.u64("group size")? as usize;
    anyhow::ensure!(group_size >= 1, "'{name}': group size 0");
    let rows = rd.u64("rows")? as usize;
    let cols = rd.u64("cols")? as usize;
    anyhow::ensure!(rows >= 1 && cols >= 1, "'{name}': degenerate shape {rows}x{cols}");
    let rank = if v1 { rd.u64("rank")? as usize } else { 0 };
    let n_words = rd.u64("packed word count")? as usize;
    // Checked arithmetic throughout: size fields come from untrusted bytes,
    // and a wrapped multiplication must become a named error, not a panic.
    let expect_words = rows
        .checked_mul(words_per_row(cols, bits))
        .ok_or_else(|| anyhow::anyhow!("'{name}': shape {rows}x{cols} overflows"))?;
    anyhow::ensure!(
        n_words == expect_words,
        "'{name}': {n_words} packed words, but {rows}x{cols} at {bits} bits needs {expect_words}"
    );
    anyhow::ensure!(
        n_words <= rd.remaining() / 4,
        "'{name}': {n_words} packed words exceed the payload"
    );
    let wbytes = rd.bytes(n_words * 4, "packed words")?;
    let packed: Vec<u32> =
        wbytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
    let num_groups = rows.div_ceil(group_size);
    let cap = rd.remaining() / 8; // untrusted-count allocations bounded by the bytes present
    let params = match kind {
        KIND_GRID => {
            let sg = rd.u64("scale group count")? as usize;
            anyhow::ensure!(
                sg == num_groups,
                "'{name}': {sg} scale groups, but {rows} rows at group size {group_size} \
                 needs {num_groups}"
            );
            let sn = sg
                .checked_mul(cols)
                .filter(|&v| v <= cap)
                .ok_or_else(|| anyhow::anyhow!("'{name}': {sg}x{cols} scales exceed the payload"))?;
            let scales = Matrix::from_vec(sg, cols, rd.f64s(sn, "scales")?);
            let zeros = Matrix::from_vec(sg, cols, rd.f64s(sn, "zeros")?);
            DequantParams::Grid { scales, zeros }
        }
        KIND_CODEBOOK => {
            let nl = rd.u32("codebook size")? as usize;
            anyhow::ensure!(
                nl == 1usize << bits,
                "'{name}': codebook of {nl} levels cannot index {bits}-bit codes"
            );
            let levels = rd.f64s(nl, "codebook levels")?;
            let ag = rd.u64("absmax group count")? as usize;
            anyhow::ensure!(
                ag == num_groups,
                "'{name}': {ag} absmax groups, but {rows} rows at block size {group_size} \
                 needs {num_groups}"
            );
            let an = ag
                .checked_mul(cols)
                .filter(|&v| v <= cap)
                .ok_or_else(|| anyhow::anyhow!("'{name}': {ag}x{cols} absmax exceed the payload"))?;
            let absmax = Matrix::from_vec(ag, cols, rd.f64s(an, "absmax")?);
            DequantParams::Codebook { levels, absmax }
        }
        other => anyhow::bail!("'{name}': unknown param kind {other}"),
    };
    Ok((PackedLayer { name, rows, cols, bits, group_size, packed, params }, rank))
}

fn decode_layer_base(payload: &[u8]) -> anyhow::Result<PackedLayer> {
    let mut rd = Rd::new(payload);
    let (layer, _) = decode_base_fields(&mut rd, false)?;
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{}': {} trailing bytes after dequant params",
        layer.name,
        rd.remaining()
    );
    Ok(layer)
}

fn decode_layer_v1(payload: &[u8]) -> anyhow::Result<(PackedLayer, LoraPair)> {
    let mut rd = Rd::new(payload);
    let (layer, rank) = decode_base_fields(&mut rd, true)?;
    let name = layer.name.clone();
    let cap = rd.remaining() / 8;
    let numel = |d: usize, what: &str| {
        d.checked_mul(rank)
            .filter(|&v| v <= cap)
            .ok_or_else(|| anyhow::anyhow!("'{name}': {what} of {d}x{rank} exceeds the payload"))
    };
    let na = numel(layer.rows, "adapter A")?;
    let a = Matrix::from_vec(layer.rows, rank, rd.f64s(na, "adapter A")?);
    let nb = numel(layer.cols, "adapter B")?;
    let b = Matrix::from_vec(layer.cols, rank, rd.f64s(nb, "adapter B")?);
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{name}': {} trailing bytes after adapter B",
        rd.remaining()
    );
    Ok((layer, LoraPair::new(a, b)))
}

fn decode_layer_adapter(payload: &[u8]) -> anyhow::Result<(String, LoraPair)> {
    let mut rd = Rd::new(payload);
    let name = rd.str("layer name")?;
    let rows = rd.u64("rows")? as usize;
    let cols = rd.u64("cols")? as usize;
    let rank = rd.u64("rank")? as usize;
    anyhow::ensure!(rows >= 1 && cols >= 1, "'{name}': degenerate shape {rows}x{cols}");
    // Bound untrusted counts by the bytes actually REMAINING (the header
    // is already consumed), matching the sibling decoders.
    let cap = rd.remaining() / 8;
    let numel = |d: usize, what: &str| {
        d.checked_mul(rank)
            .filter(|&v| v <= cap)
            .ok_or_else(|| anyhow::anyhow!("'{name}': {what} of {d}x{rank} exceeds the payload"))
    };
    let a = Matrix::from_vec(rows, rank, rd.f64s(numel(rows, "adapter A")?, "adapter A")?);
    let b = Matrix::from_vec(cols, rank, rd.f64s(numel(cols, "adapter B")?, "adapter B")?);
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{name}': {} trailing bytes after adapter B",
        rd.remaining()
    );
    Ok((name, LoraPair::new(a, b)))
}

/// Per-file error context: builds the typed [`ServeError::Artifact`]
/// values so every failure carries the path, a classified kind, and the
/// offending layer when known.
struct FileCtx {
    path: String,
}

impl FileCtx {
    fn new(path: &Path) -> FileCtx {
        FileCtx { path: path.display().to_string() }
    }

    fn err(&self, kind: ArtifactErrorKind, layer: Option<String>, detail: String) -> ServeError {
        ServeError::Artifact { path: self.path.clone(), layer, kind, detail }
    }

    /// Wrap a structural decode failure with the layer index/name context.
    fn malformed(&self, idx: usize, n: usize, payload: &[u8], e: anyhow::Error) -> ServeError {
        self.err(
            ArtifactErrorKind::Malformed,
            peek_name(payload),
            format!("layer {idx}/{n}: {e}"),
        )
    }
}

/// Read one CRC-framed record: length, payload, checksum. Every failure
/// names the layer index (and, on a checksum mismatch, the best-effort
/// layer name) with a classified kind.
fn read_record<'a>(
    rd: &mut Rd<'a>,
    ctx: &FileCtx,
    idx: usize,
    n_layers: usize,
) -> Result<&'a [u8], ServeError> {
    let trunc = |e: anyhow::Error, stage: &str| {
        ctx.err(
            ArtifactErrorKind::Truncated,
            None,
            format!("layer {idx}/{n_layers}: {e} — file truncated {stage}"),
        )
    };
    let len = rd.u64("payload length").map_err(|e| trunc(e, "mid-header"))? as usize;
    let payload = rd.bytes(len, "payload").map_err(|e| trunc(e, "mid-layer"))?;
    let stored_crc = rd.u32("checksum").map_err(|e| trunc(e, "before checksum"))?;
    let computed = crc32(payload);
    if computed != stored_crc {
        let name = peek_name(payload);
        return Err(ctx.err(
            ArtifactErrorKind::ChecksumMismatch,
            name.clone(),
            format!(
                "layer {idx}/{n_layers} ('{}') checksum mismatch: stored {stored_crc:08x}, \
                 computed {computed:08x} — layer bytes are corrupted",
                name.as_deref().unwrap_or("<unreadable>")
            ),
        ));
    }
    Ok(payload)
}

/// Read and validate magic + version; returns the parsed version's magic.
fn read_header<'a>(
    rd: &mut Rd<'a>,
    ctx: &FileCtx,
    accept: &[(&'static [u8; 8], u32)],
) -> Result<&'static [u8; 8], ServeError> {
    let magic = rd
        .bytes(8, "magic")
        .map_err(|e| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}")))?;
    let found = accept.iter().find(|(m, _)| magic == &m[..]);
    let &(m, want_version) = found.ok_or_else(|| {
        ctx.err(
            ArtifactErrorKind::BadMagic,
            None,
            format!(
                "bad magic {:02x?} (expected one of {:?} — not a matching serving artifact)",
                magic,
                accept
                    .iter()
                    .map(|(m, _)| String::from_utf8_lossy(&m[..]).into_owned())
                    .collect::<Vec<_>>()
            ),
        )
    })?;
    let version = rd
        .u32("version")
        .map_err(|e| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}")))?;
    if version != want_version {
        return Err(ctx.err(
            ArtifactErrorKind::BadVersion,
            None,
            format!(
                "unsupported version {version} (this build reads {want_version} for {})",
                String::from_utf8_lossy(&m[..])
            ),
        ));
    }
    Ok(m)
}

fn read_layer_records<'a>(
    rd: &mut Rd<'a>,
    ctx: &FileCtx,
) -> Result<Vec<(usize, usize, &'a [u8])>, ServeError> {
    let n_layers = rd
        .u32("layer count")
        .map_err(|e| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}")))?;
    let n_layers = n_layers as usize;
    // Untrusted count: cap the reservation by what the remaining bytes could
    // possibly hold (≥ 12 bytes per record: length + checksum), so a corrupt
    // header cannot trigger a huge allocation before validation runs.
    let mut records = Vec::with_capacity(n_layers.min(rd.remaining() / 12));
    for idx in 0..n_layers {
        records.push((idx, n_layers, read_record(rd, ctx, idx, n_layers)?));
    }
    if rd.remaining() != 0 {
        return Err(ctx.err(
            ArtifactErrorKind::Malformed,
            None,
            format!("{} trailing bytes after the last layer", rd.remaining()),
        ));
    }
    Ok(records)
}

fn ensure_unique(names: &[String], ctx: &FileCtx) -> Result<(), ServeError> {
    for (i, n) in names.iter().enumerate() {
        if let Some(prev) = names[..i].iter().position(|p| p == n) {
            return Err(ctx.err(
                ArtifactErrorKind::Malformed,
                Some(n.clone()),
                format!(
                    "layer {i}/{}: duplicate layer name '{n}' (also layer {prev}) — \
                     name-addressed serving would route requests ambiguously",
                    names.len()
                ),
            ));
        }
    }
    Ok(())
}

fn read_file(path: &Path) -> Result<Vec<u8>, ServeError> {
    std::fs::read(path).map_err(|e| io_err(path, "cannot read", e))
}

/// Autodetecting open: the magic bytes decide which decoder runs.
fn open_at(path: &Path) -> Result<Artifact, ServeError> {
    let bytes = read_file(path)?;
    let ctx = FileCtx::new(path);
    let mut rd = Rd::new(&bytes);
    let magic = read_header(
        &mut rd,
        &ctx,
        &[
            (MAGIC_BASE, VERSION_BASE),
            (MAGIC_ADAPTER, VERSION_ADAPTER),
            (MAGIC_V1, VERSION_V1),
        ],
    )?;
    if magic == MAGIC_ADAPTER {
        let id = rd
            .str("adapter id")
            .map_err(|e| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}")))?;
        let mut set = AdapterSet::new(&id);
        for (idx, n_layers, payload) in read_layer_records(&mut rd, &ctx)? {
            let (name, pair) = decode_layer_adapter(payload)
                .map_err(|e| ctx.malformed(idx, n_layers, payload, e))?;
            set.insert(&name, pair).map_err(|e| {
                ctx.err(
                    ArtifactErrorKind::Malformed,
                    Some(name.clone()),
                    format!("layer {idx}/{n_layers}: {e}"),
                )
            })?;
        }
        return Ok(Artifact::Adapter(set));
    }
    let v1 = magic == MAGIC_V1;
    let mut layers = Vec::new();
    let mut pairs = Vec::new();
    for (idx, n_layers, payload) in read_layer_records(&mut rd, &ctx)? {
        if v1 {
            let (layer, pair) = decode_layer_v1(payload)
                .map_err(|e| ctx.malformed(idx, n_layers, payload, e))?;
            pairs.push((layer.name.clone(), pair));
            layers.push(layer);
        } else {
            let layer = decode_layer_base(payload)
                .map_err(|e| ctx.malformed(idx, n_layers, payload, e))?;
            layers.push(layer);
        }
    }
    let names: Vec<String> = layers.iter().map(|l| l.name.clone()).collect();
    ensure_unique(&names, &ctx)?;
    let model = PackedModel { layers };
    if v1 {
        let adapters = AdapterSet::from_pairs(V1_ADAPTER_ID, pairs)
            .map_err(|e| ctx.err(ArtifactErrorKind::Malformed, None, format!("{e}")))?;
        Ok(Artifact::LegacyV1 { model, adapters })
    } else {
        Ok(Artifact::Base(model))
    }
}

fn load_base_at(path: &Path) -> Result<PackedModel, ServeError> {
    match open_at(path)? {
        Artifact::Base(model) => Ok(model),
        Artifact::LegacyV1 { .. } => Err(ServeError::Unsupported {
            detail: format!(
                "artifact {}: this is a legacy v1 (CLOQPKD1) single-tenant artifact with \
                 embedded adapters; open() it and match Artifact::LegacyV1 so the \
                 adapters are not dropped",
                path.display()
            ),
        }),
        Artifact::Adapter(_) => Err(ServeError::Unsupported {
            detail: format!(
                "artifact {}: this is an adapter artifact, not a packed base",
                path.display()
            ),
        }),
    }
}

// ---- deprecated free-function shims over the store internals ----

/// Deprecated free-function shim; see [`ArtifactStore::save_base`].
#[deprecated(note = "use ArtifactStore::save_base (the unified artifact store)")]
pub fn save_base_artifact(model: &PackedModel, path: &Path) -> anyhow::Result<()> {
    Ok(save_base_at(model, path)?)
}

/// Deprecated free-function shim; see [`ArtifactStore::save_adapter`].
#[deprecated(note = "use ArtifactStore::save_adapter (the unified artifact store)")]
pub fn save_adapter_artifact(set: &AdapterSet, path: &Path) -> anyhow::Result<()> {
    Ok(save_adapter_at(set, path)?)
}

/// Deprecated free-function shim; see [`ArtifactStore::save_legacy_v1`].
#[deprecated(note = "use ArtifactStore::save_legacy_v1 (the unified artifact store)")]
pub fn save_artifact_v1(
    model: &PackedModel,
    set: &AdapterSet,
    path: &Path,
) -> anyhow::Result<()> {
    Ok(save_v1_at(model, set, path)?)
}

/// Deprecated free-function shim; see [`ArtifactStore::load_base`] /
/// [`ArtifactStore::open`].
#[deprecated(note = "use ArtifactStore::load_base or ArtifactStore::open")]
pub fn load_base_artifact(path: &Path) -> anyhow::Result<PackedModel> {
    Ok(load_base_at(path)?)
}

/// Deprecated free-function shim; see [`ArtifactStore::load_adapter`] /
/// [`ArtifactStore::open`].
#[deprecated(note = "use ArtifactStore::load_adapter or ArtifactStore::open")]
pub fn load_adapter_artifact(path: &Path) -> anyhow::Result<AdapterSet> {
    match open_at(path)? {
        Artifact::Adapter(set) => Ok(set),
        other => Err(anyhow::anyhow!(
            "artifact {}: expected an adapter artifact, found a {} artifact",
            path.display(),
            other.kind_name()
        )),
    }
}

/// Deprecated free-function shim; [`ArtifactStore::open`] replaces the
/// compat entry point (match [`Artifact::LegacyV1`] for v1 files).
#[deprecated(note = "use ArtifactStore::open and match the Artifact variant")]
pub fn load_artifact_compat(path: &Path) -> anyhow::Result<(PackedModel, Option<AdapterSet>)> {
    match open_at(path)? {
        Artifact::Base(model) => Ok((model, None)),
        Artifact::LegacyV1 { model, adapters } => Ok((model, Some(adapters))),
        Artifact::Adapter(_) => Err(anyhow::anyhow!(
            "artifact {}: this is an adapter artifact, not a packed model",
            path.display()
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_nf, quantize_rtn, QuantState};
    use crate::util::prng::Rng;

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    fn store(tag: &str) -> ArtifactStore {
        ArtifactStore::at(
            std::env::temp_dir().join(format!("cloq_serve_{tag}_{}", std::process::id())),
        )
    }

    fn small_model(seed: u64) -> (PackedModel, AdapterSet) {
        let mut rng = Rng::new(seed);
        let w1 = Matrix::randn(20, 9, 0.3, &mut rng);
        let w2 = Matrix::randn(16, 5, 0.3, &mut rng);
        let l1 = PackedLayer::from_state("blk0.wq", &QuantState::Int(quantize_rtn(&w1, 3, 8)))
            .unwrap();
        let p1 = LoraPair::new(
            Matrix::randn(20, 2, 0.1, &mut rng),
            Matrix::randn(9, 2, 0.1, &mut rng),
        );
        let l2 = PackedLayer::from_state("blk0.wo", &QuantState::Nf(quantize_nf(&w2, 4, 8)))
            .unwrap();
        let p2 = LoraPair::new(
            Matrix::randn(16, 2, 0.1, &mut rng),
            Matrix::randn(5, 2, 0.1, &mut rng),
        );
        let set = AdapterSet::from_pairs(
            "tenant",
            vec![("blk0.wq".to_string(), p1), ("blk0.wo".to_string(), p2)],
        )
        .unwrap();
        (PackedModel::new(vec![l1, l2]), set)
    }

    #[test]
    fn base_roundtrip_preserves_forward_bits() {
        let st = store("rt");
        let (model, _) = small_model(300);
        st.save_base(&model, "model.cloqpkd2").unwrap();
        let loaded = st.load_base("model.cloqpkd2").unwrap();
        let mut rng = Rng::new(301);
        for (a, b) in model.layers.iter().zip(&loaded.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.packed, b.packed);
            let x = rng.gauss_vec(a.rows);
            let (ya, yb) = (a.forward(&x, None), b.forward(&x, None));
            for (u, v) in ya.iter().zip(&yb) {
                assert_eq!(u.to_bits(), v.to_bits(), "layer {}", a.name);
            }
        }
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn open_autodetects_all_three_formats() {
        let st = store("auto");
        let (model, set) = small_model(305);
        st.save_base(&model, "base.bin").unwrap();
        st.save_adapter(&set, "adp.bin").unwrap();
        st.save_legacy_v1(&model, &set, "legacy.bin").unwrap();
        assert!(matches!(st.open("base.bin").unwrap(), Artifact::Base(_)));
        match st.open("adp.bin").unwrap() {
            Artifact::Adapter(s) => assert_eq!(s.id(), "tenant"),
            other => panic!("expected an adapter artifact, got {}", other.kind_name()),
        }
        match st.open("legacy.bin").unwrap() {
            Artifact::LegacyV1 { model: m, adapters } => {
                assert_eq!(m.layers.len(), model.layers.len());
                assert_eq!(adapters.id(), V1_ADAPTER_ID);
            }
            other => panic!("expected a legacy artifact, got {}", other.kind_name()),
        }
        // The typed accessors refuse cross-format reads with a pointer.
        let err = st.load_base("legacy.bin").unwrap_err();
        assert!(matches!(err, ServeError::Unsupported { .. }), "{err:?}");
        assert!(format!("{err}").contains("LegacyV1"), "{err}");
        let err = st.load_adapter("base.bin").unwrap_err();
        assert!(format!("{err}").contains("found a base artifact"), "{err}");
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn corruption_names_the_layer_with_a_typed_kind() {
        let st = store("bad");
        let (model, _) = small_model(302);
        let path = st.save_base(&model, "model.cloqpkd2").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one bit deep inside the SECOND layer's payload.
        let n = bytes.len();
        bytes[n - 40] ^= 0x10;
        std::fs::write(st.path("flipped.cloqpkd2"), &bytes).unwrap();
        let err = st.open("flipped.cloqpkd2").unwrap_err();
        assert!(
            matches!(
                &err,
                ServeError::Artifact {
                    kind: ArtifactErrorKind::ChecksumMismatch,
                    layer: Some(l),
                    ..
                } if l == "blk0.wo"
            ),
            "{err:?}"
        );
        let msg = format!("{err}");
        assert!(msg.contains("layer 1/2"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn bad_magic_and_version_rejected_with_typed_kinds() {
        let st = store("magic");
        std::fs::create_dir_all(st.dir()).unwrap();
        std::fs::write(st.path("junk.bin"), b"NOTCLOQ!rest").unwrap();
        let err = st.open("junk.bin").unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::BadMagic, .. }),
            "{err:?}"
        );

        let (model, _) = small_model(303);
        let good = st.save_base(&model, "good.cloqpkd2").unwrap();
        let mut bytes = std::fs::read(&good).unwrap();
        bytes[8] = 99; // version field
        std::fs::write(st.path("vbad.cloqpkd2"), &bytes).unwrap();
        let err = st.open("vbad.cloqpkd2").unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::BadVersion, .. }),
            "{err:?}"
        );
        assert!(format!("{err}").contains("unsupported version 99"), "{err}");
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn missing_file_is_an_io_kind() {
        let st = store("io");
        let err = st.open("never-written.bin").unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::Io, .. }),
            "{err:?}"
        );
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_still_roundtrip() {
        // The free functions stay as working shims for one deprecation
        // cycle; they share the store's internals byte-for-byte.
        let dir = std::env::temp_dir().join(format!("cloq_serve_shim_{}", std::process::id()));
        let (model, set) = small_model(304);
        let bpath = dir.join("base.cloqpkd2");
        let vpath = dir.join("legacy.cloqpkd");
        save_base_artifact(&model, &bpath).unwrap();
        save_adapter_artifact(&set, &dir.join("a.cloqadp")).unwrap();
        save_artifact_v1(&model, &set, &vpath).unwrap();
        let loaded = load_base_artifact(&bpath).unwrap();
        assert_eq!(loaded.layers.len(), model.layers.len());
        let aset = load_adapter_artifact(&dir.join("a.cloqadp")).unwrap();
        assert_eq!(aset.id(), "tenant");
        let (v1m, v1s) = load_artifact_compat(&vpath).unwrap();
        assert_eq!(v1m.layers.len(), model.layers.len());
        assert_eq!(v1s.unwrap().id(), V1_ADAPTER_ID);
        let msg = format!("{}", load_base_artifact(&vpath).unwrap_err());
        assert!(msg.contains("LegacyV1"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

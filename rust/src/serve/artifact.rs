//! The packed serving artifacts: versioned binary checkpoints for the
//! packed base and for individual adapter sets.
//!
//! Two current formats plus one legacy reader (all integers little-endian,
//! every record CRC-framed):
//!
//! ```text
//!   base artifact (v2)                adapter artifact
//!   magic    "CLOQPKD2"   8 bytes     magic    "CLOQADP1"   8 bytes
//!   version  u32 (= 2)                version  u32 (= 1)
//!   n_layers u32                      id_len   u32
//!   repeat n_layers times:            id       id_len bytes
//!     payload_len u64                 n_layers u32
//!     payload     (base layer)        repeat n_layers times:
//!     crc32       u32                   payload_len u64
//!                                       payload     (name, shape, A, B)
//!                                       crc32       u32
//! ```
//!
//! The v2 **base** artifact carries NO LoRA payloads: codes + dequant
//! params only. Adapters ship separately in the small **adapter** artifact
//! (`CLOQADP1`), so a new tenant deploys without re-shipping the packed
//! base — the multi-tenant split `serve::adapters` serves from. The v1
//! format (`CLOQPKD1`, PR 2's single-tenant layout with A/B embedded per
//! layer) is still read by [`load_artifact_compat`], which converts it
//! into base + one adapter set named [`V1_ADAPTER_ID`]; `save_artifact_v1`
//! is kept so the compatibility path stays testable byte-for-byte.
//!
//! Each layer payload carries its own name, shapes and parameter kind, so
//! the loaders can validate structurally and — the part that matters at
//! 3 a.m. — every corruption error **names the offending layer**: a
//! truncated file, a flipped bit (CRC mismatch), or an inconsistent shape
//! all report `layer k ('name'): …` instead of a bare parse failure.
//!
//! Roundtrip contract (locked by `rust/tests/golden_serve.rs`): save →
//! load reproduces every layer's quantization state **byte-identically**
//! (codes, scales/zeros or levels/absmax, adapters — all f64, no precision
//! laundering) and therefore a bit-identical packed forward; and loading a
//! v1 file through the compat shim forwards bit-identically to the
//! original embedded-adapter layers.

use std::io::Write;
use std::path::Path;

use crate::linalg::Matrix;
use crate::lowrank::LoraPair;
use crate::serve::adapters::AdapterSet;
use crate::serve::packed::{words_per_row, DequantParams, PackedLayer, PackedModel};

/// Legacy single-tenant format (PR 2): adapters embedded per layer.
pub const MAGIC_V1: &[u8; 8] = b"CLOQPKD1";
pub const VERSION_V1: u32 = 1;
/// Current base format: no LoRA payloads.
pub const MAGIC_BASE: &[u8; 8] = b"CLOQPKD2";
pub const VERSION_BASE: u32 = 2;
/// Adapter artifact: one AdapterSet, shippable without the base.
pub const MAGIC_ADAPTER: &[u8; 8] = b"CLOQADP1";
pub const VERSION_ADAPTER: u32 = 1;

/// Adapter-set id assigned when [`load_artifact_compat`] converts a v1
/// artifact's embedded adapters.
pub const V1_ADAPTER_ID: &str = "v1";

const KIND_GRID: u8 = 0;
const KIND_CODEBOOK: u8 = 1;

// ---- CRC-32 (IEEE 802.3), table built at compile time ----

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 over `bytes` (the checksum guarding each layer payload).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = (c >> 8) ^ CRC_TABLE[((c ^ b as u32) & 0xFF) as usize];
    }
    c ^ 0xFFFF_FFFF
}

// ---- encoding ----

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// The base-layer fields shared by the v1 and v2 payloads: identity,
/// quantization geometry, packed words and dequant params. v1 additionally
/// interleaves `rank` (after `cols`) and appends A/B — see `encode_layer_v1`.
fn encode_base_fields(b: &mut Vec<u8>, l: &PackedLayer, rank_v1: Option<usize>) {
    put_str(b, &l.name);
    b.push(match &l.params {
        DequantParams::Grid { .. } => KIND_GRID,
        DequantParams::Codebook { .. } => KIND_CODEBOOK,
    });
    put_u32(b, l.bits);
    put_u64(b, l.group_size as u64);
    put_u64(b, l.rows as u64);
    put_u64(b, l.cols as u64);
    if let Some(r) = rank_v1 {
        put_u64(b, r as u64);
    }
    put_u64(b, l.packed.len() as u64);
    for w in &l.packed {
        put_u32(b, *w);
    }
    match &l.params {
        DequantParams::Grid { scales, zeros } => {
            put_u64(b, scales.rows as u64);
            put_f64s(b, &scales.data);
            put_f64s(b, &zeros.data);
        }
        DequantParams::Codebook { levels, absmax } => {
            put_u32(b, levels.len() as u32);
            put_f64s(b, levels);
            put_u64(b, absmax.rows as u64);
            put_f64s(b, &absmax.data);
        }
    }
}

fn encode_layer_base(l: &PackedLayer) -> Vec<u8> {
    let mut b = Vec::new();
    encode_base_fields(&mut b, l, None);
    b
}

/// v1 layout (PR 2, byte-for-byte): base fields with `rank` after `cols`,
/// then A and B row-major f64.
fn encode_layer_v1(l: &PackedLayer, pair: &LoraPair) -> Vec<u8> {
    let mut b = Vec::new();
    encode_base_fields(&mut b, l, Some(pair.rank()));
    put_f64s(&mut b, &pair.a.data);
    put_f64s(&mut b, &pair.b.data);
    b
}

fn encode_layer_adapter(name: &str, pair: &LoraPair) -> Vec<u8> {
    let mut b = Vec::new();
    put_str(&mut b, name);
    put_u64(&mut b, pair.a.rows as u64);
    put_u64(&mut b, pair.b.rows as u64);
    put_u64(&mut b, pair.rank() as u64);
    put_f64s(&mut b, &pair.a.data);
    put_f64s(&mut b, &pair.b.data);
    b
}

fn write_file(path: &Path, header: &[u8], payloads: Vec<Vec<u8>>) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(header)?;
    for payload in payloads {
        f.write_all(&(payload.len() as u64).to_le_bytes())?;
        f.write_all(&payload)?;
        f.write_all(&crc32(&payload).to_le_bytes())?;
    }
    f.flush()?;
    Ok(())
}

/// Save the packed BASE (v2, `CLOQPKD2`): codes + dequant params, no LoRA.
pub fn save_base_artifact(model: &PackedModel, path: &Path) -> anyhow::Result<()> {
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_BASE);
    header.extend_from_slice(&VERSION_BASE.to_le_bytes());
    header.extend_from_slice(&(model.layers.len() as u32).to_le_bytes());
    write_file(path, &header, model.layers.iter().map(encode_layer_base).collect())
}

/// Save one adapter set (`CLOQADP1`) — the small per-tenant file that ships
/// without re-shipping the packed base.
pub fn save_adapter_artifact(set: &AdapterSet, path: &Path) -> anyhow::Result<()> {
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_ADAPTER);
    header.extend_from_slice(&VERSION_ADAPTER.to_le_bytes());
    put_str(&mut header, set.id());
    header.extend_from_slice(&(set.len() as u32).to_le_bytes());
    let payloads = set.entries().map(|(n, p)| encode_layer_adapter(n, p)).collect();
    write_file(path, &header, payloads)
}

/// Save in the LEGACY v1 single-tenant layout (`CLOQPKD1`): every layer
/// embeds its adapter from `set`, which must cover the whole model. Kept so
/// the v1 → v2 compatibility path stays testable byte-for-byte; new code
/// should write base + adapter artifacts instead.
pub fn save_artifact_v1(
    model: &PackedModel,
    set: &AdapterSet,
    path: &Path,
) -> anyhow::Result<()> {
    let mut payloads = Vec::with_capacity(model.layers.len());
    for l in &model.layers {
        let pair = set.get(&l.name).ok_or_else(|| {
            anyhow::anyhow!(
                "v1 artifact embeds one adapter per layer, but set '{}' has none for '{}'",
                set.id(),
                l.name
            )
        })?;
        l.check_adapter(pair)?;
        payloads.push(encode_layer_v1(l, pair));
    }
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_V1);
    header.extend_from_slice(&VERSION_V1.to_le_bytes());
    header.extend_from_slice(&(model.layers.len() as u32).to_le_bytes());
    write_file(path, &header, payloads)
}

// ---- decoding ----

/// Bounds-checked byte reader; every read error carries the field name so
/// the loader's layer-context wrapper produces actionable messages.
struct Rd<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, off: 0 }
    }

    fn bytes(&mut self, n: usize, what: &str) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.buf.len() - self.off, // subtraction form: off ≤ len, no overflow
            "truncated while reading {what} (need {n} bytes at offset {}, have {})",
            self.off,
            self.buf.len() - self.off,
        );
        let buf = self.buf; // copy the &'a reference so the slice outlives &mut self
        let s = &buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> anyhow::Result<u32> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> anyhow::Result<u64> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    fn f64s(&mut self, n: usize, what: &str) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(
            n <= (self.buf.len() - self.off) / 8,
            "truncated while reading {what} (need {n} f64s, have {} bytes)",
            self.buf.len() - self.off,
        );
        let b = self.bytes(n * 8, what)?;
        Ok(b.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    fn str(&mut self, what: &str) -> anyhow::Result<String> {
        let len = self.u32(&format!("{what} length"))? as usize;
        String::from_utf8(self.bytes(len, what)?.to_vec())
            .map_err(|e| anyhow::anyhow!("{what} is not UTF-8: {e}"))
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }
}

/// Best-effort layer name from a payload prefix, for CRC-mismatch errors
/// where the payload itself is untrustworthy.
fn peek_name(payload: &[u8]) -> String {
    let mut rd = Rd::new(payload);
    rd.str("name").unwrap_or_else(|_| "<unreadable>".to_string())
}

/// Decode the base fields shared by v1 and v2 payloads. `v1` controls
/// whether the legacy interleaved `rank` field is read (returned as 0 for
/// v2). Leaves `rd` positioned after the dequant params.
fn decode_base_fields(rd: &mut Rd, v1: bool) -> anyhow::Result<(PackedLayer, usize)> {
    let name = rd.str("layer name")?;
    let kind = rd.bytes(1, "param kind")?[0];
    let bits = rd.u32("bits")?;
    anyhow::ensure!((1..=8).contains(&bits), "'{name}': bit width {bits} outside 1..=8");
    let group_size = rd.u64("group size")? as usize;
    anyhow::ensure!(group_size >= 1, "'{name}': group size 0");
    let rows = rd.u64("rows")? as usize;
    let cols = rd.u64("cols")? as usize;
    anyhow::ensure!(rows >= 1 && cols >= 1, "'{name}': degenerate shape {rows}x{cols}");
    let rank = if v1 { rd.u64("rank")? as usize } else { 0 };
    let n_words = rd.u64("packed word count")? as usize;
    // Checked arithmetic throughout: size fields come from untrusted bytes,
    // and a wrapped multiplication must become a named error, not a panic.
    let expect_words = rows
        .checked_mul(words_per_row(cols, bits))
        .ok_or_else(|| anyhow::anyhow!("'{name}': shape {rows}x{cols} overflows"))?;
    anyhow::ensure!(
        n_words == expect_words,
        "'{name}': {n_words} packed words, but {rows}x{cols} at {bits} bits needs {expect_words}"
    );
    anyhow::ensure!(
        n_words <= rd.remaining() / 4,
        "'{name}': {n_words} packed words exceed the payload"
    );
    let wbytes = rd.bytes(n_words * 4, "packed words")?;
    let packed: Vec<u32> =
        wbytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
    let num_groups = rows.div_ceil(group_size);
    let cap = rd.remaining() / 8; // untrusted-count allocations bounded by the bytes present
    let params = match kind {
        KIND_GRID => {
            let sg = rd.u64("scale group count")? as usize;
            anyhow::ensure!(
                sg == num_groups,
                "'{name}': {sg} scale groups, but {rows} rows at group size {group_size} \
                 needs {num_groups}"
            );
            let sn = sg
                .checked_mul(cols)
                .filter(|&v| v <= cap)
                .ok_or_else(|| anyhow::anyhow!("'{name}': {sg}x{cols} scales exceed the payload"))?;
            let scales = Matrix::from_vec(sg, cols, rd.f64s(sn, "scales")?);
            let zeros = Matrix::from_vec(sg, cols, rd.f64s(sn, "zeros")?);
            DequantParams::Grid { scales, zeros }
        }
        KIND_CODEBOOK => {
            let nl = rd.u32("codebook size")? as usize;
            anyhow::ensure!(
                nl == 1usize << bits,
                "'{name}': codebook of {nl} levels cannot index {bits}-bit codes"
            );
            let levels = rd.f64s(nl, "codebook levels")?;
            let ag = rd.u64("absmax group count")? as usize;
            anyhow::ensure!(
                ag == num_groups,
                "'{name}': {ag} absmax groups, but {rows} rows at block size {group_size} \
                 needs {num_groups}"
            );
            let an = ag
                .checked_mul(cols)
                .filter(|&v| v <= cap)
                .ok_or_else(|| anyhow::anyhow!("'{name}': {ag}x{cols} absmax exceed the payload"))?;
            let absmax = Matrix::from_vec(ag, cols, rd.f64s(an, "absmax")?);
            DequantParams::Codebook { levels, absmax }
        }
        other => anyhow::bail!("'{name}': unknown param kind {other}"),
    };
    Ok((PackedLayer { name, rows, cols, bits, group_size, packed, params }, rank))
}

fn decode_layer_base(payload: &[u8]) -> anyhow::Result<PackedLayer> {
    let mut rd = Rd::new(payload);
    let (layer, _) = decode_base_fields(&mut rd, false)?;
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{}': {} trailing bytes after dequant params",
        layer.name,
        rd.remaining()
    );
    Ok(layer)
}

fn decode_layer_v1(payload: &[u8]) -> anyhow::Result<(PackedLayer, LoraPair)> {
    let mut rd = Rd::new(payload);
    let (layer, rank) = decode_base_fields(&mut rd, true)?;
    let name = layer.name.clone();
    let cap = rd.remaining() / 8;
    let numel = |d: usize, what: &str| {
        d.checked_mul(rank)
            .filter(|&v| v <= cap)
            .ok_or_else(|| anyhow::anyhow!("'{name}': {what} of {d}x{rank} exceeds the payload"))
    };
    let na = numel(layer.rows, "adapter A")?;
    let a = Matrix::from_vec(layer.rows, rank, rd.f64s(na, "adapter A")?);
    let nb = numel(layer.cols, "adapter B")?;
    let b = Matrix::from_vec(layer.cols, rank, rd.f64s(nb, "adapter B")?);
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{name}': {} trailing bytes after adapter B",
        rd.remaining()
    );
    Ok((layer, LoraPair::new(a, b)))
}

fn decode_layer_adapter(payload: &[u8]) -> anyhow::Result<(String, LoraPair)> {
    let mut rd = Rd::new(payload);
    let name = rd.str("layer name")?;
    let rows = rd.u64("rows")? as usize;
    let cols = rd.u64("cols")? as usize;
    let rank = rd.u64("rank")? as usize;
    anyhow::ensure!(rows >= 1 && cols >= 1, "'{name}': degenerate shape {rows}x{cols}");
    // Bound untrusted counts by the bytes actually REMAINING (the header
    // is already consumed), matching the sibling decoders.
    let cap = rd.remaining() / 8;
    let numel = |d: usize, what: &str| {
        d.checked_mul(rank)
            .filter(|&v| v <= cap)
            .ok_or_else(|| anyhow::anyhow!("'{name}': {what} of {d}x{rank} exceeds the payload"))
    };
    let a = Matrix::from_vec(rows, rank, rd.f64s(numel(rows, "adapter A")?, "adapter A")?);
    let b = Matrix::from_vec(cols, rank, rd.f64s(numel(cols, "adapter B")?, "adapter B")?);
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{name}': {} trailing bytes after adapter B",
        rd.remaining()
    );
    Ok((name, LoraPair::new(a, b)))
}

/// Read one CRC-framed record: length, payload, checksum. Every failure is
/// wrapped with `lctx` so it names the layer index (and, on a checksum
/// mismatch, the best-effort layer name).
fn read_record<'a>(
    rd: &mut Rd<'a>,
    lctx: &impl Fn(String) -> anyhow::Error,
) -> anyhow::Result<&'a [u8]> {
    let len = rd
        .u64("payload length")
        .map_err(|e| lctx(format!("{e} — file truncated mid-header")))? as usize;
    let payload = rd
        .bytes(len, "payload")
        .map_err(|e| lctx(format!("{e} — file truncated mid-layer")))?;
    let stored_crc = rd
        .u32("checksum")
        .map_err(|e| lctx(format!("{e} — file truncated before checksum")))?;
    let computed = crc32(payload);
    if computed != stored_crc {
        return Err(lctx(format!(
            "('{}') checksum mismatch: stored {stored_crc:08x}, computed {computed:08x} — \
             layer bytes are corrupted",
            peek_name(payload)
        )));
    }
    Ok(payload)
}

struct FileCtx {
    path: String,
}

impl FileCtx {
    fn new(path: &Path) -> FileCtx {
        FileCtx { path: path.display().to_string() }
    }

    fn err(&self, msg: String) -> anyhow::Error {
        anyhow::anyhow!("artifact {}: {msg}", self.path)
    }
}

/// Read and validate magic + version; returns the parsed version's magic.
fn read_header<'a>(
    rd: &mut Rd<'a>,
    ctx: &FileCtx,
    accept: &[(&'static [u8; 8], u32)],
) -> anyhow::Result<&'static [u8; 8]> {
    let magic = rd.bytes(8, "magic").map_err(|e| ctx.err(format!("{e}")))?;
    let found = accept.iter().find(|(m, _)| magic == &m[..]);
    let &(m, want_version) = found.ok_or_else(|| {
        ctx.err(format!(
            "bad magic {:02x?} (expected one of {:?} — not a matching serving artifact)",
            magic,
            accept
                .iter()
                .map(|(m, _)| String::from_utf8_lossy(&m[..]).into_owned())
                .collect::<Vec<_>>()
        ))
    })?;
    let version = rd.u32("version").map_err(|e| ctx.err(format!("{e}")))?;
    if version != want_version {
        return Err(ctx.err(format!(
            "unsupported version {version} (this build reads {want_version} for {})",
            String::from_utf8_lossy(&m[..])
        )));
    }
    Ok(m)
}

fn read_layer_records<'a>(
    rd: &mut Rd<'a>,
    ctx: &FileCtx,
) -> anyhow::Result<Vec<(usize, usize, &'a [u8])>> {
    let n_layers = rd.u32("layer count").map_err(|e| ctx.err(format!("{e}")))? as usize;
    // Untrusted count: cap the reservation by what the remaining bytes could
    // possibly hold (≥ 12 bytes per record: length + checksum), so a corrupt
    // header cannot trigger a huge allocation before validation runs.
    let mut records = Vec::with_capacity(n_layers.min(rd.remaining() / 12));
    for idx in 0..n_layers {
        let lctx = |msg: String| ctx.err(format!("layer {idx}/{n_layers}: {msg}"));
        records.push((idx, n_layers, read_record(rd, &lctx)?));
    }
    anyhow::ensure!(
        rd.remaining() == 0,
        "artifact {}: {} trailing bytes after the last layer",
        ctx.path,
        rd.remaining()
    );
    Ok(records)
}

fn ensure_unique(names: &[String], ctx: &FileCtx) -> anyhow::Result<()> {
    for (i, n) in names.iter().enumerate() {
        if let Some(prev) = names[..i].iter().position(|p| p == n) {
            return Err(ctx.err(format!(
                "layer {i}/{}: duplicate layer name '{n}' (also layer {prev}) — \
                 name-addressed serving would route requests ambiguously",
                names.len()
            )));
        }
    }
    Ok(())
}

/// Load a v2 BASE artifact. v1 files are refused with a pointer to the
/// compat loader (they carry adapters this function would silently drop).
pub fn load_base_artifact(path: &Path) -> anyhow::Result<PackedModel> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("cannot read artifact {}: {e}", path.display()))?;
    let ctx = FileCtx::new(path);
    let mut rd = Rd::new(&bytes);
    if bytes.len() >= 8 && &bytes[..8] == MAGIC_V1 {
        return Err(ctx.err(
            "this is a v1 (CLOQPKD1) single-tenant artifact with embedded adapters; \
             load it with load_artifact_compat, which converts it to base + one \
             adapter set"
                .to_string(),
        ));
    }
    let _ = read_header(&mut rd, &ctx, &[(MAGIC_BASE, VERSION_BASE)])?;
    let mut layers = Vec::new();
    for (idx, n_layers, payload) in read_layer_records(&mut rd, &ctx)? {
        let layer = decode_layer_base(payload)
            .map_err(|e| ctx.err(format!("layer {idx}/{n_layers}: {e}")))?;
        layers.push(layer);
    }
    let names: Vec<String> = layers.iter().map(|l| l.name.clone()).collect();
    ensure_unique(&names, &ctx)?;
    Ok(PackedModel { layers })
}

/// Load one adapter artifact (`CLOQADP1`).
pub fn load_adapter_artifact(path: &Path) -> anyhow::Result<AdapterSet> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("cannot read artifact {}: {e}", path.display()))?;
    let ctx = FileCtx::new(path);
    let mut rd = Rd::new(&bytes);
    let _ = read_header(&mut rd, &ctx, &[(MAGIC_ADAPTER, VERSION_ADAPTER)])?;
    let id = rd.str("adapter id").map_err(|e| ctx.err(format!("{e}")))?;
    let mut set = AdapterSet::new(&id);
    for (idx, n_layers, payload) in read_layer_records(&mut rd, &ctx)? {
        let (name, pair) = decode_layer_adapter(payload)
            .map_err(|e| ctx.err(format!("layer {idx}/{n_layers}: {e}")))?;
        set.insert(&name, pair)
            .map_err(|e| ctx.err(format!("layer {idx}/{n_layers}: {e}")))?;
    }
    Ok(set)
}

/// Load EITHER format a served model can start from:
///
/// * a v2 base artifact → `(model, None)` — adapters arrive separately via
///   [`load_adapter_artifact`];
/// * a legacy v1 artifact → `(model, Some(set))` — the embedded per-layer
///   adapters are split out into one [`AdapterSet`] named
///   [`V1_ADAPTER_ID`], ready for `ServeEngine::register_adapter`. The
///   conversion is value-exact (same f64 bits), so forwards through the
///   converted pair are bit-identical to the v1 embedded layout.
pub fn load_artifact_compat(path: &Path) -> anyhow::Result<(PackedModel, Option<AdapterSet>)> {
    let bytes = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("cannot read artifact {}: {e}", path.display()))?;
    let ctx = FileCtx::new(path);
    let mut rd = Rd::new(&bytes);
    let magic =
        read_header(&mut rd, &ctx, &[(MAGIC_BASE, VERSION_BASE), (MAGIC_V1, VERSION_V1)])?;
    let v1 = magic == MAGIC_V1;
    let mut layers = Vec::new();
    let mut pairs = Vec::new();
    for (idx, n_layers, payload) in read_layer_records(&mut rd, &ctx)? {
        let lerr = |e: anyhow::Error| ctx.err(format!("layer {idx}/{n_layers}: {e}"));
        if v1 {
            let (layer, pair) = decode_layer_v1(payload).map_err(lerr)?;
            pairs.push((layer.name.clone(), pair));
            layers.push(layer);
        } else {
            layers.push(decode_layer_base(payload).map_err(lerr)?);
        }
    }
    let names: Vec<String> = layers.iter().map(|l| l.name.clone()).collect();
    ensure_unique(&names, &ctx)?;
    let set = if v1 {
        Some(
            AdapterSet::from_pairs(V1_ADAPTER_ID, pairs)
                .map_err(|e| ctx.err(format!("{e}")))?,
        )
    } else {
        None
    };
    Ok((PackedModel { layers }, set))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_nf, quantize_rtn, QuantState};
    use crate::util::prng::Rng;

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    fn tmp(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("cloq_serve_{tag}_{}", std::process::id()))
    }

    fn small_model(seed: u64) -> (PackedModel, AdapterSet) {
        let mut rng = Rng::new(seed);
        let w1 = Matrix::randn(20, 9, 0.3, &mut rng);
        let w2 = Matrix::randn(16, 5, 0.3, &mut rng);
        let l1 = PackedLayer::from_state("blk0.wq", &QuantState::Int(quantize_rtn(&w1, 3, 8)))
            .unwrap();
        let p1 = LoraPair::new(
            Matrix::randn(20, 2, 0.1, &mut rng),
            Matrix::randn(9, 2, 0.1, &mut rng),
        );
        let l2 = PackedLayer::from_state("blk0.wo", &QuantState::Nf(quantize_nf(&w2, 4, 8)))
            .unwrap();
        let p2 = LoraPair::new(
            Matrix::randn(16, 2, 0.1, &mut rng),
            Matrix::randn(5, 2, 0.1, &mut rng),
        );
        let set = AdapterSet::from_pairs(
            "tenant",
            vec![("blk0.wq".to_string(), p1), ("blk0.wo".to_string(), p2)],
        )
        .unwrap();
        (PackedModel::new(vec![l1, l2]), set)
    }

    #[test]
    fn base_roundtrip_preserves_forward_bits() {
        let dir = tmp("rt");
        let (model, _) = small_model(300);
        let path = dir.join("model.cloqpkd2");
        save_base_artifact(&model, &path).unwrap();
        let loaded = load_base_artifact(&path).unwrap();
        let mut rng = Rng::new(301);
        for (a, b) in model.layers.iter().zip(&loaded.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.packed, b.packed);
            let x = rng.gauss_vec(a.rows);
            let (ya, yb) = (a.forward(&x, None), b.forward(&x, None));
            for (u, v) in ya.iter().zip(&yb) {
                assert_eq!(u.to_bits(), v.to_bits(), "layer {}", a.name);
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn adapter_roundtrip_is_exact() {
        let dir = tmp("adp");
        let (_, set) = small_model(305);
        let path = dir.join("tenant.cloqadp");
        save_adapter_artifact(&set, &path).unwrap();
        let loaded = load_adapter_artifact(&path).unwrap();
        assert_eq!(loaded.id(), "tenant");
        assert_eq!(loaded.len(), set.len());
        for (name, pair) in set.entries() {
            let got = loaded.get(name).unwrap();
            assert!(
                pair.a.data.iter().map(|v| v.to_bits()).eq(got.a.data.iter().map(|v| v.to_bits())),
                "{name}: A"
            );
            assert!(
                pair.b.data.iter().map(|v| v.to_bits()).eq(got.b.data.iter().map(|v| v.to_bits())),
                "{name}: B"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn corruption_names_the_layer() {
        let dir = tmp("bad");
        let (model, _) = small_model(302);
        let path = dir.join("model.cloqpkd2");
        save_base_artifact(&model, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one bit deep inside the SECOND layer's payload.
        let n = bytes.len();
        bytes[n - 40] ^= 0x10;
        let bad = dir.join("flipped.cloqpkd2");
        std::fs::write(&bad, &bytes).unwrap();
        let msg = format!("{}", load_base_artifact(&bad).unwrap_err());
        assert!(msg.contains("layer 1/2"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        assert!(msg.contains("blk0.wo"), "error should name the layer: {msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bad_magic_and_version_rejected() {
        let dir = tmp("magic");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.bin");
        std::fs::write(&p, b"NOTCLOQ!rest").unwrap();
        let msg = format!("{}", load_base_artifact(&p).unwrap_err());
        assert!(msg.contains("bad magic"), "{msg}");

        let (model, _) = small_model(303);
        let good = dir.join("good.cloqpkd2");
        save_base_artifact(&model, &good).unwrap();
        let mut bytes = std::fs::read(&good).unwrap();
        bytes[8] = 99; // version field
        let vbad = dir.join("vbad.cloqpkd2");
        std::fs::write(&vbad, &bytes).unwrap();
        let msg = format!("{}", load_base_artifact(&vbad).unwrap_err());
        assert!(msg.contains("unsupported version 99"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn v1_files_are_refused_by_the_base_loader_with_a_pointer() {
        let dir = tmp("v1ptr");
        let (model, set) = small_model(304);
        let path = dir.join("legacy.cloqpkd");
        save_artifact_v1(&model, &set, &path).unwrap();
        let msg = format!("{}", load_base_artifact(&path).unwrap_err());
        assert!(msg.contains("load_artifact_compat"), "{msg}");
        std::fs::remove_dir_all(&dir).ok();
    }
}

//! The packed serving artifacts: versioned binary checkpoints for the
//! packed base and for individual adapter sets, unified behind
//! [`ArtifactStore`].
//!
//! Three current formats plus one legacy reader (all integers
//! little-endian, every payload CRC-guarded):
//!
//! ```text
//!   base artifact (v2)                adapter artifact
//!   magic    "CLOQPKD2"   8 bytes     magic    "CLOQADP1"   8 bytes
//!   version  u32 (= 2)                version  u32 (= 1)
//!   n_layers u32                      id_len   u32
//!   repeat n_layers times:            id       id_len bytes
//!     payload_len u64                 n_layers u32
//!     payload     (base layer)        repeat n_layers times:
//!     crc32       u32                   payload_len u64
//!                                       payload     (name, shape, A, B)
//!                                       crc32       u32
//!
//!   base artifact (v3, zero-copy)
//!   magic    "CLOQPKD3"   8 bytes
//!   version  u32 (= 3)
//!   n_layers u32
//!   repeat n_layers times (the directory):
//!     name_len u32 · name · kind u8 · bits u32
//!     group_size u64 · rows u64 · cols u64
//!     codes_off u64 · codes_len u64 · codes_crc u32
//!     params_off u64 · params_len u64 · params_crc u32
//!   dir_crc  u32  (crc32 of everything from version to here)
//!   ...zero padding to the next 4096 boundary...
//!   per layer, each section starting at a 4096 multiple:
//!     codes  section (raw LE u32 words, row-aligned)
//!     params section (same byte encoding as the v2 params tail)
//! ```
//!
//! The **base** artifacts carry NO LoRA payloads: codes + dequant params
//! only. Adapters ship separately in the small **adapter** artifact
//! (`CLOQADP1`), so a new tenant deploys without re-shipping the packed
//! base — the multi-tenant split `serve::adapters` serves from. The v1
//! format (`CLOQPKD1`, the original single-tenant layout with A/B embedded
//! per layer) is still readable: [`ArtifactStore::open`] autodetects it
//! and returns [`Artifact::LegacyV1`] with the embedded adapters split
//! into one set named [`V1_ADAPTER_ID`].
//!
//! **v3 is the zero-copy layout.** Its code sections are page-aligned so
//! [`ArtifactStore::open_mapped`] can `mmap` the file and serve the
//! packed words **in place** (`PackedSource::Mapped`): cold start reads
//! the directory, eagerly decodes + CRC-checks the small params
//! sections, and defers each code section's CRC to its first kernel
//! touch (`PackedLayer::verify`) — no copy, no up-front hash of the big
//! sections, and at most one resident copy of the base shared by every
//! process that maps it. [`ArtifactStore::open`] also reads v3, eagerly
//! and fully checked, for callers that want copy semantics. Every header
//! byte is guarded: the magic by the magic check, everything from the
//! version to the end of the directory by `dir_crc`, each section by its
//! directory CRC — only the zero padding between sections is outside any
//! checksum (locked by the exhaustive single-bit corruption sweep in
//! `rust/tests/golden_serve.rs`).
//!
//! **The store** is the one entry point: [`ArtifactStore::save_base`] /
//! [`ArtifactStore::save_base_v3`] / [`ArtifactStore::save_adapter`]
//! write the current formats, and [`ArtifactStore::open`] /
//! [`ArtifactStore::open_mapped`] read ANY of the four — the magic
//! bytes, not the file name, decide what comes back, so a deployment
//! script can point the server at a directory of mixed artifacts and
//! match on [`Artifact`].
//!
//! Each layer payload carries its own name, shapes and parameter kind, so
//! the loaders can validate structurally and — the part that matters at
//! 3 a.m. — every corruption error is a typed
//! [`ServeError::Artifact`] whose `kind` classifies the failure
//! ([`ArtifactErrorKind`]: truncation vs checksum vs structure) and whose
//! `layer` **names the offending layer** whenever the bytes still reveal
//! it, instead of a bare parse failure.
//!
//! Roundtrip contract (locked by `rust/tests/golden_serve.rs`): save →
//! load reproduces every layer's quantization state **byte-identically**
//! (codes, scales/zeros or levels/absmax, adapters — all f64, no precision
//! laundering) and therefore a bit-identical packed forward; and loading a
//! v1 file through the legacy path forwards bit-identically to the
//! original embedded-adapter layers.

use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::linalg::Matrix;
use crate::lowrank::LoraPair;
use crate::serve::adapters::AdapterSet;
use crate::serve::error::{ArtifactErrorKind, ServeError};
use crate::serve::mmap::MappedFile;
use crate::serve::packed::{words_per_row, DequantParams, PackedLayer, PackedModel, PackedSource};

/// Legacy single-tenant format: adapters embedded per layer.
pub const MAGIC_V1: &[u8; 8] = b"CLOQPKD1";
pub const VERSION_V1: u32 = 1;
/// Record-framed base format: no LoRA payloads.
pub const MAGIC_BASE: &[u8; 8] = b"CLOQPKD2";
pub const VERSION_BASE: u32 = 2;
/// Zero-copy base format: directory + page-aligned mmap-able sections.
pub const MAGIC_V3: &[u8; 8] = b"CLOQPKD3";
pub const VERSION_V3: u32 = 3;
/// Section alignment of the v3 layout: one x86-64/aarch64 base page, so a
/// mapped code section is both page- and word-aligned in memory.
pub const V3_ALIGN: usize = 4096;
/// Adapter artifact: one AdapterSet, shippable without the base.
pub const MAGIC_ADAPTER: &[u8; 8] = b"CLOQADP1";
pub const VERSION_ADAPTER: u32 = 1;

/// Adapter-set id assigned when a legacy v1 artifact's embedded adapters
/// are split out ([`Artifact::LegacyV1`]).
pub const V1_ADAPTER_ID: &str = "v1";

const KIND_GRID: u8 = 0;
const KIND_CODEBOOK: u8 = 1;

// ---- CRC-32 (IEEE 802.3), table built at compile time ----

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// IEEE CRC-32 over `bytes` (the checksum guarding each layer payload).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = (c >> 8) ^ CRC_TABLE[((c ^ b as u32) & 0xFF) as usize];
    }
    c ^ 0xFFFF_FFFF
}

// ---- the unified store ----

/// What [`ArtifactStore::open`] found, decided by the file's magic bytes.
pub enum Artifact {
    /// A v2 base artifact: the packed model, no adapters.
    Base(PackedModel),
    /// An adapter artifact: one tenant's set, shipped without the base.
    Adapter(AdapterSet),
    /// A legacy v1 single-tenant file: the base plus its embedded
    /// adapters, split into one set named [`V1_ADAPTER_ID`]. The
    /// conversion is value-exact (same f64 bits), so forwards through the
    /// converted pair are bit-identical to the embedded layout.
    LegacyV1 { model: PackedModel, adapters: AdapterSet },
}

impl Artifact {
    /// Short slug for logs and error messages.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Artifact::Base(_) => "base",
            Artifact::Adapter(_) => "adapter",
            Artifact::LegacyV1 { .. } => "legacy-v1",
        }
    }

    /// The packed model, refusing non-base artifacts. A legacy file is
    /// refused too — its embedded adapters must not be dropped silently;
    /// match [`Artifact::LegacyV1`] to keep them.
    pub fn into_base(self) -> Result<PackedModel, ServeError> {
        match self {
            Artifact::Base(m) => Ok(m),
            other => Err(ServeError::Unsupported {
                detail: format!(
                    "expected a base artifact, found a {} artifact; open() and match \
                     the Artifact variant instead",
                    other.kind_name()
                ),
            }),
        }
    }

    /// The adapter set, refusing non-adapter artifacts.
    pub fn into_adapter(self) -> Result<AdapterSet, ServeError> {
        match self {
            Artifact::Adapter(s) => Ok(s),
            other => Err(ServeError::Unsupported {
                detail: format!(
                    "expected an adapter artifact, found a {} artifact; open() and \
                     match the Artifact variant instead",
                    other.kind_name()
                ),
            }),
        }
    }
}

/// Kind slug only — the payloads (whole packed models) are far too large
/// to dump, and tests only need `Result<Artifact, _>::unwrap_err`.
impl std::fmt::Debug for Artifact {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_tuple("Artifact").field(&self.kind_name()).finish()
    }
}

/// The unified serving-artifact store: one directory, three formats, one
/// read entry point. Writers pick the format
/// ([`ArtifactStore::save_base`] / [`ArtifactStore::save_adapter`]);
/// [`ArtifactStore::open`] autodetects what a file is from its magic
/// bytes and returns the matching [`Artifact`]. All failures are typed
/// [`ServeError::Artifact`] values carrying the path, the failure
/// [`ArtifactErrorKind`], and the offending layer's name when known.
pub struct ArtifactStore {
    dir: PathBuf,
    /// Optional engine telemetry: open counts (by mode) and open-duration
    /// histogram. None = uninstrumented, zero overhead.
    telemetry: Option<Arc<crate::serve::telemetry::Telemetry>>,
}

impl ArtifactStore {
    /// A store rooted at `dir` (created lazily on the first save).
    pub fn at(dir: impl Into<PathBuf>) -> ArtifactStore {
        ArtifactStore { dir: dir.into(), telemetry: None }
    }

    /// Instrument this store: reads record `ArtifactOpensEager` /
    /// `ArtifactOpensMapped` counters and the `ArtifactOpen` duration
    /// histogram into `telemetry` (wire an engine's core in via
    /// `ServeEngine::telemetry_handle`).
    pub fn with_telemetry(
        mut self,
        telemetry: Arc<crate::serve::telemetry::Telemetry>,
    ) -> ArtifactStore {
        self.telemetry = Some(telemetry);
        self
    }

    fn observe_open(&self, mode: crate::serve::telemetry::Counter, t0: std::time::Instant) {
        if let Some(t) = &self.telemetry {
            t.incr(mode);
            t.observe(crate::serve::telemetry::Metric::ArtifactOpen, t0.elapsed().as_secs_f64());
        }
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The on-disk path a name maps to (`dir/name` — names may carry
    /// their own extension convention, e.g. `base.cloqpkd2`).
    pub fn path(&self, name: &str) -> PathBuf {
        self.dir.join(name)
    }

    /// Write the packed BASE (v2, `CLOQPKD2`): codes + dequant params, no
    /// LoRA. Returns the written path. (v2 stays the default writer so
    /// committed golden bytes stay stable; [`ArtifactStore::save_base_v3`]
    /// writes the zero-copy layout.)
    pub fn save_base(&self, model: &PackedModel, name: &str) -> Result<PathBuf, ServeError> {
        let path = self.path(name);
        save_base_at(model, &path)?;
        Ok(path)
    }

    /// Write the packed BASE in the ZERO-COPY layout (v3, `CLOQPKD3`):
    /// directory up front, every code/params section page-aligned so
    /// [`ArtifactStore::open_mapped`] can serve the codes straight from
    /// mapped pages. Returns the written path.
    pub fn save_base_v3(&self, model: &PackedModel, name: &str) -> Result<PathBuf, ServeError> {
        let path = self.path(name);
        save_base_v3_at(model, &path)?;
        Ok(path)
    }

    /// Write one adapter set (`CLOQADP1`) — the small per-tenant file that
    /// ships without re-shipping the packed base. Returns the written path.
    pub fn save_adapter(&self, set: &AdapterSet, name: &str) -> Result<PathBuf, ServeError> {
        let path = self.path(name);
        save_adapter_at(set, &path)?;
        Ok(path)
    }

    /// Write the LEGACY v1 single-tenant layout (`CLOQPKD1`): every layer
    /// embeds its adapter from `set`, which must cover the whole model.
    /// Kept so the v1 compatibility path stays testable byte-for-byte; new
    /// deployments write base + adapter artifacts instead.
    pub fn save_legacy_v1(
        &self,
        model: &PackedModel,
        set: &AdapterSet,
        name: &str,
    ) -> Result<PathBuf, ServeError> {
        let path = self.path(name);
        save_v1_at(model, set, &path)?;
        Ok(path)
    }

    /// Read `name`, autodetecting which of the four formats it holds from
    /// the magic bytes. Always EAGER and fully checked — every section
    /// CRC is verified before this returns, and the result owns its
    /// buffers (a v3 file is copied, not mapped; use
    /// [`ArtifactStore::open_mapped`] for zero-copy).
    pub fn open(&self, name: &str) -> Result<Artifact, ServeError> {
        let t0 = std::time::Instant::now();
        let art = open_at(&self.path(name))?;
        self.observe_open(crate::serve::telemetry::Counter::ArtifactOpensEager, t0);
        Ok(art)
    }

    /// Zero-copy open: `mmap` the file and, when it is a v3 base
    /// artifact, serve the packed code sections IN PLACE — the directory
    /// and the small params sections are checked eagerly, each code
    /// section's CRC is deferred to its first kernel touch
    /// (`PackedLayer::verify`, surfacing as a typed `ChecksumMismatch`
    /// naming the layer). Non-v3 files fall back to [`ArtifactStore::open`]
    /// byte-identically, so callers can point this at any artifact. The
    /// codes also fall back to owned copies (with eager CRCs) when the
    /// platform cannot honor the in-place cast — big-endian hosts, or an
    /// mmap-less filesystem.
    pub fn open_mapped(&self, name: &str) -> Result<Artifact, ServeError> {
        let t0 = std::time::Instant::now();
        let art = open_mapped_at(&self.path(name))?;
        self.observe_open(crate::serve::telemetry::Counter::ArtifactOpensMapped, t0);
        Ok(art)
    }

    /// Read a base artifact, refusing adapter and legacy files with a
    /// pointer to [`ArtifactStore::open`] (a legacy file's embedded
    /// adapters must not be dropped silently).
    pub fn load_base(&self, name: &str) -> Result<PackedModel, ServeError> {
        let t0 = std::time::Instant::now();
        let model = load_base_at(&self.path(name))?;
        self.observe_open(crate::serve::telemetry::Counter::ArtifactOpensEager, t0);
        Ok(model)
    }

    /// Read an adapter artifact, refusing the other formats (one source
    /// of truth: [`Artifact::into_adapter`], with the path prepended).
    pub fn load_adapter(&self, name: &str) -> Result<AdapterSet, ServeError> {
        self.open(name)?.into_adapter().map_err(|e| match e {
            ServeError::Unsupported { detail } => ServeError::Unsupported {
                detail: format!("artifact {}: {detail}", self.path(name).display()),
            },
            other => other,
        })
    }
}

// ---- encoding ----

pub(crate) fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub(crate) fn put_f64s(out: &mut Vec<u8>, vs: &[f64]) {
    for v in vs {
        out.extend_from_slice(&v.to_le_bytes());
    }
}

pub(crate) fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// The dequant-params tail shared byte-for-byte by the v1/v2 payloads and
/// the v3 params SECTION (one encoder, so v2→v3 conversion cannot drift).
fn encode_params(b: &mut Vec<u8>, params: &DequantParams) {
    match params {
        DequantParams::Grid { scales, zeros } => {
            put_u64(b, scales.rows as u64);
            put_f64s(b, &scales.data);
            put_f64s(b, &zeros.data);
        }
        DequantParams::Codebook { levels, absmax } => {
            put_u32(b, levels.len() as u32);
            put_f64s(b, levels);
            put_u64(b, absmax.rows as u64);
            put_f64s(b, &absmax.data);
        }
    }
}

/// The base-layer fields shared by the v1 and v2 payloads: identity,
/// quantization geometry, packed words and dequant params. v1 additionally
/// interleaves `rank` (after `cols`) and appends A/B — see `encode_layer_v1`.
fn encode_base_fields(b: &mut Vec<u8>, l: &PackedLayer, rank_v1: Option<usize>) {
    put_str(b, &l.name);
    b.push(match &l.params {
        DequantParams::Grid { .. } => KIND_GRID,
        DequantParams::Codebook { .. } => KIND_CODEBOOK,
    });
    put_u32(b, l.bits);
    put_u64(b, l.group_size as u64);
    put_u64(b, l.rows as u64);
    put_u64(b, l.cols as u64);
    if let Some(r) = rank_v1 {
        put_u64(b, r as u64);
    }
    put_u64(b, l.packed.len() as u64);
    for w in l.packed.words() {
        put_u32(b, *w);
    }
    encode_params(b, &l.params);
}

fn encode_layer_base(l: &PackedLayer) -> Vec<u8> {
    let mut b = Vec::new();
    encode_base_fields(&mut b, l, None);
    b
}

/// v1 layout (byte-for-byte): base fields with `rank` after `cols`, then A
/// and B row-major f64.
fn encode_layer_v1(l: &PackedLayer, pair: &LoraPair) -> Vec<u8> {
    let mut b = Vec::new();
    encode_base_fields(&mut b, l, Some(pair.rank()));
    put_f64s(&mut b, &pair.a.data);
    put_f64s(&mut b, &pair.b.data);
    b
}

pub(crate) fn encode_layer_adapter(name: &str, pair: &LoraPair) -> Vec<u8> {
    let mut b = Vec::new();
    put_str(&mut b, name);
    put_u64(&mut b, pair.a.rows as u64);
    put_u64(&mut b, pair.b.rows as u64);
    put_u64(&mut b, pair.rank() as u64);
    put_f64s(&mut b, &pair.a.data);
    put_f64s(&mut b, &pair.b.data);
    b
}

fn io_err(path: &Path, what: &str, e: std::io::Error) -> ServeError {
    ServeError::Artifact {
        path: path.display().to_string(),
        layer: None,
        kind: ArtifactErrorKind::Io,
        detail: format!("{what}: {e}"),
    }
}

fn write_file(path: &Path, header: &[u8], payloads: Vec<Vec<u8>>) -> Result<(), ServeError> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).map_err(|e| io_err(path, "cannot create dir", e))?;
    }
    let inner = || -> std::io::Result<()> {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(header)?;
        for payload in &payloads {
            f.write_all(&(payload.len() as u64).to_le_bytes())?;
            f.write_all(payload)?;
            f.write_all(&crc32(payload).to_le_bytes())?;
        }
        f.flush()
    };
    inner().map_err(|e| io_err(path, "cannot write", e))
}

fn save_base_at(model: &PackedModel, path: &Path) -> Result<(), ServeError> {
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_BASE);
    header.extend_from_slice(&VERSION_BASE.to_le_bytes());
    header.extend_from_slice(&(model.layers.len() as u32).to_le_bytes());
    write_file(path, &header, model.layers.iter().map(encode_layer_base).collect())
}

/// Round `off` up to the next [`V3_ALIGN`] boundary.
fn v3_align_up(off: usize) -> usize {
    off.div_ceil(V3_ALIGN) * V3_ALIGN
}

/// Byte length of one v3 directory entry (see the module-docs diagram).
fn v3_entry_len(name: &str) -> usize {
    // name(4+len) + kind(1) + bits(4) + group_size/rows/cols(24)
    // + codes off/len/crc(20) + params off/len/crc(20)
    4 + name.len() + 1 + 4 + 24 + 20 + 20
}

fn save_base_v3_at(model: &PackedModel, path: &Path) -> Result<(), ServeError> {
    // Pass 1: encode the params sections and lay out the section offsets.
    // The directory's size depends only on the layer names, so the header
    // length — and with it the first aligned section offset — is known
    // before any offsets are written.
    let params_blobs: Vec<Vec<u8>> = model
        .layers
        .iter()
        .map(|l| {
            let mut b = Vec::new();
            encode_params(&mut b, &l.params);
            b
        })
        .collect();
    let header_len = 8
        + 4
        + 4
        + model.layers.iter().map(|l| v3_entry_len(&l.name)).sum::<usize>()
        + 4; // dir_crc
    let mut off = header_len;
    let mut sections = Vec::with_capacity(model.layers.len()); // (codes_off, params_off)
    for (l, blob) in model.layers.iter().zip(&params_blobs) {
        off = v3_align_up(off);
        let codes_off = off;
        off += l.packed.len() * 4;
        off = v3_align_up(off);
        let params_off = off;
        off += blob.len();
        sections.push((codes_off, params_off));
    }

    // Pass 2: fill the file image — sections first, then the directory
    // (whose CRC fields hash the section bytes just written), then
    // dir_crc over everything from the version to the end of the
    // directory. The gaps stay zero and are the ONLY unchecksummed bytes.
    let mut buf = vec![0u8; off];
    for ((l, blob), &(codes_off, params_off)) in
        model.layers.iter().zip(&params_blobs).zip(&sections)
    {
        let mut w = codes_off;
        for word in l.packed.words() {
            buf[w..w + 4].copy_from_slice(&word.to_le_bytes());
            w += 4;
        }
        buf[params_off..params_off + blob.len()].copy_from_slice(blob);
    }
    let mut header = Vec::with_capacity(header_len);
    header.extend_from_slice(MAGIC_V3);
    put_u32(&mut header, VERSION_V3);
    put_u32(&mut header, model.layers.len() as u32);
    for ((l, blob), &(codes_off, params_off)) in
        model.layers.iter().zip(&params_blobs).zip(&sections)
    {
        let codes_len = l.packed.len() * 4;
        put_str(&mut header, &l.name);
        header.push(match &l.params {
            DequantParams::Grid { .. } => KIND_GRID,
            DequantParams::Codebook { .. } => KIND_CODEBOOK,
        });
        put_u32(&mut header, l.bits);
        put_u64(&mut header, l.group_size as u64);
        put_u64(&mut header, l.rows as u64);
        put_u64(&mut header, l.cols as u64);
        put_u64(&mut header, codes_off as u64);
        put_u64(&mut header, codes_len as u64);
        put_u32(&mut header, crc32(&buf[codes_off..codes_off + codes_len]));
        put_u64(&mut header, params_off as u64);
        put_u64(&mut header, blob.len() as u64);
        put_u32(&mut header, crc32(blob));
    }
    let dir_crc = crc32(&header[8..]);
    put_u32(&mut header, dir_crc);
    debug_assert_eq!(header.len(), header_len);
    buf[..header_len].copy_from_slice(&header);

    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent).map_err(|e| io_err(path, "cannot create dir", e))?;
    }
    std::fs::write(path, &buf).map_err(|e| io_err(path, "cannot write", e))
}

fn save_adapter_at(set: &AdapterSet, path: &Path) -> Result<(), ServeError> {
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_ADAPTER);
    header.extend_from_slice(&VERSION_ADAPTER.to_le_bytes());
    put_str(&mut header, set.id());
    header.extend_from_slice(&(set.len() as u32).to_le_bytes());
    let payloads = set.entries().map(|(n, p)| encode_layer_adapter(n, p)).collect();
    write_file(path, &header, payloads)
}

/// v1 embeds one adapter per layer: fetch and shape-check the layer's pair
/// from `set`, as a typed error when it is absent.
fn v1_pair<'a>(l: &PackedLayer, set: &'a AdapterSet) -> Result<&'a LoraPair, ServeError> {
    let pair = set.get(&l.name).ok_or_else(|| ServeError::AdapterMismatch {
        adapter: set.id().to_string(),
        layer: Some(l.name.clone()),
    })?;
    l.check_adapter(pair)?;
    Ok(pair)
}

fn save_v1_at(model: &PackedModel, set: &AdapterSet, path: &Path) -> Result<(), ServeError> {
    let mut payloads = Vec::with_capacity(model.layers.len());
    for l in &model.layers {
        payloads.push(encode_layer_v1(l, v1_pair(l, set)?));
    }
    let mut header = Vec::new();
    header.extend_from_slice(MAGIC_V1);
    header.extend_from_slice(&VERSION_V1.to_le_bytes());
    header.extend_from_slice(&(model.layers.len() as u32).to_le_bytes());
    write_file(path, &header, payloads)
}

// ---- decoding ----

/// Bounds-checked byte reader; every read error carries the field name so
/// the loader's layer-context wrapper produces actionable messages.
/// Crate-visible: the adapter WAL (`serve::wal`) frames its record
/// payloads with the same primitives.
pub(crate) struct Rd<'a> {
    buf: &'a [u8],
    off: usize,
}

impl<'a> Rd<'a> {
    pub(crate) fn new(buf: &'a [u8]) -> Rd<'a> {
        Rd { buf, off: 0 }
    }

    pub(crate) fn bytes(&mut self, n: usize, what: &str) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.buf.len() - self.off, // subtraction form: off ≤ len, no overflow
            "truncated while reading {what} (need {n} bytes at offset {}, have {})",
            self.off,
            self.buf.len() - self.off,
        );
        let buf = self.buf; // copy the &'a reference so the slice outlives &mut self
        let s = &buf[self.off..self.off + n];
        self.off += n;
        Ok(s)
    }

    pub(crate) fn u32(&mut self, what: &str) -> anyhow::Result<u32> {
        let b = self.bytes(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub(crate) fn u64(&mut self, what: &str) -> anyhow::Result<u64> {
        let b = self.bytes(8, what)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    pub(crate) fn f64s(&mut self, n: usize, what: &str) -> anyhow::Result<Vec<f64>> {
        anyhow::ensure!(
            n <= (self.buf.len() - self.off) / 8,
            "truncated while reading {what} (need {n} f64s, have {} bytes)",
            self.buf.len() - self.off,
        );
        let b = self.bytes(n * 8, what)?;
        Ok(b.chunks_exact(8).map(|c| f64::from_le_bytes(c.try_into().unwrap())).collect())
    }

    pub(crate) fn str(&mut self, what: &str) -> anyhow::Result<String> {
        let len = self.u32(&format!("{what} length"))? as usize;
        String::from_utf8(self.bytes(len, what)?.to_vec())
            .map_err(|e| anyhow::anyhow!("{what} is not UTF-8: {e}"))
    }

    pub(crate) fn remaining(&self) -> usize {
        self.buf.len() - self.off
    }
}

/// Best-effort layer name from a payload prefix, for errors where the
/// payload itself is suspect or partially decoded.
fn peek_name(payload: &[u8]) -> Option<String> {
    Rd::new(payload).str("name").ok()
}

/// Decode the base fields shared by v1 and v2 payloads. `v1` controls
/// whether the legacy interleaved `rank` field is read (returned as 0 for
/// v2). Leaves `rd` positioned after the dequant params.
fn decode_base_fields(rd: &mut Rd, v1: bool) -> anyhow::Result<(PackedLayer, usize)> {
    let name = rd.str("layer name")?;
    let kind = rd.bytes(1, "param kind")?[0];
    let bits = rd.u32("bits")?;
    anyhow::ensure!((1..=8).contains(&bits), "'{name}': bit width {bits} outside 1..=8");
    let group_size = rd.u64("group size")? as usize;
    anyhow::ensure!(group_size >= 1, "'{name}': group size 0");
    let rows = rd.u64("rows")? as usize;
    let cols = rd.u64("cols")? as usize;
    anyhow::ensure!(rows >= 1 && cols >= 1, "'{name}': degenerate shape {rows}x{cols}");
    let rank = if v1 { rd.u64("rank")? as usize } else { 0 };
    let n_words = rd.u64("packed word count")? as usize;
    // Checked arithmetic throughout: size fields come from untrusted bytes,
    // and a wrapped multiplication must become a named error, not a panic.
    let expect_words = rows
        .checked_mul(words_per_row(cols, bits))
        .ok_or_else(|| anyhow::anyhow!("'{name}': shape {rows}x{cols} overflows"))?;
    anyhow::ensure!(
        n_words == expect_words,
        "'{name}': {n_words} packed words, but {rows}x{cols} at {bits} bits needs {expect_words}"
    );
    anyhow::ensure!(
        n_words <= rd.remaining() / 4,
        "'{name}': {n_words} packed words exceed the payload"
    );
    let wbytes = rd.bytes(n_words * 4, "packed words")?;
    let packed: Vec<u32> =
        wbytes.chunks_exact(4).map(|c| u32::from_le_bytes(c.try_into().unwrap())).collect();
    let params = decode_params(rd, &name, kind, bits, rows, cols, group_size)?;
    Ok((PackedLayer { name, rows, cols, bits, group_size, packed: packed.into(), params }, rank))
}

/// Decode the dequant-params tail — shared by the v1/v2 payload decoders
/// and the v3 params-section reader (one decoder, mirroring
/// `encode_params`). Validates group counts against the layer geometry
/// and bounds every untrusted count by the bytes present.
fn decode_params(
    rd: &mut Rd,
    name: &str,
    kind: u8,
    bits: u32,
    rows: usize,
    cols: usize,
    group_size: usize,
) -> anyhow::Result<DequantParams> {
    let num_groups = rows.div_ceil(group_size);
    let cap = rd.remaining() / 8; // untrusted-count allocations bounded by the bytes present
    Ok(match kind {
        KIND_GRID => {
            let sg = rd.u64("scale group count")? as usize;
            anyhow::ensure!(
                sg == num_groups,
                "'{name}': {sg} scale groups, but {rows} rows at group size {group_size} \
                 needs {num_groups}"
            );
            let sn = sg
                .checked_mul(cols)
                .filter(|&v| v <= cap)
                .ok_or_else(|| anyhow::anyhow!("'{name}': {sg}x{cols} scales exceed the payload"))?;
            let scales = Matrix::from_vec(sg, cols, rd.f64s(sn, "scales")?);
            let zeros = Matrix::from_vec(sg, cols, rd.f64s(sn, "zeros")?);
            DequantParams::Grid { scales, zeros }
        }
        KIND_CODEBOOK => {
            let nl = rd.u32("codebook size")? as usize;
            anyhow::ensure!(
                nl == 1usize << bits,
                "'{name}': codebook of {nl} levels cannot index {bits}-bit codes"
            );
            let levels = rd.f64s(nl, "codebook levels")?;
            let ag = rd.u64("absmax group count")? as usize;
            anyhow::ensure!(
                ag == num_groups,
                "'{name}': {ag} absmax groups, but {rows} rows at block size {group_size} \
                 needs {num_groups}"
            );
            let an = ag
                .checked_mul(cols)
                .filter(|&v| v <= cap)
                .ok_or_else(|| anyhow::anyhow!("'{name}': {ag}x{cols} absmax exceed the payload"))?;
            let absmax = Matrix::from_vec(ag, cols, rd.f64s(an, "absmax")?);
            DequantParams::Codebook { levels, absmax }
        }
        other => anyhow::bail!("'{name}': unknown param kind {other}"),
    })
}

fn decode_layer_base(payload: &[u8]) -> anyhow::Result<PackedLayer> {
    let mut rd = Rd::new(payload);
    let (layer, _) = decode_base_fields(&mut rd, false)?;
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{}': {} trailing bytes after dequant params",
        layer.name,
        rd.remaining()
    );
    Ok(layer)
}

fn decode_layer_v1(payload: &[u8]) -> anyhow::Result<(PackedLayer, LoraPair)> {
    let mut rd = Rd::new(payload);
    let (layer, rank) = decode_base_fields(&mut rd, true)?;
    let name = layer.name.clone();
    let cap = rd.remaining() / 8;
    let numel = |d: usize, what: &str| {
        d.checked_mul(rank)
            .filter(|&v| v <= cap)
            .ok_or_else(|| anyhow::anyhow!("'{name}': {what} of {d}x{rank} exceeds the payload"))
    };
    let na = numel(layer.rows, "adapter A")?;
    let a = Matrix::from_vec(layer.rows, rank, rd.f64s(na, "adapter A")?);
    let nb = numel(layer.cols, "adapter B")?;
    let b = Matrix::from_vec(layer.cols, rank, rd.f64s(nb, "adapter B")?);
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{name}': {} trailing bytes after adapter B",
        rd.remaining()
    );
    Ok((layer, LoraPair::new(a, b)))
}

pub(crate) fn decode_layer_adapter(payload: &[u8]) -> anyhow::Result<(String, LoraPair)> {
    let mut rd = Rd::new(payload);
    let name = rd.str("layer name")?;
    let rows = rd.u64("rows")? as usize;
    let cols = rd.u64("cols")? as usize;
    let rank = rd.u64("rank")? as usize;
    anyhow::ensure!(rows >= 1 && cols >= 1, "'{name}': degenerate shape {rows}x{cols}");
    // Bound untrusted counts by the bytes actually REMAINING (the header
    // is already consumed), matching the sibling decoders.
    let cap = rd.remaining() / 8;
    let numel = |d: usize, what: &str| {
        d.checked_mul(rank)
            .filter(|&v| v <= cap)
            .ok_or_else(|| anyhow::anyhow!("'{name}': {what} of {d}x{rank} exceeds the payload"))
    };
    let a = Matrix::from_vec(rows, rank, rd.f64s(numel(rows, "adapter A")?, "adapter A")?);
    let b = Matrix::from_vec(cols, rank, rd.f64s(numel(cols, "adapter B")?, "adapter B")?);
    anyhow::ensure!(
        rd.remaining() == 0,
        "'{name}': {} trailing bytes after adapter B",
        rd.remaining()
    );
    Ok((name, LoraPair::new(a, b)))
}

/// Per-file error context: builds the typed [`ServeError::Artifact`]
/// values so every failure carries the path, a classified kind, and the
/// offending layer when known.
struct FileCtx {
    path: String,
}

impl FileCtx {
    fn new(path: &Path) -> FileCtx {
        FileCtx { path: path.display().to_string() }
    }

    fn err(&self, kind: ArtifactErrorKind, layer: Option<String>, detail: String) -> ServeError {
        ServeError::Artifact { path: self.path.clone(), layer, kind, detail }
    }

    /// Wrap a structural decode failure with the layer index/name context.
    fn malformed(&self, idx: usize, n: usize, payload: &[u8], e: anyhow::Error) -> ServeError {
        self.err(
            ArtifactErrorKind::Malformed,
            peek_name(payload),
            format!("layer {idx}/{n}: {e}"),
        )
    }
}

/// Read one CRC-framed record: length, payload, checksum. Every failure
/// names the layer index (and, on a checksum mismatch, the best-effort
/// layer name) with a classified kind.
fn read_record<'a>(
    rd: &mut Rd<'a>,
    ctx: &FileCtx,
    idx: usize,
    n_layers: usize,
) -> Result<&'a [u8], ServeError> {
    let trunc = |e: anyhow::Error, stage: &str| {
        ctx.err(
            ArtifactErrorKind::Truncated,
            None,
            format!("layer {idx}/{n_layers}: {e} — file truncated {stage}"),
        )
    };
    let len = rd.u64("payload length").map_err(|e| trunc(e, "mid-header"))? as usize;
    let payload = rd.bytes(len, "payload").map_err(|e| trunc(e, "mid-layer"))?;
    let stored_crc = rd.u32("checksum").map_err(|e| trunc(e, "before checksum"))?;
    let computed = crc32(payload);
    if computed != stored_crc {
        let name = peek_name(payload);
        return Err(ctx.err(
            ArtifactErrorKind::ChecksumMismatch,
            name.clone(),
            format!(
                "layer {idx}/{n_layers} ('{}') checksum mismatch: stored {stored_crc:08x}, \
                 computed {computed:08x} — layer bytes are corrupted",
                name.as_deref().unwrap_or("<unreadable>")
            ),
        ));
    }
    Ok(payload)
}

/// Read and validate magic + version; returns the parsed version's magic.
fn read_header<'a>(
    rd: &mut Rd<'a>,
    ctx: &FileCtx,
    accept: &[(&'static [u8; 8], u32)],
) -> Result<&'static [u8; 8], ServeError> {
    let magic = rd
        .bytes(8, "magic")
        .map_err(|e| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}")))?;
    let found = accept.iter().find(|(m, _)| magic == &m[..]);
    let &(m, want_version) = found.ok_or_else(|| {
        ctx.err(
            ArtifactErrorKind::BadMagic,
            None,
            format!(
                "bad magic {:02x?} (expected one of {:?} — not a matching serving artifact)",
                magic,
                accept
                    .iter()
                    .map(|(m, _)| String::from_utf8_lossy(&m[..]).into_owned())
                    .collect::<Vec<_>>()
            ),
        )
    })?;
    let version = rd
        .u32("version")
        .map_err(|e| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}")))?;
    if version != want_version {
        return Err(ctx.err(
            ArtifactErrorKind::BadVersion,
            None,
            format!(
                "unsupported version {version} (this build reads {want_version} for {})",
                String::from_utf8_lossy(&m[..])
            ),
        ));
    }
    Ok(m)
}

fn read_layer_records<'a>(
    rd: &mut Rd<'a>,
    ctx: &FileCtx,
) -> Result<Vec<(usize, usize, &'a [u8])>, ServeError> {
    let n_layers = rd
        .u32("layer count")
        .map_err(|e| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}")))?;
    let n_layers = n_layers as usize;
    // Untrusted count: cap the reservation by what the remaining bytes could
    // possibly hold (≥ 12 bytes per record: length + checksum), so a corrupt
    // header cannot trigger a huge allocation before validation runs.
    let mut records = Vec::with_capacity(n_layers.min(rd.remaining() / 12));
    for idx in 0..n_layers {
        records.push((idx, n_layers, read_record(rd, ctx, idx, n_layers)?));
    }
    if rd.remaining() != 0 {
        return Err(ctx.err(
            ArtifactErrorKind::Malformed,
            None,
            format!("{} trailing bytes after the last layer", rd.remaining()),
        ));
    }
    Ok(records)
}

fn ensure_unique(names: &[String], ctx: &FileCtx) -> Result<(), ServeError> {
    for (i, n) in names.iter().enumerate() {
        if let Some(prev) = names[..i].iter().position(|p| p == n) {
            return Err(ctx.err(
                ArtifactErrorKind::Malformed,
                Some(n.clone()),
                format!(
                    "layer {i}/{}: duplicate layer name '{n}' (also layer {prev}) — \
                     name-addressed serving would route requests ambiguously",
                    names.len()
                ),
            ));
        }
    }
    Ok(())
}

fn read_file(path: &Path) -> Result<Vec<u8>, ServeError> {
    std::fs::read(path).map_err(|e| io_err(path, "cannot read", e))
}

/// One parsed v3 directory entry (offsets/lengths still untrusted until
/// the bounds pass in `read_v3`).
struct V3Entry {
    name: String,
    kind: u8,
    bits: u32,
    group_size: usize,
    rows: usize,
    cols: usize,
    codes_off: usize,
    codes_len: usize,
    codes_crc: u32,
    params_off: usize,
    params_len: usize,
    params_crc: u32,
}

/// The v3 reader, shared by the eager copy path (`mapped = None`: every
/// section CRC checked now, codes owned) and the zero-copy path
/// (`mapped = Some`: codes served from the mapped pages with their CRC
/// deferred to first touch — unless the platform can't honor the
/// in-place cast, in which case that section silently falls back to an
/// eagerly-checked owned copy). `bytes` is the WHOLE file.
fn read_v3(
    bytes: &[u8],
    ctx: &FileCtx,
    mapped: Option<(&Arc<MappedFile>, &Arc<str>)>,
) -> Result<PackedModel, ServeError> {
    let mut rd = Rd::new(bytes);
    read_header(&mut rd, ctx, &[(MAGIC_V3, VERSION_V3)])?;
    let trunc = |e: anyhow::Error| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}"));
    let n_layers = rd.u32("layer count").map_err(trunc)? as usize;
    // ≥ 73 bytes per directory entry: cap the untrusted reservation.
    let mut entries: Vec<V3Entry> = Vec::with_capacity(n_layers.min(rd.remaining() / 73));
    for idx in 0..n_layers {
        let mut parse = || -> anyhow::Result<V3Entry> {
            Ok(V3Entry {
                name: rd.str("layer name")?,
                kind: rd.bytes(1, "param kind")?[0],
                bits: rd.u32("bits")?,
                group_size: rd.u64("group size")? as usize,
                rows: rd.u64("rows")? as usize,
                cols: rd.u64("cols")? as usize,
                codes_off: rd.u64("codes offset")? as usize,
                codes_len: rd.u64("codes length")? as usize,
                codes_crc: rd.u32("codes checksum")?,
                params_off: rd.u64("params offset")? as usize,
                params_len: rd.u64("params length")? as usize,
                params_crc: rd.u32("params checksum")?,
            })
        };
        let entry = parse().map_err(|e| {
            ctx.err(
                ArtifactErrorKind::Truncated,
                None,
                format!("directory entry {idx}/{n_layers}: {e}"),
            )
        })?;
        entries.push(entry);
    }
    // The directory CRC covers EVERYTHING from the version to here, so a
    // flipped bit anywhere in the header (bar the magic, which has its
    // own check) is caught before any entry field is trusted further.
    let dir_end = bytes.len() - rd.remaining();
    let stored_dir_crc = rd.u32("directory checksum").map_err(trunc)?;
    let computed = crc32(&bytes[8..dir_end]);
    if computed != stored_dir_crc {
        return Err(ctx.err(
            ArtifactErrorKind::ChecksumMismatch,
            None,
            format!(
                "directory checksum mismatch: stored {stored_dir_crc:08x}, computed \
                 {computed:08x} — header bytes are corrupted"
            ),
        ));
    }

    // Structural validation: geometry sane, sections in bounds, file ends
    // exactly where the last section does (v2-parity trailing-byte check).
    let header_len = dir_end + 4;
    let mut expected_end = header_len;
    for (idx, e) in entries.iter().enumerate() {
        let malformed = |detail: String| {
            ctx.err(
                ArtifactErrorKind::Malformed,
                Some(e.name.clone()),
                format!("directory entry {idx}/{n_layers}: {detail}"),
            )
        };
        if !(1..=8).contains(&e.bits) {
            return Err(malformed(format!("'{}': bit width {} outside 1..=8", e.name, e.bits)));
        }
        if e.group_size < 1 {
            return Err(malformed(format!("'{}': group size 0", e.name)));
        }
        if e.rows < 1 || e.cols < 1 {
            return Err(malformed(format!(
                "'{}': degenerate shape {}x{}",
                e.name, e.rows, e.cols
            )));
        }
        let expect_words = e
            .rows
            .checked_mul(words_per_row(e.cols, e.bits))
            .ok_or_else(|| {
                malformed(format!("'{}': shape {}x{} overflows", e.name, e.rows, e.cols))
            })?;
        if e.codes_len != expect_words * 4 {
            return Err(malformed(format!(
                "'{}': {} code bytes, but {}x{} at {} bits needs {}",
                e.name,
                e.codes_len,
                e.rows,
                e.cols,
                e.bits,
                expect_words * 4
            )));
        }
        for (what, off, len) in
            [("codes", e.codes_off, e.codes_len), ("params", e.params_off, e.params_len)]
        {
            let end = off
                .checked_add(len)
                .filter(|&end| off >= header_len && end <= bytes.len())
                .ok_or_else(|| {
                    malformed(format!(
                        "'{}': {what} section [{off}, +{len}) outside the file ({} bytes)",
                        e.name,
                        bytes.len()
                    ))
                })?;
            expected_end = expected_end.max(end);
        }
    }
    if bytes.len() != expected_end {
        return Err(ctx.err(
            ArtifactErrorKind::Malformed,
            None,
            format!("{} trailing bytes after the last section", bytes.len() - expected_end),
        ));
    }
    let names: Vec<String> = entries.iter().map(|e| e.name.clone()).collect();
    ensure_unique(&names, ctx)?;

    let mut layers = Vec::with_capacity(entries.len());
    for (idx, e) in entries.iter().enumerate() {
        // Params sections are small (per-group scalars): always decoded —
        // and therefore CRC-checked — eagerly, on both paths.
        let pbytes = &bytes[e.params_off..e.params_off + e.params_len];
        let pcrc = crc32(pbytes);
        if pcrc != e.params_crc {
            return Err(ctx.err(
                ArtifactErrorKind::ChecksumMismatch,
                Some(e.name.clone()),
                format!(
                    "layer {idx}/{n_layers} ('{}') params checksum mismatch: stored {:08x}, \
                     computed {pcrc:08x} — params bytes are corrupted",
                    e.name, e.params_crc
                ),
            ));
        }
        let mut prd = Rd::new(pbytes);
        let params =
            decode_params(&mut prd, &e.name, e.kind, e.bits, e.rows, e.cols, e.group_size)
                .and_then(|p| {
                    anyhow::ensure!(
                        prd.remaining() == 0,
                        "'{}': {} trailing bytes after dequant params",
                        e.name,
                        prd.remaining()
                    );
                    Ok(p)
                })
                .map_err(|err| ctx.malformed(idx, n_layers, pbytes, err))?;
        let words = e.codes_len / 4;
        let zero_copy_ok = mapped.is_some_and(|(file, _)| {
            file.is_zero_copy()
                && cfg!(target_endian = "little")
                && (file.bytes().as_ptr() as usize + e.codes_off) % 4 == 0
        });
        let packed = if zero_copy_ok {
            let (file, arc_path) = mapped.unwrap();
            PackedSource::mapped(file.clone(), e.codes_off, words, e.codes_crc, arc_path.clone())
        } else {
            let cbytes = &bytes[e.codes_off..e.codes_off + e.codes_len];
            let ccrc = crc32(cbytes);
            if ccrc != e.codes_crc {
                return Err(ctx.err(
                    ArtifactErrorKind::ChecksumMismatch,
                    Some(e.name.clone()),
                    format!(
                        "layer {idx}/{n_layers} ('{}') codes checksum mismatch: stored {:08x}, \
                         computed {ccrc:08x} — code bytes are corrupted",
                        e.name, e.codes_crc
                    ),
                ));
            }
            let owned: Vec<u32> = cbytes
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            owned.into()
        };
        layers.push(PackedLayer {
            name: e.name.clone(),
            rows: e.rows,
            cols: e.cols,
            bits: e.bits,
            group_size: e.group_size,
            packed,
            params,
        });
    }
    Ok(PackedModel { layers })
}

/// Zero-copy open: mmap + in-place v3 codes; non-v3 magics fall back to
/// the eager copy path byte-identically.
fn open_mapped_at(path: &Path) -> Result<Artifact, ServeError> {
    let file = MappedFile::open(path).map_err(|e| io_err(path, "cannot map", e))?;
    if file.len() < 8 || file.bytes()[..8] != MAGIC_V3[..] {
        // Not a v3 base (or too short to tell): the copy path handles the
        // other three formats — and junk files — with the same typed
        // errors open() produces.
        drop(file);
        return open_at(path);
    }
    let ctx = FileCtx::new(path);
    let arc_path: Arc<str> = ctx.path.as_str().into();
    let file = Arc::new(file);
    let model = read_v3(file.bytes(), &ctx, Some((&file, &arc_path)))?;
    Ok(Artifact::Base(model))
}

/// Autodetecting open: the magic bytes decide which decoder runs.
fn open_at(path: &Path) -> Result<Artifact, ServeError> {
    let bytes = read_file(path)?;
    let ctx = FileCtx::new(path);
    let mut rd = Rd::new(&bytes);
    let magic = read_header(
        &mut rd,
        &ctx,
        &[
            (MAGIC_BASE, VERSION_BASE),
            (MAGIC_V3, VERSION_V3),
            (MAGIC_ADAPTER, VERSION_ADAPTER),
            (MAGIC_V1, VERSION_V1),
        ],
    )?;
    if magic == MAGIC_V3 {
        // Eager v3: re-read from the top (read_v3 owns the whole parse),
        // every CRC checked before returning, codes copied out.
        return Ok(Artifact::Base(read_v3(&bytes, &ctx, None)?));
    }
    if magic == MAGIC_ADAPTER {
        let id = rd
            .str("adapter id")
            .map_err(|e| ctx.err(ArtifactErrorKind::Truncated, None, format!("{e}")))?;
        let mut set = AdapterSet::new(&id);
        for (idx, n_layers, payload) in read_layer_records(&mut rd, &ctx)? {
            let (name, pair) = decode_layer_adapter(payload)
                .map_err(|e| ctx.malformed(idx, n_layers, payload, e))?;
            set.insert(&name, pair).map_err(|e| {
                ctx.err(
                    ArtifactErrorKind::Malformed,
                    Some(name.clone()),
                    format!("layer {idx}/{n_layers}: {e}"),
                )
            })?;
        }
        return Ok(Artifact::Adapter(set));
    }
    let v1 = magic == MAGIC_V1;
    let mut layers = Vec::new();
    let mut pairs = Vec::new();
    for (idx, n_layers, payload) in read_layer_records(&mut rd, &ctx)? {
        if v1 {
            let (layer, pair) = decode_layer_v1(payload)
                .map_err(|e| ctx.malformed(idx, n_layers, payload, e))?;
            pairs.push((layer.name.clone(), pair));
            layers.push(layer);
        } else {
            let layer = decode_layer_base(payload)
                .map_err(|e| ctx.malformed(idx, n_layers, payload, e))?;
            layers.push(layer);
        }
    }
    let names: Vec<String> = layers.iter().map(|l| l.name.clone()).collect();
    ensure_unique(&names, &ctx)?;
    let model = PackedModel { layers };
    if v1 {
        let adapters = AdapterSet::from_pairs(V1_ADAPTER_ID, pairs)
            .map_err(|e| ctx.err(ArtifactErrorKind::Malformed, None, format!("{e}")))?;
        Ok(Artifact::LegacyV1 { model, adapters })
    } else {
        Ok(Artifact::Base(model))
    }
}

fn load_base_at(path: &Path) -> Result<PackedModel, ServeError> {
    match open_at(path)? {
        Artifact::Base(model) => Ok(model),
        Artifact::LegacyV1 { .. } => Err(ServeError::Unsupported {
            detail: format!(
                "artifact {}: this is a legacy v1 (CLOQPKD1) single-tenant artifact with \
                 embedded adapters; open() it and match Artifact::LegacyV1 so the \
                 adapters are not dropped",
                path.display()
            ),
        }),
        Artifact::Adapter(_) => Err(ServeError::Unsupported {
            detail: format!(
                "artifact {}: this is an adapter artifact, not a packed base",
                path.display()
            ),
        }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_nf, quantize_rtn, QuantState};
    use crate::util::prng::Rng;

    #[test]
    fn crc32_known_vectors() {
        // IEEE CRC-32 check values.
        assert_eq!(crc32(b""), 0x0000_0000);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
    }

    fn store(tag: &str) -> ArtifactStore {
        ArtifactStore::at(
            std::env::temp_dir().join(format!("cloq_serve_{tag}_{}", std::process::id())),
        )
    }

    fn small_model(seed: u64) -> (PackedModel, AdapterSet) {
        let mut rng = Rng::new(seed);
        let w1 = Matrix::randn(20, 9, 0.3, &mut rng);
        let w2 = Matrix::randn(16, 5, 0.3, &mut rng);
        let l1 = PackedLayer::from_state("blk0.wq", &QuantState::Int(quantize_rtn(&w1, 3, 8)))
            .unwrap();
        let p1 = LoraPair::new(
            Matrix::randn(20, 2, 0.1, &mut rng),
            Matrix::randn(9, 2, 0.1, &mut rng),
        );
        let l2 = PackedLayer::from_state("blk0.wo", &QuantState::Nf(quantize_nf(&w2, 4, 8)))
            .unwrap();
        let p2 = LoraPair::new(
            Matrix::randn(16, 2, 0.1, &mut rng),
            Matrix::randn(5, 2, 0.1, &mut rng),
        );
        let set = AdapterSet::from_pairs(
            "tenant",
            vec![("blk0.wq".to_string(), p1), ("blk0.wo".to_string(), p2)],
        )
        .unwrap();
        (PackedModel::new(vec![l1, l2]), set)
    }

    #[test]
    fn base_roundtrip_preserves_forward_bits() {
        let st = store("rt");
        let (model, _) = small_model(300);
        st.save_base(&model, "model.cloqpkd2").unwrap();
        let loaded = st.load_base("model.cloqpkd2").unwrap();
        let mut rng = Rng::new(301);
        for (a, b) in model.layers.iter().zip(&loaded.layers) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.packed, b.packed);
            let x = rng.gauss_vec(a.rows);
            let (ya, yb) = (a.forward(&x, None), b.forward(&x, None));
            for (u, v) in ya.iter().zip(&yb) {
                assert_eq!(u.to_bits(), v.to_bits(), "layer {}", a.name);
            }
        }
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn open_autodetects_all_three_formats() {
        let st = store("auto");
        let (model, set) = small_model(305);
        st.save_base(&model, "base.bin").unwrap();
        st.save_adapter(&set, "adp.bin").unwrap();
        st.save_legacy_v1(&model, &set, "legacy.bin").unwrap();
        assert!(matches!(st.open("base.bin").unwrap(), Artifact::Base(_)));
        match st.open("adp.bin").unwrap() {
            Artifact::Adapter(s) => assert_eq!(s.id(), "tenant"),
            other => panic!("expected an adapter artifact, got {}", other.kind_name()),
        }
        match st.open("legacy.bin").unwrap() {
            Artifact::LegacyV1 { model: m, adapters } => {
                assert_eq!(m.layers.len(), model.layers.len());
                assert_eq!(adapters.id(), V1_ADAPTER_ID);
            }
            other => panic!("expected a legacy artifact, got {}", other.kind_name()),
        }
        // The typed accessors refuse cross-format reads with a pointer.
        let err = st.load_base("legacy.bin").unwrap_err();
        assert!(matches!(err, ServeError::Unsupported { .. }), "{err:?}");
        assert!(format!("{err}").contains("LegacyV1"), "{err}");
        let err = st.load_adapter("base.bin").unwrap_err();
        assert!(format!("{err}").contains("found a base artifact"), "{err}");
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn corruption_names_the_layer_with_a_typed_kind() {
        let st = store("bad");
        let (model, _) = small_model(302);
        let path = st.save_base(&model, "model.cloqpkd2").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Flip one bit deep inside the SECOND layer's payload.
        let n = bytes.len();
        bytes[n - 40] ^= 0x10;
        std::fs::write(st.path("flipped.cloqpkd2"), &bytes).unwrap();
        let err = st.open("flipped.cloqpkd2").unwrap_err();
        assert!(
            matches!(
                &err,
                ServeError::Artifact {
                    kind: ArtifactErrorKind::ChecksumMismatch,
                    layer: Some(l),
                    ..
                } if l == "blk0.wo"
            ),
            "{err:?}"
        );
        let msg = format!("{err}");
        assert!(msg.contains("layer 1/2"), "{msg}");
        assert!(msg.contains("checksum mismatch"), "{msg}");
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn bad_magic_and_version_rejected_with_typed_kinds() {
        let st = store("magic");
        std::fs::create_dir_all(st.dir()).unwrap();
        std::fs::write(st.path("junk.bin"), b"NOTCLOQ!rest").unwrap();
        let err = st.open("junk.bin").unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::BadMagic, .. }),
            "{err:?}"
        );

        let (model, _) = small_model(303);
        let good = st.save_base(&model, "good.cloqpkd2").unwrap();
        let mut bytes = std::fs::read(&good).unwrap();
        bytes[8] = 99; // version field
        std::fs::write(st.path("vbad.cloqpkd2"), &bytes).unwrap();
        let err = st.open("vbad.cloqpkd2").unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::BadVersion, .. }),
            "{err:?}"
        );
        assert!(format!("{err}").contains("unsupported version 99"), "{err}");
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn missing_file_is_an_io_kind() {
        let st = store("io");
        let err = st.open("never-written.bin").unwrap_err();
        assert!(
            matches!(&err, ServeError::Artifact { kind: ArtifactErrorKind::Io, .. }),
            "{err:?}"
        );
    }

    #[test]
    fn store_roundtrips_every_format_and_refuses_cross_format_reads() {
        // Successor of the deleted deprecated-shim test: the store is the
        // one entry point for all four formats, and the typed accessors
        // keep refusing cross-format reads actionably.
        let st = store("allfmt");
        let (model, set) = small_model(304);
        st.save_base(&model, "base.cloqpkd2").unwrap();
        st.save_adapter(&set, "a.cloqadp").unwrap();
        st.save_legacy_v1(&model, &set, "legacy.cloqpkd").unwrap();
        let loaded = st.load_base("base.cloqpkd2").unwrap();
        assert_eq!(loaded.layers.len(), model.layers.len());
        let aset = st.load_adapter("a.cloqadp").unwrap();
        assert_eq!(aset.id(), "tenant");
        match st.open("legacy.cloqpkd").unwrap() {
            Artifact::LegacyV1 { model: v1m, adapters } => {
                assert_eq!(v1m.layers.len(), model.layers.len());
                assert_eq!(adapters.id(), V1_ADAPTER_ID);
            }
            other => panic!("expected a legacy artifact, got {}", other.kind_name()),
        }
        let msg = format!("{}", st.load_base("legacy.cloqpkd").unwrap_err());
        assert!(msg.contains("LegacyV1"), "{msg}");
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn v3_roundtrip_both_paths_and_zero_copy_maps() {
        let st = store("v3");
        let (model, _) = small_model(306);
        st.save_base_v3(&model, "base.cloqpkd3").unwrap();
        // Eager copy path: fully checked, codes owned.
        let eager = st.open("base.cloqpkd3").unwrap().into_base().unwrap();
        // Zero-copy path: codes come straight from the mapped pages.
        let mapped = st.open_mapped("base.cloqpkd3").unwrap().into_base().unwrap();
        let mut rng = Rng::new(307);
        for ((a, b), c) in model.layers.iter().zip(&eager.layers).zip(&mapped.layers) {
            assert!(!b.packed.is_mapped());
            if cfg!(all(unix, target_endian = "little")) {
                assert!(c.packed.is_mapped(), "unix open_mapped must map v3 codes");
            }
            c.verify().unwrap();
            assert_eq!(a.packed, b.packed);
            assert_eq!(a.packed, c.packed);
            let x = rng.gauss_vec(a.rows);
            let (ya, yb, yc) = (a.forward(&x, None), b.forward(&x, None), c.forward(&x, None));
            for ((u, v), w) in ya.iter().zip(&yb).zip(&yc) {
                assert_eq!(u.to_bits(), v.to_bits(), "layer {}", a.name);
                assert_eq!(u.to_bits(), w.to_bits(), "layer {}", a.name);
            }
        }
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn v3_sections_are_page_aligned() {
        let st = store("v3align");
        let (model, _) = small_model(308);
        let path = st.save_base_v3(&model, "base.cloqpkd3").unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], MAGIC_V3);
        // Walk the directory and check every section offset is a 4096
        // multiple (the property open_mapped's in-place cast rides on).
        let mut rd = Rd::new(&bytes[12..]);
        let n = rd.u32("n").unwrap() as usize;
        assert_eq!(n, 2);
        for _ in 0..n {
            rd.str("name").unwrap();
            rd.bytes(1, "kind").unwrap();
            rd.u32("bits").unwrap();
            rd.u64("gs").unwrap();
            rd.u64("rows").unwrap();
            rd.u64("cols").unwrap();
            let codes_off = rd.u64("codes off").unwrap();
            rd.u64("codes len").unwrap();
            rd.u32("codes crc").unwrap();
            let params_off = rd.u64("params off").unwrap();
            rd.u64("params len").unwrap();
            rd.u32("params crc").unwrap();
            assert_eq!(codes_off % V3_ALIGN as u64, 0);
            assert_eq!(params_off % V3_ALIGN as u64, 0);
        }
        std::fs::remove_dir_all(st.dir()).ok();
    }

    #[test]
    fn v3_lazy_checksum_names_the_layer_on_first_touch() {
        let st = store("v3lazy");
        let (model, _) = small_model(309);
        let path = st.save_base_v3(&model, "base.cloqpkd3").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // Corrupt one byte in the FIRST code section (first 4096-aligned
        // offset past the header).
        let n = bytes.len();
        let first_section = (0..n).step_by(V3_ALIGN).find(|&o| o > 12).unwrap();
        bytes[first_section + 5] ^= 0x40;
        std::fs::write(st.path("bad.cloqpkd3"), &bytes).unwrap();
        // Eager open detects it immediately...
        let err = st.open("bad.cloqpkd3").unwrap_err();
        assert!(
            matches!(
                &err,
                ServeError::Artifact {
                    kind: ArtifactErrorKind::ChecksumMismatch,
                    layer: Some(l),
                    ..
                } if l == "blk0.wq"
            ),
            "{err:?}"
        );
        // ...while the mapped open succeeds and defers to first touch.
        // (On platforms without real mmap the codes fall back to an
        // eagerly-checked owned copy, so open_mapped fails up front —
        // also a detection, just an earlier one.)
        if !cfg!(all(unix, target_endian = "little")) {
            assert!(st.open_mapped("bad.cloqpkd3").is_err());
            std::fs::remove_dir_all(st.dir()).ok();
            return;
        }
        let mapped = st.open_mapped("bad.cloqpkd3").unwrap().into_base().unwrap();
        let bad = &mapped.layers[0];
        let err = bad.verify().unwrap_err();
        assert!(
            matches!(
                &err,
                ServeError::Artifact {
                    kind: ArtifactErrorKind::ChecksumMismatch,
                    layer: Some(l),
                    ..
                } if l == "blk0.wq"
            ),
            "{err:?}"
        );
        // The verdict is cached: the second touch fails identically.
        assert!(bad.verify().is_err());
        // The OTHER layer's section is intact and verifies clean.
        mapped.layers[1].verify().unwrap();
        std::fs::remove_dir_all(st.dir()).ok();
    }
}

//! Non-blocking request completion: the one-shot cell behind every ticket.
//!
//! The engine used to resolve tickets over `std::sync::mpsc` channels,
//! which offer exactly one consumption mode: park the calling thread in
//! `recv()`. That shape is fine for a benchmark loop and fatal for a
//! serving front-end — an HTTP connection with 64 pipelined requests in
//! flight would need 64 parked threads just to notice completions. This
//! module replaces the channel with a purpose-built one-shot
//! [`CompletionCell`]: a `Mutex`-guarded slot plus `Condvar` that supports
//! all three consumption modes from one primitive:
//!
//! * **blocking** — [`CompletionHandle::wait`] / `wait_timeout` park on the
//!   condvar exactly like `recv()` did (the engine's original contract,
//!   preserved bit-for-bit including the dropped-engine →
//!   [`ServeError::ShuttingDown`] mapping);
//! * **polling** — [`CompletionHandle::try_take`] returns `None` until the
//!   result lands, then yields it exactly once;
//! * **callback** — [`CompletionHandle::on_complete`] installs a
//!   `FnOnce(Result<T, ServeError>)` that the COMPLETING thread runs the
//!   moment it delivers (inline if the result already landed). This is the
//!   HTTP layer's mode: one thread per connection, any number of in-flight
//!   requests, zero parked waiters.
//!
//! The public face is the [`Completion`] trait, implemented by `Ticket`,
//! `ModelTicket`, and `serve::generate`'s `GenTicket`/`TokenTicket` (the
//! per-token streaming pair — one cell per token event, so the HTTP layer
//! flushes chunks from completion callbacks without parking), so generic
//! callers (the HTTP handlers, load generators, tests) drive every ticket
//! shape through one interface.
//!
//! Delivery semantics, chosen to match the old channel exactly:
//!
//! * first delivery wins; later sends are dropped (the engine never
//!   double-sends, but a late reply after a `wait_timeout` abandon must be
//!   a no-op, as it was when the receiver was dropped);
//! * dropping the LAST sender with nothing delivered delivers
//!   `Err(ServeError::ShuttingDown)` — the mpsc "disconnected" contract —
//!   so an engine that drops a `Pending` on the floor during shutdown
//!   still resolves every outstanding ticket;
//! * callbacks run on whichever thread completes the cell (an engine
//!   worker, or the caller itself when installed after delivery), NEVER
//!   under the cell's lock — a callback is free to take other locks, issue
//!   new submits, or write to a socket.

use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::serve::error::ServeError;

/// Boxed completion callback: runs exactly once with the request's result.
pub type CompleteFn<T> = Box<dyn FnOnce(Result<T, ServeError>) + Send + 'static>;

/// What the cell's slot currently holds.
enum Slot<T> {
    /// No result yet, no callback installed.
    Empty,
    /// Result delivered, not yet consumed.
    Value(Result<T, ServeError>),
    /// Caller installed a callback before the result arrived; the
    /// completing thread takes it and runs it outside the lock.
    Callback(CompleteFn<T>),
    /// Result consumed (taken by `try_take`/`wait` or fed to a callback).
    Taken,
}

struct State<T> {
    slot: Slot<T>,
    /// Live [`CompletionSender`] clones. When this reaches zero with the
    /// slot still undelivered, the drop path delivers `ShuttingDown`.
    senders: usize,
}

/// The shared one-shot cell. Senders and the handle each hold an `Arc`.
struct CompletionCell<T> {
    state: Mutex<State<T>>,
    cv: Condvar,
}

impl<T> CompletionCell<T> {
    /// Deliver `value` (first delivery wins). Returns whether the value
    /// was accepted; a pre-delivered or consumed cell drops it. Runs any
    /// installed callback outside the lock.
    fn deliver(&self, value: Result<T, ServeError>) -> bool {
        let callback = {
            let mut st = self.state.lock().unwrap();
            match std::mem::replace(&mut st.slot, Slot::Taken) {
                Slot::Empty => {
                    st.slot = Slot::Value(value);
                    self.cv.notify_all();
                    return true;
                }
                Slot::Callback(f) => f, // slot stays Taken
                prev @ (Slot::Value(_) | Slot::Taken) => {
                    st.slot = prev; // late/duplicate delivery: drop `value`
                    return false;
                }
            }
        };
        callback(value);
        true
    }
}

/// Producer side of a completion cell. Clonable (a traversal's reply path
/// moves between queues); the LAST clone to drop without delivering
/// resolves the cell with [`ServeError::ShuttingDown`].
pub(crate) struct CompletionSender<T> {
    cell: Arc<CompletionCell<T>>,
}

impl<T> CompletionSender<T> {
    /// Deliver the result. Returns `false` when the cell was already
    /// resolved (late reply after an abandoned `wait_timeout`; dropped on
    /// the floor, exactly like a send to a dropped mpsc receiver).
    pub fn send(&self, value: Result<T, ServeError>) -> bool {
        self.cell.deliver(value)
    }
}

impl<T> Clone for CompletionSender<T> {
    fn clone(&self) -> CompletionSender<T> {
        self.cell.state.lock().unwrap().senders += 1;
        CompletionSender { cell: Arc::clone(&self.cell) }
    }
}

impl<T> Drop for CompletionSender<T> {
    fn drop(&mut self) {
        let last = {
            let mut st = self.cell.state.lock().unwrap();
            st.senders -= 1;
            st.senders == 0 && matches!(st.slot, Slot::Empty | Slot::Callback(_))
        };
        if last {
            // All senders gone, nothing delivered: the engine dropped this
            // request (shutdown drain). Resolve the waiter.
            self.cell.deliver(Err(ServeError::ShuttingDown));
        }
    }
}

/// Consumer side of a completion cell; embedded in `Ticket` /
/// `ModelTicket`. One result, consumed exactly once through whichever of
/// the three modes the caller picks.
pub(crate) struct CompletionHandle<T> {
    cell: Arc<CompletionCell<T>>,
}

impl<T> CompletionHandle<T> {
    /// Non-blocking poll: the result if it has landed, else `None`.
    /// Yields the result at most once.
    pub fn try_take(&mut self) -> Option<Result<T, ServeError>> {
        let mut st = self.cell.state.lock().unwrap();
        match std::mem::replace(&mut st.slot, Slot::Taken) {
            Slot::Value(v) => Some(v),
            other => {
                st.slot = other;
                None
            }
        }
    }

    /// Install `f` to run with the result. If the result already landed,
    /// `f` runs inline on this thread before the call returns; otherwise
    /// the completing engine thread runs it at delivery.
    pub fn on_complete(self, f: CompleteFn<T>) {
        let value = {
            let mut st = self.cell.state.lock().unwrap();
            match std::mem::replace(&mut st.slot, Slot::Taken) {
                Slot::Value(v) => v,
                Slot::Empty => {
                    st.slot = Slot::Callback(f);
                    return;
                }
                Slot::Callback(_) => unreachable!("on_complete installed twice"),
                Slot::Taken => unreachable!("on_complete after the result was consumed"),
            }
        };
        f(value);
    }

    /// Park until the result lands. A cell whose senders all dropped
    /// resolves as `Err(ShuttingDown)` (delivered by the drop path), so
    /// this can never deadlock against a dying engine.
    pub fn wait(mut self) -> Result<T, ServeError> {
        let mut st = self.cell.state.lock().unwrap();
        loop {
            if let Slot::Value(_) = st.slot {
                drop(st);
                return self.try_take().expect("slot checked Value under the lock");
            }
            st = self.cell.cv.wait(st).unwrap();
        }
    }

    /// [`wait`](CompletionHandle::wait) with a deadline:
    /// [`ServeError::Timeout`] once `timeout` elapses with no result.
    pub fn wait_timeout(mut self, timeout: Duration) -> Result<T, ServeError> {
        let t0 = Instant::now();
        let mut st = self.cell.state.lock().unwrap();
        loop {
            if let Slot::Value(_) = st.slot {
                drop(st);
                return self.try_take().expect("slot checked Value under the lock");
            }
            let left = match timeout.checked_sub(t0.elapsed()) {
                Some(left) => left,
                None => return Err(ServeError::Timeout { elapsed: t0.elapsed() }),
            };
            let (guard, res) = self.cell.cv.wait_timeout(st, left).unwrap();
            st = guard;
            if res.timed_out() && !matches!(st.slot, Slot::Value(_)) {
                return Err(ServeError::Timeout { elapsed: t0.elapsed() });
            }
        }
    }
}

/// Create a linked sender/handle pair over a fresh cell.
pub(crate) fn channel<T>() -> (CompletionSender<T>, CompletionHandle<T>) {
    let cell = Arc::new(CompletionCell {
        state: Mutex::new(State { slot: Slot::Empty, senders: 1 }),
        cv: Condvar::new(),
    });
    (CompletionSender { cell: Arc::clone(&cell) }, CompletionHandle { cell })
}

/// The unified ticket interface: every submitted request — single-layer
/// `Ticket` or model/session `ModelTicket` — resolves through one of three
/// consumption modes. Generic callers (the HTTP front-end's dispatch path,
/// load generators) take `impl Completion<Output = _>` and never care
/// which ticket shape they hold.
///
/// `wait` and `wait_timeout` are the pre-existing blocking API, now
/// trivial wrappers over the shared cell; `try_wait` and `on_complete`
/// are the non-blocking additions.
pub trait Completion: Send {
    type Output: Send + 'static;

    /// Non-blocking poll: `Some(result)` once resolved (at most once).
    fn try_wait(&mut self) -> Option<Result<Self::Output, ServeError>>;

    /// Consume the ticket, installing a callback the completing thread
    /// runs with the result (inline if already resolved). The callback
    /// runs outside all engine locks.
    fn on_complete(self, f: CompleteFn<Self::Output>);

    /// Block until the engine answers. An engine that dropped before
    /// answering reports [`ServeError::ShuttingDown`].
    fn wait(self) -> Result<Self::Output, ServeError>;

    /// [`wait`](Completion::wait) with a deadline: [`ServeError::Timeout`]
    /// once `timeout` elapses with no reply. The deadline is a CALLER-side
    /// contract only — the request is not cancelled; it still holds its
    /// live backpressure slot and its late reply is dropped.
    fn wait_timeout(self, timeout: Duration) -> Result<Self::Output, ServeError>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::thread;

    #[test]
    fn try_take_polls_then_yields_once() {
        let (tx, mut rx) = channel::<u32>();
        assert!(rx.try_take().is_none());
        assert!(tx.send(Ok(7)));
        assert_eq!(rx.try_take().unwrap().unwrap(), 7);
        assert!(rx.try_take().is_none(), "a result is consumed exactly once");
    }

    #[test]
    fn wait_blocks_until_cross_thread_delivery() {
        let (tx, rx) = channel::<u32>();
        let t = thread::spawn(move || rx.wait());
        thread::sleep(Duration::from_millis(10));
        assert!(tx.send(Ok(42)));
        assert_eq!(t.join().unwrap().unwrap(), 42);
    }

    #[test]
    fn dropping_last_sender_resolves_shutting_down() {
        let (tx, rx) = channel::<u32>();
        let tx2 = tx.clone();
        drop(tx);
        let t = thread::spawn(move || rx.wait());
        thread::sleep(Duration::from_millis(5));
        drop(tx2); // LAST sender: delivers ShuttingDown
        assert!(matches!(t.join().unwrap(), Err(ServeError::ShuttingDown)));
    }

    #[test]
    fn wait_timeout_times_out_then_late_send_is_dropped() {
        let (tx, rx) = channel::<u32>();
        let err = rx.wait_timeout(Duration::from_millis(5)).unwrap_err();
        assert!(matches!(err, ServeError::Timeout { .. }), "{err:?}");
        assert!(!tx.send(Ok(1)), "late reply after an abandoned wait is dropped");
    }

    #[test]
    fn callback_installed_before_delivery_runs_on_completing_thread() {
        let (tx, rx) = channel::<u32>();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        rx.on_complete(Box::new(move |r| {
            assert_eq!(r.unwrap(), 9);
            h.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(hits.load(Ordering::SeqCst), 0, "not yet delivered");
        let t = thread::spawn(move || tx.send(Ok(9)));
        assert!(t.join().unwrap());
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn callback_installed_after_delivery_runs_inline() {
        let (tx, rx) = channel::<u32>();
        assert!(tx.send(Err(ServeError::ShuttingDown)));
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        rx.on_complete(Box::new(move |r| {
            assert!(matches!(r, Err(ServeError::ShuttingDown)));
            h.fetch_add(1, Ordering::SeqCst);
        }));
        assert_eq!(hits.load(Ordering::SeqCst), 1, "ran inline before on_complete returned");
    }

    #[test]
    fn sender_drop_fires_installed_callback() {
        let (tx, rx) = channel::<u32>();
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        rx.on_complete(Box::new(move |r| {
            assert!(matches!(r, Err(ServeError::ShuttingDown)));
            h.fetch_add(1, Ordering::SeqCst);
        }));
        drop(tx);
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn first_delivery_wins() {
        let (tx, rx) = channel::<u32>();
        assert!(tx.send(Ok(1)));
        assert!(!tx.send(Ok(2)));
        assert_eq!(rx.wait().unwrap(), 1);
    }
}

//! The serving subsystem's structured error type.
//!
//! Every public failure path in `serve/` — admission refusals, queue
//! backpressure, kernel panics, artifact corruption — resolves to one
//! [`ServeError`] variant, so callers branch with `matches!` instead of
//! string-searching `anyhow` messages (how do you tell "overloaded, retry
//! with backoff" from "unknown adapter, fail the tenant" from "the engine
//! is draining, re-route" when all three are opaque strings?). The
//! taxonomy is locked down by `rust/tests/errors_serve.rs`.
//!
//! `ServeError` implements [`std::error::Error`], so it flows into
//! `anyhow::Result` contexts with `?` unchanged — the coordinator and
//! other offline callers keep compiling while serving callers get typed
//! matching.
//!
//! Field conventions: `layer` / `adapter` fields carry the NAME the
//! request used (errors must be actionable at 3 a.m.); free-text context
//! that doesn't affect dispatch lives in `detail` strings.

use std::fmt;

/// What went wrong with a serving artifact file — the `kind` field of
/// [`ServeError::Artifact`]. Classified where the failure is detected, so
/// a caller can distinguish "the disk is corrupt" (re-fetch the file) from
/// "the format is foreign" (wrong path or wrong build).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactErrorKind {
    /// The file could not be read or written at all.
    Io,
    /// The leading magic bytes match no known serving-artifact format.
    BadMagic,
    /// Known format, unsupported version number.
    BadVersion,
    /// The byte stream ended mid-record (header, payload, or checksum).
    Truncated,
    /// A layer payload's CRC-32 does not match its stored checksum.
    ChecksumMismatch,
    /// Structurally invalid content after the checksum passed: shape lies,
    /// impossible counts, trailing bytes, duplicate layer names.
    Malformed,
}

impl fmt::Display for ArtifactErrorKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            ArtifactErrorKind::Io => "io",
            ArtifactErrorKind::BadMagic => "bad-magic",
            ArtifactErrorKind::BadVersion => "bad-version",
            ArtifactErrorKind::Truncated => "truncated",
            ArtifactErrorKind::ChecksumMismatch => "checksum-mismatch",
            ArtifactErrorKind::Malformed => "malformed",
        })
    }
}

/// Structured error for every public failure path of the serving façade.
///
/// Variants are the dispatch surface; their `String` fields name the
/// entity the caller asked about. Match on variants:
///
/// ```ignore
/// match ticket.wait() {
///     Err(ServeError::Overloaded { .. }) => retry_with_backoff(),
///     Err(ServeError::UnknownAdapter { adapter }) => evict_tenant(&adapter),
///     Err(ServeError::ShuttingDown) => reroute_to_peer(),
///     other => other?,
/// }
/// ```
#[derive(Clone, Debug)]
pub enum ServeError {
    /// The request named a layer the served model does not have (or a
    /// `LayerId` resolved against a different model).
    UnknownLayer { layer: String },
    /// The named adapter is not currently registered: never registered,
    /// evicted by the byte budget, or unregistered.
    UnknownAdapter { adapter: String },
    /// The adapter is registered but carries no delta for the request:
    /// `layer: Some(_)` — a single-layer request at that layer;
    /// `layer: None` — a model request whose route it covers nowhere.
    AdapterMismatch { adapter: String, layer: Option<String> },
    /// An activation or adapter does not fit the named layer's shape.
    ShapeMismatch { layer: String, detail: String },
    /// A layer route that cannot be traversed: empty, out of range, or a
    /// chain break (one hop's output width != the next hop's input width).
    BadRoute { detail: String },
    /// Admission refused at `max_pending` live hop slots (queued or
    /// mid-kernel). Transient — retry later.
    Overloaded { max_pending: usize },
    /// Admissions are closed ([`close`]/[`shutdown`] was called), or the
    /// engine dropped before answering.
    ///
    /// [`close`]: crate::serve::ServeEngine::close
    /// [`shutdown`]: crate::serve::ServeEngine::shutdown
    ShuttingDown,
    /// A `wait_timeout` deadline elapsed before the engine replied.
    /// `elapsed` is the wall time actually waited. The request itself is
    /// NOT cancelled: it still holds its live slot, still executes, and
    /// its reply is dropped when it arrives (the waiter is gone) — see
    /// [`Ticket::wait_timeout`](crate::serve::Ticket::wait_timeout).
    Timeout { elapsed: std::time::Duration },
    /// The kernel panicked serving the micro-batch this request rode in
    /// (`hop: Some(_)` names the failing hop of a model request). The
    /// worker survives; only the batch's riders fail.
    WorkerPanic { layer: String, batch: usize, hop: Option<usize> },
    /// A session's caller-supplied step function panicked or returned a
    /// misshapen next input, after `forward` completed passes.
    StepFailed { forward: usize, detail: String },
    /// A serving artifact could not be read or written. `layer` is the
    /// offending layer's name when the payload still reveals it.
    Artifact { path: String, layer: Option<String>, kind: ArtifactErrorKind, detail: String },
    /// Invalid configuration or construction input (builder validation,
    /// duplicate names, zero-step sessions, over-budget adapter sets).
    InvalidConfig { detail: String },
    /// The operation is not supported for this input (e.g. packing an
    /// fp-base method, or reading a legacy artifact through a base-only
    /// accessor).
    Unsupported { detail: String },
}

impl ServeError {
    /// Stable machine-readable error code — the `code` field of every
    /// JSON error body the HTTP front-end emits. Part of the wire
    /// contract: codes never change meaning and never get reused. The
    /// match is exhaustive ON PURPOSE (no `_` arm): adding a variant
    /// without assigning its wire code is a compile error, not a silent
    /// `"internal"` fallback.
    pub fn code(&self) -> &'static str {
        match self {
            ServeError::UnknownLayer { .. } => "unknown-layer",
            ServeError::UnknownAdapter { .. } => "unknown-adapter",
            ServeError::AdapterMismatch { .. } => "adapter-mismatch",
            ServeError::ShapeMismatch { .. } => "shape-mismatch",
            ServeError::BadRoute { .. } => "bad-route",
            ServeError::Overloaded { .. } => "overloaded",
            ServeError::ShuttingDown => "shutting-down",
            ServeError::Timeout { .. } => "timeout",
            ServeError::WorkerPanic { .. } => "worker-panic",
            ServeError::StepFailed { .. } => "step-failed",
            ServeError::Artifact { .. } => "artifact",
            ServeError::InvalidConfig { .. } => "invalid-config",
            ServeError::Unsupported { .. } => "unsupported",
        }
    }

    /// The HTTP status this error maps to on the wire (the other half of
    /// the contract [`code`](ServeError::code) anchors). Taxonomy: the
    /// caller named something that does not exist → 404; the request
    /// itself is malformed or impossible → 400; transient pressure the
    /// caller should back off from → 429; the engine is going away → 503;
    /// a caller-side deadline elapsed → 504 (the gateway-timeout shape:
    /// the work continues, the reply is gone); the engine broke → 500.
    /// Exhaustive like `code()` — a new variant must pick its status.
    pub fn http_status(&self) -> u16 {
        match self {
            ServeError::UnknownLayer { .. } | ServeError::UnknownAdapter { .. } => 404,
            ServeError::AdapterMismatch { .. }
            | ServeError::ShapeMismatch { .. }
            | ServeError::BadRoute { .. }
            | ServeError::InvalidConfig { .. }
            | ServeError::Unsupported { .. } => 400,
            ServeError::Overloaded { .. } => 429,
            ServeError::ShuttingDown => 503,
            ServeError::Timeout { .. } => 504,
            ServeError::WorkerPanic { .. }
            | ServeError::StepFailed { .. }
            | ServeError::Artifact { .. } => 500,
        }
    }
}

impl fmt::Display for ServeError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ServeError::UnknownLayer { layer } => {
                write!(f, "no such layer '{layer}' in the served model")
            }
            ServeError::UnknownAdapter { adapter } => write!(
                f,
                "adapter '{adapter}' is not registered (never registered, evicted, \
                 or unregistered)"
            ),
            ServeError::AdapterMismatch { adapter, layer: Some(layer) } => {
                write!(f, "adapter '{adapter}' carries no delta for layer '{layer}'")
            }
            ServeError::AdapterMismatch { adapter, layer: None } => {
                write!(f, "adapter '{adapter}' carries no delta for any layer on the route")
            }
            ServeError::ShapeMismatch { layer, detail } => write!(f, "layer '{layer}': {detail}"),
            ServeError::BadRoute { detail } => f.write_str(detail),
            ServeError::Overloaded { max_pending } => write!(
                f,
                "engine overloaded: {max_pending} hops queued or in flight at max_pending; \
                 retry later"
            ),
            ServeError::ShuttingDown => f.write_str("engine is shutting down; request refused"),
            ServeError::Timeout { elapsed } => write!(
                f,
                "no reply within {:.3}s; the request still completes in the engine and its \
                 reply is dropped",
                elapsed.as_secs_f64()
            ),
            ServeError::WorkerPanic { layer, batch, hop: None } => {
                write!(f, "layer '{layer}': serving batch of {batch} panicked in the kernel")
            }
            ServeError::WorkerPanic { layer, batch, hop: Some(hop) } => write!(
                f,
                "model request failed at hop {hop}: layer '{layer}' panicked serving a \
                 batch of {batch}"
            ),
            ServeError::StepFailed { forward, detail } => {
                write!(f, "session step after forward {forward}: {detail}")
            }
            ServeError::Artifact { path, kind, detail, .. } => {
                write!(f, "artifact {path} [{kind}]: {detail}")
            }
            ServeError::InvalidConfig { detail } => f.write_str(detail),
            ServeError::Unsupported { detail } => f.write_str(detail),
        }
    }
}

impl std::error::Error for ServeError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_entities() {
        let e = ServeError::UnknownLayer { layer: "wq".to_string() };
        assert!(format!("{e}").contains("no such layer 'wq'"), "{e}");
        let e = ServeError::AdapterMismatch { adapter: "t".to_string(), layer: None };
        assert!(format!("{e}").contains("any layer on the route"), "{e}");
        let e = ServeError::WorkerPanic { layer: "l".to_string(), batch: 4, hop: Some(2) };
        let msg = format!("{e}");
        assert!(msg.contains("hop 2") && msg.contains("'l'") && msg.contains("4"), "{msg}");
        let e = ServeError::Timeout { elapsed: std::time::Duration::from_millis(1500) };
        let msg = format!("{e}");
        assert!(msg.contains("1.500s") && msg.contains("reply is dropped"), "{msg}");
    }

    #[test]
    fn converts_into_anyhow_with_question_mark() {
        fn typed(fail: bool) -> Result<usize, ServeError> {
            if fail {
                return Err(ServeError::ShuttingDown);
            }
            Ok(7)
        }
        fn inner(fail: bool) -> anyhow::Result<usize> {
            Ok(typed(fail)?)
        }
        assert_eq!(inner(false).unwrap(), 7);
        let msg = format!("{}", inner(true).unwrap_err());
        assert!(msg.contains("shutting down"), "{msg}");
    }

    /// One instance of every variant — keep in sync with the enum (the
    /// exhaustive matches in `code`/`http_status` make forgetting one
    /// there impossible; this list keeps the TESTS honest too).
    fn all_variants() -> Vec<ServeError> {
        vec![
            ServeError::UnknownLayer { layer: "l".into() },
            ServeError::UnknownAdapter { adapter: "a".into() },
            ServeError::AdapterMismatch { adapter: "a".into(), layer: None },
            ServeError::ShapeMismatch { layer: "l".into(), detail: "d".into() },
            ServeError::BadRoute { detail: "d".into() },
            ServeError::Overloaded { max_pending: 8 },
            ServeError::ShuttingDown,
            ServeError::Timeout { elapsed: std::time::Duration::from_millis(1) },
            ServeError::WorkerPanic { layer: "l".into(), batch: 1, hop: None },
            ServeError::StepFailed { forward: 1, detail: "d".into() },
            ServeError::Artifact {
                path: "/p".into(),
                layer: None,
                kind: ArtifactErrorKind::Io,
                detail: "d".into(),
            },
            ServeError::InvalidConfig { detail: "d".into() },
            ServeError::Unsupported { detail: "d".into() },
        ]
    }

    #[test]
    fn every_variant_has_a_distinct_stable_code() {
        let codes: Vec<&'static str> = all_variants().iter().map(|e| e.code()).collect();
        let mut dedup = codes.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), codes.len(), "codes must be unique: {codes:?}");
        for code in codes {
            assert!(
                code.chars().all(|c| c.is_ascii_lowercase() || c == '-'),
                "codes are lowercase-kebab slugs: {code}"
            );
        }
    }

    #[test]
    fn http_status_mapping_is_the_locked_wire_contract() {
        let expect: &[(&str, u16)] = &[
            ("unknown-layer", 404),
            ("unknown-adapter", 404),
            ("adapter-mismatch", 400),
            ("shape-mismatch", 400),
            ("bad-route", 400),
            ("overloaded", 429),
            ("shutting-down", 503),
            ("timeout", 504),
            ("worker-panic", 500),
            ("step-failed", 500),
            ("artifact", 500),
            ("invalid-config", 400),
            ("unsupported", 400),
        ];
        let variants = all_variants();
        assert_eq!(variants.len(), expect.len());
        for (e, &(code, status)) in variants.iter().zip(expect) {
            assert_eq!(e.code(), code, "{e:?}");
            assert_eq!(e.http_status(), status, "{e:?}");
        }
    }

    #[test]
    fn artifact_kind_displays_as_a_slug() {
        assert_eq!(format!("{}", ArtifactErrorKind::ChecksumMismatch), "checksum-mismatch");
        let e = ServeError::Artifact {
            path: "/tmp/x".to_string(),
            layer: Some("blk0.wo".to_string()),
            kind: ArtifactErrorKind::Truncated,
            detail: "layer 1/2: file ended".to_string(),
        };
        let msg = format!("{e}");
        assert!(msg.contains("/tmp/x") && msg.contains("[truncated]"), "{msg}");
    }
}

//! Per-session decode state behind the [`SessionState`] trait.
//!
//! Autoregressive decode needs SOMETHING that turns the token history into
//! the next forward's input activation. For a transformer that something
//! is an embedding lookup plus a per-layer KV cache; for this engine —
//! whose packed layers are stateless matvec chains — it is any
//! deterministic fold over the absorbed tokens. The trait keeps the decode
//! loop agnostic: [`GenCore`](super::GenCore) absorbs prompt and sampled
//! tokens through it and reads back the next input, so a real KV-cache
//! state can slot in later without touching the loop, the batcher, or the
//! parity contract (ROADMAP follow-up).
//!
//! The default [`HashEmbedState`] is a decayed hash-embedding recurrence:
//!
//! ```text
//!   h ← h·DECAY + embed(token),   embed(token)[i] ∈ [-1, 1) pseudo-random
//! ```
//!
//! `embed` is a pure function of `(token, i)` (a [`SplitMix64`] stream
//! keyed by the token id), so the state — and therefore every logits
//! vector a generation produces — is bit-determined by the token history
//! alone. That is the property the 0-ULP parity contract rides on: the
//! engine path and the serial reference absorb identical histories through
//! identical f64 arithmetic.

use crate::util::prng::SplitMix64;

/// Per-session decode state: folds absorbed tokens into the next forward's
/// input activation. Implementations must be deterministic — `x()` after a
/// given absorb history must be bit-identical across runs, because the
/// greedy-parity contract compares engine and serial paths at 0 ULP.
pub trait SessionState: Send + 'static {
    /// Fold one token (prompt or freshly sampled) into the state.
    fn absorb(&mut self, token: i32);
    /// The next forward's input activation (width = route head's `rows`).
    fn x(&self) -> Vec<f64>;
}

/// Decay applied to the running state per absorbed token (exactly
/// representable in binary, so the recurrence is reproducible arithmetic,
/// not an approximation).
pub const EMBED_DECAY: f64 = 0.5;

/// Salt mixed into the per-token embedding stream so token id 0 does not
/// collapse onto the all-zeros SplitMix64 seed.
const EMBED_SALT: u64 = 0x9e37_79b9_7f4a_7c15;

/// The pseudo-embedding of one token: `dim` values in `[-1, 1)`, a pure
/// deterministic function of `(token, index)`.
pub fn hash_embed(token: i32, dim: usize) -> Vec<f64> {
    let key = (token as u32 as u64).wrapping_mul(0x2545_f491_4f6c_dd1d) ^ EMBED_SALT;
    let mut sm = SplitMix64::new(key);
    (0..dim)
        .map(|_| {
            // Top 53 bits → an exact dyadic rational in [0, 1), then an
            // affine map to [-1, 1). Every step is exact f64 arithmetic.
            let u = sm.next_u64() >> 11;
            u as f64 * (2.0 / 9_007_199_254_740_992.0) - 1.0
        })
        .collect()
}

/// The default [`SessionState`]: a fixed-width decayed hash-embedding
/// recurrence (module docs). Cheap (O(dim) per token, no model access),
/// deterministic, and sensitive to the whole token history — enough to
/// exercise the decode loop, the batcher, and the parity suite without a
/// trained embedding table.
pub struct HashEmbedState {
    h: Vec<f64>,
}

impl HashEmbedState {
    /// Fresh state producing activations of width `dim` (the route head's
    /// input width).
    pub fn new(dim: usize) -> HashEmbedState {
        HashEmbedState { h: vec![0.0; dim] }
    }

    pub fn dim(&self) -> usize {
        self.h.len()
    }
}

impl SessionState for HashEmbedState {
    fn absorb(&mut self, token: i32) {
        let e = hash_embed(token, self.h.len());
        for (hi, ei) in self.h.iter_mut().zip(e) {
            *hi = *hi * EMBED_DECAY + ei;
        }
    }

    fn x(&self) -> Vec<f64> {
        self.h.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embed_is_deterministic_and_bounded() {
        let a = hash_embed(42, 16);
        let b = hash_embed(42, 16);
        assert_eq!(a, b, "pure function of (token, index)");
        assert!(a.iter().all(|v| (-1.0..1.0).contains(v)), "{a:?}");
        let c = hash_embed(43, 16);
        assert_ne!(a, c, "distinct tokens must embed differently");
    }

    #[test]
    fn state_is_a_function_of_the_token_history() {
        let mut s1 = HashEmbedState::new(8);
        let mut s2 = HashEmbedState::new(8);
        for t in [1, 70, 71, 2] {
            s1.absorb(t);
            s2.absorb(t);
        }
        assert_eq!(s1.x(), s2.x(), "same history, bit-identical state");
        s2.absorb(9);
        assert_ne!(s1.x(), s2.x());
        assert_eq!(s1.dim(), 8);
        assert_eq!(s1.x().len(), 8);
    }

    #[test]
    fn order_matters() {
        let mut ab = HashEmbedState::new(6);
        ab.absorb(10);
        ab.absorb(20);
        let mut ba = HashEmbedState::new(6);
        ba.absorb(20);
        ba.absorb(10);
        assert_ne!(ab.x(), ba.x(), "the decay makes the fold order-sensitive");
    }
}
